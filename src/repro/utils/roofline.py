"""Roofline terms for TPU v5e from compiled dry-run artifacts.

Hardware constants (per chip):
  * 197 TFLOP/s bf16 peak (MXU)
  * 819 GB/s HBM bandwidth
  * ~50 GB/s/link ICI (one link charged per mesh axis; conservative)

All HLO-derived quantities are PER DEVICE (the compiled module is the
per-device SPMD program), so terms are seconds-per-step directly:

  compute_s    = HLO_FLOPs_per_device / 197e12
  memory_s     = HLO_bytes_per_device / 819e9
  collective_s = sum_axis collective_bytes_axis / 50e9

MODEL_FLOPS is the analytic useful compute: 6*N*D for dense training
(2*N*D prefill, 2*N*B_tokens decode), with N = active params for MoE.
The ratio MODEL_FLOPS / (HLO_FLOPs * chips) exposes remat/dispatch waste.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

from repro.configs.base import ModelConfig, ShapeConfig

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    collective_by_axis: Dict[str, float]
    hlo_flops_per_dev: float
    hlo_bytes_per_dev: float
    model_flops_total: float
    useful_ratio: float          # MODEL_FLOPS / (HLO_FLOPs * chips)
    bottleneck: str
    step_time_s: float           # max of the three terms (no overlap)
    roofline_frac: float         # compute_s / step_time_s
    memory_per_dev_gb: Optional[float] = None
    notes: str = ""

    def row(self) -> str:
        col = ",".join(f"{a}:{v*1e3:.2f}ms"
                       for a, v in sorted(self.collective_by_axis.items()))
        mem = f"{self.memory_per_dev_gb:.2f}" if self.memory_per_dev_gb \
            else "-"
        return (f"| {self.arch} | {self.shape} | {self.mesh} "
                f"| {self.compute_s*1e3:.2f} | {self.memory_s*1e3:.2f} "
                f"| {self.collective_s*1e3:.2f} ({col}) "
                f"| **{self.bottleneck}** | {self.useful_ratio:.2f} "
                f"| {self.roofline_frac:.2f} | {mem} |")


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Analytic 'useful' FLOPs per step: 6ND train / 2ND prefill / 2NB
    decode (N = active params)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def terms_from_hlo(arch: str, shape: ShapeConfig, mesh_name: str, chips: int,
                   hlo_costs, cfg: ModelConfig,
                   memory_per_dev_gb: Optional[float] = None,
                   notes: str = "") -> RooflineTerms:
    compute_s = hlo_costs.flops / PEAK_FLOPS
    memory_s = hlo_costs.bytes / HBM_BW
    col_by_axis = {a: b / ICI_BW
                   for a, b in hlo_costs.collective_bytes_by_axis.items()}
    collective_s = sum(col_by_axis.values())
    mf = model_flops(cfg, shape)
    useful = mf / max(hlo_costs.flops * chips, 1.0)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    step = max(terms.values())
    return RooflineTerms(
        arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        collective_by_axis=col_by_axis,
        hlo_flops_per_dev=hlo_costs.flops,
        hlo_bytes_per_dev=hlo_costs.bytes,
        model_flops_total=mf, useful_ratio=useful,
        bottleneck=bottleneck, step_time_s=step,
        roofline_frac=compute_s / step if step > 0 else 0.0,
        memory_per_dev_gb=memory_per_dev_gb, notes=notes)


TABLE_HEADER = (
    "| arch | shape | mesh | compute (ms) | memory (ms) "
    "| collective (ms, by axis) | bottleneck | useful 6ND/HLO "
    "| roofline frac | mem/dev (GB) |\n"
    "|---|---|---|---|---|---|---|---|---|---|")
