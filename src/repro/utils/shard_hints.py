"""Logical activation-sharding hints (MaxText-style).

Models annotate activations with *logical* axis names
(``hint(x, "batch", "seq", "heads", "head_dim")``); a context manager maps
logical names to mesh axes per run.  Outside a mesh context (smoke tests,
1-device examples) hints are identity, so model code stays mesh-agnostic.

Rules drop axes that are absent from the ambient mesh or do not divide the
dimension, so one rule set serves every (arch x shape x mesh) cell.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

Axes = Union[None, str, Tuple[str, ...]]

# logical axis -> mesh axis (or tuple). The default table serves train and
# prefill shapes; decode/long-context runs override via logical_axis_rules.
DEFAULT_RULES: Dict[str, Axes] = {
    "batch": ("pod", "data"),
    "seq": None,
    "kv_seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "expert": "model",
    # expert_mlp also maps to model: when EP applies (expert count divides
    # the axis) the duplicate-axis guard in hint() drops it automatically,
    # leaving EP; otherwise the expert dim drops and the FFN width is TP.
    "expert_mlp": "model",
    "capacity": None,
    "flat_tokens": ("pod", "data"),
    "state": None,
}

_local = threading.local()


def _rules() -> Dict[str, Axes]:
    return getattr(_local, "rules", DEFAULT_RULES)


@contextlib.contextmanager
def logical_axis_rules(overrides: Dict[str, Axes]):
    old = _rules()
    _local.rules = {**old, **overrides}
    try:
        yield
    finally:
        _local.rules = old


def _current_mesh():
    try:
        mesh = jax._src.mesh.thread_resources.env.physical_mesh
        if mesh.empty:
            return None
        return mesh
    except Exception:
        return None


def hint(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Apply with_sharding_constraint mapping logical names to mesh axes.

    No-op outside a mesh context. Axes that don't exist in the mesh or
    don't divide the dimension are dropped (never fails)."""
    mesh = _current_mesh()
    if mesh is None:
        return x
    if len(logical) != x.ndim:
        return x
    rules = _rules()
    names = set(mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    spec = []
    used: set = set()
    for dim, name in zip(x.shape, logical):
        ax = rules.get(name) if name is not None else None
        if ax is None:
            spec.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        axes = tuple(a for a in axes if a in names and a not in used)
        total = int(np.prod([sizes[a] for a in axes])) if axes else 1
        if axes and dim % total == 0:
            spec.append(axes)
            used.update(axes)
        else:
            spec.append(None)
    spec = [s if not isinstance(s, tuple) else (s[0] if len(s) == 1 else s)
            for s in spec]
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


def decode_rules(sequence_parallel: bool) -> Dict[str, Axes]:
    """Rule overrides for decode shapes. SP (batch=1 long-context): the KV
    sequence axis shards over 'data' (flash-decode style)."""
    if sequence_parallel:
        return {"batch": None, "seq": "data", "kv_seq": "data"}
    return {"kv_seq": None}
