"""Post-compile HLO analysis: FLOPs, bytes, and collective traffic.

Why not ``compiled.cost_analysis()`` alone?  XLA's HloCostAnalysis visits a
``while`` body ONCE (verified empirically in this repo: an 8-step scan
reports 1/8 of the unrolled flops).  Our models scan over layers, so raw
numbers undercount by ~num_layers.

This module parses ``compiled.as_text()`` (post-optimization, post-fusion):

* while trip counts come from XLA's own annotation
  (``backend_config={"known_trip_count":{"n":...}}``) — exact, works for
  nested scans and unequal encoder/decoder depths;
* dot/conv FLOPs from result shape x contracted dims (symbol tables resolve
  operand shapes);
* bytes accessed = operand + result bytes per instruction; fusions count
  once at the call site (internals are register-resident post-fusion);
* collective bytes per mesh axis, attributed by replica-group stride
  (row-major device order), with ring-model per-device wire traffic.
"""

from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

SKIP_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
            "after-all", "iota", "partition-id", "replica-id", "copy-done",
            "copy-start"}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _first_shape(type_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dtype, dims = m.groups()
    return dtype, [int(d) for d in dims.split(",") if d]


@dataclasses.dataclass
class Instruction:
    name: str
    op: str
    type_str: str
    operands: List[str]
    line: str


def _parse_instruction(line: str) -> Optional[Instruction]:
    line = line.strip()
    if line.startswith("ROOT "):
        line = line[5:]
    m = re.match(r"^%?([\w.\-]+)\s*=\s*(.*)$", line)
    if not m:
        return None
    name, rhs = m.groups()
    # TYPE: either a tuple "( ... )" or a single token like f32[4,8]{1,0}
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                break
        type_str, rest = rhs[:i + 1], rhs[i + 1:].strip()
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        type_str, rest = rhs[:sp], rhs[sp + 1:].strip()
    mo = re.match(r"([\w\-]+)\(", rest)
    if not mo:
        return None
    op = mo.group(1)
    # operands: inside the eventual top-level parens
    start = rest.find("(")
    depth = 0
    for i in range(start, len(rest)):
        depth += rest[i] == "("
        depth -= rest[i] == ")"
        if depth == 0:
            break
    args = rest[start + 1:i]
    # An operand is either a bare reference ("%name" / "name") or, in newer
    # XLA dumps, type-prefixed ("f32[32,128]{1,0} %name") — the reference is
    # always the last whitespace-separated token.
    operands = [a.strip().split()[-1].lstrip("%")
                for a in _split_top(args) if a.strip()]
    return Instruction(name=name, op=op, type_str=type_str,
                       operands=[o for o in operands if o], line=line)


def _split_top(s: str) -> List[str]:
    out, depth, cur = [], 0, []
    for ch in s:
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
            continue
        depth += ch in "([{"
        depth -= ch in ")]}"
        cur.append(ch)
    if cur:
        out.append("".join(cur))
    return out


def _parse_computations(hlo: str) -> Tuple[Dict[str, List[Instruction]],
                                           Optional[str]]:
    comps: Dict[str, List[Instruction]] = {}
    entry = None
    current = None
    for raw in hlo.splitlines():
        line = raw.strip()
        if line.endswith("{") and "(" in line and "=" not in \
                line.split("(", 1)[0]:
            header = line
            is_entry = header.startswith("ENTRY")
            if is_entry:
                header = header[len("ENTRY"):].strip()
            mn = re.match(r"%?([\w.\-]+)\s*\(", header)
            if mn:
                current = mn.group(1)
                comps[current] = []
                if is_entry:
                    entry = current
            continue
        if line.startswith("}"):
            current = None
            continue
        if current is not None and "=" in line:
            inst = _parse_instruction(line)
            if inst is not None:
                comps[current].append(inst)
    return comps, entry


def _trip_count(line: str, default: int) -> int:
    m = re.search(r'known_trip_count[^0-9]*"?n"?[^0-9]*(\d+)', line)
    return int(m.group(1)) if m else default


def _replica_group_info(line: str, mesh_shape: Tuple[int, ...],
                        axis_names: Tuple[str, ...]) -> Tuple[int, str]:
    """(group_size, axis) from replica_groups; axis via id stride
    (row-major device order: last mesh axis has stride 1)."""
    n_dev = int(math.prod(mesh_shape))

    def axis_of_stride(stride: int) -> str:
        s = 1
        for i in range(len(mesh_shape) - 1, -1, -1):
            if stride == s:
                return axis_names[i]
            s *= mesh_shape[i]
        return axis_names[0]  # spans several axes: charge the slowest one

    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](T\(([0-9,]+)\))?",
                  line)
    if m:
        group_size = int(m.group(2))
        if group_size <= 1:
            return 1, axis_names[-1]
        if m.group(4):  # iota with reshape+transpose
            dims = [int(d) for d in m.group(3).split(",")]
            perm = [int(d) for d in m.group(5).split(",")]
            tshape = [dims[p] for p in perm]

            def elem(flat_t: int) -> int:
                idx, out = flat_t, []
                for s in reversed(tshape):
                    out.append(idx % s)
                    idx //= s
                tidx = list(reversed(out))
                oidx = [0] * len(dims)
                for i, p in enumerate(perm):
                    oidx[p] = tidx[i]
                flat = 0
                for s, i in zip(dims, oidx):
                    flat = flat * s + i
                return flat

            stride = elem(1) - elem(0)
        else:
            stride = 1
        return group_size, axis_of_stride(stride)

    mb = re.search(r"replica_groups=\{\{([0-9,]+)\}", line)
    if mb:
        ids = [int(x) for x in mb.group(1).split(",")]
        if len(ids) <= 1:
            return 1, axis_names[-1]
        return len(ids), axis_of_stride(ids[1] - ids[0])
    return n_dev, axis_names[0]


def _ring_bytes(op: str, inst: Instruction,
                symbols: Dict[str, str], group: int) -> float:
    """Per-device wire bytes under a ring schedule."""
    if group <= 1:
        return 0.0
    result = _shape_bytes(inst.type_str)
    f = (group - 1) / group
    if op == "all-reduce":
        return 2.0 * f * result
    if op == "all-gather":
        return f * result                 # result = gathered (full) shape
    if op == "reduce-scatter":
        return f * result * group        # operand = full shape
    if op == "all-to-all":
        return f * result
    if op == "collective-permute":
        return float(result)
    return float(result)


@dataclasses.dataclass
class HloCosts:
    flops: float
    bytes: float
    collective_bytes_by_axis: Dict[str, float]
    collective_count: float
    raw_entry_flops: float
    while_trips: List[int]
    bytes_f32: float = 0.0               # instruction bytes from f32 tensors
    collective_bytes_f32: float = 0.0    # collective bytes from f32 tensors

    @property
    def collective_bytes(self) -> float:
        return sum(self.collective_bytes_by_axis.values())

    def bf16_corrected(self) -> "HloCosts":
        """XLA CPU's float-normalization pass upcasts bf16 -> f32 (the CPU
        has no native bf16), inflating every activation tensor 2x relative
        to the TPU target.  This correction halves the f32-attributed share
        of bytes/collectives — slightly conservative for genuinely-f32
        tensors (optimizer moments, softmax stats), which are a small
        fraction of traffic; both raw and corrected numbers are recorded."""
        scale_b = self.bytes - self.bytes_f32 / 2.0
        col_scale = (1.0 - 0.5 * self.collective_bytes_f32 /
                     max(self.collective_bytes, 1.0))
        col = {k: v * col_scale
               for k, v in self.collective_bytes_by_axis.items()}
        return dataclasses.replace(self, bytes=scale_b,
                                   collective_bytes_by_axis=col)


def analyze_hlo(hlo: str, mesh_shape: Tuple[int, ...],
                axis_names: Tuple[str, ...],
                default_trip: int = 1) -> HloCosts:
    comps, entry = _parse_computations(hlo)
    if not comps:
        return HloCosts(0, 0, {}, 0, 0, [])
    if entry is None:
        entry = next(iter(comps))

    trips: List[int] = []

    def _f32_bytes(type_str: str) -> int:
        total = 0
        for dtype, dims in _SHAPE_RE.findall(type_str):
            if dtype != "f32":
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * 4
        return total

    def walk(cname: str, mult: float, depth: int = 0):
        if cname not in comps or depth > 16:
            return 0.0, 0.0, {}, 0.0, 0.0, 0.0
        fl = by = cnt = by32 = col32 = 0.0
        col: Dict[str, float] = defaultdict(float)
        symbols: Dict[str, str] = {}
        for inst in comps[cname]:
            symbols[inst.name] = inst.type_str
            if inst.op == "while":
                trip = _trip_count(inst.line, default_trip)
                trips.append(trip)
                mbody = re.search(r"body=%?([\w.\-]+)", inst.line)
                if mbody:
                    f2, b2, c2, n2, b32, c32 = walk(mbody.group(1),
                                                    mult * trip, depth + 1)
                    fl += f2
                    by += b2
                    by32 += b32
                    col32 += c32
                    for k, v in c2.items():
                        col[k] += v
                    cnt += n2
                continue
            if inst.op in ("call", "conditional"):
                mcall = re.search(r"(?:to_apply|calls)=%?([\w.\-]+)",
                                  inst.line)
                if mcall:
                    f2, b2, c2, n2, b32, c32 = walk(mcall.group(1), mult,
                                                    depth + 1)
                    fl += f2
                    by += b2
                    by32 += b32
                    col32 += c32
                    for k, v in c2.items():
                        col[k] += v
                    cnt += n2
                continue
            if inst.op in SKIP_OPS:
                continue
            if inst.op == "dynamic-slice":
                # hardware reads only the slice (= result), not the operand
                by += 2 * _shape_bytes(inst.type_str) * mult
                by32 += 2 * _f32_bytes(inst.type_str) * mult
                continue
            if inst.op == "dynamic-update-slice":
                # in-place read-modify-write of the update region only
                upd = inst.operands[1] if len(inst.operands) > 1 else None
                ub = _shape_bytes(symbols[upd]) if upd in symbols else 0
                uf = _f32_bytes(symbols[upd]) if upd in symbols else 0
                by += 2 * ub * mult
                by32 += 2 * uf * mult
                continue
            rbytes = _shape_bytes(inst.type_str)
            obytes = sum(_shape_bytes(symbols[o]) for o in inst.operands
                         if o in symbols)
            by += (rbytes + obytes) * mult
            by32 += (_f32_bytes(inst.type_str)
                     + sum(_f32_bytes(symbols[o]) for o in inst.operands
                           if o in symbols)) * mult

            if inst.op in ("dot", "convolution"):
                shp = _first_shape(inst.type_str)
                if shp:
                    k = 1
                    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}",
                                   inst.line)
                    if mc and inst.operands and inst.operands[0] in symbols:
                        ls = _first_shape(symbols[inst.operands[0]])
                        if ls:
                            for ci in mc.group(1).split(","):
                                if ci:
                                    k *= ls[1][int(ci)]
                    fl += 2.0 * math.prod(shp[1]) * max(k, 1) * mult
            elif any(c in inst.op for c in COLLECTIVE_OPS):
                base = next(c for c in COLLECTIVE_OPS if c in inst.op)
                group, axis = _replica_group_info(inst.line, mesh_shape,
                                                  axis_names)
                wire = _ring_bytes(base, inst, symbols, group) * mult
                col[axis] += wire
                if _f32_bytes(inst.type_str) > 0:
                    col32 += wire
                cnt += mult
        return fl, by, dict(col), cnt, by32, col32

    fl, by, col, cnt, by32, col32 = walk(entry, 1.0)
    # raw entry flops: recompute without recursion
    raw = 0.0
    symbols = {}
    for inst in comps[entry]:
        symbols[inst.name] = inst.type_str
        if inst.op == "dot":
            shp = _first_shape(inst.type_str)
            if shp:
                k = 1
                mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}",
                               inst.line)
                if mc and inst.operands and inst.operands[0] in symbols:
                    ls = _first_shape(symbols[inst.operands[0]])
                    if ls:
                        for ci in mc.group(1).split(","):
                            if ci:
                                k *= ls[1][int(ci)]
                raw += 2.0 * math.prod(shp[1]) * max(k, 1)
    return HloCosts(flops=fl, bytes=by, collective_bytes_by_axis=col,
                    collective_count=cnt, raw_entry_flops=raw,
                    while_trips=trips, bytes_f32=by32,
                    collective_bytes_f32=col32)
