from . import adamw, compression
from .adamw import AdamWConfig, AdamWState, cosine_schedule

__all__ = ["adamw", "compression", "AdamWConfig", "AdamWState",
           "cosine_schedule"]
