"""Gradient compression for cross-pod reduction, with error feedback.

At multi-pod scale the pod-axis all-reduce crosses the slowest links (DCN or
inter-pod ICI).  ``compressed_psum`` quantizes gradients to int8 with a
per-block scale before the cross-pod reduction and keeps the quantization
residual locally ("error feedback"), which provably preserves SGD
convergence (Karimireddy et al., 2019).  Intra-pod reduction stays full
precision.

Used by launch/train.py when ``grad_compression="int8"``; a pure function so
it is testable numerically on CPU without a mesh (the collective is
injected).
"""

from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Blockwise symmetric int8 quantization. x: flat f32."""
    n = x.shape[0]
    pad = (-n) % BLOCK
    xp = jnp.pad(x, (0, pad)).reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(xp), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xp / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray, n: int) -> jnp.ndarray:
    x = (q.astype(jnp.float32) * scale).reshape(-1)
    return x[:n]


def compress_grads(grads: Any, residual: Any) -> Tuple[Any, Any, Any]:
    """-> (quantized payloads, scales, new residuals). Leafwise int8 + EF."""
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        flat = gf.reshape(-1)
        q, s = _quantize_int8(flat)
        deq = _dequantize(q, s, flat.shape[0]).reshape(g.shape)
        return q, s, gf - deq  # residual carries quantization error

    trees = jax.tree.map(one, grads, residual)
    is3 = lambda x: isinstance(x, tuple)
    qs = jax.tree.map(lambda t: t[0], trees, is_leaf=is3)
    ss = jax.tree.map(lambda t: t[1], trees, is_leaf=is3)
    rs = jax.tree.map(lambda t: t[2], trees, is_leaf=is3)
    return qs, ss, rs


def decompress_grads(qs: Any, ss: Any, like: Any) -> Any:
    def one(q, s, g):
        return _dequantize(q, s, int(jnp.prod(jnp.array(g.shape)))
                           if g.shape else 1).reshape(g.shape)

    # shapes are static: compute element counts from the exemplar tree
    def one_static(q, s, g):
        n = 1
        for d in g.shape:
            n *= d
        return _dequantize(q, s, n).reshape(g.shape).astype(g.dtype)

    return jax.tree.map(one_static, qs, ss, like)


def compressed_cross_pod_mean(grads: Any, residual: Any,
                              psum_fn: Callable[[Any], Any],
                              pmax_fn: Callable[[Any], Any],
                              n_pods: int) -> Tuple[Any, Any]:
    """Two-phase compressed mean across pods.

    1. max-reduce the blockwise scales so all pods quantize on a COMMON grid
       (a tiny f32 collective: numel/256 floats);
    2. sum-reduce the int8 payloads in int32 (the big collective, 4x smaller
       than f32 and 2x smaller than bf16 gradients);
    3. dequantize with the common scale / n_pods -> exact mean of the
       quantized gradients.  Per-pod quantization error stays in the local
       error-feedback residual.

    ``psum_fn`` / ``pmax_fn`` are the collectives (e.g.
    partial(lax.psum, axis_name="pod")); injected so unit tests can run the
    arithmetic without a mesh.
    """
    def local_scale(g, r):
        gf = g.astype(jnp.float32) + r
        flat = gf.reshape(-1)
        pad = (-flat.shape[0]) % BLOCK
        xp = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
        s = jnp.max(jnp.abs(xp), axis=1, keepdims=True) / 127.0
        return jnp.maximum(s, 1e-12)

    scales = pmax_fn(jax.tree.map(local_scale, grads, residual))

    def quantize_common(g, r, s):
        gf = g.astype(jnp.float32) + r
        flat = gf.reshape(-1)
        pad = (-flat.shape[0]) % BLOCK
        xp = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
        q = jnp.clip(jnp.round(xp / s), -127, 127).astype(jnp.int8)
        deq = _dequantize(q, s, flat.shape[0]).reshape(g.shape)
        return q, gf - deq

    pairs = jax.tree.map(quantize_common, grads, residual, scales)
    is2 = lambda x: isinstance(x, tuple)
    qs = jax.tree.map(lambda t: t[0], pairs, is_leaf=is2)
    new_res = jax.tree.map(lambda t: t[1], pairs, is_leaf=is2)

    qsum = psum_fn(jax.tree.map(lambda q: q.astype(jnp.int32), qs))
    mean = jax.tree.map(
        lambda q, s, g: _dequantize(q.astype(jnp.float32), s / n_pods,
                                    _numel(g)).reshape(g.shape),
        qsum, scales, grads)
    return mean, new_res


def _numel(g) -> int:
    n = 1
    for d in g.shape:
        n *= d
    return n
