"""AdamW with global-norm clipping, dependency-free (no optax in the image).

Optimizer state is a pytree shaped exactly like params, so the parameter
PartitionSpecs apply verbatim — ZeRO-1 sharded optimizer states come for
free from the FSDP parameter sharding.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # moments kept in f32 regardless of param dtype (mixed-precision safe)
    schedule: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def init(params: Any) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def update(grads: Any, state: AdamWState, params: Any,
           cfg: AdamWConfig) -> Tuple[Any, AdamWState, dict]:
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = cfg.lr if cfg.schedule is None else cfg.lr * cfg.schedule(step)

    b1, b2 = cfg.b1, cfg.b2
    t = step.astype(jnp.float32)
    bias1 = 1.0 - b1 ** t
    bias2 = 1.0 - b2 ** t

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mhat = mu / bias1
        nhat = nu / bias2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), mu, nu

    flat = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], flat,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], flat,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_state = AdamWState(step=step, mu=new_mu, nu=new_nu)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


def cosine_schedule(warmup: int, total: int, floor: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / max(warmup, 1), 1.0)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return warm * cos
    return fn
