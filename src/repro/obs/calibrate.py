"""Measured-cost calibration: regress profile constants from telemetry.

Closes the first half of ROADMAP's "measured-cost calibration loop": the
cost model's profile constants — per-op I/O weights on the (Z0, Z1, Q, W)
cost vector and the lazy-leveling fill factor (`LAZY_LEVELING_FILL`) —
were hand-fit against one benchmark; this pass refits them from captured
``session.execute`` span telemetry (per-phase IOStats deltas attached to
spans by ``workload_runner.execute_session``) and emits a calibration
artifact recording measured-vs-model agreement per policy, before and
after the fit.

The fit is deliberately simple and well-conditioned:

* **per-op weights** — for one policy with model cost vector ``c`` (4,)
  and S captured sessions (mix matrix ``M`` (S,4), measured I/O ``y``
  (S,)), solve the least-squares ``y ~= M @ (c * alpha)`` for the
  multiplicative correction ``alpha`` (clipped non-negative).  The bench
  fleet's four near-pure sessions make this a well-conditioned 4x4
  system, so the fitted agreement is near-exact by construction — the
  artifact's value is *alpha itself*: how far each hand constant sits
  from measurement.
* **lazy-leveling fill** — a 1-D grid search on the ``fill`` knob of
  :func:`repro.core.policy_effective_phi`, minimising the squared
  log-ratio between measured and model session I/O.  This is the exact
  constant the hand calibration fixed at 0.125.

Agreement is reported as the suite's ``agreement_ratio`` (measured mean
over model mean) plus its symmetric *closeness* ``min(a, 1/a)`` — 1.0 is
perfect, and "fitted >= hand" is the gate in ``BENCH_obs.json``.

Unlike the rest of :mod:`repro.obs` this module needs numpy, and the
fill fit lazily imports the jax cost model — it is a leaf submodule,
never imported by ``repro.obs.__init__``, so subprocess workers stay
jax-free.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.faults import atomic_write_json, stamp_checksum

SCHEMA = "repro.obs.calibration.v1"


def session_samples(events: Sequence[dict]) -> List[dict]:
    """Extract calibration samples from ``session.execute`` span events.

    Returns one dict per span that carried a mix and a measured I/O:
    ``{"label", "mix" (4,), "avg_io", "queries"}``; ``label`` is the
    tree's obs label (``.../<policy>`` by fleet convention)."""
    out: List[dict] = []
    for ev in events:
        if ev.get("kind") != "span" or ev.get("name") != "session.execute":
            continue
        attrs = ev.get("attrs") or {}
        if "mix" not in attrs or "avg_io" not in attrs:
            continue
        out.append({
            "label": str(ev.get("track", "") or attrs.get("label", "")),
            "mix": np.asarray(attrs["mix"], np.float64),
            "avg_io": float(attrs["avg_io"]),
            "queries": int(attrs.get("queries", 0)),
        })
    return out


def group_by_policy(samples: Sequence[dict]
                    ) -> Dict[str, Tuple[np.ndarray, np.ndarray]]:
    """Group samples into per-policy ``(M, y)`` regression inputs.

    The fleet labels trees ``<tenant-or-cell>/<policy>``; the suffix
    after the last ``/`` is the policy name."""
    grouped: Dict[str, List[dict]] = {}
    for s in samples:
        policy = s["label"].rsplit("/", 1)[-1] if s["label"] else ""
        grouped.setdefault(policy, []).append(s)
    out = {}
    for policy, rows in grouped.items():
        M = np.stack([r["mix"] for r in rows])
        y = np.array([r["avg_io"] for r in rows], np.float64)
        out[policy] = (M, y)
    return out


def agreement(measured: np.ndarray, model: np.ndarray) -> Tuple[float, float]:
    """(ratio, closeness): the BENCH_compaction ``agreement_ratio`` and
    its symmetric closeness ``min(a, 1/a)`` in (0, 1]."""
    a = float(np.mean(measured) / max(float(np.mean(model)), 1e-12))
    closeness = min(a, 1.0 / a) if a > 0 else 0.0
    return a, closeness


def fit_io_weights(M: np.ndarray, y: np.ndarray, c_model: np.ndarray
                   ) -> Dict[str, object]:
    """Least-squares per-op I/O weight corrections (see module docstring).

    Returns alpha (4,), the fitted cost vector, and hand/fitted
    agreement for this policy's captured sessions."""
    M = np.atleast_2d(np.asarray(M, np.float64))
    y = np.asarray(y, np.float64)
    c = np.asarray(c_model, np.float64)
    A = M * c[None, :]
    alpha, *_ = np.linalg.lstsq(A, y, rcond=None)
    alpha = np.clip(alpha, 0.0, None)
    c_fit = c * alpha
    hand_ratio, hand_close = agreement(y, M @ c)
    fit_ratio, fit_close = agreement(y, M @ c_fit)
    return {
        "alpha": [round(float(a), 6) for a in alpha],
        "c_model": [round(float(x), 6) for x in c],
        "c_fitted": [round(float(x), 6) for x in c_fit],
        "agreement_hand": round(hand_ratio, 4),
        "agreement_fitted": round(fit_ratio, 4),
        "closeness_hand": round(hand_close, 4),
        "closeness_fitted": round(fit_close, 4),
        "sessions": int(len(y)),
    }


def fit_lazy_fill(phi, sys, M: np.ndarray, y: np.ndarray,
                  params: tuple = (),
                  grid: Optional[Sequence[float]] = None
                  ) -> Dict[str, float]:
    """Grid-refit the lazy-leveling ``fill`` constant from measurement.

    Minimises the mean squared log-ratio between measured session I/O and
    the model prediction at each candidate fill.  Lazily imports the jax
    cost model; returns the fitted fill, the hand value in use, and the
    loss at both."""
    from repro.core import (LAZY_LEVELING_FILL, cost_vector,
                            policy_effective_phi)
    M = np.atleast_2d(np.asarray(M, np.float64))
    y = np.asarray(y, np.float64)
    hand = float(dict(params).get("fill", LAZY_LEVELING_FILL))
    if grid is None:
        grid = [round(0.025 * g, 3) for g in range(1, 33)]   # 0.025 .. 0.8

    def loss_at(fill: float) -> float:
        p = tuple(kv for kv in params if kv[0] != "fill") + (("fill", fill),)
        eff = policy_effective_phi(phi, sys, "lazy_leveling", p)
        c = np.asarray(cost_vector(eff, sys), np.float64)
        pred = np.maximum(M @ c, 1e-12)
        return float(np.mean(np.log(np.maximum(y, 1e-12) / pred) ** 2))

    losses = {float(f): loss_at(float(f)) for f in grid}
    best = min(losses, key=lambda f: (losses[f], f))
    return {"fill_hand": hand, "fill_fitted": best,
            "loss_hand": round(loss_at(hand), 6),
            "loss_fitted": round(losses[best], 6)}


def calibrate(events: Sequence[dict],
              model_costs: Dict[str, np.ndarray],
              phi_by_policy: Optional[Dict[str, object]] = None,
              sys=None,
              policy_params: Dict[str, tuple] = ()) -> Dict[str, object]:
    """The full calibration pass: telemetry events -> artifact payload.

    ``model_costs`` maps policy -> hand-calibrated cost vector (4,)
    (``Report.model_costs[cell]``).  When ``phi_by_policy``/``sys`` are
    given and a lazy_leveling group exists, the fill constant is refit
    too."""
    groups = group_by_policy(session_samples(events))
    policies: Dict[str, object] = {}
    for policy in sorted(model_costs):
        if policy not in groups:
            continue
        M, y = groups[policy]
        fit = fit_io_weights(M, y, model_costs[policy])
        if (policy == "lazy_leveling" and phi_by_policy
                and policy in phi_by_policy and sys is not None):
            fit["fill"] = fit_lazy_fill(
                phi_by_policy[policy], sys, M, y,
                params=dict(policy_params).get(policy, ()))
        policies[policy] = fit
    payload = {
        "schema": SCHEMA,
        "policies": policies,
        "all_fitted_ge_hand": bool(policies) and all(
            p["closeness_fitted"] >= p["closeness_hand"] - 1e-9
            for p in policies.values()),
    }
    return payload


def write_calibration(path: str, payload: Dict[str, object]) -> None:
    """Persist the calibration artifact (checksummed, atomic)."""
    atomic_write_json(path, stamp_checksum(dict(payload)))
