"""Chrome/Perfetto trace export: the event ring as ``trace.json``.

Maps the telemetry vocabulary onto the Chrome Trace Event format (the
JSON flavour Perfetto's legacy importer and ``chrome://tracing`` both
read): spans become complete duration events (``ph="X"``), instant
events become ``ph="i"``, and each distinct **track** label (shard,
tenant, deployment) becomes its own named thread via ``thread_name``
metadata events — so a fleet run renders as one lane per shard/tenant.

Counters are aggregate-only in this plane (no per-sample timeline), so
the exporter emits each one as a single terminal counter sample
(``ph="C"``) on its own track; the full totals live in the ``metrics``
block of the BENCH payload.

Timestamps: wall-clock spans are seconds and scale to microseconds;
under the deterministic ``ticks`` clock one tick maps to 1 µs, which
keeps golden traces byte-stable.  Stdlib-only, like the rest of
:mod:`repro.obs`.
"""

from __future__ import annotations

from typing import Dict, List, Optional

from repro.faults import atomic_write_json

from .core import Telemetry, get

_MAIN_TRACK = "main"


def _ts_scale(clock: str) -> float:
    return 1.0 if clock == "ticks" else 1e6


def chrome_trace(events: List[dict], clock: str = "wall",
                 counters: Optional[Dict[str, float]] = None,
                 process_name: str = "repro") -> dict:
    """Render ring events as a ``{"traceEvents": [...]}`` document."""
    scale = _ts_scale(clock)
    out: List[dict] = [{
        "ph": "M", "name": "process_name", "pid": 1, "tid": 0,
        "args": {"name": process_name},
    }]
    tids: Dict[str, int] = {}

    def tid_of(track: str) -> int:
        label = track or _MAIN_TRACK
        tid = tids.get(label)
        if tid is None:
            tid = tids[label] = len(tids) + 1
            out.append({"ph": "M", "name": "thread_name", "pid": 1,
                        "tid": tid, "args": {"name": label}})
        return tid

    last_ts = 0.0
    for ev in events:
        tid = tid_of(ev.get("track", ""))
        ts = float(ev.get("ts", 0.0)) * scale
        last_ts = max(last_ts, ts)
        tev = {"name": ev.get("name", ""), "cat": ev.get("kind", "event"),
               "pid": 1, "tid": tid, "ts": ts}
        if ev.get("kind") == "span":
            tev["ph"] = "X"
            tev["dur"] = max(float(ev.get("dur", 0.0)) * scale, 0.0)
        else:
            tev["ph"] = "i"
            tev["s"] = "t"
        args = dict(ev.get("attrs", {}))
        args["seq"] = ev.get("seq", 0)
        tev["args"] = args
        out.append(tev)
    for name in sorted(counters or {}):
        out.append({"ph": "C", "name": name, "pid": 1, "tid": 0,
                    "ts": last_ts, "args": {"value": counters[name]}})
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def write_trace(path: str, telemetry: Optional[Telemetry] = None) -> int:
    """Export the live (or given) telemetry ring to ``path`` atomically.

    Returns the number of ring events exported (0 when disabled)."""
    t = telemetry if telemetry is not None else get()
    if t is None:
        atomic_write_json(path, {"traceEvents": [], "displayTimeUnit": "ms"})
        return 0
    events = t.events_snapshot()
    snap = t.metrics_snapshot()
    atomic_write_json(path, chrome_trace(events, clock=t.clock,
                                         counters=snap["counters"]))
    return len(events)
