"""The telemetry core: spans, counters/gauges, and the event ring.

One process-global switch (:func:`configure` / :func:`disable`), mirroring
``lsm/read_path.py``'s kernel-mode pattern: telemetry is a pure execution
choice, never an engine-config field, so configs stay hashable,
JSON-round-trippable, and jax-free.  **Off by default** — every
instrumentation point in the engine / online loop / backends boils down to
one module-global ``is None`` check when disabled, and the enabled path
only appends plain dicts to a bounded ring, so engine results are
bit-identical either way (gated: ``BENCH_obs.json``).

Vocabulary (see ``docs/observability.md`` for the span/event taxonomy):

* **span** — a named duration with attached attributes (op counts,
  ``IOStats`` deltas): ``with obs.span("engine.flush", entries=n) as sp:
  ...; sp.set(pages=k)``.  Spans nest; each event records its ``sid`` and
  enclosing ``parent`` sid, per thread.
* **counter / gauge** — monotonically accumulated named totals
  (``obs.count("engine.flush")``) and last-value-wins observations
  (``obs.gauge(...)``).  Aggregate-only: they live in the metrics
  snapshot, not the ring, so the hottest seams cost one dict op.
* **event** — an instant ring entry (``obs.event("drift.decide",
  reason=..., kl=...)``) for decisions worth trace-diffing.
* **track** — a thread-local label (``with obs.track("w0/klsm")``)
  inherited by every span/event inside it; the Perfetto export maps one
  track per shard/tenant/deployment.

Determinism: with ``clock="ticks"`` timestamps are a process-global
monotonic counter instead of wall time, so a seeded run emits a
bit-reproducible event stream (the golden schema tests pin this).  The
ring is bounded (``capacity``); overflow drops the oldest events and
counts them in ``events_dropped``.  An optional JSONL sink streams every
event to disk as it is emitted.

Stdlib-only, like :mod:`repro.faults`: subprocess fleet workers import the
engine (and therefore this module) without jax.  Set ``REPRO_OBS=1`` to
auto-enable at import — the CI tier-1 obs leg runs the whole suite that
way to catch instrumentation drift.
"""

from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Any, Dict, List, Optional

VALID_CLOCKS = ("wall", "ticks")

DEFAULT_CAPACITY = 65536


class Span:
    """One open span; emitted to the ring when the ``with`` block exits."""

    __slots__ = ("_t", "name", "attrs", "sid", "parent", "_t0")

    def __init__(self, telemetry: "Telemetry", name: str, attrs: dict):
        self._t = telemetry
        self.name = name
        self.attrs = attrs
        self.sid = 0
        self.parent = 0
        self._t0 = 0.0

    def set(self, **attrs) -> "Span":
        """Attach/overwrite attributes (op counts, IOStats deltas) before
        the span closes."""
        self.attrs.update(attrs)
        return self

    def __bool__(self) -> bool:
        return True

    def __enter__(self) -> "Span":
        t = self._t
        self.sid = t.new_sid()
        stack = t.span_stack()
        self.parent = stack[-1].sid if stack else 0
        stack.append(self)
        self._t0 = t.now()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        t = self._t
        stack = t.span_stack()
        if stack and stack[-1] is self:
            stack.pop()
        t.emit("span", self.name, self._t0, t.now() - self._t0, self.attrs,
               sid=self.sid, parent=self.parent)


class _NullSpan:
    """The disabled path: a shared no-op context manager."""

    __slots__ = ()

    def set(self, **attrs) -> "_NullSpan":
        return self

    def __bool__(self) -> bool:
        return False

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        return None


NULL_SPAN = _NullSpan()


class Telemetry:
    """The process-global telemetry state: ring + counters + sink."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY,
                 clock: str = "wall", jsonl_path: str = ""):
        if clock not in VALID_CLOCKS:
            raise ValueError(f"unknown clock {clock!r}; one of "
                             f"{VALID_CLOCKS}")
        self.capacity = int(capacity)
        self.clock = clock
        self.jsonl_path = str(jsonl_path or "")
        self.events: deque = deque(maxlen=self.capacity)
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, Any] = {}
        self.seq = 0                     # events ever emitted (ring + dropped)
        self._sids = 0
        self._ticks = 0
        self._t0 = time.perf_counter()
        self._lock = threading.Lock()
        self._tls = threading.local()
        self._sink = open(self.jsonl_path, "w") if self.jsonl_path else None

    # -- clocks / ids ------------------------------------------------------

    def now(self) -> float:
        """Seconds since configure (wall) or a deterministic tick count."""
        if self.clock == "ticks":
            with self._lock:
                self._ticks += 1
                return float(self._ticks)
        return time.perf_counter() - self._t0

    def new_sid(self) -> int:
        with self._lock:
            self._sids += 1
            return self._sids

    # -- thread-local span/track state -------------------------------------

    def span_stack(self) -> List[Span]:
        stack = getattr(self._tls, "spans", None)
        if stack is None:
            stack = self._tls.spans = []
        return stack

    def track_stack(self) -> List[str]:
        stack = getattr(self._tls, "tracks", None)
        if stack is None:
            stack = self._tls.tracks = []
        return stack

    def current_track(self) -> str:
        stack = getattr(self._tls, "tracks", None)
        return stack[-1] if stack else ""

    # -- emission ----------------------------------------------------------

    def emit(self, kind: str, name: str, ts: float, dur: float,
             attrs: Optional[dict], sid: int = 0, parent: int = 0) -> dict:
        ev = {"seq": 0, "kind": kind, "name": name,
              "ts": round(float(ts), 9), "track": self.current_track()}
        if kind == "span":
            ev["dur"] = round(float(dur), 9)
            ev["sid"] = sid
            ev["parent"] = parent
        if attrs:
            ev["attrs"] = attrs
        with self._lock:
            self.seq += 1
            ev["seq"] = self.seq
            self.events.append(ev)       # maxlen drops the oldest silently
            sink = self._sink
        if sink is not None:
            try:
                sink.write(json.dumps(ev, default=_json_default) + "\n")
            except (ValueError, OSError):
                pass                     # a closed/full sink never raises
        return ev

    def count(self, name: str, n: float = 1) -> None:
        with self._lock:
            self.counters[name] = self.counters.get(name, 0) + n

    def gauge(self, name: str, value) -> None:
        with self._lock:
            self.gauges[name] = value

    # -- snapshots ---------------------------------------------------------

    @property
    def dropped(self) -> int:
        return max(0, self.seq - len(self.events))

    def events_snapshot(self) -> List[dict]:
        with self._lock:
            return list(self.events)

    def metrics_snapshot(self) -> Dict[str, Any]:
        """The ``metrics`` block merged into the Report/BENCH schema."""
        with self._lock:
            return {
                "counters": {k: self.counters[k]
                             for k in sorted(self.counters)},
                "gauges": {k: _json_default_pass(self.gauges[k])
                           for k in sorted(self.gauges)},
                "events_total": self.seq,
                "events_dropped": self.dropped,
                "clock": self.clock,
            }

    def clear(self) -> None:
        """Reset ring/counters/clock state; the configuration stays."""
        with self._lock:
            self.events.clear()
            self.counters.clear()
            self.gauges.clear()
            self.seq = 0
            self._sids = 0
            self._ticks = 0
            self._t0 = time.perf_counter()

    def dump_jsonl(self, path: str) -> int:
        """Write the current ring as JSON lines; returns the event count."""
        events = self.events_snapshot()
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            for ev in events:
                f.write(json.dumps(ev, default=_json_default) + "\n")
        os.replace(tmp, path)
        return len(events)

    def close(self) -> None:
        sink, self._sink = self._sink, None
        if sink is not None:
            try:
                sink.close()
            except OSError:
                pass


def _json_default(x):
    """Sink serialization for numpy scalars/arrays without importing
    numpy: anything with ``.item()`` or ``.tolist()`` lowers itself."""
    if hasattr(x, "tolist"):
        return x.tolist()
    if hasattr(x, "item"):
        return x.item()
    return str(x)


def _json_default_pass(x):
    if isinstance(x, (dict, list, tuple, str, int, float, bool)) or x is None:
        return x
    return _json_default(x)


# ---------------------------------------------------------------------------
# The process-global switch (the lsm/read_path.py mode pattern)
# ---------------------------------------------------------------------------

_T: Optional[Telemetry] = None


def configure(enabled: bool = True, capacity: int = DEFAULT_CAPACITY,
              clock: str = "wall", jsonl_path: str = ""
              ) -> Optional[Telemetry]:
    """Install (or tear down) the process-global telemetry plane.

    Returns the live :class:`Telemetry` (or None when ``enabled=False``).
    Reconfiguring closes the previous sink and starts a fresh ring."""
    global _T
    if _T is not None:
        _T.close()
    _T = Telemetry(capacity=capacity, clock=clock,
                   jsonl_path=jsonl_path) if enabled else None
    return _T


def disable() -> None:
    configure(enabled=False)


def enabled() -> bool:
    return _T is not None


def get() -> Optional[Telemetry]:
    return _T


@contextmanager
def scoped(enabled: bool = True, **kw):
    """Scoped :func:`configure` (tests / benchmarks): restores the previous
    telemetry object — including its ring — on exit."""
    global _T
    prev = _T
    _T = Telemetry(**kw) if enabled else None
    try:
        yield _T
    finally:
        if _T is not None:
            _T.close()
        _T = prev


# -- the instrumentation surface (all no-ops when disabled) -----------------

def span(name: str, **attrs):
    t = _T
    if t is None:
        return NULL_SPAN
    return Span(t, name, attrs)


def count(name: str, n: float = 1) -> None:
    t = _T
    if t is not None:
        t.count(name, n)


def gauge(name: str, value) -> None:
    t = _T
    if t is not None:
        t.gauge(name, value)


def event(name: str, **attrs) -> None:
    t = _T
    if t is not None:
        ts = t.now()
        t.emit("event", name, ts, 0.0, attrs)


@contextmanager
def track(label):
    """Scoped track label (one Perfetto track per shard/tenant).  A falsy
    label — or disabled telemetry — is a pure pass-through."""
    t = _T
    if t is None or not label:
        yield
        return
    stack = t.track_stack()
    stack.append(str(label))
    try:
        yield
    finally:
        stack.pop()


def metrics_snapshot() -> Dict[str, Any]:
    t = _T
    return t.metrics_snapshot() if t is not None else {}


def events_snapshot() -> List[dict]:
    t = _T
    return t.events_snapshot() if t is not None else []


def clear() -> None:
    t = _T
    if t is not None:
        t.clear()


# CI's obs leg: REPRO_OBS=1 runs the whole tier-1 suite with telemetry
# live, so instrumentation drift (an event that perturbs engine results,
# an attribute that stops serializing) fails tests instead of landing.
if os.environ.get("REPRO_OBS") == "1":     # pragma: no cover - env-driven
    configure(enabled=True)
