"""Structured telemetry plane: spans, counters, trace export.

Stdlib-only (importable from jax-free subprocess workers, like
:mod:`repro.faults`).  Off by default; ``obs.configure()`` flips the
process-global switch, mirroring ``lsm/read_path.py``'s kernel-mode
pattern.  See ``docs/observability.md`` for the taxonomy and schema.

The calibration pass (:mod:`repro.obs.calibrate`) is deliberately NOT
re-exported here: it needs numpy + the analytic cost model, and keeping
it a leaf submodule keeps ``import repro.obs`` dependency-free.
"""

from .core import (NULL_SPAN, Span, Telemetry, VALID_CLOCKS, clear,
                   configure, count, disable, enabled, event,
                   events_snapshot, gauge, get, metrics_snapshot, scoped,
                   span, track)
from .trace import chrome_trace, write_trace

__all__ = [
    "NULL_SPAN", "Span", "Telemetry", "VALID_CLOCKS",
    "chrome_trace", "clear", "configure", "count", "disable", "enabled",
    "event", "events_snapshot", "gauge", "get", "metrics_snapshot",
    "scoped", "span", "track", "write_trace",
]
