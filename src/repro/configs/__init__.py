"""Config registry: one module per assigned architecture."""

from .base import SHAPES, EncoderConfig, ModelConfig, MoEConfig, ShapeConfig, shape_applicable

from . import (deepseek_moe_16b, glm4_9b, jamba_1_5_large_398b, mixtral_8x7b,
               phi3_mini_3_8b, qwen1_5_110b, qwen2_vl_72b, qwen3_14b,
               rwkv6_3b, whisper_base)

ARCHS = {
    m.CONFIG.name: m.CONFIG
    for m in (qwen1_5_110b, glm4_9b, phi3_mini_3_8b, qwen3_14b, rwkv6_3b,
              whisper_base, deepseek_moe_16b, mixtral_8x7b, qwen2_vl_72b,
              jamba_1_5_large_398b)
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = ["ARCHS", "SHAPES", "EncoderConfig", "ModelConfig", "MoEConfig",
           "ShapeConfig", "get_config", "shape_applicable"]
