"""Jamba-1.5-Large 398B: hybrid Mamba+attention 1:7 interleave, MoE 16e
top-2 on alternate layers. [arXiv:2403.19887]

8-layer period: attention at position 4, Mamba elsewhere; MoE on odd
positions.  Recurrent mixers dominate -> runs long_500k (attention KV
sharded via SP decode)."""
from .base import ModelConfig, MoEConfig

_PERIOD = tuple(
    ("attn" if i == 4 else "mamba", "moe" if i % 2 == 1 else "dense")
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=65536,
    pattern=_PERIOD,
    moe=MoEConfig(num_experts=16, top_k=2, d_expert=24576),
    mamba_d_state=16, mamba_d_conv=4, mamba_expand=2,
    rope_theta=1e6, norm="rms", act="swiglu",
)
