"""Mixtral-8x7B: 8 experts top-2, sliding-window attention (4096).
[arXiv:2401.04088]  SWA makes prefill sub-quadratic and bounds the decode
cache -> runs long_500k."""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000,
    pattern=(("attn", "moe"),),
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=14336),
    window=4096, rope_theta=1e6, norm="rms", act="swiglu",
)
