"""Qwen2-VL-72B backbone: M-RoPE (t/h/w sections), dynamic-resolution vision
tower stubbed -- input_specs feeds precomputed patch embeddings + position
triples. [arXiv:2409.12191]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=29568, vocab_size=152064,
    pattern=(("attn", "dense"),),
    mrope_sections=(16, 24, 24),
    embed_inputs=False,
    rope_theta=1e6, qkv_bias=True, norm="rms", act="swiglu",
)
