"""Phi-3-mini 3.8B: dense, RoPE, SwiGLU, MHA (kv=32). [arXiv:2404.14219]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b", family="dense",
    num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32, head_dim=96,
    d_ff=8192, vocab_size=32064,
    pattern=(("attn", "dense"),),
    rope_theta=1e4, norm="rms", act="swiglu",
)
