"""DeepSeek-MoE 16B: fine-grained MoE, 2 shared + 64 routed top-6; first
layer dense (d_ff=10944), expert width 1408. [arXiv:2401.06066]"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16, head_dim=128,
    d_ff=10944, vocab_size=102400,
    prelude=(("attn", "dense"),),
    pattern=(("attn", "moe"),),
    moe=MoEConfig(num_experts=64, top_k=6, d_expert=1408, num_shared=2),
    rope_theta=1e4, norm="rms", act="swiglu",
)
