"""Model/config system for the assigned architectures.

A :class:`ModelConfig` fully describes one architecture: per-layer pattern of
(sequence-mixer, channel-mixer) blocks, attention flavor knobs, MoE settings,
and runtime/perf knobs used by the hillclimbing loop (remat policy, scan
unroll, logits chunking, dtype).

``pattern`` is repeated ``num_layers / len(pattern)`` times and scanned over
(stacked params); ``prelude`` layers run before the scan with their own
params (e.g. DeepSeek-MoE's dense first layer).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

Pair = Tuple[str, str]  # (mixer, mlp) kinds


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    d_expert: int = 0           # per-expert FFN width
    num_shared: int = 0         # always-on shared experts (DeepSeek-MoE)
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Encoder tower for enc-dec (whisper backbone; conv frontend stubbed)."""
    num_layers: int = 6
    d_input: int = 0  # stub frame-embedding dim (0 -> d_model)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"  # dense|ssm|moe|vlm|audio|hybrid
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 64
    d_ff: int = 512
    vocab_size: int = 1024

    # layer pattern
    pattern: Tuple[Pair, ...] = (("attn", "dense"),)
    prelude: Tuple[Pair, ...] = ()

    # attention flavor
    rope_theta: float = 10000.0
    rotary_pct: float = 1.0
    qkv_bias: bool = False
    qk_norm: bool = False
    window: Optional[int] = None                 # sliding-window attention
    mrope_sections: Optional[Tuple[int, int, int]] = None  # M-RoPE (t,h,w)

    # mixers
    moe: Optional[MoEConfig] = None
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    rwkv_head_dim: int = 64
    rwkv_decay_lora: int = 64

    # towers
    encoder: Optional[EncoderConfig] = None      # enc-dec (audio)
    embed_inputs: bool = True                    # False -> stub embeddings in
    norm: str = "rms"                            # rms|ln
    act: str = "swiglu"                          # swiglu|gelu
    tie_embeddings: bool = False
    norm_eps: float = 1e-5

    # runtime / perf knobs (hillclimb surface)
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    remat: str = "full"            # none|dots|full
    scan_unroll: int = 1
    logits_chunk: int = 0          # 0 -> unchunked lm head
    attention_impl: str = "xla"    # xla|xla_chunked|pallas
    q_chunk: int = 512             # xla_chunked: q-block size
    mamba_chunk: int = 256         # chunked selective-scan block
    shard_vocab: bool = True
    fsdp_params: bool = True       # 2D (fsdp+tp) weight sharding

    # ----------------------------------------------------------------- utils
    @property
    def n_repeats(self) -> int:
        n_scan = self.num_layers - len(self.prelude)
        assert n_scan % len(self.pattern) == 0, (
            f"{self.name}: {n_scan} scan layers not divisible by pattern "
            f"{len(self.pattern)}")
        return n_scan // len(self.pattern)

    @property
    def d_inner_mamba(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def is_pure_full_attention(self) -> bool:
        """True if *every* mixer is unwindowed full attention.  Only these
        skip long_500k; hybrids (Jamba: 1 attn per 8 layers) and SWA archs
        (Mixtral) run it — per the assignment's skip rule."""
        mixers = {m for m, _ in self.pattern + self.prelude}
        return mixers == {"attn"} and self.window is None

    @property
    def has_decoder(self) -> bool:
        return True  # every assigned arch has an autoregressive decoder

    def moe_param_count(self) -> int:
        if self.moe is None:
            return 0
        n = self.moe.num_experts * 3 * self.d_model * self.moe.d_expert
        n += self.d_model * self.moe.num_experts  # router
        n += self.moe.num_shared * 3 * self.d_model * self.moe.d_expert
        return n

    def param_count(self) -> int:
        """Approximate total parameter count N (used for 6ND cross-checks)."""
        d, hd = self.d_model, self.head_dim
        attn = d * (self.num_heads * hd) * 2 \
            + d * (self.num_kv_heads * hd) * 2
        dense_mlp = 3 * d * self.d_ff if self.act == "swiglu" \
            else 2 * d * self.d_ff
        moe_mlp = self.moe_param_count()
        mamba = (d * 2 * self.d_inner_mamba          # in_proj
                 + self.d_inner_mamba * (self.mamba_d_conv +
                                         self.mamba_d_state * 2 + 2)
                 + self.d_inner_mamba * d)           # out_proj
        rwkv = 5 * d * d + 2 * d * self.rwkv_decay_lora  # r,k,v,g,o + decay LoRA

        total = 0
        for mixer, mlp in self.prelude + tuple(
                self.pattern) * self.n_repeats:
            total += {"attn": attn, "mamba": mamba, "rwkv": rwkv}[mixer]
            total += {"dense": dense_mlp, "moe": moe_mlp,
                      "rwkv_ffn": 2 * d * self.d_ff + d * d}[mlp]
            total += 2 * d  # norms
        total += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.encoder is not None:
            enc_layer = attn + dense_mlp + 2 * d
            total += self.encoder.num_layers * enc_layer
            total += self.num_layers * (attn + 2 * d)  # cross-attention
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        full_moe = self.moe_param_count()
        active_moe = ((m.top_k + m.num_shared) * 3 * self.d_model *
                      m.d_expert + self.d_model * m.num_experts)
        n_moe_layers = sum(1 for _, mlp in self.prelude + tuple(
            self.pattern) * self.n_repeats if mlp == "moe")
        return self.param_count() - n_moe_layers * (full_moe - active_moe)

    def reduced(self, **overrides) -> "ModelConfig":
        """A smoke-test-sized config of the same family/pattern."""
        kw = dict(
            name=self.name + "-smoke",
            num_layers=len(self.prelude) + 2 * len(self.pattern),
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if
            self.num_kv_heads < self.num_heads else 4,
            head_dim=16,
            d_ff=128,
            vocab_size=512,
            rwkv_head_dim=16,
            rwkv_decay_lora=8,
            mamba_d_state=8,
            dtype="float32",
            param_dtype="float32",
            remat="none",
            logits_chunk=0,
        )
        if self.moe is not None:
            # capacity_factor high enough that no token ever drops: keeps
            # prefill/decode exactly consistent in the smoke tests (capacity
            # dropping is batch-composition-dependent by design).
            kw["moe"] = dataclasses.replace(
                self.moe, num_experts=4, top_k=2, d_expert=32,
                num_shared=min(self.moe.num_shared, 1),
                capacity_factor=8.0)
        if self.encoder is not None:
            kw["encoder"] = EncoderConfig(num_layers=2, d_input=64)
        if self.mrope_sections is not None:
            kw["mrope_sections"] = (2, 3, 3)  # sums to head_dim/2 = 8
        kw.update(overrides)
        return dataclasses.replace(self, **kw)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned): every arch is paired with all four shapes.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """long_500k is skipped for pure full-attention archs (DESIGN.md table);
    SSM / SWA / hybrid archs run it."""
    if shape.name == "long_500k" and cfg.is_pure_full_attention:
        return False, ("pure full-attention arch: 500k context requires "
                       "sub-quadratic attention (skip per DESIGN.md)")
    return True, ""
