"""Whisper-base backbone: enc-dec, conv frontend stubbed (input_specs feeds
precomputed frame embeddings). [arXiv:2212.04356]

vocab 51865 is not divisible by the 16-way model axis -> vocab replicated
(the unembed is only 27 MB)."""
from .base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio",
    num_layers=6, d_model=512, num_heads=8, num_kv_heads=8, head_dim=64,
    d_ff=2048, vocab_size=51865,
    pattern=(("attn", "dense"),),
    encoder=EncoderConfig(num_layers=6, d_input=128),
    norm="ln", act="gelu", tie_embeddings=True, shard_vocab=False,
    rotary_pct=0.0,  # whisper uses absolute/no rotary; positions unused
)
