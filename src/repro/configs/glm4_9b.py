"""GLM4-9B: dense, RoPE (partial rotary), GQA kv=2. [hf:THUDM/glm-4-9b]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b", family="dense",
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=2, head_dim=128,
    d_ff=13696, vocab_size=151552,
    pattern=(("attn", "dense"),),
    rope_theta=1e4, rotary_pct=0.5, qkv_bias=True, norm="rms", act="swiglu",
)
