"""Checkpointing with an ENDURE-tuned LSM manifest — the paper's technique
as a first-class framework feature.

Tensor shards are written as flat ``.npy`` files; all *metadata* (manifest
entries, step registry, data-pipeline cursors, health heartbeats) lives in a
:class:`repro.lsm.LSMTree` whose tuning comes from the robust tuner: the
framework derives its expected storage workload mix from the run config
(checkpoint writes vs. restore reads vs. manifest scans) and an uncertainty
radius rho from the preemption-rate assumption, then deploys
``tune_robust(...)`` output via ``LSMTree.from_phi``.

Restore is *elastic*: tensors are saved with their global shape and layout
metadata and can be restored onto a different mesh/device count — each host
reads only the byte ranges its new shards need (here: full arrays on one
host, sliced per-shard on load).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import pathlib
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import LSMSystem, tune_robust_many
from repro.lsm import EngineConfig, LSMTree


def _key_of(name: str) -> int:
    """Manifest keys are uint64 hashes of the logical name."""
    return int.from_bytes(hashlib.blake2b(name.encode(),
                                          digest_size=8).digest(), "big")


def framework_storage_workload(ckpt_interval: int, restore_prob: float,
                               scan_frac: float = 0.05) -> np.ndarray:
    """Map run behaviour to the paper's (z0, z1, q, w) workload vector.

    writes  ~ manifest puts per checkpoint; z1 ~ restores + lookups;
    z0 ~ existence probes of absent steps; q ~ manifest scans (listing)."""
    w_write = 1.0 / max(ckpt_interval, 1) * 20
    z1 = 0.2 + restore_prob
    z0 = 0.1
    q = scan_frac
    v = np.array([z0, z1, q, w_write], np.float64)
    return v / v.sum()


def retune_storm(workloads, rhos, sys, seed: int = 0, design=None,
                 n_starts: int = 64, steps: int = 250, lr: float = 0.25,
                 pad_pow2: bool = False) -> list:
    """One batched tuner dispatch for a fleet-wide re-tuning storm.

    The storm path every online re-tune in the framework goes through: a
    batch of (workload, rho) re-tune requests — manifest stores after a
    config shift, :mod:`repro.online` drift triggers firing across a fleet —
    becomes ONE ``tune_robust_many`` grid (workloads on one axis, the
    distinct positive rhos on the other, each request picking its cell) plus
    one ``tune_nominal_many`` batch for the ``rho <= 0`` requests, instead
    of a per-request ``tune_robust`` loop.

    ``pad_pow2`` pads the workload axis to the next power of two with
    repeats of the last row (dropped from the result): storm sizes vary
    call-to-call, and the batched tuners recompile per distinct grid shape —
    bucketing shapes keeps a long-running adaptive loop to O(log fleet)
    compilations.  The vmap lanes are independent, so padding never changes
    the surviving results.

    Returns one :class:`repro.core.TuningResult` per request, in order."""
    W = np.atleast_2d(np.asarray(workloads, np.float64))
    R = np.asarray(rhos, np.float64).reshape(-1)
    if len(W) != len(R):
        raise ValueError(f"{len(W)} workloads for {len(R)} rhos")
    obs.count("tuner.storms")
    obs.count("tuner.storm_requests", len(W))
    with obs.span("tuner.storm", requests=len(W), pad_pow2=bool(pad_pow2)):
        return _retune_storm(W, R, sys, seed, design, n_starts, steps, lr,
                             pad_pow2)


def _retune_storm(W, R, sys, seed, design, n_starts, steps, lr,
                  pad_pow2) -> list:
    from repro.core import tune_nominal_many
    kw = dict(n_starts=n_starts, steps=steps, lr=lr, seed=seed)
    if design is not None:
        kw["design"] = design

    def padded(M: np.ndarray) -> np.ndarray:
        if not pad_pow2 or len(M) < 2:
            return M
        P = 1 << (len(M) - 1).bit_length()
        return np.concatenate([M, np.repeat(M[-1:], P - len(M), axis=0)])

    out: list = [None] * len(W)
    nom = np.flatnonzero(R <= 0)
    if nom.size:
        res = tune_nominal_many(padded(W[nom]), sys, **kw)
        for i, r in zip(nom, res):
            out[i] = r
    rob = np.flatnonzero(R > 0)
    if rob.size:
        uniq = sorted(set(float(r) for r in R[rob]))
        grid = tune_robust_many(padded(W[rob]), uniq, sys, **kw)
        for row, i in zip(grid, rob):
            out[i] = row[uniq.index(float(R[i]))]
    return out


def tuned_manifest_trees(specs: Sequence[Dict[str, Any]],
                         seed: int = 0) -> list:
    """Deploy ENDURE-tuned manifests for a whole fleet in ONE tuner dispatch.

    ``specs`` is a sequence of dicts with the :func:`tuned_manifest_tree`
    keywords (``expected_entries``, ``ckpt_interval``, ``restore_prob``,
    ``rho``).  A re-tuning storm — every store in a fleet re-deriving its
    manifest tuning after a config/workload shift — goes through
    :func:`retune_storm` (one batched grid per distinct store size) instead
    of a per-(workload, rho) ``tune_robust`` loop.  Specs sharing
    ``expected_entries`` share a compiled sweep."""
    trees: list = [None] * len(specs)
    by_n: Dict[int, list] = {}
    for i, spec in enumerate(specs):
        by_n.setdefault(int(spec.get("expected_entries", 50_000)),
                        []).append(i)
    for n_entries, idxs in by_n.items():
        sys_small = LSMSystem(N=float(n_entries), entry_bits=256 * 8,
                              page_bits=4096 * 8, bits_per_entry=16.0,
                              min_buf_bits=256 * 8 * 64, s_rq=2e-5)
        W = [framework_storage_workload(
            specs[i].get("ckpt_interval", 100),
            specs[i].get("restore_prob", 0.3)) for i in idxs]
        rhos = [float(specs[i].get("rho", 1.0)) for i in idxs]
        tunings = retune_storm(np.stack(W), rhos, sys_small, seed=seed)
        for i, tuning in zip(idxs, tunings):
            trees[i] = LSMTree.from_phi(tuning.phi, sys_small,
                                        expected_entries=n_entries,
                                        entry_bytes=256)
    return trees


def tuned_manifest_tree(expected_entries: int = 50_000,
                        ckpt_interval: int = 100,
                        restore_prob: float = 0.3,
                        rho: float = 1.0,
                        seed: int = 0) -> LSMTree:
    """An LSM manifest whose (T, K, memory split) comes from ENDURE."""
    return tuned_manifest_trees([dict(expected_entries=expected_entries,
                                      ckpt_interval=ckpt_interval,
                                      restore_prob=restore_prob, rho=rho)],
                                seed=seed)[0]


@dataclasses.dataclass
class CheckpointStore:
    root: pathlib.Path
    manifest: LSMTree

    @classmethod
    def create(cls, root: str, **tuning_kw) -> "CheckpointStore":
        p = pathlib.Path(root)
        p.mkdir(parents=True, exist_ok=True)
        return cls(root=p, manifest=tuned_manifest_tree(**tuning_kw))

    # -- manifest KV helpers --------------------------------------------

    def _mput(self, name: str, value: Dict[str, Any]) -> None:
        self.manifest.put(_key_of(name), json.dumps(value))

    def _mget(self, name: str) -> Optional[Dict[str, Any]]:
        v = self.manifest.get(_key_of(name))
        return None if v is None else json.loads(v)

    # -- save / restore ----------------------------------------------------

    @staticmethod
    def _write_array(path: pathlib.Path, arr: np.ndarray) -> None:
        """One tensor file, atomically: serialize to memory, then temp +
        ``os.replace`` — a crash mid-save can leave an *unreferenced* file,
        never a torn ``.npy`` at a path the manifest points to."""
        import io
        from repro.faults import atomic_write_bytes
        buf = io.BytesIO()
        np.save(buf, arr)
        atomic_write_bytes(str(path), buf.getvalue())

    @staticmethod
    def _write_npz(path: pathlib.Path, arrays: Dict[str, np.ndarray]) -> None:
        import io
        from repro.faults import atomic_write_bytes
        buf = io.BytesIO()
        np.savez(buf, **arrays)
        atomic_write_bytes(str(path), buf.getvalue())

    def save(self, step: int, params: Any, opt_state: Any = None,
             data_state: Optional[Dict[str, int]] = None) -> None:
        """Write one checkpoint crash-safely.

        Ordering is the durability contract (``docs/faults.md``): every
        tensor file and per-step manifest entry lands *before* the
        ``latest`` pointer flips, and each file write is atomic — so a save
        interrupted anywhere leaves ``latest_step()`` on the previous fully
        written checkpoint, which remains restorable, and never leaves a
        torn tensor file at a manifest-referenced path."""
        ckdir = self.root / f"step_{step:08d}"
        ckdir.mkdir(parents=True, exist_ok=True)
        flat, treedef = jax.tree_util.tree_flatten_with_path(params)
        names = []
        for path, leaf in flat:
            name = jax.tree_util.keystr(path)
            arr = np.asarray(jax.device_get(leaf))
            if arr.dtype.name not in ("float32", "float64", "int32",
                                      "int64", "uint32", "uint64", "bool"):
                arr = arr.astype(np.float32)  # bf16 etc: store widened
            fname = hashlib.md5(name.encode()).hexdigest() + ".npy"
            self._write_array(ckdir / fname, arr)
            self._mput(f"tensor/{step}/{name}", {
                "file": fname, "shape": list(arr.shape),
                "dtype": str(arr.dtype)})
            names.append(name)
        extras: Dict[str, Any] = {"names": names, "step": step}
        if data_state is not None:
            extras["data_state"] = data_state
        self._mput(f"ckpt/{step}", extras)
        if opt_state is not None:
            def widen(l):
                a = np.asarray(jax.device_get(l))
                return a.astype(np.float32) if a.dtype.name == "bfloat16" \
                    else a
            self._write_npz(ckdir / "opt_state.npz", {
                f"s{i}": widen(l)
                for i, l in enumerate(jax.tree.leaves(opt_state))})
        # the commit point: everything above must already be durable
        self._mput("latest", {"step": step})
        self.manifest.flush()

    def latest_step(self) -> Optional[int]:
        v = self._mget("latest")
        return None if v is None else int(v["step"])

    def restore(self, params_like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[Any, Dict[str, Any]]:
        """Restore onto (possibly different) shardings — elastic restart."""
        step = self.latest_step() if step is None else step
        assert step is not None, "no checkpoint found"
        meta = self._mget(f"ckpt/{step}")
        assert meta is not None, f"manifest missing ckpt/{step}"
        ckdir = self.root / f"step_{step:08d}"
        flat, treedef = jax.tree_util.tree_flatten_with_path(params_like)
        leaves = []
        for path, like in flat:
            name = jax.tree_util.keystr(path)
            info = self._mget(f"tensor/{step}/{name}")
            assert info is not None, f"manifest missing {name}"
            arr = np.load(ckdir / info["file"])
            assert list(arr.shape) == list(like.shape), (name, arr.shape,
                                                         like.shape)
            leaves.append(jnp.asarray(arr).astype(like.dtype))
        restored = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(params_like), leaves)
        if shardings is not None:
            restored = jax.tree.map(
                lambda a, s: jax.device_put(a, s), restored, shardings)
        return restored, meta

    def restore_opt_state(self, opt_like: Any, step: Optional[int] = None
                          ) -> Any:
        step = self.latest_step() if step is None else step
        z = np.load(self.root / f"step_{step:08d}" / "opt_state.npz")
        leaves = [jnp.asarray(z[f"s{i}"]).astype(l.dtype)
                  if hasattr(l, "dtype") else z[f"s{i}"]
                  for i, l in enumerate(jax.tree.leaves(opt_like))]
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(opt_like), leaves)

    # -- health / straggler bookkeeping (elastic.py reads these) -----------

    def heartbeat(self, worker: int, step: int, t: float) -> None:
        self._mput(f"hb/{worker}", {"step": step, "t": t})

    def heartbeats(self, workers: int) -> Dict[int, Dict[str, Any]]:
        out = {}
        for w in range(workers):
            v = self._mget(f"hb/{w}")
            if v is not None:
                out[w] = v
        return out
