from .store import (CheckpointStore, framework_storage_workload,
                    tuned_manifest_tree)

__all__ = ["CheckpointStore", "framework_storage_workload",
           "tuned_manifest_tree"]
