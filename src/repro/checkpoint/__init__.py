from .store import (CheckpointStore, framework_storage_workload,
                    retune_storm, tuned_manifest_tree,
                    tuned_manifest_trees)

__all__ = ["CheckpointStore", "framework_storage_workload",
           "retune_storm", "tuned_manifest_tree", "tuned_manifest_trees"]
