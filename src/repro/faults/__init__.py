"""Deterministic fault injection + the crash-safe execution substrate.

Three stdlib-only modules (importable from jax-free worker processes):

* :mod:`repro.faults.spec` — :class:`FaultSpec` / :class:`FaultPlan`, the
  seeded chaos schedule carried on ``ExperimentSpec.faults``;
* :mod:`repro.faults.artifacts` — atomic writes + content checksums for
  every persisted artifact (shard results, BENCH baselines, checkpoints);
* :mod:`repro.faults.retry` — :class:`RetryPolicy` (seeded backoff,
  per-attempt timeouts) and :class:`ShardSupervisor` (dead-worker
  membership + elastic re-sharding), the :mod:`repro.launch.elastic`
  pattern at sweep granularity.

See ``docs/faults.md`` for the taxonomy, the determinism contract, and the
resume workflow.
"""

from .artifacts import (CHECKSUM_KEY, TornWriteError, atomic_write_bytes,
                        atomic_write_json, canonical_json, checksum_ok,
                        dump_job, load_checked_json, load_job,
                        payload_checksum, stamp_checksum)
from .retry import RetryPolicy, ShardSupervisor
from .spec import (ARTIFACT_KINDS, HANG_SLEEP_S, KINDS, WORKER_KINDS,
                   FaultAction, FaultPlan, FaultSpec, u01)

__all__ = [
    "FaultSpec", "FaultPlan", "FaultAction",
    "KINDS", "WORKER_KINDS", "ARTIFACT_KINDS", "HANG_SLEEP_S", "u01",
    "RetryPolicy", "ShardSupervisor",
    "CHECKSUM_KEY", "TornWriteError", "atomic_write_bytes",
    "atomic_write_json", "canonical_json", "checksum_ok", "dump_job",
    "load_checked_json", "load_job", "payload_checksum", "stamp_checksum",
]
