"""Retry policy and worker-shard membership for fault-tolerant sweeps.

The :mod:`repro.launch.elastic` pattern — pure-policy membership decisions
(dead-worker detection, remesh over survivors) consumed by a thin actuation
loop — re-applied at fleet-trial granularity.  Here the observation channel
is direct (a shard launch returns, times out, or exits nonzero; no
heartbeat table needed) and "remesh" becomes re-sharding: a dead shard's
trees are regrouped onto fresh worker slots.  Both halves stay pure data +
pure functions so they unit-test without processes.

Everything is deterministic: backoff delays are hash draws over
``(seed, shard, attempt)`` (:func:`repro.faults.spec.u01`), and
re-assignment is a sorted round-robin — the same failure schedule always
produces the same recovery schedule.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

from .spec import u01


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded, seeded exponential backoff for shard launches.

    A shard is attempted at most ``max_retries + 1`` times; attempt ``a``
    (a >= 1) is preceded by a delay of ``backoff_s * 2**(a-1)`` scaled by a
    deterministic jitter in [0.5, 1.5) drawn from ``(seed, shard, a)`` —
    jitter de-synchronizes a fleet of retrying shards without making the
    schedule irreproducible.  ``timeout_s`` is the per-attempt deadline
    after which a worker is declared hung and killed."""

    max_retries: int = 2
    backoff_s: float = 0.05
    timeout_s: float = 900.0
    seed: int = 0

    def attempts(self) -> int:
        return self.max_retries + 1

    def delay(self, shard: int, attempt: int) -> float:
        if attempt <= 0:
            return 0.0
        jitter = 0.5 + u01(self.seed, "backoff", shard, attempt)
        return self.backoff_s * (2.0 ** (attempt - 1)) * jitter


@dataclasses.dataclass
class ShardSupervisor:
    """Membership + failure bookkeeping for one sweep's worker shards.

    Mirrors :class:`repro.launch.elastic.RunSupervisor`'s shape (record
    observations, then ask for a decision) with the sweep's direct failure
    signal standing in for heartbeats: a shard that exhausts its retry
    budget is *dead*, and :meth:`reassign` is the remesh — its trees move
    onto fresh jobs sized to the surviving capacity."""

    failures: Dict[int, List[str]] = dataclasses.field(default_factory=dict)
    dead: List[int] = dataclasses.field(default_factory=list)
    completed: List[int] = dataclasses.field(default_factory=list)

    def record_failure(self, shard: int, error: str) -> None:
        self.failures.setdefault(shard, []).append(error)

    def mark_dead(self, shard: int) -> None:
        if shard not in self.dead:
            self.dead.append(shard)

    def mark_completed(self, shard: int) -> None:
        self.completed.append(shard)

    def last_error(self, shard: int) -> str:
        errs = self.failures.get(shard)
        return errs[-1] if errs else "<no error recorded>"

    @property
    def retries(self) -> int:
        """Total failed attempts across all shards (retried or not)."""
        return sum(len(v) for v in self.failures.values())

    def reassign(self, trees: Sequence[int], capacity: int
                 ) -> List[List[int]]:
        """Regroup dead shards' trees onto at most ``capacity`` fresh jobs.

        Sorted round-robin: deterministic, and it splits a dead shard's
        load across survivors instead of recreating the same doomed shard
        (different shard ids also re-roll the fault draws, which is exactly
        how a preempted-slot retry behaves on real infrastructure)."""
        if not trees:
            return []
        n = max(1, min(len(trees), capacity))
        jobs: List[List[int]] = [[] for _ in range(n)]
        for i, t in enumerate(sorted(trees)):
            jobs[i % n].append(t)
        return jobs
