"""Crash-safe artifacts: atomic writes plus content checksums.

Two independent defenses, used together everywhere the harness persists
results (per-shard sweep results, ``BENCH_<suite>.json`` baselines,
checkpoint tensor files):

* **atomic replace** — payload lands in a same-directory temp file,
  fsynced, then :func:`os.replace`'d over the destination, so a crash
  mid-write leaves either the old file or the new one, never a torn hybrid;
* **content checksum** — a sha256 over the canonical serialization travels
  with the payload, and every loader validates it before trusting the
  content, so corruption that bypasses the atomic writer (a torn write from
  older code, disk bit-rot, a truncated copy) is *detected* instead of
  silently consumed — the CI perf gate, for instance, must reject a corrupt
  baseline as misconfigured rather than report a phantom regression.

The ``fault`` parameter threads the deterministic chaos layer
(:class:`repro.faults.FaultPlan`) through the write path: a ``torn_write``
fault simulates a crash inside a non-atomic writer by leaving a truncated
payload at the *final* path and raising :class:`TornWriteError` — exactly
the wound the checksum validation is there to catch.

Stdlib-only (json/os/pickle/hashlib): importable from jax-free workers.
"""

from __future__ import annotations

import hashlib
import json
import os
import pickle
import tempfile
from typing import Any, Dict, Optional

#: checksum field/prefix conventions shared by every artifact schema.
CHECKSUM_KEY = "checksum"
_PREFIX = "sha256:"


class TornWriteError(OSError):
    """An injected torn artifact write (crash mid-write simulation)."""


def canonical_json(payload) -> str:
    """The canonical serialization checksums are computed over (key-sorted,
    separator-minimal, strict floats) — independent of on-disk indenting."""
    return json.dumps(payload, sort_keys=True, separators=(",", ":"),
                      allow_nan=False)


def payload_checksum(payload: Dict[str, Any]) -> str:
    """Checksum of a JSON payload, excluding its own checksum field."""
    body = {k: v for k, v in payload.items() if k != CHECKSUM_KEY}
    digest = hashlib.sha256(canonical_json(body).encode()).hexdigest()
    return _PREFIX + digest


def stamp_checksum(payload: Dict[str, Any]) -> Dict[str, Any]:
    """Return ``payload`` with its checksum field (re)computed in place."""
    payload[CHECKSUM_KEY] = payload_checksum(payload)
    return payload


def checksum_ok(payload: Dict[str, Any]) -> bool:
    claimed = payload.get(CHECKSUM_KEY)
    return claimed is not None and claimed == payload_checksum(payload)


def atomic_write_bytes(path: str, data: bytes, fault=None) -> None:
    """Write ``data`` to ``path`` via same-directory temp + ``os.replace``.

    With a matching ``torn_write`` fault in ``fault``, simulates a crash
    mid-write instead: truncated bytes land at the final path and
    :class:`TornWriteError` is raised (callers treat it as any other
    persistence failure; the next *loader* must reject the torn file)."""
    path = os.fspath(path)
    name = os.path.basename(path)
    if fault is not None and fault.tears_write(name):
        with open(path, "wb") as f:
            f.write(data[: max(1, len(data) // 2)])
        raise TornWriteError(f"injected torn write of {name!r}")
    fd, tmp = tempfile.mkstemp(prefix=name + ".", suffix=".tmp",
                               dir=os.path.dirname(path) or ".")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def atomic_write_json(path: str, payload: Dict[str, Any], indent: int = 1,
                      fault=None) -> Dict[str, Any]:
    """Checksum-stamp ``payload`` and atomically write it as strict JSON.

    Returns the stamped payload (mutated in place)."""
    stamp_checksum(payload)
    text = json.dumps(payload, indent=indent, sort_keys=True,
                      allow_nan=False)
    atomic_write_bytes(path, text.encode(), fault=fault)
    return payload


def load_checked_json(path: str) -> Dict[str, Any]:
    """Load a checksummed JSON artifact, raising ``ValueError`` if the file
    does not parse, carries no checksum, or fails validation."""
    with open(path) as f:
        payload = json.load(f)
    if not isinstance(payload, dict) or CHECKSUM_KEY not in payload:
        raise ValueError(f"{path}: no {CHECKSUM_KEY!r} field")
    if not checksum_ok(payload):
        raise ValueError(f"{path}: checksum mismatch (corrupt or torn file)")
    return payload


# ---------------------------------------------------------------------------
# Checksummed pickle jobs (per-shard sweep results)
# ---------------------------------------------------------------------------

def dump_job(path: str, obj: Any, fault=None) -> None:
    """Persist one pickled job result: ``sha256-hexdigest \\n payload``,
    written atomically (or torn, under an injected fault)."""
    payload = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    header = hashlib.sha256(payload).hexdigest().encode() + b"\n"
    atomic_write_bytes(path, header + payload, fault=fault)


def load_job(path: str) -> Optional[Any]:
    """Load a checksummed job pickle; ``None`` for anything invalid —
    missing, torn, checksum-mismatched, or unpicklable (a corrupt shard
    artifact is re-executed, never trusted)."""
    try:
        with open(path, "rb") as f:
            data = f.read()
        header, _, payload = data.partition(b"\n")
        if hashlib.sha256(payload).hexdigest().encode() != header:
            return None
        return pickle.loads(payload)
    except (OSError, pickle.UnpicklingError, EOFError, AttributeError,
            ValueError, IndexError):
        return None
