"""Deterministic, seeded fault injection: chaos scenarios as spec data.

The source paper motivates robust tuning with shared cloud infrastructure —
workers are preempted, slow, and flaky — and ENDURE's thesis is that
robustness is an outcome of a *process* that accounts for uncertainty, not
a property of a single design.  The same must hold for the harness that
executes experiments: this module makes the failure process itself a
declarative, reproducible input.

A :class:`FaultSpec` declares one fault population (what kind, which worker
shards, how many attempts, with what probability); a tuple of them rides on
``ExperimentSpec.faults`` and round-trips through JSON like every other
axis, so a chaos scenario is a spec file, not a shell script.  A
:class:`FaultPlan` compiles the tuple into a pure decision function: every
injection decision is a counter-free hash draw over ``(seed, kind, shard,
attempt)``, so the schedule is bit-reproducible run-to-run, independent of
thread interleaving, and a retried attempt re-rolls its own coordinate
rather than replaying the failure forever.

Fault taxonomy (``FaultSpec.kind``):

* ``"crash"``   — the worker process dies before doing any work (preemption);
* ``"hang"``    — the worker sleeps past any reasonable deadline (lost/
  livelocked worker; the backend's per-shard timeout is the detector);
* ``"slow"``    — the worker sleeps ``delay_s`` then completes (straggler);
* ``"corrupt"`` — the worker completes but ships a truncated result pickle
  (bit-rot / torn pipe);
* ``"torn_write"`` — an artifact write is cut short mid-file *at the final
  path* (a crash inside a non-atomic writer), exercising the checksum
  validation every artifact loader performs.

Everything here is stdlib-only: fault descriptors are pickled into
jax-free worker processes.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Optional, Tuple

#: worker-scoped kinds are injected inside the worker process; artifact
#: kinds are injected in the artifact-write path of the parent.
WORKER_KINDS = ("crash", "hang", "slow", "corrupt")
ARTIFACT_KINDS = ("torn_write",)
KINDS = WORKER_KINDS + ARTIFACT_KINDS

#: a hung worker sleeps this long (forever, at sweep timescales); the
#: backend's per-shard timeout is what bounds the damage.
HANG_SLEEP_S = 6 * 3600.0


def u01(*key) -> float:
    """A uniform [0, 1) draw as a pure hash of the key tuple.

    Counter-free by construction: the draw for one ``(seed, kind, shard,
    attempt)`` coordinate never depends on how many other draws happened or
    in what order, which is what keeps a multi-threaded fault schedule
    deterministic."""
    h = hashlib.blake2b(repr(key).encode(), digest_size=8).digest()
    return int.from_bytes(h, "big") / 2.0 ** 64


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One declarative fault population.

    A fault *fires* for worker-shard coordinate ``(shard, attempt)`` when
    all three hold:

    * ``shards`` is empty (match every shard) or contains ``shard``;
    * ``attempt < max_hits`` — a bounded fault retires after its first
      ``max_hits`` attempts per shard, so retry/re-shard can make progress
      (``max_hits`` large enough models a permanently dead worker);
    * the deterministic draw ``u01(seed, kind, shard, attempt) < p``.

    ``torn_write`` faults target artifact writes instead: they fire for a
    file whose basename contains ``match`` (empty = every artifact) with
    probability ``p`` drawn over ``(seed, kind, basename)``.

    ``delay_s`` is the injected latency of ``slow`` faults; ``hang``
    ignores it and sleeps effectively forever (the backend timeout is the
    recovery path under test)."""

    kind: str
    p: float = 1.0
    max_hits: int = 1
    shards: Tuple[int, ...] = ()
    delay_s: float = 0.0
    match: str = ""
    seed: int = 0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"known: {sorted(KINDS)}")
        if not 0.0 <= self.p <= 1.0:
            raise ValueError(f"fault probability p={self.p} outside [0, 1]")
        if self.max_hits < 0:
            raise ValueError(f"max_hits={self.max_hits} must be >= 0")
        if self.delay_s < 0:
            raise ValueError(f"delay_s={self.delay_s} must be >= 0")

    def fires_worker(self, shard: int, attempt: int) -> bool:
        if self.kind not in WORKER_KINDS:
            return False
        if self.shards and shard not in self.shards:
            return False
        if attempt >= self.max_hits:
            return False
        return u01(self.seed, self.kind, shard, attempt) < self.p

    def fires_write(self, basename: str) -> bool:
        if self.kind not in ARTIFACT_KINDS:
            return False
        if self.match and self.match not in basename:
            return False
        return u01(self.seed, self.kind, basename) < self.p


@dataclasses.dataclass(frozen=True)
class FaultAction:
    """One resolved injection, shipped to the worker inside its job pickle
    (plain data — the worker stays jax-free)."""

    kind: str
    delay_s: float = 0.0


class FaultPlan:
    """A compiled fault schedule: the pure decision functions the execution
    layer consults.  Stateless and thread-safe; an empty plan answers
    ``None``/``False`` everywhere, which is the production fast path."""

    __slots__ = ("specs",)

    def __init__(self, specs: Tuple[FaultSpec, ...] = ()):
        self.specs = tuple(specs)

    @classmethod
    def from_specs(cls, specs) -> "FaultPlan":
        return cls(tuple(specs))

    def __bool__(self) -> bool:
        return bool(self.specs)

    def worker_fault(self, shard: int, attempt: int) -> Optional[FaultAction]:
        """The fault (if any) injected into worker ``shard``'s
        ``attempt``-th launch; first matching spec wins."""
        for s in self.specs:
            if s.fires_worker(shard, attempt):
                return FaultAction(kind=s.kind, delay_s=s.delay_s)
        return None

    def tears_write(self, basename: str) -> bool:
        """Whether the write of artifact ``basename`` is torn mid-file."""
        return any(s.fires_write(basename) for s in self.specs)
