"""The uncertainty benchmark (paper Section 7).

* 15 expected workloads (Table 4): uniform / unimodal / bimodal / trimodal.
* A benchmark set ``B`` of 10,000 sampled workloads: per-class query counts
  drawn uniformly from (0, 10000), normalized.
"""

from __future__ import annotations

import numpy as np

# Table 4, exactly.
EXPECTED_WORKLOADS = np.array([
    [0.25, 0.25, 0.25, 0.25],  # 0  uniform
    [0.97, 0.01, 0.01, 0.01],  # 1  unimodal
    [0.01, 0.97, 0.01, 0.01],  # 2
    [0.01, 0.01, 0.97, 0.01],  # 3
    [0.01, 0.01, 0.01, 0.97],  # 4
    [0.49, 0.49, 0.01, 0.01],  # 5  bimodal
    [0.49, 0.01, 0.49, 0.01],  # 6
    [0.49, 0.01, 0.01, 0.49],  # 7
    [0.01, 0.49, 0.49, 0.01],  # 8
    [0.01, 0.49, 0.01, 0.49],  # 9
    [0.01, 0.01, 0.49, 0.49],  # 10
    [0.33, 0.33, 0.33, 0.01],  # 11 trimodal
    [0.33, 0.33, 0.01, 0.33],  # 12
    [0.33, 0.01, 0.33, 0.33],  # 13
    [0.01, 0.33, 0.33, 0.33],  # 14
], dtype=np.float64)

WORKLOAD_CATEGORY = (
    ["uniform"] + ["unimodal"] * 4 + ["bimodal"] * 6 + ["trimodal"] * 4
)


def sample_benchmark(n: int = 10_000, seed: int = 0,
                     max_count: int = 10_000) -> np.ndarray:
    """The benchmark set B: counts ~ U(0, max_count) per class, normalized."""
    rng = np.random.default_rng(seed)
    counts = rng.uniform(1.0, float(max_count), size=(n, 4))
    return counts / counts.sum(axis=1, keepdims=True)


def zippydb_like() -> np.ndarray:
    """Facebook ZippyDB mix (paper Section 7): 78% gets, 19% writes, 3% range.

    Gets are split empty/non-empty evenly (the survey does not distinguish)."""
    return np.array([0.39, 0.39, 0.03, 0.19], dtype=np.float64)
