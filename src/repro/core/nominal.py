"""NOMINAL TUNING (paper Problem 1): Phi_N = argmin_Phi C(w, Phi).

Two solvers:

* :func:`tune_nominal` — JAX-native: sigmoid-reparameterized box constraints,
  Adam, ``vmap`` over multi-starts, ``jit`` over the whole sweep.  This is the
  default; it is orders of magnitude faster than per-problem SLSQP and — for
  the K-LSM design with its ~26 decision variables — substantially more stable
  (the paper's Section 11 *Limitations* reports exactly this SLSQP fragility).
* :func:`tune_nominal_slsqp` — paper-faithful SciPy SLSQP on the same
  objective (with JAX gradients), for parity experiments.

Both return integral tunings (ceil/round per Section 5.2) re-evaluated with
the exact (non-smooth) cost model.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import designs
from .designs import DesignSpace
from .lsm_cost import LSMSystem, Phi, expected_cost


@dataclasses.dataclass
class TuningResult:
    phi: Phi                     # integral, deploy-ready
    cost: float                  # exact C(w, phi) after rounding
    design: DesignSpace
    raw_phi: Optional[Phi] = None  # pre-rounding solution
    solver: str = "jax"

    def describe(self, sys: LSMSystem) -> str:
        return designs.describe(self.phi, sys)


# ---------------------------------------------------------------------------
# JAX multi-start tuner (delegates to the batched engine, P = 1)
# ---------------------------------------------------------------------------

def tune_nominal(w, sys: LSMSystem,
                 design: DesignSpace = DesignSpace.CLASSIC,
                 n_starts: int = 64, steps: int = 250, lr: float = 0.25,
                 seed: int = 0) -> TuningResult:
    """Solve NOMINAL TUNING for ``design``; CLASSIC = best of {level, tier}.

    Thin wrapper over :func:`repro.core.batch.tune_nominal_many` with a
    single-workload batch; CLASSIC is folded into one padded batch axis there
    rather than solved as two recursive calls.
    """
    from .batch import tune_nominal_many  # local import: batch imports us
    return tune_nominal_many([w], sys, design=design, n_starts=n_starts,
                             steps=steps, lr=lr, seed=seed)[0]


# ---------------------------------------------------------------------------
# SciPy SLSQP (paper-parity)
# ---------------------------------------------------------------------------

def _theta_bounds(design: DesignSpace, sys: LSMSystem):
    return [(-8.0, 8.0)] * designs.n_params(design, sys)


def tune_nominal_slsqp(w, sys: LSMSystem,
                       design: DesignSpace = DesignSpace.CLASSIC,
                       n_starts: int = 8, seed: int = 0) -> TuningResult:
    """Paper-faithful SLSQP (SciPy) on the smooth objective.

    We optimize in the same sigmoid-transformed coordinates (so box
    constraints hold by construction, matching the paper's bounded SLSQP),
    with analytic JAX gradients."""
    from scipy.optimize import minimize  # lazy: scipy only needed here

    if design is DesignSpace.CLASSIC:
        cands = [tune_nominal_slsqp(w, sys, d, n_starts, seed)
                 for d in (DesignSpace.LEVELING, DesignSpace.TIERING)]
        return min(cands, key=lambda r: r.cost)

    w = jnp.asarray(w, jnp.float32)

    @jax.jit
    def obj(theta):
        phi = designs.to_phi(theta, design, sys, smooth=True)
        return expected_cost(w, phi, sys, smooth=True)

    val_and_grad = jax.jit(jax.value_and_grad(obj))

    def f(x):
        v, g = val_and_grad(jnp.asarray(x, jnp.float32))
        return float(v), np.asarray(g, np.float64)

    rng = np.random.default_rng(seed)
    best_x, best_v = None, np.inf
    for _ in range(n_starts):
        x0 = rng.uniform(-3, 3, designs.n_params(design, sys))
        try:
            res = minimize(f, x0, jac=True, method="SLSQP",
                           bounds=_theta_bounds(design, sys),
                           options={"maxiter": 200, "ftol": 1e-12})
        except Exception:
            continue
        if np.isfinite(res.fun) and res.fun < best_v:
            best_x, best_v = res.x, float(res.fun)
    if best_x is None:  # SLSQP failed on every start (paper Section 11 mode)
        return tune_nominal(w, sys, design, seed=seed)

    raw_phi = designs.to_phi(jnp.asarray(best_x, jnp.float32), design, sys)
    phi = raw_phi.round_integral(sys)
    cost = float(expected_cost(w, phi, sys, smooth=False))
    return TuningResult(phi=phi, cost=cost, design=design, raw_phi=raw_phi,
                        solver="slsqp")
