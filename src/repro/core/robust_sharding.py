"""Beyond-paper: the ENDURE robust-tuning paradigm applied to mesh/layout
selection under an uncertain workload mix.

The paper's final remark (Section 11) observes that the robust formulation
generalizes to "any database tuning problem [with] a known cost model".
This module instantiates that for the *framework itself*:

  * workload vector  w = (train, prefill, decode, long) step fractions
    (exactly the 4-dim simplex of the paper's (z0, z1, q, w));
  * configurations Phi = discrete layout candidates (mesh split, remat,
    attention impl, SP on/off), each with a measured cost vector c(Phi) =
    per-class step seconds from the dry-run roofline terms;
  * ROBUST TUNING = argmin_Phi max_{w' in KL-ball} w'.c(Phi), solved with
    the same zero-gap dual (robust.robust_cost) — here the "design space"
    is discrete, so the outer argmin is exact enumeration.

The result is a layout that keeps serving well when the traffic mix drifts
(long-context bursts, prefill storms) — the systems analogue of the paper's
"robustness is an outcome of the tuning process" takeaway.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from .robust import robust_cost
from .workload import kl_divergence, worst_case_workload

STEP_CLASSES = ("train", "prefill", "decode", "long")


@dataclasses.dataclass
class LayoutCandidate:
    name: str
    step_costs: np.ndarray          # seconds per step class, shape (4,)
    meta: Optional[Dict] = None
    worst_case: float = float("nan")
    nominal_worst_case: float = float("nan")

    def expected_cost(self, mix: np.ndarray) -> float:
        return float(np.asarray(mix) @ self.step_costs)


def nominal_layout(candidates: Sequence[LayoutCandidate],
                   mix: np.ndarray) -> LayoutCandidate:
    """Problem 1 analogue: best layout for the expected mix."""
    return min(candidates, key=lambda c: c.expected_cost(mix))


def robust_layout(candidates: Sequence[LayoutCandidate], mix: np.ndarray,
                  rho: float) -> LayoutCandidate:
    """Problem 2 analogue: best worst-case layout over the KL ball.

    Discrete Phi -> exact enumeration; the inner max uses the same
    eta-eliminated dual as the LSM tuner (zero duality gap)."""
    return robust_layout_sweep(candidates, mix, [rho])[0]


def _grid_jit():
    """Module-cached jitted (C, mix, R) -> worst-case grid (compiled once
    per shape; a per-call lambda would re-trace on every invocation)."""
    global _GRID_FN
    if _GRID_FN is None:
        import jax

        def grid(C, mix, R):
            inner = jax.vmap(lambda c, r: robust_cost(c, mix, r),
                             in_axes=(None, 0))          # over rhos
            return jax.vmap(inner, in_axes=(0, None))(C, R)  # over candidates

        _GRID_FN = jax.jit(grid)
    return _GRID_FN


_GRID_FN = None


def worst_case_grid(candidates: Sequence[LayoutCandidate], mix: np.ndarray,
                    rhos: Sequence[float]) -> np.ndarray:
    """(len(candidates), len(rhos)) worst-case costs in ONE device dispatch.

    A re-tuning storm — every serving cell re-evaluating its layout after a
    fleet-wide mix shift — is a (candidate x rho) grid of ``robust_cost``
    duals; evaluating it as a vmap-over-vmap batch replaces per-cell jit
    dispatch, the same batching the LSM tuner got in ``core.batch``."""
    C = jnp.asarray(np.stack([c.step_costs for c in candidates]), jnp.float32)
    R = jnp.asarray(np.asarray(rhos, np.float32))
    mix_j = jnp.asarray(mix, jnp.float32)
    return np.asarray(_grid_jit()(C, mix_j, R))


def robust_layout_sweep(candidates: Sequence[LayoutCandidate],
                        mix: np.ndarray,
                        rhos: Sequence[float]) -> List[LayoutCandidate]:
    """The robust pick for every rho, from one batched worst-case grid.

    Equivalent to ``[robust_layout(candidates, mix, rho) for rho in rhos]``
    but the whole (candidate x rho) dual grid is a single jit; the returned
    candidates carry ``worst_case`` / ``nominal_worst_case`` for the LAST
    rho they were scored under (matching the sequential API)."""
    grid = worst_case_grid(candidates, mix, rhos)
    nom = nominal_layout(candidates, mix)
    nom_idx = next(i for i, c in enumerate(candidates) if c is nom)
    picks = []
    for j in range(grid.shape[1]):
        best_i = int(np.argmin(grid[:, j]))
        for i, c in enumerate(candidates):
            c.worst_case = float(grid[i, j])
            c.nominal_worst_case = float(grid[nom_idx, j])
        picks.append(candidates[best_i])
    return picks


def adversarial_mix(candidate: LayoutCandidate, mix: np.ndarray,
                    rho: float) -> np.ndarray:
    """The traffic mix that realizes the worst case for a layout."""
    return np.asarray(worst_case_workload(
        jnp.asarray(candidate.step_costs, jnp.float32),
        jnp.asarray(mix, jnp.float32), rho))


def candidates_from_dryrun(arch: str, dryrun_dir: str,
                           tags: Sequence[str] = ("baseline",),
                           mesh: str = "single") -> List[LayoutCandidate]:
    """Build layout candidates for one arch from dry-run records: one
    candidate per tag, cost vector = step_time_s of the four shapes."""
    d = pathlib.Path(dryrun_dir)
    shape_for = {"train": "train_4k", "prefill": "prefill_32k",
                 "decode": "decode_32k", "long": "long_500k"}
    out = []
    for tag in tags:
        costs = []
        ok = True
        for cls in STEP_CLASSES:
            f = d / f"{arch}__{shape_for[cls]}__{mesh}__{tag}.json"
            if not f.exists():
                ok = False
                break
            r = json.loads(f.read_text())
            if r["status"] == "skipped":
                costs.append(1e3)   # inapplicable class: huge penalty
            elif r["status"] != "ok":
                ok = False
                break
            else:
                costs.append(r["roofline"]["step_time_s"])
        if ok:
            out.append(LayoutCandidate(name=f"{arch}:{tag}:{mesh}",
                                       step_costs=np.asarray(costs)))
    return out
