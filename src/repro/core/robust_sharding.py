"""Beyond-paper: the ENDURE robust-tuning paradigm applied to mesh/layout
selection under an uncertain workload mix.

The paper's final remark (Section 11) observes that the robust formulation
generalizes to "any database tuning problem [with] a known cost model".
This module instantiates that for the *framework itself*:

  * workload vector  w = (train, prefill, decode, long) step fractions
    (exactly the 4-dim simplex of the paper's (z0, z1, q, w));
  * configurations Phi = discrete layout candidates (mesh split, remat,
    attention impl, SP on/off), each with a measured cost vector c(Phi) =
    per-class step seconds from the dry-run roofline terms;
  * ROBUST TUNING = argmin_Phi max_{w' in KL-ball} w'.c(Phi), solved with
    the same zero-gap dual (robust.robust_cost) — here the "design space"
    is discrete, so the outer argmin is exact enumeration.

The result is a layout that keeps serving well when the traffic mix drifts
(long-context bursts, prefill storms) — the systems analogue of the paper's
"robustness is an outcome of the tuning process" takeaway.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from .robust import robust_cost
from .workload import kl_divergence, worst_case_workload

STEP_CLASSES = ("train", "prefill", "decode", "long")


@dataclasses.dataclass
class LayoutCandidate:
    name: str
    step_costs: np.ndarray          # seconds per step class, shape (4,)
    meta: Optional[Dict] = None
    worst_case: float = float("nan")
    nominal_worst_case: float = float("nan")

    def expected_cost(self, mix: np.ndarray) -> float:
        return float(np.asarray(mix) @ self.step_costs)


def nominal_layout(candidates: Sequence[LayoutCandidate],
                   mix: np.ndarray) -> LayoutCandidate:
    """Problem 1 analogue: best layout for the expected mix."""
    return min(candidates, key=lambda c: c.expected_cost(mix))


def robust_layout(candidates: Sequence[LayoutCandidate], mix: np.ndarray,
                  rho: float) -> LayoutCandidate:
    """Problem 2 analogue: best worst-case layout over the KL ball.

    Discrete Phi -> exact enumeration; the inner max uses the same
    eta-eliminated dual as the LSM tuner (zero duality gap)."""
    mix_j = jnp.asarray(mix, jnp.float32)
    nom = nominal_layout(candidates, mix)
    nom_wc = float(robust_cost(jnp.asarray(nom.step_costs, jnp.float32),
                               mix_j, rho))
    best, best_wc = None, np.inf
    for c in candidates:
        wc = float(robust_cost(jnp.asarray(c.step_costs, jnp.float32),
                               mix_j, rho))
        c.worst_case = wc
        c.nominal_worst_case = nom_wc
        if wc < best_wc:
            best, best_wc = c, wc
    return best


def adversarial_mix(candidate: LayoutCandidate, mix: np.ndarray,
                    rho: float) -> np.ndarray:
    """The traffic mix that realizes the worst case for a layout."""
    return np.asarray(worst_case_workload(
        jnp.asarray(candidate.step_costs, jnp.float32),
        jnp.asarray(mix, jnp.float32), rho))


def candidates_from_dryrun(arch: str, dryrun_dir: str,
                           tags: Sequence[str] = ("baseline",),
                           mesh: str = "single") -> List[LayoutCandidate]:
    """Build layout candidates for one arch from dry-run records: one
    candidate per tag, cost vector = step_time_s of the four shapes."""
    d = pathlib.Path(dryrun_dir)
    shape_for = {"train": "train_4k", "prefill": "prefill_32k",
                 "decode": "decode_32k", "long": "long_500k"}
    out = []
    for tag in tags:
        costs = []
        ok = True
        for cls in STEP_CLASSES:
            f = d / f"{arch}__{shape_for[cls]}__{mesh}__{tag}.json"
            if not f.exists():
                ok = False
                break
            r = json.loads(f.read_text())
            if r["status"] == "skipped":
                costs.append(1e3)   # inapplicable class: huge penalty
            elif r["status"] != "ok":
                ok = False
                break
            else:
                costs.append(r["roofline"]["step_time_s"])
        if ok:
            out.append(LayoutCandidate(name=f"{arch}:{tag}:{mesh}",
                                       step_costs=np.asarray(costs)))
    return out
