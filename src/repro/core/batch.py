"""Batched tuning engine: single-jit (workload x rho x design) sweeps.

The paper's headline experiments (Figs. 6-10, Table 5) are *sweeps* — every
expected workload crossed with every uncertainty radius rho and candidate
design — yet solving each cell with a separate :func:`tune_nominal` /
:func:`tune_robust` call spends its time in Python dispatch and per-call jit
overhead instead of on the device.  This module flattens the full

    (workload x rho) x multi-start [x CLASSIC branch]

grid into one ``vmap``-over-``vmap`` problem compiled in a single ``jit``:

* :func:`tune_nominal_many`  — NOMINAL TUNING for a batch of workloads;
* :func:`tune_robust_many`   — ROBUST TUNING over a (workloads x rhos) grid.

CLASSIC (= best of {LEVELING, TIERING}) is handled by *folding* both branches
into one padded batch axis: the two designs share the same 2-parameter theta
layout, so each problem simply optimizes ``2 * n_starts`` starts where the
second half carries ``policy = 1.0`` (tiering) through
:func:`repro.core.designs.to_phi_policy`.  Because

    min(min over leveling starts, min over tiering starts)
      = min over the concatenated starts,

with ``argmin`` tie-breaking to the first (leveling) index — exactly the
recursive solver's ``min(cands, ...)`` order — the fold is semantics
preserving, and the shared inits (see ``designs.random_inits_many``) make the
batched results match the sequential tuners seed-for-seed.

Robust inner solve
------------------
The robust objective needs the 1-D convex dual minimum over ``lam`` at every
Adam step.  Instead of re-solving from a cold grid each time, each start
carries ``log lam*`` through the Adam ``lax.scan`` (``minimize_adam_carry``)
and refines it with :func:`repro.core.robust.dual_solve_warm`; only the very
first evaluation per start pays :func:`repro.core.robust.dual_solve_cold`.
See robust.py's module docstring for the warm-start exactness argument.  The
winning start is always re-scored with the full cold-grid ``robust_cost`` on
the integral (rounded) tuning, so reported costs are warm-start independent.

``tune_nominal`` / ``tune_robust`` are thin wrappers over this module with a
single-cell grid, so there is exactly one solver implementation.
"""

from __future__ import annotations

from functools import partial
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import designs
from .designs import DesignSpace
from .lsm_cost import LSMSystem, Phi, cost_vector, expected_cost
from ._opt import minimize_adam, minimize_adam_carry
from .nominal import TuningResult


def _phi_of(theta, policy, design: DesignSpace, sys: LSMSystem, smooth: bool):
    """theta -> Phi; CLASSIC routes through the traced policy axis."""
    if design is DesignSpace.CLASSIC:
        return designs.to_phi_policy(theta, policy, sys, smooth=smooth)
    return designs.to_phi(theta, design, sys, smooth=smooth)


@partial(jax.jit, static_argnames=("design", "sys", "n_starts", "steps",
                                   "lr", "robust"))
def _solve_many(key, W, rhos, design: DesignSpace, sys: LSMSystem,
                n_starts: int, steps: int, lr: float, robust: bool):
    """The single-jit sweep: W (P, 4) workloads, rhos (P,) radii.

    Returns per-problem arrays: exact cost of the winning start, its CLASSIC
    policy, and the raw + integral-rounded Phi components.  ``key`` is traced
    (a new seed must not recompile the sweep program).
    """
    from .robust import dual_solve_cold, dual_solve_warm, robust_cost

    P = W.shape[0]
    base = designs.random_inits_many(key, P, n_starts, design, sys)
    if design is DesignSpace.CLASSIC:
        # Fold LEVELING/TIERING onto the start axis: (P, 2 * n_starts, p).
        thetas = jnp.concatenate([base, base], axis=1)
        policies = jnp.concatenate([
            jnp.zeros((n_starts,), base.dtype),
            jnp.ones((n_starts,), base.dtype)])
    else:
        thetas = base
        policies = jnp.zeros((n_starts,), base.dtype)

    def solve_problem(w, rho, thetas_p):
        def run_start(theta0, pol):
            if robust:
                def obj(theta, llam):
                    c = cost_vector(_phi_of(theta, pol, design, sys, True),
                                    sys, smooth=True)
                    return dual_solve_warm(c, w, rho, llam)

                c0 = cost_vector(_phi_of(theta0, pol, design, sys, True),
                                 sys, smooth=True)
                _, llam0 = dual_solve_cold(c0, w, rho)
                best_t, _, _ = minimize_adam_carry(obj, theta0, llam0,
                                                   steps=steps, lr=lr)
            else:
                def obj(theta):
                    return expected_cost(
                        w, _phi_of(theta, pol, design, sys, True), sys,
                        smooth=True)

                best_t, _ = minimize_adam(obj, theta0, steps=steps, lr=lr)
            return best_t

        best_ts = jax.vmap(run_start)(thetas_p, policies)

        # Exact re-evaluation (ceil/round, cold-grid dual) before picking a
        # winner: the smooth warm-started objective is only a surrogate.
        def exact_eval(theta, pol):
            phi = _phi_of(theta, pol, design, sys, False).round_integral(sys)
            c = cost_vector(phi, sys, smooth=False)
            if robust:
                return robust_cost(c, w, rho)
            return jnp.dot(w, c)

        exact = jax.vmap(exact_eval)(best_ts, policies)
        i = jnp.argmin(jnp.where(jnp.isfinite(exact), exact, jnp.inf))
        t_win, pol_win = best_ts[i], policies[i]
        raw = _phi_of(t_win, pol_win, design, sys, False)
        phi = raw.round_integral(sys)
        return (exact[i], pol_win, raw.T, raw.mfilt_bits, raw.K, phi.T, phi.K)

    return jax.vmap(solve_problem)(W, rhos, thetas)


def _build_results(out, design: DesignSpace,
                   sys: LSMSystem) -> List[TuningResult]:
    """Device outputs -> TuningResults, numpy-only (no per-cell dispatches)."""
    cost, pol, T_raw, mfilt, K_raw, T_int, K_int = [
        np.asarray(x) for x in jax.device_get(out)]
    results = []
    for p in range(cost.shape[0]):
        if design is DesignSpace.CLASSIC:
            d = DesignSpace.TIERING if pol[p] > 0.5 else DesignSpace.LEVELING
        else:
            d = design
        raw_phi = Phi(T=T_raw[p], mfilt_bits=mfilt[p], K=K_raw[p])
        phi = Phi(T=T_int[p], mfilt_bits=mfilt[p], K=K_int[p])
        results.append(TuningResult(phi=phi, cost=float(cost[p]), design=d,
                                    raw_phi=raw_phi, solver="jax"))
    return results


def solve_grid(key, W_flat, rho_flat, design: DesignSpace, sys: LSMSystem,
               n_starts: int, steps: int, lr: float, robust: bool):
    """Flat-grid entry point for execution backends (repro.api.backends).

    Identical jit program to the ``tune_*_many`` wrappers, but the caller
    controls the placement of ``W_flat`` (P, 4) / ``rho_flat`` (P,) — e.g.
    device_put with a NamedSharding over the problem axis shards the vmap
    lanes across a mesh.  Pair with :func:`build_results` on the output."""
    return _solve_many(key, W_flat, rho_flat, design, sys, n_starts, steps,
                       lr, robust=robust)


def build_results(out, design: DesignSpace, sys: LSMSystem
                  ) -> List[TuningResult]:
    """Public counterpart of the device-output -> TuningResult conversion."""
    return _build_results(out, design, sys)


def _as_workload_matrix(workloads) -> jnp.ndarray:
    W = np.atleast_2d(np.asarray(workloads, np.float32))
    if W.ndim != 2 or W.shape[1] != 4:
        raise ValueError(f"workloads must be (P, 4), got {W.shape}")
    return jnp.asarray(W)


def tune_nominal_many(workloads, sys: LSMSystem,
                      design: DesignSpace = DesignSpace.CLASSIC,
                      n_starts: int = 64, steps: int = 250, lr: float = 0.25,
                      seed: int = 0) -> List[TuningResult]:
    """Solve NOMINAL TUNING for every workload in one device dispatch.

    Equivalent to ``[tune_nominal(w, sys, design, ...) for w in workloads]``
    (same seeds, same multi-start inits, same winner selection) but compiled
    as a single jit over the whole batch.
    """
    W = _as_workload_matrix(workloads)
    rhos = jnp.zeros((W.shape[0],), jnp.float32)
    out = _solve_many(jax.random.PRNGKey(seed), W, rhos, design, sys,
                      n_starts, steps, lr, robust=False)
    return _build_results(out, design, sys)


def tune_robust_many(workloads, rhos: Sequence[float], sys: LSMSystem,
                     design: DesignSpace = DesignSpace.CLASSIC,
                     n_starts: int = 64, steps: int = 250, lr: float = 0.25,
                     seed: int = 0) -> List[List[TuningResult]]:
    """Solve ROBUST TUNING over the full (workloads x rhos) grid in one jit.

    Returns a nested list indexed ``[workload][rho]``.  Equivalent to a
    sequential ``tune_robust`` double loop with the same seed, at a fraction
    of the wall clock (one dispatch, warm-started dual, folded CLASSIC).
    """
    W = _as_workload_matrix(workloads)
    R = np.asarray(rhos, np.float32).reshape(-1)
    n_w, n_r = W.shape[0], R.shape[0]
    W_flat = jnp.repeat(W, n_r, axis=0)
    rho_flat = jnp.asarray(np.tile(R, n_w))
    out = _solve_many(jax.random.PRNGKey(seed), W_flat, rho_flat, design,
                      sys, n_starts, steps, lr, robust=True)
    flat = _build_results(out, design, sys)
    return [flat[i * n_r:(i + 1) * n_r] for i in range(n_w)]
