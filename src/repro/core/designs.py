"""LSM design-space parameterizations (paper Table 3).

Every design is a differentiable map from an unconstrained parameter vector
``theta`` to a :class:`~repro.core.lsm_cost.Phi`, plus bookkeeping for the
number of free parameters.  The tuners (nominal.py / robust.py) are generic
over designs; this module is what makes K-LSM "unify" leveling, tiering,
Lazy Leveling, Fluid LSM (Dostoevsky) and 1-Leveling.

Parameterization (sigmoid box transforms keep everything feasible):
    T       = 2 + (maxT - 2) * sigmoid(t0)
    m_filt  = (m_total - min_buf) * sigmoid(t1)      [bits]
    K_i     = 1 + (T - 2) * sigmoid(t_i)             [in [1, T-1]]

``DOSTOEVSKY`` is Fluid-LSM with *fixed* memory allocation (paper Section 5.3:
m_filt = 10 bits/entry is the whole budget minus a fixed 2 MiB buffer).
"""

from __future__ import annotations

import enum
from typing import Callable

import jax
import jax.numpy as jnp

from .lsm_cost import LSMSystem, Phi, mbuf_bits, num_levels


class DesignSpace(enum.Enum):
    LEVELING = "leveling"           # K_i = 1
    TIERING = "tiering"             # K_i = T - 1
    CLASSIC = "classic"             # best of {leveling, tiering} (ENDURE's pi)
    LAZY_LEVELING = "lazy_leveling"  # K_L = 1, K_i = T-1 otherwise
    ONE_LEVELING = "one_leveling"   # K_1 = T-1, K_i = 1 otherwise
    FLUID = "fluid"                 # K_1..K_{L-1} equal, K_L free
    DOSTOEVSKY = "dostoevsky"       # FLUID with fixed memory split
    KLSM = "klsm"                   # every K_i free


DOSTOEVSKY_BUF_BITS = 2.0 * 1024 * 1024 * 8  # 2 MiB, paper Section 5.3


def n_params(design: DesignSpace, sys: LSMSystem) -> int:
    if design in (DesignSpace.LEVELING, DesignSpace.TIERING, DesignSpace.CLASSIC):
        return 2                      # (T, m_filt)
    if design in (DesignSpace.LAZY_LEVELING, DesignSpace.ONE_LEVELING):
        return 2
    if design is DesignSpace.FLUID:
        return 4                      # (T, m_filt, K_upper, K_last)
    if design is DesignSpace.DOSTOEVSKY:
        return 3                      # (T, K_upper, K_last); memory fixed
    if design is DesignSpace.KLSM:
        return 2 + sys.max_levels     # (T, m_filt, K_1..K_max)
    raise ValueError(design)


def _T_from(theta0: jnp.ndarray, sys: LSMSystem) -> jnp.ndarray:
    return 2.0 + (sys.max_T - 2.0) * jax.nn.sigmoid(theta0)


def _mfilt_from(theta1: jnp.ndarray, sys: LSMSystem) -> jnp.ndarray:
    return (sys.m_total_bits - sys.min_buf_bits) * jax.nn.sigmoid(theta1)


def _K_from(theta: jnp.ndarray, T: jnp.ndarray) -> jnp.ndarray:
    return 1.0 + jnp.maximum(T - 2.0, 0.0) * jax.nn.sigmoid(theta)


def to_phi(theta: jnp.ndarray, design: DesignSpace, sys: LSMSystem,
           smooth: bool = False) -> Phi:
    """Map unconstrained ``theta`` -> feasible ``Phi`` for ``design``."""
    idx = jnp.arange(1, sys.max_levels + 1, dtype=theta.dtype)

    if design is DesignSpace.DOSTOEVSKY:
        T = _T_from(theta[0], sys)
        mfilt = jnp.asarray(sys.m_total_bits - DOSTOEVSKY_BUF_BITS, theta.dtype)
        K_up = _K_from(theta[1], T)
        K_last = _K_from(theta[2], T)
    else:
        T = _T_from(theta[0], sys)
        mfilt = _mfilt_from(theta[1], sys)
        K_up = K_last = None

    if design in (DesignSpace.LEVELING,):
        K = jnp.ones((sys.max_levels,), theta.dtype)
    elif design is DesignSpace.TIERING:
        K = jnp.full((sys.max_levels,), 1.0) * jnp.maximum(T - 1.0, 1.0)
    elif design is DesignSpace.CLASSIC:
        raise ValueError("CLASSIC is solved as best-of {LEVELING, TIERING}; "
                         "tuners handle it explicitly.")
    elif design in (DesignSpace.LAZY_LEVELING, DesignSpace.ONE_LEVELING,
                    DesignSpace.FLUID, DesignSpace.DOSTOEVSKY):
        phi_tmp = Phi(T=T, mfilt_bits=mfilt, K=jnp.ones((sys.max_levels,)))
        L = num_levels(T, mbuf_bits(phi_tmp, sys), sys, smooth=False)
        is_last = (idx == L)
        if design is DesignSpace.LAZY_LEVELING:
            K = jnp.where(is_last, 1.0, jnp.maximum(T - 1.0, 1.0))
        elif design is DesignSpace.ONE_LEVELING:
            K = jnp.where(idx == 1, jnp.maximum(T - 1.0, 1.0), 1.0)
        else:  # FLUID / DOSTOEVSKY
            if design is DesignSpace.FLUID:
                K_up = _K_from(theta[2], T)
                K_last = _K_from(theta[3], T)
            K = jnp.where(is_last, K_last, K_up)
    elif design is DesignSpace.KLSM:
        K = _K_from(theta[2:2 + sys.max_levels], T)
    else:
        raise ValueError(design)

    return Phi(T=T, mfilt_bits=mfilt, K=K)


def to_phi_policy(theta: jnp.ndarray, policy: jnp.ndarray, sys: LSMSystem,
                  smooth: bool = False) -> Phi:
    """Design-axis-aware map for the CLASSIC family.

    ``policy`` selects the run-cap profile along a *traced* axis — 0.0 is
    LEVELING (K_i = 1), 1.0 is TIERING (K_i = max(T-1, 1)) — so the batched
    tuners can fold both CLASSIC branches into one (2 * n_starts) batch axis
    instead of two recursive Python calls.  Both branches share the same
    2-parameter theta layout, and at policy in {0.0, 1.0} this reproduces
    ``to_phi(theta, LEVELING/TIERING, sys)`` exactly.
    """
    T = _T_from(theta[0], sys)
    mfilt = _mfilt_from(theta[1], sys)
    K_tier = jnp.maximum(T - 1.0, 1.0)
    K = (1.0 + policy * (K_tier - 1.0)) * jnp.ones((sys.max_levels,),
                                                   theta.dtype)
    return Phi(T=T, mfilt_bits=mfilt, K=K)


#: engine-side compaction policies (repro.lsm.planner.POLICIES) the cost
#: model knows how to predict for — the policy axis of Table-5-style sweeps.
ENGINE_POLICIES = ("klsm", "lazy_leveling", "partial", "tombstone_ttl")


#: Calibrated steady-state fill of lazy leveling's upper levels, as a
#: fraction of the tiering headroom ``T - 2`` above the 1-run floor:
#: ``K_upper = 1 + LAZY_LEVELING_FILL * (T - 2)``.  The K = T-1 tiering
#: *ceiling* assumed upper levels sit at their run cap, but the measured
#: engine runs far below it — read-triggered squeezes drain the deepest
#: level, capacity spills empty upper levels wholesale, and read-dominant
#: sessions add few new runs — so the ceiling overestimated measured cost
#: ~2x on range-heavy mixes (agreement 0.45 in BENCH_compaction.json).
#: 0.125 is calibrated against that suite's measured sub-tiering steady
#: state (250k keys x 10k queries, T=6: ~1-1.6 live runs per upper level,
#: i.e. K_upper ~= 1.5 = 1 + 0.125 * (T-2)); it lifts the suite's
#: measured/model agreement to ~0.9 while keeping the policy's signature
#: (reads cost slightly more than leveling, writes slightly less).  The
#: regenerated baseline documents the post-calibration agreement.
LAZY_LEVELING_FILL = 0.125


def policy_effective_phi(phi: Phi, sys: LSMSystem, policy: str,
                         params: tuple = ()) -> Phi:
    """The Phi whose cost vector predicts ``phi`` deployed under an engine
    compaction policy.

    The cost model speaks only run-cap profiles (K_i), so each policy maps
    to the profile its steady state exhibits:

    * ``klsm`` / ``tombstone_ttl`` — the tuning's own K profile (TTL sweeps
      change *when* deletes are purged, not the steady-state shape);
    * ``lazy_leveling`` — a *measured sub-tiering* profile above, a single
      run at the last level (read pressure keeps the bottom squeezed):
      ``K_i = 1 + LAZY_LEVELING_FILL * (T-2)`` for ``i < L``, ``K_L = 1``.
      The previous ``K_i = T-1`` ceiling assumed upper levels pinned at
      their run cap; the engine's measured steady state sits near the
      1-run floor (see :data:`LAZY_LEVELING_FILL`), and the ceiling
      overestimated range-heavy cost ~2x;
    * ``partial`` — the tuning's own K profile (slice-at-a-time granularity
      changes per-trigger latency, not amortized totals: every entry still
      crosses every level once per level of depth).

    ``params`` are the policy's engine constructor kwargs as (name, value)
    pairs (:class:`repro.api.DesignSpec.policy_params`); a ``fill`` entry
    overrides :data:`LAZY_LEVELING_FILL` for the lazy profile.
    """
    if policy not in ENGINE_POLICIES:
        raise ValueError(f"unknown engine policy {policy!r}; "
                         f"known: {ENGINE_POLICIES}")
    if policy != "lazy_leveling":
        return phi
    fill = float(dict(params).get("fill", LAZY_LEVELING_FILL))
    idx = jnp.arange(1, sys.max_levels + 1, dtype=phi.K.dtype)
    L = num_levels(phi.T, mbuf_bits(phi, sys), sys, smooth=False)
    K_up = 1.0 + fill * jnp.maximum(phi.T - 2.0, 0.0)
    K = jnp.where(idx == L, 1.0, K_up)
    return Phi(T=phi.T, mfilt_bits=phi.mfilt_bits, K=K)


def describe(phi: Phi, sys: LSMSystem) -> str:
    """Human-readable tuning summary: (T, m_filt bits/entry, K-profile)."""
    import numpy as np
    T = float(phi.T)
    h = float(phi.mfilt_bits) / sys.N
    L = int(num_levels(phi.T, mbuf_bits(phi, sys), sys))
    K = np.asarray(phi.K)[:L]
    if np.allclose(K, 1.0):
        pol = "L"
    elif np.allclose(K, max(T - 1.0, 1.0), atol=0.5):
        pol = "T"
    else:
        pol = "K=" + ",".join(f"{k:.0f}" for k in K)
    return f"(T={T:.1f}, h={h:.1f}b/e, {pol})"


InitFn = Callable[[jax.Array, int], jnp.ndarray]


def random_inits(key: jax.Array, n: int, design: DesignSpace,
                 sys: LSMSystem) -> jnp.ndarray:
    """Multi-start initial thetas, shape (n, n_params)."""
    p = n_params(design, sys)
    return jax.random.uniform(key, (n, p), minval=-3.0, maxval=3.0)


def random_inits_many(key: jax.Array, n_problems: int, n_starts: int,
                      design: DesignSpace, sys: LSMSystem,
                      share: bool = True) -> jnp.ndarray:
    """Batched multi-start inits, shape (n_problems, n_starts, n_params).

    With ``share=True`` (default) every problem gets the *same* starts as a
    sequential ``random_inits(key, n_starts, ...)`` call would produce, so the
    batched tuners reproduce the sequential tuners' trajectories seed-for-seed
    (and CLASSIC's two folded branches see identical inits, as the recursive
    solver did).  ``share=False`` draws independent starts per problem.
    """
    if share:
        t = random_inits(key, n_starts, design, sys)
        return jnp.broadcast_to(t, (n_problems,) + t.shape)
    keys = jax.random.split(key, n_problems)
    return jax.vmap(lambda k: random_inits(k, n_starts, design, sys))(keys)
