"""A tiny pure-JAX Adam used by the vmapped multi-start tuners.

Deliberately dependency-free (no optax in the environment) and shaped so that
`jax.vmap` over independent optimization problems is trivial: state is a flat
pytree of arrays matching theta.

Two entry points:

* :func:`minimize_adam` — plain objective ``theta -> value``.
* :func:`minimize_adam_carry` — stateful objective
  ``(theta, carry) -> (value, carry')`` run under ``lax.scan``.  The carry
  threads solver-side state across Adam steps; the robust tuner uses it to
  warm-start the 1-D dual minimization over ``lam`` (see robust.py), so each
  step *refines* the previous dual solution instead of re-solving from a cold
  grid.  Gradients are taken w.r.t. ``theta`` only (``carry`` is auxiliary,
  never differentiated).

Both evaluate the objective exactly once per step (``value_and_grad``), plus
one final evaluation of the last iterate, and track the best value seen across
the whole trajectory — the same visited set {theta_0..theta_N} as the previous
two-evaluations-per-step fori_loop implementation, at half the cost.
"""

from __future__ import annotations

from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    mu: jnp.ndarray
    nu: jnp.ndarray
    step: jnp.ndarray


def adam_init(theta: jnp.ndarray) -> AdamState:
    return AdamState(mu=jnp.zeros_like(theta), nu=jnp.zeros_like(theta),
                     step=jnp.zeros((), jnp.int32))


def adam_update(grad: jnp.ndarray, state: AdamState, lr: float,
                b1: float = 0.9, b2: float = 0.999,
                eps: float = 1e-8) -> Tuple[jnp.ndarray, AdamState]:
    step = state.step + 1
    mu = b1 * state.mu + (1 - b1) * grad
    nu = b2 * state.nu + (1 - b2) * grad * grad
    mu_hat = mu / (1 - b1 ** step.astype(grad.dtype))
    nu_hat = nu / (1 - b2 ** step.astype(grad.dtype))
    delta = lr * mu_hat / (jnp.sqrt(nu_hat) + eps)
    return delta, AdamState(mu=mu, nu=nu, step=step)


def minimize_adam_carry(obj: Callable, theta0: jnp.ndarray, carry0,
                        steps: int, lr: float, lr_decay: float = 0.1):
    """Adam with cosine lr decay over a *stateful* objective.

    ``obj(theta, carry) -> (value, carry')``; the carry is an arbitrary pytree
    of solver state passed from one step to the next (treated as auxiliary by
    autodiff).  Returns ``(best_theta, best_value, final_carry)`` with the best
    pair tracked across every visited iterate, which makes the optimizer
    robust to late-stage oscillation.
    """
    vg = jax.value_and_grad(obj, has_aux=True)

    def step_fn(state, i):
        theta, st, carry, best_t, best_v = state
        frac = i / max(steps - 1, 1)
        lr_i = lr * (lr_decay + (1 - lr_decay) * 0.5 *
                     (1 + jnp.cos(jnp.pi * frac)))
        (v, carry), grad = vg(theta, carry)
        grad = jnp.where(jnp.isfinite(grad), grad, 0.0)
        better = jnp.isfinite(v) & (v < best_v)
        best_t = jnp.where(better, theta, best_t)
        best_v = jnp.where(better, v, best_v)
        delta, st = adam_update(grad, st, lr_i)
        return (theta - delta, st, carry, best_t, best_v), None

    init = (theta0, adam_init(theta0), carry0, theta0,
            jnp.asarray(jnp.inf, theta0.dtype))
    (theta, _, carry, best_t, best_v), _ = jax.lax.scan(
        step_fn, init, jnp.arange(steps))
    # The scan evaluated theta_0..theta_{N-1}; cover the final iterate too.
    v, carry = obj(theta, carry)
    better = jnp.isfinite(v) & (v < best_v)
    best_t = jnp.where(better, theta, best_t)
    best_v = jnp.where(better, v, best_v)
    return best_t, best_v, carry


def minimize_adam(obj: Callable[[jnp.ndarray], jnp.ndarray],
                  theta0: jnp.ndarray, steps: int, lr: float,
                  lr_decay: float = 0.1) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Run Adam for ``steps`` iterations with cosine lr decay to lr*lr_decay.

    Returns (best_theta, best_value) tracked across the whole trajectory.
    """
    best_t, best_v, _ = minimize_adam_carry(
        lambda t, c: (obj(t), c), theta0, (), steps=steps, lr=lr,
        lr_decay=lr_decay)
    return best_t, best_v
