"""A tiny pure-JAX Adam used by the vmapped multi-start tuners.

Deliberately dependency-free (no optax in the environment) and shaped so that
`jax.vmap` over independent optimization problems is trivial: state is a flat
pytree of arrays matching theta.
"""

from __future__ import annotations

from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    mu: jnp.ndarray
    nu: jnp.ndarray
    step: jnp.ndarray


def adam_init(theta: jnp.ndarray) -> AdamState:
    return AdamState(mu=jnp.zeros_like(theta), nu=jnp.zeros_like(theta),
                     step=jnp.zeros((), jnp.int32))


def adam_update(grad: jnp.ndarray, state: AdamState, lr: float,
                b1: float = 0.9, b2: float = 0.999,
                eps: float = 1e-8) -> Tuple[jnp.ndarray, AdamState]:
    step = state.step + 1
    mu = b1 * state.mu + (1 - b1) * grad
    nu = b2 * state.nu + (1 - b2) * grad * grad
    mu_hat = mu / (1 - b1 ** step.astype(grad.dtype))
    nu_hat = nu / (1 - b2 ** step.astype(grad.dtype))
    delta = lr * mu_hat / (jnp.sqrt(nu_hat) + eps)
    return delta, AdamState(mu=mu, nu=nu, step=step)


def minimize_adam(obj: Callable[[jnp.ndarray], jnp.ndarray],
                  theta0: jnp.ndarray, steps: int, lr: float,
                  lr_decay: float = 0.1) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Run Adam for ``steps`` iterations with cosine lr decay to lr*lr_decay.

    Returns (best_theta, best_value) tracked across the whole trajectory, which
    makes the optimizer robust to late-stage oscillation.
    """
    g = jax.grad(lambda t: obj(t))

    def body(i, carry):
        theta, st, best_t, best_v = carry
        frac = i / max(steps - 1, 1)
        lr_i = lr * (lr_decay + (1 - lr_decay) * 0.5 *
                     (1 + jnp.cos(jnp.pi * frac)))
        grad = g(theta)
        grad = jnp.where(jnp.isfinite(grad), grad, 0.0)
        delta, st = adam_update(grad, st, lr_i)
        theta = theta - delta
        v = obj(theta)
        better = jnp.isfinite(v) & (v < best_v)
        best_t = jnp.where(better, theta, best_t)
        best_v = jnp.where(better, v, best_v)
        return theta, st, best_t, best_v

    v0 = obj(theta0)
    v0 = jnp.where(jnp.isfinite(v0), v0, jnp.inf)
    init = (theta0, adam_init(theta0), theta0, v0)
    _, _, best_t, best_v = jax.lax.fori_loop(0, steps, body, init)
    return best_t, best_v
