"""ENDURE / K-LSM core: the paper's contribution as a composable JAX module.

- lsm_cost:  the unified K-LSM cost model (Eqs. 1-9)
- designs:   Table-3 design-space parameterizations
- nominal:   NOMINAL TUNING (Problem 1) solvers (JAX multistart + SLSQP)
- robust:    ROBUST TUNING (Problem 2) via the KL dual (Eqs. 16-17)
- batch:     single-jit (workload x rho x design) sweep engine backing both
             tuners (tune_nominal_many / tune_robust_many)
- workload:  KL uncertainty regions, exact inner maximizer, rho heuristics
- uncertainty_bench: Table 4 expected workloads + benchmark set B
- metrics:   Delta-throughput and throughput-range (Section 8.1)
- robust_sharding: beyond-paper — same dual applied to mesh/layout selection
"""

from .batch import (build_results, solve_grid, tune_nominal_many,
                    tune_robust_many)
from .designs import (ENGINE_POLICIES, LAZY_LEVELING_FILL, DesignSpace,
                      describe, policy_effective_phi, to_phi, to_phi_policy)
from .lsm_cost import (LSMSystem, Phi, cost_across_memory, cost_vector,
                       expected_cost, leveling_phi, make_phi, num_levels,
                       throughput, tiering_phi)
from .metrics import delta_throughput, delta_throughput_batch, throughput_range
from .nominal import TuningResult, tune_nominal, tune_nominal_slsqp
from .robust import (dual_solve_cold, dual_solve_warm, primal_worst_case,
                     robust_cost, tune_robust, tune_robust_slsqp)
from .uncertainty_bench import (EXPECTED_WORKLOADS, WORKLOAD_CATEGORY,
                                sample_benchmark, zippydb_like)
from .workload import (kl_divergence, rho_from_history, rho_from_pair,
                       rho_from_ranges, worst_case_workload)

__all__ = [
    "DesignSpace", "LSMSystem", "Phi", "TuningResult",
    "cost_vector", "cost_across_memory", "expected_cost", "throughput",
    "num_levels",
    "make_phi", "leveling_phi", "tiering_phi", "describe", "to_phi",
    "to_phi_policy", "ENGINE_POLICIES", "policy_effective_phi",
    "tune_nominal", "tune_nominal_slsqp", "tune_robust", "tune_robust_slsqp",
    "tune_nominal_many", "tune_robust_many", "solve_grid", "build_results",
    "LAZY_LEVELING_FILL",
    "robust_cost", "dual_solve_cold", "dual_solve_warm",
    "primal_worst_case", "worst_case_workload",
    "kl_divergence", "rho_from_history", "rho_from_pair", "rho_from_ranges",
    "delta_throughput", "delta_throughput_batch", "throughput_range",
    "EXPECTED_WORKLOADS", "WORKLOAD_CATEGORY", "sample_benchmark",
    "zippydb_like",
]
