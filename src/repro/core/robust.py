"""ROBUST TUNING (paper Problem 2, Section 6): ENDURE.

    Phi_R = argmin_Phi  max_{w' in U^rho_w}  w'^T c(Phi)

Solved through the Ben-Tal et al. dual (Eqs. 16-17):

    min_{Phi, lam>=0, eta}  eta + rho*lam + lam * sum_i w_i phi*_KL((c_i - eta)/lam)

with the KL conjugate ``phi*_KL(s) = e^s - 1``.  The inner minimization over
``eta`` has the closed form ``eta* = lam * log sum_i w_i exp(c_i / lam)``;
substituting gives the numerically robust *entropic risk* form

    g(lam; Phi) = rho*lam + lam * logsumexp_i( log w_i + c_i(Phi) / lam )

which we minimize over ``lam`` inside JAX (1-D convex problem), and over
``Phi`` by the same vmapped multi-start Adam as the nominal tuner.  This
substitution is *exact* (simple calculus on Eq. 16), not an approximation;
tests assert equality of both forms and a ~zero primal-dual gap against the
exact inner maximizer of workload.py.

Warm-started dual solve
-----------------------
``g(lam)`` is convex in ``log lam`` and its minimizer moves only slightly when
``Phi`` moves by one Adam step, so re-solving the 1-D problem from scratch at
every objective evaluation (a 64-point geometric grid + 40 golden-section
iterations) wastes almost all of its work.  The tuners instead thread
``log lam*`` through the Adam scan (see ``_opt.minimize_adam_carry``):

* :func:`dual_solve_cold` — one full grid + golden solve, used once per start
  at ``theta_0`` (with a grid cut to 24 points, enough to *bracket* the
  minimum — the golden refinement does the rest);
* :func:`dual_solve_warm` — a 3-point local scan around the carried
  ``log lam*`` followed by a short golden refinement, used at every Adam step.

Exactness: the returned value is ``g(lam_hat)`` with ``lam_hat`` the refined
bracket midpoint.  Since ``g`` is convex with minimum ``g(lam*)``, the value
is an upper bound whose error is *second order* in the bracket width (golden
section shrinks the width by 0.618^n), and gradients w.r.t. ``c`` are exact at
fixed ``lam_hat`` by the envelope theorem (``dg/dlam = 0`` at the minimum, so
freezing ``lam_hat`` with ``stop_gradient`` loses only the same second-order
term).  If the minimizer drifts outside the local window, the window
re-centers by up to ``half_width`` per step and re-locks within a few steps;
the final tuning is always re-scored with the full cold solve
(:func:`robust_cost`), so warm-start inaccuracy can never corrupt reported
costs — only, at worst, the search trajectory.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import designs
from .designs import DesignSpace
from .lsm_cost import LSMSystem, Phi, cost_vector
from .nominal import TuningResult, _theta_bounds
from .workload import worst_case_workload

_GR = 0.6180339887498949  # golden ratio conjugate


def dual_objective_explicit(c: jnp.ndarray, w: jnp.ndarray, rho: float,
                            lam: jnp.ndarray, eta: jnp.ndarray) -> jnp.ndarray:
    """Eq. 16 verbatim: eta + rho lam + lam sum w_i (exp((c_i-eta)/lam) - 1)."""
    lam = jnp.maximum(lam, 1e-12)
    s = (c - eta) / lam
    return eta + rho * lam + lam * jnp.sum(w * (jnp.exp(s) - 1.0))


def _g_of_lam(c: jnp.ndarray, w: jnp.ndarray, rho: float,
              lam: jnp.ndarray) -> jnp.ndarray:
    """g(lam) = rho lam + lam * LSE(log w + c/lam)  (eta eliminated)."""
    lam = jnp.maximum(lam, 1e-12)
    return rho * lam + lam * jax.nn.logsumexp(jnp.log(w) + c / lam)


def _golden_refine(c, w, rho, llo, lhi, n_golden: int):
    """Golden-section minimization of g(exp(llam)) on the log-lam bracket."""
    def body(_, bounds):
        llo, lhi = bounds
        a = lhi - _GR * (lhi - llo)
        b = llo + _GR * (lhi - llo)
        fa = _g_of_lam(c, w, rho, jnp.exp(a))
        fb = _g_of_lam(c, w, rho, jnp.exp(b))
        smaller = fa < fb
        return jnp.where(smaller, llo, a), jnp.where(smaller, b, lhi)

    return jax.lax.fori_loop(0, n_golden, body, (llo, lhi))


def _grid_bracket(c, w, rho, lams):
    """argmin over a lam grid -> (log lo, log hi) bracket around the min."""
    n = lams.shape[0]
    vals = jax.vmap(lambda l: _g_of_lam(c, w, rho, l))(lams)
    i = jnp.argmin(vals)
    lo = lams[jnp.maximum(i - 1, 0)]
    hi = lams[jnp.minimum(i + 1, n - 1)]
    return jnp.log(lo), jnp.log(hi)


def robust_cost(c: jnp.ndarray, w: jnp.ndarray, rho: float,
                n_grid: int = 64, n_golden: int = 40) -> jnp.ndarray:
    """Worst-case expected cost  max_{w' in U^rho_w} w'^T c  via the dual.

    The 1-D convex minimization over lam uses a geometric grid spanning the
    cost scale followed by golden-section refinement.  Differentiable in ``c``
    via the envelope theorem (gradients flow through g at the minimizing lam).
    This is the exact (cold-start) solve used for final scoring; the tuners'
    inner loops use the warm-started pair below.
    """
    w = jnp.asarray(w)
    c = jnp.asarray(c)
    span = jnp.maximum(jnp.max(c) - jnp.min(c), 1e-9)
    # lam* scales with span/rho-ish; cover many decades around it.
    lams = span * jnp.logspace(-6.0, 6.0, n_grid)
    llo, lhi = _grid_bracket(c, w, rho, lams)
    llo, lhi = _golden_refine(c, w, rho, llo, lhi, n_golden)
    lam_star = jnp.exp(0.5 * (llo + lhi))
    g = _g_of_lam(c, w, rho, lam_star)
    # rho = 0 degenerates to the nominal expected cost.
    return jnp.where(rho <= 0.0, jnp.dot(w, c), g)


def dual_solve_cold(c: jnp.ndarray, w: jnp.ndarray, rho,
                    n_grid: int = 24, n_golden: int = 20):
    """Full dual solve from scratch; returns ``(value, log lam*)``.

    The grid only needs to *bracket* the convex minimum (golden refinement
    does the rest), so it is cut to 24 points vs robust_cost's scoring-grade
    64.  Used once per multi-start at theta_0 to seed the warm carry.
    """
    c = jnp.asarray(c)
    w = jnp.asarray(w)
    span = jnp.maximum(jnp.max(c) - jnp.min(c), 1e-9)
    lams = span * jnp.logspace(-6.0, 6.0, n_grid)
    llo, lhi = _grid_bracket(c, w, rho, lams)
    llo, lhi = _golden_refine(c, w, rho, llo, lhi, n_golden)
    llam = jax.lax.stop_gradient(0.5 * (llo + lhi))
    val = jnp.where(rho <= 0.0, jnp.dot(w, c),
                    _g_of_lam(c, w, rho, jnp.exp(llam)))
    return val, llam


def dual_solve_warm(c: jnp.ndarray, w: jnp.ndarray, rho, llam,
                    half_width: float = 0.8, n_local: int = 3,
                    n_golden: int = 6, impl: str = "fused"):
    """One warm-started dual refinement; returns ``(value, new log lam*)``.

    Scans ``n_local`` points on ``llam +- half_width`` (log-lam), brackets the
    convex minimum, and golden-refines.  The carry means Adam steps *track*
    lam* instead of re-finding it; it is clipped to the same +-16-nat window
    around the cost span that the cold grid covers, so it can never drift into
    exp() overflow (e.g. at rho = 0, where g is minimized at lam -> inf).

    Delegates to the kernel tier (``repro.kernels.dual_solve``): the default
    ``impl="fused"`` is the cached-point golden section (12 g-evaluations per
    call vs the classic 16 of ``impl="ref"``, same 0.618^n bracket shrink and
    second-order value accuracy); a lane-tiled Pallas kernel of the same
    algorithm backs the batched entry point there.
    """
    from repro.kernels.dual_solve.ops import dual_solve_warm as _warm
    return _warm(c, w, rho, llam, half_width=half_width, n_local=n_local,
                 n_golden=n_golden, impl=impl)


def robust_phi_objective(phi: Phi, w: jnp.ndarray, rho: float,
                         sys: LSMSystem, smooth: bool = False) -> jnp.ndarray:
    return robust_cost(cost_vector(phi, sys, smooth=smooth), w, rho)


# ---------------------------------------------------------------------------
# JAX multi-start robust tuner (delegates to the batched engine, P = 1)
# ---------------------------------------------------------------------------

def tune_robust(w, rho: float, sys: LSMSystem,
                design: DesignSpace = DesignSpace.CLASSIC,
                n_starts: int = 64, steps: int = 250, lr: float = 0.25,
                seed: int = 0) -> TuningResult:
    """ENDURE: solve ROBUST TUNING for ``design`` at uncertainty radius rho.

    Thin wrapper over :func:`repro.core.batch.tune_robust_many` with a
    1x1 (workload, rho) grid; CLASSIC is folded into a single padded batch
    axis there rather than solved as two recursive calls.
    """
    from .batch import tune_robust_many  # local import: batch imports us
    return tune_robust_many([w], [rho], sys, design=design, n_starts=n_starts,
                            steps=steps, lr=lr, seed=seed)[0][0]


def tune_robust_slsqp(w, rho: float, sys: LSMSystem,
                      design: DesignSpace = DesignSpace.CLASSIC,
                      n_starts: int = 8, seed: int = 0) -> TuningResult:
    """Paper-faithful SLSQP solve of Eq. 17 (over Phi, lam, eta jointly)."""
    from scipy.optimize import minimize

    if design is DesignSpace.CLASSIC:
        cands = [tune_robust_slsqp(w, rho, sys, d, n_starts, seed)
                 for d in (DesignSpace.LEVELING, DesignSpace.TIERING)]
        return min(cands, key=lambda r: r.cost)

    w = jnp.asarray(w, jnp.float32)
    n_phi = designs.n_params(design, sys)

    @jax.jit
    def obj(x):
        theta, log_lam, eta = x[:n_phi], x[n_phi], x[n_phi + 1]
        phi = designs.to_phi(theta, design, sys, smooth=True)
        c = cost_vector(phi, sys, smooth=True)
        return dual_objective_explicit(c, w, rho, jnp.exp(log_lam), eta)

    vag = jax.jit(jax.value_and_grad(obj))

    def f(x):
        v, g = vag(jnp.asarray(x, jnp.float32))
        return float(v), np.asarray(g, np.float64)

    rng = np.random.default_rng(seed)
    best_x, best_v = None, np.inf
    bounds = _theta_bounds(design, sys) + [(-10.0, 10.0), (None, None)]
    for _ in range(n_starts):
        x0 = np.concatenate([rng.uniform(-3, 3, n_phi), [0.0], [1.0]])
        try:
            res = minimize(f, x0, jac=True, method="SLSQP", bounds=bounds,
                           options={"maxiter": 300, "ftol": 1e-12})
        except Exception:
            continue
        if np.isfinite(res.fun) and res.fun < best_v:
            best_x, best_v = res.x, float(res.fun)
    if best_x is None:
        return tune_robust(w, rho, sys, design, seed=seed)

    raw_phi = designs.to_phi(jnp.asarray(best_x[:n_phi], jnp.float32),
                             design, sys)
    phi = raw_phi.round_integral(sys)
    cost = float(robust_phi_objective(phi, w, rho, sys))
    return TuningResult(phi=phi, cost=cost, design=design, raw_phi=raw_phi,
                        solver="slsqp")


# ---------------------------------------------------------------------------
# Primal-side evaluation helpers
# ---------------------------------------------------------------------------

def primal_worst_case(phi: Phi, w, rho: float, sys: LSMSystem):
    """(worst-case workload, worst-case cost) for the *primal* problem; used
    to verify the zero duality gap (Lemma 1)."""
    c = cost_vector(phi, sys)
    w_hat = worst_case_workload(c, jnp.asarray(w), rho)
    return w_hat, jnp.dot(w_hat, c)
