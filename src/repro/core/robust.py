"""ROBUST TUNING (paper Problem 2, Section 6): ENDURE.

    Phi_R = argmin_Phi  max_{w' in U^rho_w}  w'^T c(Phi)

Solved through the Ben-Tal et al. dual (Eqs. 16-17):

    min_{Phi, lam>=0, eta}  eta + rho*lam + lam * sum_i w_i phi*_KL((c_i - eta)/lam)

with the KL conjugate ``phi*_KL(s) = e^s - 1``.  The inner minimization over
``eta`` has the closed form ``eta* = lam * log sum_i w_i exp(c_i / lam)``;
substituting gives the numerically robust *entropic risk* form

    g(lam; Phi) = rho*lam + lam * logsumexp_i( log w_i + c_i(Phi) / lam )

which we minimize over ``lam`` by geometric-grid + golden refinement inside
JAX (1-D convex problem), and over ``Phi`` by the same vmapped multi-start
Adam as the nominal tuner.  This substitution is *exact* (simple calculus on
Eq. 16), not an approximation; tests assert equality of both forms and a
~zero primal-dual gap against the exact inner maximizer of workload.py.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import designs
from ._opt import minimize_adam
from .designs import DesignSpace
from .lsm_cost import LSMSystem, Phi, cost_vector, expected_cost
from .nominal import TuningResult, _theta_bounds
from .workload import kl_divergence, worst_case_workload


def dual_objective_explicit(c: jnp.ndarray, w: jnp.ndarray, rho: float,
                            lam: jnp.ndarray, eta: jnp.ndarray) -> jnp.ndarray:
    """Eq. 16 verbatim: eta + rho lam + lam sum w_i (exp((c_i-eta)/lam) - 1)."""
    lam = jnp.maximum(lam, 1e-12)
    s = (c - eta) / lam
    return eta + rho * lam + lam * jnp.sum(w * (jnp.exp(s) - 1.0))


def _g_of_lam(c: jnp.ndarray, w: jnp.ndarray, rho: float,
              lam: jnp.ndarray) -> jnp.ndarray:
    """g(lam) = rho lam + lam * LSE(log w + c/lam)  (eta eliminated)."""
    lam = jnp.maximum(lam, 1e-12)
    return rho * lam + lam * jax.nn.logsumexp(jnp.log(w) + c / lam)


def robust_cost(c: jnp.ndarray, w: jnp.ndarray, rho: float,
                n_grid: int = 64, n_golden: int = 40) -> jnp.ndarray:
    """Worst-case expected cost  max_{w' in U^rho_w} w'^T c  via the dual.

    The 1-D convex minimization over lam uses a geometric grid spanning the
    cost scale followed by golden-section refinement.  Differentiable in ``c``
    via the envelope theorem (gradients flow through g at the minimizing lam).
    """
    w = jnp.asarray(w)
    c = jnp.asarray(c)
    span = jnp.maximum(jnp.max(c) - jnp.min(c), 1e-9)
    # lam* scales with span/rho-ish; cover many decades around it.
    lams = span * jnp.logspace(-6.0, 6.0, n_grid)
    vals = jax.vmap(lambda l: _g_of_lam(c, w, rho, l))(lams)
    i = jnp.argmin(vals)
    lo = lams[jnp.maximum(i - 1, 0)]
    hi = lams[jnp.minimum(i + 1, n_grid - 1)]

    # Golden-section on log-lam.
    gr = 0.6180339887498949
    llo, lhi = jnp.log(lo), jnp.log(hi)

    def body(_, bounds):
        llo, lhi = bounds
        a = lhi - gr * (lhi - llo)
        b = llo + gr * (lhi - llo)
        fa = _g_of_lam(c, w, rho, jnp.exp(a))
        fb = _g_of_lam(c, w, rho, jnp.exp(b))
        smaller = fa < fb
        return jnp.where(smaller, llo, a), jnp.where(smaller, b, lhi)

    llo, lhi = jax.lax.fori_loop(0, n_golden, body, (llo, lhi))
    lam_star = jnp.exp(0.5 * (llo + lhi))
    g = _g_of_lam(c, w, rho, lam_star)
    # rho = 0 degenerates to the nominal expected cost.
    return jnp.where(rho <= 0.0, jnp.dot(w, c), g)


def robust_phi_objective(phi: Phi, w: jnp.ndarray, rho: float,
                         sys: LSMSystem, smooth: bool = False) -> jnp.ndarray:
    return robust_cost(cost_vector(phi, sys, smooth=smooth), w, rho)


# ---------------------------------------------------------------------------
# JAX multi-start robust tuner
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("design", "sys", "n_starts", "steps", "lr"))
def _tune_robust_batch(key, w, rho, design: DesignSpace, sys: LSMSystem,
                       n_starts: int, steps: int, lr: float):
    thetas = designs.random_inits(key, n_starts, design, sys)

    def obj(theta):
        phi = designs.to_phi(theta, design, sys, smooth=True)
        return robust_phi_objective(phi, w, rho, sys, smooth=True)

    best_t, _ = jax.vmap(lambda t0: minimize_adam(obj, t0, steps=steps,
                                                  lr=lr))(thetas)

    def exact_obj(theta):
        phi = designs.to_phi(theta, design, sys, smooth=False)
        phi = phi.round_integral(sys)
        return robust_phi_objective(phi, w, rho, sys, smooth=False)

    exact = jax.vmap(exact_obj)(best_t)
    i = jnp.argmin(jnp.where(jnp.isfinite(exact), exact, jnp.inf))
    return best_t[i], exact[i]


def tune_robust(w, rho: float, sys: LSMSystem,
                design: DesignSpace = DesignSpace.CLASSIC,
                n_starts: int = 64, steps: int = 250, lr: float = 0.25,
                seed: int = 0) -> TuningResult:
    """ENDURE: solve ROBUST TUNING for ``design`` at uncertainty radius rho."""
    w = jnp.asarray(w, jnp.float32)
    rho = float(rho)
    if design is DesignSpace.CLASSIC:
        cands = [tune_robust(w, rho, sys, d, n_starts, steps, lr, seed)
                 for d in (DesignSpace.LEVELING, DesignSpace.TIERING)]
        return min(cands, key=lambda r: r.cost)

    key = jax.random.PRNGKey(seed)
    theta, _ = _tune_robust_batch(key, w, jnp.asarray(rho, jnp.float32),
                                  design, sys, n_starts, steps, lr)
    raw_phi = designs.to_phi(theta, design, sys, smooth=False)
    phi = raw_phi.round_integral(sys)
    cost = float(robust_phi_objective(phi, w, rho, sys))
    return TuningResult(phi=phi, cost=cost, design=design, raw_phi=raw_phi,
                        solver="jax")


def tune_robust_slsqp(w, rho: float, sys: LSMSystem,
                      design: DesignSpace = DesignSpace.CLASSIC,
                      n_starts: int = 8, seed: int = 0) -> TuningResult:
    """Paper-faithful SLSQP solve of Eq. 17 (over Phi, lam, eta jointly)."""
    from scipy.optimize import minimize

    if design is DesignSpace.CLASSIC:
        cands = [tune_robust_slsqp(w, rho, sys, d, n_starts, seed)
                 for d in (DesignSpace.LEVELING, DesignSpace.TIERING)]
        return min(cands, key=lambda r: r.cost)

    w = jnp.asarray(w, jnp.float32)
    n_phi = designs.n_params(design, sys)

    @jax.jit
    def obj(x):
        theta, log_lam, eta = x[:n_phi], x[n_phi], x[n_phi + 1]
        phi = designs.to_phi(theta, design, sys, smooth=True)
        c = cost_vector(phi, sys, smooth=True)
        return dual_objective_explicit(c, w, rho, jnp.exp(log_lam), eta)

    vag = jax.jit(jax.value_and_grad(obj))

    def f(x):
        v, g = vag(jnp.asarray(x, jnp.float32))
        return float(v), np.asarray(g, np.float64)

    rng = np.random.default_rng(seed)
    best_x, best_v = None, np.inf
    bounds = _theta_bounds(design, sys) + [(-10.0, 10.0), (None, None)]
    for _ in range(n_starts):
        x0 = np.concatenate([rng.uniform(-3, 3, n_phi), [0.0], [1.0]])
        try:
            res = minimize(f, x0, jac=True, method="SLSQP", bounds=bounds,
                           options={"maxiter": 300, "ftol": 1e-12})
        except Exception:
            continue
        if np.isfinite(res.fun) and res.fun < best_v:
            best_x, best_v = res.x, float(res.fun)
    if best_x is None:
        return tune_robust(w, rho, sys, design, seed=seed)

    raw_phi = designs.to_phi(jnp.asarray(best_x[:n_phi], jnp.float32),
                             design, sys)
    phi = raw_phi.round_integral(sys)
    cost = float(robust_phi_objective(phi, w, rho, sys))
    return TuningResult(phi=phi, cost=cost, design=design, raw_phi=raw_phi,
                        solver="slsqp")


# ---------------------------------------------------------------------------
# Primal-side evaluation helpers
# ---------------------------------------------------------------------------

def primal_worst_case(phi: Phi, w, rho: float, sys: LSMSystem):
    """(worst-case workload, worst-case cost) for the *primal* problem; used
    to verify the zero duality gap (Lemma 1)."""
    c = cost_vector(phi, sys)
    w_hat = worst_case_workload(c, jnp.asarray(w), rho)
    return w_hat, jnp.dot(w_hat, c)
