"""Evaluation metrics (paper Section 8.1)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .lsm_cost import LSMSystem, Phi, cost_vector


def delta_throughput(w: jnp.ndarray, phi1: Phi, phi2: Phi,
                     sys: LSMSystem) -> jnp.ndarray:
    """Normalized delta throughput Delta_w(phi1, phi2); > 0 iff phi2 wins."""
    c1 = jnp.dot(w, cost_vector(phi1, sys))
    c2 = jnp.dot(w, cost_vector(phi2, sys))
    return (1.0 / c2 - 1.0 / c1) / (1.0 / c1)


def delta_throughput_batch(W: jnp.ndarray, phi1: Phi, phi2: Phi,
                           sys: LSMSystem) -> jnp.ndarray:
    """Vectorized over a workload set, shape (n, 4) -> (n,)."""
    c1v = cost_vector(phi1, sys)
    c2v = cost_vector(phi2, sys)
    c1 = W @ c1v
    c2 = W @ c2v
    return (1.0 / c2 - 1.0 / c1) / (1.0 / c1)


def throughput_range(W: jnp.ndarray, phi: Phi, sys: LSMSystem) -> jnp.ndarray:
    """Theta_B(phi) = max over workload pairs of throughput difference
    = max 1/C - min 1/C over the benchmark set."""
    thr = 1.0 / (W @ cost_vector(phi, sys))
    return jnp.max(thr) - jnp.min(thr)
