"""Workloads, KL-divergence uncertainty regions, and the rho heuristics.

A workload is a probability vector ``w = (z0, z1, q, w_frac)`` over the four
query classes (paper Section 3).  The uncertainty region (Eq. 12) is

    U^rho_w = { w' >= 0 : sum w' = 1, I_KL(w', w) <= rho }.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

QUERY_CLASSES = ("z0", "z1", "q", "w")
DIM = 4


def normalize(w: jnp.ndarray) -> jnp.ndarray:
    w = jnp.maximum(w, 0.0)
    return w / jnp.sum(w, axis=-1, keepdims=True)


def kl_divergence(p: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """I_KL(p, q) = sum_i p_i log(p_i / q_i); 0 log 0 := 0 (Definition 1)."""
    p = jnp.asarray(p)
    q = jnp.asarray(q)
    ratio = jnp.where(p > 0, p / jnp.maximum(q, 1e-30), 1.0)
    return jnp.sum(jnp.where(p > 0, p * jnp.log(ratio), 0.0), axis=-1)


def worst_case_workload(c: jnp.ndarray, w: jnp.ndarray, rho: float,
                        iters: int = 80) -> jnp.ndarray:
    """Exact inner maximizer of Eq. 13: argmax_{w' in U^rho_w} w'^T c.

    The maximizer is the exponential tilt  w'_i ∝ w_i exp(c_i / lam)  with the
    temperature ``lam >= 0`` chosen so that I_KL(w', w) = rho (or lam -> 0 when
    even the point mass on argmax c is inside the ball).  Solved by bisection;
    fully differentiable in ``c`` via the closed form at fixed lam.
    """
    c = jnp.asarray(c, jnp.float64) if jax.config.jax_enable_x64 else jnp.asarray(c)
    w = normalize(jnp.asarray(w, c.dtype))
    span = jnp.maximum(jnp.max(c) - jnp.min(c), 1e-12)

    def tilt(lam):
        logits = jnp.log(w) + c / jnp.maximum(lam, 1e-12)
        return jax.nn.softmax(logits)

    # Degenerate cases: rho <= 0 -> w itself; flat costs -> w itself.
    def kl_at(lam):
        return kl_divergence(tilt(lam), w)

    # KL(tilt(lam), w) is decreasing in lam; find lam with KL = rho.
    lo = span * 1e-9
    hi = span * 1e9

    def body(_, bounds):
        lo, hi = bounds
        mid = jnp.sqrt(lo * hi)  # geometric bisection over many decades
        too_spread = kl_at(mid) > rho
        return jnp.where(too_spread, mid, lo), jnp.where(too_spread, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    lam = jnp.sqrt(lo * hi)
    w_hat = tilt(lam)
    # If even the most adversarial tilt stays within rho (max KL is bounded by
    # -log w_argmax), return the point-mass-limit tilt at tiny lam.
    w_lim = tilt(jnp.asarray(span * 1e-9, c.dtype))
    w_hat = jnp.where(kl_at(span * 1e-9) <= rho, w_lim, w_hat)
    return jnp.where(rho <= 0.0, w, jnp.where(span < 1e-12, w, w_hat))


def rho_from_history(workloads: np.ndarray) -> float:
    """Algorithm 1: rho = max_i I_KL(w_i, w_bar) over historical workloads."""
    W = np.asarray(workloads, dtype=np.float64)
    w_bar = W.mean(axis=0)
    kls = np.array([float(kl_divergence(w, w_bar)) for w in W])
    return float(kls.max())


def rho_from_pair(expected: np.ndarray, off_period: np.ndarray) -> float:
    """DBA heuristic: KL between an expected and an off-period workload."""
    return float(kl_divergence(np.asarray(off_period), np.asarray(expected)))


def rho_from_ranges(lo: np.ndarray, hi: np.ndarray, n_samples: int = 4096,
                    seed: int = 0) -> float:
    """DBA heuristic: sample workloads within per-class ranges, apply Alg. 1."""
    rng = np.random.default_rng(seed)
    lo = np.asarray(lo, np.float64)
    hi = np.asarray(hi, np.float64)
    samples = rng.uniform(lo, hi, size=(n_samples, DIM))
    samples = samples / samples.sum(axis=1, keepdims=True)
    return rho_from_history(samples)
