"""K-LSM unified cost model (paper Eqs. 1-9), written as differentiable JAX.

The model maps an LSM configuration ``Phi = (T, m_filt, K_1..K_L)`` and system
parameters to the expected I/O cost of the four query classes

    c(Phi) = (Z0, Z1, Q, W)

- ``Z0``: empty point lookups   (Eq. 4)
- ``Z1``: non-empty point lookups (Eq. 6)
- ``Q`` : range lookups          (Eq. 7)
- ``W`` : writes (amortized)     (Eq. 9)

with Monkey-style per-level Bloom-filter false-positive rates (Eq. 3).

Design notes
------------
* Everything is written against a *static* ``max_levels`` ladder with masking
  so the model is ``jit``/``vmap``/``grad`` compatible.  Levels ``i > L(T)``
  contribute zero.
* ``L(T)`` (Eq. 1) uses an exact ``ceil`` by default (paper semantics).  The
  tuners optionally use a smooth interpolation for better-behaved gradients
  (the paper relaxes integrality of T the same way, Section 5.2); evaluation
  is always exact.
* All memory quantities are in **bits** (paper convention): entry size ``E``
  in bits, total memory ``m = m_buf + m_filt`` in bits.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

LN2_SQ = 0.4804530139182014  # ln(2)^2


@dataclasses.dataclass(frozen=True)
class LSMSystem:
    """System ("untunable") parameters, paper Table 1 + Section 4.1.

    Defaults follow the paper's model-based study (Sections 5.3, 8.2):
    10B entries of 1 KiB, 4 KiB pages, 10 bits/entry of total memory.
    """

    N: float = 1e10              # total number of entries
    entry_bits: float = 8192.0   # E, bits per entry (1 KiB)
    page_bits: float = 32768.0   # page size in bits (4 KiB)
    bits_per_entry: float = 10.0  # total memory budget m / N (filters + buffer)
    f_a: float = 1.0             # storage read/write asymmetry (writes cost f_a x reads)
    f_seq: float = 1.0           # sequential-vs-random I/O cost ratio
    s_rq: float = 5e-9           # range query selectivity S_RQ (short ranges)
    min_buf_bits: float = 8.0 * 1024 * 1024 * 8  # floor on m_buf (8 MiB), keeps L finite
    max_levels: int = 24         # static ladder size (must exceed any realistic L)
    max_T: float = 100.0         # solver bound on size ratio

    @property
    def B(self) -> float:
        """Entries per page."""
        return self.page_bits / self.entry_bits

    @property
    def m_total_bits(self) -> float:
        return self.bits_per_entry * self.N

    def replace(self, **kw: Any) -> "LSMSystem":
        return dataclasses.replace(self, **kw)


# Registered as a pytree-compatible static object (hashable dataclass); we pass
# it through `partial`/closures rather than traced args.


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Phi:
    """An LSM tuning configuration.

    ``T``: size ratio (scalar, >= 2)
    ``mfilt_bits``: Bloom-filter memory in bits (scalar); buffer gets the rest.
    ``K``: per-level run caps, shape ``(max_levels,)``; entries beyond ``L(T)``
    are ignored by the cost model.
    """

    T: jnp.ndarray
    mfilt_bits: jnp.ndarray
    K: jnp.ndarray

    def tree_flatten(self):
        return (self.T, self.mfilt_bits, self.K), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    def round_integral(self, sys: LSMSystem) -> "Phi":
        """Deploy-time integer rounding (paper Section 5.2): ceil(T), round(K)."""
        T = jnp.ceil(self.T)
        K = jnp.clip(jnp.round(self.K), 1.0, jnp.maximum(T - 1.0, 1.0))
        return Phi(T=T, mfilt_bits=self.mfilt_bits, K=K)


def mbuf_bits(phi: Phi, sys: LSMSystem, m_total_bits=None) -> jnp.ndarray:
    """Buffer memory = total budget - filter bits.  ``m_total_bits``
    overrides the system's static budget with a *traced* value — the hook
    the fleet memory arbiter sweeps per-tenant budgets through without
    recompiling per candidate (``sys`` stays a static closure constant)."""
    mtot = sys.m_total_bits if m_total_bits is None else m_total_bits
    return mtot - phi.mfilt_bits


def num_levels(T: jnp.ndarray, mbuf: jnp.ndarray, sys: LSMSystem,
               smooth: bool = False) -> jnp.ndarray:
    """Eq. 1: L(T) = ceil( log_T( N*E/m_buf + 1 ) ). ``smooth`` skips the ceil
    (used only inside gradient-based tuners; evaluation is exact)."""
    T = jnp.maximum(T, 1.0 + 1e-6)
    x = sys.N * sys.entry_bits / jnp.maximum(mbuf, sys.min_buf_bits) + 1.0
    lf = jnp.log(x) / jnp.log(T)
    if smooth:
        return jnp.maximum(lf, 1.0)
    return jnp.maximum(jnp.ceil(lf), 1.0)


def level_fprs(phi: Phi, sys: LSMSystem, smooth: bool = False) -> jnp.ndarray:
    """Eq. 3 (Monkey allocation): per-level false positive rates, shape
    ``(max_levels,)``, clipped to [~0, 1]. Levels beyond L contribute via the
    mask applied by callers."""
    T = jnp.maximum(phi.T, 1.0 + 1e-6)
    L = num_levels(T, mbuf_bits(phi, sys), sys, smooth=smooth)
    i = jnp.arange(1, sys.max_levels + 1, dtype=phi.T.dtype)
    # T^{T/(T-1)} / T^{L+1-i} * exp(-(m_filt/N) ln(2)^2)
    log_T = jnp.log(T)
    log_f = (T / (T - 1.0)) * log_T - (L + 1.0 - i) * log_T \
        - (phi.mfilt_bits / sys.N) * LN2_SQ
    return jnp.clip(jnp.exp(jnp.minimum(log_f, 0.0)), 1e-30, 1.0)


def level_mask(phi: Phi, sys: LSMSystem, smooth: bool = False) -> jnp.ndarray:
    """1.0 for levels 1..L, 0.0 beyond. With ``smooth`` the last level gets a
    fractional weight so that d(mask)/dT exists through L."""
    L = num_levels(phi.T, mbuf_bits(phi, sys), sys, smooth=smooth)
    i = jnp.arange(1, sys.max_levels + 1, dtype=phi.T.dtype)
    if smooth:
        return jnp.clip(L - i + 1.0, 0.0, 1.0)
    return (i <= L).astype(phi.T.dtype)


def _clamped_K(phi: Phi) -> jnp.ndarray:
    """K_i in [1, T-1] (a leveling run cap floor of 1; tiering cap of T-1)."""
    return jnp.clip(phi.K, 1.0, jnp.maximum(phi.T - 1.0, 1.0))


def empty_read_cost(phi: Phi, sys: LSMSystem, smooth: bool = False) -> jnp.ndarray:
    """Eq. 4: Z0 = sum_i K_i * f_i."""
    f = level_fprs(phi, sys, smooth=smooth)
    m = level_mask(phi, sys, smooth=smooth)
    K = _clamped_K(phi)
    return jnp.sum(m * K * f)


def nonempty_read_cost(phi: Phi, sys: LSMSystem, smooth: bool = False) -> jnp.ndarray:
    """Eq. 6: expectation over the level holding the entry of
    1 (the hit) + false-positive I/Os above + half the runs within the level."""
    T = jnp.maximum(phi.T, 1.0 + 1e-6)
    f = level_fprs(phi, sys, smooth=smooth)
    m = level_mask(phi, sys, smooth=smooth)
    K = _clamped_K(phi)
    mbuf = jnp.maximum(mbuf_bits(phi, sys), sys.min_buf_bits)
    i = jnp.arange(1, sys.max_levels + 1, dtype=phi.T.dtype)
    # level capacity (entries): (T-1) T^{i-1} m_buf / E   (Eq. 5 summand).
    # Mask in log-space: exp() of masked-out deep levels would overflow f32
    # and poison the sum with inf*0 = nan.
    log_cap = jnp.log(T - 1.0) + (i - 1.0) * jnp.log(T) + jnp.log(mbuf / sys.entry_bits)
    cap = jnp.exp(jnp.where(m > 0, log_cap, -jnp.inf)) * m
    Nf = jnp.sum(cap)  # Eq. 5
    p_level = cap / jnp.maximum(Nf, 1.0)
    # false positives strictly above level i: cumsum shifted by one
    kf = m * K * f
    above = jnp.cumsum(kf) - kf
    per_level = 1.0 + above + 0.5 * (K - 1.0) * f
    return jnp.sum(p_level * per_level)


def range_cost(phi: Phi, sys: LSMSystem, smooth: bool = False) -> jnp.ndarray:
    """Eq. 7: Q = f_seq * S_RQ * N/B + sum_i K_i."""
    m = level_mask(phi, sys, smooth=smooth)
    K = _clamped_K(phi)
    return sys.f_seq * sys.s_rq * sys.N / sys.B + jnp.sum(m * K)


def write_cost(phi: Phi, sys: LSMSystem, smooth: bool = False) -> jnp.ndarray:
    """Eq. 9: W = f_seq * (1+f_a)/B * sum_i (T - 1 + K_i) / (2 K_i)."""
    m = level_mask(phi, sys, smooth=smooth)
    K = _clamped_K(phi)
    per_level = (phi.T - 1.0 + K) / (2.0 * K)
    return sys.f_seq * (1.0 + sys.f_a) / sys.B * jnp.sum(m * per_level)


def cost_vector(phi: Phi, sys: LSMSystem, smooth: bool = False,
                m_total_bits=None) -> jnp.ndarray:
    """c(Phi) = (Z0, Z1, Q, W), paper Section 3.

    Fused implementation: identical formulas to the four component functions
    above (tests assert elementwise equality), but the shared intermediates
    (L, per-level FPRs, level mask, clamped K) are computed once instead of
    once per component — this sits on the tuners' innermost hot path, where it
    runs at every Adam step for every (workload, rho, start) lane.

    ``m_total_bits`` (traced) replaces ``sys.m_total_bits`` — the memory
    axis the fleet arbiter differentiates tenants along; ``None`` (default)
    is bit-identical to the two-argument form.
    """
    T = jnp.maximum(phi.T, 1.0 + 1e-6)
    mbuf_raw = mbuf_bits(phi, sys, m_total_bits)
    mbuf = jnp.maximum(mbuf_raw, sys.min_buf_bits)
    L = num_levels(T, mbuf_raw, sys, smooth=smooth)
    i = jnp.arange(1, sys.max_levels + 1, dtype=phi.T.dtype)
    log_T = jnp.log(T)

    # Eq. 3 (Monkey FPRs) and the 1..L mask.
    log_f = (T / (T - 1.0)) * log_T - (L + 1.0 - i) * log_T \
        - (phi.mfilt_bits / sys.N) * LN2_SQ
    f = jnp.clip(jnp.exp(jnp.minimum(log_f, 0.0)), 1e-30, 1.0)
    if smooth:
        m = jnp.clip(L - i + 1.0, 0.0, 1.0)
    else:
        m = (i <= L).astype(phi.T.dtype)
    K = _clamped_K(phi)

    # Eq. 4.
    kf = m * K * f
    z0 = jnp.sum(kf)

    # Eqs. 5-6 (masked in log-space; see nonempty_read_cost).
    log_cap = jnp.log(T - 1.0) + (i - 1.0) * log_T \
        + jnp.log(mbuf / sys.entry_bits)
    cap = jnp.exp(jnp.where(m > 0, log_cap, -jnp.inf)) * m
    Nf = jnp.sum(cap)
    p_level = cap / jnp.maximum(Nf, 1.0)
    above = jnp.cumsum(kf) - kf
    z1 = jnp.sum(p_level * (1.0 + above + 0.5 * (K - 1.0) * f))

    # Eq. 7.
    q = sys.f_seq * sys.s_rq * sys.N / sys.B + jnp.sum(m * K)

    # Eq. 9.
    w = sys.f_seq * (1.0 + sys.f_a) / sys.B \
        * jnp.sum(m * (phi.T - 1.0 + K) / (2.0 * K))

    return jnp.stack([z0, z1, q, w])


def expected_cost(w: jnp.ndarray, phi: Phi, sys: LSMSystem,
                  smooth: bool = False) -> jnp.ndarray:
    """Eq. 2: C(w, Phi) = w^T c(Phi); w = (z0, z1, q, w)."""
    return jnp.dot(w, cost_vector(phi, sys, smooth=smooth))


def throughput(w: jnp.ndarray, phi: Phi, sys: LSMSystem) -> jnp.ndarray:
    """Paper Section 8.1: throughput := 1 / C(w, Phi)."""
    return 1.0 / expected_cost(w, phi, sys)


def cost_across_memory(phi: Phi, sys: LSMSystem,
                       budgets_bpe: jnp.ndarray,
                       smooth: bool = False) -> jnp.ndarray:
    """``(G, 4)`` cost vectors of ``phi`` re-deployed at each per-entry
    memory budget in ``budgets_bpe`` (bits/entry), holding the tuning's
    filter/buffer *split fraction* fixed while the total scales.

    This is the marginal-benefit curve the fleet memory arbiter scores
    tenants with: the true post-re-tune cost re-optimizes the split under
    the granted budget, so the fixed-fraction curve is a (tight,
    conservative) upper bound on it.  One vmap over the budget grid; the
    budget is traced (see :func:`cost_vector`), so every tenant/grid
    combination shares a single compilation."""
    b = jnp.asarray(budgets_bpe, jnp.float32)

    def at(budget):
        scale = budget / sys.bits_per_entry
        phi_b = Phi(T=phi.T, mfilt_bits=phi.mfilt_bits * scale, K=phi.K)
        return cost_vector(phi_b, sys, smooth=smooth,
                           m_total_bits=budget * sys.N)

    return jax.vmap(at)(b)


# ---------------------------------------------------------------------------
# Convenience constructors for the classic designs (Table 3 reference points).
# The tuners build Phi through designs.py; these are for tests/baselines.
# ---------------------------------------------------------------------------

def make_phi(T: float, mfilt_bits: float, K, sys: LSMSystem) -> Phi:
    K = jnp.broadcast_to(jnp.asarray(K, dtype=jnp.float32), (sys.max_levels,))
    return Phi(T=jnp.asarray(T, jnp.float32),
               mfilt_bits=jnp.asarray(mfilt_bits, jnp.float32), K=K)


def leveling_phi(T: float, mfilt_bits: float, sys: LSMSystem) -> Phi:
    return make_phi(T, mfilt_bits, 1.0, sys)


def tiering_phi(T: float, mfilt_bits: float, sys: LSMSystem) -> Phi:
    return make_phi(T, mfilt_bits, max(T - 1.0, 1.0), sys)
