"""The adversary arm: the robust objective's inner max as a live opponent.

ENDURE's guarantee is a dual bound: for a tuning ``phi`` with cost vector
``c = c(phi)``, every workload ``w'`` inside the KL ball
``U^rho_w = {w' : I_KL(w', w) <= rho}`` satisfies

    w'^T c  <=  max_{w'' in U^rho_w} w''^T c  =  min_lam [dual]  (Eq. 13)

so a robust tuning's *measured regret* — realized cost over the nominal
cost ``w^T c`` — can never exceed the dual bound's margin while the
executed workload stays inside the ball.  This scenario turns the
quantifier into an opponent: each drift window it reads the defender's
live state (deployed ``phi``, current KL center ``w``, live budget
``rho``), solves the inner max *exactly*
(:func:`repro.core.worst_case_workload`: exponential tilt + bisection on
``I_KL = rho``), and executes that worst case against every arm.  Each
window emits a regret record — chosen mix, its KL from the center, the
nominal / realized model costs, and the independently-computed dual bound
(:func:`repro.core.robust_cost`) — and the gated claim
``claim_regret_le_dual_bound`` asserts realized <= bound on every window:
zero duality gap, measured live.

The defender is the adapting arm when present (``online``), else the
robust one, else whatever deployed — so the ball tracks re-centering: an
online defender that re-tunes moves both ``w`` and ``rho``, and the
adversary re-aims inside the *new* ball.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

from .base import Scenario

#: defender preference: the adversary attacks the adapting arm when it is
#: deployed, else the static robust arm, else whatever is present.
DEFENDER_ORDER = ("online", "static_robust", "stale_nominal", "oracle")


class AdversaryScenario(Scenario):
    """Per-window worst-case workload inside the defender's rho-ball.

    ``rho`` is the fallback ball radius when the defender carries none (a
    nominal deployment has ``rho_live = 0``; its "ball" is a point, which
    makes the claim vacuous); ``iters`` is the bisection depth of the
    inner-max solve.  The static schedule is a placeholder (the expected
    mix tiled) — ``execute_drift`` replaces every segment's mix with
    :meth:`attack`'s choice at run time."""

    kind = "adversary"
    PARAMS = {"rho": 0.25, "iters": 80}

    def __init__(self, drift):
        super().__init__(drift)
        if float(self.params["rho"]) <= 0.0:
            raise ValueError("adversary fallback rho must be > 0")

    @property
    def is_adversary(self) -> bool:
        return True

    def attack(self, phi, w_center, rho_live: float,
               sys) -> Tuple[np.ndarray, dict]:
        """Solve the inner max against one deployed tuning.

        Returns ``(w_adv, record)``: the worst-case mix inside the ball
        ``U^rho_{w_center}`` for the tuning's cost vector, plus the regret
        record (model costs, KL dual bound, per-window verdict).  Lazy jax
        imports keep this module numpy-only for spec-loading workers."""
        from repro.core import (cost_vector, kl_divergence, robust_cost,
                                worst_case_workload)
        w0 = np.asarray(w_center, np.float64)
        w0 = w0 / w0.sum()
        rho = float(rho_live) if rho_live > 0.0 else float(self.params["rho"])
        c = np.asarray(cost_vector(phi, sys), np.float64)
        w_adv = np.asarray(worst_case_workload(
            c, w0, rho, iters=int(self.params["iters"])), np.float64)
        w_adv = np.maximum(w_adv, 0.0)
        w_adv = w_adv / w_adv.sum()
        nominal = float(c @ w0)
        realized = float(c @ w_adv)
        bound = float(robust_cost(c, w0, rho))
        record = {
            "rho": rho,
            "w_center": [round(float(x), 6) for x in w0],
            "w_adv": [round(float(x), 6) for x in w_adv],
            "kl_adv": float(kl_divergence(w_adv, w0)),
            "cost_nominal": nominal,
            "cost_adv": realized,
            "dual_bound": bound,
            "regret": realized - nominal,
            # realized <= bound up to solver tolerance: the dual bound is
            # computed by an independent solver (1-D dual minimization vs
            # the primal tilt), so this is a real cross-check, not x <= x
            "le_dual_bound": bool(realized <= bound * (1.0 + 1e-6) + 1e-9),
        }
        return w_adv, record
