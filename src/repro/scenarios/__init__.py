"""Scenario engine: adversarial and trace-shaped workload generators.

Drift experiments before this package moved mixes along synthetic paths
(gradual / flip / cyclic).  A *scenario* is a named stress pattern from
real deployments — heavy-tailed key skew with a migrating hot set, flash
crowds, queue-like tombstone churn, range-scan-dominant analytics — plus
an *adversary* that plays the robust formulation's inner max live: each
drift window it solves ``argmax_{w' in U^rho_w} w'^T c`` against the
deployed tuning and executes that worst case, so the paper's KL dual
bound becomes a measured, gated claim instead of a theorem
(``claim_regret_le_dual_bound``; see ``docs/scenarios.md``).

Every scenario lowers onto existing machinery: it is a
:class:`repro.api.DriftSpec` ``kind`` whose generator supplies the
per-segment true-mix schedule plus session-plan shaping
(:func:`repro.lsm.materialize_session` kwargs — Zipf exponent and hot-set
offset, per-segment arrival scaling, delete fraction, range-scan span) —
so the whole library runs unchanged on the ``inline`` / ``sharded`` /
``subprocess`` backends, with faults and memory arbitration composing on
top, and lands in the same ``Report`` / BENCH schema.

The module is numpy-only at import time (specs must stay loadable in
jax-free worker processes); the adversary's solver imports live lazily.
"""

from __future__ import annotations

from .adversary import AdversaryScenario
from .base import Scenario
from .library import (BurstStormScenario, ScanHeavyScenario,
                      TombstoneChurnScenario, ZipfMigrateScenario)

#: kind -> generator class; ``DriftSpec.kind`` selects from here.
SCENARIOS = {
    cls.kind: cls
    for cls in (ZipfMigrateScenario, BurstStormScenario,
                TombstoneChurnScenario, ScanHeavyScenario,
                AdversaryScenario)
}

SCENARIO_KINDS = frozenset(SCENARIOS)


def get_scenario(drift) -> "Scenario | None":
    """Instantiate the generator for a drift spec, or None for the classic
    kinds (gradual / flip / cyclic / schedule)."""
    cls = SCENARIOS.get(drift.kind)
    return cls(drift) if cls is not None else None


def validate_scenario_params(kind: str, pairs) -> None:
    """Spec-time validation: every (name, value) pair must be a knob the
    scenario declares (typos surface at construction, not mid-run)."""
    cls = SCENARIOS[kind]
    unknown = sorted(set(dict(pairs)) - set(cls.PARAMS))
    if unknown:
        raise ValueError(f"unknown {kind!r} scenario params {unknown}; "
                         f"known: {sorted(cls.PARAMS)}")


__all__ = ["Scenario", "ZipfMigrateScenario", "BurstStormScenario",
           "TombstoneChurnScenario", "ScanHeavyScenario",
           "AdversaryScenario", "SCENARIOS", "SCENARIO_KINDS",
           "get_scenario", "validate_scenario_params"]
