"""The scenario generator contract.

A scenario owns three decisions of a drift experiment, each a pure
function of (spec, segment index) so replays are deterministic and
backends stay bit-identical:

* :meth:`Scenario.schedule` — the (S, 4) true-mix trajectory (what the
  classic kinds compute in ``repro.api.compile.drift_schedule``);
* :meth:`Scenario.segment_queries` — the arrival volume of a segment
  (burst scenarios scale it; everything else returns the spec's
  ``n_queries``);
* :meth:`Scenario.session_kwargs` — extra
  :func:`repro.lsm.materialize_session` shaping (Zipf exponent + hot-set
  offset, delete fraction, range-scan span).

The adversary overrides none of these usefully — its mix is chosen *live*
per window against the deployed tuning (``is_adversary`` routes
``repro.online.execute_drift`` to :meth:`AdversaryScenario.attack`), so
its static schedule is a placeholder tile of the expected mix.
"""

from __future__ import annotations

from typing import Any, Dict

import numpy as np


def _norm(w) -> np.ndarray:
    w = np.asarray(w, np.float64)
    return w / w.sum()


class Scenario:
    """Base generator: constant-at-expected schedule, unshaped sessions."""

    kind: str = ""
    #: knob name -> default; ``DriftSpec.scenario_params`` overrides these
    #: (unknown names are rejected at spec construction).
    PARAMS: Dict[str, Any] = {}

    def __init__(self, drift):
        self.drift = drift
        given = dict(drift.scenario_params)
        unknown = sorted(set(given) - set(self.PARAMS))
        if unknown:
            raise ValueError(f"unknown {self.kind!r} scenario params "
                             f"{unknown}; known: {sorted(self.PARAMS)}")
        self.params = {**self.PARAMS, **given}

    @property
    def is_adversary(self) -> bool:
        return False

    def target_mix(self, default) -> np.ndarray:
        """The spec's ``target`` when declared, else the scenario default."""
        t = self.drift.target
        return _norm(default if t is None else t)

    def ramp(self, expected, target, t: np.ndarray) -> np.ndarray:
        """Interpolated (S, 4) schedule along blend coefficients ``t``."""
        w0, w1 = _norm(expected), _norm(target)
        sched = (1.0 - t)[:, None] * w0 + t[:, None] * w1
        return sched / sched.sum(axis=1, keepdims=True)

    # -- the three hooks ----------------------------------------------------

    def schedule(self, expected) -> np.ndarray:
        """Per-segment true mixes, (S, 4); default holds the expected mix."""
        return np.tile(_norm(expected), (int(self.drift.segments), 1))

    def segment_queries(self, segment: int) -> int:
        """Arrival volume of one segment (default: the spec's)."""
        return int(self.drift.n_queries)

    def session_kwargs(self, segment: int, n_existing: int) -> Dict[str, Any]:
        """Extra ``materialize_session`` kwargs for one segment."""
        return {}
