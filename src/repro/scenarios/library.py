"""The trace-shaped scenario library.

Four stress patterns the synthetic gradual/flip/cyclic drifts never
reach, each chosen so the executed workload tilts toward *expensive*
query classes (the direction the KL worst case points and the robust
hedge anticipates — see the "direction matters" finding in
``docs/online.md``):

* :class:`ZipfMigrateScenario` — heavy-tailed key skew whose hot set
  migrates every segment (caching/Bloom locality keeps breaking);
* :class:`BurstStormScenario` — flash crowds: periodic segments arrive at
  ``amplitude`` x the baseline volume under a different (read-heavy) mix;
* :class:`TombstoneChurnScenario` — queue-like insert/delete churn: a
  write-dominant mix where a fraction of writes delete the oldest live
  keys (the Sarkar et al. taxonomy's tombstone workload);
* :class:`ScanHeavyScenario` — analytics arriving: the mix ramps toward
  range scans and the scans themselves widen.
"""

from __future__ import annotations

from typing import Any, Dict

import numpy as np

from .base import Scenario


class ZipfMigrateScenario(Scenario):
    """Zipf(a) key skew on non-empty reads with a per-segment hot-set
    migration: segment s rotates the rank->key mapping by
    ``migrate * s * n_existing`` positions, so yesterday's hot keys are
    cold today.  The mix ramps from the expected toward a non-empty-read-
    dominant target (skew only matters on reads that hit)."""

    kind = "zipf_migrate"
    PARAMS = {"zipf_a": 1.35, "migrate": 0.25}

    def schedule(self, expected) -> np.ndarray:
        S = int(self.drift.segments)
        t = np.arange(S, dtype=np.float64) / max(S - 1, 1)
        return self.ramp(expected, self.target_mix((0.10, 0.70, 0.10, 0.10)),
                         t)

    def session_kwargs(self, segment: int, n_existing: int) -> Dict[str, Any]:
        shift = int(float(self.params["migrate"]) * segment
                    * max(n_existing, 1))
        return {"zipf_a": float(self.params["zipf_a"]),
                "hot_offset": shift}


class BurstStormScenario(Scenario):
    """Flash crowds: every ``period``-th segment is a burst arriving at
    ``amplitude`` x the baseline volume (up to 1000x) under the target mix
    (default read-heavy — a crowd reads); quiet segments run the expected
    mix at baseline volume.  KL-only triggers lag here: the estimator's
    window dilutes a short burst, which is what the Page-Hinkley detector
    option (``DriftSpec.detector``) is for."""

    kind = "burst_storm"
    PARAMS = {"amplitude": 8.0, "period": 4}

    def __init__(self, drift):
        super().__init__(drift)
        amp = float(self.params["amplitude"])
        if not 1.0 <= amp <= 1000.0:
            raise ValueError(f"burst amplitude {amp} outside [1, 1000]")
        if int(self.params["period"]) < 2:
            raise ValueError("burst period must be >= 2 segments")

    def is_burst(self, segment: int) -> bool:
        period = int(self.params["period"])
        return segment % period == period - 1

    def schedule(self, expected) -> np.ndarray:
        S = int(self.drift.segments)
        t = np.array([1.0 if self.is_burst(s) else 0.0 for s in range(S)])
        return self.ramp(expected, self.target_mix((0.25, 0.60, 0.10, 0.05)),
                         t)

    def segment_queries(self, segment: int) -> int:
        base = int(self.drift.n_queries)
        if self.is_burst(segment):
            return max(1, int(round(base * float(self.params["amplitude"]))))
        return base


class TombstoneChurnScenario(Scenario):
    """Queue-like churn: after a calm first segment the mix flips to the
    write-dominant target and ``delete_fraction`` of every session's
    writes become deletes of the *oldest* live keys (tombstones flow down
    toward the data they shadow — the pattern that exposes round-robin
    partial-compaction slice selection and motivates overlap-based
    selection in ``lsm/planner.py``)."""

    kind = "tombstone_churn"
    PARAMS = {"delete_fraction": 0.5}

    def __init__(self, drift):
        super().__init__(drift)
        df = float(self.params["delete_fraction"])
        if not 0.0 <= df <= 1.0:
            raise ValueError(f"delete_fraction {df} outside [0, 1]")

    def schedule(self, expected) -> np.ndarray:
        S = int(self.drift.segments)
        t = (np.arange(S) >= 1).astype(np.float64)
        return self.ramp(expected, self.target_mix((0.05, 0.10, 0.05, 0.80)),
                         t)

    def session_kwargs(self, segment: int, n_existing: int) -> Dict[str, Any]:
        if segment == 0:
            return {}
        return {"delete_fraction": float(self.params["delete_fraction"])}


class ScanHeavyScenario(Scenario):
    """Analytics arriving: the mix ramps linearly toward a range-scan-
    dominant target while the scans widen to ``scan_scale`` x the spec's
    ``range_fraction`` — the workload the paper's q-cost term (and
    fence/seek accounting) is most sensitive to."""

    kind = "scan_heavy"
    PARAMS = {"scan_scale": 8.0}

    def schedule(self, expected) -> np.ndarray:
        S = int(self.drift.segments)
        t = np.arange(S, dtype=np.float64) / max(S - 1, 1)
        return self.ramp(expected, self.target_mix((0.05, 0.10, 0.80, 0.05)),
                         t)

    def session_kwargs(self, segment: int, n_existing: int) -> Dict[str, Any]:
        S = int(self.drift.segments)
        t = segment / max(S - 1, 1)
        scale = 1.0 + (float(self.params["scan_scale"]) - 1.0) * t
        return {"range_fraction": float(self.drift.range_fraction) * scale}
