"""Dense jnp reference for the fused per-level point read.

Same contract as ``lsm.read_path.point_read_level_numpy`` — Bloom probe
+ fence + per-run binary search for a key batch against one level, with
sequential-equivalent accounting — but expressed as fixed-shape dense
ops (masks instead of boolean compaction) so the Pallas kernel can
mirror it op for op.  Counters come back *per key* (their sums are the
engine's integers; the decomposition is what the bit-equivalence tests
compare).

Requires 64-bit mode (``jax.experimental.enable_x64``): the Bloom hash
is the engine's exact splitmix64 over uint64 keys.  ``ops.py`` manages
the x64 scope; on TPU hardware uint64 would need limb emulation — this
tier is exercised in interpret mode until then (see docs/kernels.md).
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

_GAMMA = 0x9E3779B97F4A7C15


def splitmix64_jnp(x: jnp.ndarray, seed: int) -> jnp.ndarray:
    """Elementwise splitmix64, bit-identical to ``lsm.bloom.splitmix64``."""
    z = x + jnp.uint64(seed) * jnp.uint64(_GAMMA)
    z = (z ^ (z >> jnp.uint64(30))) * jnp.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> jnp.uint64(27))) * jnp.uint64(0x94D049BB133111EB)
    return z ^ (z >> jnp.uint64(31))


def point_read_level_ref(sub_keys: jnp.ndarray, arena_keys: jnp.ndarray,
                         arena_vals: jnp.ndarray, starts: Tuple[int, ...],
                         words: jnp.ndarray, n_bits: Tuple[int, ...],
                         ks: Tuple[int, ...], use_limb_hash: bool = False
                         ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray,
                                    jnp.ndarray, jnp.ndarray]:
    """Returns (hit, enc, probes_pk, reads_pk, fps_pk), each (B,).

    ``starts``/``n_bits``/``ks`` are static host tuples (the level's
    run layout); ``words`` is the level's packed (R, Wmax) filter
    matrix.  Runs are visited newest -> oldest; per-key counters add 1
    probe per run visited while unresolved, 1 read per Bloom-positive
    visit, 1 false positive per Bloom-positive visit that missed.

    ``use_limb_hash`` routes the Bloom hash through the uint32-limb
    splitmix64 (``limb.py``, bit-identical by construction and by test)
    instead of native uint64 — the TPU-portable arithmetic path.
    """
    B = sub_keys.shape[0]
    R = len(starts) - 1
    kmax = max(ks) if R else 0
    if use_limb_hash:
        from .limb import mod_limbs, split64_jnp, splitmix64_limbs
        xlo, xhi = split64_jnp(sub_keys)
        hs_limb = [splitmix64_limbs(xlo, xhi, j + 1) for j in range(kmax)]
    else:
        hs = [splitmix64_jnp(sub_keys, j + 1) for j in range(kmax)]

    hit = jnp.zeros(B, bool)
    enc = jnp.zeros(B, jnp.int64)
    live = jnp.ones(B, bool)
    probes = jnp.zeros(B, jnp.int64)
    reads = jnp.zeros(B, jnp.int64)
    fps = jnp.zeros(B, jnp.int64)

    for r in range(R):
        probes = probes + live
        bloom_ok = jnp.ones(B, bool)
        for j in range(ks[r]):
            if use_limb_hash:
                hm = mod_limbs(*hs_limb[j], int(n_bits[r])) \
                    .astype(jnp.uint64)
            else:
                hm = hs[j] % jnp.uint64(n_bits[r])
            w = words[r, (hm >> jnp.uint64(6)).astype(jnp.int64)]
            bloom_ok &= ((w >> (hm & jnp.uint64(63)))
                         & jnp.uint64(1)).astype(bool)
        pos = live & bloom_ok
        reads = reads + pos
        s, e = int(starts[r]), int(starts[r + 1])
        if e > s:
            rkeys = arena_keys[s:e]
            loc = jnp.searchsorted(rkeys, sub_keys)
            safe = jnp.minimum(loc, e - s - 1)
            found = pos & (loc < e - s) & (rkeys[safe] == sub_keys)
            venc = arena_vals[s:e][safe]
            hit = hit | found
            enc = jnp.where(found, venc, enc)
            live = live & ~found
        else:
            found = jnp.zeros(B, bool)
        fps = fps + (pos & ~found)
    return hit, enc, probes, reads, fps


def as_static(x) -> Tuple[int, ...]:
    """Host metadata array -> hashable tuple of Python ints."""
    return tuple(int(v) for v in np.asarray(x))
