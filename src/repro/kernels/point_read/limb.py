"""splitmix64 on 32-bit limbs: the TPU-portable Bloom hash.

The dense reference (``ref.py``) and the Pallas kernel both hash with
native uint64 splitmix64, which confines them to x64-capable backends —
TPU vector units have no 64-bit integer lanes (``docs/kernels.md``).
This module re-expresses the exact same function over pairs of uint32
limbs ``(lo, hi)`` using only 32-bit adds, multiplies, shifts and
selects, so the hash tier of the fused point-read kernel is expressible
on hardware without uint64.  Every op is wrap-around mod 2^32 (uint32
semantics), and the composition is *bit-identical* to
``lsm.bloom.splitmix64`` — the test suite checks all 64 bits against the
numpy engine hash, plus the reduced ``% n_bits`` positions the filter
probe actually consumes.

The mod reduction (``mod_limbs``) is 32 steps of shift-and-conditional-
subtract after a native 32-bit remainder of the high limb: it needs the
modulus below 2^31 (so ``2*r + bit`` cannot wrap), which every per-run
filter size satisfies by orders of magnitude.
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

_GAMMA = 0x9E3779B97F4A7C15
_MUL1 = 0xBF58476D1CE4E5B9
_MUL2 = 0x94D049BB133111EB
_MASK32 = 0xFFFFFFFF


def to_limbs(x: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Host-side uint64 array -> (lo, hi) uint32 limb arrays."""
    x = np.asarray(x, np.uint64)
    lo = (x & np.uint64(_MASK32)).astype(np.uint32)
    hi = (x >> np.uint64(32)).astype(np.uint32)
    return lo, hi


def from_limbs(lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
    """Host-side (lo, hi) uint32 limbs -> uint64 array."""
    return (np.asarray(hi, np.uint64) << np.uint64(32)) \
        | np.asarray(lo, np.uint64)


def split64_jnp(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """uint64 jnp array -> (lo, hi) uint32 limbs (x64 mode only; the entry
    point for callers that still hold native uint64 keys)."""
    lo = (x & jnp.uint64(_MASK32)).astype(jnp.uint32)
    hi = (x >> jnp.uint64(32)).astype(jnp.uint32)
    return lo, hi


def _add64(alo, ahi, blo, bhi):
    """(a + b) mod 2^64 on limbs; the carry is ``lo < alo`` (uint32 adds
    wrap, so overflow shows as the sum dipping below an addend)."""
    lo = alo + blo
    carry = (lo < alo).astype(jnp.uint32)
    return lo, ahi + bhi + carry


def _mul32x32(a, b):
    """Full 32x32 -> 64 product as (lo, hi) limbs via 16-bit halves.

    ``mid`` accumulates three <= 0xFFFF-ish terms of at most 17+16 bits —
    it cannot wrap uint32 — and carries into the high limb."""
    al = a & jnp.uint32(0xFFFF)
    ah = a >> jnp.uint32(16)
    bl = b & jnp.uint32(0xFFFF)
    bh = b >> jnp.uint32(16)
    ll = al * bl
    lh = al * bh
    hl = ah * bl
    hh = ah * bh
    mid = (ll >> jnp.uint32(16)) + (lh & jnp.uint32(0xFFFF)) \
        + (hl & jnp.uint32(0xFFFF))
    lo = (ll & jnp.uint32(0xFFFF)) | (mid << jnp.uint32(16))
    hi = hh + (lh >> jnp.uint32(16)) + (hl >> jnp.uint32(16)) \
        + (mid >> jnp.uint32(16))
    return lo, hi


def _mul64(alo, ahi, blo, bhi):
    """(a * b) mod 2^64 on limbs: the full low product plus the two cross
    terms that land in the high limb (the hi*hi term is all mod-2^64
    overflow and drops)."""
    lo, hi = _mul32x32(alo, blo)
    hi = hi + alo * bhi + ahi * blo      # wrapping uint32: exactly mod 2^32
    return lo, hi


def _xshr(lo, hi, s: int):
    """Logical 64-bit right shift by static ``0 < s < 32`` on limbs."""
    lo2 = (lo >> jnp.uint32(s)) | (hi << jnp.uint32(32 - s))
    hi2 = hi >> jnp.uint32(s)
    return lo2, hi2


def _const_limbs(v: int):
    return jnp.uint32(v & _MASK32), jnp.uint32((v >> 32) & _MASK32)


def splitmix64_limbs(xlo: jnp.ndarray, xhi: jnp.ndarray,
                     seed: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Elementwise splitmix64 on uint32 limbs, bit-identical to
    ``lsm.bloom.splitmix64(x, seed)``.  ``seed`` is static, so the
    ``seed * GAMMA`` offset folds to a host-side constant."""
    off = (int(seed) * _GAMMA) & 0xFFFFFFFFFFFFFFFF
    zlo, zhi = _add64(xlo, xhi, *_const_limbs(off))
    slo, shi = _xshr(zlo, zhi, 30)
    zlo, zhi = _mul64(zlo ^ slo, zhi ^ shi, *_const_limbs(_MUL1))
    slo, shi = _xshr(zlo, zhi, 27)
    zlo, zhi = _mul64(zlo ^ slo, zhi ^ shi, *_const_limbs(_MUL2))
    slo, shi = _xshr(zlo, zhi, 31)
    return zlo ^ slo, zhi ^ shi


def mod_limbs(lo: jnp.ndarray, hi: jnp.ndarray, m: int) -> jnp.ndarray:
    """``(hi * 2^32 + lo) % m`` as uint32, for static ``0 < m < 2^31``.

    The high limb reduces natively; its residue is then shifted left
    through lo's 32 bits with a conditional subtract per step — the
    invariant ``r < m < 2^31`` keeps ``2r + bit`` inside uint32."""
    m = int(m)
    if not 0 < m < 2 ** 31:
        raise ValueError(f"mod_limbs needs 0 < m < 2^31, got {m}")
    mm = jnp.uint32(m)
    r = hi % mm
    for i in range(31, -1, -1):
        bit = (lo >> jnp.uint32(i)) & jnp.uint32(1)
        r = r * jnp.uint32(2) + bit
        r = jnp.where(r >= mm, r - mm, r)
    return r
