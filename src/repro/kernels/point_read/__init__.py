"""Fused batched point read over one level's SoA arenas."""

from .ops import point_read_level_arrays  # noqa: F401
from .ref import point_read_level_ref  # noqa: F401
