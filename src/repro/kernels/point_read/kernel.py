"""Pallas kernel: fused per-level point read, one VMEM pass per key tile.

Grid is over 128-key tiles; each grid step holds its key tile plus the
level's arenas (key/value SoA) and packed Bloom words resident and runs
the *entire* level lookup for those keys — k splitmix64 hash rounds
(shared across runs, exactly like ``BloomPack.probe``), per-run bit
tests, fence-pointer window check, and a masked branchless binary
search per run — newest -> oldest with the engine's sequential-
equivalent per-key counters.

The run layout (``starts``/``n_bits``/``ks``/fence keys) is static —
baked into the kernel as Python constants, so run loops unroll and
every bound/modulus is an immediate.  Levels are small (R <= ~10) and
re-trace per layout, which interpret mode absorbs; a production TPU
build would tile the arena block-by-block instead of assuming it fits
VMEM, and would emulate uint64 as 2x32-bit limbs (x64 interpret mode
runs the engine's exact splitmix64 directly — see docs/kernels.md).

Bit-equivalence with ``ref.point_read_level_ref`` (same op sequence on
the same masks) is tested per run-shape edge case.
"""

from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .._compat import compiler_params, interpret_default
from .ref import _GAMMA

KEY_TILE = 128


def _point_read_tile(keys_ref, akeys_ref, avals_ref, words_ref,
                     hit_ref, enc_ref, probes_ref, reads_ref, fps_ref, *,
                     starts: Tuple[int, ...], n_bits: Tuple[int, ...],
                     ks: Tuple[int, ...], fence_lo: Tuple[int, ...],
                     fence_hi: Tuple[int, ...]):
    qk = keys_ref[...]            # (1, T) uint64
    ak = akeys_ref[...]           # (1, E) uint64
    av = avals_ref[...]           # (1, E) int64
    words = words_ref[...]        # (R, Wmax) uint64
    T = qk.shape[1]
    R = len(starts) - 1

    # Shared hash rounds (seeds 1..kmax), computed once per tile.
    kmax = max(ks) if R else 0
    hs = []
    for j in range(kmax):
        z = qk + jnp.uint64(j + 1) * jnp.uint64(_GAMMA)
        z = (z ^ (z >> jnp.uint64(30))) * jnp.uint64(0xBF58476D1CE4E5B9)
        z = (z ^ (z >> jnp.uint64(27))) * jnp.uint64(0x94D049BB133111EB)
        hs.append(z ^ (z >> jnp.uint64(31)))

    hit = jnp.zeros((1, T), bool)
    enc = jnp.zeros((1, T), jnp.int64)
    live = jnp.ones((1, T), bool)
    probes = jnp.zeros((1, T), jnp.int64)
    reads = jnp.zeros((1, T), jnp.int64)
    fps = jnp.zeros((1, T), jnp.int64)

    for r in range(R):            # newest -> oldest, unrolled
        probes = probes + live
        bloom_ok = jnp.ones((1, T), bool)
        for j in range(ks[r]):
            hm = hs[j] % jnp.uint64(n_bits[r])
            w = words[r, (hm >> jnp.uint64(6)).astype(jnp.int64)]
            bloom_ok &= ((w >> (hm & jnp.uint64(63)))
                         & jnp.uint64(1)).astype(bool)
        pos = live & bloom_ok
        reads = reads + pos
        s, e = starts[r], starts[r + 1]
        if e > s:
            # Fence-pointer window: keys outside the run's [min, max]
            # cannot be found; gates the search without changing counts.
            in_fence = (pos & (qk >= jnp.uint64(fence_lo[r]))
                        & (qk <= jnp.uint64(fence_hi[r])))
            n_steps = max(1, int(math.ceil(math.log2(max(e - s, 1)))) + 1)

            def bstep(_, st):
                lo, hi = st
                active = lo < hi
                mid = (lo + hi) >> 1
                am = ak[0, jnp.clip(mid, s, e - 1)]
                less = am < qk
                lo = jnp.where(active & less, mid + 1, lo)
                hi = jnp.where(active & ~less, mid, hi)
                return lo, hi

            lo0 = jnp.full((1, T), s, jnp.int64)
            hi0 = jnp.full((1, T), e, jnp.int64)
            lo, _ = jax.lax.fori_loop(0, n_steps, bstep, (lo0, hi0))
            safe = jnp.clip(lo, s, e - 1)
            found = in_fence & (lo < e) & (ak[0, safe] == qk)
            venc = av[0, safe]
            hit = hit | found
            enc = jnp.where(found, venc, enc)
            live = live & ~found
        else:
            found = jnp.zeros((1, T), bool)
        fps = fps + (pos & ~found)

    hit_ref[...] = hit
    enc_ref[...] = enc
    probes_ref[...] = probes
    reads_ref[...] = reads
    fps_ref[...] = fps


def point_read_level_kernel(sub_keys, arena_keys, arena_vals, words,
                            starts: Tuple[int, ...],
                            n_bits: Tuple[int, ...], ks: Tuple[int, ...],
                            fence_lo: Tuple[int, ...],
                            fence_hi: Tuple[int, ...],
                            interpret: bool | None = None):
    """Batched level read; returns (hit, enc, probes, reads, fps), (B,) each.

    ``sub_keys`` (B,) uint64; ``arena_keys``/``arena_vals`` (E,) with
    E >= 1; ``words`` (R, Wmax).  Run layout arguments are static host
    tuples.  Caller manages the x64 scope (see ops.py).
    """
    if interpret is None:
        interpret = interpret_default()
    B = sub_keys.shape[0]
    E = arena_keys.shape[0]
    R, Wmax = words.shape
    Bp = -(-B // KEY_TILE) * KEY_TILE
    keys_p = jnp.pad(sub_keys, (0, Bp - B))[None, :]

    kern = functools.partial(_point_read_tile, starts=starts, n_bits=n_bits,
                             ks=ks, fence_lo=fence_lo, fence_hi=fence_hi)
    full = lambda i: (0, 0)  # noqa: E731  (arena/words: whole-array blocks)
    tile = lambda i: (0, i)  # noqa: E731
    out = pl.pallas_call(
        kern,
        grid=(Bp // KEY_TILE,),
        in_specs=[
            pl.BlockSpec((1, KEY_TILE), tile),
            pl.BlockSpec((1, E), full),
            pl.BlockSpec((1, E), full),
            pl.BlockSpec((R, Wmax), full),
        ],
        out_specs=[pl.BlockSpec((1, KEY_TILE), tile)] * 5,
        out_shape=[
            jax.ShapeDtypeStruct((1, Bp), bool),
            jax.ShapeDtypeStruct((1, Bp), jnp.int64),
            jax.ShapeDtypeStruct((1, Bp), jnp.int64),
            jax.ShapeDtypeStruct((1, Bp), jnp.int64),
            jax.ShapeDtypeStruct((1, Bp), jnp.int64),
        ],
        compiler_params=compiler_params(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(keys_p, arena_keys[None, :], arena_vals[None, :], words)
    return tuple(o[0, :B] for o in out)
