"""Dispatch for the fused per-level point read (jnp ref vs Pallas).

The engine-facing entry point takes the level's host-side numpy arrays
(the ``LevelStore`` arenas + ``BloomPack`` matrices), runs the selected
implementation inside a 64-bit jax scope (the Bloom hash is splitmix64
over uint64 keys), and hands back numpy results plus the three summed
I/O counters in the exact shape ``lsm.read_path`` expects.

Both implementations return bit-identical results and per-key counters
(tested in tests/test_kernels.py); the engine-level golden tests assert
that switching modes leaves query results and ``IOStats`` unchanged.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .ref import as_static, point_read_level_ref


def point_read_level_arrays(sub_keys: np.ndarray, arena_keys: np.ndarray,
                            arena_vals: np.ndarray, starts: np.ndarray,
                            words: np.ndarray, n_bits: np.ndarray,
                            ks: np.ndarray, min_keys: np.ndarray,
                            max_keys: np.ndarray, impl: str = "jnp"
                            ) -> Tuple[np.ndarray, np.ndarray, int, int, int]:
    """(hit, enc, probes, reads, fps) for one level — array-level entry."""
    B = len(sub_keys)
    R = len(starts) - 1
    if B == 0 or R == 0:
        return np.zeros(B, bool), np.zeros(B, np.int64), 0, 0, 0
    st = as_static(starts)
    nb = as_static(n_bits)
    kt = as_static(ks)
    if len(arena_keys) == 0:
        # All runs empty: every key stays live through every run, all
        # Bloom words are zero, so only probes accrue (R per key).
        return (np.zeros(B, bool), np.zeros(B, np.int64), R * B, 0, 0)
    with jax.experimental.enable_x64():
        keys_j = jnp.asarray(sub_keys, jnp.uint64)
        ak = jnp.asarray(arena_keys, jnp.uint64)
        av = jnp.asarray(arena_vals, jnp.int64)
        wj = jnp.asarray(words, jnp.uint64)
        if impl == "jnp":
            hit, enc, probes, reads, fps = point_read_level_ref(
                keys_j, ak, av, st, wj, nb, kt)
        elif impl == "jnp_limb":
            # the TPU-portable hash tier: splitmix64 on uint32 limbs
            hit, enc, probes, reads, fps = point_read_level_ref(
                keys_j, ak, av, st, wj, nb, kt, use_limb_hash=True)
        elif impl == "pallas":
            from .kernel import point_read_level_kernel
            # Fence keys; empty runs never search, any placeholder works.
            flo = tuple(int(v) for v in np.asarray(min_keys, np.uint64))
            fhi = tuple(int(v) for v in np.asarray(max_keys, np.uint64))
            hit, enc, probes, reads, fps = point_read_level_kernel(
                keys_j, ak, av, wj, st, nb, kt, flo, fhi)
        else:
            raise ValueError(f"unknown point_read impl {impl!r}")
        return (np.asarray(hit), np.asarray(enc),
                int(jnp.sum(probes)), int(jnp.sum(reads)),
                int(jnp.sum(fps)))
