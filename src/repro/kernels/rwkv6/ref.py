"""Pure-jnp oracle for the RWKV6 WKV kernel: the exact per-step recurrence."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv_ref(r, k, v, logw, u):
    """r/k/v/logw: (BH, S, n); u: (BH, n). Sequential-scan ground truth.

    Returns (y (BH,S,n) f32, final state (BH,n,n) f32)."""
    r, k, v, logw = (t.astype(jnp.float32) for t in (r, k, v, logw))
    u = u.astype(jnp.float32)
    BH, S, n = r.shape

    def step(state, xs):
        rt, kt, vt, lwt = xs                       # (BH, n) each
        a = kt[:, :, None] * vt[:, None, :]        # (BH, n, n)
        y = jnp.einsum("bn,bnm->bm", rt, state + u[:, :, None] * a)
        state = state * jnp.exp(lwt)[:, :, None] + a
        return state, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, logw))
    state0 = jnp.zeros((BH, n, n), jnp.float32)
    state, ys = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(ys, 0, 1), state
