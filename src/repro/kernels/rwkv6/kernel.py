"""RWKV-6 WKV recurrence as a chunked Pallas TPU kernel.

Grid = (batch*heads, num_chunks); chunks are the innermost ("arbitrary")
axis so the (n x n) recurrent state lives in VMEM scratch across chunk
steps.  Within a chunk the pairwise log-space decay form is used (exponents
always <= 0 -> numerically stable), with the three large contractions
(intra-chunk attention x v, r x state, and k_tail^T x v state update)
expressed as dots for the MXU.  Matches models/rwkv.wkv_chunked (= ref.py)
exactly.

Block shapes: (chunk, n) tiles for r/k/v/logw and the output; (n, n) f32
state scratch.  n (head dim) is 64 across the assigned archs; chunk=32
keeps the (chunk, chunk, n) pairwise tensor at 256 KiB of VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import compiler_params


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, s_out_ref,
                state_ref, *, chunk: int, num_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[...].astype(jnp.float32)        # (c, n)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    lw = lw_ref[...].astype(jnp.float32)      # log decay, < 0
    u = u_ref[...].astype(jnp.float32)        # (1, n)

    Lc = jnp.cumsum(lw, axis=0)               # (c, n) inclusive
    Lc_prev = Lc - lw                         # exclusive
    total = Lc[-1:, :]                        # (1, n)

    # intra-chunk: att[t,j] = sum_i r[t,i] k[j,i] e^{Lc_prev[t,i]-Lc[j,i]}
    D = Lc_prev[:, None, :] - Lc[None, :, :]  # (c, c, n), <= 0 on tril
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    E = jnp.exp(jnp.where(tri[:, :, None], D, -jnp.inf))
    att = jnp.sum(r[:, None, :] * k[None, :, :] * E, axis=2)   # (c, c)
    y = jax.lax.dot(att, v)                                     # (c, n)

    # current-token bonus: (sum_i r[t,i] u[i] k[t,i]) v[t]
    diag = jnp.sum(r * u * k, axis=1, keepdims=True)            # (c, 1)
    y = y + diag * v

    # inter-chunk: y += (r * e^{Lc_prev}) @ S
    y = y + jax.lax.dot(r * jnp.exp(Lc_prev), state_ref[...])

    # state update: S = S * e^{total}^T + sum_j e^{total-Lc[j]} k_j v_j^T
    k_tail = k * jnp.exp(total - Lc)                            # (c, n)
    state_ref[...] = state_ref[...] * jnp.exp(total).T + \
        jax.lax.dot(k_tail.T, v)

    o_ref[...] = y.astype(o_ref.dtype)

    @pl.when(ci == num_chunks - 1)
    def _emit_state():
        s_out_ref[...] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_kernel(r: jax.Array, k: jax.Array, v: jax.Array, logw: jax.Array,
                 u: jax.Array, chunk: int = 32,
                 interpret: bool = False):
    """All of r/k/v/logw: (BH, S, n); u: (BH, n).

    Returns (y (BH, S, n) float32, final_state (BH, n, n) float32)."""
    BH, S, n = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    kernel = functools.partial(_wkv_kernel, chunk=chunk, num_chunks=nc)
    return pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((None, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, n), lambda b, c: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, n, n), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, n), jnp.float32),
            jax.ShapeDtypeStruct((BH, n, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, logw, u)
