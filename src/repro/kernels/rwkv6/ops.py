"""jit'd wrapper: model-facing chunked WKV (Pallas on TPU, interpret on CPU)."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import rwkv6_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("chunk",))
def rwkv6_chunked(r, k, v, logw, u, chunk: int = 32):
    """r/k/v/logw: (B, S, H, n); u: (H, n) -> (y (B,S,H,n) f32,
    final state (B,H,n,n) f32). Drop-in for models.rwkv.wkv_chunked."""
    B, S, H, n = r.shape
    to_flat = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, S, n)
    u_flat = jnp.tile(u, (B, 1))
    y, state = rwkv6_kernel(to_flat(r), to_flat(k), to_flat(v),
                            to_flat(logw), u_flat, chunk=chunk,
                            interpret=not _on_tpu())
    y = y.reshape(B, H, S, n).transpose(0, 2, 1, 3)
    return y, state.reshape(B, H, n, n)
