"""Dispatch for the warm-started dual solve: reference / fused / Pallas.

Three implementations of one contract (see ``ref.py``):

* ``impl="ref"``   — the pre-fusion algorithm (two g-evaluations per
  golden iteration; 16 per call at production settings).  Accuracy
  oracle and perf baseline.
* ``impl="fused"`` — the production path: a *cached-point* golden
  section that seeds both interior points once and then evaluates only
  the single new point per iteration (12 g-evaluations per call).  The
  bracket shrinks by the same 0.618 factor per iteration, so the value
  error keeps the same second-order-in-bracket-width bound as the
  reference (golden identity: the retained interior point of the old
  bracket *is* an interior point of the new one up to f32 rounding).
  Pure jnp, so it inlines into the tuner's vmap-over-starts scan and
  XLA fuses the whole lane batch.
* ``impl="pallas"``— the same cached-point algorithm as a lane-tiled
  Pallas kernel (``kernel.py``), for batched entry points; bit-equal
  to vmapped ``fused`` (tested).

``impl`` is an explicit (trace-time) argument rather than a module
global: the tuner's jit caches would not observe a global flip.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro import obs

from .ref import _GR, dual_solve_warm_ref, g_of_llam


def dual_solve_warm_fused(c: jnp.ndarray, w: jnp.ndarray, rho, llam,
                          half_width: float = 0.8, n_local: int = 3,
                          n_golden: int = 6):
    """Cached-point warm dual refinement; returns ``(value, new log lam*)``.

    Identical bracket/scan structure to :func:`ref.dual_solve_warm_ref`,
    but the golden loop carries ``(a, b, g(a), g(b))`` so each iteration
    evaluates g once instead of twice: n_local + 2 + n_golden + 1 evals.
    """
    c = jnp.asarray(c)
    w = jnp.asarray(w)
    logw = jnp.log(w)
    llam = jax.lax.stop_gradient(llam)

    offs = jnp.linspace(-half_width, half_width, n_local)
    lls = llam + offs
    vals = jax.vmap(lambda ll: g_of_llam(c, logw, rho, ll))(lls)
    i = jnp.argmin(vals)
    llo = lls[jnp.maximum(i - 1, 0)]
    lhi = lls[jnp.minimum(i + 1, n_local - 1)]

    a0 = lhi - _GR * (lhi - llo)
    b0 = llo + _GR * (lhi - llo)
    fa0 = g_of_llam(c, logw, rho, a0)
    fb0 = g_of_llam(c, logw, rho, b0)

    def body(_, st):
        llo, lhi, a, b, fa, fb = st
        smaller = fa < fb
        nlo = jnp.where(smaller, llo, a)
        nhi = jnp.where(smaller, b, lhi)
        na = jnp.where(smaller, nhi - _GR * (nhi - nlo), b)
        nb = jnp.where(smaller, a, nlo + _GR * (nhi - nlo))
        fnew = g_of_llam(c, logw, rho, jnp.where(smaller, na, nb))
        nfa = jnp.where(smaller, fnew, fb)
        nfb = jnp.where(smaller, fa, fnew)
        return (nlo, nhi, na, nb, nfa, nfb)

    llo, lhi, _, _, _, _ = jax.lax.fori_loop(
        0, n_golden, body, (llo, lhi, a0, b0, fa0, fb0))
    lspan = jnp.log(jnp.maximum(jnp.max(c) - jnp.min(c), 1e-9))
    llam_new = jax.lax.stop_gradient(
        jnp.clip(0.5 * (llo + lhi), lspan - 16.0, lspan + 16.0))
    val = jnp.where(rho <= 0.0, jnp.sum(w * c),
                    g_of_llam(c, logw, rho, llam_new))
    return val, llam_new


def dual_solve_warm(c, w, rho, llam, half_width: float = 0.8,
                    n_local: int = 3, n_golden: int = 6,
                    impl: str = "fused"):
    """Single-lane dispatch point (the robust tuner calls this)."""
    # Trace-time counter: this body runs when jax (re)traces a caller, so
    # the count is compilations through this tier, not solver invocations.
    obs.count("kernel.dispatch.dual_solve." + impl)
    if impl == "fused":
        return dual_solve_warm_fused(c, w, rho, llam, half_width, n_local,
                                     n_golden)
    if impl == "ref":
        return dual_solve_warm_ref(c, w, rho, llam, half_width, n_local,
                                   n_golden)
    raise ValueError(f"unknown dual_solve impl {impl!r} "
                     "(single-lane: 'fused' or 'ref'; 'pallas' is batched — "
                     "use dual_solve_warm_batch)")


@partial(jax.jit, static_argnames=("half_width", "n_local", "n_golden",
                                   "impl"))
def dual_solve_warm_batch(C, W, rho, llam, half_width: float = 0.8,
                          n_local: int = 3, n_golden: int = 6,
                          impl: str = "fused"):
    """Lane-batched warm solve: C (L, n), W (L, n) or (n,), rho/llam (L,).

    Returns ``(values (L,), new log lam* (L,))``.  ``impl="pallas"``
    routes to the lane-tiled kernel; "fused"/"ref" vmap the single-lane
    implementations.
    """
    # Trace-time counter (see dual_solve_warm): counts jit traces per tier.
    obs.count("kernel.dispatch.dual_solve_batch." + impl)
    C = jnp.asarray(C, jnp.float32)
    rho = jnp.asarray(rho, jnp.float32)
    llam = jnp.asarray(llam, jnp.float32)
    W = jnp.broadcast_to(jnp.asarray(W, jnp.float32), C.shape)
    if impl == "pallas":
        from .kernel import dual_solve_warm_kernel
        return dual_solve_warm_kernel(C, W, rho, llam,
                                      half_width=half_width,
                                      n_local=n_local, n_golden=n_golden)
    fn = dual_solve_warm_fused if impl == "fused" else dual_solve_warm_ref
    return jax.vmap(lambda c, w, r, ll: fn(c, w, r, ll, half_width, n_local,
                                           n_golden))(C, W, rho, llam)
