"""Pallas lane-tiled kernel for the cached-point warm dual solve.

One grid step owns a tile of ``LANE_TILE`` independent lanes (tuning
starts x problems), laid out lanes-last so the cost matrix tile is
``(n, 128)`` — the n-axis reductions (logsumexp over the 4 workload
components) are sublane reductions and every golden iteration is a
fully vectorized VPU pass over the tile.  The entire solve — local
scan, bracket pick, ``n_golden`` cached-point golden iterations, final
re-evaluation — runs on-chip per tile; nothing round-trips to HBM
between g-evaluations.

The op sequence mirrors ``ops.dual_solve_warm_fused`` primitive for
primitive (same hand-written logsumexp from ``ref.lse``, same
where-selects), so interpret-mode outputs are bit-identical to the
vmapped fused path — tested in ``tests/test_kernels.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .._compat import compiler_params, interpret_default
from .ref import _GR

LANE_TILE = 128


def _pick(arr: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """arr (k, T), idx (1, T) in [0, k) -> per-column gather via selects
    (k is tiny and static; avoids an in-kernel gather)."""
    out = arr[0:1]
    for j in range(1, arr.shape[0]):
        out = jnp.where(idx == j, arr[j:j + 1], out)
    return out


def _dual_solve_tile(c_ref, w_ref, rho_ref, llam_ref, val_ref, lnew_ref, *,
                     half_width: float, n_local: int, n_golden: int):
    C = c_ref[...]            # (n, T)
    W = w_ref[...]            # (n, T)
    rho = rho_ref[...]        # (1, T)
    llam = llam_ref[...]      # (1, T)
    logW = jnp.log(W)

    def g(ll):                # (1, T) -> (1, T)
        lam = jnp.maximum(jnp.exp(ll), 1e-12)
        x = logW + C / lam
        m = jnp.max(x, axis=0, keepdims=True)
        s = m + jnp.log(jnp.sum(jnp.exp(x - m), axis=0, keepdims=True))
        return rho * lam + lam * s

    offs = jnp.linspace(-half_width, half_width, n_local)
    lls = jnp.concatenate([llam + offs[j] for j in range(n_local)], axis=0)
    vals = jnp.concatenate([g(lls[j:j + 1]) for j in range(n_local)], axis=0)
    i = jnp.argmin(vals, axis=0)[None, :]
    llo = _pick(lls, jnp.maximum(i - 1, 0))
    lhi = _pick(lls, jnp.minimum(i + 1, n_local - 1))

    a0 = lhi - _GR * (lhi - llo)
    b0 = llo + _GR * (lhi - llo)
    fa0 = g(a0)
    fb0 = g(b0)

    def body(_, st):
        llo, lhi, a, b, fa, fb = st
        smaller = fa < fb
        nlo = jnp.where(smaller, llo, a)
        nhi = jnp.where(smaller, b, lhi)
        na = jnp.where(smaller, nhi - _GR * (nhi - nlo), b)
        nb = jnp.where(smaller, a, nlo + _GR * (nhi - nlo))
        fnew = g(jnp.where(smaller, na, nb))
        nfa = jnp.where(smaller, fnew, fb)
        nfb = jnp.where(smaller, fa, fnew)
        return (nlo, nhi, na, nb, nfa, nfb)

    llo, lhi, _, _, _, _ = jax.lax.fori_loop(
        0, n_golden, body, (llo, lhi, a0, b0, fa0, fb0))
    span = jnp.max(C, axis=0, keepdims=True) - jnp.min(C, axis=0,
                                                       keepdims=True)
    lspan = jnp.log(jnp.maximum(span, 1e-9))
    lnew = jnp.clip(0.5 * (llo + lhi), lspan - 16.0, lspan + 16.0)
    nominal = jnp.sum(W * C, axis=0, keepdims=True)
    val_ref[...] = jnp.where(rho <= 0.0, nominal, g(lnew))
    lnew_ref[...] = lnew


@functools.partial(jax.jit, static_argnames=("half_width", "n_local",
                                             "n_golden", "interpret"))
def dual_solve_warm_kernel(C, W, rho, llam, half_width: float = 0.8,
                           n_local: int = 3, n_golden: int = 6,
                           interpret: bool | None = None):
    """Batched warm solve: C/W (L, n), rho/llam (L,) -> ((L,), (L,))."""
    if interpret is None:
        interpret = interpret_default()
    L, n = C.shape
    Lp = -(-L // LANE_TILE) * LANE_TILE
    pad = Lp - L
    Ct = jnp.pad(jnp.asarray(C, jnp.float32), ((0, pad), (0, 0))).T
    Wt = jnp.pad(jnp.asarray(W, jnp.float32), ((0, pad), (0, 0)),
                 constant_values=1.0).T
    rho_p = jnp.pad(jnp.asarray(rho, jnp.float32), (0, pad),
                    constant_values=1.0)[None, :]
    llam_p = jnp.pad(jnp.asarray(llam, jnp.float32), (0, pad))[None, :]

    kern = functools.partial(_dual_solve_tile, half_width=half_width,
                             n_local=n_local, n_golden=n_golden)
    val, lnew = pl.pallas_call(
        kern,
        grid=(Lp // LANE_TILE,),
        in_specs=[
            pl.BlockSpec((n, LANE_TILE), lambda i: (0, i)),
            pl.BlockSpec((n, LANE_TILE), lambda i: (0, i)),
            pl.BlockSpec((1, LANE_TILE), lambda i: (0, i)),
            pl.BlockSpec((1, LANE_TILE), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, LANE_TILE), lambda i: (0, i)),
            pl.BlockSpec((1, LANE_TILE), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, Lp), jnp.float32),
            jax.ShapeDtypeStruct((1, Lp), jnp.float32),
        ],
        compiler_params=compiler_params(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(Ct, Wt, rho_p, llam_p)
    return val[0, :L], lnew[0, :L]
