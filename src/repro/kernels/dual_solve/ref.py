"""Reference jnp implementation of the warm-started dual refinement.

This is the pre-kernel-tier algorithm exactly as the robust tuner ran it
(``core/robust.dual_solve_warm`` before the fused tier): a 3-point local
scan around the carried ``log lam*`` plus a classic golden-section loop
that evaluates *both* interior points at every iteration.  Per call that
is ``n_local + 2 * n_golden + 1`` evaluations of

    g(lam) = rho lam + lam * logsumexp(log w + c / lam)

(16 with the production ``n_local=3, n_golden=6``).  The fused tier
(``ops.dual_solve_warm_fused`` / ``kernel.dual_solve_warm_kernel``)
reuses the bracket endpoints' values across golden iterations and needs
only ``n_local + 2 + n_golden + 1`` (12): same convexity contract, same
second-order-in-bracket-width accuracy (see ``core/robust`` docstring),
strictly fewer g-evaluations.  This module is the accuracy oracle and
the perf baseline the fused paths are gated against.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_GR = 0.6180339887498949  # golden ratio conjugate


def lse(x: jnp.ndarray) -> jnp.ndarray:
    """Stable logsumexp over the last axis, written out primitive-by-
    primitive so the fused jnp path and the Pallas kernel can reproduce
    the exact same op sequence (bit-equivalence is tested)."""
    m = jnp.max(x, axis=-1)
    return m + jnp.log(jnp.sum(jnp.exp(x - m[..., None]), axis=-1))


def g_of_llam(c: jnp.ndarray, logw: jnp.ndarray, rho: jnp.ndarray,
              llam: jnp.ndarray) -> jnp.ndarray:
    """g(exp(llam)) for one lane: c, logw (n,); rho, llam scalars."""
    lam = jnp.maximum(jnp.exp(llam), 1e-12)
    return rho * lam + lam * lse(logw + c / lam)


def dual_solve_warm_ref(c: jnp.ndarray, w: jnp.ndarray, rho, llam,
                        half_width: float = 0.8, n_local: int = 3,
                        n_golden: int = 6):
    """One warm-started dual refinement; returns ``(value, new log lam*)``.

    Single-lane reference: scans ``n_local`` points on ``llam +-
    half_width`` (log-lam), brackets the convex minimum, golden-refines
    with two g-evaluations per iteration, and re-evaluates g at the
    clipped bracket midpoint.
    """
    c = jnp.asarray(c)
    logw = jnp.log(jnp.asarray(w))
    llam = jax.lax.stop_gradient(llam)

    offs = jnp.linspace(-half_width, half_width, n_local)
    lls = llam + offs
    vals = jax.vmap(lambda ll: g_of_llam(c, logw, rho, ll))(lls)
    i = jnp.argmin(vals)
    llo = lls[jnp.maximum(i - 1, 0)]
    lhi = lls[jnp.minimum(i + 1, n_local - 1)]

    def body(_, bounds):
        llo, lhi = bounds
        a = lhi - _GR * (lhi - llo)
        b = llo + _GR * (lhi - llo)
        fa = g_of_llam(c, logw, rho, a)
        fb = g_of_llam(c, logw, rho, b)
        smaller = fa < fb
        return jnp.where(smaller, llo, a), jnp.where(smaller, b, lhi)

    llo, lhi = jax.lax.fori_loop(0, n_golden, body, (llo, lhi))
    lspan = jnp.log(jnp.maximum(jnp.max(c) - jnp.min(c), 1e-9))
    llam_new = jax.lax.stop_gradient(
        jnp.clip(0.5 * (llo + lhi), lspan - 16.0, lspan + 16.0))
    val = jnp.where(rho <= 0.0, jnp.dot(jnp.asarray(w), c),
                    g_of_llam(c, logw, rho, llam_new))
    return val, llam_new
