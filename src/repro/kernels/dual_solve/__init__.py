"""Fused warm-started KL dual solve (the robust tuner's inner loop)."""

from .ops import (dual_solve_warm, dual_solve_warm_batch,  # noqa: F401
                  dual_solve_warm_fused)
from .ref import dual_solve_warm_ref  # noqa: F401
