"""Pallas TPU kernels for the framework's compute hot spots.

flash_attention/  FlashAttention-2 (causal/SWA/GQA)
rwkv6/            chunked WKV recurrence (data-dependent decay)
bloom_probe/      blocked-bloom membership probe (MXU one-hot gather)

Each has kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd wrapper) and
ref.py (pure-jnp oracle).  Validated with interpret=True on CPU; TPU v5e is
the lowering target.
"""
