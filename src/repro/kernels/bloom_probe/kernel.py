"""Batch Bloom-filter probe as a Pallas TPU kernel (blocked bloom filter).

Hardware adaptation (see DESIGN.md): TPUs have no efficient scalar gather,
so the filter is laid out as a *blocked* bloom filter — each key hashes to
one block (a row of ``block_bits`` bits) and the row fetch is expressed as a
one-hot matmul on the MXU.  Bits are stored as an f32 0/1 bit-plane
(``(num_blocks, block_bits)``), trading 32x memory for gatherability —
filters are MiB-scale per run (Monkey allocation), so a VMEM-resident tile
of the plane covers typical per-run filters.

Probing: per key, k derived hashes select bits within its block; membership
is the min over the k fetched bits.  Hashing is a splitmix-style integer mix
(matching lsm/bloom.py's first 32 bits) on the VPU.

Grid: (num_key_tiles,) with the whole bit-plane resident; keys processed in
tiles of 128 (lane width).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from .._compat import compiler_params

KEY_TILE = 128


def _mix32(x: jnp.ndarray, seed: int) -> jnp.ndarray:
    """splitmix-like 32-bit mix, elementwise on uint32."""
    x = x + jnp.uint32(seed) * jnp.uint32(0x9E3779B9)
    x = (x ^ (x >> jnp.uint32(16))) * jnp.uint32(0x85EBCA6B)
    x = (x ^ (x >> jnp.uint32(13))) * jnp.uint32(0xC2B2AE35)
    return x ^ (x >> jnp.uint32(16))


def _probe_kernel(keys_ref, plane_ref, out_ref, *, num_blocks: int,
                  block_bits: int, num_hashes: int):
    keys = keys_ref[...]                                  # (tile,) uint32
    tile = keys.shape[0]
    plane = plane_ref[...]                                # (blocks, bits) f32

    block = (_mix32(keys, 1) % jnp.uint32(num_blocks)).astype(jnp.int32)
    onehot_b = (block[:, None] ==
                jax.lax.broadcasted_iota(jnp.int32, (tile, num_blocks), 1)
                ).astype(jnp.float32)
    rows = jax.lax.dot(onehot_b, plane)                   # (tile, bits)

    member = jnp.ones((tile,), jnp.float32)
    for j in range(num_hashes):
        bit = (_mix32(keys, j + 2) % jnp.uint32(block_bits)).astype(jnp.int32)
        onehot_bit = (bit[:, None] ==
                      jax.lax.broadcasted_iota(jnp.int32, (tile, block_bits),
                                               1)).astype(jnp.float32)
        val = jnp.sum(rows * onehot_bit, axis=1)          # (tile,)
        member = member * val
    out_ref[...] = member


@functools.partial(jax.jit, static_argnames=("num_hashes", "interpret"))
def bloom_probe_kernel(keys: jax.Array, plane: jax.Array,
                       num_hashes: int = 4,
                       interpret: bool = False) -> jax.Array:
    """keys: (N,) uint32 (N % 128 == 0); plane: (num_blocks, block_bits)
    f32 0/1 bit-plane. Returns (N,) f32 membership (1.0 = maybe present)."""
    N = keys.shape[0]
    assert N % KEY_TILE == 0, N
    num_blocks, block_bits = plane.shape
    kernel = functools.partial(_probe_kernel, num_blocks=num_blocks,
                               block_bits=block_bits, num_hashes=num_hashes)
    return pl.pallas_call(
        kernel,
        grid=(N // KEY_TILE,),
        in_specs=[
            pl.BlockSpec((KEY_TILE,), lambda i: (i,)),
            pl.BlockSpec((num_blocks, block_bits), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((KEY_TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((N,), jnp.float32),
        compiler_params=compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(keys, plane)
