"""jit'd wrapper for the blocked-bloom probe kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import KEY_TILE, bloom_probe_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("num_hashes",))
def bloom_probe(keys: jnp.ndarray, plane: jnp.ndarray,
                num_hashes: int = 4) -> jnp.ndarray:
    """keys: (N,) uint32 (auto-padded to the 128 tile); plane f32 0/1.
    Returns (N,) bool."""
    N = keys.shape[0]
    pad = (-N) % KEY_TILE
    kp = jnp.pad(keys, (0, pad))
    out = bloom_probe_kernel(kp, plane, num_hashes=num_hashes,
                             interpret=not _on_tpu())
    return out[:N] > 0.5
