"""Pure-jnp oracle for the blocked-bloom probe kernel (+ builder)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def mix32(x, seed: int):
    x = np.asarray(x, np.uint32)
    with np.errstate(over="ignore"):
        x = x + np.uint32(seed) * np.uint32(0x9E3779B9)
        x = (x ^ (x >> np.uint32(16))) * np.uint32(0x85EBCA6B)
        x = (x ^ (x >> np.uint32(13))) * np.uint32(0xC2B2AE35)
        return x ^ (x >> np.uint32(16))


def build_plane(keys: np.ndarray, num_blocks: int, block_bits: int,
                num_hashes: int) -> np.ndarray:
    """Insert keys into an f32 0/1 bit-plane blocked bloom filter."""
    plane = np.zeros((num_blocks, block_bits), np.float32)
    block = mix32(keys, 1) % np.uint32(num_blocks)
    for j in range(num_hashes):
        bit = mix32(keys, j + 2) % np.uint32(block_bits)
        plane[block.astype(np.int64), bit.astype(np.int64)] = 1.0
    return plane


def probe_ref(keys: np.ndarray, plane: np.ndarray,
              num_hashes: int) -> np.ndarray:
    num_blocks, block_bits = plane.shape
    block = mix32(keys, 1) % np.uint32(num_blocks)
    member = np.ones(len(keys), np.float32)
    for j in range(num_hashes):
        bit = mix32(keys, j + 2) % np.uint32(block_bits)
        member *= plane[block.astype(np.int64), bit.astype(np.int64)]
    return member
