"""Fused stable two-way / k-way merge for compaction."""

from .ops import merge_runs_arrays  # noqa: F401
from .ref import two_way_merge_ref  # noqa: F401
