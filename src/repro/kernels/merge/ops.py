"""Dispatch for the k-way compaction merge (jnp ref vs Pallas).

The engine-facing entry folds a newest-first run list pairwise: each
step is one fixed-shape two-way stable merge (reference scatter form or
the merge-path kernel) followed by a host-side adjacent-duplicate drop
(newest-wins dedup; jax shapes stay static, compaction is host-driven
anyway).  Newest-wins is associative, so the fold is bit-identical to
the legacy global argsort-merge — asserted by the store-level golden
tests.

Runs under ``jax.experimental.enable_x64`` (uint64 keys, int64 encoded
values — the engine's exact dtypes).
"""

from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .ref import two_way_merge_ref


def _dedup(keys: np.ndarray, vals: np.ndarray
           ) -> Tuple[np.ndarray, np.ndarray]:
    keep = np.ones(len(keys), bool)
    keep[1:] = keys[1:] != keys[:-1]          # first (newest) wins
    return keys[keep], vals[keep]


def merge_runs_arrays(keys_list: Sequence[np.ndarray],
                      vals_list: Sequence[np.ndarray], impl: str = "jnp"
                      ) -> Tuple[np.ndarray, np.ndarray]:
    """Newest-first k-way merge -> (sorted unique keys, newest vals)."""
    if impl == "pallas":
        from .kernel import two_way_merge_kernel
        two_way = two_way_merge_kernel
    elif impl == "jnp":
        two_way = two_way_merge_ref
    else:
        raise ValueError(f"unknown merge impl {impl!r}")

    acc_k = np.asarray(keys_list[0], np.uint64)
    acc_v = np.asarray(vals_list[0], np.int64)
    with jax.experimental.enable_x64():
        for k, v in zip(keys_list[1:], vals_list[1:]):
            if len(k) == 0:
                continue
            if len(acc_k) == 0:
                acc_k = np.asarray(k, np.uint64)
                acc_v = np.asarray(v, np.int64)
                continue
            mk, mv = two_way(jnp.asarray(acc_k, jnp.uint64),
                             jnp.asarray(acc_v, jnp.int64),
                             jnp.asarray(k, jnp.uint64),
                             jnp.asarray(v, jnp.int64))
            acc_k, acc_v = _dedup(np.asarray(mk), np.asarray(mv))
    return acc_k, acc_v
