"""Pallas merge-path kernel: stable two-way merge, gather-only.

Each grid step owns a 128-wide tile of *output* positions and finds,
for every position ``m``, the merge-path split ``i`` — how many of the
first ``m`` outputs come from run A — by binary search over the
diagonal (Green et al.'s GPU Merge Path, the standard work-partitioned
merge).  The split obeys the stability rule "A (newer) before equal B":
``i`` is the smallest split with ``B[m-i-1] < A[i]``.  The output
element is then a single gather from A or B.  No scatter anywhere —
each lane independently computes its own output — which is what makes
the merge expressible on a TPU's vector unit; both runs stay resident
per tile (a production build would walk run windows via the grid).

Output matches ``ref.two_way_merge_ref`` bit for bit (same interleave
permutation); the caller (ops.py) drops adjacent duplicate keys to
finish newest-wins dedup.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .._compat import compiler_params, interpret_default

OUT_TILE = 128


def _merge_tile(ak_ref, av_ref, bk_ref, bv_ref, k_ref, v_ref, *,
                n_a: int, n_b: int):
    t = pl.program_id(0)
    T = k_ref.shape[1]
    Ak = ak_ref[...]          # (1, nA)
    Av = av_ref[...]
    Bk = bk_ref[...]          # (1, nB)
    Bv = bv_ref[...]
    m = (t * T + jax.lax.broadcasted_iota(jnp.int64, (1, T), 1))

    lo = jnp.maximum(jnp.int64(0), m - n_b)
    hi = jnp.minimum(m, jnp.int64(n_a))
    n_steps = max(1, int(math.ceil(math.log2(n_a + n_b + 1))) + 1)

    def bstep(_, st):
        lo, hi = st
        active = lo < hi
        i = (lo + hi) >> 1
        # When active, 0 <= i < nA and 0 <= m-i-1 < nB hold by the
        # bracket invariants; clip only guards padded lanes.
        a_cand = Ak[0, jnp.clip(i, 0, n_a - 1)]
        b_cand = Bk[0, jnp.clip(m - i - 1, 0, n_b - 1)]
        take_more_a = ~(b_cand < a_cand)      # B[m-i-1] >= A[i]: i too small
        lo = jnp.where(active & take_more_a, i + 1, lo)
        hi = jnp.where(active & ~take_more_a, i, hi)
        return lo, hi

    i, _ = jax.lax.fori_loop(0, n_steps, bstep, (lo, hi))
    j = m - i
    a_key = Ak[0, jnp.clip(i, 0, n_a - 1)]
    b_key = Bk[0, jnp.clip(j, 0, n_b - 1)]
    take_a = (i < n_a) & ((j >= n_b) | (a_key <= b_key))
    k_ref[...] = jnp.where(take_a, a_key, b_key)
    v_ref[...] = jnp.where(take_a, Av[0, jnp.clip(i, 0, n_a - 1)],
                           Bv[0, jnp.clip(j, 0, n_b - 1)])


def two_way_merge_kernel(a_keys, a_vals, b_keys, b_vals,
                         interpret: bool | None = None):
    """Stable interleave of (A newer, B older); (keys, vals) of |A|+|B|.

    Caller manages the x64 scope (uint64 keys / int64 values).
    """
    if interpret is None:
        interpret = interpret_default()
    n_a, n_b = a_keys.shape[0], b_keys.shape[0]
    N = n_a + n_b
    Np = -(-N // OUT_TILE) * OUT_TILE

    kern = functools.partial(_merge_tile, n_a=n_a, n_b=n_b)
    full = lambda i: (0, 0)  # noqa: E731
    tile = lambda i: (0, i)  # noqa: E731
    keys, vals = pl.pallas_call(
        kern,
        grid=(Np // OUT_TILE,),
        in_specs=[
            pl.BlockSpec((1, n_a), full),
            pl.BlockSpec((1, n_a), full),
            pl.BlockSpec((1, n_b), full),
            pl.BlockSpec((1, n_b), full),
        ],
        out_specs=[pl.BlockSpec((1, OUT_TILE), tile)] * 2,
        out_shape=[
            jax.ShapeDtypeStruct((1, Np), a_keys.dtype),
            jax.ShapeDtypeStruct((1, Np), a_vals.dtype),
        ],
        compiler_params=compiler_params(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(a_keys[None, :], a_vals[None, :], b_keys[None, :], b_vals[None, :])
    return keys[0, :N], vals[0, :N]
