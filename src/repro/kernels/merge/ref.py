"""jnp reference for the stable two-way merge (rank-based, one pass).

Merges two sorted unique key runs A (newer) and B (older) into the
stable interleave of length ``|A| + |B|``: every element's output rank
is its own index plus a ``searchsorted`` against the other run, with
the tie rule "A before equal B" (newest first).  Output is *with*
duplicates — equal keys land adjacent, A's version first — so the
caller drops ``keys[i] == keys[i-1]`` positions to finish newest-wins
dedup (the same adjacent-drop the legacy argsort-merge used), keeping
shapes static for jax.

This is exactly the permutation a stable sort of ``concat([A, B])``
produces, so folding pairs newest-first reproduces the k-way
argsort-merge bit for bit (associativity of newest-wins; tested).

The Pallas kernel (kernel.py) computes the same interleave gather-only
(merge-path binary search per output position) — no scatter, which is
what makes the merge TPU-shaped.
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


def two_way_merge_ref(a_keys: jnp.ndarray, a_vals: jnp.ndarray,
                      b_keys: jnp.ndarray, b_vals: jnp.ndarray
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Stable interleave of (A newer, B older); returns (keys, vals)."""
    nA, nB = a_keys.shape[0], b_keys.shape[0]
    # Rank of A[i]: i + (# of B strictly before it); ties -> A first.
    pos_a = jnp.arange(nA) + jnp.searchsorted(b_keys, a_keys, side="left")
    # Rank of B[j]: j + (# of A at or before it); ties -> B after A.
    pos_b = jnp.arange(nB) + jnp.searchsorted(a_keys, b_keys, side="right")
    keys = jnp.zeros(nA + nB, a_keys.dtype)
    vals = jnp.zeros(nA + nB, a_vals.dtype)
    keys = keys.at[pos_a].set(a_keys).at[pos_b].set(b_keys)
    vals = vals.at[pos_a].set(a_vals).at[pos_b].set(b_vals)
    return keys, vals
