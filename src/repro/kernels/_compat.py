"""Pallas TPU API compatibility layer.

The kernel tier targets the modern Pallas TPU surface
(``pltpu.CompilerParams``), but JAX builds in this range ship the same
object under the older name ``pltpu.TPUCompilerParams`` (and very old
builds lack the TPU backend entirely).  Kernels import the two symbols
below instead of reaching into ``pltpu`` directly so that:

* on any JAX with a Pallas TPU backend, ``compiler_params(...)``
  constructs whichever CompilerParams class exists — kernels construct
  and run (interpret mode on CPU, Mosaic on TPU);
* on a JAX without the TPU backend, ``compiler_params(...)`` returns
  ``None`` (``pl.pallas_call(compiler_params=None)`` is accepted) and
  ``HAS_MOSAIC`` is False, so callers/tests know only interpret mode is
  available.

``interpret_default()`` centralises the dispatch rule used by every
``ops.py``: run compiled only when actually on a TPU backend.
"""

from __future__ import annotations

from typing import Any, Optional

import jax

try:  # pallas TPU backend (present on CPU jaxlib builds too)
    from jax.experimental.pallas import tpu as pltpu
except Exception:  # pragma: no cover - very old / trimmed builds
    pltpu = None

# The class moved names across JAX versions: CompilerParams (new) vs
# TPUCompilerParams (0.4.x).  Resolve whichever exists.
_PARAMS_CLS = None
if pltpu is not None:
    _PARAMS_CLS = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

#: True when TPU compiler params can be constructed (Mosaic lowering is
#: at least expressible; actual compiled execution still needs a TPU).
HAS_MOSAIC: bool = _PARAMS_CLS is not None


def compiler_params(**kwargs: Any) -> Optional[Any]:
    """Build a Pallas TPU CompilerParams under whichever name this JAX has.

    Returns None (a valid ``pallas_call`` argument meaning "defaults")
    when the TPU param class is absent; interpret mode ignores it anyway.
    """
    if _PARAMS_CLS is None:
        return None
    return _PARAMS_CLS(**kwargs)


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def interpret_default() -> bool:
    """Dispatch rule shared by the ops.py wrappers: interpret off-TPU."""
    return not on_tpu()
