"""jit'd wrapper: model-facing flash attention with GQA + 4D layout.

On CPU (this container) the kernel runs in interpret mode; on TPU it lowers
to Mosaic.  The wrapper folds (batch, heads) into the kernel's leading grid
axis and pre-expands GQA kv heads (broadcast; free under TP sharding).
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import flash_attention_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_kv"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = 128, block_kv: int = 128) -> jnp.ndarray:
    """q: (B, S, H, d); k/v: (B, S, KV, d). Returns (B, S, H, d)."""
    B, S, H, d = q.shape
    KV = k.shape[2]
    G = H // KV
    if G > 1:
        k = jnp.broadcast_to(k[:, :, :, None, :],
                             (B, k.shape[1], KV, G, d)).reshape(
                                 B, k.shape[1], H, d)
        v = jnp.broadcast_to(v[:, :, :, None, :],
                             (B, v.shape[1], KV, G, d)).reshape(
                                 B, v.shape[1], H, d)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, d)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, k.shape[1], d)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, v.shape[1], d)
    out = flash_attention_kernel(qf, kf, vf, causal=causal, window=window,
                                 block_q=block_q, block_kv=block_kv,
                                 interpret=not _on_tpu())
    return out.reshape(B, H, S, d).transpose(0, 2, 1, 3)
