"""FlashAttention-2-style fused attention as a Pallas TPU kernel.

Tiling: grid = (batch*heads, num_q_blocks, num_kv_blocks); the kv dimension
is the innermost ("arbitrary") grid axis so the online-softmax running
statistics (m, l, acc) live in VMEM scratch across kv steps.  Block shapes
are (BLOCK_Q, head_dim) / (BLOCK_KV, head_dim) — head_dim in {64, 96, 128}
keeps the MXU matmuls 128-lane aligned; BLOCK_Q/BLOCK_KV default to 128.

Causal + sliding-window masking is applied inside the kernel from absolute
block offsets; fully-masked kv blocks are skipped via
``pl.when`` (rather than host-side grid pruning, which keeps BlockSpecs
static).  GQA is handled by the ops.py wrapper (kv heads repeated to q
heads before the call — a broadcast, free under TP sharding).

Validated in interpret mode against ref.py on CPU; TPU v5e is the target.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import compiler_params

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_KV = 128
NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 causal: bool, window: Optional[int], block_q: int,
                 block_kv: int, num_kv_blocks: int, sm_scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_kv

    # Skip kv blocks that are entirely masked for this q block.
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + block_q - 1)
    if window is not None:
        run = jnp.logical_and(run,
                              k_start + block_kv - 1 > q_start - window)

    @pl.when(run)
    def _step():
        q = q_ref[...].astype(jnp.float32) * sm_scale      # (bq, d)
        k = k_ref[...].astype(jnp.float32)                 # (bkv, d)
        v = v_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq,bkv)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_kv), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_kv), 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                             # (bq, bkv)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())))

    @pl.when(ki == num_kv_blocks - 1)
    def _finish():
        # rows with no valid kv (shouldn't happen for causal q>=0) guard
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_kv", "interpret"))
def flash_attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array,
                           causal: bool = True,
                           window: Optional[int] = None,
                           block_q: int = DEFAULT_BLOCK_Q,
                           block_kv: int = DEFAULT_BLOCK_KV,
                           interpret: bool = False) -> jax.Array:
    """q/k/v: (BH, S, d) with equal head counts (GQA pre-expanded).

    Returns (BH, S, d) in q.dtype."""
    BH, S, d = q.shape
    Sk = k.shape[1]
    block_q = min(block_q, S)
    block_kv = min(block_kv, Sk)
    assert S % block_q == 0 and Sk % block_kv == 0, (S, Sk, block_q, block_kv)
    nq = S // block_q
    nkv = Sk // block_kv
    sm_scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _attn_kernel, causal=causal, window=window, block_q=block_q,
        block_kv=block_kv, num_kv_blocks=nkv, sm_scale=sm_scale)

    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nkv),
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((None, block_kv, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((None, block_kv, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # m
            pltpu.VMEM((block_q, 1), jnp.float32),   # l
            pltpu.VMEM((block_q, d), jnp.float32),   # acc
        ],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
