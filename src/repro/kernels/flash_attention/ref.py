"""Pure-jnp oracle for the flash-attention kernel."""

from __future__ import annotations

import math
from typing import Optional

import jax.numpy as jnp
import jax

NEG_INF = -1e30


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True,
                  window: Optional[int] = None) -> jnp.ndarray:
    """q/k/v: (BH, S, d) -> (BH, S, d); plain materialized softmax."""
    BH, S, d = q.shape
    Sk = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((S, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
