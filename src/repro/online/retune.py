"""Drift triggers and the storm-batched re-tune path.

The *policy* half of the online loop: :class:`DriftPolicy` decides — from
the estimator's current mix and the tuning's expected mix — whether a
deployment's tuning is stale, and :func:`retune_fleet` turns every fired
trigger across a fleet into ONE batched tuner dispatch through
``repro.checkpoint.store.retune_storm`` (workloads on one grid axis,
distinct rhos on the other, power-of-two shape bucketing so a long-running
adaptive loop compiles O(log fleet) programs, not one per storm).

Two triggers, both in KL space (the same divergence the uncertainty region
is defined in):

* **threshold** — the estimated mix drifted more than ``kl_threshold`` nats
  from the mix the live tuning was derived for;
* **budget exhaustion** — the drift exceeds ``budget_slack`` x the live
  tuning's own rho: the executed workload left the uncertainty ball the
  robust tuning was hedged over, so its worst-case guarantee no longer
  covers reality.

``min_windows`` gates both (no re-tuning off a cold estimator) and
``cooldown`` enforces a minimum number of segments between re-tunes
(hysteresis: a re-tune moves the expected mix to the estimate, so a noisy
estimator cannot thrash the solver).

A third, optional trigger lives in *sequence* space rather than KL space:
:class:`PageHinkleyDetector` (Page 1954; Hinkley 1971 — the CUSUM family)
watches the per-segment KL observations as a time series and alarms on a
sustained upward shift of their mean.  Where the KL threshold compares a
*windowed estimate* to a fixed bar — so a short burst is diluted by the
estimator's memory — Page-Hinkley accumulates deviation-above-mean and
alarms when the cumulative excursion since its running minimum exceeds
``lambda``, catching changes whose per-window magnitude never clears the
threshold.  Select it per-experiment with ``DriftSpec.detector =
"page_hinkley"``.

:class:`CusumDetector` (Page 1954) is the classical one-sided upper CUSUM
beside it: ``s_t = max(0, s_{t-1} + x_t - k)`` alarms when ``s_t > h``.
Unlike Page-Hinkley it carries no running mean — the reference level ``k``
is an absolute bar in KL space, so it reacts faster to a level shift but
must be re-centred by hand when the baseline moves.  Select with
``DriftSpec.detector = "cusum"``; every trigger decision is emitted as a
``drift.decide`` telemetry event (:mod:`repro.obs`), so detector
comparisons are trace-diffable."""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Union

import numpy as np

from repro import obs


class PageHinkleyDetector:
    """Page-Hinkley change-point test over a scalar observation stream.

    Maintains the running mean ``x_bar_t`` and the cumulative statistic
    ``m_t = sum_{i<=t} (x_i - x_bar_i - delta)``; alarms when
    ``m_t - min_{i<=t} m_i > lambda`` — i.e. the observations have run
    ``delta``-above their own mean long enough to climb ``lambda`` from the
    deepest trough.  ``delta`` sets the magnitude considered "no change"
    (noise floor), ``lambda`` the evidence required.  Stateful: callers
    (:class:`repro.online.session.OnlineSession`) feed one observation per
    segment and :meth:`reset` after acting on an alarm."""

    def __init__(self, delta: float = 0.005, lam: float = 0.25):
        self.delta = float(delta)
        self.lam = float(lam)
        self.reset()

    def reset(self) -> None:
        self.n = 0
        self.mean = 0.0
        self.m = 0.0
        self.m_min = 0.0

    def update(self, x: float) -> bool:
        """Feed one observation; True when the test alarms."""
        x = float(x)
        self.n += 1
        self.mean += (x - self.mean) / self.n
        self.m += x - self.mean - self.delta
        self.m_min = min(self.m_min, self.m)
        return self.m - self.m_min > self.lam


class CusumDetector:
    """One-sided (upper) CUSUM test over a scalar observation stream.

    ``s_t = max(0, s_{t-1} + x_t - k)``; alarms when ``s_t > h``.  ``k``
    is the reference level (observations below it drain the statistic),
    ``h`` the decision interval.  Same stateful contract as
    :class:`PageHinkleyDetector`: one :meth:`update` per segment,
    :meth:`reset` after an alarm is acted on."""

    def __init__(self, k: float = 0.01, h: float = 0.15):
        self.k = float(k)
        self.h = float(h)
        self.reset()

    def reset(self) -> None:
        self.n = 0
        self.s = 0.0

    def update(self, x: float) -> bool:
        """Feed one observation; True when the test alarms."""
        self.n += 1
        self.s = max(0.0, self.s + float(x) - self.k)
        return self.s > self.h


@dataclasses.dataclass(frozen=True)
class DriftPolicy:
    kl_threshold: float = 0.05
    budget_slack: float = 1.0
    min_windows: int = 2
    cooldown: int = 1
    #: floor for re-derived rho budgets (a steady post-drift history still
    #: keeps a hedge; also keeps the re-tune on the robust solver path)
    rho_floor: float = 0.05
    #: which change signal arms the trigger: "kl" (threshold + budget, the
    #: default), "page_hinkley", or "cusum" (each adds its sequential test
    #: on the per-segment KL stream; both KL triggers stay active)
    detector: str = "kl"
    ph_delta: float = 0.005
    ph_lambda: float = 0.25
    cusum_k: float = 0.01
    cusum_h: float = 0.15

    def make_detector(self
                      ) -> Optional[Union[PageHinkleyDetector,
                                          CusumDetector]]:
        """The stateful sequential detector this policy asks for, or None.
        The policy itself is frozen; the owner (one per deployment) holds
        the detector and feeds it the per-segment KL observations."""
        if self.detector == "page_hinkley":
            return PageHinkleyDetector(delta=self.ph_delta,
                                       lam=self.ph_lambda)
        if self.detector == "cusum":
            return CusumDetector(k=self.cusum_k, h=self.cusum_h)
        return None

    def decide(self, kl_obs: float, rho_live: float, n_windows: int,
               since_retune: int,
               change_point: bool = False) -> Optional[str]:
        """The trigger: a reason string when a re-tune should fire, else
        None.  ``since_retune`` counts segments since the last swap;
        ``change_point`` is the sequential detector's alarm for this
        segment (False when the policy runs KL-only)."""
        if n_windows < self.min_windows or since_retune < self.cooldown:
            return None
        if rho_live > 0.0 and kl_obs > self.budget_slack * rho_live:
            return "budget_exhausted"
        if kl_obs > self.kl_threshold:
            return "kl_threshold"
        if change_point:
            return "change_point"
        return None


@dataclasses.dataclass
class RetuneRequest:
    """One fleet member's fired trigger: re-tune for ``w`` at budget
    ``rho`` (``rho <= 0`` requests the nominal solver — the oracle path)."""

    w: np.ndarray
    rho: float
    reason: str = ""


def retune_fleet(requests: Sequence[RetuneRequest], sys, design=None,
                 n_starts: int = 32, steps: int = 200, lr: float = 0.25,
                 seed: int = 0) -> List[object]:
    """Solve every fired trigger of a fleet in one storm dispatch.

    Thin adapter onto :func:`repro.checkpoint.store.retune_storm` (the
    framework's one batched re-tune path) with shape bucketing enabled.
    ``design`` pins the design space the deployments were tuned in (None =
    the tuners' default) so a re-tune never swaps a tree across spaces.
    Returns one ``TuningResult`` per request, in order."""
    from repro.checkpoint.store import retune_storm
    if not requests:
        return []
    obs.count("tuner.retune_fleet")
    with obs.span("tuner.retune_fleet", requests=len(requests),
                  reasons=[r.reason for r in requests]):
        W = np.stack([np.asarray(r.w, np.float64) for r in requests])
        rhos = [float(r.rho) for r in requests]
        return retune_storm(W, rhos, sys, seed=seed, design=design,
                            n_starts=n_starts, steps=steps, lr=lr,
                            pad_pow2=True)
