"""Online drift subsystem: observe -> estimate -> re-tune, closed.

ENDURE's premise is that the executed workload lives in a KL neighborhood
of the expected one; everywhere else in this repo the expected workload is
a static input.  This package closes the loop on top of the existing stack:

* **observe** — the session executor emits per-flush-window op counts
  (``SessionResult.window_ops``, :mod:`repro.lsm.workload_runner`);
* **estimate** (:mod:`repro.online.estimate`) — bounded window histories,
  sliding-window / EWMA mix estimators, and rho-from-history budgets
  (scalar + fleet-vectorized);
* **decide + re-tune** (:mod:`repro.online.retune`) — KL-threshold and
  budget-exhaustion triggers, storms batched through
  ``repro.checkpoint.store.retune_storm``;
* **drive** (:mod:`repro.online.session`) — :class:`OnlineSession` swaps
  tunings at flush boundaries via ``LSMTree.retune``; :func:`execute_drift`
  runs whole drift experiments (the ``repro.api`` `DriftSpec` lowering);
* **arbitrate** (:mod:`repro.online.memory`) — fleet-level memory as a
  single global budget: :class:`MemoryBudget` / :class:`FleetArbiter`
  divide it across tenants by marginal cost-model benefit and re-divide on
  the drift triggers; :func:`execute_memory_fleet` runs whole arbitration
  experiments (the ``repro.api`` `MemorySpec` lowering).
"""

from .estimate import (ESTIMATORS, EWMAEstimator, SlidingWindowEstimator,
                       WindowHistory, kl_np, make_estimator,
                       normalize_counts, rho_from_history_batch,
                       rho_from_windows, smooth_mix)
from .memory import (MEMORY_ARMS, FleetArbiter, MemoryBudget, divide_budget,
                     execute_memory_fleet, memory_cost_curves)
from .retune import (CusumDetector, DriftPolicy, PageHinkleyDetector,
                     RetuneRequest, retune_fleet)
from .session import (ARMS, DriftArmResult, OnlineSession, SegmentRecord,
                      execute_drift)

__all__ = [
    "WindowHistory", "SlidingWindowEstimator", "EWMAEstimator",
    "ESTIMATORS", "make_estimator", "normalize_counts", "kl_np",
    "rho_from_windows", "rho_from_history_batch", "smooth_mix",
    "CusumDetector", "DriftPolicy", "PageHinkleyDetector", "RetuneRequest",
    "retune_fleet",
    "ARMS", "OnlineSession", "SegmentRecord", "DriftArmResult",
    "execute_drift",
    "MEMORY_ARMS", "MemoryBudget", "FleetArbiter", "divide_budget",
    "memory_cost_curves", "execute_memory_fleet",
]
