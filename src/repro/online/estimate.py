"""Streaming workload estimation over observed flush-window op counts.

The observation stream is the ``SessionResult.window_ops`` arrays the
session executor emits (one (z0, z1, q, w) count row per flush window, see
:mod:`repro.lsm.workload_runner`).  This module turns that stream into

* a bounded history (:class:`WindowHistory`, a fixed-capacity ring buffer of
  window counts — O(capacity) memory regardless of session length);
* a current-mix *estimate* (:class:`SlidingWindowEstimator` — count-weighted
  mean of the last W windows — and :class:`EWMAEstimator` — exponentially
  weighted mean of per-window mixes);
* a *robustness budget*: :func:`rho_from_windows` is the online form of the
  paper's Algorithm 1 (rho = max KL of the observed window mixes against a
  center), and :func:`rho_from_history_batch` evaluates the measured
  KL divergence between expected and observed mixes for a whole fleet in one
  vectorized (jax) dispatch — the ``rho_from_history`` rho source of
  :class:`repro.api.WorkloadSpec`, fed from live history.

Everything scalar here is plain numpy (the online loop must not pull jax
into engine workers); only the fleet-batched entry point uses jax.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

#: probability floor used inside KL, matching repro.core.workload's clamp.
_KL_EPS = 1e-30


def normalize_counts(counts) -> np.ndarray:
    """Rows of op counts (or mixes) -> normalized probability rows."""
    c = np.atleast_2d(np.asarray(counts, np.float64))
    tot = np.maximum(c.sum(axis=1, keepdims=True), 1e-30)
    return c / tot


def smooth_mix(mix, eps: float = 0.004) -> np.ndarray:
    """Floor a mix away from the simplex boundary: (1-eps) m + eps/4.

    An estimate that serves as a KL *center* (drift reference, re-tune
    target) must not carry zero-probability classes: a single later
    observation of a zero-count class would otherwise produce an unbounded
    divergence — and an unbounded robustness budget.  ``eps`` bounds any
    KL against the smoothed center by ~ln(4/eps) nats."""
    m = np.asarray(mix, np.float64)
    return (1.0 - eps) * m + eps / m.shape[-1]


def kl_np(p, q) -> np.ndarray:
    """I_KL(p, q) with 0 log 0 := 0 — numpy twin of core.kl_divergence."""
    p = np.asarray(p, np.float64)
    q = np.asarray(q, np.float64)
    ratio = np.where(p > 0, p / np.maximum(q, _KL_EPS), 1.0)
    return np.sum(np.where(p > 0, p * np.log(ratio), 0.0), axis=-1)


class WindowHistory:
    """Fixed-capacity ring buffer of per-window (z0, z1, q, w) counts.

    ``append`` takes one window row or a whole ``window_ops`` batch; the
    oldest windows fall off once ``capacity`` is exceeded.  Accessors return
    chronological (oldest -> newest) views."""

    def __init__(self, capacity: int = 128):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self._buf = np.zeros((self.capacity, 4), np.int64)
        self._next = 0            # next write slot
        self._n = 0               # live rows (<= capacity)
        self.total_windows = 0    # windows ever observed

    def __len__(self) -> int:
        return self._n

    def append(self, counts) -> None:
        rows = np.atleast_2d(np.asarray(counts, np.int64))
        if rows.shape[-1] != 4:
            raise ValueError(f"window counts must be (., 4), got {rows.shape}")
        self.total_windows += len(rows)
        if len(rows) >= self.capacity:   # only the newest `capacity` survive
            self._buf[:] = rows[-self.capacity:]
            self._next = 0
            self._n = self.capacity
            return
        for row in rows:                 # small batches: ring insert
            self._buf[self._next] = row
            self._next = (self._next + 1) % self.capacity
            self._n = min(self._n + 1, self.capacity)

    def counts(self, last: Optional[int] = None) -> np.ndarray:
        """The newest ``last`` (default: all live) windows, chronological."""
        n = self._n if last is None else min(int(last), self._n)
        idx = (self._next - n + np.arange(n)) % self.capacity
        return self._buf[idx]

    def mixes(self, last: Optional[int] = None) -> np.ndarray:
        return normalize_counts(self.counts(last))

    def total_mix(self, last: Optional[int] = None) -> np.ndarray:
        """Count-weighted mix over the newest ``last`` windows.  An empty
        (or all-zero) history has no evidence and estimates uniform — the
        only mix that biases no query class, and a proper distribution for
        downstream KL centers (all-zero would not be)."""
        c = self.counts(last).sum(axis=0)
        if c.sum() <= 0:
            return np.full(4, 0.25)
        return normalize_counts(c)[0]


class SlidingWindowEstimator:
    """Count-weighted mean mix over the newest ``window`` flush windows."""

    name = "window"

    def __init__(self, window: int = 16, **_):
        self.window = int(window)

    def estimate(self, history: WindowHistory) -> np.ndarray:
        return history.total_mix(last=self.window)


class EWMAEstimator:
    """Exponentially weighted mean of per-window mixes (newest weight
    ``alpha``); weights renormalize over the live history, so the estimate
    is a proper convex combination from the very first window."""

    name = "ewma"

    def __init__(self, alpha: float = 0.35, **_):
        if not 0.0 < alpha <= 1.0:
            raise ValueError("alpha must be in (0, 1]")
        self.alpha = float(alpha)

    def estimate(self, history: WindowHistory) -> np.ndarray:
        mixes = history.mixes()                     # chronological
        n = len(mixes)
        if n == 0:                 # no evidence: uniform, like total_mix
            return np.full(4, 0.25)
        w = self.alpha * (1.0 - self.alpha) ** np.arange(n - 1, -1, -1.0)
        w /= w.sum()
        return w @ mixes


ESTIMATORS = {
    SlidingWindowEstimator.name: SlidingWindowEstimator,
    EWMAEstimator.name: EWMAEstimator,
}


def make_estimator(name: str, **kw):
    try:
        cls = ESTIMATORS[name]
    except KeyError:
        raise ValueError(f"unknown estimator {name!r}; "
                         f"known: {sorted(ESTIMATORS)}") from None
    return cls(**kw)


def rho_from_windows(counts, center=None, floor: float = 0.0) -> float:
    """Algorithm 1 on an observed window history: rho = max_i I_KL(m_i, c).

    ``counts`` are window count (or mix) rows; ``center`` defaults to their
    mean mix (exactly :func:`repro.core.rho_from_history` on the normalized
    rows), or pass the estimator's current mix to budget the spread around
    the tuning target.  ``floor`` clamps the result away from zero so a
    perfectly steady history still leaves a hedge.  An empty history has
    measured no drift: the budget is exactly the floor."""
    mixes = normalize_counts(counts)
    if mixes.shape[0] == 0 or not np.any(np.asarray(counts)):
        return float(floor)
    c = mixes.mean(axis=0) if center is None else \
        normalize_counts(center)[0]
    return float(max(kl_np(mixes, c).max(), floor))


def rho_from_history_batch(expected, counts, floor: float = 0.0):
    """Fleet-vectorized rho-from-history: measured drift per tree.

    ``expected`` is the (F, 4) matrix of tuning-time expected mixes and
    ``counts`` the (F, W, 4) stack of observed window counts (one history
    per tree).  Returns the (F,) robustness budgets rho_f = max over windows
    of I_KL(observed mix, expected_f) — the measured KL divergence between
    what each tree was tuned for and what it actually served — through one
    broadcasted :func:`repro.core.kl_divergence` dispatch (the same batch
    machinery the tuners vmap over)."""
    import jax.numpy as jnp
    from repro.core import kl_divergence
    E = np.atleast_2d(np.asarray(expected, np.float64))
    C = np.asarray(counts, np.float64)
    if C.ndim != 3 or C.shape[0] != E.shape[0] or C.shape[-1] != 4:
        raise ValueError(f"counts must be (F, W, 4) matching expected "
                         f"(F, 4); got {C.shape} vs {E.shape}")
    if C.shape[1] == 0:            # no windows observed: no measured drift
        return np.full(E.shape[0], floor, np.float64)
    mixes = C / np.maximum(C.sum(axis=-1, keepdims=True), 1e-30)
    kls = kl_divergence(jnp.asarray(mixes), jnp.asarray(E[:, None, :]))
    return np.maximum(np.asarray(kls).max(axis=-1), floor)
