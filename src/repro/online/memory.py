"""Fleet-level adaptive memory arbitration: one byte budget, N tenants.

Everywhere else in this repo each tree owns a fixed ``(buffer, bloom bits)``
split chosen at tune time — ``LSMSystem.bits_per_entry`` is a per-tree
constant.  This module makes memory a *fleet-level* resource instead (the
"Breaking Down Memory Walls" direction, see PAPERS.md): a single global
budget of :class:`MemoryBudget` is divided across N tenants' write buffers
and Bloom/filter memory, and re-divided online as their workload mixes
drift — write-heavy tenants borrow buffer from read-heavy ones.

Three pieces:

* :class:`MemoryBudget` — the budget semantics: a global total (bits per
  tenant-entry), a per-tenant floor, and an allocation quantum that
  discretizes the candidate shares (bounding both the greedy search and the
  number of distinct systems the re-tune storms compile against).
* :func:`divide_budget` + the cost curves — every tenant's marginal benefit
  per quantum is scored by the existing jitted cost model:
  :func:`repro.core.cost_across_memory` sweeps the tenant's *current*
  tuning across the share grid with the budget as a traced axis (one
  compilation for the whole fleet x grid), and a deterministic greedy
  water-fill grants each quantum to the tenant whose modeled,
  traffic-weighted cost drops most.
* :class:`FleetArbiter` — the online controller: per-tenant KL drift
  triggers (the same :class:`~repro.online.retune.DriftPolicy` contract as
  the PR 5 loop — ``min_windows`` cold-start gate, fleet-level ``cooldown``
  hysteresis), one re-division when any tenant fires, and re-tune storms
  grouped by granted share (``retune_storm`` solves one system per
  dispatch).  New splits land through :meth:`repro.lsm.LSMTree.retune` at
  flush boundaries, so transition compaction is charged to measured I/O.

:func:`execute_memory_fleet` is the driver the execution backends call for
a compiled :class:`repro.api.MemorySpec` experiment: a paired comparison of
a ``static`` fleet (today's fixed equal split, exactly the
:func:`~repro.online.session.execute_drift` ``static_robust`` path) against
an ``arbitrated`` fleet (initial division from expected mixes, online
re-division on drift) over the same keys and session plans.  With
arbitration disabled the arbitrated fleet never deviates from the equal
split, and its results are bit-identical to the static fleet.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs

from .estimate import make_estimator, rho_from_windows, smooth_mix
from .retune import DriftPolicy, RetuneRequest, retune_fleet
from .session import DriftArmResult, OnlineSession

#: memory-experiment fleets, in report order.
MEMORY_ARMS = ("static", "arbitrated")


@dataclasses.dataclass(frozen=True)
class MemoryBudget:
    """The global memory budget and its division semantics.

    All quantities are **bits per tenant-entry** (the unit
    ``LSMSystem.bits_per_entry`` / ``LSMTree.config_from_phi`` already
    speak): a tenant granted share ``b`` deploys under
    ``sys.replace(bits_per_entry=b)``, i.e. ``b * n_keys`` bits split
    between its write buffer and Bloom filters by its own tuning.  With
    equal per-tenant key populations (the fleet driver's convention) this
    is exactly a global byte budget.

    ``total_bpe`` is the fleet-wide sum of shares; ``floor_bpe`` the
    minimum any tenant can be squeezed to (a tree needs *some* buffer and
    filter memory to function); ``quantum_bpe`` the granularity shares move
    in — hysteresis in space, complementing the arbiter's cooldown in time
    (a re-division below one quantum is not worth a transition
    compaction)."""

    total_bpe: float
    floor_bpe: float = 2.0
    quantum_bpe: float = 0.5

    def __post_init__(self):
        if self.floor_bpe <= 0.0:
            raise ValueError("floor_bpe must be > 0")
        if self.quantum_bpe <= 0.0:
            raise ValueError("quantum_bpe must be > 0")

    def validate(self, n_tenants: int) -> None:
        if self.total_bpe < n_tenants * self.floor_bpe - 1e-9:
            raise ValueError(
                f"budget total_bpe={self.total_bpe:g} cannot cover "
                f"{n_tenants} tenants at floor_bpe={self.floor_bpe:g}")

    def units(self, n_tenants: int) -> int:
        """Divisible quanta above the all-at-floor baseline."""
        return int((self.total_bpe - n_tenants * self.floor_bpe)
                   / self.quantum_bpe + 1e-9)

    def grid(self, n_tenants: int) -> np.ndarray:
        """Candidate per-tenant shares: floor, floor + q, ..., floor + Uq
        (one tenant absorbing every free quantum)."""
        return self.floor_bpe + self.quantum_bpe * np.arange(
            self.units(n_tenants) + 1, dtype=np.float64)


# -- cost curves: one cached jit per (system) closure ------------------------

_CURVE_FNS: Dict[object, object] = {}


def _curve_fn(sys):
    """Cached jit of :func:`repro.core.cost_across_memory` for one system
    closure.  Distinct systems appear only per distinct granted share, and
    shares live on the budget's quantum grid — so the cache is bounded by
    the grid size, not the session length."""
    fn = _CURVE_FNS.get(sys)
    if fn is None:
        import jax
        from repro.core import cost_across_memory

        @jax.jit
        def fn(phi, grid):
            return cost_across_memory(phi, sys, grid)

        _CURVE_FNS[sys] = fn
    return fn


def memory_cost_curves(phis: Sequence[object], sys_list: Sequence[object],
                       mixes: np.ndarray, grid: np.ndarray) -> np.ndarray:
    """``(F, G)`` modeled expected cost of tenant ``f``'s current tuning
    re-deployed at grid share ``g``, under its current mix estimate."""
    import jax.numpy as jnp
    g = jnp.asarray(grid, jnp.float32)
    M = np.atleast_2d(np.asarray(mixes, np.float64))
    curves = np.empty((len(phis), len(grid)), np.float64)
    for f, (phi, sys_f) in enumerate(zip(phis, sys_list)):
        c = np.asarray(_curve_fn(sys_f)(phi, g), np.float64)   # (G, 4)
        curves[f] = c @ M[f]
    return curves


def divide_budget(curves: np.ndarray, weights: np.ndarray,
                  budget: MemoryBudget) -> np.ndarray:
    """Greedy marginal water-fill of the global budget, deterministic.

    Every tenant starts at the floor; each free quantum goes to the tenant
    with the largest traffic-weighted modeled cost drop for one more grid
    step (``weights[f] * (C[f, g] - C[f, g+1])``), ties to the lowest
    tenant index.  Each per-tenant curve is (modeled) convex-ish and
    monotone decreasing in memory, so this is the classic exchange-argument
    optimum on the quantized grid; either way it is reproducible, which the
    paired static/arbitrated comparison requires.  Returns the (F,) shares
    in bits/entry, summing to ``floor + units * quantum`` exactly."""
    F, G = curves.shape
    w = np.asarray(weights, np.float64)
    alloc = np.zeros(F, np.int64)
    for _ in range(budget.units(F)):
        nxt = np.minimum(alloc + 1, G - 1)
        gains = w * (curves[np.arange(F), alloc]
                     - curves[np.arange(F), nxt])
        gains[alloc + 1 >= G] = -np.inf          # at the grid cap
        alloc[int(np.argmax(gains))] += 1
    return budget.floor_bpe + budget.quantum_bpe * alloc.astype(np.float64)


class FleetArbiter:
    """The fleet-level memory controller.

    Holds the budget, the base (equal-split) system, and the drift policy;
    :meth:`initial_shares` divides the budget from the expected mixes at
    deploy time, :meth:`step` watches every tenant's KL drift trigger after
    each executed segment and — when one fires and the fleet-level cooldown
    has passed — re-divides the budget from the current mix estimates and
    re-tunes every affected tenant (share changed, or trigger fired) in
    share-grouped storms.  ``events`` records every division for the
    report."""

    def __init__(self, budget: MemoryBudget, base_sys, policy: DriftPolicy,
                 design=None, n_starts: int = 32, steps: int = 200,
                 lr: float = 0.25, seed: int = 0):
        self.budget = budget
        self.base_sys = base_sys
        self.policy = policy
        self.design = design
        self.retune_kw = dict(design=design, n_starts=n_starts, steps=steps,
                              lr=lr, seed=seed)
        self._since = 10 ** 9           # fleet-level cooldown counter
        self.events: List[dict] = []

    # -- division ----------------------------------------------------------

    def sys_for(self, share: float):
        return self.base_sys.replace(bits_per_entry=float(share))

    def arbitrate(self, phis, sys_list, mixes, weights) -> np.ndarray:
        grid = self.budget.grid(len(phis))
        curves = memory_cost_curves(phis, sys_list, mixes, grid)
        return divide_budget(curves, weights, self.budget)

    def initial_shares(self, tunings, expected: np.ndarray) -> np.ndarray:
        """Deploy-time division: no history yet, so the expected mixes are
        the evidence and traffic weights are uniform."""
        F = len(tunings)
        shares = self.arbitrate([t.phi for t in tunings],
                                [self.base_sys] * F,
                                np.asarray(expected, np.float64),
                                np.ones(F))
        self.events.append(dict(segment=-1, reason="initial_division",
                                shares=[float(s) for s in shares],
                                retuned=[]))
        if obs.enabled():
            obs.event("arbiter.division", **self.events[-1])
            obs.count("arbiter.divisions")
        return shares

    # -- the online trigger ------------------------------------------------

    def step(self, sessions: Sequence[OnlineSession], tunings: List[object],
             segment: int) -> Optional[np.ndarray]:
        """One post-segment decision for the arbitrated fleet.

        Returns the new shares when a re-division fired (mutating
        ``sessions`` — swaps applied — and ``tunings`` in place), else
        None.  The per-tenant trigger is exactly the drift loop's
        :meth:`DriftPolicy.decide`; ``cooldown`` hysteresis is fleet-level
        (one re-division resets the whole fleet's counter, so a noisy
        tenant cannot thrash everyone's memory)."""
        self._since += 1
        reasons: Dict[int, str] = {}
        for f, sess in enumerate(sessions):
            rec = sess.records[-1]
            why = self.policy.decide(rec.kl_est, sess.rho,
                                     len(sess.history), self._since)
            if obs.enabled():
                obs.event("arbiter.decide", segment=int(segment), tenant=f,
                          kl=round(float(rec.kl_est), 9),
                          rho_live=round(float(sess.rho), 9),
                          since=min(self._since, 10 ** 9),
                          reason=why or "none")
                obs.count("arbiter.trigger." + (why or "none"))
            if why is not None:
                reasons[f] = why
        if not reasons:
            return None

        F = len(sessions)
        mixes = np.stack([smooth_mix(s.estimator.estimate(s.history))
                          for s in sessions])
        weights = np.array([max(float(s.history.counts().sum()), 1.0)
                            for s in sessions])
        shares = self.arbitrate([t.phi for t in tunings],
                                [s.sys for s in sessions], mixes, weights)

        # re-tune: any tenant whose share moved >= half a quantum, plus any
        # whose own trigger fired (drifted in place — re-center it even if
        # its share held)
        moved = [f for f in range(F)
                 if abs(shares[f] - sessions[f].sys.bits_per_entry)
                 >= 0.5 * self.budget.quantum_bpe]
        retune = sorted(set(moved) | set(reasons))
        by_share: Dict[float, List[int]] = {}
        for f in retune:
            by_share.setdefault(float(shares[f]), []).append(f)
        for share, fs in sorted(by_share.items()):
            sys_f = self.sys_for(share)
            reqs = [RetuneRequest(
                w=mixes[f],
                rho=rho_from_windows(sessions[f].history.counts(),
                                     center=mixes[f],
                                     floor=self.policy.rho_floor),
                reason=reasons.get(f, "rebalance")) for f in fs]
            sols = retune_fleet(reqs, sys_f, **self.retune_kw)
            for f, req, tr in zip(fs, reqs, sols):
                sessions[f].apply(tr, w_center=req.w, rho=req.rho,
                                  reason=req.reason, sys=sys_f)
                tunings[f] = tr
        self._since = 0
        self.events.append(dict(
            segment=int(segment),
            reason=";".join(f"w{f}:{r}" for f, r in sorted(reasons.items())),
            shares=[float(s) for s in shares],
            retuned=[int(f) for f in retune]))
        if obs.enabled():
            obs.event("arbiter.division", **self.events[-1])
            obs.count("arbiter.divisions")
        return shares


def execute_memory_fleet(plan) -> Tuple[Dict[Tuple[int, str],
                                             DriftArmResult], List[dict]]:
    """Run a compiled memory-arbitration experiment
    (:class:`repro.api.compile.MemoryPlan`); returns
    ``({(tenant index, fleet): DriftArmResult}, division events)``.

    Paired by construction: both fleets share per-tenant key populations
    (seed ``key_seed + widx``) and per-segment session plans (seed
    ``session_seed + widx * S + s``) — the :func:`execute_drift`
    conventions exactly, so the ``static`` fleet is bit-identical to that
    driver's ``static_robust`` arm, and throughput differences between the
    fleets are memory-division differences.  Like the drift loop, the
    segment loop is a feedback system and inherently sequential; every
    backend runs this same inline driver (re-tune storms inside it are
    still batched)."""
    from repro.lsm import LSMTree, draw_keys, materialize_session, populate
    d, m = plan.drift, plan.memory
    S = int(d.segments)
    F = len(plan.expected)
    budget = MemoryBudget(
        total_bpe=(m.total_bits_per_entry if m.total_bits_per_entry
                   is not None else F * plan.sys.bits_per_entry),
        floor_bpe=m.floor_bits_per_entry,
        quantum_bpe=m.quantum_bits_per_entry)
    budget.validate(F)
    policy = DriftPolicy(
        kl_threshold=(m.rebalance_kl if m.rebalance_kl is not None
                      else d.kl_threshold),
        budget_slack=d.budget_slack, min_windows=m.min_windows,
        cooldown=m.cooldown, rho_floor=d.rho_floor)
    arbiter = FleetArbiter(budget, plan.sys, policy, design=plan.design,
                           n_starts=d.retune_starts, steps=d.retune_steps,
                           seed=d.retune_seed)

    # -- initial division + per-tenant (re-)tunes for non-equal shares -----
    shares = np.full(F, plan.sys.bits_per_entry, np.float64)
    tunings = list(plan.tunings)
    if m.enabled:
        shares = arbiter.initial_shares(tunings, plan.expected)
        by_share: Dict[float, List[int]] = {}
        for f in range(F):
            if abs(shares[f] - plan.sys.bits_per_entry) \
                    >= 0.5 * budget.quantum_bpe:
                by_share.setdefault(float(shares[f]), []).append(f)
        for share, fs in sorted(by_share.items()):
            sys_f = arbiter.sys_for(share)
            reqs = [RetuneRequest(w=plan.expected[f], rho=plan.rho0,
                                  reason="initial_division") for f in fs]
            sols = retune_fleet(reqs, sys_f, **arbiter.retune_kw)
            for f, tr in zip(fs, sols):
                tunings[f] = tr
        arbiter.events[-1]["retuned"] = sorted(
            f for fs in by_share.values() for f in fs)

    # -- deploy: shared keys per tenant, one tree per (tenant, fleet) ------
    keys: Dict[int, np.ndarray] = {}
    sessions: Dict[Tuple[int, str], OnlineSession] = {}
    for f in range(F):
        keys[f] = draw_keys(d.n_keys, seed=d.key_seed + f,
                            key_space=d.key_space)
        for arm in MEMORY_ARMS:
            tuning = plan.tunings[f] if arm == "static" else tunings[f]
            sys_f = plan.sys if arm == "static" \
                else arbiter.sys_for(shares[f])
            tree = LSMTree.from_phi(tuning.phi, sys_f,
                                    expected_entries=d.n_keys,
                                    entry_bytes=d.entry_bytes,
                                    policy=plan.policies[f],
                                    policy_params=plan.policy_params[f])
            tree.obs_label = f"t{f}.{arm}/{plan.policies[f]}"
            populate(tree, d.n_keys, key_space=d.key_space, keys=keys[f])
            sessions[(f, arm)] = OnlineSession(
                tree, expected=plan.expected[f], rho=plan.rho0, sys=sys_f,
                mode="static", policy=policy,
                estimator=make_estimator(d.estimator, alpha=d.alpha,
                                         window=d.window),
                capacity=d.capacity, f_a=d.f_a, f_seq=d.f_seq)
    arb_sessions = [sessions[(f, "arbitrated")] for f in range(F)]
    arb_tunings = list(tunings)

    # -- the segment loop --------------------------------------------------
    scenario = getattr(plan, "scenario", None)   # trace-shaped kinds only:
    # the spec rejects the adversary on the memory axis (no defender arm)
    for s in range(S):
        for f in range(F):
            mix = plan.schedules[f][s]
            nq = d.n_queries
            extra = {}
            if scenario is not None:
                nq = int(scenario.segment_queries(s))
                extra = dict(scenario.session_kwargs(s, len(keys[f])))
            rf = float(extra.pop("range_fraction", d.range_fraction))
            splan = materialize_session(
                keys[f], mix, n_queries=nq,
                seed=d.session_seed + f * S + s, key_space=d.key_space,
                range_fraction=rf, **extra)
            for arm in MEMORY_ARMS:
                sessions[(f, arm)].execute_segment(splan, mix, s)
            keys[f] = np.concatenate([keys[f], splan.insert_keys])
        if m.enabled and s < S - 1:    # a re-division after the last
            arbiter.step(arb_sessions, arb_tunings, segment=s)

    results = {(f, arm): DriftArmResult(widx=f, arm=arm,
                                        records=sessions[(f, arm)].records)
               for f in range(F) for arm in MEMORY_ARMS}
    return results, arbiter.events
