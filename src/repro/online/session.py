"""The online loop: interleave fleet execution segments with re-tune
decisions.

:class:`OnlineSession` wraps one deployed :class:`repro.lsm.LSMTree` with
the observe -> estimate -> decide state machine: every executed segment
feeds its per-flush-window op counts (``SessionResult.window_ops``) into a
:class:`~repro.online.estimate.WindowHistory`, the estimator produces the
current mix, and — in ``online`` mode — the :class:`~repro.online.retune
.DriftPolicy` may emit a :class:`RetuneRequest`.  Tuning swaps land through
:meth:`repro.lsm.LSMTree.retune`, i.e. exactly at flush boundaries, and the
transition compaction they cause is measured workload I/O like any other.

:func:`execute_drift` is the fleet driver the execution backends call for a
compiled :class:`repro.api.DriftSpec` experiment: it steps every arm
(``stale_nominal`` / ``static_robust`` / ``online`` / ``oracle``) of every
workload through the drift schedule in lockstep — arms of one workload
share the key population and the materialized session plan per segment, so
the comparison is paired — and batches all re-tunes that fire at a segment
boundary (the whole fleet's, across workloads) into ONE
:func:`~repro.online.retune.retune_fleet` storm.  The oracle arm re-tunes
every segment to the *true* upcoming mix; its solves for the entire
schedule are one storm up front."""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro import obs

from .estimate import (WindowHistory, kl_np, make_estimator,
                       rho_from_windows, smooth_mix)
from .retune import DriftPolicy, RetuneRequest, retune_fleet

#: drift-experiment arms, in report order.
ARMS = ("stale_nominal", "static_robust", "online", "oracle")


@dataclasses.dataclass
class SegmentRecord:
    """One executed segment of an online session."""

    index: int
    true_mix: np.ndarray
    observed_mix: np.ndarray          # executed counts, normalized
    est_mix: np.ndarray               # estimator output after this segment
    kl_est: float                     # I_KL(est_mix, live expected mix)
    rho_live: float                   # budget of the deployed tuning
    avg_io_per_query: float
    queries: int
    windows: int
    retuned: bool = False             # ran under a tuning swapped at start
    retune_reason: str = ""


@dataclasses.dataclass
class DriftArmResult:
    """All segments of one (workload, arm) deployment."""

    widx: int
    arm: str
    records: List[SegmentRecord]

    @property
    def avg_io_per_query(self) -> float:
        q = sum(r.queries for r in self.records)
        return sum(r.avg_io_per_query * r.queries
                   for r in self.records) / max(q, 1)

    @property
    def throughput(self) -> float:
        return 1.0 / max(self.avg_io_per_query, 1e-9)

    @property
    def retunes(self) -> int:
        return sum(r.retuned for r in self.records)


class OnlineSession:
    """Observe -> estimate -> decide around one deployed tree.

    ``mode``: ``"static"`` never re-tunes (it still observes, so drift
    diagnostics are recorded); ``"online"`` emits a :class:`RetuneRequest`
    when the policy fires (the caller executes it — batched across the
    fleet — and calls :meth:`apply`); ``"oracle"`` expects the caller to
    :meth:`apply` the true mix's tuning before every segment."""

    MODES = ("static", "online", "oracle")

    def __init__(self, tree, expected, rho: float, sys, mode: str = "online",
                 policy: Optional[DriftPolicy] = None, estimator=None,
                 capacity: int = 128, f_a: float = 1.0, f_seq: float = 1.0,
                 phi=None):
        if mode not in self.MODES:
            raise ValueError(f"mode {mode!r} not in {self.MODES}")
        self.tree = tree
        self.sys = sys
        self.mode = mode
        self.expected = np.asarray(expected, np.float64)
        self.rho = float(rho)
        #: the deployed tuning's design point — what an adversary scenario
        #: reads to cost its attack; kept current across :meth:`apply`.
        self.phi = phi
        self.policy = policy or DriftPolicy()
        self.estimator = estimator or make_estimator("window")
        #: the policy's optional sequential change-point test; the policy
        #: object is frozen and fleet-shared, so the per-deployment state
        #: (running mean, cumulative statistic) lives here
        self.detector = self.policy.make_detector()
        self.history = WindowHistory(capacity)
        self.records: List[SegmentRecord] = []
        self._since_retune = 10 ** 9
        self._swap_reason: Optional[str] = None
        self._pending: Optional[RetuneRequest] = None
        self.f_a = f_a
        self.f_seq = f_seq

    def execute_segment(self, plan, true_mix, index: int) -> SegmentRecord:
        """Run one materialized session segment and update the loop state."""
        from repro.lsm import execute_session
        res = execute_session(self.tree, plan, f_a=self.f_a, f_seq=self.f_seq)
        self.history.append(res.window_ops)
        # smoothed: the estimate serves as a KL center and re-tune target,
        # so zero-count classes must not produce unbounded divergences
        est = smooth_mix(self.estimator.estimate(self.history))
        kl = float(kl_np(est, self.expected))
        rec = SegmentRecord(
            index=index, true_mix=np.asarray(true_mix, np.float64),
            observed_mix=res.observed_mix, est_mix=est, kl_est=kl,
            rho_live=self.rho, avg_io_per_query=res.avg_io_per_query,
            queries=res.queries, windows=len(res.window_ops),
            retuned=self._swap_reason is not None,
            retune_reason=self._swap_reason or "")
        self._swap_reason = None
        self.records.append(rec)
        self._since_retune += 1
        change_point = (self.detector.update(kl)
                        if self.detector is not None else False)
        if self.mode == "online":
            reason = self.policy.decide(kl, self.rho, len(self.history),
                                        self._since_retune,
                                        change_point=change_point)
            if obs.enabled():
                obs.event("drift.decide", segment=index,
                          kl=round(kl, 9), rho_live=round(self.rho, 9),
                          since_retune=min(self._since_retune, 10 ** 9),
                          windows=len(self.history),
                          detector=self.policy.detector,
                          change_point=bool(change_point),
                          reason=reason or "none")
                obs.count("drift.trigger." + (reason or "none"))
            if reason is not None:
                # re-center on the estimate; budget = measured spread of the
                # history around it (Algorithm 1, floored)
                rho_new = rho_from_windows(self.history.counts(), center=est,
                                           floor=self.policy.rho_floor)
                self._pending = RetuneRequest(w=est, rho=rho_new,
                                              reason=reason)
        return rec

    def take_request(self) -> Optional[RetuneRequest]:
        req, self._pending = self._pending, None
        return req

    def apply(self, tuning, w_center, rho: float, reason: str,
              sys=None) -> None:
        """Swap the deployed tuning (at a flush boundary) and re-center the
        drift reference on what the new tuning was derived for.  ``sys``
        replaces the session's live system first — the fleet memory arbiter
        re-tunes a tenant *under a new memory share*, so the system the
        tuning was solved against must land with it."""
        if sys is not None:
            self.sys = sys
        if obs.enabled():
            obs.event("drift.apply", reason=reason, rho=round(float(rho), 9),
                      label=self.tree.obs_label)
            obs.count("drift.retunes")
        self.tree.retune(tuning.phi, self.sys)
        self.phi = tuning.phi
        self.expected = np.asarray(w_center, np.float64)
        self.rho = float(rho)
        self._since_retune = 0
        self._swap_reason = reason
        if self.detector is not None:
            self.detector.reset()    # the change was acted on; re-arm


def execute_drift(plan):
    """Run a compiled drift experiment (:class:`repro.api.compile
    .DriftPlan`); returns ``(results, regret)`` where ``results`` is
    ``{(workload index, arm): DriftArmResult}`` and ``regret`` is
    ``{workload index: [per-segment regret record, ...]}`` — non-empty only
    under an adversary scenario, where each record carries the attacked
    mix, the model costs, and the KL dual bound it must stay under.

    Inherently sequential across segments (the loop is a feedback system),
    so every execution backend runs this same inline driver; within a
    segment boundary all fired re-tunes are one storm.  Scenario kinds
    (:mod:`repro.scenarios`) hook in at three points: the compiled schedule
    (already lowered by :func:`repro.api.compile.drift_schedule`), the
    per-segment session shaping (query volume, skew/rotation, deletes,
    scan width), and — for the adversary — the per-segment mix itself,
    re-solved inside the defender's live rho-ball."""
    from repro.lsm import LSMTree, draw_keys, materialize_session, populate
    d = plan.drift
    S = int(d.segments)
    scenario = getattr(plan, "scenario", None)
    adversary = scenario if scenario is not None and scenario.is_adversary \
        else None
    policy = DriftPolicy(kl_threshold=d.kl_threshold,
                         budget_slack=d.budget_slack,
                         min_windows=d.min_windows, cooldown=d.cooldown,
                         rho_floor=d.rho_floor, detector=d.detector,
                         ph_delta=d.ph_delta, ph_lambda=d.ph_lambda,
                         cusum_k=d.cusum_k, cusum_h=d.cusum_h)
    retune_kw = dict(design=getattr(plan, "design", None),
                     n_starts=d.retune_starts, steps=d.retune_steps,
                     seed=d.retune_seed)

    # -- oracle: the whole schedule's nominal tunings in one storm ----------
    oracle_arms = [a for a in plan.arms if a.arm == "oracle"]
    oracle_tunings: Dict[Tuple[int, int], object] = {}
    if oracle_arms:
        widxs = sorted({a.widx for a in oracle_arms})
        reqs = [RetuneRequest(w=plan.schedules[w][s], rho=0.0,
                              reason="oracle")
                for w in widxs for s in range(S)]
        sols = retune_fleet(reqs, plan.sys, **retune_kw)
        for (w, s), tr in zip(((w, s) for w in widxs for s in range(S)),
                              sols):
            oracle_tunings[(w, s)] = tr

    # -- deploy: per-workload shared key population, one tree per arm -------
    keys: Dict[int, np.ndarray] = {}
    sessions: Dict[Tuple[int, str], OnlineSession] = {}
    for a in plan.arms:
        if a.widx not in keys:
            keys[a.widx] = draw_keys(d.n_keys, seed=d.key_seed + a.widx,
                                     key_space=d.key_space)
        tuning = oracle_tunings[(a.widx, 0)] if a.arm == "oracle" \
            else a.tuning
        tree = LSMTree.from_phi(tuning.phi, plan.sys,
                                expected_entries=d.n_keys,
                                entry_bytes=d.entry_bytes, policy=a.policy,
                                policy_params=a.policy_params)
        tree.obs_label = f"w{a.widx}.{a.arm}/{a.policy}"
        populate(tree, d.n_keys, key_space=d.key_space, keys=keys[a.widx])
        mode = {"online": "online", "oracle": "oracle"}.get(a.arm, "static")
        expected = plan.schedules[a.widx][0] if a.arm == "oracle" \
            else plan.expected[a.widx]
        sessions[(a.widx, a.arm)] = OnlineSession(
            tree, expected=expected, rho=a.rho, sys=plan.sys, mode=mode,
            policy=policy, phi=tuning.phi,
            estimator=make_estimator(d.estimator, alpha=d.alpha,
                                     window=d.window),
            capacity=d.capacity, f_a=d.f_a, f_seq=d.f_seq)

    # -- the segment loop ---------------------------------------------------
    regret: Dict[int, List[dict]] = {w: [] for w in keys}
    for s in range(S):
        if s > 0:
            for a in oracle_arms:
                sessions[(a.widx, a.arm)].apply(
                    oracle_tunings[(a.widx, s)],
                    w_center=plan.schedules[a.widx][s], rho=0.0,
                    reason="oracle")
        for widx in sorted(keys):
            mix = plan.schedules[widx][s]
            rec = None
            if adversary is not None:
                # attack the preferred deployed arm's live state; every arm
                # then executes the attacked mix (the comparison stays
                # paired — same keys, same session plan)
                from repro.scenarios.adversary import DEFENDER_ORDER
                defender_arm = next(arm for arm in DEFENDER_ORDER
                                    if (widx, arm) in sessions)
                defender = sessions[(widx, defender_arm)]
                mix, rec = adversary.attack(defender.phi, defender.expected,
                                            defender.rho, plan.sys)
            nq = d.n_queries
            extra = {}
            if scenario is not None:
                nq = int(scenario.segment_queries(s))
                extra = dict(scenario.session_kwargs(s, len(keys[widx])))
            rf = float(extra.pop("range_fraction", d.range_fraction))
            splan = materialize_session(
                keys[widx], mix, n_queries=nq,
                seed=d.session_seed + widx * S + s, key_space=d.key_space,
                range_fraction=rf, **extra)
            for a in plan.arms:
                if a.widx == widx:
                    sessions[(widx, a.arm)].execute_segment(splan, mix, s)
            if rec is not None:
                rec["segment"] = s
                rec["widx"] = widx
                rec["defender"] = defender_arm
                rec["measured_io"] = float(
                    defender.records[-1].avg_io_per_query)
                regret[widx].append(rec)
            keys[widx] = np.concatenate([keys[widx], splan.insert_keys])
        fired = [(key, req) for key, sess in sessions.items()
                 for req in [sess.take_request()] if req is not None]
        if fired and s < S - 1:        # a swap after the last segment is moot
            sols = retune_fleet([req for _, req in fired], plan.sys,
                                **retune_kw)
            for (key, req), tr in zip(fired, sols):
                sessions[key].apply(tr, w_center=req.w, rho=req.rho,
                                    reason=req.reason)

    results = {key: DriftArmResult(widx=key[0], arm=key[1],
                                   records=sess.records)
               for key, sess in sessions.items()}
    return results, {w: r for w, r in regret.items() if r}
