from .mesh import (axis_size, data_axes, make_host_mesh, make_mesh,
                   make_production_mesh)

__all__ = ["axis_size", "data_axes", "make_host_mesh", "make_mesh",
           "make_production_mesh"]
