"""Sharding rules: parameter/optimizer/cache PartitionSpecs for any arch.

Scheme (MaxText-style 2-D "fsdp + tensor" sharding):
  * batch dims  -> ("pod", "data")        (pod is extra data parallelism)
  * TP dims     -> "model" (heads, d_ff, experts, mamba inner, vocab)
  * FSDP dims   -> "data" (the non-TP axis of every large weight)
Optimizer state inherits the parameter specs (ZeRO-1 by construction).

Head counts that do not divide the model axis (qwen3's 40 heads, rwkv's 40
heads, whisper's 8) still shard — GSPMD pads uneven dims; the padding waste
is noted in EXPERIMENTS.md.  Expert counts shard on "model" only when they
divide it (EP); otherwise experts stay replicated and their FFN widths go
tensor-parallel.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from .mesh import axis_size, data_axes


def _div(n: int, mesh, axis: str) -> bool:
    return n % axis_size(mesh, axis) == 0


def param_spec(path: Tuple[str, ...], shape: Tuple[int, ...],
               cfg: ModelConfig, mesh) -> P:
    """PartitionSpec for one parameter leaf, identified by its tree path."""
    names = [str(p) for p in path]
    name = names[-1]
    stacked = "layers" in names or name in ("enc_layers", "dec_layers") or \
        ("enc_layers" in names or "dec_layers" in names)
    fsdp = "data" if cfg.fsdp_params else None
    model = "model"
    in_moe = any(n in ("wi_gate", "wi_up", "wo") for n in names[-1:]) and \
        any(n == "mlp" or n == "shared" for n in names) and cfg.moe is not None

    def v_axis(V: int) -> Optional[str]:
        return model if (cfg.shard_vocab and _div(V, mesh, model)) else None

    base: Optional[Tuple] = None
    dims = len(shape) - (1 if stacked else 0)
    core = shape[1:] if stacked else shape

    if name in ("embed", "embed_out"):
        base = (v_axis(core[0]), fsdp)
    elif name == "lm_head":
        base = (fsdp, v_axis(core[1]))
    elif name in ("adapter", "frontend"):
        base = (None, model)
    elif name in ("scale", "bias", "w_base", "dt_bias", "D", "conv_b",
                  "ln_out") or name.startswith("mu_"):
        base = (model,) if (dims == 1 and _div(core[0], mesh, model)
                            and core[0] >= 256) else (None,) * dims
    elif name == "wq":
        # shard heads when divisible, else head_dim (always /16 across archs)
        h_ok = _div(core[1], mesh, model)
        base = (fsdp, model, None) if h_ok else (fsdp, None, model)
    elif name in ("wk", "wv") and dims == 3:
        h_ok = _div(core[1], mesh, model)
        base = (fsdp, model, None) if h_ok else (fsdp, None, model)
    elif name == "wo" and dims == 3 and not in_moe:
        h_ok = _div(core[0], mesh, model)
        base = (model, None, fsdp) if h_ok else (None, model, fsdp)
    elif name in ("bq", "bk", "bv"):
        h_ok = _div(core[0], mesh, model)
        base = (model, None) if h_ok else (None, model)
    elif name in ("q_norm", "k_norm"):
        base = (None,)
    elif name == "u":
        base = (model, None)
    elif name == "router":
        base = (None, None)
    elif name in ("wi_gate", "wi_up") and dims == 3:  # moe experts (E, d, ef)
        ep = _div(core[0], mesh, model)
        base = (model, fsdp, None) if ep else (None, fsdp, model)
    elif name == "wo" and dims == 3:                  # moe (E, ef, d)
        ep = _div(core[0], mesh, model)
        base = (model, None, fsdp) if ep else (None, model, fsdp)
    elif name in ("wi_gate", "wi_up", "wi", "wk") and dims == 2:
        base = (fsdp, model)
    elif name in ("wo", "wv") and dims == 2:
        base = (model, fsdp)
    elif name in ("wr", "wg") and dims == 2:          # rwkv square proj
        base = (fsdp, model)
    elif name == "w_A":
        base = (fsdp, None)
    elif name == "w_B":
        base = (None, model)
    elif name == "in_proj":
        base = (fsdp, model)
    elif name == "conv_w":
        base = (None, model)
    elif name == "x_proj":
        base = (model, None)
    elif name == "dt_proj":
        base = (None, model)
    elif name == "A_log":
        base = (model, None)
    elif name == "out_proj":
        base = (model, fsdp)
    if base is None:
        base = (None,) * dims

    # Guard: jit in_shardings require exact divisibility — drop any axis the
    # mesh cannot divide evenly (GSPMD padding is not allowed on arguments).
    checked = []
    for ax, n in zip(base, core):
        checked.append(ax if (ax is not None and _div(n, mesh, ax))
                       else None)
    base = tuple(checked)
    return P(*(((None,) + base) if stacked else base))


def param_shardings(params_spec_tree: Any, cfg: ModelConfig, mesh):
    """NamedShardings matching a params (or eval_shape'd params) pytree."""
    def one(path, leaf):
        keys = tuple(getattr(p, "key", getattr(p, "idx", p)) for p in path)
        return NamedSharding(mesh, param_spec(keys, leaf.shape, cfg, mesh))
    return jax.tree_util.tree_map_with_path(one, params_spec_tree)


# ---------------------------------------------------------------------------
# Activation / batch / cache shardings
# ---------------------------------------------------------------------------

def batch_shardings(batch_spec: Dict[str, Any], cfg: ModelConfig, mesh,
                    shape: ShapeConfig):
    """Input shardings for a train/prefill batch dict."""
    dp = data_axes(mesh)
    seq_ax = None
    if shape.global_batch % int(np.prod([axis_size(mesh, a)
                                         for a in dp])) != 0:
        # batch==1 long-context: shard sequence instead (SP)
        dp, seq_ax = (), "data"

    def spec(k, leaf):
        nd = len(leaf.shape)
        if k == "positions":          # (3, B, S)
            return P(None, dp or None, seq_ax)
        if k == "cache":
            return None
        lead = dp or None
        if nd == 2:                   # tokens/labels (B, S)
            return P(lead, seq_ax)
        if nd == 3:                   # embeds (B, S, d)
            return P(lead, seq_ax, None)
        return P(*([None] * nd))

    out = {}
    for k, v in batch_spec.items():
        out[k] = jax.tree.map(
            lambda leaf, kk=k: NamedSharding(mesh, spec(kk, leaf)), v)
    return out


def cache_shardings(cache_spec: Any, cfg: ModelConfig, mesh,
                    shape: ShapeConfig):
    """Decode-cache shardings.

    Regular decode: batch over (pod,data), kv-heads over model (padded when
    uneven).  long-context batch=1 decode: sequence-parallel — the KV cache
    S axis shards over "data" (flash-decode with logsumexp combine happens
    inside XLA's partitioned softmax; see DESIGN.md SP notes).
    """
    dp = data_axes(mesh)
    n_dp = int(np.prod([axis_size(mesh, a) for a in dp]))
    sp = shape.global_batch % n_dp != 0  # can't shard batch -> shard seq

    def spec(path, leaf):
        names = [str(getattr(p, "key", p)) for p in path]
        name = names[-1]
        nd = len(leaf.shape)
        stacked = "layers" in names or "self" in names or \
            name.startswith("cross")
        lead = (None,) if stacked else ()
        core = leaf.shape[1:] if stacked else leaf.shape

        def ax_div(dim_idx: int, ax: str):
            return ax if _div(core[dim_idx], mesh, ax) else None

        if name in ("k", "v") or name.startswith("cross"):
            # (B, S, KV, hd): kv-heads over model when divisible, else hd
            kv_ax = ax_div(2, "model")
            hd_ax = None if kv_ax else ax_div(3, "model")
            if sp:  # batch=1 long context: sequence-parallel cache
                return P(*lead, None, ax_div(1, "data"), kv_ax, hd_ax)
            b_ax = dp if core[0] % n_dp == 0 else None
            return P(*lead, b_ax or None, None, kv_ax, hd_ax)
        b = None if sp else ((dp if core[0] % n_dp == 0 else None) or None)
        if name == "state":      # rwkv (B, H, n, n)
            h_ax = ax_div(1, "model")
            n_ax = None if h_ax else ax_div(2, "model")
            return P(*lead, b, h_ax, n_ax, None)
        if name == "ssm":        # mamba (B, di, ds)
            return P(*lead, b, ax_div(1, "model"), None)
        if name == "conv":       # mamba (B, dc-1, di)
            return P(*lead, b, None, ax_div(2, "model"))
        if name == "x_prev":
            return P(*lead, b, *([None] * (len(core) - 1)))
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, spec(p, l)), cache_spec)
