import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first: jax locks the device count at first
init, and the dry-run needs 512 placeholder CPU devices to build the
production meshes ((16,16) single pod; (2,16,16) two pods).  Smoke tests and
benchmarks must NOT import this module (they want 1 device).

Per cell this script:
  1. builds the step function (train_step / prefill_step / decode_step),
  2. lowers with ShapeDtypeStruct inputs + NamedShardings (no allocation),
  3. compiles, prints memory_analysis() (proves it fits) and
     cost_analysis(), and
  4. runs the HLO analyzer (utils/hlo.py) for while-corrected FLOPs/bytes
     and per-axis collective bytes -> roofline terms (utils/roofline.py).

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json and are
aggregated into EXPERIMENTS.md by benchmarks/bench_roofline.py.

CLI:
  PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b \
      --shape train_4k --mesh single            # one cell
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single
  ... --set remat=none --set logits_chunk=8192  # hillclimb overrides
"""

import argparse
import dataclasses
import json
import pathlib
import time
import traceback
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_config, shape_applicable
from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import build_model
from repro.optim import adamw
from repro.utils import hlo as hlo_mod
from repro.utils import roofline
from .mesh import make_production_mesh
from .sharding import batch_shardings, cache_shardings, param_shardings

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _apply_overrides(cfg: ModelConfig, overrides: Dict[str, str]
                     ) -> ModelConfig:
    kw: Dict[str, Any] = {}
    for k, v in overrides.items():
        field = {f.name: f for f in dataclasses.fields(cfg)}[k]
        if field.type in ("int", int):
            kw[k] = int(v)
        elif field.type in ("bool", bool):
            kw[k] = v.lower() in ("1", "true", "yes")
        elif field.type in ("float", float):
            kw[k] = float(v)
        else:
            kw[k] = v
    return cfg.replace(**kw)


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh):
    """-> (jitted fn lowered-ready, example input specs tuple)."""
    api = build_model(cfg)
    pspecs = api.param_specs()
    pshard = param_shardings(pspecs, cfg, mesh)

    if shape.kind == "train":
        opt_cfg = adamw.AdamWConfig()

        def train_step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                api.loss_fn, has_aux=True)(params, batch)
            params, opt_state, om = adamw.update(grads, opt_state, params,
                                                 opt_cfg)
            return params, opt_state, {"loss": loss, **om}

        ospec = jax.eval_shape(adamw.init, pspecs)
        oshard = adamw.AdamWState(
            step=NamedSharding(mesh, P()),
            mu=param_shardings(ospec.mu, cfg, mesh),
            nu=param_shardings(ospec.nu, cfg, mesh))
        bspecs = api.input_specs(shape)
        bshard = batch_shardings(bspecs, cfg, mesh, shape)
        fn = jax.jit(train_step,
                     in_shardings=(pshard, oshard, bshard),
                     out_shardings=(pshard, oshard, None),
                     donate_argnums=(0, 1))
        return fn, (pspecs, ospec, bspecs)

    if shape.kind == "prefill":
        bspecs = api.input_specs(shape)
        bshard = batch_shardings(bspecs, cfg, mesh, shape)
        fn = jax.jit(api.prefill, in_shardings=(pshard, bshard))
        return fn, (pspecs, bspecs)

    # decode
    specs = api.input_specs(shape)
    cshard = cache_shardings(specs["cache"], cfg, mesh, shape)
    if isinstance(specs["tokens"], jax.ShapeDtypeStruct) and \
            specs["tokens"].dtype == jnp.int32:
        tshard = NamedSharding(mesh, P(None, None))
    else:
        tshard = NamedSharding(mesh, P(None, None, None))
    fn = jax.jit(api.decode_step,
                 in_shardings=(pshard, cshard, tshard,
                               NamedSharding(mesh, P())),
                 out_shardings=None,
                 donate_argnums=(1,))
    return fn, (pspecs, specs["cache"], specs["tokens"], specs["pos"])


def _make_mesh(mesh_kind: str):
    """'single' | 'multipod' | 'DxM' custom (data, model) single-pod mesh."""
    if mesh_kind == "single":
        return make_production_mesh()
    if mesh_kind == "multipod":
        return make_production_mesh(multi_pod=True)
    d, m = (int(x) for x in mesh_kind.split("x"))
    return jax.make_mesh((d, m), ("data", "model"))


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             overrides: Optional[Dict[str, str]] = None,
             tag: str = "baseline", save: bool = True,
             verbose: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch)
    if overrides:
        cfg = _apply_overrides(cfg, overrides)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    record: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "tag": tag,
        "overrides": overrides or {},
    }
    if not ok:
        record["status"] = "skipped"
        record["reason"] = why
        if verbose:
            print(f"[skip] {arch} x {shape_name}: {why}")
        if save:
            _save(record)
        return record

    mesh = _make_mesh(mesh_kind)
    chips = int(np.prod(mesh.devices.shape))
    t0 = time.time()
    try:
        with mesh:
            fn, specs = build_cell(cfg, shape, mesh)
            lowered = fn.lower(*specs)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo_text = compiled.as_text()
    except Exception as e:
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(f"[FAIL] {arch} x {shape_name} x {mesh_kind}: "
                  f"{record['error'][:300]}")
        if save:
            _save(record)
        return record

    mem_gb = None
    if mem is not None:
        per_dev = (getattr(mem, "argument_size_in_bytes", 0)
                   + getattr(mem, "temp_size_in_bytes", 0)
                   + getattr(mem, "output_size_in_bytes", 0)
                   - getattr(mem, "alias_size_in_bytes", 0))
        mem_gb = per_dev / 1e9
        record["memory_analysis"] = {
            "argument_gb": getattr(mem, "argument_size_in_bytes", 0) / 1e9,
            "temp_gb": getattr(mem, "temp_size_in_bytes", 0) / 1e9,
            "output_gb": getattr(mem, "output_size_in_bytes", 0) / 1e9,
            "alias_gb": getattr(mem, "alias_size_in_bytes", 0) / 1e9,
            "total_live_gb": mem_gb,
        }

    costs_raw = hlo_mod.analyze_hlo(hlo_text, mesh.devices.shape,
                                    mesh.axis_names,
                                    default_trip=cfg.n_repeats)
    # XLA CPU float-normalizes bf16->f32; correct bytes back to the TPU
    # target dtype (raw numbers are recorded alongside).
    costs = costs_raw.bf16_corrected() if cfg.dtype == "bfloat16" \
        else costs_raw
    terms = roofline.terms_from_hlo(arch, shape, mesh_kind, chips, costs,
                                    cfg, memory_per_dev_gb=mem_gb)
    record.update({
        "status": "ok",
        "chips": chips,
        "lower_s": t_lower,
        "compile_s": t_compile,
        "cost_analysis_raw": {k: float(v) for k, v in (cost or {}).items()
                              if isinstance(v, (int, float))
                              and k in ("flops", "bytes accessed")},
        "hlo": {
            "flops_per_dev": costs.flops,
            "bytes_per_dev": costs.bytes,
            "bytes_per_dev_raw_f32normalized": costs_raw.bytes,
            "collective_bytes_by_axis": costs.collective_bytes_by_axis,
            "collective_bytes_raw": costs_raw.collective_bytes,
            "collective_count": costs.collective_count,
            "while_trips": costs.while_trips,
        },
        "roofline": dataclasses.asdict(terms),
    })
    if verbose:
        print(f"[ok] {arch} x {shape_name} x {mesh_kind} "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s) "
              f"mem/dev={mem_gb if mem_gb is None else round(mem_gb, 2)}GB")
        print(f"     compute {terms.compute_s*1e3:.2f}ms "
              f"memory {terms.memory_s*1e3:.2f}ms "
              f"collective {terms.collective_s*1e3:.2f}ms "
              f"-> {terms.bottleneck}-bound, useful={terms.useful_ratio:.2f}")
    if save:
        _save(record)
    return record


def _save(record: Dict[str, Any]) -> None:
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    tag = record.get("tag", "baseline")
    name = f"{record['arch']}__{record['shape']}__{record['mesh']}__{tag}.json"
    (OUT_DIR / name).write_text(json.dumps(record, indent=1, default=float))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    help="single | multipod | both | DxM (e.g. 32x8)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override k=v (e.g. remat=none)")
    args = ap.parse_args()
    overrides = dict(kv.split("=", 1) for kv in args.set) or None

    meshes = ["single", "multipod"] if args.mesh == "both" else [args.mesh]
    archs = sorted(ARCHS) if args.all or args.arch is None else [args.arch]
    shapes = sorted(SHAPES) if args.all or args.shape is None \
        else [args.shape]

    failures = 0
    for m in meshes:
        for a in archs:
            for s in shapes:
                rec = run_cell(a, s, m, overrides=overrides, tag=args.tag)
                failures += rec["status"] == "error"
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
