"""Failure / straggler / elasticity policy for 1000+-node runs.

This module is deliberately *pure policy* — decisions are computed from
heartbeat tables and timing stats so they can be unit-tested on CPU; the
cluster-facing actuation (killing a pod, relaunching with a new mesh) is the
thin launcher loop in train.py that consumes these decisions.

Mechanisms:
* step-granular checkpoints with the data cursor inside (exactly-once),
* deterministic data re-sharding (data/pipeline.py) so surviving workers
  re-derive a lost worker's batches without coordination,
* straggler ejection by robust z-score on per-step times,
* elastic remesh: the largest (data x model) mesh that fits the survivors,
  keeping the model axis fixed (weight layout preserved; see
  CheckpointStore.restore's re-shard-on-load path).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class ElasticPolicy:
    heartbeat_timeout_s: float = 120.0
    straggler_zscore: float = 4.0
    min_data_parallel: int = 1
    checkpoint_interval: int = 100


def dead_workers(heartbeats: Dict[int, Dict], now: float, num_workers: int,
                 policy: ElasticPolicy) -> List[int]:
    """Workers whose last heartbeat is too old (or missing entirely)."""
    dead = []
    for w in range(num_workers):
        hb = heartbeats.get(w)
        if hb is None or (now - float(hb["t"])) > policy.heartbeat_timeout_s:
            dead.append(w)
    return dead


def stragglers(step_times: Dict[int, Sequence[float]],
               policy: ElasticPolicy) -> List[int]:
    """Robust z-score on median per-worker step time (MAD-based)."""
    med = {w: _median(list(ts)) for w, ts in step_times.items() if ts}
    if len(med) < 3:
        return []
    vals = sorted(med.values())
    m = _median(vals)
    mad = _median([abs(v - m) for v in vals]) or 1e-9
    return [w for w, v in med.items()
            if (v - m) / (1.4826 * mad) > policy.straggler_zscore]


def _median(xs: List[float]) -> float:
    xs = sorted(xs)
    n = len(xs)
    return xs[n // 2] if n % 2 else 0.5 * (xs[n // 2 - 1] + xs[n // 2])


def remesh(num_alive: int, model_parallel: int,
           policy: ElasticPolicy) -> Optional[Tuple[int, int]]:
    """Largest (data, model) mesh over the survivors, model axis fixed.

    Returns None if survivors cannot host even the minimum mesh."""
    if num_alive < model_parallel * policy.min_data_parallel:
        return None
    data = num_alive // model_parallel
    return (data, model_parallel)


def reshard_plan(old_shards: int, new_shards: int,
                 global_batch: int) -> Dict[int, List[int]]:
    """Which old data-shard ranges each new shard re-derives.

    Because batches are pure functions of (seed, step, shard), the 'plan' is
    informational — workers just switch shard ids; this mapping is used to
    verify coverage in tests."""
    assert global_batch % new_shards == 0
    per_new = global_batch // new_shards
    per_old = global_batch // old_shards
    plan: Dict[int, List[int]] = {}
    for ns in range(new_shards):
        lo, hi = ns * per_new, (ns + 1) * per_new
        plan[ns] = sorted({i // per_old for i in range(lo, hi)})
    return plan


@dataclasses.dataclass
class RunSupervisor:
    """Tracks run health; the launcher queries `decide` each step."""
    num_workers: int
    model_parallel: int
    policy: ElasticPolicy = ElasticPolicy()
    step_times: Dict[int, List[float]] = dataclasses.field(
        default_factory=dict)

    def record_step(self, worker: int, seconds: float) -> None:
        self.step_times.setdefault(worker, []).append(seconds)

    def decide(self, heartbeats: Dict[int, Dict], now: float) -> Dict:
        dead = dead_workers(heartbeats, now, self.num_workers, self.policy)
        slow = [w for w in stragglers(self.step_times, self.policy)
                if w not in dead]
        alive = self.num_workers - len(dead) - len(slow)
        action: Dict = {"dead": dead, "stragglers": slow, "action": "none"}
        if dead or slow:
            new_mesh = remesh(alive, self.model_parallel, self.policy)
            if new_mesh is None:
                action["action"] = "halt"
            else:
                action["action"] = "restart_from_checkpoint"
                action["new_mesh"] = new_mesh
        return action
