"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and only then builds meshes.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh for tests/examples (e.g. (1, 1) on one CPU device)."""
    return jax.make_mesh(shape, axes)


def make_problem_mesh():
    """A 1-D mesh over every visible device, axis name ``problem``.

    The sweep-sharding mesh: batched-tuner grids (``core.batch.solve_grid``)
    flatten the (workload x rho) cross product onto one problem axis, and a
    ``NamedSharding(mesh, P("problem"))`` on the inputs lets XLA partition
    the independent vmap lanes device-parallel (see
    ``repro.api.backends.ShardedBackend``)."""
    return jax.make_mesh((len(jax.devices()),), ("problem",))


def make_host_mesh(model: int = 1):
    """A mesh over however many devices this host actually has."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return jax.make_mesh((n // model, model), ("data", "model"))


def data_axes(mesh) -> Tuple[str, ...]:
    """Axes that carry the batch dimension (pod + data when present)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def axis_size(mesh, name: str) -> int:
    names = mesh.axis_names
    if name not in names:
        return 1
    return mesh.devices.shape[names.index(name)]
