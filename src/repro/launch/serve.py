"""Batched serving driver: prefill a batch of prompts, then decode greedily.

Completes the launcher family (train.py / dryrun.py / serve.py).  On one CPU
device this serves reduced configs end-to-end (examples, tests); the
production-mesh serving path is exercised by the decode cells of the
dry-run and the robust layout selection in core/robust_sharding.py.

CLI:  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b \
          --reduced --batch 4 --prompt-len 16 --gen 24
"""

from __future__ import annotations

import argparse
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model


def pad_cache_to(cache, api, batch: int, max_seq: int):
    """Pad a prefill cache out to decode capacity (attention KV only)."""
    full = api.init_cache(batch, max_seq)

    def pad(c, f):
        if c.shape == f.shape:
            return c.astype(f.dtype)
        pads = [(0, fs - cs) for cs, fs in zip(c.shape, f.shape)]
        return jnp.pad(c, pads).astype(f.dtype)

    return jax.tree.map(pad, cache, full)


def serve_batch(arch: str, reduced: bool = True, batch: int = 4,
                prompt_len: int = 16, gen: int = 16, seed: int = 0,
                greedy: bool = True) -> Dict[str, np.ndarray]:
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    max_seq = prompt_len + gen

    prompts = rng.integers(0, cfg.vocab_size, (batch, prompt_len))
    batch_in: Dict[str, jnp.ndarray] = {}
    if cfg.encoder is not None:
        d_in = cfg.encoder.d_input or cfg.d_model
        batch_in["embeds"] = jnp.asarray(
            rng.normal(size=(batch, prompt_len, d_in)), jnp.float32)
        batch_in["tokens"] = jnp.asarray(prompts, jnp.int32)
    elif cfg.embed_inputs:
        batch_in["tokens"] = jnp.asarray(prompts, jnp.int32)
    else:
        batch_in["embeds"] = jnp.asarray(
            rng.normal(size=(batch, prompt_len, cfg.d_model)), jnp.float32)

    t0 = time.time()
    prefill = jax.jit(api.prefill)
    logits, cache = prefill(params, batch_in)
    cache = pad_cache_to(cache, api, batch, max_seq)
    t_prefill = time.time() - t0

    decode = jax.jit(api.decode_step)
    out_tokens = np.zeros((batch, gen), np.int32)
    next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)

    t0 = time.time()
    for i in range(gen):
        out_tokens[:, i] = np.asarray(next_tok)
        if cfg.embed_inputs or cfg.encoder is not None:
            step_in = next_tok[:, None]
        else:  # stub-embedding archs: feed the token's output embedding
            step_in = jnp.take(params["embed_out"], next_tok,
                               axis=0)[:, None, :]
        logits, cache = decode(params, cache, step_in,
                               jnp.asarray(prompt_len + i, jnp.int32))
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    t_decode = time.time() - t0

    return {"tokens": out_tokens, "prefill_s": t_prefill,
            "decode_s": t_decode,
            "tok_per_s": batch * gen / max(t_decode, 1e-9)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()
    out = serve_batch(args.arch, args.reduced, args.batch, args.prompt_len,
                      args.gen)
    print(f"prefill {out['prefill_s']:.2f}s  decode {out['decode_s']:.2f}s "
          f"({out['tok_per_s']:.1f} tok/s)")
    print("first sequences:", out["tokens"][:2, :12].tolist())


if __name__ == "__main__":
    main()
