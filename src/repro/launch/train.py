"""Training driver: mesh + sharded train step + checkpoint/restart loop.

Runs end-to-end on one CPU device (examples, tests) and lowers/compiles for
the production meshes (dry-run).  Fault tolerance: step-granular checkpoints
carrying the data cursor, heartbeats into the LSM manifest, and an elastic
supervisor that decides restart/remesh on failure (see elastic.py).

CLI:  PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b \
          --reduced --steps 50 --ckpt-dir /tmp/ck --mesh 1x1
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.pipeline import DataConfig, DataState, shard_batch_at
from repro.models import build_model
from repro.optim import adamw
from .mesh import make_mesh
from .sharding import batch_shardings, param_shardings


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 50
    ckpt_interval: int = 20
    lr: float = 3e-4
    warmup: int = 10
    seed: int = 0
    aux_weight: float = 0.01
    grad_compression: str = "none"  # none|int8 (pod-axis mean)
    log_interval: int = 10


def make_train_step(api, opt_cfg: adamw.AdamWConfig, cfg: ModelConfig):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            api.loss_fn, has_aux=True)(params, batch)
        params, opt_state, om = adamw.update(grads, opt_state, params,
                                             opt_cfg)
        metrics = dict(metrics, loss=loss, **om)
        return params, opt_state, metrics

    return step


def jit_train_step(api, opt_cfg, mesh, shape: ShapeConfig):
    cfg = api.cfg
    step = make_train_step(api, opt_cfg, cfg)
    pspecs = api.param_specs()
    pshard = param_shardings(pspecs, cfg, mesh)
    ostate_spec = jax.eval_shape(adamw.init, pspecs)
    oshard = adamw.AdamWState(
        step=NamedSharding(mesh, P()),
        mu=param_shardings(ostate_spec.mu, cfg, mesh),
        nu=param_shardings(ostate_spec.nu, cfg, mesh))
    bshard = batch_shardings(api.input_specs(shape), cfg, mesh, shape)
    return jax.jit(step,
                   in_shardings=(pshard, oshard, bshard),
                   out_shardings=(pshard, oshard, None),
                   donate_argnums=(0, 1)), pshard, oshard, bshard


def train_loop(arch: str, reduced: bool, steps: int, mesh_shape=(1, 1),
               ckpt_dir: Optional[str] = None, resume: bool = False,
               seq_len: int = 64, global_batch: int = 8,
               tc: TrainConfig = TrainConfig(), worker: int = 0,
               num_workers: int = 1) -> Dict[str, Any]:
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    api = build_model(cfg)
    mesh = make_mesh(mesh_shape, ("data", "model"))
    shape = ShapeConfig("train_cli", seq_len, global_batch, "train")
    opt_cfg = adamw.AdamWConfig(
        lr=tc.lr, schedule=adamw.cosine_schedule(tc.warmup, steps))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq_len,
                      global_batch=global_batch, seed=tc.seed)

    with mesh:
        jstep, pshard, oshard, bshard = jit_train_step(api, opt_cfg, mesh,
                                                       shape)
        store = None
        data_state = DataState()
        if ckpt_dir is not None:
            from repro.checkpoint.store import CheckpointStore
            store = CheckpointStore.create(
                ckpt_dir, ckpt_interval=tc.ckpt_interval)
        if resume and store is not None and store.latest_step() is not None:
            pspecs = api.param_specs()
            params, meta = store.restore(pspecs, shardings=pshard)
            opt_state = store.restore_opt_state(
                jax.eval_shape(adamw.init, pspecs))
            opt_state = jax.device_put(opt_state, oshard)
            data_state = DataState.from_dict(meta["data_state"])
            start = int(meta["step"]) + 1
        else:
            params = jax.jit(api.init, out_shardings=pshard)(
                jax.random.PRNGKey(tc.seed))
            opt_state = jax.jit(adamw.init, out_shardings=oshard)(params)
            start = 0

        losses = []
        t_start = time.time()
        for s in range(start, steps):
            batch_np = shard_batch_at(dcfg, data_state.step, 0, 1)
            batch = _prep_batch(batch_np, api, bshard)
            t0 = time.time()
            params, opt_state, metrics = jstep(params, opt_state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            data_state.step += 1
            if store is not None:
                store.heartbeat(worker, s, time.time())
                if (s + 1) % tc.ckpt_interval == 0 or s == steps - 1:
                    store.save(s, params, opt_state,
                               data_state=data_state.to_dict())
            if s % tc.log_interval == 0 or s == steps - 1:
                print(f"step {s:5d} loss {loss:8.4f} "
                      f"gnorm {float(metrics['grad_norm']):7.3f} "
                      f"({time.time()-t0:.2f}s)")
        wall = time.time() - t_start
        return {"losses": losses, "params": params, "opt_state": opt_state,
                "wall": wall, "api": api, "store": store}


def _prep_batch(batch_np: Dict[str, np.ndarray], api, bshard):
    cfg = api.cfg
    batch: Dict[str, Any] = {}
    if cfg.encoder is not None:
        B, S = batch_np["tokens"].shape
        d_in = cfg.encoder.d_input or cfg.d_model
        rng = np.random.default_rng(int(batch_np["tokens"][0, 0]) + 17)
        batch["embeds"] = rng.normal(size=(B, S, d_in)).astype(np.float32)
        batch["tokens"] = batch_np["tokens"]
        batch["labels"] = batch_np["labels"]
    elif cfg.embed_inputs:
        batch = dict(batch_np)
    else:
        B, S = batch_np["tokens"].shape
        rng = np.random.default_rng(int(batch_np["tokens"][0, 0]) + 17)
        batch["embeds"] = rng.normal(size=(B, S, cfg.d_model)).astype(
            np.float32)
        if cfg.mrope_sections is not None:
            base = np.broadcast_to(np.arange(S)[None], (B, S))
            batch["positions"] = np.broadcast_to(base[None],
                                                 (3, B, S)).astype(np.int32)
        batch["labels"] = batch_np["labels"]
    return jax.tree.map(
        lambda a, s: jax.device_put(jnp.asarray(a), s), batch, bshard)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--mesh", default="1x1", help="DATAxMODEL, e.g. 1x1")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()
    d, m = (int(x) for x in args.mesh.split("x"))
    out = train_loop(args.arch, args.reduced, args.steps,
                     mesh_shape=(d, m), ckpt_dir=args.ckpt_dir,
                     resume=args.resume, seq_len=args.seq_len,
                     global_batch=args.global_batch)
    print(f"final loss {out['losses'][-1]:.4f}  wall {out['wall']:.1f}s")


if __name__ == "__main__":
    main()
