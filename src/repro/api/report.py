"""One report schema for every experiment.

A :class:`Report` is the single result tree a compiled experiment produces:
the tunings of every (workload, rho) cell and policy arm, the model cost
vectors next to the engine-measured ones, Delta-throughput metrics, and the
phase wall times — serialized in exactly the ``BENCH_<suite>.json`` schema
that ``benchmarks/run.py --check`` gates on::

    {"suite": <name>, "wall_time_s": <float>, "error": null,
     "rows": [{"name": ..., "us_per_call": ..., "derived": {...}}, ...],
     "checksum": "sha256:..."}

The ``checksum`` field (sha256 over the canonical payload minus itself,
:func:`repro.faults.payload_checksum`) plus tmp-file + ``os.replace``
writes make every emitted baseline crash-safe: a driver killed mid-write
can no longer leave a torn ``BENCH_<suite>.json`` that the perf gate then
trusts — ``--check`` validates the checksum and rejects an invalid
baseline as *misconfigured* (exit 2), not a phantom regression.

The row/formatting layer the benchmarks shared (:class:`Row`, strict-JSON
coercion, benchmark-set cost evaluation, Delta-throughput) lives here now;
``benchmarks/common.py`` re-exports it for the suites that predate the
facade.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np


class Row:
    """One CSV/JSON output row: name, us_per_call, derived metrics."""

    def __init__(self, name: str, us: float, **derived):
        self.name = name
        self.us = us
        self.derived = derived

    def csv(self) -> str:
        d = ";".join(f"{k}={v}" for k, v in self.derived.items())
        return f"{self.name},{self.us:.1f},{d}"


def timed(fn: Callable, *args, **kw) -> Tuple[float, object]:
    t0 = time.time()
    out = fn(*args, **kw)
    return (time.time() - t0) * 1e6, out


def fmt(x: float) -> str:
    return f"{x:.4g}"


def jsonable(x):
    """Best-effort conversion of derived metric values to *strict* JSON types
    (non-finite floats become null: consumers parse these files with strict
    parsers, which reject the bare NaN/Infinity literals json.dump emits)."""
    if isinstance(x, dict):
        return {str(k): jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [jsonable(v) for v in x]
    if isinstance(x, bool) or x is None:
        return x
    if hasattr(x, "item"):          # numpy / jax scalars
        try:
            return jsonable(x.item())
        except Exception:
            return str(x)
    if isinstance(x, float):
        return x if math.isfinite(x) else None
    if isinstance(x, (int, str)):
        return x
    return str(x)


def costs_over_benchmark(phi, sys, B: np.ndarray) -> np.ndarray:
    """C(w, phi) for every workload in a benchmark set (vectorized)."""
    from repro.core import cost_vector
    c = np.asarray(cost_vector(phi, sys), np.float64)
    return np.asarray(B, np.float64) @ c


def delta_tp(cn: np.ndarray, cr: np.ndarray) -> np.ndarray:
    """Normalized delta throughput of robust (cr) vs nominal (cn)."""
    return (1.0 / cr - 1.0 / cn) / (1.0 / cn)


# ---------------------------------------------------------------------------
# Structured results
# ---------------------------------------------------------------------------

#: A tuning cell: (workload_index_in_spec, rho) with rho=None for nominal.
Cell = Tuple[int, Optional[float]]


@dataclasses.dataclass
class TreeProbe:
    """Post-trial engine introspection, as plain data (so worker processes
    can ship it back without pickling live trees)."""

    shape: List[Tuple[int, List[int]]]
    last_level_runs: int
    flush_seq: int
    tomb_ages: List[int]                 # flush_seq - tomb_seq per live run
    dead_keys_resurfaced: int = 0
    intern_table_len: int = 0

    @property
    def max_tombstone_age(self) -> int:
        return max(self.tomb_ages, default=0)

    @classmethod
    def from_tree(cls, tree, dead_keys=None) -> "TreeProbe":
        ages = [tree.flush_seq - ts for lv in tree.store.levels
                for ts in lv.tomb_seqs if ts >= 0]
        shape = tree.shape()
        resurfaced = 0
        if dead_keys is not None and len(dead_keys):
            resurfaced = sum(tree.get(int(k)) is not None for k in dead_keys)
        return cls(shape=shape,
                   last_level_runs=len(shape[-1][1]) if shape else 0,
                   flush_seq=tree.flush_seq, tomb_ages=ages,
                   dead_keys_resurfaced=resurfaced,
                   intern_table_len=len(tree.store.codec.objects))


@dataclasses.dataclass
class Report:
    """The one result tree of an experiment.

    Everything is keyed by :data:`Cell` = (workload index within the spec,
    rho-or-None) and policy-arm name, in the deterministic cell order
    ``cells`` (nominal cells first, then the (workload-major, rho-minor)
    robust grid — the same flattening ``tune_robust_many`` uses)."""

    spec: Any                                 # the ExperimentSpec
    sys: Any                                  # resolved LSMSystem
    cells: List[Cell]
    tunings: Dict[Cell, Dict[str, Any]]       # cell -> arm -> TuningResult
    arm_costs: Dict[Cell, Dict[str, float]]   # exact objective per arm
    chosen: Dict[Cell, str]                   # joint policy-arm winner
    model_costs: Dict[Cell, Dict[str, np.ndarray]]  # c(effective phi), (4,)
    bench_costs: Dict[Cell, np.ndarray] = dataclasses.field(
        default_factory=dict)                 # C over benchmark set B
    bench_set: Optional[np.ndarray] = None
    fleet: Dict[Tuple[Cell, str], list] = dataclasses.field(
        default_factory=dict)                 # -> [SessionResult per session]
    probes: Dict[Tuple[Cell, str], TreeProbe] = dataclasses.field(
        default_factory=dict)
    #: the design-space axis (DesignSpec.spaces): space name -> cell ->
    #: TuningResult, and the matching benchmark-set costs
    design_tunings: Dict[str, Dict[Cell, Any]] = dataclasses.field(
        default_factory=dict)
    design_bench_costs: Dict[str, Dict[Cell, np.ndarray]] = \
        dataclasses.field(default_factory=dict)
    #: the drift experiment (ExperimentSpec.drift): (workload index, arm)
    #: -> repro.online.DriftArmResult
    drift: Dict[Tuple[int, str], Any] = dataclasses.field(
        default_factory=dict)
    #: adversary-scenario regret trace (DriftSpec.kind="adversary"):
    #: workload index -> per-segment records (attacked mix, its KL from the
    #: live center, nominal/realized model cost, the independently-solved
    #: KL dual bound, and the per-segment ``le_dual_bound`` verdict)
    regret: Dict[int, List[dict]] = dataclasses.field(default_factory=dict)
    #: the memory-arbitration experiment (ExperimentSpec.memory):
    #: (tenant index, fleet in repro.online.MEMORY_ARMS) -> DriftArmResult,
    #: plus the arbiter's division event log (initial division + every
    #: online re-division: segment, reasons, granted shares, re-tuned set)
    memory: Dict[Tuple[int, str], Any] = dataclasses.field(
        default_factory=dict)
    memory_events: List[dict] = dataclasses.field(default_factory=list)
    #: graceful degradation: trial trees whose shard exhausted every retry
    #: and re-shard attempt, keyed like ``fleet``, valued with the final
    #: error (worker stderr included) — the sweep completes with explicit
    #: holes instead of crashing (``docs/faults.md``).
    failed_cells: Dict[Tuple[Cell, str], str] = dataclasses.field(
        default_factory=dict)
    #: SubprocessBackend per-attempt log: one dict per worker launch
    #: ({"shard", "attempt", "ok", "latency_s"}), successes included — a
    #: shard that flapped (failed, then succeeded on retry) is visible
    #: here even though the sweep reported no failure.
    shard_attempts: List[dict] = dataclasses.field(default_factory=list)
    walls: Dict[str, float] = dataclasses.field(default_factory=dict)

    # -- accessors ----------------------------------------------------------

    def tuning(self, cell: Cell, policy: Optional[str] = None):
        arms = self.tunings[cell]
        return arms[policy or self.chosen[cell]]

    def measured_io(self, cell: Cell, policy: Optional[str] = None
                    ) -> np.ndarray:
        """avg I/O per query for every session of one deployed tree."""
        res = self.fleet[(cell, policy or self.chosen[cell])]
        return np.array([r.avg_io_per_query for r in res])

    def model_session_io(self, cell: Cell, sessions,
                         policy: Optional[str] = None) -> np.ndarray:
        """The cost model's prediction for each session mix (S,)."""
        c = self.model_costs[cell][policy or self.chosen[cell]]
        return np.atleast_2d(np.asarray(sessions, np.float64)) @ c

    def delta_tp_vs_nominal(self, widx: int, rho: float,
                            policy: Optional[str] = None) -> np.ndarray:
        """Model Delta-throughput of the robust cell vs its nominal baseline
        over the benchmark set B (requires ``bench_n`` > 0 in the spec)."""
        cn = self.bench_costs[(widx, None)]
        cr = self.bench_costs[(widx, rho)]
        return delta_tp(cn, cr)

    def memory_fleet_throughput(self, fleet: str) -> float:
        """Fleet-wide throughput of one memory arm (``"static"`` /
        ``"arbitrated"``): total queries over total measured I/O across
        every tenant — tenants serving more traffic weigh more, exactly
        like the per-tree query weighting."""
        recs = [rec for (_, arm), res in self.memory.items()
                if arm == fleet for rec in res.records]
        q = sum(r.queries for r in recs)
        io = sum(r.avg_io_per_query * r.queries for r in recs)
        return q / max(io, 1e-9)

    @property
    def wall_time_s(self) -> float:
        """Total of the phase timings (keys ending in ``_s``; other keys in
        ``walls`` are annotations, e.g. worker counts)."""
        return float(sum(v for k, v in self.walls.items()
                         if k.endswith("_s")))

    # -- rows / serialization ----------------------------------------------

    def rows(self) -> List[Row]:
        """The default row rendering: one row per cell (chosen arm, per-arm
        objective costs, measured-vs-model when a trial ran) plus a wall-time
        summary row — the generic ``--spec FILE.json`` output."""
        name = self.spec.name
        out: List[Row] = []
        for cell in self.cells:
            widx, rho = cell
            tag = f"w{widx}" if rho is None else f"w{widx}_rho{rho:g}"
            r = self.tuning(cell)
            derived = dict(
                chosen_policy=self.chosen[cell],
                design=r.design.value,
                tuning=r.describe(self.sys),
                cost=round(float(r.cost), 4),
                arm_costs={p: round(float(c), 4)
                           for p, c in self.arm_costs[cell].items()},
            )
            if (cell, self.chosen[cell]) in self.fleet:
                sessions = self.spec.trial.sessions
                measured = self.measured_io(cell)
                model = self.model_session_io(cell, sessions)
                derived.update(
                    measured_io=[round(float(x), 3) for x in measured],
                    model_io=[round(float(x), 3) for x in model],
                    agreement_ratio=round(
                        float(measured.mean() / model.mean()), 3),
                )
            out.append(Row(f"{name}_{tag}", 0.0, **derived))
        for (widx, arm), res in self.drift.items():
            last = res.records[-1]
            out.append(Row(
                f"{name}_drift_w{widx}_{arm}", 0.0,
                avg_io=round(res.avg_io_per_query, 4),
                throughput=round(res.throughput, 4),
                retunes=res.retunes,
                segments=len(res.records),
                final_kl=round(float(last.kl_est), 4),
                final_rho=round(float(last.rho_live), 4),
                segment_io=[round(r.avg_io_per_query, 3)
                            for r in res.records],
            ))
        for widx, recs in sorted(self.regret.items()):
            out.append(Row(
                f"{name}_regret_w{widx}", 0.0,
                segments=len(recs),
                defender=recs[-1]["defender"],
                max_regret=round(max(r["regret"] for r in recs), 6),
                max_kl_adv=round(max(r["kl_adv"] for r in recs), 6),
                # the gated robustness claim: on EVERY attacked segment the
                # realized model cost stayed under the KL dual bound
                claim_regret_le_dual_bound=bool(
                    all(r["le_dual_bound"] for r in recs)),
                trace=[{"segment": r["segment"], "rho": round(r["rho"], 4),
                        "kl_adv": round(r["kl_adv"], 5),
                        "cost_nominal": round(r["cost_nominal"], 5),
                        "cost_adv": round(r["cost_adv"], 5),
                        "dual_bound": round(r["dual_bound"], 5),
                        "measured_io": round(r["measured_io"], 4)}
                       for r in recs],
            ))
        for (widx, fleet), res in sorted(self.memory.items(),
                                         key=lambda kv: (kv[0][0],
                                                         kv[0][1])):
            last = res.records[-1]
            out.append(Row(
                f"{name}_memory_w{widx}_{fleet}", 0.0,
                avg_io=round(res.avg_io_per_query, 4),
                throughput=round(res.throughput, 4),
                retunes=res.retunes,
                segments=len(res.records),
                final_kl=round(float(last.kl_est), 4),
                segment_io=[round(r.avg_io_per_query, 3)
                            for r in res.records],
            ))
        if self.memory:
            tp_static = self.memory_fleet_throughput("static")
            tp_arb = self.memory_fleet_throughput("arbitrated")
            out.append(Row(
                f"{name}_memory_fleet", 0.0,
                tenants=len({w for w, _ in self.memory}),
                tp_static=round(tp_static, 4),
                tp_arbitrated=round(tp_arb, 4),
                fleet_speedup=round(tp_arb / max(tp_static, 1e-9), 4),
                divisions=len(self.memory_events),
                events=[{"segment": e["segment"], "reason": e["reason"],
                         "shares": [round(s, 3) for s in e["shares"]],
                         "retuned": e["retuned"]}
                        for e in self.memory_events],
            ))
        if self.failed_cells:
            out.append(Row(
                f"{name}_failed", 0.0,
                failed=len(self.failed_cells),
                cells=[f"w{w}" + ("" if rho is None else f"_rho{rho:g}")
                       + f":{pol}"
                       for (w, rho), pol in sorted(
                           self.failed_cells, key=str)],
                errors=[err.splitlines()[-1][:200] if err else ""
                        for _, err in sorted(self.failed_cells.items(),
                                             key=lambda kv: str(kv[0]))],
            ))
        if self.shard_attempts:
            lat = [a["latency_s"] for a in self.shard_attempts]
            failed = {a["shard"] for a in self.shard_attempts if not a["ok"]}
            flapping = sorted(
                failed & {a["shard"] for a in self.shard_attempts
                          if a["ok"]})
            out.append(Row(
                f"{name}_shards", 0.0,
                attempts=len(self.shard_attempts),
                failed_attempts=sum(not a["ok"]
                                    for a in self.shard_attempts),
                flapping_shards=flapping,
                max_attempt_latency=round(max(lat), 4),
                mean_attempt_latency=round(sum(lat) / len(lat), 4),
            ))
        out.append(Row(f"{name}_walls", self.wall_time_s * 1e6,
                       **{k: round(v, 3) for k, v in self.walls.items()},
                       cells=len(self.cells),
                       policies=len(self.spec.design.policies),
                       backend=self.spec.backend))
        return out

    def to_bench_payload(self, rows: Optional[List[Row]] = None,
                         error: Optional[str] = None) -> Dict[str, Any]:
        """Exactly the ``BENCH_<suite>.json`` schema ``run.py`` emits and
        ``--check`` diffs (suite / wall_time_s / error / rows / checksum)."""
        from repro import obs
        from repro.faults import stamp_checksum
        rows = self.rows() if rows is None else rows
        payload: Dict[str, Any] = {
            "suite": self.spec.name,
            "wall_time_s": round(self.wall_time_s, 3),
            "error": error,
            "rows": [{"name": r.name,
                      "us_per_call": jsonable(round(float(r.us), 1)),
                      "derived": jsonable(r.derived)} for r in rows],
        }
        # Only when telemetry is live — an untraced run's payload stays
        # byte-identical to baselines captured before obs existed.
        if obs.enabled():
            payload["metrics"] = jsonable(obs.metrics_snapshot())
        return stamp_checksum(payload)

    def write_bench_json(self, path: str,
                         rows: Optional[List[Row]] = None) -> None:
        """Atomic (tmp + ``os.replace``), checksummed baseline write — a
        crash mid-save leaves the previous file, never a torn one."""
        from repro.faults import atomic_write_json
        atomic_write_json(path, self.to_bench_payload(rows))
