"""Lower an :class:`~repro.api.spec.ExperimentSpec` onto the batched engines.

``compile_spec`` turns the declarative spec into

* one :class:`TuningPlan` per distinct *tuning design* — the whole
  (workload x rho x multi-start) grid of a plan is a single
  ``tune_nominal_many`` / ``tune_robust_many`` jit dispatch (policy arms
  that reshape the steady-state K profile, e.g. ``lazy_leveling``, tune
  under their matching continuous design; profile-preserving arms share the
  spec's primary design, so the common single-arm case stays ONE grid and is
  bit-identical to calling the batched tuners directly);
* a joint *policy-arm selection*: every arm's effective configuration
  (:func:`repro.core.policy_effective_phi`) is scored under the cell's
  exact objective (expected cost for nominal cells, the KL-dual worst case
  for robust cells) and the argmin arm is recorded per cell — tuning over
  the policy axis as a discrete arm of the same optimization;
* one :class:`TrialPlan` — the flat (tree x session) fleet grid in exactly
  :func:`repro.lsm.run_policy_fleet`'s conventions (shared key draws,
  shared session plans), executed by the spec's backend.

The existing ``core``/``lsm`` functions stay the stable low-level layer this
compiler targets; nothing here re-implements a solver or an engine.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from .report import Cell, Report
from .spec import ExperimentSpec, Pairs

#: policy arm -> the continuous design space whose K profile matches the
#: arm's steady state; arms not listed preserve the tuning's own profile and
#: share the spec's primary design grid.
ARM_DESIGNS = {"lazy_leveling": "lazy_leveling"}

#: ``DesignSpec.policy_params`` entries consumed by the cost model
#: (``policy_effective_phi``) only — stripped before the engine planner
#: constructor sees them.
MODEL_ONLY_PARAMS = frozenset({"fill"})


@dataclasses.dataclass
class TuningPlan:
    """One batched-tuner dispatch: the full (workload x rho) grid for one
    design, solved robust (``rhos``) and/or nominal (``nominal``)."""

    W: np.ndarray                    # (n_w, 4) float32 workload matrix
    rhos: Tuple[float, ...]
    nominal: bool
    design: object                   # repro.core.DesignSpace
    n_starts: int
    steps: int
    lr: float
    seed: int
    sys: object                      # repro.core.LSMSystem


@dataclasses.dataclass
class TreeBuild:
    """One engine deployment: a (cell, policy) tree, as plain data (no jax
    types), so worker processes can rebuild it from a pickle."""

    cell: Cell
    policy: str
    policy_params: Pairs
    T: float
    mfilt_bits: float
    K: Tuple[float, ...]
    key_group: int                   # trees sharing a group share a key draw
    key_seed: int
    session_seeds: Tuple[int, ...]


@dataclasses.dataclass
class DriftArmInit:
    """One drift-experiment deployment: its workload, arm kind, and the
    tuning it starts from (``None`` for oracle — pre-tuned per segment)."""

    widx: int
    arm: str
    tuning: object                   # TuningResult; None for oracle
    rho: float                       # live budget of the initial tuning
    policy: str
    policy_params: Pairs


@dataclasses.dataclass
class DriftPlan:
    """A compiled drift experiment (:class:`repro.api.spec.DriftSpec`):
    per-workload expected mixes + true-mix schedules, one arm list, and the
    live system for re-tune storms.  Executed by
    :func:`repro.online.execute_drift` (inherently sequential — the loop is
    a feedback system — so every backend shares the inline driver)."""

    arms: List[DriftArmInit]
    expected: np.ndarray             # (n_w, 4)
    schedules: np.ndarray            # (n_w, S, 4)
    drift: object                    # the DriftSpec
    sys: object                      # repro.core.LSMSystem
    design: object = None            # DesignSpace re-tunes solve in
    #: scenario generator (repro.scenarios) for scenario drift kinds; None
    #: for the classic kinds.  The executor consults it for per-segment
    #: session shaping / arrival volume, and — for the adversary — the
    #: live inner-max mix choice (schedules then hold its placeholder).
    scenario: object = None


@dataclasses.dataclass
class MemoryPlan:
    """A compiled memory-arbitration experiment
    (:class:`repro.api.spec.MemorySpec` over a drift schedule): one tenant
    per workload row, each starting from its robust cell's chosen policy
    arm, plus the budget spec and the equal-split base system.  Executed by
    :func:`repro.online.execute_memory_fleet` (paired static/arbitrated
    fleets; inherently sequential like the drift loop, so every backend
    shares the inline driver)."""

    tunings: List[object]            # per-tenant initial TuningResult
    policies: List[str]              # per-tenant chosen policy arm
    policy_params: List[Pairs]
    rho0: float                      # live budget of the initial tunings
    expected: np.ndarray             # (F, 4)
    schedules: np.ndarray            # (F, S, 4)
    drift: object                    # the DriftSpec (schedule + loop knobs)
    memory: object                   # the MemorySpec (budget semantics)
    sys: object                      # equal-split base LSMSystem
    design: object = None            # DesignSpace re-tunes solve in
    #: scenario generator for scenario drift kinds (never the adversary —
    #: the spec rejects it on the memory axis); None for classic kinds
    scenario: object = None


def drift_schedule(expected: np.ndarray, drift) -> np.ndarray:
    """Materialize a drift spec's per-segment true mixes, (S, 4).

    Scenario kinds delegate to their generator (for the adversary the
    result is a placeholder — its mixes are chosen live per segment)."""
    S = int(drift.segments)
    w0 = np.asarray(expected, np.float64)
    w0 = w0 / w0.sum()
    from repro.scenarios import get_scenario
    sc = get_scenario(drift)
    if sc is not None:
        return sc.schedule(w0)
    if drift.kind == "schedule":
        sched = np.asarray(drift.schedule, np.float64)
        return sched / sched.sum(axis=1, keepdims=True)
    w1 = np.asarray(drift.target, np.float64)
    w1 = w1 / w1.sum()
    if drift.kind == "gradual":
        t = np.arange(S, dtype=np.float64) / max(S - 1, 1)
    elif drift.kind == "flip":
        t = (np.arange(S) >= S / 2).astype(np.float64)
    else:                                        # cyclic
        t = (np.arange(S) % 2).astype(np.float64)
    sched = (1.0 - t)[:, None] * w0 + t[:, None] * w1
    return sched / sched.sum(axis=1, keepdims=True)


@dataclasses.dataclass
class TrialPlan:
    """The flat fleet grid plus everything needed to run it jax-free."""

    trees: List[TreeBuild]
    sessions: Tuple[Tuple[float, ...], ...]
    n_keys: int
    n_queries: int
    key_space: int
    range_fraction: float
    entry_bytes: int
    delete_fraction: float
    f_a: float
    f_seq: float
    zipf_a: Optional[float]
    bits_per_entry: float            # sys fields from_phi reads
    sys_N: float
    probe_dead_keys: int = 200       # dead keys per tree checked for resurface


_ARM_SCORERS: Dict[tuple, object] = {}


def _arm_scorer(sys, policy: str, params: Pairs):
    """Cached jit: phi -> (effective cost vector, exact objective at rho).

    ``rho`` is traced (0.0 degenerates to the nominal expected cost inside
    ``robust_cost``), so one compile per (sys, policy, params) covers every
    cell of the grid."""
    key = (sys, policy, params)
    fn = _ARM_SCORERS.get(key)
    if fn is None:
        import jax
        from repro.core import cost_vector, policy_effective_phi
        from repro.core.robust import robust_cost

        @jax.jit
        def fn(phi, w, rho):
            eff = policy_effective_phi(phi, sys, policy, params)
            c = cost_vector(eff, sys)
            return c, robust_cost(c, w, rho)

        _ARM_SCORERS[key] = fn
    return fn


class CompiledExperiment:
    """The lowered experiment: resolved system, workload matrix, tuning
    plans keyed by design, and the trial builder."""

    def __init__(self, spec: ExperimentSpec):
        from repro.core import (DesignSpace, EXPECTED_WORKLOADS, LSMSystem,
                                rho_from_history, sample_benchmark)
        self.spec = spec
        self.sys = LSMSystem().replace(**dict(spec.system)) if spec.system \
            else LSMSystem()
        wl = spec.workload
        if wl.indices is not None:
            self.W = np.asarray(EXPECTED_WORKLOADS[list(wl.indices)],
                                np.float64)
            self.widx = list(wl.indices)
        else:
            W = np.asarray(wl.workloads, np.float64)
            self.W = W / W.sum(axis=1, keepdims=True)
            self.widx = list(range(len(self.W)))
        # resolved rho cells: the declared radii, plus — for the
        # "from_history" rho source — one radius measured from the observed
        # history (Algorithm 1 over its normalized rows)
        self.rhos: Tuple[float, ...] = tuple(wl.rhos)
        if wl.rho_source == "from_history":
            H = np.asarray(wl.history, np.float64)
            H = H / np.maximum(H.sum(axis=1, keepdims=True), 1e-30)
            self.rhos += (float(rho_from_history(H)),)
        self.cells: List[Cell] = []
        if wl.nominal:
            self.cells += [(i, None) for i in range(len(self.W))]
        self.cells += [(i, rho) for i in range(len(self.W))
                       for rho in self.rhos]
        self.bench = sample_benchmark(wl.bench_n, seed=wl.bench_seed) \
            if wl.bench_n else None

        # -- arm -> tuning design grouping --------------------------------
        # plans are keyed (DesignSpace, n_starts): the design-space axis
        # may tune the same space at a different multi-start budget
        self.primary_design = DesignSpace(spec.design.space)
        self.arm_design: Dict[str, object] = {}
        for pol in spec.design.policies:
            space = ARM_DESIGNS.get(pol)
            self.arm_design[pol] = DesignSpace(space) if space is not None \
                else self.primary_design
        self.space_arms: List[Tuple[str, Tuple[object, int]]] = [
            (name, (DesignSpace(name), n_starts))
            for name, n_starts in spec.design.space_arms()]

    # -- tuning -----------------------------------------------------------

    def tuning_plans(self) -> Dict[Tuple[object, int], TuningPlan]:
        """One plan per distinct (design, n_starts) among the policy arms
        and the design-space axis (usually one)."""
        if self.spec.design.fixed is not None:
            return {}
        d = self.spec.design
        keys: List[Tuple[object, int]] = []
        for pol in d.policies:
            key = (self.arm_design[pol], d.n_starts)
            if key not in keys:
                keys.append(key)
        for _, key in self.space_arms:
            if key not in keys:
                keys.append(key)
        return {key: TuningPlan(W=self.W, rhos=self.rhos,
                                nominal=self.spec.workload.nominal,
                                design=key[0], n_starts=key[1],
                                steps=d.steps, lr=d.lr, seed=d.seed,
                                sys=self.sys)
                for key in keys}

    def _fixed_phi(self):
        from repro.core import make_phi
        from repro.core.nominal import TuningResult
        T, filt_bpe, K = self.spec.design.fixed
        phi = make_phi(float(T), float(filt_bpe) * self.sys.N, float(K),
                       self.sys)
        return TuningResult(phi=phi, cost=float("nan"),
                            design=self.primary_design, solver="fixed")

    def select_arms(self, solved: Dict[object, Dict[Cell, object]]) -> Report:
        """Joint policy-arm selection + the model-side report skeleton.

        ``solved`` maps design -> cell -> TuningResult (the backends'
        output).  Each arm is scored by the exact objective of its
        *effective* phi — expected cost for nominal cells, the cold-grid
        KL-dual worst case at the cell's rho for robust cells — through one
        cached jit per (policy, params); ties break to the first arm in
        spec order (the primary arm), so single-arm specs carry the
        TuningResult through untouched."""
        spec = self.spec
        fixed = self._fixed_phi() if spec.design.fixed is not None else None
        scorers = {pol: _arm_scorer(self.sys, pol,
                                    spec.design.params_for(pol))
                   for pol in spec.design.policies}
        tunings: Dict[Cell, Dict[str, object]] = {}
        arm_costs: Dict[Cell, Dict[str, float]] = {}
        chosen: Dict[Cell, str] = {}
        model_costs: Dict[Cell, Dict[str, np.ndarray]] = {}
        bench_costs: Dict[Cell, np.ndarray] = {}
        for cell in self.cells:
            i, rho = cell
            w = np.asarray(self.W[i], np.float32)
            arms: Dict[str, object] = {}
            costs: Dict[str, float] = {}
            models: Dict[str, np.ndarray] = {}
            for pol in spec.design.policies:
                r = fixed if fixed is not None \
                    else solved[(self.arm_design[pol],
                                 spec.design.n_starts)][cell]
                c, cost = scorers[pol](r.phi, w,
                                       np.float32(rho or 0.0))
                arms[pol] = r
                costs[pol] = float(cost)
                models[pol] = np.asarray(c, np.float64)
            best = min(costs, key=lambda p: (costs[p],
                                             spec.design.policies.index(p)))
            tunings[cell] = arms
            arm_costs[cell] = costs
            chosen[cell] = best
            model_costs[cell] = models
            if self.bench is not None:
                bench_costs[cell] = np.asarray(self.bench, np.float64) \
                    @ models[best]
        # -- the design-space axis: per-arm tunings + benchmark costs ------
        # (scored through the primary policy's effective-phi scorer, so a
        # space arm equals a separate spec with that primary space exactly)
        design_tunings: Dict[str, Dict[Cell, object]] = {}
        design_bench_costs: Dict[str, Dict[Cell, np.ndarray]] = {}
        primary_scorer = scorers[spec.design.policies[0]]
        for name, key in self.space_arms:
            per_cell = dict(solved[key])
            design_tunings[name] = per_cell
            if self.bench is not None:
                B = np.asarray(self.bench, np.float64)
                costs_d: Dict[Cell, np.ndarray] = {}
                for cell in self.cells:
                    i, rho = cell
                    c, _ = primary_scorer(per_cell[cell].phi,
                                          np.asarray(self.W[i], np.float32),
                                          np.float32(rho or 0.0))
                    costs_d[cell] = B @ np.asarray(c, np.float64)
                design_bench_costs[name] = costs_d
        return Report(spec=spec, sys=self.sys, cells=list(self.cells),
                      tunings=tunings, arm_costs=arm_costs, chosen=chosen,
                      model_costs=model_costs, bench_costs=bench_costs,
                      bench_set=self.bench, design_tunings=design_tunings,
                      design_bench_costs=design_bench_costs)

    # -- trial -------------------------------------------------------------

    def build_trial(self, report: Report) -> Optional[TrialPlan]:
        """The flat (cell x policy) tree grid in run_policy_fleet order."""
        tr = self.spec.trial
        if tr is None:
            return None
        S = len(tr.sessions)
        if tr.session_seeds is not None:
            base_seeds = tuple(int(s) for s in tr.session_seeds)
        else:
            base_seeds = tuple(range(S))
        trees: List[TreeBuild] = []
        for cell in self.cells:
            i, _ = cell
            if tr.per_workload_keys:
                # Table-5 convention: the nominal/robust pair of a workload
                # shares one key draw and one session-seed row, so run_fleet
                # materializes each drifted session once per workload.
                group, kseed = i, tr.key_seed + self.widx[i]
                seeds = tuple(kseed + s for s in range(S))
            else:
                group, kseed = 0, tr.key_seed
                seeds = base_seeds
            for pol in self.spec.design.policies:
                r = report.tunings[cell][pol]
                engine_params = tuple(
                    (k, v) for k, v in self.spec.design.params_for(pol)
                    if k not in MODEL_ONLY_PARAMS)
                trees.append(TreeBuild(
                    cell=cell, policy=pol,
                    policy_params=engine_params,
                    T=float(r.phi.T), mfilt_bits=float(r.phi.mfilt_bits),
                    K=tuple(float(k) for k in np.asarray(r.phi.K)),
                    key_group=group, key_seed=kseed, session_seeds=seeds))
        return TrialPlan(trees=trees, sessions=tr.sessions,
                         n_keys=tr.n_keys, n_queries=tr.n_queries,
                         key_space=tr.key_space,
                         range_fraction=tr.range_fraction,
                         entry_bytes=tr.entry_bytes,
                         delete_fraction=tr.delete_fraction,
                         f_a=tr.f_a, f_seq=tr.f_seq, zipf_a=tr.zipf_a,
                         bits_per_entry=self.sys.bits_per_entry,
                         sys_N=self.sys.N)

    # -- drift --------------------------------------------------------------

    def build_drift(self, report: Report) -> Optional[DriftPlan]:
        """Lower the spec's drift schedule onto per-arm deployments.

        ``stale_nominal`` starts from the cell (i, None); ``static_robust``
        and ``online`` from (i, rho*) with rho* the LAST resolved rho —
        under ``rho_source="from_history"`` that is the history-measured
        budget; ``oracle`` is tuned per segment by the executor.  Trees
        deploy the chosen policy arm of their source cell."""
        dr = self.spec.drift
        if dr is None:
            return None
        rho0 = self.rhos[-1] if self.rhos else 0.0
        arms: List[DriftArmInit] = []
        for i in range(len(self.W)):
            for arm in dr.arms:
                if arm == "oracle":
                    cell, rho = None, 0.0
                elif arm == "stale_nominal":
                    cell, rho = (i, None), 0.0
                else:                            # static_robust | online
                    cell, rho = (i, rho0), rho0
                tuning, pol = None, self.spec.design.policies[0]
                if cell is not None:
                    pol = report.chosen[cell]
                    tuning = report.tunings[cell][pol]
                engine_params = tuple(
                    (k, v) for k, v in self.spec.design.params_for(pol)
                    if k not in MODEL_ONLY_PARAMS)
                arms.append(DriftArmInit(widx=i, arm=arm, tuning=tuning,
                                         rho=rho, policy=pol,
                                         policy_params=engine_params))
        schedules = np.stack([drift_schedule(self.W[i], dr)
                              for i in range(len(self.W))])
        from repro.scenarios import get_scenario
        return DriftPlan(arms=arms, expected=np.asarray(self.W, np.float64),
                         schedules=schedules, drift=dr, sys=self.sys,
                         design=self.primary_design,
                         scenario=get_scenario(dr))

    # -- memory -------------------------------------------------------------

    def build_memory(self, report: Report) -> Optional[MemoryPlan]:
        """Lower the spec's memory axis onto a per-tenant fleet.

        Every workload row is one tenant; each deploys its robust cell
        (i, rho*) at the LAST resolved rho — the ``static_robust``
        convention, so the static fleet here is bit-identical to that
        drift arm — with the cell's chosen policy arm.  When a memory spec
        is present it *replaces* drift-arm execution: the drift spec is
        the schedule/loop configuration, the memory spec the division
        semantics."""
        me = self.spec.memory
        if me is None:
            return None
        dr = self.spec.drift
        rho0 = self.rhos[-1] if self.rhos else 0.0
        tunings: List[object] = []
        policies: List[str] = []
        params: List[Pairs] = []
        for i in range(len(self.W)):
            cell = (i, rho0)
            pol = report.chosen[cell]
            tunings.append(report.tunings[cell][pol])
            policies.append(pol)
            params.append(tuple(
                (k, v) for k, v in self.spec.design.params_for(pol)
                if k not in MODEL_ONLY_PARAMS))
        schedules = np.stack([drift_schedule(self.W[i], dr)
                              for i in range(len(self.W))])
        from repro.scenarios import get_scenario
        return MemoryPlan(tunings=tunings, policies=policies,
                          policy_params=params, rho0=float(rho0),
                          expected=np.asarray(self.W, np.float64),
                          schedules=schedules, drift=dr, memory=me,
                          sys=self.sys, design=self.primary_design,
                          scenario=get_scenario(dr))


def compile_spec(spec: ExperimentSpec) -> CompiledExperiment:
    return CompiledExperiment(spec)
