"""Declarative experiment specs: the paper's pipeline as one frozen value.

An experiment in this codebase is always the same logical object — an
*uncertain workload* (expected mixes + KL radii), a *design space*
(continuous Theta plus the engine compaction policy as a discrete arm), and
optionally a *system trial* that deploys the tunings on the executable LSM
engine and measures I/O per query.  Before this module every scenario
re-wired that pipeline by hand (``tune_robust_many`` grids here,
``run_fleet`` tuples there, per-benchmark ad-hoc dicts everywhere); an
:class:`ExperimentSpec` states the whole cross-product declaratively and
:mod:`repro.api.compile` lowers it onto the existing batched engines.

Every spec is a frozen dataclass built from JSON-native scalars and tuples,
so the full experiment round-trips through JSON (``to_json`` /
``ExperimentSpec.from_json``) — the contract behind ``benchmarks/run.py
--spec FILE.json``: new scenarios are data, not new bench scripts.

The execution *backend* is an axis of the spec (``inline`` | ``sharded`` |
``subprocess``, see :mod:`repro.api.backends`), so the same experiment
scales from a laptop to a device mesh or a worker pool unchanged.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Optional, Tuple

Pairs = Tuple[Tuple[str, Any], ...]


def _tupled(x):
    """Recursively convert lists (JSON arrays) back to tuples."""
    if isinstance(x, list):
        return tuple(_tupled(v) for v in x)
    return x


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """The uncertain workload: expected mixes plus KL uncertainty radii.

    ``indices`` selects rows of the paper's Table-4
    :data:`repro.core.EXPECTED_WORKLOADS`; ``workloads`` gives explicit
    (z0, z1, q, w) mixes instead (exactly one of the two must be set).
    ``rhos`` are the KL radii of ROBUST TUNING cells (one robust tuning per
    workload x rho); the rho *source* heuristics
    (``repro.core.rho_from_pair`` / ``rho_from_history`` /
    ``rho_from_ranges``) produce values for this field.  ``nominal`` adds
    the rho-free NOMINAL TUNING baseline per workload.  ``bench_n`` > 0
    requests model evaluation of every tuning over a sampled benchmark set
    B (``sample_benchmark(bench_n, bench_seed)``), the Section 8 metric
    source."""

    indices: Optional[Tuple[int, ...]] = None
    workloads: Optional[Tuple[Tuple[float, ...], ...]] = None
    rhos: Tuple[float, ...] = ()
    nominal: bool = True
    bench_n: int = 0
    bench_seed: int = 0

    def __post_init__(self):
        if (self.indices is None) == (self.workloads is None):
            raise ValueError("set exactly one of indices / workloads")
        if not self.rhos and not self.nominal:
            raise ValueError("no tuning cells: empty rhos and nominal=False")


@dataclasses.dataclass(frozen=True)
class DesignSpec:
    """The design space: continuous Theta plus policy as a discrete arm.

    ``space`` names a :class:`repro.core.DesignSpace` (the continuous
    parameterization the tuner optimizes).  ``policies`` are engine
    compaction-policy arms (:data:`repro.core.ENGINE_POLICIES`): the tuners
    optimize Theta once per cell and the compiler then scores every arm's
    *effective* configuration (:func:`repro.core.policy_effective_phi`,
    the policy's steady-state K profile) under the cell's exact objective,
    selecting the best arm jointly — the ROADMAP "tune over the policy axis"
    item.  ``policy_params`` carries per-arm planner constructor kwargs as
    (policy, ((name, value), ...)) pairs.

    ``fixed`` = (T, filter bits/entry, K) bypasses tuning entirely and
    deploys that configuration in every cell (the compaction design-space
    sweeps pin Theta to isolate the policy axis)."""

    space: str = "classic"
    policies: Tuple[str, ...] = ("klsm",)
    policy_params: Tuple[Tuple[str, Pairs], ...] = ()
    n_starts: int = 64
    steps: int = 250
    lr: float = 0.25
    seed: int = 0
    fixed: Optional[Tuple[float, ...]] = None

    def __post_init__(self):
        if not self.policies:
            raise ValueError("at least one policy arm is required")
        if self.fixed is not None and len(self.fixed) != 3:
            raise ValueError("fixed must be (T, filt_bits_per_entry, K)")

    def params_for(self, policy: str) -> Pairs:
        return dict(self.policy_params).get(policy, ())


@dataclasses.dataclass(frozen=True)
class TrialSpec:
    """The system trial: deploy every (cell, policy) tuning on the
    executable engine and measure I/O per query over workload sessions.

    Mirrors :func:`repro.lsm.run_policy_fleet`'s conventions exactly (one
    shared key draw at ``key_seed``, per-session seeds ``session_seeds`` or
    ``0..S-1``), so a single-arm spec is bit-identical to a direct call.
    ``per_workload_keys`` switches to the Table-5 convention: each
    workload's trees share a key draw seeded ``key_seed + widx`` and
    session seeds ``key_seed + widx + s`` (the nominal/robust pair of a
    workload then shares materialized session plans).  ``delete_fraction``
    seeds tombstones after populate (every ``1/fraction``-th key), the
    tombstone-TTL policies' workload."""

    n_keys: int = 100_000
    n_queries: int = 2000
    sessions: Tuple[Tuple[float, ...], ...] = ()
    key_space: int = 2 ** 48
    range_fraction: float = 2e-5
    entry_bytes: int = 64
    key_seed: int = 7
    session_seeds: Optional[Tuple[int, ...]] = None
    per_workload_keys: bool = False
    delete_fraction: float = 0.0
    f_a: float = 1.0
    f_seq: float = 1.0
    zipf_a: Optional[float] = None

    def __post_init__(self):
        if not self.sessions:
            raise ValueError("a trial needs at least one session mix")


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """The whole experiment: workload uncertainty x design x trial x backend.

    ``system`` holds :class:`repro.core.LSMSystem` overrides as (name,
    value) pairs (the reduced-scale Table-5 systems); ``backend`` selects
    the execution backend (:data:`repro.api.backends.BACKENDS`) and
    ``backend_params`` its constructor kwargs (e.g. ``(("workers", 4),)``
    for ``subprocess``)."""

    name: str
    workload: WorkloadSpec
    design: DesignSpec = DesignSpec()
    trial: Optional[TrialSpec] = None
    system: Pairs = ()
    backend: str = "inline"
    backend_params: Pairs = ()

    # -- JSON round-trip ----------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    def to_json(self, **kw) -> str:
        kw.setdefault("indent", 1)
        kw.setdefault("sort_keys", True)
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ExperimentSpec":
        d = dict(d)
        wl = {k: _tupled(v) for k, v in d.pop("workload").items()}
        ds = {k: _tupled(v) for k, v in d.pop("design", {}).items()}
        tr = d.pop("trial", None)
        return cls(workload=WorkloadSpec(**wl), design=DesignSpec(**ds),
                   trial=TrialSpec(**{k: _tupled(v) for k, v in tr.items()})
                   if tr is not None else None,
                   **{k: _tupled(v) for k, v in d.items()})

    @classmethod
    def from_json(cls, text: str) -> "ExperimentSpec":
        return cls.from_dict(json.loads(text))
