"""Declarative experiment specs: the paper's pipeline as one frozen value.

An experiment in this codebase is always the same logical object — an
*uncertain workload* (expected mixes + KL radii), a *design space*
(continuous Theta plus the engine compaction policy as a discrete arm), and
optionally a *system trial* that deploys the tunings on the executable LSM
engine and measures I/O per query.  Before this module every scenario
re-wired that pipeline by hand (``tune_robust_many`` grids here,
``run_fleet`` tuples there, per-benchmark ad-hoc dicts everywhere); an
:class:`ExperimentSpec` states the whole cross-product declaratively and
:mod:`repro.api.compile` lowers it onto the existing batched engines.

Every spec is a frozen dataclass built from JSON-native scalars and tuples,
so the full experiment round-trips through JSON (``to_json`` /
``ExperimentSpec.from_json``) — the contract behind ``benchmarks/run.py
--spec FILE.json``: new scenarios are data, not new bench scripts.

The execution *backend* is an axis of the spec (``inline`` | ``sharded`` |
``subprocess``, see :mod:`repro.api.backends`), so the same experiment
scales from a laptop to a device mesh or a worker pool unchanged.  So is
the *failure process*: ``faults`` carries a tuple of
:class:`repro.faults.FaultSpec` (deterministic, seeded chaos injection —
worker crashes, hangs, slowdowns, corrupted result pickles, torn artifact
writes), making a chaos scenario a JSON-round-trippable spec like
everything else.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Optional, Tuple

from repro.faults import FaultSpec

Pairs = Tuple[Tuple[str, Any], ...]


def _tupled(x):
    """Recursively convert lists (JSON arrays) back to tuples."""
    if isinstance(x, list):
        return tuple(_tupled(v) for v in x)
    return x


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """The uncertain workload: expected mixes plus KL uncertainty radii.

    ``indices`` selects rows of the paper's Table-4
    :data:`repro.core.EXPECTED_WORKLOADS`; ``workloads`` gives explicit
    (z0, z1, q, w) mixes instead (exactly one of the two must be set).
    ``rhos`` are the KL radii of ROBUST TUNING cells (one robust tuning per
    workload x rho).  ``nominal`` adds the rho-free NOMINAL TUNING baseline
    per workload.  ``bench_n`` > 0 requests model evaluation of every
    tuning over a sampled benchmark set B (``sample_benchmark(bench_n,
    bench_seed)``), the Section 8 metric source.

    ``rho_source`` declares where the robustness budget comes from:

    * ``"fixed"`` (default) — exactly the declared ``rhos``; compilation is
      bit-identical to a spec without the field.
    * ``"from_history"`` — ``history`` carries observed workload mixes (or
      op-count rows, e.g. ``SessionResult.window_ops`` windows) and the
      compiler APPENDS one rho cell per workload whose radius is the
      paper's Algorithm 1 on that history
      (:func:`repro.core.rho_from_history`): the budget is the *measured*
      KL spread of what was executed, not a declared guess."""

    indices: Optional[Tuple[int, ...]] = None
    workloads: Optional[Tuple[Tuple[float, ...], ...]] = None
    rhos: Tuple[float, ...] = ()
    nominal: bool = True
    bench_n: int = 0
    bench_seed: int = 0
    rho_source: str = "fixed"
    history: Optional[Tuple[Tuple[float, ...], ...]] = None

    def __post_init__(self):
        if (self.indices is None) == (self.workloads is None):
            raise ValueError("set exactly one of indices / workloads")
        if self.rho_source not in ("fixed", "from_history"):
            raise ValueError(f"unknown rho_source {self.rho_source!r}; "
                             "use 'fixed' or 'from_history'")
        if self.rho_source == "from_history":
            if self.history is None or len(self.history) < 2:
                raise ValueError("rho_source='from_history' needs a history "
                                 "of at least 2 observed mixes")
        elif not self.rhos and not self.nominal:
            raise ValueError("no tuning cells: empty rhos and nominal=False")


@dataclasses.dataclass(frozen=True)
class DesignSpec:
    """The design space: continuous Theta plus policy as a discrete arm.

    ``space`` names a :class:`repro.core.DesignSpace` (the continuous
    parameterization the tuner optimizes).  ``policies`` are engine
    compaction-policy arms (:data:`repro.core.ENGINE_POLICIES`): the tuners
    optimize Theta once per cell and the compiler then scores every arm's
    *effective* configuration (:func:`repro.core.policy_effective_phi`,
    the policy's steady-state K profile) under the cell's exact objective,
    selecting the best arm jointly — the ROADMAP "tune over the policy axis"
    item.  ``policy_params`` carries per-arm planner constructor kwargs as
    (policy, ((name, value), ...)) pairs.

    ``fixed`` = (T, filter bits/entry, K) bypasses tuning entirely and
    deploys that configuration in every cell (the compaction design-space
    sweeps pin Theta to isolate the policy axis).

    ``spaces`` makes the design space itself an experiment AXIS: each entry
    is a design-space name or a ``(name, n_starts)`` pair, every arm is
    tuned over the full cell grid (one batched plan per distinct
    (space, n_starts)), and the report carries per-arm tunings and
    benchmark costs (``Report.design_tunings`` / ``design_bench_costs``)
    next to the primary results — the Figure-19 "flexibility vs robustness"
    comparison as one spec instead of a loop of specs.  ``space`` stays the
    *primary* design (rows, policy-arm selection, trials)."""

    space: str = "classic"
    policies: Tuple[str, ...] = ("klsm",)
    policy_params: Tuple[Tuple[str, Pairs], ...] = ()
    n_starts: int = 64
    steps: int = 250
    lr: float = 0.25
    seed: int = 0
    fixed: Optional[Tuple[float, ...]] = None
    spaces: Tuple[Any, ...] = ()

    def __post_init__(self):
        if not self.policies:
            raise ValueError("at least one policy arm is required")
        if self.fixed is not None and len(self.fixed) != 3:
            raise ValueError("fixed must be (T, filt_bits_per_entry, K)")
        if self.spaces and self.fixed is not None:
            raise ValueError("the design-space axis requires tuning; "
                             "drop `spaces` or `fixed`")
        for arm in self.spaces:
            if not (isinstance(arm, str)
                    or (isinstance(arm, tuple) and len(arm) == 2
                        and isinstance(arm[0], str))):
                raise ValueError(f"spaces entries are a name or a "
                                 f"(name, n_starts) pair, got {arm!r}")
        names = [a if isinstance(a, str) else a[0] for a in self.spaces]
        if len(set(names)) != len(names):
            # report results are keyed by space name; a repeated name
            # would silently overwrite one arm with the other
            raise ValueError(f"duplicate design-space arms in {names}")

    def params_for(self, policy: str) -> Pairs:
        return dict(self.policy_params).get(policy, ())

    def space_arms(self) -> Tuple[Tuple[str, int], ...]:
        """The design-space axis as (name, n_starts) pairs."""
        return tuple((arm, self.n_starts) if isinstance(arm, str)
                     else (arm[0], int(arm[1])) for arm in self.spaces)


@dataclasses.dataclass(frozen=True)
class TrialSpec:
    """The system trial: deploy every (cell, policy) tuning on the
    executable engine and measure I/O per query over workload sessions.

    Mirrors :func:`repro.lsm.run_policy_fleet`'s conventions exactly (one
    shared key draw at ``key_seed``, per-session seeds ``session_seeds`` or
    ``0..S-1``), so a single-arm spec is bit-identical to a direct call.
    ``per_workload_keys`` switches to the Table-5 convention: each
    workload's trees share a key draw seeded ``key_seed + widx`` and
    session seeds ``key_seed + widx + s`` (the nominal/robust pair of a
    workload then shares materialized session plans).  ``delete_fraction``
    seeds tombstones after populate (every ``1/fraction``-th key), the
    tombstone-TTL policies' workload."""

    n_keys: int = 100_000
    n_queries: int = 2000
    sessions: Tuple[Tuple[float, ...], ...] = ()
    key_space: int = 2 ** 48
    range_fraction: float = 2e-5
    entry_bytes: int = 64
    key_seed: int = 7
    session_seeds: Optional[Tuple[int, ...]] = None
    per_workload_keys: bool = False
    delete_fraction: float = 0.0
    f_a: float = 1.0
    f_seq: float = 1.0
    zipf_a: Optional[float] = None

    def __post_init__(self):
        if not self.sessions:
            raise ValueError("a trial needs at least one session mix")


@dataclasses.dataclass(frozen=True)
class DriftSpec:
    """An online drift experiment: the executed workload moves away from
    the expected one over ``segments`` equal segments of ``n_queries``
    queries, and per-arm deployments react (or don't) — the
    :mod:`repro.online` loop as a declarative schedule.

    **Schedule** — ``kind`` generates the per-segment true mixes from the
    workload's expected mix and ``target``: ``"gradual"`` (linear rotation
    expected -> target), ``"flip"`` (abrupt switch at mid-schedule),
    ``"cyclic"`` (alternate expected / target per segment), or
    ``"schedule"`` (take ``schedule`` rows verbatim, one per segment).
    Scenario kinds (:data:`repro.scenarios.SCENARIO_KINDS`:
    ``zipf_migrate`` / ``burst_storm`` / ``tombstone_churn`` /
    ``scan_heavy`` / ``adversary``) delegate the schedule — and session
    shaping like Zipf skew, burst volume, delete fraction, scan span — to
    the scenario generator; ``scenario_params`` overrides its knobs and
    ``target`` (optional here) overrides its default drift target.  The
    ``adversary`` kind picks every segment's mix live: the worst workload
    inside the deployed tuning's rho-ball (see ``docs/scenarios.md``).

    **Arms** — any of ``repro.online.ARMS``: ``stale_nominal`` deploys the
    workload's nominal cell and never re-tunes; ``static_robust`` deploys
    the robust cell at the spec's LAST resolved rho (with
    ``rho_source="from_history"`` that is the history-derived budget) and
    never re-tunes; ``online`` starts from the same robust cell and runs
    the estimator + drift-trigger loop; ``oracle`` re-tunes every segment
    to the true upcoming mix (the adaptation upper bound).  Arms of one
    workload share the key population and per-segment session plans, so
    throughput differences are tuning differences.

    **Deployment** mirrors :class:`TrialSpec` (shared key draw at
    ``key_seed``, engine scale via ``n_keys``/``entry_bytes``); estimator /
    trigger / re-tune solver knobs map onto
    :class:`repro.online.DriftPolicy`, ``repro.online.ESTIMATORS`` and
    :func:`repro.online.retune_fleet`."""

    kind: str = "gradual"
    segments: int = 8
    n_queries: int = 1000
    target: Optional[Tuple[float, ...]] = None
    schedule: Optional[Tuple[Tuple[float, ...], ...]] = None
    #: scenario-kind knobs as (name, value) pairs, validated against the
    #: generator's declared PARAMS (see repro.scenarios)
    scenario_params: Pairs = ()
    arms: Tuple[str, ...] = ("stale_nominal", "static_robust", "online",
                             "oracle")
    # deployment (TrialSpec conventions)
    n_keys: int = 100_000
    key_space: int = 2 ** 48
    range_fraction: float = 2e-5
    entry_bytes: int = 64
    key_seed: int = 7
    session_seed: int = 0
    f_a: float = 1.0
    f_seq: float = 1.0
    # estimator
    estimator: str = "window"
    alpha: float = 0.35
    window: int = 16
    capacity: int = 128
    # drift triggers
    kl_threshold: float = 0.05
    budget_slack: float = 1.0
    min_windows: int = 2
    cooldown: int = 1
    rho_floor: float = 0.05
    #: change-point detector beside the KL triggers: "kl" (none extra),
    #: "page_hinkley" (mean-shift detector over per-segment observed KL —
    #: catches burst storms the windowed estimator dilutes), or "cusum"
    #: (one-sided upper CUSUM with an absolute reference level in KL space)
    detector: str = "kl"
    ph_delta: float = 0.005
    ph_lambda: float = 0.25
    cusum_k: float = 0.01
    cusum_h: float = 0.15
    # re-tune solver
    retune_starts: int = 32
    retune_steps: int = 200
    retune_seed: int = 0

    def __post_init__(self):
        # lazy: repro.scenarios is numpy-only, but spec loading must not
        # pull it in for the classic kinds' jax-free worker processes
        from repro.scenarios import SCENARIO_KINDS, get_scenario
        classic = ("gradual", "flip", "cyclic", "schedule")
        if self.kind not in classic and self.kind not in SCENARIO_KINDS:
            raise ValueError(f"unknown drift kind {self.kind!r}; classic "
                             f"kinds {classic} or scenario kinds "
                             f"{sorted(SCENARIO_KINDS)}")
        if self.kind == "schedule":
            if self.schedule is None or len(self.schedule) != self.segments:
                raise ValueError("kind='schedule' needs one schedule row "
                                 "per segment")
            if any(len(row) != 4 for row in self.schedule):
                raise ValueError("schedule rows must be 4-class mixes")
        elif self.kind in SCENARIO_KINDS:
            # target overrides the scenario's default drift target; the
            # generator's constructor validates knob names and ranges
            if self.target is not None and len(self.target) != 4:
                raise ValueError("target must be a 4-class mix")
            get_scenario(self)
        elif self.target is None or len(self.target) != 4:
            raise ValueError(f"kind={self.kind!r} needs a 4-class target "
                             "mix")
        if self.scenario_params and self.kind not in SCENARIO_KINDS:
            raise ValueError(f"scenario_params only apply to scenario "
                             f"kinds {sorted(SCENARIO_KINDS)}, not "
                             f"{self.kind!r}")
        if self.detector not in ("kl", "page_hinkley", "cusum"):
            raise ValueError(f"unknown detector {self.detector!r}; use "
                             "'kl', 'page_hinkley', or 'cusum'")
        if self.segments < 1:
            raise ValueError("segments must be >= 1")
        bad = set(self.arms) - {"stale_nominal", "static_robust", "online",
                                "oracle"}
        if bad or not self.arms:
            raise ValueError(f"unknown drift arms {sorted(bad)}"
                             if bad else "at least one arm is required")


@dataclasses.dataclass(frozen=True)
class MemorySpec:
    """Fleet-level memory arbitration over the drift schedule — the
    :mod:`repro.online.memory` subsystem as a spec axis.

    Composes with (and requires) :class:`DriftSpec`: the drift spec
    supplies the tenants (the workload rows), the per-tenant true-mix
    schedules, the deployment scale, and the estimator / trigger / re-tune
    solver knobs; this spec supplies the budget semantics.  Execution
    replaces the drift arms with a paired two-fleet comparison (``static``
    fixed equal split vs ``arbitrated``; see
    :func:`repro.online.execute_memory_fleet`).

    **Budget** — ``total_bits_per_entry`` is the global budget summed over
    tenants (default: ``n_tenants * sys.bits_per_entry``, i.e. exactly the
    memory the fixed-split fleet already holds, so the comparison is
    division, not provisioning).  ``floor_bits_per_entry`` bounds how far a
    tenant can be squeezed; ``quantum_bits_per_entry`` is the allocation
    granularity (spatial hysteresis).

    **Trigger/hysteresis** — per-tenant KL triggers reuse the
    :class:`repro.online.DriftPolicy` contract with the drift spec's
    ``kl_threshold`` (override with ``rebalance_kl``) and ``rho_floor``;
    ``min_windows`` and ``cooldown`` here gate the *fleet-level* decision
    (one re-division resets every tenant's cooldown).

    ``enabled=False`` deploys the arbitrated fleet at the fixed equal
    split and never re-divides: its results are bit-identical to the
    static fleet (the disabled-arbitration invariant the memory bench
    gates)."""

    enabled: bool = True
    total_bits_per_entry: Optional[float] = None
    floor_bits_per_entry: float = 2.0
    quantum_bits_per_entry: float = 0.5
    rebalance_kl: Optional[float] = None
    min_windows: int = 2
    cooldown: int = 2

    def __post_init__(self):
        if self.floor_bits_per_entry <= 0.0:
            raise ValueError("floor_bits_per_entry must be > 0")
        if self.quantum_bits_per_entry <= 0.0:
            raise ValueError("quantum_bits_per_entry must be > 0")
        if self.total_bits_per_entry is not None \
                and self.total_bits_per_entry <= 0.0:
            raise ValueError("total_bits_per_entry must be > 0 (or None "
                             "for n_tenants * sys.bits_per_entry)")
        if self.rebalance_kl is not None and self.rebalance_kl <= 0.0:
            raise ValueError("rebalance_kl must be > 0 (or None for the "
                             "drift spec's kl_threshold)")
        if self.min_windows < 1 or self.cooldown < 0:
            raise ValueError("min_windows must be >= 1 and cooldown >= 0")


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """The whole experiment: workload uncertainty x design x trial x backend.

    ``system`` holds :class:`repro.core.LSMSystem` overrides as (name,
    value) pairs (the reduced-scale Table-5 systems); ``backend`` selects
    the execution backend (:data:`repro.api.backends.BACKENDS`) and
    ``backend_params`` its constructor kwargs (e.g. ``(("workers", 4),)``
    for ``subprocess`` — which also accepts the fault-tolerance knobs
    ``max_retries`` / ``backoff_s`` / ``timeout_s`` / ``retry_seed`` /
    ``reshard`` / ``run_dir`` / ``resume``).

    ``faults`` is the injected failure schedule
    (:class:`repro.faults.FaultSpec` tuple): worker-scoped faults fire in
    the ``subprocess`` backend's workers, ``torn_write`` faults in the
    artifact persistence path.  The backend contract is unchanged by any
    fault schedule — recovered results must be bit-identical to
    :class:`repro.api.backends.InlineBackend` (see ``docs/faults.md``)."""

    name: str
    workload: WorkloadSpec
    design: DesignSpec = DesignSpec()
    trial: Optional[TrialSpec] = None
    drift: Optional[DriftSpec] = None
    memory: Optional[MemorySpec] = None
    system: Pairs = ()
    backend: str = "inline"
    backend_params: Pairs = ()
    faults: Tuple[FaultSpec, ...] = ()

    def __post_init__(self):
        for f in self.faults:
            if not isinstance(f, FaultSpec):
                raise ValueError(f"faults entries must be FaultSpec, "
                                 f"got {type(f).__name__}: {f!r}")
        if self.drift is not None:
            need_robust = {"static_robust", "online"} & set(self.drift.arms)
            if need_robust and not self.workload.rhos \
                    and self.workload.rho_source != "from_history":
                raise ValueError(f"drift arms {sorted(need_robust)} need a "
                                 "robust cell: declare rhos or "
                                 "rho_source='from_history'")
            if "stale_nominal" in self.drift.arms \
                    and not self.workload.nominal:
                raise ValueError("drift arm 'stale_nominal' needs "
                                 "workload.nominal=True")
        if self.memory is not None:
            if self.drift is None:
                raise ValueError(
                    "memory arbitration rides the drift schedule: a "
                    "MemorySpec needs a DriftSpec (tenants, schedules, "
                    "deployment scale, estimator/trigger knobs)")
            if self.drift.kind == "adversary":
                raise ValueError(
                    "kind='adversary' solves its mix against a drift "
                    "defender arm per segment; memory fleets have no such "
                    "arm — use a trace-shaped scenario kind instead")
            if not self.workload.rhos \
                    and self.workload.rho_source != "from_history":
                raise ValueError(
                    "memory fleets deploy each tenant's robust cell: "
                    "declare rhos or rho_source='from_history'")

    # -- JSON round-trip ----------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    def to_json(self, **kw) -> str:
        kw.setdefault("indent", 1)
        kw.setdefault("sort_keys", True)
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ExperimentSpec":
        d = dict(d)
        wl = {k: _tupled(v) for k, v in d.pop("workload").items()}
        ds = {k: _tupled(v) for k, v in d.pop("design", {}).items()}
        tr = d.pop("trial", None)
        dr = d.pop("drift", None)
        me = d.pop("memory", None)
        fa = d.pop("faults", ())
        return cls(workload=WorkloadSpec(**wl), design=DesignSpec(**ds),
                   trial=TrialSpec(**{k: _tupled(v) for k, v in tr.items()})
                   if tr is not None else None,
                   drift=DriftSpec(**{k: _tupled(v) for k, v in dr.items()})
                   if dr is not None else None,
                   memory=MemorySpec(**{k: _tupled(v) for k, v in me.items()})
                   if me is not None else None,
                   faults=tuple(
                       f if isinstance(f, FaultSpec)
                       else FaultSpec(**{k: _tupled(v) for k, v in f.items()})
                       for f in fa),
                   **{k: _tupled(v) for k, v in d.items()})

    @classmethod
    def from_json(cls, text: str) -> "ExperimentSpec":
        return cls.from_dict(json.loads(text))
