"""Pluggable execution backends: the *where/how* axis of an experiment.

A backend executes a compiled experiment's two heavy phases — the batched
tuning grid and the engine fleet trial — without changing their semantics:

* :class:`InlineBackend` (``"inline"``, default) — today's single-process
  path: one ``tune_nominal_many`` / ``tune_robust_many`` vmap grid per
  plan, one :func:`repro.lsm.run_fleet` call for the whole (tree x session)
  grid.  Every other backend must produce results identical to this one.
* :class:`ShardedBackend` (``"sharded"``) — splits the flattened
  (workload x rho) problem axis across JAX devices with a 1-D
  ``launch.mesh`` mesh + ``NamedSharding`` (each device solves a contiguous
  slab of the grid's vmap lanes).  On a single-device host it falls back to
  the inline path, so the same spec runs anywhere — the per-lane solves are
  independent, which is what makes the sharding semantics-free.
* :class:`SubprocessBackend` (``"subprocess"``) — shards the fleet grid's
  *trees* across worker processes (spawned, jax-free: the engine is pure
  numpy).  Trees sharing a key draw stay on one worker so materialized
  session plans stay shared; tuning falls back inline.

Backends are registered in :data:`BACKENDS`; the spec's ``backend`` field
selects one, so the same experiment scales from laptop to cluster by
flipping a string.

**The fault-recovery invariant.**  Backends must also be semantics-free
under *failure*: the engine shard is deterministic (keys and session plans
are pure functions of their seeds), so retrying a dead worker, re-sharding
its trees onto survivors, or resuming a killed sweep from persisted shard
results moves work but never changes it — under ANY injected fault
schedule (:class:`repro.faults.FaultPlan`), every recovered result is
bit-identical to :class:`InlineBackend`.  When recovery itself is
exhausted (bounded retries, then one elastic re-shard round), the sweep
degrades gracefully: it completes with the unrecoverable trees recorded in
``Report.failed_cells`` instead of crashing.  The chaos suite
(``tests/test_faults.py``) and the gated ``BENCH_faults.json`` enforce
both halves; ``docs/faults.md`` has the full contract.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro import obs

from .compile import TreeBuild, TrialPlan, TuningPlan
from .report import Cell, Report, TreeProbe


# ---------------------------------------------------------------------------
# The shared (jax-free) trial executor
# ---------------------------------------------------------------------------

class _SysLite:
    """The two LSMSystem fields ``LSMTree.from_phi`` reads, as plain floats
    (worker processes never import jax)."""

    __slots__ = ("bits_per_entry", "N")

    def __init__(self, bits_per_entry: float, N: float):
        self.bits_per_entry = bits_per_entry
        self.N = N


class _PhiLite:
    __slots__ = ("T", "mfilt_bits", "K")

    def __init__(self, T: float, mfilt_bits: float, K: Tuple[float, ...]):
        self.T = T
        self.mfilt_bits = mfilt_bits
        self.K = np.asarray(K, np.float64)


def execute_trial(plan: TrialPlan, trees: Optional[List[TreeBuild]] = None):
    """Build, populate, and run one shard of the fleet grid.

    Returns ``(results, probes, populate_s, fleet_s)`` with one entry per
    :class:`TreeBuild` (in input order): the per-session
    :class:`~repro.lsm.SessionResult` list and the post-trial
    :class:`TreeProbe`.  Pure numpy end-to-end — both the inline backend
    and subprocess workers run exactly this function, so sharding cannot
    change measured I/O."""
    from repro.lsm import IOStats, LSMTree, draw_keys, populate, run_fleet

    builds = plan.trees if trees is None else trees
    sys_lite = _SysLite(plan.bits_per_entry, plan.sys_N)
    t0 = time.time()
    keys_by_group: Dict[int, np.ndarray] = {}
    dead_by_group: Dict[int, np.ndarray] = {}
    engine_trees, keys_list, seed_rows = [], [], []
    with obs.span("trial.populate", trees=len(builds)):
        for b in builds:
            keys = keys_by_group.get(b.key_group)
            if keys is None:
                keys = draw_keys(plan.n_keys, seed=b.key_seed,
                                 key_space=plan.key_space)
                keys_by_group[b.key_group] = keys
                if plan.delete_fraction > 0:
                    dead_by_group[b.key_group] = \
                        keys[::int(1 / plan.delete_fraction)]
            tree = LSMTree.from_phi(_PhiLite(b.T, b.mfilt_bits, b.K),
                                    sys_lite,
                                    expected_entries=plan.n_keys,
                                    entry_bytes=plan.entry_bytes,
                                    policy=b.policy,
                                    policy_params=b.policy_params)
            tree.obs_label = f"w{b.cell[0]}.rho{b.cell[1]}/{b.policy}"
            populate(tree, plan.n_keys, key_space=plan.key_space, keys=keys)
            if plan.delete_fraction > 0:
                for k in dead_by_group[b.key_group]:  # seed tombstones
                    tree.delete(int(k))
                tree.flush()
                tree.stats = IOStats()    # deletes are setup, not workload
            engine_trees.append(tree)
            keys_list.append(keys)
            seed_rows.append(list(b.session_seeds))
    populate_s = time.time() - t0

    t0 = time.time()
    with obs.span("trial.fleet", trees=len(builds),
                  sessions=len(plan.sessions)):
        results = run_fleet(engine_trees,
                            np.asarray(plan.sessions, np.float64),
                            keys_list, n_queries=plan.n_queries,
                            seeds=np.asarray(seed_rows),
                            key_space=plan.key_space,
                            range_fraction=plan.range_fraction,
                            f_a=plan.f_a, f_seq=plan.f_seq,
                            zipf_a=plan.zipf_a)
    fleet_s = time.time() - t0
    probes = [TreeProbe.from_tree(
        t, dead_by_group.get(b.key_group, np.empty(0))[:plan.probe_dead_keys]
        if plan.delete_fraction > 0 else None)
        for t, b in zip(engine_trees, builds)]
    return results, probes, populate_s, fleet_s


def _attach_trial(report: Report, builds: List[TreeBuild], results,
                  probes) -> None:
    for b, res, probe in zip(builds, results, probes):
        report.fleet[(b.cell, b.policy)] = res
        report.probes[(b.cell, b.policy)] = probe


# ---------------------------------------------------------------------------
# Backends
# ---------------------------------------------------------------------------

class ExecutionBackend:
    """The backend protocol: solve one tuning plan, run one fleet trial.

    ``solve`` returns ``{cell: TuningResult}`` for every cell of the plan's
    (workload x rho [x nominal]) grid; ``run_trial`` fills the report's
    ``fleet`` / ``probes`` / wall-time fields in place (and, when recovery
    is exhausted, ``failed_cells``).  Implementations must be
    *semantics-free*: any backend, on any topology, under any injected
    fault schedule (``faults``, a :class:`repro.faults.FaultPlan`),
    produces the same tunings and the same measured ``IOStats`` as
    :class:`InlineBackend` for every tree it recovers (sharding and
    retrying move work, never change it)."""

    name = "abstract"

    def solve(self, plan: TuningPlan) -> Dict[Cell, object]:
        raise NotImplementedError

    def run_trial(self, plan: TrialPlan, report: Report,
                  faults=None) -> None:
        raise NotImplementedError

    def run_drift(self, plan, report: Report) -> None:
        """Run a compiled drift experiment (``repro.api.compile.DriftPlan``).

        One shared implementation: the online loop is a feedback system —
        segment s+1's tunings depend on what segment s observed — so it is
        inherently sequential per deployment and every backend runs the
        same inline driver (re-tune storms inside it are still one batched
        dispatch across the whole fleet)."""
        from repro.online import execute_drift
        t0 = time.time()
        results, regret = execute_drift(plan)
        report.drift.update(results)
        for widx, recs in regret.items():
            report.regret.setdefault(widx, []).extend(recs)
        report.walls["drift_s"] = time.time() - t0

    def run_memory(self, plan, report: Report) -> None:
        """Run a compiled memory-arbitration experiment
        (``repro.api.compile.MemoryPlan``).

        Shared for the same reason as :meth:`run_drift`: the arbitration
        loop feeds observed segments back into memory divisions, so it is
        sequential per fleet and every backend runs the same inline driver
        (its re-tune storms are still one batched dispatch per granted
        share)."""
        from repro.online import execute_memory_fleet
        t0 = time.time()
        results, events = execute_memory_fleet(plan)
        report.memory.update(results)
        report.memory_events.extend(events)
        report.walls["memory_s"] = time.time() - t0


class InlineBackend(ExecutionBackend):
    """Single-process reference execution (today's vmap path).

    Worker-scoped faults are a no-op here by definition — there is no
    worker process to kill — which is exactly what makes this backend the
    reference side of the fault-recovery invariant."""

    name = "inline"

    def __init__(self, **_):
        pass

    def solve(self, plan: TuningPlan) -> Dict[Cell, object]:
        from repro.core import tune_nominal_many, tune_robust_many
        kw = dict(design=plan.design, n_starts=plan.n_starts,
                  steps=plan.steps, lr=plan.lr, seed=plan.seed)
        out: Dict[Cell, object] = {}
        if plan.nominal:
            for i, r in enumerate(tune_nominal_many(plan.W, plan.sys, **kw)):
                out[(i, None)] = r
        if plan.rhos:
            grid = tune_robust_many(plan.W, list(plan.rhos), plan.sys, **kw)
            for i, row in enumerate(grid):
                for j, rho in enumerate(plan.rhos):
                    out[(i, rho)] = row[j]
        return out

    def run_trial(self, plan: TrialPlan, report: Report,
                  faults=None) -> None:
        results, probes, populate_s, fleet_s = execute_trial(plan)
        _attach_trial(report, plan.trees, results, probes)
        report.walls["populate_s"] = populate_s
        report.walls["fleet_s"] = fleet_s


class ShardedBackend(InlineBackend):
    """Device-sharded tuning: the flattened problem axis is placed across
    all JAX devices via ``NamedSharding`` before the single-jit solve, so
    XLA partitions the vmap lanes device-parallel.  Falls back to the
    inline path (bit-identical results — the lanes are independent either
    way) when only one device is visible."""

    name = "sharded"

    def solve(self, plan: TuningPlan) -> Dict[Cell, object]:
        import jax
        devices = jax.devices()
        if len(devices) <= 1:
            return super().solve(plan)
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec
        from repro.core import batch
        from repro.launch.mesh import make_problem_mesh

        shard = NamedSharding(make_problem_mesh(), PartitionSpec("problem"))

        def solve_flat(W_flat, rho_flat, robust) -> list:
            P0 = len(W_flat)
            pad = (-P0) % len(devices)
            if pad:        # pad with repeats of the last cell, dropped below
                W_flat = np.concatenate([W_flat, np.repeat(
                    W_flat[-1:], pad, axis=0)])
                rho_flat = np.concatenate([rho_flat, np.repeat(
                    rho_flat[-1:], pad)])
            W_d = jax.device_put(jnp.asarray(W_flat, jnp.float32), shard)
            r_d = jax.device_put(jnp.asarray(rho_flat, jnp.float32), shard)
            out = batch.solve_grid(jax.random.PRNGKey(plan.seed), W_d, r_d,
                                   plan.design, plan.sys, plan.n_starts,
                                   plan.steps, plan.lr, robust)
            out = [np.asarray(x)[:P0] for x in jax.device_get(out)]
            return batch.build_results(out, plan.design, plan.sys)

        out: Dict[Cell, object] = {}
        n_w = len(plan.W)
        if plan.nominal:
            flat = solve_flat(np.asarray(plan.W, np.float32),
                              np.zeros(n_w, np.float32), robust=False)
            out.update({(i, None): r for i, r in enumerate(flat)})
        if plan.rhos:
            R = np.asarray(plan.rhos, np.float32)
            W_flat = np.repeat(np.asarray(plan.W, np.float32),
                               len(R), axis=0)
            rho_flat = np.tile(R, n_w)
            flat = solve_flat(W_flat, rho_flat, robust=True)
            for i in range(n_w):
                for j, rho in enumerate(plan.rhos):
                    out[(i, rho)] = flat[i * len(R) + j]
        return out


# ---------------------------------------------------------------------------
# Subprocess fleet backend: workers, retries, re-sharding, resume
# ---------------------------------------------------------------------------

class ShardFailure(RuntimeError):
    """One shard attempt failed; the message carries the phase (launch /
    timeout / exit code / result decode) and the worker's stderr tail."""


def _stderr_tail(data, limit: int = 2000) -> str:
    if not data:
        return "<no stderr>"
    if isinstance(data, bytes):
        data = data.decode("utf-8", "replace")
    return data[-limit:].strip()


def _inject_worker_fault(fault) -> None:
    """Execute a pre-launch worker fault (crash / hang / slow) inside the
    worker process.  Crash announces itself on stderr first — the parent's
    stderr capture is part of what the chaos suite verifies."""
    import os
    import sys
    from repro.faults import HANG_SLEEP_S
    if fault.kind == "crash":
        print("InjectedWorkerCrash: deterministic chaos fault (kind=crash)",
              file=sys.stderr)
        sys.stderr.flush()
        os._exit(17)
    elif fault.kind == "hang":
        time.sleep(HANG_SLEEP_S)     # parent's per-shard timeout kills us
    elif fault.kind == "slow":
        time.sleep(fault.delay_s)


def _worker_main() -> None:
    """Entry point of one fleet-shard worker process.

    Reads a pickled ``(plan, builds, fault)`` job from stdin (the legacy
    2-tuple without a fault is still accepted), runs
    :func:`execute_trial`, and writes the pickled result to stdout.
    ``fault`` is the parent's resolved :class:`repro.faults.FaultAction`
    for this (shard, attempt) coordinate — crash/hang/slow execute before
    the work, ``corrupt`` truncates the result pickle after it.  Importing
    this module pulls no jax — the engine shard is pure numpy — so worker
    startup is cheap and safe regardless of the parent's device runtime
    state (no fork-with-threads, no ``__main__`` re-import)."""
    import pickle
    import sys
    job = pickle.load(sys.stdin.buffer)
    plan, builds, fault = job if len(job) == 3 else (job[0], job[1], None)
    if fault is not None and fault.kind in ("crash", "hang", "slow"):
        _inject_worker_fault(fault)
    out = execute_trial(plan, builds)
    payload = pickle.dumps(out, protocol=pickle.HIGHEST_PROTOCOL)
    if fault is not None and fault.kind == "corrupt":
        payload = payload[: max(1, len(payload) // 2)]
    sys.stdout.buffer.write(payload)
    sys.stdout.buffer.flush()


def _plan_digest(plan: TrialPlan) -> str:
    """A stable fingerprint of the trial plan, stamped into every persisted
    shard result so a resume never consumes results from a different
    experiment (pickle of the plan's plain-data fields is deterministic
    for equal content)."""
    import hashlib
    import pickle
    return hashlib.sha256(
        pickle.dumps(plan, protocol=4)).hexdigest()[:16]


class SubprocessBackend(InlineBackend):
    """Fleet-trial sharding across worker processes, hardened against the
    faults :mod:`repro.faults` can inject.

    The (tree x session) grid is partitioned by *key group* (trees sharing
    a key draw — and therefore materialized session plans — stay together),
    groups are assigned to workers largest-first, and each worker process
    runs the same :func:`execute_trial` the inline backend runs, on its
    shard.  Workers are plain ``python -c`` subprocesses fed pickles over
    stdin/stdout (jax-free: the engine is numpy-only).

    Recovery layers, in order (all deterministic — see
    :class:`repro.faults.RetryPolicy` and ``docs/faults.md``):

    * **per-attempt timeout** (``timeout_s``) — a hung worker is killed and
      the attempt failed, with whatever stderr it produced attached;
    * **bounded retries with seeded exponential backoff**
      (``max_retries`` / ``backoff_s`` / ``retry_seed``) — crashes,
      timeouts, and corrupt result pickles re-launch the same shard;
    * **elastic re-shard** (``reshard``) — a shard dead after every retry
      has its trees regrouped onto fresh worker slots
      (:class:`repro.faults.ShardSupervisor`, the ``launch/elastic.py``
      membership pattern) and re-run once with a fresh retry budget;
    * **graceful degradation** — trees still unrecovered land in
      ``Report.failed_cells`` with their final error; the sweep completes.

    With ``run_dir`` set, every completed shard's per-tree results persist
    atomically (checksummed pickles, :func:`repro.faults.dump_job`) as soon
    as that shard finishes, so a driver killed mid-sweep loses only
    in-flight shards; ``resume=True`` loads any valid persisted results for
    this exact plan (by digest) and executes only the remainder —
    ``benchmarks/run.py --spec ... --run-dir D --resume`` is the CLI."""

    name = "subprocess"

    def __init__(self, workers: int = 0, max_retries: int = 2,
                 backoff_s: float = 0.05, timeout_s: float = 900.0,
                 retry_seed: int = 0, reshard: bool = True,
                 run_dir: str = "", resume: bool = False, **_):
        import os
        from repro.faults import RetryPolicy
        self.workers = int(workers) or min(4, os.cpu_count() or 1)
        self.retry = RetryPolicy(max_retries=int(max_retries),
                                 backoff_s=float(backoff_s),
                                 timeout_s=float(timeout_s),
                                 seed=int(retry_seed))
        self.reshard = bool(reshard)
        self.run_dir = str(run_dir or "")
        self.resume = bool(resume)

    # -- sharding ----------------------------------------------------------

    def _partition(self, plan: TrialPlan) -> List[List[int]]:
        """Tree indices per shard.  Prefer keeping key groups together
        (trees sharing a draw also share materialized session plans):
        largest-group-first onto the emptiest shard.  With fewer groups
        than workers, split within groups instead — each worker re-draws
        the (seed-deterministic) keys, trading one redundant draw for
        tree-level parallelism."""
        by_group: Dict[int, List[int]] = {}
        for t, b in enumerate(plan.trees):
            by_group.setdefault(b.key_group, []).append(t)
        if len(by_group) >= self.workers:
            shards: List[List[int]] = [[] for _ in range(self.workers)]
            for members in sorted(by_group.values(), key=len, reverse=True):
                min(shards, key=len).extend(members)
        else:
            order = list(range(len(plan.trees)))
            shards = [order[i::self.workers] for i in range(self.workers)]
        return [s for s in shards if s]

    # -- one shard attempt -------------------------------------------------

    def _launch(self, cmd, env, plan: TrialPlan, shard: List[int],
                sid: int, attempt: int, faults):
        """One worker launch; raises :class:`ShardFailure` on timeout,
        nonzero exit, or an undecodable/short result — always with the
        worker's stderr attached."""
        import pickle
        import subprocess
        fault = faults.worker_fault(sid, attempt) if faults else None
        if fault is not None and obs.enabled():
            # cross-reference: this attempt's outcome event carries the
            # same (shard, attempt) key as the injection that shaped it
            obs.event("shard.fault_injected", shard=sid, attempt=attempt,
                      fault=getattr(fault, "kind", None) or str(fault))
        job = pickle.dumps((plan, [plan.trees[t] for t in shard], fault),
                           protocol=pickle.HIGHEST_PROTOCOL)
        try:
            proc = subprocess.run(cmd, input=job, stdout=subprocess.PIPE,
                                  stderr=subprocess.PIPE, env=env,
                                  timeout=self.retry.timeout_s)
        except subprocess.TimeoutExpired as exc:
            raise ShardFailure(
                f"shard {sid} attempt {attempt}: no result within "
                f"timeout_s={self.retry.timeout_s:g} (hung worker killed); "
                f"stderr: {_stderr_tail(exc.stderr)}") from None
        if proc.returncode != 0:
            raise ShardFailure(
                f"shard {sid} attempt {attempt}: worker exited "
                f"{proc.returncode}; stderr: {_stderr_tail(proc.stderr)}")
        try:
            results, probes, p_s, f_s = pickle.loads(proc.stdout)
            if len(results) != len(shard) or len(probes) != len(shard):
                raise ValueError(f"{len(results)} results for "
                                 f"{len(shard)} trees")
        except ShardFailure:
            raise
        except Exception as exc:
            raise ShardFailure(
                f"shard {sid} attempt {attempt}: corrupt result pickle "
                f"({type(exc).__name__}: {exc}); "
                f"stderr: {_stderr_tail(proc.stderr)}") from None
        return results, probes, p_s, f_s

    def _job_path(self, digest: str, shard: List[int]) -> str:
        import hashlib
        import os
        tag = hashlib.sha256(",".join(map(str, shard)).encode()) \
            .hexdigest()[:12]
        return os.path.join(self.run_dir, f"job_{digest}_{tag}.pkl")

    def _load_resumed(self, digest: str, n_trees: int) -> Dict[int, tuple]:
        """Per-tree results recovered from a previous (killed) sweep:
        every valid ``job_<digest>_*.pkl`` in the run dir whose plan digest
        matches.  Torn or corrupt files load as ``None`` and are simply
        re-executed — a checksum never trusts, it only skips work."""
        import glob
        import os
        from repro.faults import load_job
        out: Dict[int, tuple] = {}
        if not (self.run_dir and os.path.isdir(self.run_dir)):
            return out
        for path in sorted(glob.glob(
                os.path.join(self.run_dir, f"job_{digest}_*.pkl"))):
            payload = load_job(path)
            if not isinstance(payload, dict) \
                    or payload.get("plan") != digest:
                continue
            for t, entry in payload.get("trees", {}).items():
                if isinstance(t, int) and 0 <= t < n_trees:
                    out[t] = entry
        return out

    def _persist(self, digest: str, shard: List[int], out, faults) -> int:
        """Atomically persist one completed shard's per-tree results;
        returns 1 if the write failed (injected torn write / disk error) —
        the sweep itself continues, a later resume just re-runs the
        shard."""
        if not self.run_dir:
            return 0
        import os
        from repro.faults import dump_job
        results, probes, p_s, f_s = out
        os.makedirs(self.run_dir, exist_ok=True)
        try:
            dump_job(self._job_path(digest, shard),
                     {"plan": digest,
                      "trees": {t: (results[i], probes[i])
                                for i, t in enumerate(shard)},
                      "populate_s": p_s, "fleet_s": f_s},
                     fault=faults)
            return 0
        except OSError:
            return 1

    # -- the sweep ---------------------------------------------------------

    def run_trial(self, plan: TrialPlan, report: Report,
                  faults=None) -> None:
        if self.workers <= 1 or len(plan.trees) <= 1:
            return super().run_trial(plan, report, faults)
        import concurrent.futures
        import os
        import sys
        from repro.faults import FaultPlan, ShardSupervisor

        faults = faults if faults is not None else FaultPlan(())
        sup = ShardSupervisor()
        digest = _plan_digest(plan)

        shards = self._partition(plan)
        report.walls["trial_workers"] = len(shards)

        # -- resume: trust only checksum-valid results for this exact plan
        done: Dict[int, tuple] = \
            self._load_resumed(digest, len(plan.trees)) if self.resume else {}
        report.walls["resumed_trees"] = len(done)
        pending = [(sid, [t for t in s if t not in done])
                   for sid, s in enumerate(shards)]
        jobs = [(sid, s) for sid, s in pending if s]

        env = dict(os.environ)
        env["PYTHONPATH"] = os.pathsep.join(p for p in sys.path if p)
        cmd = [sys.executable, "-c",
               "from repro.api.backends import _worker_main; _worker_main()"]

        stats = {"attempts": 0, "persist_failures": 0, "shards_run": 0}
        walls = {"populate_s": 0.0, "fleet_s": 0.0}
        # Every attempt — including the ones a later success used to mask —
        # is recorded here and surfaced in the Report: a silently-flapping
        # shard (fails, backs off, then succeeds) used to be invisible
        # because only failure stderr was kept.  list.append is atomic, so
        # the pool threads share this without a lock.
        attempt_log: List[dict] = []

        def run_with_retries(job):
            """(sid, shard) -> (sid, shard, out-or-None, [errors]).
            Bounded retries with seeded backoff; persists on success so a
            killed driver keeps every completed shard.  Per-attempt
            latencies and outcomes land in ``attempt_log`` either way."""
            sid, shard = job
            errors: List[str] = []
            for attempt in range(self.retry.attempts()):
                if attempt:
                    time.sleep(self.retry.delay(sid, attempt))
                a_t0 = time.perf_counter()
                try:
                    out = self._launch(cmd, env, plan, shard, sid, attempt,
                                       faults)
                except ShardFailure as exc:
                    latency = time.perf_counter() - a_t0
                    attempt_log.append({"shard": sid, "attempt": attempt,
                                        "ok": False,
                                        "latency_s": round(latency, 6)})
                    obs.count("shard.attempts")
                    obs.count("shard.failed_attempts")
                    if obs.enabled():
                        obs.event("shard.attempt", shard=sid,
                                  attempt=attempt, ok=False,
                                  latency_s=round(latency, 6),
                                  error=str(exc)[:200])
                    errors.append(str(exc))
                    continue
                latency = time.perf_counter() - a_t0
                attempt_log.append({"shard": sid, "attempt": attempt,
                                    "ok": True,
                                    "latency_s": round(latency, 6)})
                obs.count("shard.attempts")
                if obs.enabled():
                    obs.event("shard.attempt", shard=sid, attempt=attempt,
                              ok=True, latency_s=round(latency, 6))
                stats["persist_failures"] += \
                    self._persist(digest, shard, out, faults)
                return sid, shard, out, errors
            return sid, shard, None, errors

        def run_round(round_jobs):
            """Execute one round of shard jobs; returns the tree indices
            (with errors) that exhausted this round's retry budget."""
            if not round_jobs:
                return []
            stats["shards_run"] += len(round_jobs)
            with concurrent.futures.ThreadPoolExecutor(
                    len(round_jobs)) as pool:
                outs = list(pool.map(run_with_retries, round_jobs))
            lost: List[Tuple[int, str]] = []
            for sid, shard, out, errors in outs:
                for err in errors:
                    sup.record_failure(sid, err)
                stats["attempts"] += 1 + len(errors)
                if out is None:
                    sup.mark_dead(sid)
                    lost.extend((t, errors[-1]) for t in shard)
                    continue
                sup.mark_completed(sid)
                results, probes, p_s, f_s = out
                for i, t in enumerate(shard):
                    done[t] = (results[i], probes[i])
                # workers run in parallel: phase wall = slowest worker
                walls["populate_s"] = max(walls["populate_s"], p_s)
                walls["fleet_s"] = max(walls["fleet_s"], f_s)
            return lost

        lost = run_round(jobs)

        # -- elastic re-shard: dead workers' trees onto fresh slots, once.
        # Membership logic mirrors launch/elastic.py's remesh: with zero
        # surviving shards the failure is systemic (the machine, not the
        # shard), so degrade instead of re-running everything doomed.
        report.walls["reshard_trees"] = 0
        if lost and self.reshard and sup.completed:
            last_err = dict(lost)
            regrouped = sup.reassign([t for t, _ in lost], self.workers)
            report.walls["reshard_trees"] = len(last_err)
            obs.count("shard.reshards")
            if obs.enabled():
                obs.event("shard.reshard", trees=len(last_err),
                          new_shards=len(regrouped))
            next_sid = len(shards)
            lost = run_round([(next_sid + j, s)
                              for j, s in enumerate(regrouped)])

        # -- graceful degradation: explicit holes, not a crash
        for t, err in lost:
            b = plan.trees[t]
            report.failed_cells[(b.cell, b.policy)] = err

        for t, (res, probe) in done.items():
            b = plan.trees[t]
            report.fleet[(b.cell, b.policy)] = res
            report.probes[(b.cell, b.policy)] = probe

        report.walls["populate_s"] = walls["populate_s"]
        report.walls["fleet_s"] = walls["fleet_s"]
        report.walls["shards_run"] = stats["shards_run"]
        report.walls["shard_retries"] = sup.retries
        report.walls["failed_trees"] = len(report.failed_cells)
        if stats["persist_failures"]:
            report.walls["persist_failures"] = stats["persist_failures"]
        # per-attempt accounting (sorted: pool threads interleave appends):
        # total attempts, flapping shards (>= 1 failed attempt before a
        # success), and the latency spread — Report.rows renders these, so
        # a flapping fleet is visible without digging through stderr
        report.shard_attempts = sorted(
            attempt_log, key=lambda a: (a["shard"], a["attempt"]))
        report.walls["shard_attempt_count"] = len(attempt_log)
        obs.count("shard.resumed", report.walls["resumed_trees"])


class RemoteBackend(ExecutionBackend):
    """Cluster-scheduler stub (the ROADMAP "remote backend" item).

    Registered so ``ExperimentSpec.backend = "remote"`` round-trips through
    JSON and ``get_backend`` like any real backend, and so the submission
    payload contract is pinned today: :meth:`serialize_job` emits the
    versioned job envelope a scheduler shim would ship to a worker that
    runs ``benchmarks/run.py --spec job-spec.json``.  Since the
    fault-tolerance work the envelope carries the full job shape a flaky
    cluster needs — the spec, a content checksum the worker validates
    before executing (a torn submission must be rejected, not run), and
    the retry/timeout policy the remote executor should apply.  Execution
    itself is NOT implemented — every execution entry point raises with
    instructions rather than silently running locally, so a misconfigured
    deployment cannot masquerade as a cluster run."""

    name = "remote"
    #: bumped when the envelope shape changes; v2 added spec_checksum and
    #: the retry/timeout policy block.
    ENVELOPE_VERSION = 2
    _MSG = ("the 'remote' backend is a scheduling stub: it serializes the "
            "experiment (RemoteBackend.serialize_job(spec) -> JSON job "
            "envelope for `benchmarks/run.py --spec`) but cannot execute "
            "it in this process.  Submit the payload to your cluster "
            "scheduler, or pick backend='inline'/'sharded'/'subprocess' "
            "to run here.")

    def __init__(self, scheduler: str = "", queue: str = "",
                 max_retries: int = 2, backoff_s: float = 0.05,
                 timeout_s: float = 900.0, retry_seed: int = 0, **_):
        from repro.faults import RetryPolicy
        self.scheduler = scheduler
        self.queue = queue
        self.retry = RetryPolicy(max_retries=int(max_retries),
                                 backoff_s=float(backoff_s),
                                 timeout_s=float(timeout_s),
                                 seed=int(retry_seed))

    def serialize_job(self, spec) -> str:
        """The submission payload: a versioned envelope of the spec's JSON
        round-trip, its content checksum, and the retry/timeout policy the
        remote executor must honor."""
        import json
        from repro.faults import stamp_checksum
        return json.dumps(stamp_checksum({
            "version": self.ENVELOPE_VERSION,
            "scheduler": self.scheduler,
            "queue": self.queue,
            "retry": {"max_retries": self.retry.max_retries,
                      "backoff_s": self.retry.backoff_s,
                      "timeout_s": self.retry.timeout_s,
                      "seed": self.retry.seed},
            "spec": spec.to_dict(),
        }), indent=1, sort_keys=True)

    @classmethod
    def deserialize_job(cls, text: str):
        """Validate + unpack an envelope: ``(ExperimentSpec, retry dict)``.
        Raises ``ValueError`` on a version mismatch or a checksum failure —
        a torn/tampered submission must never execute."""
        import json
        from repro.faults import checksum_ok
        from .spec import ExperimentSpec
        env = json.loads(text)
        if not isinstance(env, dict) \
                or env.get("version") != cls.ENVELOPE_VERSION:
            raise ValueError(f"unknown job envelope version "
                             f"{env.get('version')!r}; expected "
                             f"{cls.ENVELOPE_VERSION}")
        if not checksum_ok(env):
            raise ValueError("job envelope checksum mismatch "
                             "(torn or tampered submission)")
        return ExperimentSpec.from_dict(env["spec"]), dict(env["retry"])

    def solve(self, plan: TuningPlan) -> Dict[Cell, object]:
        raise NotImplementedError(self._MSG)

    def run_trial(self, plan: TrialPlan, report: Report,
                  faults=None) -> None:
        raise NotImplementedError(self._MSG)

    def run_drift(self, plan, report: Report) -> None:
        raise NotImplementedError(self._MSG)

    def run_memory(self, plan, report: Report) -> None:
        raise NotImplementedError(self._MSG)


BACKENDS = {
    "inline": InlineBackend,
    "sharded": ShardedBackend,
    "subprocess": SubprocessBackend,
    "remote": RemoteBackend,
}


def get_backend(name: str, params=()):
    try:
        cls = BACKENDS[name]
    except KeyError:
        raise ValueError(f"unknown backend {name!r}; "
                         f"known: {sorted(BACKENDS)}") from None
    return cls(**dict(params))
