"""Pluggable execution backends: the *where/how* axis of an experiment.

A backend executes a compiled experiment's two heavy phases — the batched
tuning grid and the engine fleet trial — without changing their semantics:

* :class:`InlineBackend` (``"inline"``, default) — today's single-process
  path: one ``tune_nominal_many`` / ``tune_robust_many`` vmap grid per
  plan, one :func:`repro.lsm.run_fleet` call for the whole (tree x session)
  grid.  Every other backend must produce results identical to this one.
* :class:`ShardedBackend` (``"sharded"``) — splits the flattened
  (workload x rho) problem axis across JAX devices with a 1-D
  ``launch.mesh`` mesh + ``NamedSharding`` (each device solves a contiguous
  slab of the grid's vmap lanes).  On a single-device host it falls back to
  the inline path, so the same spec runs anywhere — the per-lane solves are
  independent, which is what makes the sharding semantics-free.
* :class:`SubprocessBackend` (``"subprocess"``) — shards the fleet grid's
  *trees* across worker processes (spawned, jax-free: the engine is pure
  numpy).  Trees sharing a key draw stay on one worker so materialized
  session plans stay shared; tuning falls back inline.

Backends are registered in :data:`BACKENDS`; the spec's ``backend`` field
selects one, so the same experiment scales from laptop to cluster by
flipping a string.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from .compile import TreeBuild, TrialPlan, TuningPlan
from .report import Cell, Report, TreeProbe


# ---------------------------------------------------------------------------
# The shared (jax-free) trial executor
# ---------------------------------------------------------------------------

class _SysLite:
    """The two LSMSystem fields ``LSMTree.from_phi`` reads, as plain floats
    (worker processes never import jax)."""

    __slots__ = ("bits_per_entry", "N")

    def __init__(self, bits_per_entry: float, N: float):
        self.bits_per_entry = bits_per_entry
        self.N = N


class _PhiLite:
    __slots__ = ("T", "mfilt_bits", "K")

    def __init__(self, T: float, mfilt_bits: float, K: Tuple[float, ...]):
        self.T = T
        self.mfilt_bits = mfilt_bits
        self.K = np.asarray(K, np.float64)


def execute_trial(plan: TrialPlan, trees: Optional[List[TreeBuild]] = None):
    """Build, populate, and run one shard of the fleet grid.

    Returns ``(results, probes, populate_s, fleet_s)`` with one entry per
    :class:`TreeBuild` (in input order): the per-session
    :class:`~repro.lsm.SessionResult` list and the post-trial
    :class:`TreeProbe`.  Pure numpy end-to-end — both the inline backend
    and subprocess workers run exactly this function, so sharding cannot
    change measured I/O."""
    from repro.lsm import IOStats, LSMTree, draw_keys, populate, run_fleet

    builds = plan.trees if trees is None else trees
    sys_lite = _SysLite(plan.bits_per_entry, plan.sys_N)
    t0 = time.time()
    keys_by_group: Dict[int, np.ndarray] = {}
    dead_by_group: Dict[int, np.ndarray] = {}
    engine_trees, keys_list, seed_rows = [], [], []
    for b in builds:
        keys = keys_by_group.get(b.key_group)
        if keys is None:
            keys = draw_keys(plan.n_keys, seed=b.key_seed,
                             key_space=plan.key_space)
            keys_by_group[b.key_group] = keys
            if plan.delete_fraction > 0:
                dead_by_group[b.key_group] = \
                    keys[::int(1 / plan.delete_fraction)]
        tree = LSMTree.from_phi(_PhiLite(b.T, b.mfilt_bits, b.K), sys_lite,
                                expected_entries=plan.n_keys,
                                entry_bytes=plan.entry_bytes,
                                policy=b.policy,
                                policy_params=b.policy_params)
        populate(tree, plan.n_keys, key_space=plan.key_space, keys=keys)
        if plan.delete_fraction > 0:
            for k in dead_by_group[b.key_group]:  # seed tombstones
                tree.delete(int(k))
            tree.flush()
            tree.stats = IOStats()      # deletes are setup, not workload
        engine_trees.append(tree)
        keys_list.append(keys)
        seed_rows.append(list(b.session_seeds))
    populate_s = time.time() - t0

    t0 = time.time()
    results = run_fleet(engine_trees, np.asarray(plan.sessions, np.float64),
                        keys_list, n_queries=plan.n_queries,
                        seeds=np.asarray(seed_rows),
                        key_space=plan.key_space,
                        range_fraction=plan.range_fraction,
                        f_a=plan.f_a, f_seq=plan.f_seq, zipf_a=plan.zipf_a)
    fleet_s = time.time() - t0
    probes = [TreeProbe.from_tree(
        t, dead_by_group.get(b.key_group, np.empty(0))[:plan.probe_dead_keys]
        if plan.delete_fraction > 0 else None)
        for t, b in zip(engine_trees, builds)]
    return results, probes, populate_s, fleet_s


def _attach_trial(report: Report, builds: List[TreeBuild], results,
                  probes) -> None:
    for b, res, probe in zip(builds, results, probes):
        report.fleet[(b.cell, b.policy)] = res
        report.probes[(b.cell, b.policy)] = probe


# ---------------------------------------------------------------------------
# Backends
# ---------------------------------------------------------------------------

class ExecutionBackend:
    """The backend protocol: solve one tuning plan, run one fleet trial.

    ``solve`` returns ``{cell: TuningResult}`` for every cell of the plan's
    (workload x rho [x nominal]) grid; ``run_trial`` fills the report's
    ``fleet`` / ``probes`` / wall-time fields in place.  Implementations
    must be *semantics-free*: any backend, on any topology, produces the
    same tunings and the same measured ``IOStats`` as :class:`InlineBackend`
    (sharding moves work, never changes it)."""

    name = "abstract"

    def solve(self, plan: TuningPlan) -> Dict[Cell, object]:
        raise NotImplementedError

    def run_trial(self, plan: TrialPlan, report: Report) -> None:
        raise NotImplementedError

    def run_drift(self, plan, report: Report) -> None:
        """Run a compiled drift experiment (``repro.api.compile.DriftPlan``).

        One shared implementation: the online loop is a feedback system —
        segment s+1's tunings depend on what segment s observed — so it is
        inherently sequential per deployment and every backend runs the
        same inline driver (re-tune storms inside it are still one batched
        dispatch across the whole fleet)."""
        from repro.online import execute_drift
        t0 = time.time()
        report.drift.update(execute_drift(plan))
        report.walls["drift_s"] = time.time() - t0


class InlineBackend(ExecutionBackend):
    """Single-process reference execution (today's vmap path)."""

    name = "inline"

    def __init__(self, **_):
        pass

    def solve(self, plan: TuningPlan) -> Dict[Cell, object]:
        from repro.core import tune_nominal_many, tune_robust_many
        kw = dict(design=plan.design, n_starts=plan.n_starts,
                  steps=plan.steps, lr=plan.lr, seed=plan.seed)
        out: Dict[Cell, object] = {}
        if plan.nominal:
            for i, r in enumerate(tune_nominal_many(plan.W, plan.sys, **kw)):
                out[(i, None)] = r
        if plan.rhos:
            grid = tune_robust_many(plan.W, list(plan.rhos), plan.sys, **kw)
            for i, row in enumerate(grid):
                for j, rho in enumerate(plan.rhos):
                    out[(i, rho)] = row[j]
        return out

    def run_trial(self, plan: TrialPlan, report: Report) -> None:
        results, probes, populate_s, fleet_s = execute_trial(plan)
        _attach_trial(report, plan.trees, results, probes)
        report.walls["populate_s"] = populate_s
        report.walls["fleet_s"] = fleet_s


class ShardedBackend(InlineBackend):
    """Device-sharded tuning: the flattened problem axis is placed across
    all JAX devices via ``NamedSharding`` before the single-jit solve, so
    XLA partitions the vmap lanes device-parallel.  Falls back to the
    inline path (bit-identical results — the lanes are independent either
    way) when only one device is visible."""

    name = "sharded"

    def solve(self, plan: TuningPlan) -> Dict[Cell, object]:
        import jax
        devices = jax.devices()
        if len(devices) <= 1:
            return super().solve(plan)
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec
        from repro.core import batch
        from repro.launch.mesh import make_problem_mesh

        shard = NamedSharding(make_problem_mesh(), PartitionSpec("problem"))

        def solve_flat(W_flat, rho_flat, robust) -> list:
            P0 = len(W_flat)
            pad = (-P0) % len(devices)
            if pad:        # pad with repeats of the last cell, dropped below
                W_flat = np.concatenate([W_flat, np.repeat(
                    W_flat[-1:], pad, axis=0)])
                rho_flat = np.concatenate([rho_flat, np.repeat(
                    rho_flat[-1:], pad)])
            W_d = jax.device_put(jnp.asarray(W_flat, jnp.float32), shard)
            r_d = jax.device_put(jnp.asarray(rho_flat, jnp.float32), shard)
            out = batch.solve_grid(jax.random.PRNGKey(plan.seed), W_d, r_d,
                                   plan.design, plan.sys, plan.n_starts,
                                   plan.steps, plan.lr, robust)
            out = [np.asarray(x)[:P0] for x in jax.device_get(out)]
            return batch.build_results(out, plan.design, plan.sys)

        out: Dict[Cell, object] = {}
        n_w = len(plan.W)
        if plan.nominal:
            flat = solve_flat(np.asarray(plan.W, np.float32),
                              np.zeros(n_w, np.float32), robust=False)
            out.update({(i, None): r for i, r in enumerate(flat)})
        if plan.rhos:
            R = np.asarray(plan.rhos, np.float32)
            W_flat = np.repeat(np.asarray(plan.W, np.float32),
                               len(R), axis=0)
            rho_flat = np.tile(R, n_w)
            flat = solve_flat(W_flat, rho_flat, robust=True)
            for i in range(n_w):
                for j, rho in enumerate(plan.rhos):
                    out[(i, rho)] = flat[i * len(R) + j]
        return out


def _worker_main() -> None:
    """Entry point of one fleet-shard worker process.

    Reads a pickled ``(plan, builds)`` job from stdin, runs
    :func:`execute_trial` on it, and writes the pickled result to stdout.
    Importing this module pulls no jax — the engine shard is pure numpy —
    so worker startup is cheap and safe regardless of the parent's device
    runtime state (no fork-with-threads, no ``__main__`` re-import)."""
    import pickle
    import sys
    plan, builds = pickle.load(sys.stdin.buffer)
    out = execute_trial(plan, builds)
    pickle.dump(out, sys.stdout.buffer, protocol=pickle.HIGHEST_PROTOCOL)
    sys.stdout.buffer.flush()


class SubprocessBackend(InlineBackend):
    """Fleet-trial sharding across worker processes.

    The (tree x session) grid is partitioned by *key group* (trees sharing
    a key draw — and therefore materialized session plans — stay together),
    groups are assigned to workers largest-first, and each worker process
    runs the same :func:`execute_trial` the inline backend runs, on its
    shard.  Workers are plain ``python -c`` subprocesses fed pickles over
    stdin/stdout (jax-free: the engine is numpy-only)."""

    name = "subprocess"

    def __init__(self, workers: int = 0, **_):
        import os
        self.workers = int(workers) or min(4, os.cpu_count() or 1)

    def run_trial(self, plan: TrialPlan, report: Report) -> None:
        if self.workers <= 1 or len(plan.trees) <= 1:
            return super().run_trial(plan, report)
        import concurrent.futures
        import os
        import pickle
        import subprocess
        import sys

        # Prefer keeping key groups together (trees sharing a draw also
        # share materialized session plans): largest-group-first onto the
        # emptiest shard.  With fewer groups than workers, split within
        # groups instead — each worker re-draws the (seed-deterministic)
        # keys, trading one redundant draw for tree-level parallelism.
        by_group: Dict[int, List[int]] = {}
        for t, b in enumerate(plan.trees):
            by_group.setdefault(b.key_group, []).append(t)
        if len(by_group) >= self.workers:
            shards: List[List[int]] = [[] for _ in range(self.workers)]
            for members in sorted(by_group.values(), key=len, reverse=True):
                min(shards, key=len).extend(members)
        else:
            order = list(range(len(plan.trees)))
            shards = [order[i::self.workers] for i in range(self.workers)]
        shards = [s for s in shards if s]

        env = dict(os.environ)
        env["PYTHONPATH"] = os.pathsep.join(p for p in sys.path if p)
        cmd = [sys.executable, "-c",
               "from repro.api.backends import _worker_main; _worker_main()"]

        def run_shard(shard: List[int]):
            job = pickle.dumps((plan, [plan.trees[t] for t in shard]),
                               protocol=pickle.HIGHEST_PROTOCOL)
            proc = subprocess.run(cmd, input=job, stdout=subprocess.PIPE,
                                  env=env, check=True)
            return pickle.loads(proc.stdout)

        with concurrent.futures.ThreadPoolExecutor(len(shards)) as pool:
            outs = list(pool.map(run_shard, shards))
        populate_s = fleet_s = 0.0
        for shard, (results, probes, p_s, f_s) in zip(shards, outs):
            _attach_trial(report, [plan.trees[t] for t in shard],
                          results, probes)
            populate_s = max(populate_s, p_s)     # workers run in parallel
            fleet_s = max(fleet_s, f_s)
        report.walls["populate_s"] = populate_s
        report.walls["fleet_s"] = fleet_s
        report.walls["trial_workers"] = len(shards)


class RemoteBackend(ExecutionBackend):
    """Cluster-scheduler stub (the ROADMAP "remote backend" item).

    Registered so ``ExperimentSpec.backend = "remote"`` round-trips through
    JSON and ``get_backend`` like any real backend, and so the submission
    payload contract is pinned today: :meth:`serialize_job` is the
    spec-serializing half (the JSON a scheduler shim would ship to a worker
    that runs ``benchmarks/run.py --spec job.json``).  Execution itself is
    NOT implemented — every execution entry point raises with instructions
    rather than silently running locally, so a misconfigured deployment
    cannot masquerade as a cluster run."""

    name = "remote"
    _MSG = ("the 'remote' backend is a scheduling stub: it serializes the "
            "experiment (RemoteBackend.serialize_job(spec) -> JSON for "
            "`benchmarks/run.py --spec`) but cannot execute it in this "
            "process.  Submit the payload to your cluster scheduler, or "
            "pick backend='inline'/'sharded'/'subprocess' to run here.")

    def __init__(self, scheduler: str = "", queue: str = "", **_):
        self.scheduler = scheduler
        self.queue = queue

    def serialize_job(self, spec) -> str:
        """The submission payload: exactly the spec's JSON round-trip."""
        return spec.to_json()

    def solve(self, plan: TuningPlan) -> Dict[Cell, object]:
        raise NotImplementedError(self._MSG)

    def run_trial(self, plan: TrialPlan, report: Report) -> None:
        raise NotImplementedError(self._MSG)

    def run_drift(self, plan, report: Report) -> None:
        raise NotImplementedError(self._MSG)


BACKENDS = {
    "inline": InlineBackend,
    "sharded": ShardedBackend,
    "subprocess": SubprocessBackend,
    "remote": RemoteBackend,
}


def get_backend(name: str, params=()):
    try:
        cls = BACKENDS[name]
    except KeyError:
        raise ValueError(f"unknown backend {name!r}; "
                         f"known: {sorted(BACKENDS)}") from None
    return cls(**dict(params))
