"""The unified experiment API: declarative specs over the whole stack.

One call::

    from repro.api import ExperimentSpec, WorkloadSpec, run_experiment

    spec = ExperimentSpec(
        name="demo",
        workload=WorkloadSpec(indices=(7, 11), rhos=(1.0,), bench_n=2000),
    )
    report = run_experiment(spec)

lowers the spec (:mod:`repro.api.compile`) onto the batched tuners and the
fleet executor, runs it on the spec's execution backend
(:mod:`repro.api.backends`), and returns one :class:`repro.api.Report`
(:mod:`repro.api.report`) — serializable in the ``BENCH_<suite>.json``
schema the perf gate consumes.  Specs round-trip through JSON, so
``benchmarks/run.py --spec FILE.json`` runs any experiment with no new
bench script.
"""

from __future__ import annotations

import time

from .backends import (BACKENDS, ExecutionBackend, InlineBackend,
                       RemoteBackend, ShardedBackend, SubprocessBackend,
                       execute_trial, get_backend)
from .compile import (CompiledExperiment, DriftPlan, MemoryPlan, TrialPlan,
                      TuningPlan, compile_spec, drift_schedule)
from .report import (Report, Row, TreeProbe, costs_over_benchmark, delta_tp,
                     fmt, jsonable, timed)
from .spec import (DesignSpec, DriftSpec, ExperimentSpec, MemorySpec,
                   TrialSpec, WorkloadSpec)
from repro.faults import FaultPlan, FaultSpec

__all__ = [
    "ExperimentSpec", "WorkloadSpec", "DesignSpec", "TrialSpec", "DriftSpec",
    "MemorySpec",
    "FaultSpec", "FaultPlan",
    "Report", "Row", "TreeProbe", "run_experiment",
    "compile_spec", "CompiledExperiment", "TuningPlan", "TrialPlan",
    "DriftPlan", "MemoryPlan", "drift_schedule",
    "BACKENDS", "ExecutionBackend", "InlineBackend", "ShardedBackend",
    "SubprocessBackend", "RemoteBackend", "get_backend", "execute_trial",
    "costs_over_benchmark", "delta_tp", "timed", "fmt", "jsonable",
]


def run_experiment(spec: ExperimentSpec, backend=None) -> Report:
    """Compile and execute an :class:`ExperimentSpec`; returns its
    :class:`Report`.

    ``backend`` overrides the spec's backend instance (e.g. a
    pre-configured :class:`SubprocessBackend`); by default the spec's
    ``backend`` / ``backend_params`` fields select it.  ``spec.faults``
    compiles into a :class:`repro.faults.FaultPlan` handed to the trial
    executor — the deterministic chaos schedule the backend must recover
    from (bit-identically to :class:`InlineBackend`; see
    ``docs/faults.md``)."""
    from repro.faults import FaultPlan
    cx = compile_spec(spec)
    if backend is None:
        backend = get_backend(spec.backend, spec.backend_params)
    faults = FaultPlan.from_specs(spec.faults) if spec.faults else None

    t0 = time.time()
    solved = {design: backend.solve(plan)
              for design, plan in cx.tuning_plans().items()}
    tuning_s = time.time() - t0

    t0 = time.time()
    report = cx.select_arms(solved)
    report.walls["tuning_s"] = tuning_s
    report.walls["select_s"] = time.time() - t0

    trial = cx.build_trial(report)
    if trial is not None:
        backend.run_trial(trial, report, faults=faults)
    memory = cx.build_memory(report)
    if memory is not None:
        # the memory axis REPLACES drift-arm execution: the drift spec is
        # consumed as the schedule/loop configuration of the paired
        # static/arbitrated fleet comparison (docs/memory.md)
        backend.run_memory(memory, report)
    else:
        drift = cx.build_drift(report)
        if drift is not None:
            backend.run_drift(drift, report)
    return report
