"""Core layers: norms, RoPE (incl. partial + M-RoPE), GQA attention
(causal / sliding-window / qk-norm / QKV-bias), and dense MLPs.

Pure-functional: every layer is ``apply(params, x, ...)`` with params as
nested dicts of arrays; ``init_*`` builds matching param trees.  Attention
supports three modes: full sequence (train/prefill, returns a KV cache) and
single-token decode against a cache.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.utils.shard_hints import hint

Params = Dict[str, Any]
NEG_INF = -1e30  # bf16-safe large-negative for masking


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, d: Optional[int] = None) -> Params:
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), jnp.dtype(cfg.param_dtype))}
    if cfg.norm == "ln":
        p["bias"] = jnp.zeros((d,), jnp.dtype(cfg.param_dtype))
    return p


def apply_norm(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm == "ln":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rms
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_head_norm(scale: jnp.ndarray, x: jnp.ndarray,
                  eps: float) -> jnp.ndarray:
    """Per-head q/k RMSNorm (Qwen3 qk_norm); x: (..., head_dim)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings: standard, partial, and M-RoPE
# ---------------------------------------------------------------------------

def _rope_freqs(dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, jnp.float32) / dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, cfg: ModelConfig
               ) -> jnp.ndarray:
    """x: (B, S, n_heads, head_dim); positions: (B, S) or (3, B, S) for
    M-RoPE (t/h/w position triples, Qwen2-VL)."""
    hd = x.shape[-1]
    rot = int(hd * cfg.rotary_pct)
    rot -= rot % 2
    if rot == 0:
        return x
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    freqs = _rope_freqs(rot, cfg.rope_theta)  # (rot/2,)

    if cfg.mrope_sections is not None and positions.ndim == 3:
        # M-RoPE: split the rot/2 frequency channels into (t, h, w) sections,
        # each rotated by its own position stream.
        sec = cfg.mrope_sections
        assert sum(sec) == rot // 2, (sec, rot)
        angle_parts = []
        start = 0
        for axis, n in enumerate(sec):
            f = freqs[start:start + n]
            angle_parts.append(positions[axis][..., None].astype(jnp.float32)
                               * f)  # (B, S, n)
            start += n
        angles = jnp.concatenate(angle_parts, axis=-1)  # (B, S, rot/2)
    else:
        pos = positions if positions.ndim == 2 else positions[0]
        angles = pos[..., None].astype(jnp.float32) * freqs  # (B, S, rot/2)

    cos = jnp.cos(angles)[:, :, None, :]  # (B, S, 1, rot/2)
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x_rot[..., ::2], x_rot[..., 1::2]
    xr1 = x1 * cos - x2 * sin
    xr2 = x2 * cos + x1 * sin
    x_rot = jnp.stack([xr1, xr2], axis=-1).reshape(x_rot.shape)
    return jnp.concatenate([x_rot.astype(x.dtype), x_pass], axis=-1)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def init_attention(key: jax.Array, cfg: ModelConfig,
                   cross: bool = False) -> Params:
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)
    scale_in = 1.0 / math.sqrt(d)
    scale_out = 1.0 / math.sqrt(H * hd)
    p = {
        "wq": (jax.random.normal(k1, (d, H, hd)) * scale_in).astype(dt),
        "wk": (jax.random.normal(k2, (d, KV, hd)) * scale_in).astype(dt),
        "wv": (jax.random.normal(k3, (d, KV, hd)) * scale_in).astype(dt),
        "wo": (jax.random.normal(k4, (H, hd, d)) * scale_out).astype(dt),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((H, hd), dt)
        p["bk"] = jnp.zeros((KV, hd), dt)
        p["bv"] = jnp.zeros((KV, hd), dt)
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
    return p


def _project_qkv(p: Params, xq: jnp.ndarray, xkv: jnp.ndarray,
                 cfg: ModelConfig):
    q = jnp.einsum("bsd,dhk->bshk", xq, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", xkv, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", xkv, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if "q_norm" in p:
        q = rms_head_norm(p["q_norm"], q, cfg.norm_eps)
        k = rms_head_norm(p["k_norm"], k, cfg.norm_eps)
    q = hint(q, "batch", "seq", "heads", "head_dim")
    k = hint(k, "batch", "seq", "kv_heads", "head_dim")
    v = hint(v, "batch", "seq", "kv_heads", "head_dim")
    return q, k, v


def _repeat_kv(k: jnp.ndarray, G: int) -> jnp.ndarray:
    """(B, S, KV, hd) -> (B, S, KV*G, hd), heads grouped by kv head.

    Standard TPU GQA pattern: expanding replicated/under-sharded KV heads to
    the full head count keeps the attention einsums cleanly head-parallel
    under tensor parallelism (the expansion is a broadcast, ~free)."""
    if G == 1:
        return k
    B, S, KV, hd = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (B, S, KV, G, hd))
    return k.reshape(B, S, KV * G, hd)


def _sdpa(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
          mask: Optional[jnp.ndarray], cfg: ModelConfig) -> jnp.ndarray:
    """Grouped-query scaled-dot-product attention.

    q: (B, Sq, H, hd), k/v: (B, Sk, KV, hd). H = KV * G.
    mask: broadcastable to (B, 1, Sq, Sk) additive, or None.
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    k = hint(_repeat_kv(k, G), "batch", "kv_seq", "heads", "head_dim")
    v = hint(_repeat_kv(v, G), "batch", "kv_seq", "heads", "head_dim")
    q = q * (1.0 / math.sqrt(hd))
    scores = jnp.einsum("bqhd,bshd->bhqs", q, k).astype(jnp.float32)
    if mask is not None:
        scores = scores + mask
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhqs,bshd->bqhd", probs, v)
    return out


def causal_mask(Sq: int, Sk: int, window: Optional[int],
                offset: int = 0) -> jnp.ndarray:
    """Additive causal (+ sliding window) mask of shape (1,1,1,Sq,Sk).
    ``offset``: absolute position of query row 0 (prefill starts at 0)."""
    qpos = jnp.arange(Sq)[:, None] + offset
    kpos = jnp.arange(Sk)[None, :]
    ok = kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    return jnp.where(ok, 0.0, NEG_INF)[None, None]


def _chunked_sdpa(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  cfg: ModelConfig, causal: bool, q_chunk: int
                  ) -> jnp.ndarray:
    """Attention with the q axis processed in chunks under lax.scan.

    Perf iteration #2 (EXPERIMENTS.md): the plain path materializes the full
    (B, H, S, S) f32 score tensor — 343 GB/device for qwen3 prefill_32k
    (40 heads do not divide the 16-way model axis, so scores shard on batch
    only).  Chunking bounds the transient to (B, H, q_chunk, S) and the
    scan's known_trip_count keeps the roofline accounting exact."""
    B, S, H, hd = q.shape
    Sk = k.shape[1]
    KV = k.shape[2]
    G = H // KV
    k = hint(_repeat_kv(k, G), "batch", "kv_seq", "heads", "head_dim")
    v = hint(_repeat_kv(v, G), "batch", "kv_seq", "heads", "head_dim")
    nq = S // q_chunk
    qc = jnp.moveaxis(q.reshape(B, nq, q_chunk, H, hd), 1, 0)
    scale = 1.0 / math.sqrt(hd)

    def body(_, xs):
        q_i, idx = xs
        s = jnp.einsum("bqhd,bshd->bhqs", q_i * scale,
                       k).astype(jnp.float32)
        if causal or cfg.window is not None:
            qpos = idx * q_chunk + jnp.arange(q_chunk)[:, None]
            kpos = jnp.arange(Sk)[None, :]
            ok = kpos <= qpos if causal else jnp.ones_like(kpos > 0)
            if cfg.window is not None:
                ok &= kpos > qpos - cfg.window
            s = jnp.where(ok[None, None], s, NEG_INF)
        p_ = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        return 0, jnp.einsum("bhqs,bshd->bqhd", p_, v)

    _, outs = jax.lax.scan(body, 0, (qc, jnp.arange(nq)))
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, H, hd)


def attention_full(p: Params, x: jnp.ndarray, positions: jnp.ndarray,
                   cfg: ModelConfig, causal: bool = True
                   ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Full-sequence attention (train/prefill). Returns (out, kv_cache)."""
    q, k, v = _project_qkv(p, x, x, cfg)
    q = apply_rope(q, positions, cfg)
    k = apply_rope(k, positions, cfg)
    S = x.shape[1]
    if cfg.attention_impl == "pallas":
        from repro.kernels.flash_attention.ops import flash_attention
        out = flash_attention(q, k, v, causal=causal, window=cfg.window)
    elif cfg.attention_impl == "xla_chunked" and S % cfg.q_chunk == 0 \
            and S > cfg.q_chunk:
        out = _chunked_sdpa(q, k, v, cfg, causal, cfg.q_chunk)
    else:
        mask = causal_mask(S, S, cfg.window) if causal else None
        out = _sdpa(q, k, v, mask, cfg)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, {"k": k, "v": v}


def attention_decode(p: Params, x: jnp.ndarray, pos: jnp.ndarray,
                     cache: Dict[str, jnp.ndarray], cfg: ModelConfig
                     ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Single-token decode. x: (B, 1, d); cache k/v: (B, Smax, KV, hd);
    pos: scalar int32 — index of the new token."""
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    if cfg.mrope_sections is not None:
        positions = jnp.broadcast_to(positions[None], (3, B, 1))
    q, k_new, v_new = _project_qkv(p, x, x, cfg)
    q = apply_rope(q, positions, cfg)
    k_new = apply_rope(k_new, positions, cfg)
    Smax = cache["k"].shape[1]
    # Sliding-window caches are ring buffers of `window` slots: slot = pos %
    # Smax.  RoPE is relative, so keys keep their absolute-position rotation
    # and only validity masking is needed.
    ring = cfg.window is not None and Smax <= cfg.window
    slot = pos % Smax if ring else pos
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, slot, 0, 0))
    kpos = jnp.arange(Smax)
    if ring:
        ok = (kpos <= pos) | (pos + 1 >= Smax)  # warm ring: all slots valid
    else:
        ok = kpos <= pos
        if cfg.window is not None:
            ok &= kpos > pos - cfg.window
    mask = jnp.where(ok, 0.0, NEG_INF)[None, None, None, :]
    out = _sdpa(q, k, v, mask, cfg)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, {"k": k, "v": v}


def attention_cross(p: Params, x: jnp.ndarray, enc: jnp.ndarray,
                    cfg: ModelConfig) -> jnp.ndarray:
    """Cross-attention (whisper decoder): no RoPE, no mask."""
    q, k, v = _project_qkv(p, x, enc, cfg)
    out = _sdpa(q, k, v, None, cfg)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


# ---------------------------------------------------------------------------
# Dense MLPs
# ---------------------------------------------------------------------------

def init_mlp(key: jax.Array, cfg: ModelConfig,
             d_ff: Optional[int] = None) -> Params:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    si, so = 1.0 / math.sqrt(d), 1.0 / math.sqrt(ff)
    if cfg.act == "swiglu":
        return {
            "wi_gate": (jax.random.normal(k1, (d, ff)) * si).astype(dt),
            "wi_up": (jax.random.normal(k2, (d, ff)) * si).astype(dt),
            "wo": (jax.random.normal(k3, (ff, d)) * so).astype(dt),
        }
    return {
        "wi": (jax.random.normal(k1, (d, ff)) * si).astype(dt),
        "wo": (jax.random.normal(k3, (ff, d)) * so).astype(dt),
    }


def apply_mlp(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    if "wi_gate" in p:
        g = jax.nn.silu(hint(x @ p["wi_gate"], "batch", "seq", "mlp"))
        u = hint(x @ p["wi_up"], "batch", "seq", "mlp")
        return (g * u) @ p["wo"]
    h = hint(x @ p["wi"], "batch", "seq", "mlp")
    return jax.nn.gelu(h) @ p["wo"]
