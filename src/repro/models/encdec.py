"""Encoder-decoder backbone (Whisper-style; conv/audio frontend stubbed).

The encoder consumes precomputed frame embeddings (the conv frontend is a
stub per the assignment — ``input_specs()`` supplies (B, S, d_input) float
arrays) and applies bidirectional attention blocks.  The decoder is a causal
LM with cross-attention to the encoder output; decode shapes run the decoder
step with a self-attn KV cache plus precomputed cross-attention K/V.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .layers import (apply_mlp, apply_norm, attention_cross, attention_decode,
                     attention_full, init_attention, init_mlp, init_norm,
                     _project_qkv, _sdpa)

Params = Dict[str, Any]


def _init_enc_layer(key: jax.Array, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {"norm1": init_norm(cfg), "attn": init_attention(k1, cfg),
            "norm2": init_norm(cfg), "mlp": init_mlp(k2, cfg)}


def _init_dec_layer(key: jax.Array, cfg: ModelConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"norm1": init_norm(cfg), "self_attn": init_attention(k1, cfg),
            "norm_x": init_norm(cfg), "cross_attn": init_attention(k2, cfg,
                                                                   cross=True),
            "norm2": init_norm(cfg), "mlp": init_mlp(k3, cfg)}


def init_encdec(key: jax.Array, cfg: ModelConfig) -> Params:
    enc = cfg.encoder
    d_in = enc.d_input or cfg.d_model
    keys = jax.random.split(key, 8)
    dt = jnp.dtype(cfg.param_dtype)
    p: Params = {
        "frontend": (jax.random.normal(keys[0], (d_in, cfg.d_model))
                     / math.sqrt(d_in)).astype(dt),
        "embed": (jax.random.normal(keys[1], (cfg.vocab_size, cfg.d_model))
                  * 0.02).astype(dt),
        "enc_final_norm": init_norm(cfg),
        "final_norm": init_norm(cfg),
    }
    enc_keys = jax.random.split(keys[2], enc.num_layers)
    p["enc_layers"] = jax.vmap(lambda k: _init_enc_layer(k, cfg))(enc_keys)
    dec_keys = jax.random.split(keys[3], cfg.num_layers)
    p["dec_layers"] = jax.vmap(lambda k: _init_dec_layer(k, cfg))(dec_keys)
    if not cfg.tie_embeddings:
        p["lm_head"] = (jax.random.normal(keys[4],
                                          (cfg.d_model, cfg.vocab_size))
                        / math.sqrt(cfg.d_model)).astype(dt)
    return p


def encode(params: Params, embeds: jnp.ndarray, cfg: ModelConfig
           ) -> jnp.ndarray:
    """embeds: (B, S_enc, d_input) stub frame embeddings -> (B, S_enc, d)."""
    x = (embeds @ params["frontend"]).astype(jnp.dtype(cfg.dtype))
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(x, p):
        h = apply_norm(p["norm1"], x, cfg)
        y, _ = attention_full(p["attn"], h, positions, cfg, causal=False)
        x = x + y
        h = apply_norm(p["norm2"], x, cfg)
        return x + apply_mlp(p["mlp"], h, cfg), None

    if cfg.remat != "none":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return apply_norm(params["enc_final_norm"], x, cfg)


def _dec_block(p: Params, x, cfg: ModelConfig, mode: str, enc=None,
               cache=None, pos=None, positions=None):
    new_cache: Params = {}
    h = apply_norm(p["norm1"], x, cfg)
    if mode == "decode":
        y, new_cache["self"] = attention_decode(p["self_attn"], h, pos,
                                                cache["self"], cfg)
    else:
        y, new_cache["self"] = attention_full(p["self_attn"], h, positions,
                                              cfg)
    x = x + y
    h = apply_norm(p["norm_x"], x, cfg)
    if mode == "decode":
        # cross K/V precomputed at prefill time
        q = jnp.einsum("bsd,dhk->bshk", h, p["cross_attn"]["wq"])
        y = _sdpa(q, cache["cross_k"], cache["cross_v"], None, cfg)
        y = jnp.einsum("bshk,hkd->bsd", y, p["cross_attn"]["wo"])
        new_cache["cross_k"] = cache["cross_k"]
        new_cache["cross_v"] = cache["cross_v"]
    else:
        y = attention_cross(p["cross_attn"], h, enc, cfg)
        new_cache["cross_k"] = jnp.einsum("bsd,dhk->bshk", enc,
                                          p["cross_attn"]["wk"])
        new_cache["cross_v"] = jnp.einsum("bsd,dhk->bshk", enc,
                                          p["cross_attn"]["wv"])
    x = x + y
    h = apply_norm(p["norm2"], x, cfg)
    return x + apply_mlp(p["mlp"], h, cfg), new_cache


def decode_stack(params: Params, x, cfg: ModelConfig, mode: str, enc=None,
                 cache=None, pos=None):
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(x, xs):
        p = xs[0]
        c = xs[1] if cache is not None else None
        x, nc = _dec_block(p, x, cfg, mode, enc=enc, cache=c, pos=pos,
                           positions=positions)
        return x, nc

    if mode == "train" and cfg.remat != "none":
        body = jax.checkpoint(body)
    xs = (params["dec_layers"],) if cache is None else (params["dec_layers"],
                                                        cache)
    x, new_cache = jax.lax.scan(body, x, xs)
    return apply_norm(params["final_norm"], x, cfg), new_cache


def _unembed(params: Params, cfg: ModelConfig):
    return params["lm_head"] if not cfg.tie_embeddings else params["embed"].T


def encdec_loss(params: Params, batch: Dict[str, jnp.ndarray],
                cfg: ModelConfig, aux_weight: float = 0.0):
    from .lm import softmax_xent
    enc = encode(params, batch["embeds"], cfg)
    x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(
        jnp.dtype(cfg.dtype))
    x, _ = decode_stack(params, x, cfg, "train", enc=enc)
    xent = softmax_xent(x, _unembed(params, cfg), batch["labels"], cfg)
    return xent, {"xent": xent, "aux": jnp.zeros((), jnp.float32)}


def encdec_prefill(params: Params, batch: Dict[str, jnp.ndarray],
                   cfg: ModelConfig):
    enc = encode(params, batch["embeds"], cfg)
    x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(
        jnp.dtype(cfg.dtype))
    x, cache = decode_stack(params, x, cfg, "prefill", enc=enc)
    logits = (x[:, -1:] @ _unembed(params, cfg)).astype(jnp.float32)
    return logits, cache


def encdec_decode_step(params: Params, cache, tokens: jnp.ndarray,
                       pos: jnp.ndarray, cfg: ModelConfig):
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    x, new_cache = decode_stack(params, x, cfg, "decode", cache=cache,
                                pos=pos)
    logits = (x @ _unembed(params, cfg)).astype(jnp.float32)
    return logits, new_cache


def encdec_init_cache(cfg: ModelConfig, batch: int, max_seq: int,
                      enc_seq: int) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    L = cfg.num_layers
    return {
        "self": {"k": jnp.zeros((L, batch, max_seq, KV, hd), dtype),
                 "v": jnp.zeros((L, batch, max_seq, KV, hd), dtype)},
        "cross_k": jnp.zeros((L, batch, enc_seq, KV, hd), dtype),
        "cross_v": jnp.zeros((L, batch, enc_seq, KV, hd), dtype),
    }
