"""Mamba (selective SSM) block, for the Jamba hybrid architecture.

Diagonal selective state space:  h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t,
y_t = C_t . h_t + D x_t, with input-dependent (dt, B, C).  The time dimension
uses ``jax.lax.associative_scan`` (log-depth, while-loop free — see the
roofline accounting note in utils/hlo.py); decode carries (conv window, ssm
state) explicitly.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.utils.shard_hints import hint

Params = Dict[str, jnp.ndarray]


def init_mamba(key: jax.Array, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    di = cfg.d_inner_mamba
    ds = cfg.mamba_d_state
    dc = cfg.mamba_d_conv
    dt_rank = max(1, d // 16)
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    return {
        "in_proj": (jax.random.normal(ks[0], (d, 2 * di))
                    / math.sqrt(d)).astype(dt),
        "conv_w": (jax.random.normal(ks[1], (dc, di)) * 0.2).astype(dt),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": (jax.random.normal(ks[2], (di, dt_rank + 2 * ds))
                   / math.sqrt(di)).astype(dt),
        "dt_proj": (jax.random.normal(ks[3], (dt_rank, di))
                    / math.sqrt(dt_rank)).astype(dt),
        "dt_bias": jnp.full((di,), -4.6, jnp.float32),  # softplus ~ 0.01
        "A_log": jnp.log(jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32),
                                  (di, 1))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": (jax.random.normal(ks[4], (di, d))
                     / math.sqrt(di)).astype(dt),
    }


def _ssm_inputs(p: Params, xz: jnp.ndarray, cfg: ModelConfig):
    """Common projections. xz: (B, S, 2*di) -> (x_conv_in, z, dt, Bm, Cm)."""
    di = cfg.d_inner_mamba
    ds = cfg.mamba_d_state
    x, z = xz[..., :di], xz[..., di:]
    return x, z


def _selective(p: Params, xc: jnp.ndarray, cfg: ModelConfig):
    """From conv output xc (B,S,di): dt (B,S,di), A (di,ds), B/C (B,S,ds)."""
    ds = cfg.mamba_d_state
    dt_rank = p["dt_proj"].shape[0]
    proj = xc @ p["x_proj"]
    dt_in, Bm, Cm = jnp.split(proj, [dt_rank, dt_rank + ds], axis=-1)
    dt = jax.nn.softplus((dt_in @ p["dt_proj"]).astype(jnp.float32)
                         + p["dt_bias"])             # (B,S,di)
    A = -jnp.exp(p["A_log"])                          # (di,ds), negative
    return dt, A, Bm.astype(jnp.float32), Cm.astype(jnp.float32)


def _ssm_scan_chunked(a: jnp.ndarray, b: jnp.ndarray,
                      chunk: int) -> jnp.ndarray:
    """First-order linear recurrence h_t = a_t h_{t-1} + b_t over time.

    Perf iteration #3 (EXPERIMENTS.md): a single associative_scan over the
    full sequence materializes O(S * di * ds) f32 at every tree level
    (~TB-scale transients for jamba train_4k).  Chunking runs the
    associative scan *within* ``chunk``-sized blocks and carries the state
    across blocks under lax.scan (known_trip_count keeps the roofline
    accounting exact).  a/b: (B, S, di, ds) -> h: (B, S, di, ds)."""
    B, S, di, ds = a.shape
    if S <= chunk:
        def combine(u, v):
            a1, b1 = u
            a2, b2 = v
            return a1 * a2, b2 + a2 * b1
        _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
        return h
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    ar = jnp.moveaxis(a.reshape(B, nc, chunk, di, ds), 1, 0)
    br = jnp.moveaxis(b.reshape(B, nc, chunk, di, ds), 1, 0)

    def combine(u, v):
        a1, b1 = u
        a2, b2 = v
        return a1 * a2, b2 + a2 * b1

    def body(h0, xs):
        ai, bi = xs
        aa, hh = jax.lax.associative_scan(combine, (ai, bi), axis=1)
        h = hh + aa * h0[:, None]       # fold in the carried state
        return h[:, -1], h

    _, hs = jax.lax.scan(body, jnp.zeros((B, di, ds), a.dtype), (ar, br))
    return jnp.moveaxis(hs, 0, 1).reshape(B, S, di, ds)


def mamba_full(p: Params, x: jnp.ndarray, cfg: ModelConfig
               ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Full-sequence Mamba (train/prefill). Returns (out, decode cache)."""
    B, S, d = x.shape
    di = cfg.d_inner_mamba
    dc = cfg.mamba_d_conv
    xz = hint(x @ p["in_proj"], "batch", "seq", "mlp")
    xi, z = _ssm_inputs(p, xz, cfg)

    # depthwise causal conv1d over time
    pad = jnp.zeros((B, dc - 1, di), xi.dtype)
    xpad = jnp.concatenate([pad, xi], axis=1)
    xc = sum(xpad[:, i:i + S, :] * p["conv_w"][i] for i in range(dc))
    xc = hint(jax.nn.silu(xc + p["conv_b"]), "batch", "seq", "mlp")

    dt, A, Bm, Cm = _selective(p, xc, cfg)
    # discretize: a_t = exp(dt*A) (B,S,di,ds); b_t = dt*B_t*x_t
    xf = xc.astype(jnp.float32)
    a = jnp.exp(dt[..., None] * A)                    # (B,S,di,ds)
    b = (dt * xf)[..., None] * Bm[..., None, :]       # (B,S,di,ds)
    h = _ssm_scan_chunked(a, b, min(cfg.mamba_chunk, S))
    y = jnp.einsum("bsnz,bsz->bsn", h, Cm)            # h.C  (B,S,di)
    y = y + p["D"] * xf
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = y @ p["out_proj"]
    cache = {
        "conv": xpad[:, -(dc - 1):, :] if dc > 1 else
        jnp.zeros((B, 0, di), xi.dtype),
        "ssm": h[:, -1],                              # (B,di,ds)
    }
    return out, cache


def mamba_step(p: Params, x: jnp.ndarray, cache: Dict[str, jnp.ndarray],
               cfg: ModelConfig
               ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Decode step; x: (B, 1, d)."""
    B, _, d = x.shape
    di = cfg.d_inner_mamba
    dc = cfg.mamba_d_conv
    xz = x @ p["in_proj"]
    xi, z = _ssm_inputs(p, xz, cfg)

    window = jnp.concatenate([cache["conv"], xi], axis=1)  # (B,dc,di)
    xc = sum(window[:, i, :] * p["conv_w"][i] for i in range(dc))
    xc = jax.nn.silu(xc + p["conv_b"])[:, None, :]         # (B,1,di)

    dt, A, Bm, Cm = _selective(p, xc, cfg)
    xf = xc.astype(jnp.float32)
    a = jnp.exp(dt[:, 0, :, None] * A)                     # (B,di,ds)
    b = (dt[:, 0] * xf[:, 0])[..., None] * Bm[:, 0, None, :]
    h = a * cache["ssm"] + b                               # (B,di,ds)
    y = jnp.einsum("bnz,bz->bn", h, Cm[:, 0])
    y = y + p["D"] * xf[:, 0]
    y = (y[:, None, :].astype(x.dtype)) * jax.nn.silu(z)
    out = y @ p["out_proj"]
    return out, {"conv": window[:, 1:, :], "ssm": h}
