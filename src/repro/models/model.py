"""Unified model API: build any assigned architecture from its config.

``build_model(cfg)`` returns a :class:`ModelAPI` with pure functions for
init / train-loss / prefill / decode plus ``input_specs`` producing
``ShapeDtypeStruct`` stand-ins for the dry-run (weak-type-correct, shardable,
no device allocation).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from . import encdec as encdec_mod
from . import lm as lm_mod

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    init: Callable[[jax.Array], Params]
    loss_fn: Callable[[Params, Dict[str, jnp.ndarray]], Any]
    prefill: Callable[[Params, Dict[str, jnp.ndarray]], Any]
    decode_step: Callable[[Params, Params, jnp.ndarray, jnp.ndarray], Any]
    init_cache: Callable[[int, int], Params]

    # ------------------------------------------------------------- specs
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        """ShapeDtypeStruct inputs for one (arch x shape) cell."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        f = partial(jax.ShapeDtypeStruct, dtype=jnp.int32)
        d = cfg.d_model
        emb_dt = jnp.dtype(cfg.dtype)

        if shape.kind in ("train", "prefill"):
            batch: Dict[str, Any] = {}
            if cfg.encoder is not None:
                d_in = cfg.encoder.d_input or d
                batch["embeds"] = jax.ShapeDtypeStruct((B, S, d_in), emb_dt)
                batch["tokens"] = f((B, S))
            elif cfg.embed_inputs:
                batch["tokens"] = f((B, S))
            else:
                batch["embeds"] = jax.ShapeDtypeStruct((B, S, d), emb_dt)
                if cfg.mrope_sections is not None:
                    batch["positions"] = f((3, B, S))
            if shape.kind == "train":
                batch["labels"] = f((B, S))
            return batch

        # decode: one new token against a cache of S past positions
        cache = jax.eval_shape(lambda: self.init_cache(B, S))
        if cfg.embed_inputs or cfg.encoder is not None:
            tokens = f((B, 1))
        else:
            tokens = jax.ShapeDtypeStruct((B, 1, d), emb_dt)
        return {"cache": cache, "tokens": tokens,
                "pos": jax.ShapeDtypeStruct((), jnp.int32)}

    def param_specs(self) -> Params:
        return jax.eval_shape(self.init, jax.random.PRNGKey(0))


def build_model(cfg: ModelConfig) -> ModelAPI:
    if cfg.encoder is not None:
        def init_cache(batch: int, max_seq: int) -> Params:
            return encdec_mod.encdec_init_cache(cfg, batch, max_seq,
                                                enc_seq=max_seq)

        return ModelAPI(
            cfg=cfg,
            init=partial(encdec_mod.init_encdec, cfg=cfg),
            loss_fn=partial(encdec_mod.encdec_loss, cfg=cfg),
            prefill=partial(encdec_mod.encdec_prefill, cfg=cfg),
            decode_step=partial(encdec_mod.encdec_decode_step, cfg=cfg),
            init_cache=init_cache,
        )

    def init_cache(batch: int, max_seq: int) -> Params:
        return lm_mod.lm_init_cache(None, cfg, batch, max_seq)

    return ModelAPI(
        cfg=cfg,
        init=partial(lm_mod.init_lm, cfg=cfg),
        loss_fn=partial(lm_mod.lm_loss, cfg=cfg),
        prefill=partial(lm_mod.lm_prefill, cfg=cfg),
        decode_step=partial(lm_mod.lm_decode_step, cfg=cfg),
        init_cache=init_cache,
    )
