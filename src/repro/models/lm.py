"""Decoder-only LM assembly: pattern-based blocks, scan-over-layers, KV/state
caches, and the train/prefill/decode entry points.

Layer structure is driven by ``cfg.pattern`` — a tuple of (mixer, mlp) kinds
repeated ``n_repeats`` times and executed under a single ``lax.scan`` over
stacked parameters (plus optional unstacked ``prelude`` layers).  This keeps
the HLO small for 80-layer models and — by construction — makes the layer
stack the *only* while loop in the program, which utils/hlo.py relies on for
roofline accounting.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.utils.shard_hints import hint
from . import mamba as mamba_mod
from . import moe as moe_mod
from . import rwkv as rwkv_mod
from .layers import (apply_mlp, apply_norm, attention_decode, attention_full,
                     init_attention, init_mlp, init_norm)

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Single block (mixer + channel-mlp with pre-norms and residuals)
# ---------------------------------------------------------------------------

def init_block(key: jax.Array, kind: Tuple[str, str],
               cfg: ModelConfig) -> Params:
    mixer, mlp = kind
    k1, k2 = jax.random.split(key)
    p: Params = {"norm1": init_norm(cfg), "norm2": init_norm(cfg)}
    if mixer == "attn":
        p["mixer"] = init_attention(k1, cfg)
    elif mixer == "mamba":
        p["mixer"] = mamba_mod.init_mamba(k1, cfg)
    elif mixer == "rwkv":
        p["mixer"] = rwkv_mod.init_time_mix(k1, cfg)
    else:
        raise ValueError(mixer)
    if mlp == "dense":
        p["mlp"] = init_mlp(k2, cfg)
    elif mlp == "moe":
        p["mlp"] = moe_mod.init_moe(k2, cfg)
    elif mlp == "rwkv_ffn":
        p["mlp"] = rwkv_mod.init_channel_mix(k2, cfg)
    else:
        raise ValueError(mlp)
    return p


def block_cache_init(kind: Tuple[str, str], cfg: ModelConfig, batch: int,
                     max_seq: int, dtype) -> Params:
    """Concrete zero-initialized decode cache for one block."""
    mixer, mlp = kind
    cache: Params = {}
    if mixer == "attn":
        KV, hd = cfg.num_kv_heads, cfg.head_dim
        # Sliding-window archs keep a ring buffer of `window` slots: the KV
        # cache for a 500k context is bounded by the window (Mixtral SWA).
        S = min(max_seq, cfg.window) if cfg.window is not None else max_seq
        cache["mixer"] = {
            "k": jnp.zeros((batch, S, KV, hd), dtype),
            "v": jnp.zeros((batch, S, KV, hd), dtype),
        }
    elif mixer == "mamba":
        di, ds, dc = cfg.d_inner_mamba, cfg.mamba_d_state, cfg.mamba_d_conv
        cache["mixer"] = {
            "conv": jnp.zeros((batch, dc - 1, di), dtype),
            "ssm": jnp.zeros((batch, di, ds), jnp.float32),
        }
    elif mixer == "rwkv":
        n = cfg.rwkv_head_dim
        H = cfg.d_model // n
        cache["mixer"] = {
            "state": jnp.zeros((batch, H, n, n), jnp.float32),
            "x_prev": jnp.zeros((batch, cfg.d_model), dtype),
        }
    if mlp == "rwkv_ffn":
        cache["mlp"] = {"x_prev": jnp.zeros((batch, cfg.d_model), dtype)}
    return cache


def apply_block(p: Params, x: jnp.ndarray, kind: Tuple[str, str],
                cfg: ModelConfig, mode: str,
                cache: Optional[Params] = None,
                pos: Optional[jnp.ndarray] = None,
                positions: Optional[jnp.ndarray] = None):
    """Returns (x, new_cache, aux_loss)."""
    mixer, mlp = kind
    aux = jnp.zeros((), jnp.float32)
    new_cache: Params = {}

    h = apply_norm(p["norm1"], x, cfg)
    if mixer == "attn":
        if mode == "decode":
            y, new_cache["mixer"] = attention_decode(
                p["mixer"], h, pos, cache["mixer"], cfg)
        else:
            y, kv = attention_full(p["mixer"], h, positions, cfg)
            new_cache["mixer"] = kv
    elif mixer == "mamba":
        if mode == "decode":
            y, new_cache["mixer"] = mamba_mod.mamba_step(
                p["mixer"], h, cache["mixer"], cfg)
        else:
            y, new_cache["mixer"] = mamba_mod.mamba_full(p["mixer"], h, cfg)
    elif mixer == "rwkv":
        if mode == "decode":
            y, new_cache["mixer"] = rwkv_mod.time_mix_step(
                p["mixer"], h, cache["mixer"], cfg)
        else:
            y, new_cache["mixer"] = rwkv_mod.time_mix_full(p["mixer"], h, cfg)
    else:
        raise ValueError(mixer)
    x = hint(x + y, "batch", "seq", "embed")

    h2 = apply_norm(p["norm2"], x, cfg)
    if mlp == "dense":
        y2 = apply_mlp(p["mlp"], h2, cfg)
    elif mlp == "moe":
        y2, aux = moe_mod.apply_moe(p["mlp"], h2, cfg)
    elif mlp == "rwkv_ffn":
        if mode == "decode":
            y2, new_cache["mlp"] = rwkv_mod.channel_mix_step(
                p["mlp"], h2, cache["mlp"], cfg)
        else:
            y2, new_cache["mlp"] = rwkv_mod.channel_mix_full(p["mlp"], h2, cfg)
    else:
        raise ValueError(mlp)
    x = hint(x + y2, "batch", "seq", "embed")
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Whole-model params
# ---------------------------------------------------------------------------

def init_lm(key: jax.Array, cfg: ModelConfig) -> Params:
    keys = jax.random.split(key, 8)
    dt = jnp.dtype(cfg.param_dtype)
    p: Params = {}
    if cfg.embed_inputs:
        p["embed"] = (jax.random.normal(keys[0],
                                        (cfg.vocab_size, cfg.d_model))
                      * 0.02).astype(dt)
    else:
        # stub modality frontend: a linear adapter over precomputed embeddings
        d_in = cfg.d_model
        p["adapter"] = (jax.random.normal(keys[0], (d_in, cfg.d_model))
                        / jnp.sqrt(d_in)).astype(dt)
        p["embed_out"] = (jax.random.normal(keys[5],
                                            (cfg.vocab_size, cfg.d_model))
                          * 0.02).astype(dt)

    p["prelude"] = [init_block(k, kind, cfg) for k, kind in
                    zip(jax.random.split(keys[1], max(len(cfg.prelude), 1)),
                        cfg.prelude)]

    n_rep = cfg.n_repeats
    group: Params = {}
    for j, kind in enumerate(cfg.pattern):
        sub_keys = jax.random.split(jax.random.fold_in(keys[2], j), n_rep)
        group[f"sub{j}"] = jax.vmap(
            lambda k, kind=kind: init_block(k, kind, cfg))(sub_keys)
    p["layers"] = group

    p["final_norm"] = init_norm(cfg)
    if not cfg.tie_embeddings:
        p["lm_head"] = (jax.random.normal(keys[3],
                                          (cfg.d_model, cfg.vocab_size))
                        / jnp.sqrt(cfg.d_model)).astype(dt)
    return p


def _unembed_matrix(params: Params, cfg: ModelConfig) -> jnp.ndarray:
    if not cfg.tie_embeddings:
        return params["lm_head"]
    emb = params.get("embed", params.get("embed_out"))
    return emb.T


# ---------------------------------------------------------------------------
# Stack application
# ---------------------------------------------------------------------------

def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)  # "full": save nothing


def apply_stack(params: Params, x: jnp.ndarray, cfg: ModelConfig, mode: str,
                cache: Optional[Params] = None,
                pos: Optional[jnp.ndarray] = None,
                positions: Optional[jnp.ndarray] = None):
    """Prelude layers + scanned pattern groups.

    cache layout: {"prelude": [block caches], "layers": {subj: stacked}}.
    Returns (x, new_cache, total_aux).
    """
    aux_total = jnp.zeros((), jnp.float32)
    new_cache: Params = {"prelude": [], "layers": {}}

    for i, kind in enumerate(cfg.prelude):
        c = cache["prelude"][i] if cache is not None else None
        x, nc, aux = apply_block(params["prelude"][i], x, kind, cfg, mode,
                                 c, pos, positions)
        new_cache["prelude"].append(nc)
        aux_total = aux_total + aux

    def group_body(carry, xs):
        x, aux_acc = carry
        p_grp = xs[0]
        c_grp = xs[1] if cache is not None else None
        nc_grp = {}
        for j, kind in enumerate(cfg.pattern):
            c = c_grp[f"sub{j}"] if c_grp is not None else None
            x, nc, aux = apply_block(p_grp[f"sub{j}"], x, kind, cfg, mode,
                                     c, pos, positions)
            nc_grp[f"sub{j}"] = nc
            aux_acc = aux_acc + aux
        return (x, aux_acc), nc_grp

    body = group_body
    if mode == "train":
        body = _remat(group_body, cfg)

    xs = (params["layers"],) if cache is None else (params["layers"],
                                                    cache["layers"])
    (x, aux_total), nc_layers = jax.lax.scan(
        body, (x, aux_total), xs, unroll=cfg.scan_unroll)
    new_cache["layers"] = nc_layers
    return x, new_cache, aux_total


# ---------------------------------------------------------------------------
# Losses / steps
# ---------------------------------------------------------------------------

def softmax_xent(h: jnp.ndarray, unembed: jnp.ndarray, labels: jnp.ndarray,
                 cfg: ModelConfig) -> jnp.ndarray:
    """Mean next-token cross entropy. ``cfg.logits_chunk`` > 0 computes the
    logsumexp over vocab chunks (python loop — stays while-free) to avoid
    materializing (B, S, V) in one piece."""
    B, S, d = h.shape
    V = unembed.shape[1]
    chunk = cfg.logits_chunk
    if chunk <= 0 or chunk >= V:
        logits = hint((h @ unembed).astype(jnp.float32),
                      "batch", "seq", "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, labels[..., None],
                                 axis=-1)[..., 0]
        return jnp.mean(lse - ll)

    n_chunks = -(-V // chunk)
    m = jnp.full((B, S), -jnp.inf, jnp.float32)
    s = jnp.zeros((B, S), jnp.float32)
    ll = jnp.zeros((B, S), jnp.float32)
    for i in range(n_chunks):
        lo = i * chunk
        w = unembed[:, lo:lo + chunk]
        lg = hint((h @ w).astype(jnp.float32), "batch", "seq", None)
        m_new = jnp.maximum(m, lg.max(-1))
        s = s * jnp.exp(m - m_new) + jnp.exp(lg - m_new[..., None]).sum(-1)
        m = m_new
        in_chunk = (labels >= lo) & (labels < lo + w.shape[1])
        idx = jnp.clip(labels - lo, 0, w.shape[1] - 1)
        ll = ll + jnp.where(
            in_chunk, jnp.take_along_axis(lg, idx[..., None], -1)[..., 0],
            0.0)
    lse = m + jnp.log(s)
    return jnp.mean(lse - ll)


def embed_tokens(params: Params, batch: Dict[str, jnp.ndarray],
                 cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """-> (x, positions). Handles token inputs and stub-embedding inputs."""
    if cfg.embed_inputs:
        tokens = batch["tokens"]
        x = jnp.take(params["embed"], tokens, axis=0).astype(
            jnp.dtype(cfg.dtype))
        B, S = tokens.shape
    else:
        x = (batch["embeds"] @ params["adapter"]).astype(jnp.dtype(cfg.dtype))
        B, S = x.shape[:2]
    x = hint(x, "batch", "seq", "embed")
    if cfg.mrope_sections is not None:
        positions = batch.get("positions")
        if positions is None:
            base = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
            positions = jnp.broadcast_to(base[None], (3, B, S))
    else:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    return x, positions


def lm_loss(params: Params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
            aux_weight: float = 0.01):
    """Training loss (+ metrics). batch: tokens/embeds + labels (B, S)."""
    x, positions = embed_tokens(params, batch, cfg)
    x, _, aux = apply_stack(params, x, cfg, "train", positions=positions)
    x = apply_norm(params["final_norm"], x, cfg)
    xent = softmax_xent(x, _unembed_matrix(params, cfg), batch["labels"], cfg)
    loss = xent + aux_weight * aux
    return loss, {"xent": xent, "aux": aux}


def lm_prefill(params: Params, batch: Dict[str, jnp.ndarray],
               cfg: ModelConfig):
    """Full forward returning (last-position logits, cache)."""
    x, positions = embed_tokens(params, batch, cfg)
    x, cache, _ = apply_stack(params, x, cfg, "prefill", positions=positions)
    x = apply_norm(params["final_norm"], x, cfg)
    logits = (x[:, -1:] @ _unembed_matrix(params, cfg)).astype(jnp.float32)
    return logits, cache


def lm_decode_step(params: Params, cache: Params, tokens: jnp.ndarray,
                   pos: jnp.ndarray, cfg: ModelConfig):
    """One decode step. tokens: (B, 1) int32 (or embeds (B,1,d) for stub
    frontends); pos: scalar int32. Returns (logits (B,1,V), new cache)."""
    if cfg.embed_inputs:
        x = jnp.take(params["embed"], tokens, axis=0).astype(
            jnp.dtype(cfg.dtype))
    else:
        x = (tokens @ params["adapter"]).astype(jnp.dtype(cfg.dtype))
    x, new_cache, _ = apply_stack(params, x, cfg, "decode", cache=cache,
                                  pos=pos)
    x = apply_norm(params["final_norm"], x, cfg)
    logits = (x @ _unembed_matrix(params, cfg)).astype(jnp.float32)
    return logits, new_cache


def lm_init_cache(params_or_none, cfg: ModelConfig, batch: int, max_seq: int
                  ) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    cache: Params = {
        "prelude": [block_cache_init(kind, cfg, batch, max_seq, dtype)
                    for kind in cfg.prelude],
        "layers": {},
    }
    n_rep = cfg.n_repeats
    for j, kind in enumerate(cfg.pattern):
        one = block_cache_init(kind, cfg, batch, max_seq, dtype)
        cache["layers"][f"sub{j}"] = jax.tree.map(
            lambda a: jnp.zeros((n_rep,) + a.shape, a.dtype), one)
    return cache
