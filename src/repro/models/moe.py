"""Mixture-of-Experts layer with sort-based top-k dispatch.

Capacity-bounded, fully vectorized, shardable: expert weights carry a leading
``num_experts`` axis that the mesh rules place on the ``model`` axis when the
expert count divides it (expert parallelism); otherwise experts stay
replicated and the FFN widths are tensor-parallel.

Supports DeepSeek-MoE-style *shared experts* (always-on dense path) and
returns the standard load-balancing auxiliary loss.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.utils.shard_hints import hint

Params = Dict[str, jnp.ndarray]


def init_moe(key: jax.Array, cfg: ModelConfig) -> Params:
    m = cfg.moe
    d, E, ef = cfg.d_model, m.num_experts, m.d_expert
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    si, so = 1.0 / math.sqrt(d), 1.0 / math.sqrt(ef)
    p = {
        "router": (jax.random.normal(k1, (d, E)) * si).astype(jnp.float32),
        "wi_gate": (jax.random.normal(k2, (E, d, ef)) * si).astype(dt),
        "wi_up": (jax.random.normal(k3, (E, d, ef)) * si).astype(dt),
        "wo": (jax.random.normal(k4, (E, ef, d)) * so).astype(dt),
    }
    if m.num_shared > 0:
        sf = m.num_shared * ef
        ks1, ks2, ks3 = jax.random.split(k5, 3)
        p["shared"] = {
            "wi_gate": (jax.random.normal(ks1, (d, sf)) * si).astype(dt),
            "wi_up": (jax.random.normal(ks2, (d, sf)) * si).astype(dt),
            "wo": (jax.random.normal(ks3, (sf, d)) * so).astype(dt),
        }
    return p


def _dispatch_group(xt: jnp.ndarray, eidx: jnp.ndarray, gate: jnp.ndarray,
                    E: int, cap: int):
    """Per-group sort-based dispatch.  xt: (T, d); eidx/gate: (T, k).

    Returns (buf (E, cap, d), combine metadata) — pure per-group math so
    the caller can vmap it over batch groups, keeping the group axis
    sharded over the data axes (no global token buffer)."""
    T, d = xt.shape
    k = eidx.shape[1]
    e_flat = eidx.reshape(-1)
    tok_flat = jnp.repeat(jnp.arange(T), k)
    gate_flat = gate.reshape(-1)

    order = jnp.argsort(e_flat)                       # stable
    e_sort = e_flat[order]
    tok_sort = tok_flat[order]
    gate_sort = gate_flat[order]

    counts = jnp.bincount(e_flat, length=E)
    starts = jnp.cumsum(counts) - counts              # exclusive
    pos = jnp.arange(T * k) - starts[e_sort]          # slot within expert
    keep = pos < cap
    pos_c = jnp.where(keep, pos, 0)
    gate_sort = jnp.where(keep, gate_sort, 0.0)

    buf = jnp.zeros((E, cap, d), xt.dtype)
    buf = buf.at[e_sort, pos_c].add(
        jnp.where(keep[:, None], xt[tok_sort], 0.0))
    return buf, (e_sort, pos_c, tok_sort, gate_sort, keep)


def _combine_group(eout: jnp.ndarray, meta, T: int) -> jnp.ndarray:
    e_sort, pos_c, tok_sort, gate_sort, keep = meta
    y_sort = eout[e_sort, pos_c] * gate_sort[:, None].astype(eout.dtype)
    return jnp.zeros((T, eout.shape[-1]), eout.dtype).at[tok_sort].add(
        jnp.where(keep[:, None], y_sort, 0.0))


def apply_moe(p: Params, x: jnp.ndarray, cfg: ModelConfig
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (out, aux_loss).

    GROUPED dispatch (perf iteration #1, see EXPERIMENTS.md): tokens are
    dispatched *within their batch row*, producing (B, E, cap_row, d)
    buffers whose leading axis stays sharded over the data axes.  The
    original flat-token formulation built one global (E, Nt*k*cf/E, d)
    buffer that SPMD could not shard on its token axis -> it replicated
    ~126 GB/device and serialized dispatch through cross-device scatters.
    Expert parallelism then happens purely in the (g e c d) x (e d f)
    einsums (all-to-all over the model axis when E divides it).
    """
    m = cfg.moe
    B, S, d = x.shape
    E, k = m.num_experts, m.top_k

    logits = (x.astype(jnp.float32)
              @ p["router"].astype(jnp.float32))          # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, k)                   # (B, S, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # Load-balancing aux loss (Switch/Mixtral style), over all tokens.
    pe = probs.reshape(-1, E).mean(axis=0)
    fe = jnp.zeros((E,), jnp.float32).at[eidx.reshape(-1)].add(
        1.0 / (B * S * k))
    aux = E * jnp.sum(pe * fe)

    cap = max(1, int(math.ceil(S * k / E * m.capacity_factor)))

    buf, meta = jax.vmap(
        lambda xr, er, gr: _dispatch_group(xr, er, gr, E, cap))(
            x, eidx, gate)                                 # (B, E, cap, d)
    buf = hint(buf, "batch", "expert", "capacity", "embed")

    # Batched expert FFN: (B, E, C, d) x (E, d, ef) -> (B, E, C, ef)
    g = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, p["wi_gate"]))
    u = jnp.einsum("becd,edf->becf", buf, p["wi_up"])
    g = hint(g, "batch", "expert", "capacity", "expert_mlp")
    u = hint(u, "batch", "expert", "capacity", "expert_mlp")
    eout = hint(jnp.einsum("becf,efd->becd", g * u, p["wo"]),
                "batch", "expert", "capacity", "embed")    # (B, E, C, d)

    out = jax.vmap(lambda eo, me: _combine_group(eo, me, S))(eout, meta)
    out = hint(out, "batch", "seq", "embed")

    if m.num_shared > 0:
        sp = p["shared"]
        sg = jax.nn.silu(hint(x @ sp["wi_gate"], "batch", "seq", "mlp")) \
            * hint(x @ sp["wi_up"], "batch", "seq", "mlp")
        out = out + sg @ sp["wo"]

    return out, aux
