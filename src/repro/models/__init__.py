"""Model zoo: dense/MoE/SSM/hybrid/enc-dec backbones as pure functions."""

from .model import ModelAPI, build_model

__all__ = ["ModelAPI", "build_model"]
