"""RWKV-6 ("Finch") blocks: time-mix with data-dependent decay + channel-mix.

The WKV recurrence per head (head dim n, per batch):

    S_t = diag(w_t) S_{t-1} + k_t v_t^T          (state: n x n)
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)

with per-channel decay ``w_t = exp(-exp(ww_t))`` computed from the input via
a LoRA (the paper's data-dependent decay).  Three execution paths:

* ``wkv_chunked`` — train/prefill: chunkwise *matmul* form with pairwise
  log-space decays (numerically exact, no exp overflow, while-loop free —
  important for the roofline accounting and TPU-friendly: the inner products
  hit the MXU).
* ``wkv_step`` — single-token decode against a carried (n x n) state.
* ``repro.kernels.rwkv6`` — the Pallas TPU kernel implementing the same
  chunked algorithm (ref.py oracle == wkv_chunked here).
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.utils.shard_hints import hint

Params = Dict[str, jnp.ndarray]


def init_time_mix(key: jax.Array, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    n = cfg.rwkv_head_dim
    H = d // n
    lora = cfg.rwkv_decay_lora
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    s = 1.0 / math.sqrt(d)
    return {
        # token-shift interpolation weights per stream
        "mu_r": jnp.full((d,), 0.5, dt), "mu_k": jnp.full((d,), 0.5, dt),
        "mu_v": jnp.full((d,), 0.5, dt), "mu_g": jnp.full((d,), 0.5, dt),
        "mu_w": jnp.full((d,), 0.5, dt),
        "wr": (jax.random.normal(ks[0], (d, d)) * s).astype(dt),
        "wk": (jax.random.normal(ks[1], (d, d)) * s).astype(dt),
        "wv": (jax.random.normal(ks[2], (d, d)) * s).astype(dt),
        "wg": (jax.random.normal(ks[3], (d, d)) * s).astype(dt),
        "wo": (jax.random.normal(ks[4], (d, d)) * s).astype(dt),
        # data-dependent decay: ww = w_base + tanh(xw A) B
        "w_base": jnp.full((d,), -0.6, jnp.float32),
        "w_A": (jax.random.normal(ks[5], (d, lora)) * s).astype(dt),
        "w_B": (jax.random.normal(ks[6], (lora, d)) *
                (1.0 / math.sqrt(lora))).astype(dt),
        "u": (jax.random.normal(ks[7], (H, n)) * 0.1).astype(jnp.float32),
        "ln_out": jnp.ones((d,), dt),  # per-head group norm scale
    }


def _token_shift(x: jnp.ndarray, x_prev: jnp.ndarray) -> jnp.ndarray:
    """Shifted sequence: row t sees row t-1 (x_prev seeds row 0)."""
    return jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)


def _project(p: Params, x: jnp.ndarray, x_prev: jnp.ndarray,
             cfg: ModelConfig):
    xs = _token_shift(x, x_prev)

    def lerp(mu):
        return x + (xs - x) * mu

    r = hint(lerp(p["mu_r"]) @ p["wr"], "batch", "seq", "mlp")
    k = hint(lerp(p["mu_k"]) @ p["wk"], "batch", "seq", "mlp")
    v = hint(lerp(p["mu_v"]) @ p["wv"], "batch", "seq", "mlp")
    g = hint(lerp(p["mu_g"]) @ p["wg"], "batch", "seq", "mlp")
    ww = p["w_base"] + (jnp.tanh(lerp(p["mu_w"]) @ p["w_A"])
                        @ p["w_B"]).astype(jnp.float32)
    logw = -jnp.exp(ww)  # per-channel log decay, always < 0
    return r, k, v, g, logw


def _heads(x: jnp.ndarray, n: int) -> jnp.ndarray:
    B, S, d = x.shape
    return x.reshape(B, S, d // n, n)


def wkv_chunked(r, k, v, logw, u, chunk: int = 32):
    """Chunkwise-parallel WKV. All inputs (B, S, H, n) except u (H, n).

    Within a chunk, pairwise decay products are formed in log space
    (exponents always <= 0 -> stable); across chunks a (B, H, n, n) state is
    carried with the chunk's total decay.  Output (B, S, H, n), float32.
    """
    B, S, H, n = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    C = S // chunk
    f32 = jnp.float32
    rc = r.astype(f32).reshape(B, C, chunk, H, n)
    kc = k.astype(f32).reshape(B, C, chunk, H, n)
    vc = v.astype(f32).reshape(B, C, chunk, H, n)
    lw = logw.astype(f32).reshape(B, C, chunk, H, n)

    # Cumulative log-decay within each chunk: Lc[t] = sum_{s<=t} logw[s].
    Lc = jnp.cumsum(lw, axis=2)                       # (B,C,c,H,n)
    Lc_prev = Lc - lw                                 # exclusive: sum_{s<t}
    total = Lc[:, :, -1]                              # (B,C,H,n)

    # ---- intra-chunk: y_t += sum_{j<t} (r_t . e^{Lc_{t-1}-Lc_j} k_j) v_j
    # pairwise exponent (<=0): D[t,j] = Lc_prev[t] - Lc[j]  for j < t
    Dexp = Lc_prev[:, :, :, None] - Lc[:, :, None]    # (B,C,c,c,H,n)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
    Dexp = jnp.where(tri[None, None, :, :, None, None], Dexp, -jnp.inf)
    att = jnp.einsum("bcthn,bcjhn,bctjhn->bctjh", rc, kc,
                     jnp.exp(Dexp))                   # (B,C,c,c,H)
    y_intra = jnp.einsum("bctjh,bcjhn->bcthn", att, vc)

    # diagonal (current token) bonus term: (r_t . u k_t) v_t
    diag = jnp.einsum("bcthn,hn,bcthn->bcth", rc, u.astype(f32), kc)
    y_intra = y_intra + diag[..., None] * vc

    # ---- inter-chunk: carry state S (B,H,n,n), decayed by e^{total}
    # chunk contribution to state: sum_j e^{total - Lc_j} k_j v_j^T
    k_tail = kc * jnp.exp(total[:, :, None] - Lc)     # (B,C,c,H,n)
    chunk_state = jnp.einsum("bcjhn,bcjhm->bchnm", k_tail, vc)

    def body(S0, xs):
        r_i, Lcp_i, tot_i, cs_i = xs
        # y_t += (r_t * e^{Lc_prev,t})^T S0
        y = jnp.einsum("bthn,bhnm->bthm", r_i * jnp.exp(Lcp_i), S0)
        S1 = S0 * jnp.exp(tot_i)[..., None] + cs_i
        return S1, y

    xs = (jnp.moveaxis(rc, 1, 0), jnp.moveaxis(Lc_prev, 1, 0),
          jnp.moveaxis(total, 1, 0), jnp.moveaxis(chunk_state, 1, 0))
    S0 = jnp.zeros((B, H, n, n), f32)
    # unroll=True: keeps the layer stack as the *only* while loop in the HLO,
    # which the roofline accounting relies on (see utils/hlo.py); the body is
    # just two small einsums so the HLO growth is modest.
    S_last, y_inter = jax.lax.scan(body, S0, xs, unroll=True)
    y_inter = jnp.moveaxis(y_inter, 0, 1)             # (B,C,c,H,n)

    y = (y_intra + y_inter).reshape(B, S, H, n)
    return y, S_last


def wkv_step(r, k, v, logw, u, state):
    """One decode step. r/k/v/logw: (B, H, n); state: (B, H, n, n)."""
    f32 = jnp.float32
    r, k, v, logw = (t.astype(f32) for t in (r, k, v, logw))
    a = k[..., :, None] * v[..., None, :]             # (B,H,n,n)
    y = jnp.einsum("bhn,bhnm->bhm", r, state + u[..., :, None] * a)
    new_state = state * jnp.exp(logw)[..., :, None] + a
    return y, new_state


def _group_norm(y: jnp.ndarray, scale: jnp.ndarray, eps: float,
                n: int) -> jnp.ndarray:
    """Per-head normalization of the WKV output (RWKV's GroupNorm)."""
    B = y.shape[0]
    yh = y.reshape(*y.shape[:-1], y.shape[-1] // n, n) \
        if y.ndim == 3 else y
    mu = yh.mean(-1, keepdims=True)
    var = yh.var(-1, keepdims=True)
    yn = (yh - mu) * jax.lax.rsqrt(var + eps)
    yn = yn.reshape(y.shape)
    return yn * scale.astype(yn.dtype)


def time_mix_full(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                  chunk: int = 32
                  ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Full-sequence time-mix (train/prefill). Returns (out, state cache)."""
    B, S, d = x.shape
    n = cfg.rwkv_head_dim
    x_prev0 = jnp.zeros((B, d), x.dtype)
    r, k, v, g, logw = _project(p, x, x_prev0, cfg)
    if cfg.attention_impl == "pallas":
        from repro.kernels.rwkv6.ops import rwkv6_chunked as wkv_impl
        y, S_last = wkv_impl(_heads(r, n), _heads(k, n), _heads(v, n),
                             _heads(logw, n), p["u"], chunk=chunk)
    else:
        y, S_last = wkv_chunked(_heads(r, n), _heads(k, n), _heads(v, n),
                                _heads(logw, n), p["u"], chunk=chunk)
    y = y.reshape(B, S, d).astype(x.dtype)
    y = _group_norm(y, p["ln_out"], cfg.norm_eps, n)
    out = (y * jax.nn.silu(g)) @ p["wo"]
    cache = {"state": S_last, "x_prev": x[:, -1, :]}
    return out, cache


def time_mix_step(p: Params, x: jnp.ndarray, cache: Dict[str, jnp.ndarray],
                  cfg: ModelConfig
                  ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Decode step; x: (B, 1, d)."""
    B, _, d = x.shape
    n = cfg.rwkv_head_dim
    r, k, v, g, logw = _project(p, x, cache["x_prev"], cfg)
    H = d // n
    rh, kh, vh, lwh = (t.reshape(B, H, n) for t in
                       (r[:, 0], k[:, 0], v[:, 0], logw[:, 0]))
    y, new_state = wkv_step(rh, kh, vh, lwh, p["u"], cache["state"])
    y = y.reshape(B, 1, d).astype(x.dtype)
    y = _group_norm(y, p["ln_out"], cfg.norm_eps, n)
    out = (y * jax.nn.silu(g)) @ p["wo"]
    return out, {"state": new_state, "x_prev": x[:, 0, :]}


# ---------------------------------------------------------------------------
# Channel mix (the RWKV FFN)
# ---------------------------------------------------------------------------

def init_channel_mix(key: jax.Array, cfg: ModelConfig) -> Params:
    d, ff = cfg.d_model, cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "mu_k": jnp.full((d,), 0.5, dt), "mu_r": jnp.full((d,), 0.5, dt),
        "wk": (jax.random.normal(k1, (d, ff)) / math.sqrt(d)).astype(dt),
        "wv": (jax.random.normal(k2, (ff, d)) / math.sqrt(ff)).astype(dt),
        "wr": (jax.random.normal(k3, (d, d)) / math.sqrt(d)).astype(dt),
    }


def channel_mix_full(p: Params, x: jnp.ndarray, cfg: ModelConfig
                     ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    B, S, d = x.shape
    xs = _token_shift(x, jnp.zeros((B, d), x.dtype))
    xk = x + (xs - x) * p["mu_k"]
    xr = x + (xs - x) * p["mu_r"]
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    out = jax.nn.sigmoid(xr @ p["wr"]) * (k @ p["wv"])
    return out, {"x_prev": x[:, -1, :]}


def channel_mix_step(p: Params, x: jnp.ndarray, cache: Dict[str, jnp.ndarray],
                     cfg: ModelConfig
                     ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    xs = cache["x_prev"][:, None, :]
    xk = x + (xs - x) * p["mu_k"]
    xr = x + (xs - x) * p["mu_r"]
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    out = jax.nn.sigmoid(xr @ p["wr"]) * (k @ p["wv"])
    return out, {"x_prev": x[:, 0, :]}
