"""Execute uncertainty-benchmark workload sessions against the LSM engine.

Mirrors the paper's Section 9.2 experiment design: the database is
initialized with N unique keys; each session executes a sampled workload
(z0, z1, q, w mix) for a fixed number of queries, measuring average I/Os per
query with compaction I/O amortized over writes.

The execution layer of the engine refactor: a session is *materialized*
first (:func:`materialize_session` draws every query of the session up
front, with the exact rng call sequence of per-query execution, into a
:class:`SessionPlan` of query arrays) and then *executed* in vectorized
phases (:func:`execute_session`): maximal runs of point reads become one
``classify_point_batch``, ranges one ``range_query_batch``, consecutive
writes one ``put_batch`` — phase boundaries fall only at read<->write
transitions, so the tree state seen by every query, and therefore the
measured ``IOStats``, is identical to per-query execution.

:func:`run_fleet` runs a whole (tree x session) grid — the Section 9
system-based evaluation — on these primitives, materializing each distinct
session plan once and replaying it against every tree that shares its key
set (e.g. the nominal and robust deployment of the same expected workload).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro import obs

from .engine import IOStats, LSMTree, TOMBSTONE
from .store import TOMB


@dataclasses.dataclass
class SessionResult:
    workload: np.ndarray
    queries: int
    avg_io_per_query: float
    io: IOStats
    #: per-flush-window observed op counts, shape (n_windows, 4) int64 in
    #: (z0, z1, q, w) order — the observation stream of the online drift
    #: subsystem (:mod:`repro.online`).  Window w covers the query stream
    #: between two flush boundaries (the final window is the unflushed
    #: tail), so the rows sum exactly to the session plan's op counts.
    window_ops: Optional[np.ndarray] = None

    @property
    def throughput(self) -> float:
        return 1.0 / max(self.avg_io_per_query, 1e-9)

    @property
    def observed_mix(self) -> np.ndarray:
        """The session's executed (z0, z1, q, w) mix, from the counters."""
        c = self.window_ops.sum(axis=0).astype(np.float64)
        return c / max(c.sum(), 1.0)


@dataclasses.dataclass
class SessionPlan:
    """A fully-materialized workload session: query kinds in stream order
    plus the per-kind argument arrays, consumed in order by the executor."""

    workload: np.ndarray       # normalized (z0, z1, q, w)
    kinds: np.ndarray          # (n_queries,) 0=z0 1=z1 2=q 3=w
    point_keys: np.ndarray     # uint64, one per kind-0/1 query, stream order
    range_los: np.ndarray      # uint64, one per kind-2 query
    range_his: np.ndarray
    write_keys: np.ndarray     # uint64, one per kind-3 query
    #: optional per-write delete mask: True marks a write that is a
    #: tombstone for an existing key (tombstone-churn scenarios); None
    #: means every write is a fresh insert (the classic sessions).
    write_tombs: Optional[np.ndarray] = None

    @property
    def n_queries(self) -> int:
        return len(self.kinds)

    @property
    def insert_keys(self) -> np.ndarray:
        """Fresh-key inserts only (delete targets excluded) — the keys a
        caller appends to its live-key population after the session."""
        if self.write_tombs is None:
            return self.write_keys
        return self.write_keys[~self.write_tombs]


def draw_keys(n: int, seed: int = 7, key_space: int = 2 ** 48) -> np.ndarray:
    """The population key draw, exposed so fleets can share one draw."""
    rng = np.random.default_rng(seed)
    return rng.choice(key_space, size=n, replace=False).astype(np.uint64)


def populate(tree: LSMTree, n: int, seed: int = 7,
             key_space: int = 2 ** 48,
             keys: Optional[np.ndarray] = None) -> np.ndarray:
    """Insert n unique random keys; returns the key array (for z1 queries).

    Keys go in via :meth:`LSMTree.put_batch` in buffer-sized chunks (each
    flushed as a sorted run, as ``put`` + ``flush`` would).  Pass ``keys``
    (from :func:`draw_keys`) to skip the draw when several trees share a
    population."""
    if keys is None:
        keys = draw_keys(n, seed=seed, key_space=key_space)
    values = (keys % np.uint64(997)).astype(np.int64)
    tree.put_batch(keys, values)
    tree.flush()
    # Population writes/compactions are setup cost, not workload cost.
    tree.stats = IOStats()
    return keys


def materialize_session(existing_keys: np.ndarray, w: np.ndarray,
                        n_queries: int = 2000, seed: int = 0,
                        key_space: int = 2 ** 48,
                        range_fraction: float = 2e-5,
                        zipf_a: Optional[float] = None,
                        hot_offset: int = 0,
                        delete_fraction: float = 0.0) -> SessionPlan:
    """Draw every query of a session up front.

    The rng call sequence is exactly that of per-query execution (kinds,
    then the fresh-key block, then one draw per read/range query in stream
    order), so a plan is bit-identical to what the pre-refactor runner
    executed for the same seed.  Non-empty reads sample keys known to exist
    (optionally Zipfian-ranked, Section 9.3 "Workload Skew"); empty reads
    sample the same domain but miss; range queries use a small span; writes
    insert fresh keys.

    Scenario shaping (:mod:`repro.scenarios`) extends the draw without
    perturbing it for default parameters: ``hot_offset`` rotates the
    rank->key mapping of non-empty reads (hot-set migration — a post-draw
    modular shift, so the rng sequence is untouched), and
    ``delete_fraction`` retargets that fraction of the session's writes as
    tombstones for the *oldest* live keys, drawn after the main loop so
    every classic draw is unchanged."""
    rng = np.random.default_rng(seed)
    w = np.asarray(w, np.float64)
    w = w / w.sum()
    kinds = rng.choice(4, size=n_queries, p=w)
    span = max(1, int(range_fraction * key_space))
    existing = np.asarray(existing_keys, np.uint64)
    n_writes = int((kinds == 3).sum())
    fresh = rng.choice(key_space, size=max(n_writes, 1) + 8,
                       replace=False).astype(np.uint64)
    point_keys: List[int] = []
    range_los: List[int] = []
    range_his: List[int] = []
    for kind in kinds:
        if kind == 0:        # empty point read: perturb to near-certain miss
            point_keys.append(int(rng.integers(0, key_space)) | (1 << 60))
        elif kind == 1:      # non-empty point read
            if zipf_a is not None:
                idx = min(len(existing) - 1, rng.zipf(zipf_a) - 1)
            else:
                idx = int(rng.integers(0, len(existing)))
            if hot_offset:
                idx = (idx + int(hot_offset)) % len(existing)
            point_keys.append(int(existing[idx]))
        elif kind == 2:      # short range query
            lo = int(rng.integers(0, key_space - span))
            range_los.append(lo)
            range_his.append(lo + span)
    write_keys = fresh[:n_writes]
    write_tombs = None
    if delete_fraction > 0.0 and n_writes and len(existing):
        pool = max(1, len(existing) // 2)    # the oldest half of the keys
        n_del = min(int(round(delete_fraction * n_writes)), n_writes, pool)
        if n_del > 0:
            slots = np.sort(rng.choice(n_writes, size=n_del, replace=False))
            targets = np.sort(rng.choice(pool, size=n_del, replace=False))
            write_keys = write_keys.copy()
            write_keys[slots] = existing[targets]
            write_tombs = np.zeros(n_writes, bool)
            write_tombs[slots] = True
    return SessionPlan(workload=w, kinds=kinds,
                       point_keys=np.asarray(point_keys, np.uint64),
                       range_los=np.asarray(range_los, np.uint64),
                       range_his=np.asarray(range_his, np.uint64),
                       write_keys=write_keys,
                       write_tombs=write_tombs)


def _resolve_against_pending(tree: LSMTree, read_keys: np.ndarray,
                             read_pos: np.ndarray, write_keys: np.ndarray,
                             write_pos: np.ndarray, write_encs):
    """Per-read resolution against the evolving write buffer of a window.

    A read at stream position p sees the buffer as it was at window start
    (the tree's live buffer) plus every window write at a position < p,
    newest wins.  Key collisions between reads and pending writes are rare
    (writes are fresh draws), so the per-collision position check is a tiny
    fallback loop under vectorized candidate detection.  ``write_encs`` is
    the per-write encoded value (a scalar broadcasts) — tombstone-churn
    sessions pass ``TOMB`` entries so a read after a pending delete
    resolves to not-found."""
    n = len(read_keys)
    resolved = np.zeros(n, bool)
    found = np.zeros(n, bool)
    enc = np.zeros(n, np.int64)
    if tree.buffer:
        bkeys, benc = tree._buffer_sorted()
        hit, henc = LSMTree.resolve_in_sorted(bkeys, benc, read_keys)
        if hit.any():
            resolved |= hit
            found[hit] = henc != TOMB
            enc[hit] = henc
    if len(write_keys):
        wenc = np.broadcast_to(np.asarray(write_encs, np.int64),
                               write_keys.shape)
        order = np.argsort(write_keys, kind="stable")  # pos ascending in ties
        wks = write_keys[order]
        wps = write_pos[order]
        wes = wenc[order]
        lo = np.searchsorted(wks, read_keys, side="left")
        hi = np.searchsorted(wks, read_keys, side="right")
        for i in np.flatnonzero(hi > lo):
            j = int(np.searchsorted(wps[lo[i]:hi[i]], read_pos[i]))
            if j > 0:
                e = int(wes[lo[i] + j - 1])    # latest write before the read
                resolved[i] = True
                found[i] = e != TOMB
                enc[i] = e
    return resolved, found, enc


def execute_session(tree: LSMTree, plan: SessionPlan,
                    f_a: float = 1.0, f_seq: float = 1.0) -> SessionResult:
    """Execute a materialized session in vectorized flush windows.

    The levels of the tree change only when the buffer flushes, so the
    query stream is cut at flush boundaries only: within a window, every
    point read resolves against the (exactly simulated) evolving buffer
    plus the static levels in one ``classify_point_batch``, every range
    query joins one ``range_query_batch`` (range I/O accounting never
    touches the buffer), and the window's writes land in one ``put_batch``
    whose final insertion triggers the flush that ends the window.
    Per-query I/O accounting is position-independent within a window, so
    measured ``IOStats`` equals per-query execution exactly."""
    with obs.track(tree.obs_label), obs.span("session.execute") as sp:
        return _execute_session(tree, plan, f_a, f_seq, sp)


def _execute_session(tree: LSMTree, plan: SessionPlan, f_a: float,
                     f_seq: float, sp) -> SessionResult:
    before = tree.stats.snapshot()
    kinds = plan.kinds
    n = len(kinds)
    pos = np.arange(n)
    pt_pos = pos[kinds <= 1]
    rq_pos = pos[kinds == 2]
    wr_pos = pos[kinds == 3]
    cap = tree.cfg.buf_entries
    write_enc = tree.store.codec.encode(1)    # sessions write value 1
    tombs = plan.write_tombs
    write_encs_all = None
    if tombs is not None:
        write_encs_all = np.where(tombs, TOMB, write_enc).astype(np.int64)
    pi = qi = wi = 0
    n_wr = len(wr_pos)
    win_start = 0
    win_counts: List[np.ndarray] = []
    while pi < len(pt_pos) or qi < len(rq_pos) or wi < n_wr:
        # -- window extent: writes until the buffer reaches capacity --------
        if wi < n_wr:
            w_rem = plan.write_keys[wi:]
            room = cap - len(tree.buffer)
            if tree.buffer:
                buf_keys = np.fromiter(tree.buffer.keys(), np.uint64,
                                       len(tree.buffer))
                fresh = ~np.isin(w_rem, buf_keys)   # dups don't grow the buffer
            else:
                fresh = np.ones(len(w_rem), bool)
            cut = int(np.searchsorted(np.cumsum(fresh), room))
            if cut < len(w_rem):
                m = cut + 1
                win_end = int(wr_pos[wi + m - 1])   # flush fires at this put
            else:
                m = len(w_rem)
                win_end = n
        else:
            m = 0
            win_end = n
        # -- observed op mix of the window (z0/z1/q/w counts): the window
        #    covers stream positions [win_start, win_end] when the flush
        #    fires at win_end, or the whole tail when it doesn't -----------
        boundary = win_end + 1 if win_end < n else n
        win_counts.append(np.bincount(kinds[win_start:boundary],
                                      minlength=4).astype(np.int64))
        if obs.enabled():
            obs.event("session.window", index=len(win_counts) - 1,
                      ops=win_counts[-1].tolist())
        win_start = boundary
        # -- reads of the window, against pre-flush levels ------------------
        pt_hi = int(np.searchsorted(pt_pos, win_end))
        if pt_hi > pi:
            rk = plan.point_keys[pi:pt_hi]
            pend_enc = write_enc if write_encs_all is None \
                else write_encs_all[wi:wi + m]
            resolved, found, enc = _resolve_against_pending(
                tree, rk, pt_pos[pi:pt_hi], plan.write_keys[wi:wi + m],
                wr_pos[wi:wi + m], pend_enc)
            tree.classify_point_batch(rk, resolved=resolved, found=found,
                                      enc=enc, use_buffer=False)
            pi = pt_hi
        rq_hi = int(np.searchsorted(rq_pos, win_end))
        if rq_hi > qi:
            tree.range_query_batch(plan.range_los[qi:rq_hi],
                                   plan.range_his[qi:rq_hi])
            qi = rq_hi
        # -- the window's writes (put_batch flushes at the boundary) --------
        if m:
            tslice = tombs[wi:wi + m] if tombs is not None else None
            if tslice is not None and tslice.any():
                vals = np.empty(m, object)
                vals[:] = 1
                for j in np.flatnonzero(tslice):
                    vals[j] = TOMBSTONE
                tree.put_batch(plan.write_keys[wi:wi + m], vals)
            else:   # int fast path: classic sessions are bit-unchanged
                tree.put_batch(plan.write_keys[wi:wi + m],
                               np.ones(m, np.int64))
            wi += m
    delta = tree.stats.minus(before)
    reads_io = delta.random_reads + f_seq * delta.seq_reads
    write_io = f_seq * (delta.comp_pages_read + f_a * delta.comp_pages_written)
    avg = (reads_io + write_io) / max(n, 1)
    window_ops = np.stack(win_counts) if win_counts \
        else np.zeros((0, 4), np.int64)
    result = SessionResult(workload=plan.workload, queries=n,
                           avg_io_per_query=avg, io=delta,
                           window_ops=window_ops)
    if sp:
        sp.set(label=tree.obs_label, queries=n, windows=len(win_counts),
               avg_io=round(float(avg), 9),
               mix=[round(float(x), 9) for x in result.observed_mix],
               io=delta.as_dict())
        obs.count("session.executed")
        obs.count("session.windows", len(win_counts))
    return result


def run_session(tree: LSMTree, existing_keys: np.ndarray, w: np.ndarray,
                n_queries: int = 2000, seed: int = 0,
                key_space: int = 2 ** 48,
                range_fraction: float = 2e-5,
                f_a: float = 1.0, f_seq: float = 1.0,
                zipf_a: Optional[float] = None) -> SessionResult:
    """Run one workload session; returns measured avg I/O per query."""
    plan = materialize_session(existing_keys, w, n_queries=n_queries,
                               seed=seed, key_space=key_space,
                               range_fraction=range_fraction, zipf_a=zipf_a)
    return execute_session(tree, plan, f_a=f_a, f_seq=f_seq)


def run_fleet(trees: Sequence[LSMTree], sessions,
              existing_keys, n_queries: int = 2000, seeds=None,
              key_space: int = 2 ** 48, range_fraction: float = 2e-5,
              f_a: float = 1.0, f_seq: float = 1.0,
              zipf_a: Optional[float] = None) -> List[List[SessionResult]]:
    """Run the full (tree x session) grid; returns ``results[tree][sess]``.

    ``sessions`` is an (S, 4) array of workload mixes.  ``existing_keys``
    is either one key array shared by every tree or a per-tree list;
    ``seeds`` is the per-(tree, session) seed matrix — an (S,) vector is
    broadcast to all trees.  Trees that share a key array and a seed row
    (the bench's nominal/robust pair per expected workload) share one
    materialized :class:`SessionPlan` per session, so the whole Section 9
    grid is one call with no redundant materialization."""
    sessions = np.atleast_2d(np.asarray(sessions, np.float64))
    n_trees, n_sess = len(trees), sessions.shape[0]
    if isinstance(existing_keys, np.ndarray):
        keys_list = [existing_keys] * n_trees
    else:
        keys_list = list(existing_keys)
        if len(keys_list) != n_trees:
            raise ValueError(f"{len(keys_list)} key arrays for "
                             f"{n_trees} trees")
    seeds = np.arange(n_sess) if seeds is None else np.asarray(seeds)
    if seeds.ndim == 1:
        seeds = np.broadcast_to(seeds, (n_trees, n_sess))
    plans: dict = {}
    out: List[List[SessionResult]] = []
    for t, tree in enumerate(trees):
        row: List[SessionResult] = []
        for s in range(n_sess):
            cache_key = (id(keys_list[t]), int(seeds[t, s]), s)
            plan = plans.get(cache_key)
            if plan is None:
                plan = materialize_session(
                    keys_list[t], sessions[s], n_queries=n_queries,
                    seed=int(seeds[t, s]), key_space=key_space,
                    range_fraction=range_fraction, zipf_a=zipf_a)
                plans[cache_key] = plan
            row.append(execute_session(tree, plan, f_a=f_a, f_seq=f_seq))
        out.append(row)
    return out


def run_policy_fleet(phis, sys, policies, sessions, n_keys: int,
                     n_queries: int = 2000, seed: int = 7,
                     key_space: int = 2 ** 48, range_fraction: float = 2e-5,
                     policy_params=None, entry_bytes: int = 64,
                     f_a: float = 1.0, f_seq: float = 1.0, seeds=None,
                     zipf_a: Optional[float] = None):
    """The (tuning x compaction-policy x session) grid in one fleet call.

    Builds one tree per (phi, policy) cell — ``phis`` are tuner outputs
    (:class:`repro.core.Phi`), ``policies`` names from
    :data:`repro.lsm.planner.POLICIES`, ``policy_params`` an optional
    per-policy dict of constructor kwargs — populates every tree from ONE
    shared key draw, and runs every session against every tree via
    :func:`run_fleet` (each session materialized once for the whole grid).

    Returns ``(trees, results)`` with both indexed ``[phi][policy]``:
    ``results[p][j][s]`` is the :class:`SessionResult` of tuning ``p``
    under policy ``policies[j]`` on session ``s``.
    """
    try:
        phis = list(phis)
    except TypeError:
        phis = [phis]
    policy_params = policy_params or {}
    keys = draw_keys(n_keys, seed=seed, key_space=key_space)
    trees: List[List[LSMTree]] = []
    for phi in phis:
        row = []
        for pol in policies:
            params = tuple(sorted(policy_params.get(pol, {}).items()))
            tree = LSMTree.from_phi(phi, sys, expected_entries=n_keys,
                                    entry_bytes=entry_bytes, policy=pol,
                                    policy_params=params)
            populate(tree, n_keys, key_space=key_space, keys=keys)
            row.append(tree)
        trees.append(row)
    flat = [t for row in trees for t in row]
    results_flat = run_fleet(flat, sessions, keys, n_queries=n_queries,
                             seeds=seeds, key_space=key_space,
                             range_fraction=range_fraction, f_a=f_a,
                             f_seq=f_seq, zipf_a=zipf_a)
    n_pol = len(policies)
    results = [results_flat[i * n_pol:(i + 1) * n_pol]
               for i in range(len(phis))]
    return trees, results


def measured_cost_vector(tree_factory, n_keys: int, n_queries: int = 2000,
                         seed: int = 0) -> np.ndarray:
    """Measure per-class I/O costs (z0, z1, q, w) with pure sessions.

    Used to validate the analytic cost vector c(Phi) component-wise."""
    out = []
    pure = np.eye(4) * 0.97 + 0.01
    for i in range(4):
        tree = tree_factory()
        keys = populate(tree, n_keys, seed=seed)
        res = run_session(tree, keys, pure[i], n_queries=n_queries,
                          seed=seed + i)
        out.append(res.avg_io_per_query)
    return np.asarray(out)
