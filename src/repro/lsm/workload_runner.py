"""Execute uncertainty-benchmark workload sessions against the LSM engine.

Mirrors the paper's Section 9.2 experiment design at CPU-testable scale:
the database is initialized with N unique keys; each session executes a
sampled workload (z0, z1, q, w mix) for a fixed number of queries, measuring
average I/Os per query with compaction I/O amortized over writes.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .engine import EngineConfig, IOStats, LSMTree


@dataclasses.dataclass
class SessionResult:
    workload: np.ndarray
    queries: int
    avg_io_per_query: float
    io: IOStats

    @property
    def throughput(self) -> float:
        return 1.0 / max(self.avg_io_per_query, 1e-9)


def populate(tree: LSMTree, n: int, seed: int = 7,
             key_space: int = 2 ** 48) -> np.ndarray:
    """Insert n unique random keys; returns the key array (for z1 queries).

    Keys go in via :meth:`LSMTree.put_batch` in buffer-sized chunks (each
    flushed as a sorted run, as ``put`` + ``flush`` would) rather than one
    Python ``put`` per key — same flush boundaries and resulting tree shape,
    a fraction of the host time.
    """
    rng = np.random.default_rng(seed)
    keys = rng.choice(key_space, size=n, replace=False).astype(np.uint64)
    values = (keys % np.uint64(997)).astype(np.int64).tolist()
    tree.put_batch(keys, values)
    tree.flush()
    # Population writes/compactions are setup cost, not workload cost.
    tree.stats = IOStats()
    return keys


def run_session(tree: LSMTree, existing_keys: np.ndarray, w: np.ndarray,
                n_queries: int = 2000, seed: int = 0,
                key_space: int = 2 ** 48,
                range_fraction: float = 2e-5,
                f_a: float = 1.0, f_seq: float = 1.0,
                zipf_a: Optional[float] = None) -> SessionResult:
    """Run one workload session; returns measured avg I/O per query.

    ``w`` = (z0, z1, q, w) proportions. Non-empty reads sample keys known to
    exist (optionally Zipfian-ranked, Section 9.3 "Workload Skew"); empty
    reads sample the same domain but miss; range queries use a small span
    (short ranges); writes insert fresh keys.
    """
    rng = np.random.default_rng(seed)
    w = np.asarray(w, np.float64)
    w = w / w.sum()
    kinds = rng.choice(4, size=n_queries, p=w)
    before = tree.stats.snapshot()
    span = max(1, int(range_fraction * key_space))
    existing = np.asarray(existing_keys, np.uint64)
    fresh = iter(rng.choice(key_space, size=max((kinds == 3).sum(), 1) + 8,
                            replace=False).astype(np.uint64))
    # Point reads don't mutate the tree, so consecutive runs of them batch
    # through point_query_batch (one vectorized Bloom probe per run) without
    # changing semantics; the rng draw sequence is identical to per-key
    # execution.  Pending reads flush before any state-changing write (and,
    # conservatively, before range queries).
    pending_reads: list = []
    for kind in kinds:
        if kind == 0:        # empty point read: perturb to near-certain miss
            k = int(rng.integers(0, key_space)) | (1 << 60)
            pending_reads.append(k)
        elif kind == 1:      # non-empty point read
            if zipf_a is not None:
                idx = min(len(existing) - 1, rng.zipf(zipf_a) - 1)
            else:
                idx = int(rng.integers(0, len(existing)))
            pending_reads.append(int(existing[idx]))
        elif kind == 2:      # short range query
            if pending_reads:
                tree.point_query_batch(pending_reads)
                pending_reads = []
            lo = int(rng.integers(0, key_space - span))
            tree.range_query(lo, lo + span)
        else:                # write
            if pending_reads:
                tree.point_query_batch(pending_reads)
                pending_reads = []
            tree.put(int(next(fresh)), 1)
    if pending_reads:
        tree.point_query_batch(pending_reads)
    delta = tree.stats.minus(before)
    n = delta.queries
    reads_io = delta.random_reads + f_seq * delta.seq_reads
    write_io = f_seq * (delta.comp_pages_read + f_a * delta.comp_pages_written)
    total_io = reads_io + write_io
    avg = total_io / max(n_queries, 1)
    return SessionResult(workload=w, queries=n_queries, avg_io_per_query=avg,
                         io=delta)


def measured_cost_vector(tree_factory, n_keys: int, n_queries: int = 2000,
                         seed: int = 0) -> np.ndarray:
    """Measure per-class I/O costs (z0, z1, q, w) with pure sessions.

    Used to validate the analytic cost vector c(Phi) component-wise."""
    out = []
    pure = np.eye(4) * 0.97 + 0.01
    for i in range(4):
        tree = tree_factory()
        keys = populate(tree, n_keys, seed=seed)
        res = run_session(tree, keys, pure[i], n_queries=n_queries,
                          seed=seed + i)
        out.append(res.avg_io_per_query)
    return np.asarray(out)
