"""Structure-of-arrays run store: the storage layer of the LSM engine.

Each populated level is a :class:`LevelStore` holding ALL of its runs as
contiguous arenas — one ``uint64`` key array and one ``int64`` encoded-value
array, with a ``starts`` offset table marking run boundaries (runs ordered
newest -> oldest) — plus per-run fence metadata (min/max key, page count,
flush lineage) and the per-run Bloom filter words, packable into a
:class:`repro.lsm.bloom.BloomPack` bit matrix for whole-level batch probes.

Values are *encoded*, never Python objects, so merges, tombstone drops, and
result gathers are pure vector ops (see :class:`ValueCodec`): Python ints
ride inline in the int64, everything else is interned, and deletes are the
integer sentinel ``TOMB`` instead of a sentinel object.

The store only *executes*: it places runs and applies
:class:`repro.lsm.planner.MergePlan`s with a single vectorized
lexsort-merge, counting exact logical compaction I/O into the engine's
``IOStats``.  WHAT to merge and WHEN is decided by the planner; HOW keys are
found is the engine's read path.
"""

from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

from .bloom import BloomPack, bloom_params, build_words
from .merge_path import merge_runs

#: Encoded-value sentinel for deletes.  Even (never an intern slot, those are
#: non-negative evens) and negative, so it cannot collide with either inline
#: ints (odd) or interned object ids.
TOMB = -2

_INLINE_MAX = 2 ** 62  # inline ints v are stored as 2v+1: |v| must fit


class ValueCodec:
    """Encode arbitrary Python values into int64 slots.

    * ``int`` values with ``|v| < 2**62`` are stored inline as ``2v + 1``
      (odd; arithmetic-shift decode, vectorizable);
    * any other object is interned: slot ``2 * table_index`` (even, >= 0);
    * deletes are :data:`TOMB`.

    The hot paths (workload sessions, benchmarks) use int values and never
    touch the intern table; object values (e.g. the checkpoint manifest's
    JSON strings) intern transparently.

    The intern table is append-only: slots whose runs were merged away are
    not reclaimed, so a long-lived object-valued tree holds every value
    version it ever saw (the pre-refactor engine freed them with the run
    object-arrays).  That is a deliberate trade for vector-only merges —
    manifest-scale object workloads are small; int workloads never intern.
    """

    __slots__ = ("objects",)

    def __init__(self):
        self.objects: List[Any] = []

    def encode(self, value: Any) -> int:
        # numpy integer scalars normalize to Python int (equal, not
        # identical) rather than interning one slot per write; bool is an
        # int subclass but keeps its identity through the intern table
        if isinstance(value, (int, np.integer)) \
                and not isinstance(value, bool) \
                and -_INLINE_MAX < value < _INLINE_MAX:
            return 2 * int(value) + 1
        self.objects.append(value)
        return 2 * (len(self.objects) - 1)

    def encode_many(self, values) -> np.ndarray:
        """Vectorized encode for integer arrays; falls back per-element."""
        if isinstance(values, np.ndarray) and values.dtype.kind in "iu":
            v = values.astype(np.int64, copy=False)
            lo, hi = int(v.min(initial=0)), int(v.max(initial=0))
            if -_INLINE_MAX < lo and hi < _INLINE_MAX and not (
                    values.dtype.kind == "u"
                    and int(values.max(initial=0)) >= _INLINE_MAX):
                return 2 * v + 1
        return np.fromiter((self.encode(v) for v in values), np.int64,
                           len(values))

    def decode(self, enc: int) -> Any:
        enc = int(enc)
        if enc & 1:
            return enc >> 1
        return self.objects[enc >> 1]

    def decode_many(self, enc: np.ndarray) -> List[Any]:
        """Decode a tombstone-free encoded array to a list of values."""
        enc = np.asarray(enc, np.int64)
        if len(enc) == 0 or bool((enc & 1).all()):
            return (enc >> 1).tolist()
        return [self.decode(e) for e in enc]


def pages_of(entries: int, entries_per_page: int) -> int:
    return (entries + entries_per_page - 1) // entries_per_page


@dataclasses.dataclass
class RunData:
    """One immutable sorted run in transit (flush output / merge output).

    The Bloom *parameters* (n_bits, k) are fixed at build time — they are
    what the I/O accounting observes — but the filter words materialize
    lazily on first probe: a run merged away before any read never pays the
    k x n hashing cost (the write path never probes).

    ``tomb_seq`` is the logical flush-sequence of the *oldest* tombstone in
    the run (-1 when tombstone-free): the metadata the tombstone-TTL planner
    triggers on.  Merges propagate the minimum over inputs whose tombstones
    survive into the output."""

    keys: np.ndarray          # uint64, sorted ascending, unique
    vals: np.ndarray          # int64, encoded
    flushes: int              # upstream flushes merged into this run
    n_bits: int
    k: int
    words: Optional[np.ndarray] = None   # uint64 filter words, lazy
    tomb_seq: int = -1        # flush seq of oldest tombstone; -1 = none

    @classmethod
    def build(cls, keys: np.ndarray, vals: np.ndarray, bits_per_key: float,
              flushes: int, tomb_seq: int = -1) -> "RunData":
        keys = np.asarray(keys, np.uint64)
        n_bits, k = bloom_params(len(keys), bits_per_key)
        return cls(keys=keys, vals=np.asarray(vals, np.int64),
                   flushes=flushes, n_bits=n_bits, k=k, tomb_seq=tomb_seq)

    def __len__(self) -> int:
        return len(self.keys)


class LevelStore:
    """All runs of one level as SoA arenas + packed filter metadata."""

    __slots__ = ("keys", "vals", "starts", "flushes", "n_bits", "ks",
                 "words_list", "min_keys", "max_keys", "tomb_seqs", "_pack")

    def __init__(self):
        self.keys = np.empty(0, np.uint64)
        self.vals = np.empty(0, np.int64)
        self.starts = np.zeros(1, np.int64)     # R+1 offsets, newest first
        self.flushes: List[int] = []
        self.n_bits: List[int] = []
        self.ks: List[int] = []
        self.words_list: List[np.ndarray] = []
        self.min_keys = np.empty(0, np.uint64)
        self.max_keys = np.empty(0, np.uint64)
        self.tomb_seqs: List[int] = []
        self._pack: Optional[BloomPack] = None

    # -- introspection ----------------------------------------------------

    @property
    def num_runs(self) -> int:
        return len(self.starts) - 1

    @property
    def entries(self) -> int:
        return int(self.starts[-1])

    def run_slice(self, r: int) -> Tuple[np.ndarray, np.ndarray]:
        s, e = int(self.starts[r]), int(self.starts[r + 1])
        return self.keys[s:e], self.vals[s:e]

    def run_len(self, r: int) -> int:
        return int(self.starts[r + 1] - self.starts[r])

    def run_lens(self) -> List[int]:
        return np.diff(self.starts).tolist()

    @property
    def pack(self) -> BloomPack:
        if self._pack is None:
            for r in range(self.num_runs):       # materialize lazy filters
                if self.words_list[r] is None:
                    keys, _ = self.run_slice(r)
                    self.words_list[r] = build_words(keys, self.n_bits[r],
                                                     self.ks[r])
            self._pack = BloomPack(self.words_list, self.n_bits, self.ks)
        return self._pack

    # -- mutation ----------------------------------------------------------

    def _set_runs(self, runs: Sequence[RunData]) -> None:
        """Rebuild the arenas from a newest-first run list."""
        if runs:
            self.keys = np.concatenate([r.keys for r in runs])
            self.vals = np.concatenate([r.vals for r in runs])
        else:
            self.keys = np.empty(0, np.uint64)
            self.vals = np.empty(0, np.int64)
        lens = np.fromiter((len(r) for r in runs), np.int64, len(runs))
        self.starts = np.concatenate([np.zeros(1, np.int64), np.cumsum(lens)])
        self.flushes = [r.flushes for r in runs]
        self.n_bits = [r.n_bits for r in runs]
        self.ks = [r.k for r in runs]
        self.words_list = [r.words for r in runs]
        self.tomb_seqs = [r.tomb_seq for r in runs]
        self.min_keys = np.array([r.keys[0] if len(r) else 0 for r in runs],
                                 np.uint64)
        self.max_keys = np.array([r.keys[-1] if len(r) else 0 for r in runs],
                                 np.uint64)
        self._pack = None

    def _as_rundata(self, r: int) -> RunData:
        keys, vals = self.run_slice(r)
        return RunData(keys=keys, vals=vals, flushes=self.flushes[r],
                       n_bits=self.n_bits[r], k=self.ks[r],
                       words=self.words_list[r], tomb_seq=self.tomb_seqs[r])

    def runs(self) -> List[RunData]:
        return [self._as_rundata(r) for r in range(self.num_runs)]


class RunStore:
    """The tree's storage: one :class:`LevelStore` per populated level."""

    def __init__(self, entries_per_page: int):
        self.entries_per_page = entries_per_page
        self.levels: List[LevelStore] = []
        self.codec = ValueCodec()

    # -- views --------------------------------------------------------------

    def level(self, level: int) -> LevelStore:
        """1-indexed accessor, growing the level list on demand."""
        while len(self.levels) < level:
            self.levels.append(LevelStore())
        return self.levels[level - 1]

    def occupancy(self, min_levels: int = 0):
        """(entries, run_counts, active_flushes) arrays for the planner."""
        n = max(len(self.levels), min_levels)
        entries = np.zeros(n, np.int64)
        run_counts = np.zeros(n, np.int64)
        active_flushes = np.zeros(n, np.int64)
        for i, lv in enumerate(self.levels):
            entries[i] = lv.entries
            run_counts[i] = lv.num_runs
            if lv.num_runs:
                active_flushes[i] = lv.flushes[0]
        return entries, run_counts, active_flushes

    @property
    def total_entries(self) -> int:
        return sum(lv.entries for lv in self.levels)

    def shape(self) -> List[Tuple[int, List[int]]]:
        return [(i + 1, lv.run_lens())
                for i, lv in enumerate(self.levels) if lv.num_runs]

    def filter_bits_in_use(self) -> int:
        return sum(sum(lv.n_bits) for lv in self.levels)

    # -- intern-table reclamation -------------------------------------------

    def reclaim_interned(self) -> int:
        """Compaction-time intern-table sweep: drop dead slots, remap live.

        The codec's intern table is append-only between sweeps — merges that
        drop an overwritten or tombstoned object value leave its slot behind
        — so a long-lived object-valued tree (e.g. a checkpoint-manifest
        store under churn) would hold every value version it ever saw.  This
        sweep scans the level arenas for live interned encodings (even,
        >= 0; inline ints are odd and ``TOMB`` is negative), compacts the
        object table down to the live slots, and rewrites the arenas'
        encodings in place with one vectorized gather per level.

        Must run while the write buffer is empty (the engine sweeps at the
        end of a flush): buffered encodings are not scanned or remapped.
        Returns the number of slots dropped (0 for int-only trees, which
        never intern and never pay for the scan)."""
        codec = self.codec
        n_old = len(codec.objects)
        if n_old == 0:
            return 0
        live = np.zeros(n_old, bool)
        for lv in self.levels:
            iv = lv.vals[(lv.vals >= 0) & (lv.vals & 1 == 0)]
            live[iv >> 1] = True
        n_live = int(live.sum())
        if n_live == n_old:
            return 0
        remap = np.cumsum(live) - 1            # old slot -> new slot
        codec.objects = [codec.objects[i] for i in np.flatnonzero(live)]
        for lv in self.levels:
            m = (lv.vals >= 0) & (lv.vals & 1 == 0)
            if m.any():
                lv.vals[m] = 2 * remap[lv.vals[m] >> 1]
        return n_old - n_live

    # -- plan execution ------------------------------------------------------

    def place_run(self, level: int, run: RunData) -> None:
        """Logical move: prepend ``run`` as the level's new newest run."""
        lv = self.level(level)
        lv._set_runs([run] + lv.runs())

    def merge(self, inputs: Sequence[RunData], bits_per_key: float,
              stats, drop_tombstones: bool = False) -> RunData:
        """Vectorized lexsort-merge (newest first in ``inputs``).

        Exactly the legacy ``_merge_runs``: newest version of each key wins
        via a stable (recency, key) lexsort; tombstones are dropped only when
        the planner marked the merge as deepest; compaction I/O is counted
        per input/output page."""
        epp = self.entries_per_page
        for r in inputs:
            stats.comp_pages_read += pages_of(len(r), epp)
        # Newest-wins k-way reduction; dispatched (numpy argsort-merge /
        # jnp fold / Pallas merge-path kernel), all bit-identical — see
        # lsm/merge_path.py.
        keys_u, vals_u = merge_runs([r.keys for r in inputs],
                                    [r.vals for r in inputs])
        if drop_tombstones:
            live = vals_u != TOMB
            keys_u, vals_u = keys_u[live], vals_u[live]
            tomb_seq = -1
        else:
            in_seqs = [r.tomb_seq for r in inputs if r.tomb_seq >= 0]
            tomb_seq = min(in_seqs) if in_seqs and \
                bool((vals_u == TOMB).any()) else -1
        out = RunData.build(keys_u, vals_u, bits_per_key,
                            flushes=sum(r.flushes for r in inputs),
                            tomb_seq=tomb_seq)
        stats.comp_pages_written += pages_of(len(out), epp)
        return out

    def execute(self, plan, incoming: Optional[RunData], stats,
                bits_per_key: float) -> Optional[RunData]:
        """Apply one MergePlan.  Returns the spill output (the run the engine
        must re-push at ``plan.target_level``) or None for in-level plans.

        "spill" accepts ``incoming=None`` (maintenance-triggered pushes, e.g.
        tombstone-TTL sweeps, have no arriving run); "clamp" merges the
        ``len(run_ids)`` newest runs (>= 2), honoring ``drop_tombstones`` for
        deepest-level squeezes; "partial" is the key-range-sliced merge."""
        lv = self.level(plan.level)
        if plan.kind == "spill":
            head = [incoming] if incoming is not None else []
            merged = self.merge(head + lv.runs(), bits_per_key, stats,
                                drop_tombstones=plan.drop_tombstones)
            lv._set_runs([])
            return merged
        if plan.kind == "eager":
            runs = lv.runs()
            runs[0] = self.merge([incoming, runs[0]], bits_per_key, stats)
            lv._set_runs(runs)
            return None
        if plan.kind == "move":
            self.place_run(plan.level, incoming)
            return None
        if plan.kind == "clamp":
            runs = lv.runs()
            n = max(2, len(plan.run_ids))
            merged = self.merge(runs[:n], bits_per_key, stats,
                                drop_tombstones=plan.drop_tombstones)
            lv._set_runs([merged] + runs[n:])
            return None
        if plan.kind == "partial":
            self._execute_partial(plan, stats, bits_per_key)
            return None
        raise ValueError(f"unknown plan kind {plan.kind!r}")

    def _slice_level(self, level: int, lo: np.uint64, hi: np.uint64,
                     ) -> List[RunData]:
        """Extract the ``[lo, hi)`` key slice out of every run of ``level``.

        Returns the extracted pieces newest-first and rewrites the level's
        runs as their remainders in place (empty remainders vanish).  The
        remainder of a sorted run is two sorted segments around a gap, so it
        stays a valid run; its Bloom parameters are re-derived from the new
        length (words lazily rebuilt on next probe); the flush lineage is
        apportioned by entry count (conserved, so repeated slicing cannot
        inflate it) and the tombstone age inherited conservatively."""
        lv = self.level(level)
        pieces: List[RunData] = []
        remainders: List[RunData] = []
        for r in range(lv.num_runs):
            keys, vals = lv.run_slice(r)
            i = int(np.searchsorted(keys, lo, side="left"))
            j = int(np.searchsorted(keys, hi, side="left"))
            if i == j:                        # run untouched by the slice
                remainders.append(lv._as_rundata(r))
                continue
            n = len(keys)
            # exact conservation (piece + remainder == original, pieces may
            # carry 0): repeated slicing must not inflate total lineage
            piece_fl = min(lv.flushes[r],
                           max(0, round(lv.flushes[r] * (j - i) / n)))
            pieces.append(RunData.build(
                keys[i:j], vals[i:j], self._bpk_of(lv, r),
                flushes=piece_fl, tomb_seq=lv.tomb_seqs[r]))
            rem_keys = np.concatenate([keys[:i], keys[j:]])
            if len(rem_keys):
                rem_vals = np.concatenate([vals[:i], vals[j:]])
                tomb = lv.tomb_seqs[r] if bool((rem_vals == TOMB).any()) \
                    else -1
                remainders.append(RunData.build(
                    rem_keys, rem_vals, self._bpk_of(lv, r),
                    flushes=lv.flushes[r] - piece_fl, tomb_seq=tomb))
        lv._set_runs(remainders)
        return pieces

    @staticmethod
    def _bpk_of(lv: LevelStore, r: int) -> float:
        """Recover a run's bits-per-key ratio for re-derived sub-runs."""
        n = lv.run_len(r)
        return lv.n_bits[r] / n if n else 1.0

    def _execute_partial(self, plan, stats, bits_per_key: float) -> None:
        """Key-range-sliced merge: extract ``[key_lo, key_hi)`` from every
        run of the source level AND the target level, merge the pieces
        (source pieces are newer), and place the output as the target
        level's newest run.  Remainders stay where they were — only the
        slice's pages are read and written, which is the whole point of
        partial compaction (bounded per-trigger I/O)."""
        lo = np.uint64(plan.key_lo)
        hi_int = int(plan.key_hi)
        hi = np.uint64(min(hi_int, 2 ** 64 - 1))
        src = self._slice_level(plan.level, lo, hi)
        tgt = self._slice_level(plan.target_level, lo, hi)
        inputs = src + tgt                     # source level is newer
        if not inputs:
            return
        merged = self.merge(inputs, bits_per_key, stats,
                            drop_tombstones=plan.drop_tombstones)
        if len(merged):
            self.place_run(plan.target_level, merged)
