"""Per-level fused point read: the engine's read hot loop as one op.

One call answers a key batch against ALL runs of one level — Bloom
probe, fence/page location, and per-run binary search — with the exact
sequential-equivalent I/O accounting the engine has always kept: runs
are visited newest -> oldest, a key resolved by a newer run is not
probed in older ones, and the returned (probes, reads, false-positives)
counters are the integers per-key execution would produce.

Three implementations behind :func:`point_read_level`:

* ``numpy`` (default) — a verbatim factoring of the historical
  ``LSMTree._lookup_batch`` inner loop.  Pure numpy: the subprocess
  execution backend's workers import the engine without jax, so this
  module must stay jax-free unless an opt-in mode is selected.
* ``jnp`` — the dense jax reference (``repro.kernels.point_read.ref``),
  lazily imported; exact splitmix64 under ``jax.experimental.enable_x64``.
* ``jnp_limb`` — the same reference with the Bloom hash on uint32 limbs
  (``repro.kernels.point_read.limb``): the TPU-portable arithmetic tier,
  bit-identical to the native uint64 hash.
* ``pallas`` — the fused kernel (``repro.kernels.point_read.kernel``),
  one VMEM pass per key tile per level; interpret mode off-TPU.

All modes return bit-identical results and counters (tested), so the
mode is a pure execution choice — golden ``IOStats`` are preserved.
The switch is process-global (``set_read_kernel`` / ``read_kernel``)
rather than an ``EngineConfig`` field: engine configs stay hashable,
JSON-round-trippable, and jax-free for subprocess workers.
"""

from __future__ import annotations

from contextlib import contextmanager
from typing import Tuple

import numpy as np

from repro import obs

VALID_MODES = ("numpy", "jnp", "jnp_limb", "pallas")

_MODE = "numpy"


def set_read_kernel(mode: str) -> None:
    """Select the point-read implementation for every engine in-process."""
    global _MODE
    if mode not in VALID_MODES:
        raise ValueError(f"unknown read kernel {mode!r}; one of {VALID_MODES}")
    _MODE = mode


def get_read_kernel() -> str:
    return _MODE


@contextmanager
def read_kernel(mode: str):
    """Scoped :func:`set_read_kernel` (tests / benchmarks)."""
    prev = get_read_kernel()
    set_read_kernel(mode)
    try:
        yield
    finally:
        set_read_kernel(prev)


def point_read_level_numpy(lv, sub_keys: np.ndarray
                           ) -> Tuple[np.ndarray, np.ndarray, int, int, int]:
    """(hit, enc, probes, reads, false_positives) for one level.

    ``hit[b]`` is True when key ``b`` was found in this level (including
    tombstones — the caller decides what a tombstone means); ``enc[b]``
    is the encoded value for hit keys.  Counter semantics match per-key
    sequential execution (see module docstring).
    """
    B = len(sub_keys)
    hit = np.zeros(B, bool)
    enc = np.zeros(B, np.int64)
    probes = reads = fps = 0
    pos = lv.pack.probe(sub_keys)                # (R, B)
    live = np.ones(B, bool)                      # unresolved within level
    for r in range(lv.num_runs):                 # newest -> oldest
        n_active = int(live.sum())
        if n_active == 0:
            break
        probes += n_active
        pos_r = pos[r] & live
        n_pos = int(pos_r.sum())
        if n_pos == 0:
            continue
        reads += n_pos                # fence pointer -> one page each
        rkeys, rvals = lv.run_slice(r)
        qk = sub_keys[pos_r]
        loc = np.searchsorted(rkeys, qk)
        inb = loc < len(rkeys)
        eq = np.zeros(n_pos, bool)
        eq[inb] = rkeys[loc[inb]] == qk[inb]
        fps += n_pos - int(eq.sum())
        if eq.any():
            sidx = np.flatnonzero(pos_r)[eq]
            live[sidx] = False
            hit[sidx] = True
            enc[sidx] = rvals[loc[eq]]
    return hit, enc, probes, reads, fps


def point_read_level(lv, sub_keys: np.ndarray
                     ) -> Tuple[np.ndarray, np.ndarray, int, int, int]:
    """Mode-dispatched per-level point read (see module docstring)."""
    if obs.enabled():
        obs.count("kernel.dispatch.point_read." + _MODE)
    if _MODE == "numpy":
        return point_read_level_numpy(lv, sub_keys)
    from repro.kernels.point_read.ops import point_read_level_arrays
    pack = lv.pack
    return point_read_level_arrays(
        sub_keys, lv.keys, lv.vals, np.asarray(lv.starts, np.int64),
        pack.words, np.asarray(pack.n_bits, np.uint64),
        np.asarray(pack.ks, np.int64), lv.min_keys, lv.max_keys, impl=_MODE)
