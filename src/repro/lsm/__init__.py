"""Executable LSM-tree storage engine with exact logical-I/O accounting."""

from .bloom import BloomFilter, monkey_bits_per_key
from .engine import EngineConfig, IOStats, LSMTree, TOMBSTONE
from .workload_runner import (SessionResult, measured_cost_vector, populate,
                              run_session)

__all__ = ["BloomFilter", "monkey_bits_per_key", "EngineConfig", "IOStats",
           "LSMTree", "TOMBSTONE", "SessionResult", "measured_cost_vector",
           "populate", "run_session"]
