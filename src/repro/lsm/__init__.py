"""Executable LSM-tree storage engine with exact logical-I/O accounting.

Three layers: a structure-of-arrays run store (:mod:`repro.lsm.store`), a
plan-emitting compaction policy (:mod:`repro.lsm.planner`), and the batched
engine + session executor (:mod:`repro.lsm.engine`,
:mod:`repro.lsm.workload_runner`)."""

from .bloom import BloomFilter, BloomPack, monkey_bits_per_key
from .engine import EngineConfig, IOStats, LSMTree, TOMBSTONE
from .planner import (POLICIES, CompactionPolicy, KLSMPlanner,
                      LazyLevelingPlanner, MergePlan,
                      PartialCompactionPlanner, TombstoneTTLPlanner,
                      make_planner)
from .store import RunStore, ValueCodec
from .workload_runner import (SessionPlan, SessionResult, draw_keys,
                              execute_session, materialize_session,
                              measured_cost_vector, populate, run_fleet,
                              run_policy_fleet, run_session)

__all__ = ["BloomFilter", "BloomPack", "monkey_bits_per_key", "EngineConfig",
           "IOStats", "LSMTree", "TOMBSTONE", "CompactionPolicy",
           "KLSMPlanner", "LazyLevelingPlanner", "PartialCompactionPlanner",
           "TombstoneTTLPlanner", "POLICIES", "make_planner", "MergePlan",
           "RunStore", "ValueCodec", "SessionPlan", "SessionResult",
           "draw_keys", "execute_session", "materialize_session",
           "measured_cost_vector", "populate", "run_fleet",
           "run_policy_fleet", "run_session"]
