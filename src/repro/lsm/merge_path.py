"""K-way stable newest-wins merge: the compaction hot loop as one op.

``RunStore.merge`` reduces a newest-first run list to one sorted unique
run (newest version of each key wins, exactly the legacy lexsort-merge
semantics).  This module is the dispatch point for HOW that reduction
executes:

* ``numpy`` (default) — the historical implementation, verbatim: one
  stable argsort over the concatenated arenas (concatenation order IS
  recency order) + adjacent-duplicate drop.  Jax-free, like the rest of
  the engine's default path.
* ``jnp`` — pairwise newest-first fold of rank-based two-way merges
  (``repro.kernels.merge.ref``), lazily imported.
* ``pallas`` — the same fold where each two-way merge is the
  merge-path Pallas kernel (gather-only binary-search partition per
  output tile; ``repro.kernels.merge.kernel``).

All three produce bit-identical (keys, vals) (tested): newest-wins
dedup is associative, so folding pairwise newest-first equals the
global stable sort.  The switch mirrors ``read_path``'s — process
global, never engine config.
"""

from __future__ import annotations

from contextlib import contextmanager
from typing import Sequence, Tuple

import numpy as np

from repro import obs

VALID_MODES = ("numpy", "jnp", "pallas")

_MODE = "numpy"


def set_merge_kernel(mode: str) -> None:
    """Select the compaction-merge implementation for this process."""
    global _MODE
    if mode not in VALID_MODES:
        raise ValueError(
            f"unknown merge kernel {mode!r}; one of {VALID_MODES}")
    _MODE = mode


def get_merge_kernel() -> str:
    return _MODE


@contextmanager
def merge_kernel(mode: str):
    """Scoped :func:`set_merge_kernel` (tests / benchmarks)."""
    prev = get_merge_kernel()
    set_merge_kernel(mode)
    try:
        yield
    finally:
        set_merge_kernel(prev)


def merge_runs_numpy(keys_list: Sequence[np.ndarray],
                     vals_list: Sequence[np.ndarray]
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Stable argsort-merge of newest-first runs -> sorted unique run."""
    all_keys = np.concatenate(keys_list)
    all_vals = np.concatenate(vals_list)
    # Concatenation order IS recency order (inputs newest first), so a
    # stable key sort leaves duplicates newest-first — equivalent to
    # lexsort((recency, key)) at one sort over nearly-sorted data.
    order = np.argsort(all_keys, kind="stable")
    keys_sorted = all_keys[order]
    vals_sorted = all_vals[order]
    keep = np.ones(len(keys_sorted), bool)
    keep[1:] = keys_sorted[1:] != keys_sorted[:-1]      # newest wins
    return keys_sorted[keep], vals_sorted[keep]


def merge_runs(keys_list: Sequence[np.ndarray],
               vals_list: Sequence[np.ndarray]
               ) -> Tuple[np.ndarray, np.ndarray]:
    """Mode-dispatched k-way newest-wins merge (see module docstring)."""
    if obs.enabled():
        obs.count("kernel.dispatch.merge." + _MODE)
    if _MODE == "numpy":
        return merge_runs_numpy(keys_list, vals_list)
    from repro.kernels.merge.ops import merge_runs_arrays
    return merge_runs_arrays(keys_list, vals_list, impl=_MODE)
