"""Compaction planners: WHEN/WHAT to compact, as plain data.

The policy layer of the LSM engine.  A planner never touches key arrays: it
reads the store's level-occupancy arrays (entries, run counts, active-run
flush lineage) plus per-run fence/tombstone *metadata* and emits
:class:`MergePlan` values; the store executes them with a vectorized
lexsort-merge and the engine drives the plan-execute-replan loop.  This
separation is the "compaction as data" view of the design-space taxonomy
(Sarkar et al., "Constructing and Analyzing the LSM Compaction Design
Space"): a trigger/granularity/data-movement policy decoupled from merge
execution, so alternative policies are new planners, not new engines.

Four policies span the taxonomy's axes (see ``docs/compaction.md`` for the
coordinate mapping):

* :class:`KLSMPlanner` — the paper's K-LSM semantics (Section 4.2),
  reproduced exactly: capacity-triggered full-level spills, eager in-level
  merges bounded by the per-run flush lineage cap ``ceil((T-1)/K_i)``,
  logical moves, and clamp merges restoring the ``K_i`` run cap.
* :class:`LazyLevelingPlanner` — Dostoevsky-style lazy leveling: runs
  accumulate tiering-style (cap ``T-1``) on every level, and the *deepest*
  level is squeezed back to one run only when read pressure since its last
  squeeze crosses a threshold ("merge on reads", not on writes).
* :class:`PartialCompactionPlanner` — partial/partitioned granularity: a
  level that overflows sheds a *key-range slice* (``MergePlan.key_lo`` /
  ``key_hi``, a round-robin cursor over the level's fence span) into the
  next level per trigger, instead of merging the whole level at once.
* :class:`TombstoneTTLPlanner` — K-LSM triggers plus an age-driven sweep: a
  run whose oldest tombstone exceeds ``ttl_flushes`` logical flushes is
  compacted level-by-level toward the deepest level, where the tombstone is
  dropped — bounding delete persistence (FADE-style TTLs).

``make_planner`` builds a policy from an :class:`EngineConfig` via the
``POLICIES`` registry.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MergePlan:
    """One compaction step, as data.

    ``kind``: "spill" | "eager" | "move" | "clamp" | "partial".  ``run_ids``
    are indices into the level's newest-first run list that participate in
    the merge (the incoming run, when present, is implicitly newest);
    ``target_level`` is where the output lands; ``drop_tombstones`` marks
    merges below which no data lives, so deletes can be discarded for good.
    ``key_lo``/``key_hi`` (``None`` for whole-run plans) restrict a
    "partial" plan to the key slice ``[key_lo, key_hi)``: the store extracts
    that slice from every listed run *and* from the target level's runs,
    merges the pieces, and leaves the remainders in place."""

    kind: str
    level: int
    run_ids: Tuple[int, ...]
    target_level: int
    drop_tombstones: bool = False
    key_lo: Optional[int] = None
    key_hi: Optional[int] = None


def level_capacity(level: int, T: int, buf_entries: int) -> int:
    return (T - 1) * T ** (level - 1) * buf_entries


class CompactionPolicy:
    """Base compaction policy: K-LSM-shaped push planning + a maintenance
    hook.

    ``plan_push``/``plan_clamps`` drive the write path (where does an
    arriving run go); ``plan_maintenance`` is polled by the engine after
    flushes and read batches (only when ``has_maintenance``) and may emit
    follow-up plans — read-triggered squeezes, partial spills, TTL sweeps —
    until it returns ``[]``."""

    #: engines skip the maintenance poll entirely when False (the K-LSM hot
    #: path stays byte-identical to the pre-policy engine)
    has_maintenance = False

    def __init__(self, cfg):
        self.cfg = cfg

    # -- write-path planning ------------------------------------------------

    def run_cap(self, level: int) -> int:
        """K_i: the level's run cap (policies override the profile)."""
        return self.cfg.k_at(level)

    def plan_push(self, occupancy, level: int, incoming_entries: int,
                  incoming_flushes: int) -> MergePlan:
        """Decide the fate of a run arriving at ``level``.

        ``occupancy`` is the store's ``(entries, run_counts,
        active_flushes)`` triple; entries beyond its length are empty."""
        entries, run_counts, active_flushes = occupancy
        n = len(entries)
        lv_entries = int(entries[level - 1]) if level - 1 < n else 0
        lv_runs = int(run_counts[level - 1]) if level - 1 < n else 0
        cap = level_capacity(level, self.cfg.T, self.cfg.buf_entries)
        if lv_entries + incoming_entries > cap and lv_entries > 0:
            plan = self.plan_overflow(occupancy, level, lv_runs)
            if plan is not None:
                return plan
        K = self.run_cap(level)
        flush_cap = max(1, math.ceil((self.cfg.T - 1) / K))
        if lv_runs > 0 and \
                int(active_flushes[level - 1]) + incoming_flushes <= flush_cap:
            return MergePlan(kind="eager", level=level, run_ids=(0,),
                             target_level=level)
        return MergePlan(kind="move", level=level, run_ids=(),
                         target_level=level)

    def plan_overflow(self, occupancy, level: int,
                      lv_runs: int) -> Optional[MergePlan]:
        """The capacity trigger: default is the K-LSM full-level spill.
        Returning ``None`` falls through to eager/move placement (policies
        that handle overflow in maintenance, e.g. partial compaction)."""
        _, run_counts, _ = occupancy
        deepest = int(run_counts[level:].sum()) == 0
        return MergePlan(kind="spill", level=level,
                         run_ids=tuple(range(lv_runs)),
                         target_level=level + 1,
                         drop_tombstones=deepest)

    def plan_clamps(self, occupancy, level: int) -> List[MergePlan]:
        """Merge-down plans restoring the K_i run cap after a move."""
        _, run_counts, _ = occupancy
        lv_runs = int(run_counts[level - 1]) if level - 1 < len(run_counts) \
            else 0
        K = self.run_cap(level)
        return [MergePlan(kind="clamp", level=level, run_ids=(0, 1),
                          target_level=level)
                for _ in range(max(0, lv_runs - K))]

    # -- maintenance --------------------------------------------------------

    def plan_maintenance(self, store, stats, clock: int) -> List[MergePlan]:
        """Follow-up plans, polled until empty.  ``store`` is the live
        :class:`~repro.lsm.store.RunStore` (planners read occupancy and
        fence/tombstone metadata, never key arrays); ``stats`` the engine's
        ``IOStats``; ``clock`` the logical flush sequence number."""
        return []


class KLSMPlanner(CompactionPolicy):
    """The paper's K-LSM trigger policy over an :class:`EngineConfig`."""


class LazyLevelingPlanner(CompactionPolicy):
    """Lazy leveling: tiering-style accumulation, read-triggered last-level
    squeeze (Dostoevsky's fluid LSM, taken to its lazy extreme).

    Writes see pure tiering (run cap ``T-1`` on every level), so merge work
    on the write path is minimal.  The *deepest populated* level — the one
    holding most of the data, where point lookups bottom out — is merged
    back to a single run only when ``read_trigger`` random page reads have
    accumulated since its last squeeze: reads, not writes, pay for (and
    benefit from) the merge.  Steady read load therefore drives the tree to
    the lazy-leveling shape (``K_i = T-1`` above, one run at the bottom);
    write-only load never merges the last level at all."""

    has_maintenance = True

    def __init__(self, cfg, read_trigger: int = 256):
        super().__init__(cfg)
        self.read_trigger = int(read_trigger)
        self._reads_at_squeeze = 0

    def run_cap(self, level: int) -> int:
        return max(1, self.cfg.T - 1)

    def plan_maintenance(self, store, stats, clock: int) -> List[MergePlan]:
        deepest = 0
        for i, lv in enumerate(store.levels):
            if lv.num_runs:
                deepest = i + 1
        if deepest == 0:
            return []
        lv = store.levels[deepest - 1]
        pressure = stats.random_reads - self._reads_at_squeeze
        if lv.num_runs > 1 and pressure >= self.read_trigger:
            self._reads_at_squeeze = stats.random_reads
            return [MergePlan(kind="clamp", level=deepest,
                              run_ids=tuple(range(lv.num_runs)),
                              target_level=deepest, drop_tombstones=True)]
        return []


class PartialCompactionPlanner(CompactionPolicy):
    """Partial/partitioned compaction: capacity overflow sheds one key-range
    slice per trigger instead of the whole level.

    In-level placement (eager/move/clamp) follows K-LSM, but the capacity
    trigger is disarmed on the write path: an overfull level is drained by
    maintenance, one ``[key_lo, key_hi)`` slice at a time.  ``select``
    picks the slice:

    * ``"round_robin"`` (default, byte-identical to the original planner) —
      a cursor walks the level's fence span in ``1/parts`` strides, so each
      trigger moves roughly ``entries/parts`` entries and costs a bounded,
      level-capacity-independent amount of I/O (RocksDB-leveled-style
      compaction latency, at run granularity);
    * ``"overlap"`` — score each of the ``parts`` candidate slices by its
      estimated *overlap* with the target level (per-run fence spans +
      entry counts under a uniform-density assumption — metadata only,
      planners never read key arrays) and shed the least-overlapping slice
      first: the merge that rewrites the fewest target-level entries per
      source entry moved, RocksDB's min-overlapping-ratio file picker at
      slice granularity.  A per-level skip-set of slices already tried
      since the level last changed guarantees progress (a chosen slice may
      contain no source keys; round-robin advances past it by
      construction, overlap must not re-pick it forever)."""

    has_maintenance = True

    SELECTS = ("round_robin", "overlap")

    def __init__(self, cfg, parts: int = 4, select: str = "round_robin"):
        super().__init__(cfg)
        self.parts = max(1, int(parts))
        if select not in self.SELECTS:
            raise ValueError(f"unknown slice selection {select!r}; "
                             f"known: {self.SELECTS}")
        self.select = select
        self._cursors: dict = {}        # level -> next slice start key
        self._tried: dict = {}          # level -> slice starts tried
        self._state: dict = {}          # level -> (entries, num_runs) seen

    def plan_overflow(self, occupancy, level: int,
                      lv_runs: int) -> Optional[MergePlan]:
        return None                     # maintenance drains over-capacity

    def _candidates(self, lo_key: int, hi_key: int,
                    width: int) -> List[Tuple[int, int]]:
        """The ``parts`` slice intervals ``[lo, hi)`` tiling the fence span
        (the last one absorbs the floor-division remainder)."""
        out = []
        for j in range(self.parts):
            clo = lo_key + j * width
            if clo > hi_key:
                break
            chi = hi_key + 1 if (j == self.parts - 1
                                 or clo + width > hi_key) else clo + width
            out.append((clo, chi))
        return out

    def _overlap_score(self, store, level: int, clo: int,
                       chi: int) -> float:
        """Estimated target-level entries a merge of ``[clo, chi)`` must
        rewrite: each target run contributes its entry count times the
        fraction of its fence span the slice covers (uniform density)."""
        if level >= len(store.levels):      # no target level yet: free
            return 0.0
        tgt = store.levels[level]           # 0-indexed: level+1's runs
        score = 0.0
        lens = tgt.run_lens()
        for r in range(tgt.num_runs):
            mn = int(tgt.min_keys[r])
            mx = int(tgt.max_keys[r])
            inter = min(chi - 1, mx) - max(clo, mn) + 1
            if inter > 0:
                score += lens[r] * inter / (mx - mn + 1)
        return score

    def _pick_overlap(self, store, level: int, lo_key: int, hi_key: int,
                      width: int) -> Tuple[int, int]:
        lv = store.levels[level - 1]
        state = (int(lv.entries), int(lv.num_runs))
        if self._state.get(level) != state:     # the level moved: re-arm
            self._state[level] = state
            self._tried[level] = set()
        tried = self._tried.setdefault(level, set())
        cands = self._candidates(lo_key, hi_key, width)
        fresh = [c for c in cands if c[0] not in tried]
        if not fresh:       # full cycle without movement: start over
            tried.clear()
            fresh = cands
        _, clo, chi = min((self._overlap_score(store, level, clo, chi),
                           clo, chi) for clo, chi in fresh)
        tried.add(clo)
        return clo, chi

    def plan_maintenance(self, store, stats, clock: int) -> List[MergePlan]:
        run_counts = [lv.num_runs for lv in store.levels]
        deepest = max((i + 1 for i, r in enumerate(run_counts) if r),
                      default=0)
        for i, lv in enumerate(store.levels):
            level = i + 1
            if lv.num_runs == 0:
                continue
            # restore the K cap first: partial outputs land as new runs
            if lv.num_runs > self.run_cap(level):
                return [MergePlan(kind="clamp", level=level, run_ids=(0, 1),
                                  target_level=level)]
            cap = level_capacity(level, self.cfg.T, self.cfg.buf_entries)
            if lv.entries <= cap:
                continue
            lo_key = int(lv.min_keys.min())
            hi_key = int(lv.max_keys.max())
            width = max(1, (hi_key - lo_key + 1) // self.parts)
            if self.select == "overlap":
                cur, key_hi = self._pick_overlap(store, level, lo_key,
                                                 hi_key, width)
            else:
                cur = self._cursors.get(level, lo_key)
                if cur < lo_key or cur > hi_key:
                    cur = lo_key
                key_hi = hi_key + 1 if cur + width > hi_key else cur + width
                self._cursors[level] = key_hi
            return [MergePlan(kind="partial", level=level,
                              run_ids=tuple(range(lv.num_runs)),
                              target_level=level + 1,
                              drop_tombstones=level + 1 >= deepest,
                              key_lo=cur, key_hi=key_hi)]
        return []


class TombstoneTTLPlanner(CompactionPolicy):
    """K-LSM triggers plus tombstone-TTL sweeps bounding delete persistence.

    The store stamps every run with the flush-sequence of its *oldest*
    tombstone (``tomb_seq``); once a tombstone has aged ``ttl_flushes``
    logical flushes, maintenance compacts its level into the next one,
    cascading until the tombstone reaches the deepest populated level and is
    physically dropped.  After every flush's maintenance pass, no run holds
    a tombstone older than the TTL — the invariant the paper's
    delete-persistence discussion (and FADE) asks for — while deletes
    *never* resurface because drops still only happen below all live data."""

    has_maintenance = True

    def __init__(self, cfg, ttl_flushes: int = 16):
        super().__init__(cfg)
        self.ttl_flushes = int(ttl_flushes)

    def plan_maintenance(self, store, stats, clock: int) -> List[MergePlan]:
        run_counts = [lv.num_runs for lv in store.levels]
        deepest = max((i + 1 for i, r in enumerate(run_counts) if r),
                      default=0)
        for i, lv in enumerate(store.levels):
            level = i + 1
            if lv.num_runs == 0:
                continue
            expired = any(ts >= 0 and clock - ts >= self.ttl_flushes
                          for ts in lv.tomb_seqs)
            if not expired:
                continue
            if level == deepest:
                # bottom of the tree: squeeze in place, dropping tombstones
                return [MergePlan(kind="clamp", level=level,
                                  run_ids=tuple(range(lv.num_runs)),
                                  target_level=level, drop_tombstones=True)]
            # the spill output lands ABOVE the target level's live runs, so
            # tombstones must survive until they reach the deepest level
            return [MergePlan(kind="spill", level=level,
                              run_ids=tuple(range(lv.num_runs)),
                              target_level=level + 1,
                              drop_tombstones=False)]
        return []


#: policy name -> planner class; ``EngineConfig.policy`` selects from here.
POLICIES = {
    "klsm": KLSMPlanner,
    "lazy_leveling": LazyLevelingPlanner,
    "partial": PartialCompactionPlanner,
    "tombstone_ttl": TombstoneTTLPlanner,
}


def make_planner(cfg) -> CompactionPolicy:
    """Build the planner named by ``cfg.policy`` (params from
    ``cfg.policy_params``, a tuple of (name, value) pairs)."""
    try:
        cls = POLICIES[cfg.policy]
    except KeyError:
        raise ValueError(f"unknown compaction policy {cfg.policy!r}; "
                         f"known: {sorted(POLICIES)}") from None
    return cls(cfg, **dict(getattr(cfg, "policy_params", ())))
