"""Compaction planner: WHEN/WHAT to compact, as plain data.

The policy layer of the LSM engine.  The planner never touches key arrays:
it reads the store's level-occupancy arrays (entries, run counts, active-run
flush lineage) and emits :class:`MergePlan` values; the store executes them
with a vectorized lexsort-merge and the engine drives the
plan-execute-replan loop.  This separation is the "compaction as data"
view of the design-space taxonomy (Sarkar et al., "Constructing and
Analyzing the LSM Compaction Design Space"): a trigger/granularity policy
decoupled from merge execution, so alternative policies (size-ratio
triggers, partial/partitioned compaction, lazy leveling) are new planners,
not new engines.

The one policy implemented is the paper's K-LSM semantics (Section 4.2),
reproduced exactly:

* **spill**  — a level that would exceed its entry capacity
  ``(T-1) * T^(i-1) * buf_entries`` merges *every* run (plus the incoming
  one) and pushes the result to level i+1; tombstones are dropped iff no
  deeper level holds data;
* **eager**  — otherwise the incoming run merges into the level's active
  (newest) run while that run's flush lineage stays within the per-run cap
  ``ceil((T-1) / K_i)`` ("we only merge runs or logically move them");
* **move**   — otherwise the run is placed as the level's new active run;
* **clamp**  — logical moves that overfill the ``K_i`` run cap merge the two
  newest runs until the cap holds.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Tuple


@dataclasses.dataclass(frozen=True)
class MergePlan:
    """One compaction step, as data.

    ``kind``: "spill" | "eager" | "move" | "clamp".  ``run_ids`` are indices
    into the level's newest-first run list that participate in the merge
    (the incoming run, when present, is implicitly newest); ``target_level``
    is where the output lands; ``drop_tombstones`` marks deepest-level
    merges where deletes can be discarded for good."""

    kind: str
    level: int
    run_ids: Tuple[int, ...]
    target_level: int
    drop_tombstones: bool = False


def level_capacity(level: int, T: int, buf_entries: int) -> int:
    return (T - 1) * T ** (level - 1) * buf_entries


class KLSMPlanner:
    """The paper's K-LSM trigger policy over an :class:`EngineConfig`."""

    def __init__(self, cfg):
        self.cfg = cfg

    def plan_push(self, occupancy, level: int, incoming_entries: int,
                  incoming_flushes: int) -> MergePlan:
        """Decide the fate of a run arriving at ``level``.

        ``occupancy`` is the store's ``(entries, run_counts,
        active_flushes)`` triple; entries beyond its length are empty."""
        entries, run_counts, active_flushes = occupancy
        n = len(entries)
        lv_entries = int(entries[level - 1]) if level - 1 < n else 0
        lv_runs = int(run_counts[level - 1]) if level - 1 < n else 0
        cap = level_capacity(level, self.cfg.T, self.cfg.buf_entries)
        if lv_entries + incoming_entries > cap and lv_entries > 0:
            deepest = int(run_counts[level:].sum()) == 0
            return MergePlan(kind="spill", level=level,
                             run_ids=tuple(range(lv_runs)),
                             target_level=level + 1,
                             drop_tombstones=deepest)
        K = self.cfg.k_at(level)
        flush_cap = max(1, math.ceil((self.cfg.T - 1) / K))
        if lv_runs > 0 and \
                int(active_flushes[level - 1]) + incoming_flushes <= flush_cap:
            return MergePlan(kind="eager", level=level, run_ids=(0,),
                             target_level=level)
        return MergePlan(kind="move", level=level, run_ids=(),
                         target_level=level)

    def plan_clamps(self, occupancy, level: int) -> List[MergePlan]:
        """Merge-down plans restoring the K_i run cap after a move."""
        _, run_counts, _ = occupancy
        lv_runs = int(run_counts[level - 1]) if level - 1 < len(run_counts) \
            else 0
        K = self.cfg.k_at(level)
        return [MergePlan(kind="clamp", level=level, run_ids=(0, 1),
                          target_level=level)
                for _ in range(max(0, lv_runs - K))]
