"""An executable LSM-tree key-value engine with K-LSM compaction semantics.

This is the framework's "RocksDB": a real storage engine (memtable, immutable
sorted runs, Monkey Bloom filters, fence pointers, K_i-parameterized
compaction) with *exact logical-I/O accounting*, so that measured I/Os per
query can be compared against the paper's cost model — the Section 9
system-based evaluation, reproduced on CPU.

Architecture: three explicit layers
-----------------------------------
* **Storage** (:mod:`repro.lsm.store`) — a structure-of-arrays run store:
  each level keeps ALL of its runs in contiguous ``uint64`` key /
  ``int64`` encoded-value arenas with run-boundary offsets, per-run fence
  metadata, and per-run Bloom words packable into a level-wide bit matrix
  (:class:`repro.lsm.bloom.BloomPack`).  Values are int64-encoded (inline
  ints / interned objects / an integer tombstone sentinel), so merges and
  tombstone drops are pure vector ops.
* **Policy** (:mod:`repro.lsm.planner`) — pluggable compaction planners
  that read level-occupancy arrays and fence/tombstone metadata and emit
  :class:`repro.lsm.planner.MergePlan` values (which runs -> which level,
  optional key-range slice, drop-tombstones flag) as plain data.
  ``EngineConfig.policy`` selects from the design-space registry: the
  paper's K-LSM triggers (default), lazy leveling (read-pressure last-level
  squeeze), partial/partitioned compaction (key-range slices per trigger),
  or tombstone-TTL sweeps (bounded delete persistence) — see
  ``docs/compaction.md`` for the taxonomy mapping.
* **Execution** — this module's :class:`LSMTree` drives the
  plan-execute-replan loop on the write path and owns the batched read
  paths: ``point_query_batch`` probes a key batch against every run of a
  level at once (one shared hash round per level, sequential-equivalent
  I/O accounting) and ``range_query_batch`` runs one two-sided
  ``searchsorted`` per run for a whole batch of ranges.  Sessions execute
  on these primitives via :mod:`repro.lsm.workload_runner`.

Per-level semantics (paper Section 4.2):
  * Level i holds at most ``K_i`` sorted runs and at most
    ``(T-1) * T^(i-1) * buf_entries`` entries.
  * Incoming runs are eagerly merged into the level's *active* run until that
    run reaches its flush capacity (level_capacity / K_i); then a new run
    starts ("we only merge runs or logically move them").
  * When the level exceeds its entry capacity, a full-level compaction merges
    every run and pushes the result to level i+1.
  * ``K_i = 1`` reduces to leveling; ``K_i = T-1`` to tiering.

I/O accounting (paper Section 2 "Optimizing Lookups" assumptions):
  * A point lookup on a run costs exactly 1 random page I/O after a positive
    Bloom probe (fence pointers identify the page).
  * A range lookup costs 1 seek (random I/O) per overlapping run plus
    sequential I/Os for the subsequent pages.
  * Compactions read every input page and write every output page
    (sequential); buffer flushes write sequentially.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs

from .bloom import monkey_bits_per_key
from .planner import make_planner
from .read_path import point_read_level
from .store import TOMB, RunData, RunStore, pages_of

TOMBSTONE = object()


@dataclasses.dataclass
class IOStats:
    random_reads: int = 0        # random page reads (point lookups, seeks)
    seq_reads: int = 0           # sequential page reads (range scans)
    comp_pages_read: int = 0     # compaction input pages (sequential)
    comp_pages_written: int = 0  # compaction/flush output pages (sequential)
    bloom_probes: int = 0
    bloom_false_positives: int = 0
    queries: dict = dataclasses.field(
        default_factory=lambda: {"z0": 0, "z1": 0, "q": 0, "w": 0})

    def snapshot(self) -> "IOStats":
        return dataclasses.replace(self, queries=dict(self.queries))

    def minus(self, other: "IOStats") -> "IOStats":
        return IOStats(
            random_reads=self.random_reads - other.random_reads,
            seq_reads=self.seq_reads - other.seq_reads,
            comp_pages_read=self.comp_pages_read - other.comp_pages_read,
            comp_pages_written=self.comp_pages_written - other.comp_pages_written,
            bloom_probes=self.bloom_probes - other.bloom_probes,
            bloom_false_positives=self.bloom_false_positives
            - other.bloom_false_positives,
            queries={k: self.queries[k] - other.queries[k]
                     for k in self.queries},
        )

    def as_dict(self) -> dict:
        """Plain-dict view (telemetry span attributes, JSON sinks)."""
        return {
            "random_reads": self.random_reads,
            "seq_reads": self.seq_reads,
            "comp_pages_read": self.comp_pages_read,
            "comp_pages_written": self.comp_pages_written,
            "bloom_probes": self.bloom_probes,
            "bloom_false_positives": self.bloom_false_positives,
            "queries": dict(self.queries),
        }

    def io_per_query(self, f_a: float = 1.0, f_seq: float = 1.0) -> dict:
        """Measured average logical I/O per query class, write-amortized the
        way the paper does (compaction I/O redistributed over writes)."""
        n = self.queries
        reads = max(n["z0"] + n["z1"] + n["q"], 1)
        out = {}
        out["read_io"] = (self.random_reads + f_seq * self.seq_reads) / reads
        writes = max(n["w"], 1)
        out["write_io"] = (f_seq * (self.comp_pages_read
                                    + f_a * self.comp_pages_written)) / writes
        return out


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    T: int = 4
    K: Tuple[int, ...] = ()            # per-level caps; empty -> leveling
    buf_entries: int = 1024            # memtable capacity (entries)
    entry_bytes: int = 64
    page_bytes: int = 4096
    mfilt_bits_per_entry: float = 10.0  # Monkey budget, bits per *total* entry
    expected_entries: int = 200_000     # N used for Monkey allocation + L
    #: compaction policy name (see repro.lsm.planner.POLICIES) + its
    #: constructor params as (name, value) pairs (kept a tuple so the
    #: config stays hashable)
    policy: str = "klsm"
    policy_params: Tuple[Tuple[str, Any], ...] = ()

    @property
    def entries_per_page(self) -> int:
        return max(1, self.page_bytes // self.entry_bytes)

    def k_at(self, level: int) -> int:
        """1-indexed level -> K_i, clamped to [1, T-1]."""
        if level - 1 < len(self.K):
            k = self.K[level - 1]
        elif len(self.K) > 0:
            k = self.K[-1]
        else:
            k = 1
        return int(max(1, min(k, self.T - 1)))

    @property
    def est_levels(self) -> int:
        ratio = self.expected_entries / self.buf_entries
        return max(1, int(math.ceil(math.log(ratio + 1, self.T))))


class LSMTree:
    """The engine. Keys: ints (uint64 range); values: arbitrary objects."""

    def __init__(self, config: EngineConfig):
        self.cfg = config
        self.buffer: dict = {}           # int key -> int64-encoded value
        self.store = RunStore(config.entries_per_page)
        self.planner = make_planner(config)
        self.stats = IOStats()
        self.flush_seq = 0               # logical clock: flushes so far
        #: telemetry track label (``"<tenant-or-cell>/<policy>"`` by fleet
        #: convention); "" keeps this tree on the main trace track
        self.obs_label = ""
        #: intern-table sweep threshold (doubling schedule): the codec table
        #: is reclaimed when it crosses this, keeping it within 2x the live
        #: object count.  Int-only workloads never intern and never sweep.
        self._intern_sweep_at = 64

    # -- construction from a tuning -------------------------------------

    @staticmethod
    def config_from_phi(phi, sys, expected_entries: int,
                        buf_entries: Optional[int] = None,
                        entry_bytes: int = 64, page_bytes: int = 4096,
                        policy: str = "klsm",
                        policy_params: Tuple[Tuple[str, Any], ...] = ()
                        ) -> EngineConfig:
        """Lower a tuner-recommended Phi to an :class:`EngineConfig` at
        reduced scale.

        The *shape* of the tuning (T, K profile, filter bits/entry) carries
        over; N/buffer are scaled to CPU-testable sizes with the memory split
        preserved as bits-per-entry."""
        import numpy as _np
        T = int(float(phi.T))
        K = tuple(int(k) for k in _np.asarray(phi.K))
        m_total_bpe = sys.bits_per_entry
        filt_bpe = float(phi.mfilt_bits) / sys.N
        assert filt_bpe <= 1024, (
            f"filter bits/entry = {filt_bpe:.3g}: `sys` must be the SAME "
            "LSMSystem the tuning was produced under (mfilt_bits is "
            "normalized by sys.N)")
        buf_bpe = m_total_bpe - filt_bpe
        if buf_entries is None:
            # preserve buffer share: buf_bits = buf_bpe * N_small
            buf_bits = buf_bpe * expected_entries
            buf_entries = max(64, int(buf_bits / (entry_bytes * 8)))
        return EngineConfig(T=T, K=K, buf_entries=buf_entries,
                            entry_bytes=entry_bytes, page_bytes=page_bytes,
                            mfilt_bits_per_entry=filt_bpe,
                            expected_entries=expected_entries,
                            policy=policy, policy_params=tuple(policy_params))

    @classmethod
    def from_phi(cls, phi, sys, expected_entries: int,
                 buf_entries: Optional[int] = None,
                 entry_bytes: int = 64, page_bytes: int = 4096,
                 policy: str = "klsm",
                 policy_params: Tuple[Tuple[str, Any], ...] = ()) -> "LSMTree":
        """Deploy a tuner-recommended Phi at reduced scale
        (see :meth:`config_from_phi`)."""
        return cls(cls.config_from_phi(
            phi, sys, expected_entries, buf_entries=buf_entries,
            entry_bytes=entry_bytes, page_bytes=page_bytes, policy=policy,
            policy_params=policy_params))

    def retune(self, phi, sys) -> None:
        """Swap the deployed tuning in place, at a flush boundary.

        The online re-tuning primitive (:mod:`repro.online`): the write
        buffer is flushed under the OLD tuning (so the swap lands exactly on
        a flush boundary), then the config and planner are replaced.  The
        adaptation is *gradual*, as in a live LSM deployment: existing runs
        keep their Bloom allocations and layout; new flushes, merges, and
        capacity triggers follow the new (T, K, memory split), so the tree
        converges to the new shape through normal compaction — whose I/O is
        charged to ``stats`` like any other compaction (the transition cost
        is real and measured, not waved away).  Engine-scale knobs
        (``expected_entries``, entry/page bytes) and the compaction policy
        carry over from the current config.  A re-tune that resolves to the
        CURRENT config is a no-op (no forced flush): an adaptive loop may
        re-derive the same integral tuning every window without perturbing
        the tree."""
        cfg = self.config_from_phi(
            phi, sys, self.cfg.expected_entries,
            entry_bytes=self.cfg.entry_bytes,
            page_bytes=self.cfg.page_bytes, policy=self.cfg.policy,
            policy_params=self.cfg.policy_params)
        if cfg == self.cfg:
            obs.count("engine.retune.noop")
            return
        obs.count("engine.retune")
        with obs.track(self.obs_label), \
                obs.span("engine.retune", policy=cfg.policy,
                         T=cfg.T, buf_entries=cfg.buf_entries):
            self.flush()
            self.cfg = cfg
            self.planner = make_planner(cfg)
            self._maintain()

    # -- bits allocation --------------------------------------------------

    def _bits_per_key(self, level: int) -> float:
        return monkey_bits_per_key(
            level, self.cfg.est_levels, float(self.cfg.T),
            self.cfg.mfilt_bits_per_entry * self.cfg.expected_entries,
            float(self.cfg.expected_entries))

    # -- write path --------------------------------------------------------

    def _encode(self, value: Any) -> int:
        if value is TOMBSTONE:
            return TOMB
        return self.store.codec.encode(value)

    def put(self, key: int, value: Any) -> None:
        self.stats.queries["w"] += 1
        self.buffer[int(key)] = self._encode(value)
        if len(self.buffer) >= self.cfg.buf_entries:
            self.flush()

    def delete(self, key: int) -> None:
        self.put(key, TOMBSTONE)

    def put_batch(self, keys, values: Sequence[Any]) -> None:
        """Bulk insert in buffer-sized chunks; equivalent to sequential
        :meth:`put` calls without the per-key Python overhead: same flush
        boundaries (chunks are cut to the buffer's remaining room) and same
        newest-wins semantics (insertion order is preserved, so later
        duplicates overwrite earlier ones; :meth:`flush` sorts each run)."""
        keys = np.asarray(keys, np.uint64)
        n = len(keys)
        if len(values) != n:
            raise ValueError(f"put_batch: {n} keys but {len(values)} values")
        int_vals = isinstance(values, np.ndarray) and values.dtype.kind in "iu"
        i = 0
        while i < n:
            room = max(1, self.cfg.buf_entries - len(self.buffer))
            chunk = keys[i:i + room]
            vals = values[i:i + room]
            # Encode per chunk, never ahead of insertion: a flush at a chunk
            # boundary may run the intern-table sweep, which only sees slots
            # already in the buffer/arenas — pre-encoded pending values
            # would be swept as dead and their slot ids dangle.
            if int_vals:
                enc = self.store.codec.encode_many(vals)
            else:
                # object dtypes route per-element so TOMBSTONE maps to TOMB
                enc = np.fromiter((self._encode(v) for v in vals), np.int64,
                                  len(chunk))
            self.buffer.update(zip(chunk.tolist(), enc.tolist()))
            self.stats.queries["w"] += len(chunk)
            i += len(chunk)
            if len(self.buffer) >= self.cfg.buf_entries:
                self.flush()

    def flush(self) -> None:
        if not self.buffer:
            return
        obs.count("engine.flush")
        keys = np.fromiter(self.buffer.keys(), np.uint64, len(self.buffer))
        vals = np.fromiter(self.buffer.values(), np.int64, len(self.buffer))
        order = np.argsort(keys)
        self.flush_seq += 1
        tomb_seq = self.flush_seq if bool((vals == TOMB).any()) else -1
        run = RunData.build(keys[order], vals[order], self._bits_per_key(1),
                            flushes=1, tomb_seq=tomb_seq)
        self.stats.comp_pages_written += pages_of(
            len(run), self.cfg.entries_per_page)   # sequential flush
        self.buffer.clear()
        self._push_run(1, run)
        self._maintain()
        # Compaction-time intern reclamation: the buffer is empty here, so
        # every live interned slot is visible in the level arenas.
        if len(self.store.codec.objects) >= self._intern_sweep_at:
            self.store.reclaim_interned()
            self._intern_sweep_at = max(64, 2 * len(self.store.codec.objects))

    def _execute_plan(self, plan, run, bpk):
        """``store.execute`` with per-plan telemetry counters attached:
        plan kinds, compactions per policy, and compaction page deltas."""
        if not obs.enabled():
            return self.store.execute(plan, run, self.stats, bpk)
        s = self.stats
        read0, written0 = s.comp_pages_read, s.comp_pages_written
        out = self.store.execute(plan, run, s, bpk)
        obs.count("engine.plan." + plan.kind)
        obs.count("engine.compaction." + self.cfg.policy)
        obs.count("engine.comp_pages_read", s.comp_pages_read - read0)
        obs.count("engine.comp_pages_written",
                  s.comp_pages_written - written0)
        return out

    def _push_run(self, level: int, run: RunData) -> None:
        """Plan-execute-replan until the incoming run finds a home."""
        while True:
            occ = self.store.occupancy(min_levels=level)
            plan = self.planner.plan_push(occ, level, len(run), run.flushes)
            if plan.kind == "spill":
                run = self._execute_plan(plan, run,
                                         self._bits_per_key(level + 1))
                level += 1
                continue
            bpk = self._bits_per_key(level)
            self._execute_plan(plan, run, bpk)
            for clamp in self.planner.plan_clamps(
                    self.store.occupancy(min_levels=level), level):
                self._execute_plan(clamp, None, bpk)
            return

    def _maintain(self) -> None:
        """Poll the planner's maintenance hook until it is satisfied.

        Read-pressure squeezes (lazy leveling), over-capacity partial
        spills, and tombstone-TTL sweeps all arrive through here as the
        same :class:`~repro.lsm.planner.MergePlan` vocabulary the write
        path executes.  Spill-kind plans re-enter :meth:`_push_run` at
        their target level, so a maintenance merge cascades through the
        same plan-execute-replan loop an overflowing flush would.  The
        K-LSM planner has no maintenance; this is a no-op for it."""
        if not self.planner.has_maintenance:
            return
        for _ in range(100_000):
            plans = self.planner.plan_maintenance(self.store, self.stats,
                                                  self.flush_seq)
            if not plans:
                return
            for plan in plans:
                # merge outputs live at target_level, so they take ITS
                # Monkey bits budget (only in-level plans stay at level)
                bpk = self._bits_per_key(plan.target_level)
                if plan.kind == "spill":
                    out = self._execute_plan(plan, None, bpk)
                    if len(out):
                        self._push_run(plan.target_level, out)
                else:
                    self._execute_plan(plan, None, bpk)
        raise RuntimeError(
            f"{type(self.planner).__name__}.plan_maintenance did not "
            "converge within 100000 rounds")

    # -- read path ----------------------------------------------------------

    def _buffer_sorted(self) -> Tuple[np.ndarray, np.ndarray]:
        bkeys = np.fromiter(self.buffer.keys(), np.uint64, len(self.buffer))
        benc = np.fromiter(self.buffer.values(), np.int64, len(self.buffer))
        order = np.argsort(bkeys)
        return bkeys[order], benc[order]

    @staticmethod
    def resolve_in_sorted(bkeys: np.ndarray, benc: np.ndarray,
                          keys: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """(hit, encoded) membership of ``keys`` in a sorted (keys, enc)
        buffer view — the one buffer-resolution primitive shared by the
        engine's read path and the session executor's window simulation."""
        loc = np.searchsorted(bkeys, keys)
        inb = loc < len(bkeys)
        hit = np.zeros(len(keys), bool)
        hit[inb] = bkeys[loc[inb]] == keys[inb]
        henc = benc[loc[hit]] if hit.any() else np.empty(0, np.int64)
        return hit, henc

    def _lookup_batch(self, keys_arr: np.ndarray,
                      resolved: Optional[np.ndarray] = None,
                      found: Optional[np.ndarray] = None,
                      enc: Optional[np.ndarray] = None,
                      use_buffer: bool = True
                      ) -> Tuple[np.ndarray, np.ndarray]:
        """(found, encoded_values) for a key batch.

        Visits the buffer, then every level newest -> oldest.  Bloom probes
        run as one whole-level :class:`BloomPack` probe, but probe /
        random-read / false-positive counts follow the sequential visit
        order (a key resolved by a newer run is not probed in older ones),
        so ``IOStats`` is identical to per-key execution.

        Callers that already resolved some keys upstream (the session
        executor accounts an evolving write buffer itself) pass the partial
        ``resolved``/``found``/``enc`` state and ``use_buffer=False``."""
        n = len(keys_arr)
        resolved = np.zeros(n, bool) if resolved is None else resolved
        found = np.zeros(n, bool) if found is None else found
        enc = np.zeros(n, np.int64) if enc is None else enc
        if use_buffer and self.buffer:
            if n == 1:        # scalar get/point_query: O(1) dict probe
                v = self.buffer.get(int(keys_arr[0]))
                if v is not None:
                    resolved[0] = True
                    found[0] = v != TOMB
                    enc[0] = v
            else:
                bkeys, benc = self._buffer_sorted()
                hit, henc = self.resolve_in_sorted(bkeys, benc, keys_arr)
                if hit.any():
                    resolved |= hit
                    found[hit] = henc != TOMB
                    enc[hit] = henc
        stats = self.stats
        for lv in self.store.levels:
            if lv.num_runs == 0:
                continue
            sub = np.flatnonzero(~resolved)     # still-unresolved query ids
            if sub.size == 0:
                break
            # Fused per-level read (Bloom probe + fence + binary search);
            # every implementation behind the dispatch keeps the exact
            # sequential-equivalent counters — see lsm/read_path.py.
            hit, henc, probes, reads, fps = point_read_level(
                lv, keys_arr[sub])
            stats.bloom_probes += probes
            stats.random_reads += reads
            stats.bloom_false_positives += fps
            if hit.any():
                gidx = sub[hit]
                venc = henc[hit]
                resolved[gidx] = True
                found[gidx] = venc != TOMB
                enc[gidx] = venc
        return found, enc

    def get(self, key: int) -> Optional[Any]:
        found, enc = self._lookup_batch(np.asarray([key], np.uint64))
        return self.store.codec.decode(enc[0]) if found[0] else None

    def point_query(self, key: int) -> Optional[Any]:
        """A classified point query (updates z0/z1 accounting)."""
        found, enc = self._lookup_batch(np.asarray([key], np.uint64))
        self.stats.queries["z1" if found[0] else "z0"] += 1
        out = self.store.codec.decode(enc[0]) if found[0] else None
        self._maintain()     # read-triggered policies (lazy leveling)
        return out

    def point_query_batch(self, keys) -> List[Optional[Any]]:
        """Classified point queries for a key batch; equivalent to
        ``[point_query(k) for k in keys]`` (same run visit order, same I/O
        and bloom accounting, same z0/z1 classification)."""
        keys_arr = np.asarray(keys, np.uint64)
        found, enc = self.classify_point_batch(keys_arr)
        results: List[Optional[Any]] = [None] * len(keys_arr)
        idx = np.flatnonzero(found)
        for i, v in zip(idx.tolist(),
                        self.store.codec.decode_many(enc[idx])):
            results[i] = v
        return results

    def classify_point_batch(self, keys_arr: np.ndarray,
                             resolved: Optional[np.ndarray] = None,
                             found: Optional[np.ndarray] = None,
                             enc: Optional[np.ndarray] = None,
                             use_buffer: bool = True
                             ) -> Tuple[np.ndarray, np.ndarray]:
        """The accounting core of :meth:`point_query_batch`, without
        materializing a Python result list (the fleet executor's path)."""
        s = self.stats
        before = ((s.bloom_probes, s.bloom_false_positives, s.random_reads)
                  if obs.enabled() else None)
        found, enc = self._lookup_batch(keys_arr, resolved=resolved,
                                        found=found, enc=enc,
                                        use_buffer=use_buffer)
        nz1 = int(found.sum())
        self.stats.queries["z1"] += nz1
        self.stats.queries["z0"] += len(keys_arr) - nz1
        if before is not None:
            obs.count("engine.read.batches")
            obs.count("engine.read.keys", len(keys_arr))
            obs.count("engine.bloom.probes", s.bloom_probes - before[0])
            obs.count("engine.bloom.false_positives",
                      s.bloom_false_positives - before[1])
            obs.count("engine.read.random_reads",
                      s.random_reads - before[2])
        self._maintain()     # read-triggered policies fire at batch ends
        return found, enc

    def range_query(self, lo: int, hi: int) -> List[Tuple[int, Any]]:
        return self.range_query_batch([lo], [hi], return_results=True)[0]

    def range_query_batch(self, los, his, return_results: bool = False
                          ) -> Optional[List[List[Tuple[int, Any]]]]:
        """A batch of inclusive-lo, exclusive-hi range queries.

        Per run: one two-sided ``searchsorted`` for the whole batch; each
        overlapping (query, run) pair counts 1 seek + sequential page reads,
        exactly like the per-query path.  With ``return_results`` the
        newest-wins merge across runs + buffer happens in one global
        (query, key, recency) lexsort; without it (workload sessions discard
        range results) only the accounting runs."""
        los = np.asarray(los, np.uint64)
        his = np.asarray(his, np.uint64)
        Q = len(los)
        self.stats.queries["q"] += Q
        if obs.enabled():
            obs.count("engine.range.batches")
            obs.count("engine.range.queries", Q)
        epp = self.cfg.entries_per_page
        pieces = []                         # (qid, keys, vals, recency)
        recency = 0
        for lv in self.store.levels:
            for r in range(lv.num_runs):    # newest -> oldest
                if lv.run_len(r) == 0:
                    recency += 1
                    continue
                # fence fast-path: runs no query overlaps cost nothing
                if not ((los <= lv.max_keys[r]) & (his > lv.min_keys[r])
                        ).any():
                    recency += 1
                    continue
                rkeys, rvals = lv.run_slice(r)
                i = np.searchsorted(rkeys, los, side="left")
                j = np.searchsorted(rkeys, his, side="left")
                ov = i < j
                n_ov = int(ov.sum())
                if n_ov:
                    self.stats.random_reads += n_ov           # the seeks
                    self.stats.seq_reads += int(
                        ((j[ov] - 1) // epp - i[ov] // epp).sum())
                    if return_results:
                        idx, qid = _multi_ranges(i[ov], j[ov],
                                                 np.flatnonzero(ov))
                        pieces.append((qid, rkeys[idx], rvals[idx],
                                       np.full(len(idx), recency, np.int64)))
                recency += 1
        self._maintain()     # range seeks count as read pressure too
        if not return_results:
            return None
        if self.buffer:                     # newest of all: recency -1
            bkeys, benc = self._buffer_sorted()
            i = np.searchsorted(bkeys, los, side="left")
            j = np.searchsorted(bkeys, his, side="left")
            ov = i < j
            if ov.any():
                idx, qid = _multi_ranges(i[ov], j[ov], np.flatnonzero(ov))
                pieces.append((qid, bkeys[idx], benc[idx],
                               np.full(len(idx), -1, np.int64)))
        results: List[List[Tuple[int, Any]]] = [[] for _ in range(Q)]
        if not pieces:
            return results
        qid = np.concatenate([p[0] for p in pieces])
        keys = np.concatenate([p[1] for p in pieces])
        vals = np.concatenate([p[2] for p in pieces])
        rec = np.concatenate([p[3] for p in pieces])
        order = np.lexsort((rec, keys, qid))
        qid, keys, vals = qid[order], keys[order], vals[order]
        keep = np.ones(len(qid), bool)      # first (newest) version per
        keep[1:] = (qid[1:] != qid[:-1]) | (keys[1:] != keys[:-1])  # (q, key)
        sel = keep & (vals != TOMB)
        qs = qid[sel].tolist()
        ks = keys[sel].tolist()
        vs = self.store.codec.decode_many(vals[sel])
        for q, k, v in zip(qs, ks, vs):
            results[q].append((k, v))
        return results

    # -- introspection --------------------------------------------------------

    @property
    def num_entries(self) -> int:
        return len(self.buffer) + self.store.total_entries

    def shape(self) -> List[Tuple[int, List[int]]]:
        """[(level, [run sizes])] for non-empty levels."""
        return self.store.shape()

    def filter_bits_in_use(self) -> int:
        return self.store.filter_bits_in_use()


def _multi_ranges(starts: np.ndarray, ends: np.ndarray, qids: np.ndarray
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Flatten [starts, ends) index ranges into one gather-index array plus
    the query id of every gathered element."""
    lens = (ends - starts).astype(np.int64)
    total = int(lens.sum())
    offs = np.concatenate([np.zeros(1, np.int64), np.cumsum(lens)[:-1]])
    idx = (np.arange(total, dtype=np.int64) - np.repeat(offs, lens)
           + np.repeat(starts.astype(np.int64), lens))
    return idx, np.repeat(qids, lens)
