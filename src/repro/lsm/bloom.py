"""Bloom filters with Monkey-style per-level memory allocation.

Vectorized numpy implementation: build hashes all keys at once; probes are
O(k) bit tests.  Hashing is splitmix64 with per-hash-function seeds, the same
scheme the Pallas ``bloom_probe`` kernel mirrors (kernels/bloom_probe).
"""

from __future__ import annotations

import math

import numpy as np

_SPLITMIX_GAMMA = np.uint64(0x9E3779B97F4A7C15)
_MASK64 = (1 << 64) - 1


def splitmix64(x: np.ndarray, seed: np.uint64) -> np.ndarray:
    """Deterministic 64-bit mix; operates elementwise on uint64 arrays."""
    with np.errstate(over="ignore"):
        z = (x + seed * _SPLITMIX_GAMMA).astype(np.uint64)
        z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        return z ^ (z >> np.uint64(31))


def splitmix64_scalar(x: int, seed: int) -> int:
    """Scalar splitmix64 on Python ints; bit-identical to :func:`splitmix64`.

    The per-probe hot path (one call per hash function per run per point
    lookup) — avoids allocating a 1-element numpy array per probe.
    """
    z = (x + seed * 0x9E3779B97F4A7C15) & _MASK64
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _MASK64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _MASK64
    return z ^ (z >> 31)


class BloomFilter:
    """Standard Bloom filter over uint64 keys.

    ``bits_per_key`` chooses the optimal number of hash functions
    k = bits_per_key * ln 2 (Section 4.1 assumes the optimum)."""

    __slots__ = ("n_bits", "k", "words", "n_keys")

    def __init__(self, keys: np.ndarray, bits_per_key: float):
        keys = np.asarray(keys, np.uint64)
        self.n_keys = len(keys)
        n_bits = max(64, int(math.ceil(bits_per_key * max(self.n_keys, 1))))
        self.n_bits = n_bits
        self.k = max(1, int(round(bits_per_key * math.log(2))))
        words = np.zeros((n_bits + 63) // 64, np.uint64)
        if self.n_keys:
            for j in range(self.k):
                h = splitmix64(keys, np.uint64(j + 1)) % np.uint64(n_bits)
                np.bitwise_or.at(words, (h >> np.uint64(6)).astype(np.int64),
                                 np.uint64(1) << (h & np.uint64(63)))
        self.words = words

    def might_contain(self, key: int) -> bool:
        key = int(key)
        words = self.words
        n_bits = self.n_bits
        for j in range(1, self.k + 1):
            h = splitmix64_scalar(key, j) % n_bits
            if not (int(words[h >> 6]) >> (h & 63)) & 1:
                return False
        return True

    def might_contain_batch(self, keys: np.ndarray) -> np.ndarray:
        keys = np.asarray(keys, np.uint64)
        out = np.ones(len(keys), bool)
        for j in range(self.k):
            h = splitmix64(keys, np.uint64(j + 1)) % np.uint64(self.n_bits)
            bit = (self.words[(h >> np.uint64(6)).astype(np.int64)]
                   >> (h & np.uint64(63))) & np.uint64(1)
            out &= bit.astype(bool)
        return out

    @property
    def bits_used(self) -> int:
        return self.n_bits


def monkey_bits_per_key(level: int, num_levels: int, T: float,
                        mfilt_bits: float, N: float) -> float:
    """Invert Eq. 3: level-i FPR -> bits/key = -ln(f_i) / ln(2)^2, floored at 0.

    f_i(T) = T^{T/(T-1)} / T^{L+1-i} * exp(-(m_filt/N) ln(2)^2)
    """
    ln2sq = math.log(2) ** 2
    log_f = ((T / (T - 1.0)) * math.log(T)
             - (num_levels + 1.0 - level) * math.log(T)
             - (mfilt_bits / N) * ln2sq)
    log_f = min(log_f, 0.0)
    return max(0.0, -log_f / ln2sq)
