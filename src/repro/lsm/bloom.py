"""Bloom filters with Monkey-style per-level memory allocation.

Vectorized numpy implementation: build hashes all keys at once; probes are
O(k) bit tests.  Hashing is splitmix64 with per-hash-function seeds, the same
scheme the Pallas ``bloom_probe`` kernel mirrors (kernels/bloom_probe).

Two probe granularities:

* :class:`BloomFilter` — one filter over one run (scalar + batch probes);
* :class:`BloomPack`   — the filters of every run of a level packed into one
  padded ``(runs, words)`` bit matrix, probed for a whole key batch at once.
  The splitmix hashes are shared across runs (every filter uses seeds
  ``1..k``), so a level probe hashes each key ``k`` times total instead of
  ``k x runs`` times, and the bit gathers are single fancy-index operations.
  Bit-for-bit identical to probing each run's :class:`BloomFilter`.
"""

from __future__ import annotations

import math
import sys as _sys
from typing import Sequence, Tuple

import numpy as np

_LITTLE_ENDIAN = _sys.byteorder == "little"

_SPLITMIX_GAMMA = np.uint64(0x9E3779B97F4A7C15)
_MASK64 = (1 << 64) - 1


def splitmix64(x: np.ndarray, seed: np.uint64) -> np.ndarray:
    """Deterministic 64-bit mix; operates elementwise on uint64 arrays."""
    with np.errstate(over="ignore"):
        z = (x + seed * _SPLITMIX_GAMMA).astype(np.uint64)
        z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        return z ^ (z >> np.uint64(31))


def splitmix64_seeds(x: np.ndarray, kmax: int) -> np.ndarray:
    """All k hash rounds at once: (kmax, len(x)) of splitmix64(x, j+1).

    Row j is bit-identical to ``splitmix64(x, j + 1)``; one vectorized block
    replaces the per-round Python loop on the probe hot path."""
    seeds = np.arange(1, kmax + 1, dtype=np.uint64)[:, None]
    with np.errstate(over="ignore"):
        z = (x[None, :] + seeds * _SPLITMIX_GAMMA).astype(np.uint64)
        z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        return z ^ (z >> np.uint64(31))


def splitmix64_scalar(x: int, seed: int) -> int:
    """Scalar splitmix64 on Python ints; bit-identical to :func:`splitmix64`.

    The per-probe hot path (one call per hash function per run per point
    lookup) — avoids allocating a 1-element numpy array per probe.
    """
    z = (x + seed * 0x9E3779B97F4A7C15) & _MASK64
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _MASK64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _MASK64
    return z ^ (z >> 31)


def bloom_params(n_keys: int, bits_per_key: float) -> Tuple[int, int]:
    """(n_bits, k) for a run of ``n_keys`` keys — the engine-wide layout."""
    n_bits = max(64, int(math.ceil(bits_per_key * max(n_keys, 1))))
    k = max(1, int(round(bits_per_key * math.log(2))))
    return n_bits, k


def build_words(keys: np.ndarray, n_bits: int, k: int) -> np.ndarray:
    """The packed bit array of a filter, fully vectorized.

    Equivalent to k rounds of ``np.bitwise_or.at`` (the scatter-OR ufunc,
    which is an order of magnitude slower because it loops in C per element):
    all k x n bit positions are hashed at once, scattered into a bool bitmap,
    and packed little-endian so bit ``b`` of word ``w`` is bit ``64w + b`` —
    the exact layout the probes address."""
    n_words = (n_bits + 63) // 64
    n = len(keys)
    if n == 0:
        return np.zeros(n_words, np.uint64)
    pos = splitmix64_seeds(keys, k) % np.uint64(n_bits)
    if _LITTLE_ENDIAN:
        bitmap = np.zeros(n_words * 64, bool)
        bitmap[pos.ravel()] = True
        return np.packbits(bitmap, bitorder="little").view(np.uint64)
    pos = np.unique(pos.ravel())                  # sorted unique bit indices
    words = np.zeros(n_words, np.uint64)
    widx = (pos >> np.uint64(6)).astype(np.int64)
    bits = np.uint64(1) << (pos & np.uint64(63))
    starts = np.flatnonzero(np.r_[True, widx[1:] != widx[:-1]])
    words[widx[starts]] = np.bitwise_or.reduceat(bits, starts)
    return words


class BloomFilter:
    """Standard Bloom filter over uint64 keys.

    ``bits_per_key`` chooses the optimal number of hash functions
    k = bits_per_key * ln 2 (Section 4.1 assumes the optimum)."""

    __slots__ = ("n_bits", "k", "words", "n_keys")

    def __init__(self, keys: np.ndarray, bits_per_key: float):
        keys = np.asarray(keys, np.uint64)
        self.n_keys = len(keys)
        self.n_bits, self.k = bloom_params(self.n_keys, bits_per_key)
        self.words = build_words(keys, self.n_bits, self.k)

    def might_contain(self, key: int) -> bool:
        key = int(key)
        words = self.words
        n_bits = self.n_bits
        for j in range(1, self.k + 1):
            h = splitmix64_scalar(key, j) % n_bits
            if not (int(words[h >> 6]) >> (h & 63)) & 1:
                return False
        return True

    def might_contain_batch(self, keys: np.ndarray) -> np.ndarray:
        keys = np.asarray(keys, np.uint64)
        out = np.ones(len(keys), bool)
        for j in range(self.k):
            h = splitmix64(keys, np.uint64(j + 1)) % np.uint64(self.n_bits)
            bit = (self.words[(h >> np.uint64(6)).astype(np.int64)]
                   >> (h & np.uint64(63))) & np.uint64(1)
            out &= bit.astype(bool)
        return out

    @property
    def bits_used(self) -> int:
        return self.n_bits


class BloomPack:
    """All Bloom filters of one level, packed for whole-level batch probes.

    ``words`` is a ``(runs, max_words)`` uint64 matrix (rows zero-padded to
    the widest filter — padding words are never addressed because hashes are
    reduced mod the row's own ``n_bits``).  :meth:`probe` answers "might run
    r contain key b?" for every (run, key) pair with k shared hash rounds.
    """

    __slots__ = ("words", "n_bits", "ks", "n_runs")

    def __init__(self, words_list: Sequence[np.ndarray],
                 n_bits: Sequence[int], ks: Sequence[int]):
        self.n_runs = len(words_list)
        wmax = max((len(w) for w in words_list), default=0)
        mat = np.zeros((self.n_runs, wmax), np.uint64)
        for r, w in enumerate(words_list):
            mat[r, :len(w)] = w
        self.words = mat
        self.n_bits = np.asarray(n_bits, np.uint64)
        self.ks = np.asarray(ks, np.int64)

    def probe(self, keys: np.ndarray) -> np.ndarray:
        """(runs, batch) bool: bit-identical to per-run ``might_contain``."""
        keys = np.asarray(keys, np.uint64)
        R, B = self.n_runs, len(keys)
        if R == 0 or B == 0:
            return np.ones((R, B), bool)
        kmax = int(self.ks.max())
        h = splitmix64_seeds(keys, kmax)                    # (kmax, B)
        hm = h[None, :, :] % self.n_bits[:, None, None]     # (R, kmax, B)
        w = self.words[np.arange(self.n_runs)[:, None, None],
                       (hm >> np.uint64(6)).astype(np.intp)]
        bits = ((w >> (hm & np.uint64(63))) & np.uint64(1)).astype(bool)
        # rounds past a run's own k never veto that run
        bits |= np.arange(kmax)[None, :, None] >= self.ks[:, None, None]
        return bits.all(axis=1)


def monkey_bits_per_key(level: int, num_levels: int, T: float,
                        mfilt_bits: float, N: float) -> float:
    """Invert Eq. 3: level-i FPR -> bits/key = -ln(f_i) / ln(2)^2, floored at 0.

    f_i(T) = T^{T/(T-1)} / T^{L+1-i} * exp(-(m_filt/N) ln(2)^2)
    """
    ln2sq = math.log(2) ** 2
    log_f = ((T / (T - 1.0)) * math.log(T)
             - (num_levels + 1.0 - level) * math.log(T)
             - (mfilt_bits / N) * ln2sq)
    log_f = min(log_f, 0.0)
    return max(0.0, -log_f / ln2sq)
