"""Deterministic, shard-aware, resumable synthetic token pipeline.

Batches are a pure function of (seed, step, shard) — so any worker can
recompute any batch, which is the foundation for:

* exactly-once semantics across checkpoint/restart (the cursor is one int),
* straggler/failure reassignment (a surviving worker re-derives a lost
  shard's batches deterministically),
* elastic re-sharding (changing the shard count re-partitions the same
  global stream).

The synthetic stream is a mixture of structured sequences (arithmetic-mod
chains, repeated motifs) so that a real LM can actually reduce loss on it.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int = 1024
    seq_len: int = 128
    global_batch: int = 8
    seed: int = 1234


@dataclasses.dataclass
class DataState:
    """The resumable cursor (saved in checkpoints)."""
    step: int = 0

    def to_dict(self) -> Dict[str, int]:
        return {"step": self.step}

    @classmethod
    def from_dict(cls, d: Dict[str, int]) -> "DataState":
        return cls(step=int(d["step"]))


def _sequence(rng: np.random.Generator, V: int, S: int) -> np.ndarray:
    """One structured sequence: motif repetition + modular ramps."""
    kind = rng.integers(0, 3)
    if kind == 0:  # repeated motif
        m = rng.integers(2, 9)
        motif = rng.integers(0, V, m)
        reps = -(-(S + 1) // m)
        seq = np.tile(motif, reps)[:S + 1]
    elif kind == 1:  # modular ramp
        start = rng.integers(0, V)
        stride = rng.integers(1, 7)
        seq = (start + stride * np.arange(S + 1)) % V
    else:  # noisy copy of a short prefix
        p = rng.integers(4, 16)
        prefix = rng.integers(0, V, p)
        reps = -(-(S + 1) // p)
        seq = np.tile(prefix, reps)[:S + 1]
        flips = rng.random(S + 1) < 0.05
        seq = np.where(flips, rng.integers(0, V, S + 1), seq)
    return seq.astype(np.int32)


def global_batch_at(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """The full (tokens, labels) global batch for a step (pure function)."""
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))
    seqs = np.stack([_sequence(rng, cfg.vocab_size, cfg.seq_len)
                     for _ in range(cfg.global_batch)])
    return {"tokens": seqs[:, :-1], "labels": seqs[:, 1:]}


def shard_batch_at(cfg: DataConfig, step: int, shard: int,
                   num_shards: int) -> Dict[str, np.ndarray]:
    """This shard's slice of the step's global batch."""
    assert cfg.global_batch % num_shards == 0
    per = cfg.global_batch // num_shards
    full = global_batch_at(cfg, step)
    sl = slice(shard * per, (shard + 1) * per)
    return {k: v[sl] for k, v in full.items()}


def iterate(cfg: DataConfig, state: Optional[DataState] = None,
            shard: int = 0, num_shards: int = 1
            ) -> Iterator[Dict[str, np.ndarray]]:
    state = state or DataState()
    while True:
        yield shard_batch_at(cfg, state.step, shard, num_shards)
        state.step += 1
