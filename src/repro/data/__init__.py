from .pipeline import DataConfig, DataState, global_batch_at, iterate, shard_batch_at

__all__ = ["DataConfig", "DataState", "global_batch_at", "iterate",
           "shard_batch_at"]
