"""Tests for fleet-level memory arbitration (repro.online.memory).

Covers the budget semantics (grid/units/validation), the deterministic
greedy division against hand-crafted marginal curves, the traced-budget
cost sweep (bit-identical to the plain cost vector at the current budget),
the MemorySpec axis (validation + JSON round-trip), and the execution
invariants the bench gates: with arbitration disabled the arbitrated fleet
is bit-identical to the static fleet, and the static fleet is bit-identical
to the drift driver's ``static_robust`` arm (the "today's fixed-split
path" anchor).

Solver sizes match test_online_drift's SMALL so the jit cache is shared;
the end-to-end experiment runs once per module (fixture-cached)."""

import numpy as np
import pytest

from repro.core import LSMSystem, cost_across_memory, cost_vector, make_phi
from repro.online import MEMORY_ARMS, MemoryBudget, divide_budget

SMALL = dict(n_starts=8, steps=60, seed=3)
SYS_PAIRS = (("N", 8000.0), ("entry_bits", 512.0), ("bits_per_entry", 6.0),
             ("min_buf_bits", 512.0 * 64), ("max_T", 20.0))
SYS = LSMSystem().replace(**dict(SYS_PAIRS))

#: tenant mixes: write-heavy w4 vs read-bimodal w5 (maximally skewed fleet)
TENANTS = ((0.01, 0.01, 0.01, 0.97), (0.49, 0.49, 0.01, 0.01))


def _api():
    from repro import api
    return api


# ---------------------------------------------------------------------------
# Budget semantics
# ---------------------------------------------------------------------------

def test_memory_budget_grid_and_units():
    b = MemoryBudget(total_bpe=12.0, floor_bpe=2.0, quantum_bpe=1.0)
    b.validate(2)
    assert b.units(2) == 8
    grid = b.grid(2)
    assert grid[0] == 2.0 and grid[-1] == 10.0 and len(grid) == 9
    # a 3-tenant fleet has fewer free quanta on the same total
    assert b.units(3) == 6
    with pytest.raises(ValueError):
        b.validate(7)                  # 7 * 2.0 > 12.0
    with pytest.raises(ValueError):
        MemoryBudget(total_bpe=8.0, floor_bpe=0.0)
    with pytest.raises(ValueError):
        MemoryBudget(total_bpe=8.0, quantum_bpe=-1.0)


def test_divide_budget_greedy_marginals():
    b = MemoryBudget(total_bpe=8.0, floor_bpe=1.0, quantum_bpe=1.0)
    grid = b.grid(2)
    assert len(grid) == 7              # 1..7 bits/entry
    # tenant 0's cost drops 1.0 per quantum, tenant 1's only 0.1: every
    # free quantum goes to tenant 0 (up to the grid cap)
    steep = 10.0 - 1.0 * np.arange(7)
    flat = 10.0 - 0.1 * np.arange(7)
    shares = divide_budget(np.stack([steep, flat]), np.ones(2), b)
    assert shares.tolist() == [7.0, 1.0]
    assert shares.sum() == b.total_bpe
    # traffic weights tilt the division: tenant 1 serving 100x the ops
    # outweighs the 10x marginal-cost gap
    shares_w = divide_budget(np.stack([steep, flat]),
                             np.array([1.0, 100.0]), b)
    assert shares_w.tolist() == [1.0, 7.0]
    # equal curves: deterministic lowest-index tie-break, still exhaustive
    shares_eq = divide_budget(np.stack([steep, steep]), np.ones(2), b)
    assert shares_eq.sum() == b.total_bpe
    assert shares_eq[0] >= shares_eq[1]


def test_divide_budget_is_exchange_optimal_on_convex_curves():
    """On convex decreasing curves the greedy matches brute force."""
    b = MemoryBudget(total_bpe=9.0, floor_bpe=1.0, quantum_bpe=1.0)
    g = np.arange(7, dtype=np.float64)
    curves = np.stack([5.0 * 0.5 ** g, 4.0 / (1.0 + g), 3.0 - 0.3 * g])
    w = np.array([1.0, 2.0, 0.5])
    shares = divide_budget(curves, w, b)
    best, best_cost = None, np.inf
    for a0 in range(7):
        for a1 in range(7 - a0):
            a2 = 6 - a0 - a1
            cost = (w * curves[[0, 1, 2], [a0, a1, a2]]).sum()
            if cost < best_cost - 1e-12:
                best, best_cost = (a0, a1, a2), cost
    assert shares.tolist() == [1.0 + q for q in best]


# ---------------------------------------------------------------------------
# The traced-budget cost sweep
# ---------------------------------------------------------------------------

def test_cost_across_memory_anchors_and_monotone():
    phi = make_phi(4.0, 3.0 * SYS.N, 1.0, SYS)
    grid = np.array([2.0, 4.0, 6.0, 8.0, 10.0])
    curves = np.asarray(cost_across_memory(phi, SYS, grid), np.float64)
    assert curves.shape == (5, 4)
    # at the system's own budget the sweep IS the plain cost vector
    c0 = np.asarray(cost_vector(phi, SYS), np.float64)
    np.testing.assert_array_equal(curves[2], c0)
    # more memory never hurts any tenant mix (modeled costs nonincreasing)
    for w in TENANTS + ((0.25, 0.25, 0.25, 0.25),):
        exp = curves @ np.asarray(w)
        assert np.all(np.diff(exp) <= 1e-9), (w, exp)


# ---------------------------------------------------------------------------
# The spec axis
# ---------------------------------------------------------------------------

def _mem_spec(enabled=True, with_memory=True):
    api = _api()
    memory = api.MemorySpec(enabled=enabled, floor_bits_per_entry=2.0,
                            quantum_bits_per_entry=1.0, min_windows=1,
                            cooldown=1) if with_memory else None
    return api.ExperimentSpec(
        name="mem_test",
        workload=api.WorkloadSpec(workloads=TENANTS, nominal=False,
                                  rhos=(0.5,)),
        design=api.DesignSpec(**SMALL),
        drift=api.DriftSpec(kind="flip", segments=4, n_queries=200,
                            target=(0.33, 0.33, 0.33, 0.01), n_keys=4000,
                            key_space=2 ** 20, arms=("static_robust",),
                            estimator="window", window=4, capacity=32,
                            kl_threshold=0.1, min_windows=1, cooldown=1,
                            retune_starts=8, retune_steps=60),
        memory=memory, system=SYS_PAIRS)


def test_memory_spec_validation_and_roundtrip():
    api = _api()
    spec = _mem_spec()
    assert api.ExperimentSpec.from_json(spec.to_json()) == spec
    # memory without drift is rejected
    with pytest.raises(ValueError, match="drift"):
        api.ExperimentSpec(
            name="bad", workload=api.WorkloadSpec(workloads=TENANTS,
                                                  rhos=(0.5,)),
            memory=api.MemorySpec())
    # memory without a robust cell is rejected
    with pytest.raises(ValueError, match="robust"):
        api.ExperimentSpec(
            name="bad",
            workload=api.WorkloadSpec(workloads=TENANTS, nominal=True),
            drift=spec.drift, memory=api.MemorySpec())
    for bad in (dict(floor_bits_per_entry=0.0),
                dict(quantum_bits_per_entry=0.0),
                dict(total_bits_per_entry=-1.0),
                dict(rebalance_kl=0.0), dict(min_windows=0)):
        with pytest.raises(ValueError):
            api.MemorySpec(**bad)


# ---------------------------------------------------------------------------
# Execution invariants (one cached end-to-end run)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mem_reports():
    api = _api()
    on = api.run_experiment(_mem_spec(enabled=True))
    off = api.run_experiment(_mem_spec(enabled=False))
    drift_only = api.run_experiment(_mem_spec(with_memory=False))
    return on, off, drift_only


def _record_tuple(rec):
    return (rec.index, rec.avg_io_per_query, rec.queries, rec.windows,
            tuple(rec.observed_mix.tolist()))


def test_memory_fleet_results_shape(mem_reports):
    on, _, _ = mem_reports
    assert set(on.memory) == {(f, arm) for f in range(len(TENANTS))
                              for arm in MEMORY_ARMS}
    assert on.memory_events, "enabled arbitration must log its divisions"
    ev0 = on.memory_events[0]
    assert ev0["segment"] == -1 and ev0["reason"] == "initial_division"
    total = sum(ev0["shares"])
    assert total == pytest.approx(len(TENANTS) * SYS.bits_per_entry)
    # fleet rows render (the bench's metric source)
    names = {r.name for r in on.rows()}
    assert "mem_test_memory_fleet" in names
    assert "mem_test_memory_w0_arbitrated" in names
    # drift arms are replaced by the memory fleets, not run alongside
    assert not on.drift


def test_memory_disabled_is_bit_identical_to_static(mem_reports):
    _, off, _ = mem_reports
    assert off.memory_events == []
    for f in range(len(TENANTS)):
        static = off.memory[(f, "static")].records
        arb = off.memory[(f, "arbitrated")].records
        assert [_record_tuple(r) for r in static] \
            == [_record_tuple(r) for r in arb]
    assert off.memory_fleet_throughput("static") \
        == off.memory_fleet_throughput("arbitrated")


def test_memory_static_fleet_matches_drift_static_robust(mem_reports):
    """The static fleet IS today's fixed-split path: bit-identical to the
    drift driver's static_robust arm on the same spec."""
    _, off, drift_only = mem_reports
    for f in range(len(TENANTS)):
        static = off.memory[(f, "static")].records
        robust = drift_only.drift[(f, "static_robust")].records
        assert [_record_tuple(r) for r in static] \
            == [_record_tuple(r) for r in robust]


def test_memory_runs_on_sharded_and_subprocess_backends():
    """run_memory is the shared sequential driver on every real backend;
    the remote stub must refuse rather than silently run locally."""
    api = _api()
    base = api.ExecutionBackend.run_memory
    assert api.ShardedBackend.run_memory is base
    assert api.SubprocessBackend.run_memory is base
    with pytest.raises(NotImplementedError):
        api.RemoteBackend().run_memory(None, None)
