"""Chaos suite: deterministic fault injection and the recovery invariant.

The contract under test (``docs/faults.md``): for ANY injected fault
schedule, every result the hardened subprocess backend *recovers* is
bit-identical to the inline reference — retries, elastic re-sharding, and
resume move work, never change it — and when recovery is exhausted the
sweep degrades to explicit ``Report.failed_cells`` instead of crashing.
Artifacts (per-shard results, BENCH baselines, checkpoints) must be
crash-safe: atomic writes, content checksums, loaders that reject torn
files.

``REPRO_CHAOS_SEED`` (CI runs a small seed matrix) re-seeds every
probabilistic fault draw and backoff jitter: the *schedules* differ per
seed, the invariants must hold for all of them.

Trial sizes are tiny (thousands of keys, hundreds of queries); the wall
cost is dominated by worker process startup and the deliberate
hang-timeout test.
"""

import dataclasses
import glob
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.api import (DesignSpec, ExperimentSpec, FaultSpec, TrialSpec,
                       WorkloadSpec, run_experiment)
from repro.faults import (CHECKSUM_KEY, FaultPlan, RetryPolicy,
                          ShardSupervisor, TornWriteError, atomic_write_bytes,
                          atomic_write_json, checksum_ok, dump_job,
                          load_checked_json, load_job, payload_checksum, u01)

#: CI chaos-leg seed matrix: export REPRO_CHAOS_SEED=N to re-roll every
#: fault draw and backoff jitter.  Invariants must hold for every seed.
SEED = int(os.environ.get("REPRO_CHAOS_SEED", "0"))

SESSIONS = ((0.05, 0.85, 0.05, 0.05),)


def _spec(**kw) -> ExperimentSpec:
    base = dict(
        name="chaos",
        workload=WorkloadSpec(indices=(7, 11), rhos=(), nominal=True,
                              bench_n=0),
        design=DesignSpec(fixed=(6.0, 4.0, 1.0)),
        trial=TrialSpec(n_keys=4000, n_queries=300, sessions=SESSIONS),
        system=(("N", 8000.0), ("bits_per_entry", 6.0), ("max_T", 20.0)),
    )
    base.update(kw)
    return ExperimentSpec(**base)


def _sub_params(**kw):
    base = dict(workers=2, max_retries=2, backoff_s=0.01, timeout_s=120.0,
                retry_seed=SEED)
    base.update(kw)
    return tuple(base.items())


@pytest.fixture(scope="module")
def inline_report():
    """The reference run every chaos scenario must reproduce exactly."""
    return run_experiment(_spec())


def _assert_identical(inline, chaos):
    """The recovery invariant, at full strength: per-session IOStats and
    the post-trial engine probes are equal, not just summary statistics."""
    assert set(chaos.fleet) == set(inline.fleet)
    for key in inline.fleet:
        for a, b in zip(inline.fleet[key], chaos.fleet[key]):
            assert a.io == b.io
            assert a.avg_io_per_query == b.avg_io_per_query
        assert inline.probes[key] == chaos.probes[key]
    assert not chaos.failed_cells


# ---------------------------------------------------------------------------
# Fault specs and plans
# ---------------------------------------------------------------------------

def test_fault_spec_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec(kind="meteor")
    with pytest.raises(ValueError, match="outside"):
        FaultSpec(kind="crash", p=1.5)
    with pytest.raises(ValueError, match="max_hits"):
        FaultSpec(kind="crash", max_hits=-1)
    with pytest.raises(ValueError, match="delay_s"):
        FaultSpec(kind="slow", delay_s=-0.1)


def test_fault_plan_semantics():
    plan = FaultPlan.from_specs((
        FaultSpec(kind="crash", shards=(1,), max_hits=2, seed=SEED),
        FaultSpec(kind="slow", delay_s=0.5, max_hits=1, seed=SEED),
    ))
    assert plan and not FaultPlan(())
    # shard filter + first-match-wins: shard 1 crashes, others slow
    assert plan.worker_fault(1, 0).kind == "crash"
    assert plan.worker_fault(0, 0).kind == "slow"
    assert plan.worker_fault(0, 0).delay_s == 0.5
    # max_hits retirement: attempts beyond the budget draw nothing
    assert plan.worker_fault(1, 1).kind == "crash"  # within max_hits=2
    assert plan.worker_fault(1, 2) is None          # both specs retired
    assert plan.worker_fault(0, 1) is None
    # pure-hash draws: decisions are reproducible and order-independent
    again = FaultPlan.from_specs(plan.specs)
    coords = [(s, a) for s in range(4) for a in range(3)]
    assert [plan.worker_fault(s, a) for s, a in coords] == \
           [again.worker_fault(s, a) for s, a in reversed(coords)][::-1]
    # worker kinds never tear writes; torn_write never fires for workers
    assert not plan.tears_write("job_x.pkl")
    tear = FaultPlan.from_specs((FaultSpec(kind="torn_write",
                                           match="job_", seed=SEED),))
    assert tear.tears_write("job_x.pkl") and not tear.tears_write("b.json")
    assert tear.worker_fault(0, 0) is None


def test_u01_is_uniform_ish_and_stable():
    draws = [u01(SEED, "x", i) for i in range(2000)]
    assert all(0.0 <= d < 1.0 for d in draws)
    assert abs(np.mean(draws) - 0.5) < 0.05
    assert draws == [u01(SEED, "x", i) for i in range(2000)]


def test_fault_specs_ride_the_experiment_spec_json():
    spec = _spec(backend="subprocess",
                 faults=(FaultSpec(kind="crash", shards=(0,), p=0.5,
                                   seed=SEED),
                         FaultSpec(kind="torn_write", match="job_")))
    back = ExperimentSpec.from_json(spec.to_json())
    assert back == spec
    assert back.faults[0].shards == (0,)        # tuples survive the trip
    with pytest.raises(ValueError, match="FaultSpec"):
        _spec(faults=({"kind": "crash"},))      # dicts only via from_dict


# ---------------------------------------------------------------------------
# Crash-safe artifacts
# ---------------------------------------------------------------------------

def test_atomic_json_checksum_roundtrip(tmp_path):
    path = str(tmp_path / "BENCH_x.json")
    payload = atomic_write_json(path, {"suite": "x", "rows": [1, 2]})
    assert checksum_ok(payload)
    assert load_checked_json(path) == payload
    # checksum covers content, not formatting, and excludes itself
    assert payload_checksum(payload) == payload[CHECKSUM_KEY]
    # tamper -> loader refuses
    tampered = dict(payload, rows=[1, 3])
    with open(path, "w") as f:
        json.dump(tampered, f)
    with pytest.raises(ValueError, match="checksum mismatch"):
        load_checked_json(path)
    with open(path, "w") as f:
        json.dump({"suite": "x"}, f)
    with pytest.raises(ValueError, match="no 'checksum'"):
        load_checked_json(path)


def test_atomic_write_leaves_no_tmp(tmp_path):
    path = tmp_path / "a.bin"
    atomic_write_bytes(str(path), b"x" * 1000)
    assert path.read_bytes() == b"x" * 1000
    assert os.listdir(tmp_path) == ["a.bin"]    # tmp replaced, not leaked


def test_torn_write_fault_and_job_loader(tmp_path):
    path = str(tmp_path / "job_a.pkl")
    dump_job(path, {"plan": "d", "trees": {0: (1, 2)}})
    assert load_job(path) == {"plan": "d", "trees": {0: (1, 2)}}
    # injected torn write: truncated bytes at the FINAL path + an error
    tear = FaultPlan.from_specs((FaultSpec(kind="torn_write", match="job_",
                                           seed=SEED),))
    with pytest.raises(TornWriteError):
        dump_job(path, {"plan": "d", "trees": {0: (3, 4)}}, fault=tear)
    # the torn file is detected, never trusted
    assert load_job(path) is None
    assert load_job(str(tmp_path / "absent.pkl")) is None
    (tmp_path / "garbage.pkl").write_bytes(b"\x00\x01nonsense")
    assert load_job(str(tmp_path / "garbage.pkl")) is None


# ---------------------------------------------------------------------------
# Retry policy + shard supervision (pure units)
# ---------------------------------------------------------------------------

def test_retry_policy_backoff():
    pol = RetryPolicy(max_retries=3, backoff_s=0.1, seed=SEED)
    assert pol.attempts() == 4
    assert pol.delay(0, 0) == 0.0
    d1, d2, d3 = (pol.delay(0, a) for a in (1, 2, 3))
    assert 0.05 <= d1 < 0.15          # backoff * [0.5, 1.5) jitter
    assert 0.10 <= d2 < 0.30
    assert 0.20 <= d3 < 0.60
    assert pol.delay(0, 1) == d1      # deterministic
    assert pol.delay(1, 1) != d1      # de-synchronized across shards


def test_shard_supervisor_reassign():
    sup = ShardSupervisor()
    sup.record_failure(1, "boom")
    sup.record_failure(1, "boom again")
    sup.mark_dead(1)
    sup.mark_dead(1)
    sup.mark_completed(0)
    assert sup.dead == [1] and sup.retries == 2
    assert sup.last_error(1) == "boom again"
    assert sup.last_error(5) == "<no error recorded>"
    # sorted round-robin, capacity-bounded, no empty jobs
    assert sup.reassign([9, 3, 5], capacity=2) == [[3, 9], [5]]
    assert sup.reassign([3], capacity=8) == [[3]]
    assert sup.reassign([], capacity=4) == []


# ---------------------------------------------------------------------------
# The recovery invariant, end-to-end
# ---------------------------------------------------------------------------

def test_crash_retry_bit_identical(inline_report):
    chaos = run_experiment(_spec(
        backend="subprocess", backend_params=_sub_params(),
        faults=(FaultSpec(kind="crash", shards=(0,), max_hits=1,
                          seed=SEED),)))
    assert chaos.walls["shard_retries"] >= 1
    _assert_identical(inline_report, chaos)


def test_corrupt_and_slow_bit_identical(inline_report):
    chaos = run_experiment(_spec(
        backend="subprocess", backend_params=_sub_params(),
        faults=(FaultSpec(kind="corrupt", shards=(1,), max_hits=1,
                          seed=SEED),
                FaultSpec(kind="slow", shards=(0,), delay_s=0.2,
                          max_hits=1, seed=SEED))))
    assert chaos.walls["shard_retries"] >= 1    # the corrupt result
    _assert_identical(inline_report, chaos)


def test_hung_worker_times_out_and_recovers(inline_report):
    chaos = run_experiment(_spec(
        backend="subprocess",
        backend_params=_sub_params(timeout_s=10.0),
        faults=(FaultSpec(kind="hang", shards=(1,), max_hits=1,
                          seed=SEED),)))
    assert chaos.walls["shard_retries"] >= 1
    _assert_identical(inline_report, chaos)


def test_probabilistic_chaos_storm_bit_identical(inline_report):
    """Mixed-kind storm with p < 1: the schedule varies with
    REPRO_CHAOS_SEED, the invariant must not.  max_hits=1 bounds every
    population to first attempts, so the retry budget always wins."""
    chaos = run_experiment(_spec(
        backend="subprocess", backend_params=_sub_params(max_retries=3),
        faults=(FaultSpec(kind="crash", p=0.6, max_hits=1, seed=SEED),
                FaultSpec(kind="corrupt", p=0.6, max_hits=1,
                          seed=SEED + 1),
                FaultSpec(kind="slow", p=0.6, delay_s=0.1, max_hits=1,
                          seed=SEED + 2))))
    _assert_identical(inline_report, chaos)


def test_dead_shard_resharded_onto_survivors(inline_report):
    """A permanently dead worker slot: every retry on shard 1 crashes, so
    its trees regroup onto fresh slots (which re-roll the fault draws) —
    the elastic.py remesh pattern at sweep granularity."""
    chaos = run_experiment(_spec(
        backend="subprocess", backend_params=_sub_params(max_retries=1),
        faults=(FaultSpec(kind="crash", shards=(1,), max_hits=99,
                          seed=SEED),)))
    assert chaos.walls["reshard_trees"] >= 1
    assert chaos.walls["shards_run"] >= 3       # 2 first-round + re-shard
    _assert_identical(inline_report, chaos)


def test_systemic_failure_degrades_gracefully():
    """Every shard dead on every attempt: no survivors means re-sharding
    is pointless (the elastic remesh rule), so the sweep completes with
    explicit failed_cells — crash-free — and the error carries the
    worker's stderr (the injected-crash marker)."""
    chaos = run_experiment(_spec(
        backend="subprocess", backend_params=_sub_params(max_retries=1),
        faults=(FaultSpec(kind="crash", max_hits=99, seed=SEED),)))
    assert not chaos.fleet
    assert len(chaos.failed_cells) == 2
    for err in chaos.failed_cells.values():
        assert "InjectedWorkerCrash" in err      # stderr surfaced
        assert "exited 17" in err
    # the report still renders and serializes
    rows = chaos.rows()
    failed_rows = [r for r in rows if r.name.endswith("_failed")]
    assert len(failed_rows) == 1
    assert failed_rows[0].derived["failed"] == 2
    payload = chaos.to_bench_payload()
    json.dumps(payload, allow_nan=False)
    assert checksum_ok(payload)


def test_worker_stderr_attached_to_errors():
    """Satellite of the hardening: a failing worker's stderr reaches the
    recorded error instead of vanishing (the old check=True behavior)."""
    chaos = run_experiment(_spec(
        backend="subprocess", backend_params=_sub_params(max_retries=0),
        faults=(FaultSpec(kind="crash", max_hits=99, seed=SEED),)))
    assert chaos.failed_cells
    for err in chaos.failed_cells.values():
        assert "stderr:" in err and "InjectedWorkerCrash" in err


# ---------------------------------------------------------------------------
# Persistence + resume
# ---------------------------------------------------------------------------

def test_resume_reuses_completed_shards(tmp_path, inline_report):
    run_dir = str(tmp_path / "run")
    # run 1: shard 1 permanently dead, no re-sharding -> partial sweep,
    # shard 0's results persisted as they completed
    r1 = run_experiment(_spec(
        backend="subprocess",
        backend_params=_sub_params(max_retries=1, reshard=False,
                                   run_dir=run_dir),
        faults=(FaultSpec(kind="crash", shards=(1,), max_hits=99,
                          seed=SEED),)))
    assert r1.failed_cells and len(r1.fleet) == 1
    assert glob.glob(os.path.join(run_dir, "job_*.pkl"))
    # run 2: same plan, no faults, resume -> only the missing tree runs
    r2 = run_experiment(_spec(
        backend="subprocess",
        backend_params=_sub_params(run_dir=run_dir, resume=True)))
    assert r2.walls["resumed_trees"] == 1
    assert r2.walls["shards_run"] == 1          # the shard-execution count
    _assert_identical(inline_report, r2)
    # run 3: everything persisted -> zero shards execute
    r3 = run_experiment(_spec(
        backend="subprocess",
        backend_params=_sub_params(run_dir=run_dir, resume=True)))
    assert r3.walls["resumed_trees"] == 2
    assert r3.walls["shards_run"] == 0
    _assert_identical(inline_report, r3)


def test_resume_ignores_other_plans_and_torn_jobs(tmp_path, inline_report):
    run_dir = str(tmp_path / "run")
    run_experiment(_spec(
        backend="subprocess",
        backend_params=_sub_params(run_dir=run_dir)))
    jobs = sorted(glob.glob(os.path.join(run_dir, "job_*.pkl")))
    assert len(jobs) == 2
    # tear one persisted job + plant one from a foreign plan
    with open(jobs[0], "rb") as f:
        data = f.read()
    with open(jobs[0], "wb") as f:
        f.write(data[: len(data) // 2])
    dump_job(os.path.join(run_dir, "job_feedbeef_cafe.pkl"),
             {"plan": "feedbeef", "trees": {0: ("wrong", "wrong")}})
    r = run_experiment(_spec(
        backend="subprocess",
        backend_params=_sub_params(run_dir=run_dir, resume=True)))
    # torn job -> its tree re-executed; foreign plan -> never consumed
    assert r.walls["resumed_trees"] == 1
    assert r.walls["shards_run"] == 1
    _assert_identical(inline_report, r)


def test_run_cli_run_dir_and_resume(tmp_path):
    """The operator workflow: run.py --spec --run-dir, kill, --resume."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    spec = _spec(name="fcli", backend="subprocess",
                 backend_params=_sub_params())
    spec_path = tmp_path / "spec.json"
    spec_path.write_text(spec.to_json())
    run_dir = str(tmp_path / "run")
    env = dict(os.environ,
               PYTHONPATH=os.path.join(repo, "src"))

    def cli(*extra):
        out = subprocess.run(
            [sys.executable, "-m", "benchmarks.run", "--spec",
             str(spec_path), "--run-dir", run_dir, *extra],
            cwd=repo, env=env, stdout=subprocess.PIPE,
            stderr=subprocess.STDOUT, timeout=600)
        text = out.stdout.decode()
        assert out.returncode == 0, text
        return text

    first = cli()
    assert glob.glob(os.path.join(run_dir, "job_*.pkl"))
    second = cli("--resume")
    assert "shards_run=0" in second and "resumed_trees=2" in second
    rows = lambda t: [l for l in t.splitlines()
                      if l.startswith("fcli_w")
                      and not l.startswith("fcli_walls")]
    assert rows(first) == rows(second) and rows(first)
    # --resume without --run-dir is a usage error, not a silent fresh run
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--spec", str(spec_path),
         "--resume"], cwd=repo, env=env, capture_output=True, timeout=120)
    assert out.returncode == 2
    assert b"--run-dir" in out.stderr


# ---------------------------------------------------------------------------
# Perf-gate baseline validation (exit 2, not phantom regressions)
# ---------------------------------------------------------------------------

def test_check_rejects_invalid_baselines(tmp_path):
    from benchmarks.run import EXIT_MISCONFIGURED, _load_baselines
    suites = [("x", "mod_x"), ("y", "mod_y"), ("z", "mod_z")]
    assert EXIT_MISCONFIGURED == 2
    # x: torn JSON; y: checksum mismatch; z: pre-checksum legacy
    (tmp_path / "BENCH_x.json").write_text('{"suite": "x", "wall')
    good = atomic_write_json(str(tmp_path / "BENCH_y.json"),
                             {"suite": "y", "wall_time_s": 1.0, "rows": []})
    bad = dict(good, wall_time_s=2.0)
    (tmp_path / "BENCH_y.json").write_text(json.dumps(bad))
    (tmp_path / "BENCH_z.json").write_text(
        json.dumps({"suite": "z", "wall_time_s": 1.0, "rows": []}))
    baselines, invalid = _load_baselines(suites, str(tmp_path))
    assert baselines == {} and len(invalid) == 3
    assert any("unparseable" in msg for msg in invalid)
    assert any("checksum mismatch" in msg for msg in invalid)
    assert any("no 'checksum'" in msg for msg in invalid)
    # a valid baseline loads
    atomic_write_json(str(tmp_path / "BENCH_x.json"),
                      {"suite": "x", "wall_time_s": 1.0, "rows": []})
    baselines, invalid = _load_baselines(suites[:1], str(tmp_path))
    assert set(baselines) == {"x"} and not invalid


def test_committed_baselines_are_checksum_valid():
    """Every committed BENCH_<suite>.json must pass the validation the
    gate now performs — a regression here means someone hand-edited one."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    paths = sorted(glob.glob(os.path.join(repo, "BENCH_*.json")))
    assert paths, "no committed baselines found"
    for path in paths:
        load_checked_json(path)


# ---------------------------------------------------------------------------
# Checkpoint crash-safety
# ---------------------------------------------------------------------------

def _tiny_params():
    return {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
            "b": np.ones(4, np.float32)}


def test_checkpoint_interrupted_save_keeps_latest(tmp_path, monkeypatch):
    """A save that dies mid-tensor must not clobber the previous
    checkpoint: ``latest_step`` still points at it and it restores."""
    from repro.checkpoint.store import CheckpointStore
    store = CheckpointStore.create(str(tmp_path))
    params = _tiny_params()
    store.save(1, params, data_state={"batch": 10})
    assert store.latest_step() == 1

    real = CheckpointStore._write_array     # plain function via descriptor
    calls = {"n": 0}

    def dying(path, arr):
        calls["n"] += 1
        if calls["n"] == 2:
            raise OSError("disk gone (injected)")
        real(path, arr)

    monkeypatch.setattr(CheckpointStore, "_write_array",
                        staticmethod(dying))
    p2 = {k: v + 1 for k, v in params.items()}
    with pytest.raises(OSError, match="injected"):
        store.save(2, p2, data_state={"batch": 20})
    # the commit point never flipped
    assert store.latest_step() == 1
    restored, meta = store.restore(params)
    assert meta["data_state"] == {"batch": 10}
    for k in params:
        np.testing.assert_array_equal(np.asarray(restored[k]), params[k])
    # recovery: a later complete save commits normally
    monkeypatch.setattr(CheckpointStore, "_write_array",
                        staticmethod(real))
    store.save(2, p2, data_state={"batch": 20})
    assert store.latest_step() == 2
    restored, meta = store.restore(params)
    np.testing.assert_array_equal(np.asarray(restored["w"]), p2["w"])


def test_checkpoint_tensor_files_atomic(tmp_path):
    """Tensor and opt-state files go through the atomic writer: the
    checkpoint dir holds only final artifacts, every one loadable."""
    from repro.checkpoint.store import CheckpointStore
    store = CheckpointStore.create(str(tmp_path))
    params = _tiny_params()
    store.save(3, params, opt_state=[np.zeros(4, np.float32)])
    ckdir = tmp_path / "step_00000003"
    files = sorted(os.listdir(ckdir))
    assert len(files) == 3 and not any(f.endswith(".tmp") for f in files)
    for f in files:
        if f.endswith(".npy"):
            np.load(ckdir / f)
    z = np.load(ckdir / "opt_state.npz")
    np.testing.assert_array_equal(z["s0"], np.zeros(4, np.float32))
    opt = store.restore_opt_state([np.empty(4, np.float32)])
    np.testing.assert_array_equal(np.asarray(opt[0]),
                                  np.zeros(4, np.float32))
