"""Tests for the HLO roofline analyzer: while-trip correction, dot FLOPs,
collective attribution, and the slice-accounting rules."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.utils.hlo import analyze_hlo


def _compile_text(fn, *specs):
    return jax.jit(fn).lower(*specs).compile().as_text()


def test_while_trip_correction_exact():
    """scan(n) must count n x the body flops (XLA counts it once)."""
    def body(x, w):
        return jnp.tanh(x @ w), None

    def scanned(x, Ws):
        y, _ = jax.lax.scan(body, x, Ws)
        return y

    x = jax.ShapeDtypeStruct((4, 64), jnp.float32)
    Ws = jax.ShapeDtypeStruct((6, 64, 64), jnp.float32)
    hlo = _compile_text(scanned, x, Ws)
    costs = analyze_hlo(hlo, (1,), ("data",))
    assert costs.while_trips == [6]
    expect = 6 * 2 * 4 * 64 * 64
    assert costs.flops == pytest.approx(expect, rel=0.01)


def test_dot_flops_from_shapes():
    def fn(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((32, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 16), jnp.float32)
    costs = analyze_hlo(_compile_text(fn, a, b), (1,), ("data",))
    assert costs.flops == pytest.approx(2 * 32 * 128 * 16, rel=0.01)


def test_dynamic_update_slice_counts_update_only():
    """KV-cache style DUS must count the update region, not the cache."""
    def fn(cache, new):
        return jax.lax.dynamic_update_slice(cache, new, (0, 5, 0))

    cache = jax.ShapeDtypeStruct((4, 1024, 64), jnp.float32)
    new = jax.ShapeDtypeStruct((4, 1, 64), jnp.float32)
    hlo = jax.jit(fn, donate_argnums=(0,)).lower(cache, new).compile() \
        .as_text()
    costs = analyze_hlo(hlo, (1,), ("data",))
    cache_bytes = 4 * 1024 * 64 * 4
    # The DUS itself counts ~2x the update region; allow for an XLA copy of
    # the buffer but assert we stay far below naive operand counting
    # (operand+result = 2x full cache *per DUS*).
    assert costs.bytes < 1.2 * cache_bytes


def test_bf16_correction_halves_f32_share():
    from repro.utils.hlo import HloCosts
    c = HloCosts(flops=0, bytes=100.0, collective_bytes_by_axis={"m": 10.0},
                 collective_count=1, raw_entry_flops=0, while_trips=[],
                 bytes_f32=60.0, collective_bytes_f32=10.0)
    cc = c.bf16_corrected()
    assert cc.bytes == pytest.approx(70.0)
    assert cc.collective_bytes == pytest.approx(5.0)


def test_roofline_terms_and_bottleneck():
    from repro.configs.base import ShapeConfig
    from repro.configs import get_config
    from repro.utils.hlo import HloCosts
    from repro.utils.roofline import terms_from_hlo

    cfg = get_config("glm4-9b")
    shape = ShapeConfig("train_4k", 4096, 256, "train")
    costs = HloCosts(flops=1e15, bytes=1e13, collective_bytes_by_axis={
        "data": 1e11, "model": 4e11}, collective_count=10,
        raw_entry_flops=0, while_trips=[40])
    t = terms_from_hlo("glm4-9b", shape, "single", 256, costs, cfg)
    assert t.compute_s == pytest.approx(1e15 / 197e12)
    assert t.memory_s == pytest.approx(1e13 / 819e9)
    assert t.collective_s == pytest.approx(5e11 / 50e9)
    assert t.bottleneck == "memory"
    assert 0 < t.useful_ratio < 1
    assert t.roofline_frac == pytest.approx(t.compute_s / t.memory_s)
