"""Tests for the framework substrate: data pipeline, optimizer, gradient
compression, checkpoint store, elastic policy, robust sharding."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.data.pipeline import DataConfig, global_batch_at, shard_batch_at
from repro.launch.elastic import (ElasticPolicy, RunSupervisor, dead_workers,
                                  remesh, reshard_plan, stragglers)
from repro.optim import adamw
from repro.optim.compression import compressed_cross_pod_mean


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(step=st.integers(0, 10_000), shards=st.sampled_from([1, 2, 4, 8]))
def test_pipeline_determinism_and_sharding(step, shards):
    """Any worker can recompute any batch; shards tile the global batch."""
    cfg = DataConfig(vocab_size=256, seq_len=32, global_batch=8)
    full = global_batch_at(cfg, step)
    again = global_batch_at(cfg, step)
    np.testing.assert_array_equal(full["tokens"], again["tokens"])
    parts = [shard_batch_at(cfg, step, s, shards) for s in range(shards)]
    np.testing.assert_array_equal(
        np.concatenate([p["tokens"] for p in parts]), full["tokens"])


def test_pipeline_is_learnable():
    """Labels are the shifted tokens (next-token prediction consistency)."""
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=4)
    b = global_batch_at(cfg, 7)
    assert b["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# ---------------------------------------------------------------------------
# optimizer + compression
# ---------------------------------------------------------------------------

def test_adamw_reduces_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0, 5.0])}
    state = adamw.init(params)
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw.update(grads, state, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_grad_clip_bounds_update():
    params = {"w": jnp.zeros(4)}
    state = adamw.init(params)
    cfg = adamw.AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    _, _, m = adamw.update({"w": jnp.full(4, 1e6)}, state, params, cfg)
    assert float(m["grad_norm"]) > 1e5  # measured pre-clip


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100), pods=st.sampled_from([2, 4]))
def test_compressed_mean_with_error_feedback(seed, pods):
    """int8 cross-pod mean: exact common-scale arithmetic + EF residual
    drives the accumulated error to ~0 over repeated steps."""
    rng = np.random.default_rng(seed)
    per_pod = [{"g": jnp.asarray(rng.normal(size=300), jnp.float32)}
               for _ in range(pods)]
    true_mean = np.mean([np.asarray(p["g"]) for p in per_pod], axis=0)

    # emulate the collectives across the pod list
    def psum(trees):
        return jax.tree.map(lambda *xs: sum(xs), *trees)

    def pmax(trees):
        return jax.tree.map(lambda *xs: jnp.maximum(*xs) if len(xs) == 2
                            else jnp.max(jnp.stack(xs), 0), *trees)

    residuals = [{"g": jnp.zeros(300)} for _ in range(pods)]
    # one step: quantize on common scale, sum, dequantize
    outs = []
    # common scale across pods
    import repro.optim.compression as comp
    scales = pmax([jax.tree.map(
        lambda g, r: comp._quantize_int8((g + r).reshape(-1))[1],
        per_pod[i], residuals[i]) for i in range(pods)])
    means, new_res = [], []
    for i in range(pods):
        m, r = compressed_cross_pod_mean(
            per_pod[i], residuals[i],
            psum_fn=lambda t, i=i: psum([t] * 1),  # placeholder
            pmax_fn=lambda t: scales, n_pods=1)
        means.append(m)
        new_res.append(r)
    # sum of per-pod dequantized == psum result; mean error bounded by scale
    approx = np.mean([np.asarray(m["g"]) for m in means], axis=0)
    err = np.abs(approx - true_mean).max()
    max_scale = float(np.max(np.asarray(scales["g"])))
    assert err <= 2 * max_scale  # within 2 quantization steps
    # error feedback captured the residual exactly
    for i in range(pods):
        recon = np.asarray(means[i]["g"]) + np.asarray(new_res[i]["g"])
        np.testing.assert_allclose(recon, np.asarray(per_pod[i]["g"]),
                                   atol=1e-5)


# ---------------------------------------------------------------------------
# checkpoint store + elastic restore
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_cursor(tmp_path):
    from repro.checkpoint import CheckpointStore
    store = CheckpointStore.create(str(tmp_path))
    params = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
              "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    store.save(5, params, opt_state=None, data_state={"step": 42})
    like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                        params)
    restored, meta = store.restore(like)
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(params["a"]))
    assert meta["data_state"]["step"] == 42
    assert store.latest_step() == 5


def test_checkpoint_store_uses_robust_tuning(tmp_path):
    """The manifest LSM tree must carry an ENDURE tuning (integration)."""
    from repro.checkpoint import CheckpointStore
    store = CheckpointStore.create(str(tmp_path), ckpt_interval=50,
                                   restore_prob=0.5, rho=1.0)
    cfg = store.manifest.cfg
    assert cfg.T >= 2
    assert 0 <= cfg.mfilt_bits_per_entry <= 16.0
    # engine actually works as the manifest
    store.save(1, {"w": jnp.ones(3)})
    assert store.latest_step() == 1


def test_elastic_policy_decisions():
    pol = ElasticPolicy(heartbeat_timeout_s=10, straggler_zscore=3.0)
    now = 1000.0
    hb = {0: {"t": 999.0}, 1: {"t": 998.0}, 2: {"t": 900.0}}  # 2 is dead
    assert dead_workers(hb, now, 4, pol) == [2, 3]  # 3 never heartbeat
    times = {0: [1.0] * 8, 1: [1.01] * 8, 2: [1.02] * 8, 3: [9.0] * 8}
    assert stragglers(times, pol) == [3]
    assert remesh(24, 8, pol) == (3, 8)
    assert remesh(7, 8, pol) is None


def test_reshard_plan_covers_batch():
    plan = reshard_plan(old_shards=8, new_shards=6, global_batch=48)
    covered = sorted({o for olds in plan.values() for o in olds})
    assert covered == list(range(8))


def test_supervisor_restart_decision():
    sup = RunSupervisor(num_workers=8, model_parallel=2,
                        policy=ElasticPolicy(heartbeat_timeout_s=5))
    now = time.time()
    hb = {w: {"t": now} for w in range(7)}  # worker 7 silent
    decision = sup.decide(hb, now + 2)
    assert decision["action"] == "restart_from_checkpoint"
    assert decision["new_mesh"] == (3, 2)  # 7 alive -> 3x2 mesh


# ---------------------------------------------------------------------------
# robust sharding (beyond-paper)
# ---------------------------------------------------------------------------

def test_robust_layout_prefers_flat_candidates():
    from repro.core.robust_sharding import (LayoutCandidate, nominal_layout,
                                            robust_layout)
    spiky = LayoutCandidate("spiky", np.array([0.5, 1.0, 1.0, 50.0]))
    flat = LayoutCandidate("flat", np.array([1.3, 1.3, 1.3, 2.0]))
    mix = np.array([0.9, 0.05, 0.04, 0.01])
    assert nominal_layout([spiky, flat], mix).name == "spiky"
    assert robust_layout([spiky, flat], mix, rho=1.0).name == "flat"


def test_adversarial_mix_targets_weakness():
    from repro.core.robust_sharding import LayoutCandidate, adversarial_mix
    c = LayoutCandidate("x", np.array([1.0, 1.0, 1.0, 30.0]))
    mix = np.array([0.7, 0.1, 0.1, 0.1])
    adv = adversarial_mix(c, mix, rho=0.5)
    assert adv[3] > mix[3]  # shifts mass to the weak class
    assert abs(adv.sum() - 1.0) < 1e-5
