"""Frozen pre-refactor (PR 1) LSM engine + session runner: the golden
reference for the columnar-engine refactor.

This is a verbatim snapshot of ``src/repro/lsm/engine.py`` and the
``populate`` / ``run_session`` pair from ``src/repro/lsm/workload_runner.py``
as of commit 6548ac7, with imports adjusted to be self-contained.  The
equivalence tests in ``test_engine_golden.py`` assert that the rewritten
store/planner/executor engine reproduces this implementation's ``IOStats``
*exactly* on fixed-seed scenarios.  Do not "improve" this file — its only
job is to stay identical to the engine it snapshots.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.lsm.bloom import BloomFilter, monkey_bits_per_key

TOMBSTONE = object()


@dataclasses.dataclass
class IOStats:
    random_reads: int = 0        # random page reads (point lookups, seeks)
    seq_reads: int = 0           # sequential page reads (range scans)
    comp_pages_read: int = 0     # compaction input pages (sequential)
    comp_pages_written: int = 0  # compaction/flush output pages (sequential)
    bloom_probes: int = 0
    bloom_false_positives: int = 0
    queries: dict = dataclasses.field(
        default_factory=lambda: {"z0": 0, "z1": 0, "q": 0, "w": 0})

    def snapshot(self) -> "IOStats":
        return dataclasses.replace(self, queries=dict(self.queries))

    def minus(self, other: "IOStats") -> "IOStats":
        return IOStats(
            random_reads=self.random_reads - other.random_reads,
            seq_reads=self.seq_reads - other.seq_reads,
            comp_pages_read=self.comp_pages_read - other.comp_pages_read,
            comp_pages_written=self.comp_pages_written - other.comp_pages_written,
            bloom_probes=self.bloom_probes - other.bloom_probes,
            bloom_false_positives=self.bloom_false_positives
            - other.bloom_false_positives,
            queries={k: self.queries[k] - other.queries[k]
                     for k in self.queries},
        )

    def io_per_query(self, f_a: float = 1.0, f_seq: float = 1.0) -> dict:
        """Measured average logical I/O per query class, write-amortized the
        way the paper does (compaction I/O redistributed over writes)."""
        n = self.queries
        reads = max(n["z0"] + n["z1"] + n["q"], 1)
        out = {}
        out["read_io"] = (self.random_reads + f_seq * self.seq_reads) / reads
        writes = max(n["w"], 1)
        out["write_io"] = (f_seq * (self.comp_pages_read
                                    + f_a * self.comp_pages_written)) / writes
        return out


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    T: int = 4
    K: Tuple[int, ...] = ()            # per-level caps; empty -> leveling
    buf_entries: int = 1024            # memtable capacity (entries)
    entry_bytes: int = 64
    page_bytes: int = 4096
    mfilt_bits_per_entry: float = 10.0  # Monkey budget, bits per *total* entry
    expected_entries: int = 200_000     # N used for Monkey allocation + L

    @property
    def entries_per_page(self) -> int:
        return max(1, self.page_bytes // self.entry_bytes)

    def k_at(self, level: int) -> int:
        """1-indexed level -> K_i, clamped to [1, T-1]."""
        if level - 1 < len(self.K):
            k = self.K[level - 1]
        elif len(self.K) > 0:
            k = self.K[-1]
        else:
            k = 1
        return int(max(1, min(k, self.T - 1)))

    @property
    def est_levels(self) -> int:
        ratio = self.expected_entries / self.buf_entries
        return max(1, int(math.ceil(math.log(ratio + 1, self.T))))


class SortedRun:
    """An immutable sorted run with fence pointers and a Bloom filter."""

    __slots__ = ("keys", "values", "bloom", "entries_per_page", "flushes")

    def __init__(self, keys: np.ndarray, values: np.ndarray,
                 bits_per_key: float, entries_per_page: int,
                 flushes: int = 1):
        self.keys = np.asarray(keys, np.uint64)
        self.values = values
        self.bloom = BloomFilter(self.keys, bits_per_key)
        self.entries_per_page = entries_per_page
        self.flushes = flushes  # how many upstream flushes merged into this run

    def __len__(self) -> int:
        return len(self.keys)

    @property
    def num_pages(self) -> int:
        return (len(self.keys) + self.entries_per_page - 1) \
            // self.entries_per_page

    def get(self, key: int, stats: IOStats) -> Tuple[bool, Optional[Any]]:
        """(made_io_and_found, value). Bloom-negative runs cost nothing."""
        stats.bloom_probes += 1
        if not self.bloom.might_contain(key):
            return False, None
        stats.random_reads += 1  # fence pointer -> exactly one page read
        i = int(np.searchsorted(self.keys, np.uint64(key)))
        if i < len(self.keys) and int(self.keys[i]) == key:
            return True, self.values[i]
        stats.bloom_false_positives += 1
        return False, None

    def scan(self, lo: int, hi: int, stats: IOStats) -> List[Tuple[int, Any]]:
        """Inclusive-lo, exclusive-hi scan; counts 1 seek + sequential pages."""
        i = int(np.searchsorted(self.keys, np.uint64(lo), side="left"))
        j = int(np.searchsorted(self.keys, np.uint64(hi), side="left"))
        if i >= j:
            return []
        first_page = i // self.entries_per_page
        last_page = (j - 1) // self.entries_per_page
        stats.random_reads += 1                       # the seek
        stats.seq_reads += last_page - first_page     # subsequent pages
        return [(int(self.keys[t]), self.values[t]) for t in range(i, j)]


class Level:
    __slots__ = ("runs",)

    def __init__(self):
        self.runs: List[SortedRun] = []

    @property
    def entries(self) -> int:
        return sum(len(r) for r in self.runs)


def _merge_runs(runs: Sequence[SortedRun], bits_per_key: float,
                entries_per_page: int, stats: IOStats,
                drop_tombstones: bool = False) -> SortedRun:
    """Sort-merge runs (newest first in ``runs``), newest version wins.

    Tombstones are only *dropped* when merging into the deepest populated
    level (otherwise older versions in deeper levels would resurface).
    Counts compaction I/O."""
    for r in runs:
        stats.comp_pages_read += r.num_pages
    all_keys = np.concatenate([r.keys for r in runs])
    all_vals = np.concatenate(
        [np.asarray(r.values, dtype=object) for r in runs])
    # newest-wins: stable sort by key with recency priority = position in list
    recency = np.concatenate(
        [np.full(len(r), i) for i, r in enumerate(runs)])  # 0 = newest
    order = np.lexsort((recency, all_keys))
    keys_sorted = all_keys[order]
    vals_sorted = all_vals[order]
    keep = np.ones(len(keys_sorted), bool)
    keep[1:] = keys_sorted[1:] != keys_sorted[:-1]  # first (newest) wins
    keys_u = keys_sorted[keep]
    vals_u = vals_sorted[keep]
    if drop_tombstones:
        live = np.array([v is not TOMBSTONE for v in vals_u], bool)
        keys_u, vals_u = keys_u[live], vals_u[live]
    out = SortedRun(keys_u, vals_u, bits_per_key, entries_per_page,
                    flushes=sum(r.flushes for r in runs))
    stats.comp_pages_written += out.num_pages
    return out


class LSMTree:
    """The engine. Keys: ints (uint64 range); values: arbitrary objects."""

    def __init__(self, config: EngineConfig):
        self.cfg = config
        self.buffer: dict = {}
        self.levels: List[Level] = [Level() for _ in range(64)]
        self.stats = IOStats()

    # -- construction from a tuning -------------------------------------

    @classmethod
    def from_phi(cls, phi, sys, expected_entries: int,
                 buf_entries: Optional[int] = None,
                 entry_bytes: int = 64, page_bytes: int = 4096) -> "LSMTree":
        """Deploy a tuner-recommended Phi at reduced scale.

        The *shape* of the tuning (T, K profile, filter bits/entry) carries
        over; N/buffer are scaled to CPU-testable sizes with the memory split
        preserved as bits-per-entry."""
        import numpy as _np
        T = int(float(phi.T))
        K = tuple(int(k) for k in _np.asarray(phi.K))
        m_total_bpe = sys.bits_per_entry
        filt_bpe = float(phi.mfilt_bits) / sys.N
        assert filt_bpe <= 1024, (
            f"filter bits/entry = {filt_bpe:.3g}: `sys` must be the SAME "
            "LSMSystem the tuning was produced under (mfilt_bits is "
            "normalized by sys.N)")
        buf_bpe = m_total_bpe - filt_bpe
        if buf_entries is None:
            # preserve buffer share: buf_bits = buf_bpe * N_small
            buf_bits = buf_bpe * expected_entries
            buf_entries = max(64, int(buf_bits / (entry_bytes * 8)))
        cfg = EngineConfig(T=T, K=K, buf_entries=buf_entries,
                           entry_bytes=entry_bytes, page_bytes=page_bytes,
                           mfilt_bits_per_entry=filt_bpe,
                           expected_entries=expected_entries)
        return cls(cfg)

    # -- bits allocation --------------------------------------------------

    def _bits_per_key(self, level: int) -> float:
        return monkey_bits_per_key(
            level, self.cfg.est_levels, float(self.cfg.T),
            self.cfg.mfilt_bits_per_entry * self.cfg.expected_entries,
            float(self.cfg.expected_entries))

    def _level_capacity(self, level: int) -> int:
        return (self.cfg.T - 1) * self.cfg.T ** (level - 1) \
            * self.cfg.buf_entries

    # -- write path --------------------------------------------------------

    def put(self, key: int, value: Any) -> None:
        self.stats.queries["w"] += 1
        self.buffer[key] = value
        if len(self.buffer) >= self.cfg.buf_entries:
            self.flush()

    def delete(self, key: int) -> None:
        self.put(key, TOMBSTONE)

    def put_batch(self, keys, values: Sequence[Any]) -> None:
        """Bulk insert in buffer-sized chunks; equivalent to sequential
        :meth:`put` calls without the per-key Python overhead: same flush
        boundaries (chunks are cut to the buffer's remaining room) and same
        newest-wins semantics (insertion order is preserved, so later
        duplicates overwrite earlier ones; :meth:`flush` sorts each run)."""
        keys = np.asarray(keys, np.uint64)
        i, n = 0, len(keys)
        if len(values) != n:
            raise ValueError(f"put_batch: {n} keys but {len(values)} values")
        while i < n:
            room = max(1, self.cfg.buf_entries - len(self.buffer))
            chunk = keys[i:i + room]
            self.buffer.update(zip(chunk.tolist(), values[i:i + room]))
            self.stats.queries["w"] += len(chunk)
            i += len(chunk)
            if len(self.buffer) >= self.cfg.buf_entries:
                self.flush()

    def flush(self) -> None:
        if not self.buffer:
            return
        keys = np.fromiter(self.buffer.keys(), np.uint64, len(self.buffer))
        order = np.argsort(keys)
        keys = keys[order]
        vals = np.asarray(list(self.buffer.values()), dtype=object)[order]
        run = SortedRun(keys, vals, self._bits_per_key(1),
                        self.cfg.entries_per_page)
        self.stats.comp_pages_written += run.num_pages  # sequential flush
        self.buffer.clear()
        self._push_run(1, run)

    def _push_run(self, level: int, run: SortedRun) -> None:
        lv = self.levels[level - 1]
        cap = self._level_capacity(level)
        K = self.cfg.k_at(level)
        if lv.entries + len(run) > cap and lv.entries > 0:
            # Full-level compaction: merge everything, move to level + 1.
            # Tombstones may be dropped iff nothing lives deeper.
            deepest = all(not l.runs for l in self.levels[level:])
            merged = _merge_runs([run] + lv.runs, self._bits_per_key(level + 1),
                                 self.cfg.entries_per_page, self.stats,
                                 drop_tombstones=deepest)
            lv.runs = []
            self._push_run(level + 1, merged)
            return
        # Eager-merge semantics: fill the active (newest) run up to the
        # per-run flush capacity ceil((T-1)/K) flushes, else open a new run.
        flush_cap = max(1, math.ceil((self.cfg.T - 1) / K))
        if lv.runs and lv.runs[0].flushes + run.flushes <= flush_cap:
            merged = _merge_runs([run, lv.runs[0]], self._bits_per_key(level),
                                 self.cfg.entries_per_page, self.stats)
            lv.runs[0] = merged
        else:
            lv.runs.insert(0, run)
        # Respect the K_i cap if logical moves overfilled the level.
        while len(lv.runs) > K:
            merged = _merge_runs(lv.runs[:2], self._bits_per_key(level),
                                 self.cfg.entries_per_page, self.stats)
            lv.runs = [merged] + lv.runs[2:]

    # -- read path ----------------------------------------------------------

    def get(self, key: int) -> Optional[Any]:
        found, val, _ = self._get_impl(key)
        return val if found else None

    def _get_impl(self, key: int):
        if key in self.buffer:
            v = self.buffer[key]
            return (v is not TOMBSTONE), (None if v is TOMBSTONE else v), True
        for lv in self.levels:
            for run in lv.runs:  # newest -> oldest
                found, val = run.get(key, self.stats)
                if found:
                    if val is TOMBSTONE:
                        return False, None, False
                    return True, val, False
        return False, None, False

    def point_query(self, key: int) -> Optional[Any]:
        """A classified point query (updates z0/z1 accounting)."""
        found, val, _ = self._get_impl(key)
        self.stats.queries["z1" if found else "z0"] += 1
        return val

    def point_query_batch(self, keys) -> List[Optional[Any]]:
        """Classified point queries for a key batch, one vectorized Bloom
        probe (``might_contain_batch``) + one ``searchsorted`` per run instead
        of per-key Python loops.  Equivalent to ``[point_query(k) for k in
        keys]``: same run visit order (newest -> oldest), same I/O and
        bloom-probe accounting, same z0/z1 classification."""
        keys_arr = np.asarray(keys, np.uint64)
        n = len(keys_arr)
        results: List[Optional[Any]] = [None] * n
        resolved = np.zeros(n, bool)
        found = np.zeros(n, bool)
        for idx in range(n):
            kk = int(keys_arr[idx])
            if kk in self.buffer:
                v = self.buffer[kk]
                resolved[idx] = True
                if v is not TOMBSTONE:
                    found[idx] = True
                    results[idx] = v
        for lv in self.levels:
            for run in lv.runs:  # newest -> oldest, as in _get_impl
                active = np.nonzero(~resolved)[0]
                if active.size == 0:
                    break
                sub = keys_arr[active]
                self.stats.bloom_probes += int(active.size)
                pos = run.bloom.might_contain_batch(sub)
                if not pos.any():
                    continue
                probe_idx = active[pos]
                pk = sub[pos]
                self.stats.random_reads += int(pos.sum())
                loc = np.searchsorted(run.keys, pk)
                inb = loc < len(run.keys)
                eq = np.zeros(len(pk), bool)
                eq[inb] = run.keys[loc[inb]] == pk[inb]
                self.stats.bloom_false_positives += int(len(pk) - eq.sum())
                for gi, li in zip(probe_idx[eq], loc[eq]):
                    v = run.values[li]
                    resolved[gi] = True
                    if v is not TOMBSTONE:
                        found[gi] = True
                        results[gi] = v
            if not (~resolved).any():
                break
        nz1 = int(found.sum())
        self.stats.queries["z1"] += nz1
        self.stats.queries["z0"] += n - nz1
        return results

    def range_query(self, lo: int, hi: int) -> List[Tuple[int, Any]]:
        self.stats.queries["q"] += 1
        results: dict = {}
        sources: List[List[Tuple[int, Any]]] = []
        for lv in self.levels:
            for run in lv.runs:
                sources.append(run.scan(lo, hi, self.stats))
        for src in reversed(sources):  # oldest first; newer overwrite
            for k, v in src:
                results[k] = v
        for k in list(self.buffer.keys()):
            if lo <= k < hi:
                results[k] = self.buffer[k]
        return sorted((k, v) for k, v in results.items()
                      if v is not TOMBSTONE)

    # -- introspection --------------------------------------------------------

    @property
    def num_entries(self) -> int:
        return len(self.buffer) + sum(lv.entries for lv in self.levels)

    def shape(self) -> List[Tuple[int, List[int]]]:
        """[(level, [run sizes])] for non-empty levels."""
        return [(i + 1, [len(r) for r in lv.runs])
                for i, lv in enumerate(self.levels) if lv.runs]

    def filter_bits_in_use(self) -> int:
        return sum(r.bloom.bits_used for lv in self.levels for r in lv.runs)


# ---------------------------------------------------------------------------
# Frozen session runner (pre-refactor workload_runner.py)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SessionResult:
    workload: np.ndarray
    queries: int
    avg_io_per_query: float
    io: IOStats

    @property
    def throughput(self) -> float:
        return 1.0 / max(self.avg_io_per_query, 1e-9)


def populate(tree: LSMTree, n: int, seed: int = 7,
             key_space: int = 2 ** 48) -> np.ndarray:
    rng = np.random.default_rng(seed)
    keys = rng.choice(key_space, size=n, replace=False).astype(np.uint64)
    values = (keys % np.uint64(997)).astype(np.int64).tolist()
    tree.put_batch(keys, values)
    tree.flush()
    tree.stats = IOStats()
    return keys


def run_session(tree: LSMTree, existing_keys: np.ndarray, w: np.ndarray,
                n_queries: int = 2000, seed: int = 0,
                key_space: int = 2 ** 48,
                range_fraction: float = 2e-5,
                f_a: float = 1.0, f_seq: float = 1.0,
                zipf_a=None) -> SessionResult:
    rng = np.random.default_rng(seed)
    w = np.asarray(w, np.float64)
    w = w / w.sum()
    kinds = rng.choice(4, size=n_queries, p=w)
    before = tree.stats.snapshot()
    span = max(1, int(range_fraction * key_space))
    existing = np.asarray(existing_keys, np.uint64)
    fresh = iter(rng.choice(key_space, size=max((kinds == 3).sum(), 1) + 8,
                            replace=False).astype(np.uint64))
    pending_reads: list = []
    for kind in kinds:
        if kind == 0:        # empty point read: perturb to near-certain miss
            k = int(rng.integers(0, key_space)) | (1 << 60)
            pending_reads.append(k)
        elif kind == 1:      # non-empty point read
            if zipf_a is not None:
                idx = min(len(existing) - 1, rng.zipf(zipf_a) - 1)
            else:
                idx = int(rng.integers(0, len(existing)))
            pending_reads.append(int(existing[idx]))
        elif kind == 2:      # short range query
            if pending_reads:
                tree.point_query_batch(pending_reads)
                pending_reads = []
            lo = int(rng.integers(0, key_space - span))
            tree.range_query(lo, lo + span)
        else:                # write
            if pending_reads:
                tree.point_query_batch(pending_reads)
                pending_reads = []
            tree.put(int(next(fresh)), 1)
    if pending_reads:
        tree.point_query_batch(pending_reads)
    delta = tree.stats.minus(before)
    reads_io = delta.random_reads + f_seq * delta.seq_reads
    write_io = f_seq * (delta.comp_pages_read + f_a * delta.comp_pages_written)
    total_io = reads_io + write_io
    avg = total_io / max(n_queries, 1)
    return SessionResult(workload=w, queries=n_queries, avg_io_per_query=avg,
                         io=delta)
