"""Tests for the scenario subsystem (repro.scenarios) and its satellites.

Covers the registry and spec validation, schedule lowering, the
statistical shape of each generator (Zipf tail, burst amplitude,
tombstone fraction), rng-sequence preservation for default parameters
(the bit-identity contract with classic sessions), the adversary's
inner-max against a hand-computed symmetric golden, the Page-Hinkley
change-point trigger, overlap-based partial-compaction slice selection,
the uint32-limb splitmix64 bit-identity, and the five scenario kinds end
to end on all three execution backends with inline/sharded/subprocess
bit-identity.

Solver sizes match test_online_drift's SMALL so the jit cache is shared.
"""

import numpy as np
import pytest

from repro.core import LSMSystem, tune_nominal
from repro.lsm import EngineConfig, LSMTree, execute_session, \
    materialize_session, populate
from repro.lsm.planner import PartialCompactionPlanner
from repro.online import DriftPolicy, OnlineSession, PageHinkleyDetector
from repro.scenarios import SCENARIO_KINDS, SCENARIOS, get_scenario, \
    validate_scenario_params

SMALL = dict(n_starts=8, steps=60, seed=3)
SYS_PAIRS = (("N", 8000.0), ("entry_bits", 512.0), ("bits_per_entry", 6.0),
             ("min_buf_bits", 512.0 * 64), ("max_T", 20.0))
SYS = LSMSystem().replace(**dict(SYS_PAIRS))


def _api():
    from repro import api
    return api


def _drift(kind, **kw):
    api = _api()
    kw.setdefault("segments", 4)
    return api.DriftSpec(kind=kind, **kw)


# ---------------------------------------------------------------------------
# Registry + spec validation
# ---------------------------------------------------------------------------

def test_registry_kinds_and_knob_validation():
    assert SCENARIO_KINDS == {"zipf_migrate", "burst_storm",
                              "tombstone_churn", "scan_heavy", "adversary"}
    for kind, cls in SCENARIOS.items():
        sc = get_scenario(_drift(kind))
        assert isinstance(sc, cls) and sc.kind == kind
        assert sc.is_adversary == (kind == "adversary")
    # classic kinds have no scenario
    assert get_scenario(_drift("flip", target=(0.3, 0.3, 0.3, 0.1))) is None
    with pytest.raises(ValueError):
        _drift("mystery_kind", target=(0.3, 0.3, 0.3, 0.1))
    # unknown knob names are rejected at spec construction
    with pytest.raises(ValueError, match="zipf_migrate"):
        _drift("zipf_migrate", scenario_params=(("zip_a", 1.5),))
    with pytest.raises(ValueError):
        validate_scenario_params("burst_storm", (("volume", 2.0),))
    validate_scenario_params("burst_storm", (("amplitude", 2.0),))
    # value-range checks live in the constructors and fire at spec time
    with pytest.raises(ValueError, match=r"\[1, 1000\]"):
        _drift("burst_storm", scenario_params=(("amplitude", 2000.0),))
    with pytest.raises(ValueError, match="period"):
        _drift("burst_storm", scenario_params=(("period", 1),))
    with pytest.raises(ValueError, match=r"\[0, 1\]"):
        _drift("tombstone_churn", scenario_params=(("delete_fraction", 1.5),))
    with pytest.raises(ValueError, match="rho"):
        _drift("adversary", scenario_params=(("rho", -0.1),))
    # scenario_params on a classic kind is a spec error
    with pytest.raises(ValueError, match="scenario_params"):
        _drift("flip", target=(0.3, 0.3, 0.3, 0.1),
               scenario_params=(("zipf_a", 1.5),))
    with pytest.raises(ValueError, match="detector"):
        _drift("zipf_migrate", detector="cusum_but_wrong")


def test_scenario_spec_json_round_trip_and_memory_guard():
    api = _api()
    spec = api.ExperimentSpec(
        name="rt",
        workload=api.WorkloadSpec(indices=(4,), nominal=True,
                                  rho_source="from_history",
                                  history=((0.01, 0.01, 0.01, 0.97),
                                           (0.3, 0.3, 0.3, 0.1))),
        drift=api.DriftSpec(kind="burst_storm", segments=4,
                            scenario_params=(("amplitude", 4.0),
                                             ("period", 2)),
                            detector="page_hinkley", ph_lambda=0.1))
    assert api.ExperimentSpec.from_json(spec.to_json()) == spec
    # the adversary needs a drift defender arm; memory fleets have none
    with pytest.raises(ValueError, match="adversary"):
        api.ExperimentSpec(
            name="bad",
            workload=api.WorkloadSpec(indices=(4,), rhos=(1.0,)),
            drift=api.DriftSpec(kind="adversary", segments=2),
            memory=api.MemorySpec())


def test_schedules_lower_onto_drift_plan():
    """Every scenario kind produces a normalized (S, 4) schedule tilted
    the way its docstring promises."""
    from repro.api.compile import drift_schedule
    w0 = np.array([0.01, 0.01, 0.01, 0.97])
    for kind in SCENARIO_KINDS:
        sched = drift_schedule(w0, _drift(kind, segments=6))
        assert sched.shape == (6, 4)
        np.testing.assert_allclose(sched.sum(axis=1), 1.0, atol=1e-12)
        np.testing.assert_allclose(sched[0], w0 / w0.sum(), atol=1e-12)
    zipf = drift_schedule(w0, _drift("zipf_migrate", segments=6))
    assert zipf[-1][1] > 0.5                       # non-empty-read dominant
    tomb = drift_schedule(w0, _drift("tombstone_churn", segments=6))
    assert all(row[3] > 0.5 for row in tomb[1:])   # write dominant from s=1
    scan = drift_schedule(w0, _drift("scan_heavy", segments=6))
    assert scan[-1][2] > 0.5                       # range dominant
    burst = drift_schedule(
        w0, _drift("burst_storm", segments=6,
                   scenario_params=(("period", 3),)))
    quiet, stormy = burst[0], burst[2]             # period 3: s=2, 5 burst
    assert stormy[0] + stormy[1] > quiet[0] + quiet[1]


# ---------------------------------------------------------------------------
# Statistical shape of the generators
# ---------------------------------------------------------------------------

def _tree_and_keys(n=1500, buf=64):
    tree = LSMTree(EngineConfig(T=4, buf_entries=buf,
                                mfilt_bits_per_entry=6.0,
                                expected_entries=n))
    keys = populate(tree, n, seed=11, key_space=2 ** 20)
    return tree, keys


def test_zipf_tail_concentration():
    _, keys = _tree_and_keys()
    sc = get_scenario(_drift("zipf_migrate", n_queries=2000))
    kw = sc.session_kwargs(0, len(keys))
    assert kw["hot_offset"] == 0                   # no migration at s=0
    plan = materialize_session(keys, (0.02, 0.93, 0.02, 0.03),
                               n_queries=2000, seed=5, key_space=2 ** 20,
                               **kw)
    pts = plan.point_keys[plan.kinds[plan.kinds <= 1] == 1]
    _, counts = np.unique(pts, return_counts=True)
    top_share = counts.max() / len(pts)
    # Zipf(1.35): the rank-1 key draws ~30% of hits; uniform would be 1/n
    assert top_share > 0.15
    assert top_share > 100.0 / len(keys)


def test_hot_offset_is_pure_rotation():
    """hot_offset=0 is bit-identical to the classic draw; a nonzero offset
    maps every non-empty read through the same rotated rank->key table
    without touching any other draw (the rng-sequence contract)."""
    _, keys = _tree_and_keys()
    mix = (0.1, 0.6, 0.1, 0.2)
    base = materialize_session(keys, mix, n_queries=800, seed=7,
                               key_space=2 ** 20)
    same = materialize_session(keys, mix, n_queries=800, seed=7,
                               key_space=2 ** 20, hot_offset=0)
    for f in ("kinds", "point_keys", "range_los", "range_his", "write_keys"):
        assert np.array_equal(getattr(base, f), getattr(same, f)), f
    off = 123
    shifted = materialize_session(keys, mix, n_queries=800, seed=7,
                                  key_space=2 ** 20, hot_offset=off)
    # every non-kind-1 draw is untouched
    assert np.array_equal(base.kinds, shifted.kinds)
    assert np.array_equal(base.range_los, shifted.range_los)
    assert np.array_equal(base.write_keys, shifted.write_keys)
    pos = {int(k): i for i, k in enumerate(keys)}
    is_z1 = base.kinds[base.kinds <= 1] == 1
    for b, s in zip(base.point_keys[is_z1], shifted.point_keys[is_z1]):
        assert pos[int(s)] == (pos[int(b)] + off) % len(keys)
    # empty reads (high-bit perturbed) are identical
    assert np.array_equal(base.point_keys[~is_z1],
                          shifted.point_keys[~is_z1])


def test_burst_amplitude_and_volume():
    sc = get_scenario(_drift("burst_storm", segments=6, n_queries=200,
                             scenario_params=(("amplitude", 7.0),
                                              ("period", 3))))
    vols = [sc.segment_queries(s) for s in range(6)]
    assert vols == [200, 200, 1400, 200, 200, 1400]
    sc_max = get_scenario(_drift("burst_storm", n_queries=10,
                                 scenario_params=(("amplitude", 1000.0),
                                                  ("period", 2))))
    assert sc_max.segment_queries(1) == 10_000     # the 1000x ceiling works


def test_tombstone_fraction_and_delete_execution():
    tree, keys = _tree_and_keys()
    mix = (0.05, 0.1, 0.05, 0.8)
    base = materialize_session(keys, mix, n_queries=1000, seed=9,
                               key_space=2 ** 20)
    plan = materialize_session(keys, mix, n_queries=1000, seed=9,
                               key_space=2 ** 20, delete_fraction=0.5)
    # the classic draws are untouched: deletes are drawn after the loop
    assert np.array_equal(base.kinds, plan.kinds)
    assert np.array_equal(base.point_keys, plan.point_keys)
    n_w = len(plan.write_keys)
    assert plan.write_tombs is not None and len(plan.write_tombs) == n_w
    frac = plan.write_tombs.mean()
    assert abs(frac - 0.5) < 2.0 / n_w             # rounding only
    # non-delete slots keep the fresh draw; delete slots target OLD keys
    keep = ~plan.write_tombs
    assert np.array_equal(plan.write_keys[keep], base.write_keys[keep])
    targets = plan.write_keys[plan.write_tombs]
    old_half = set(int(k) for k in keys[:len(keys) // 2])
    assert all(int(t) in old_half for t in targets)
    assert np.array_equal(plan.insert_keys, plan.write_keys[keep])
    assert np.array_equal(base.insert_keys, base.write_keys)
    # execution: deleted keys must read as absent afterwards
    res = execute_session(tree, plan)
    assert res.avg_io_per_query > 0
    tree.flush()
    for t in targets[:32]:
        assert tree.get(int(t)) is None, int(t)
    # a surviving fresh insert is present
    assert tree.get(int(plan.insert_keys[0])) is not None


def test_scan_heavy_widens_ranges():
    sc = get_scenario(_drift("scan_heavy", segments=5, range_fraction=1e-4,
                             scenario_params=(("scan_scale", 6.0),)))
    rf0 = sc.session_kwargs(0, 1000)["range_fraction"]
    rf_last = sc.session_kwargs(4, 1000)["range_fraction"]
    assert abs(rf0 - 1e-4) < 1e-12
    assert abs(rf_last - 6e-4) < 1e-12


# ---------------------------------------------------------------------------
# Adversary: hand-computed symmetric golden + live attack
# ---------------------------------------------------------------------------

def test_adversary_inner_max_symmetric_golden():
    """For cost e4 and the uniform center, the tilted worst case is
    ((1-p)/3, ..., p) with p pinned by the hand-derived KL equation
    p*ln(4p) + (1-p)*ln(4(1-p)/3) = rho — solved here by independent
    bisection, not by the library under test."""
    from repro.core import worst_case_workload, robust_cost
    c = np.array([0.0, 0.0, 0.0, 1.0])
    w = np.full(4, 0.25)
    rho = 0.1

    def kl_of(p):
        return p * np.log(4 * p) + (1 - p) * np.log(4 * (1 - p) / 3)

    lo, hi = 0.25, 1.0 - 1e-12
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        lo, hi = (mid, hi) if kl_of(mid) < rho else (lo, mid)
    p_star = 0.5 * (lo + hi)
    w_adv = np.asarray(worst_case_workload(c, w, rho, iters=80))
    assert abs(w_adv[3] - p_star) < 1e-4
    np.testing.assert_allclose(w_adv[:3], (1 - p_star) / 3, atol=1e-4)
    # zero duality gap: the primal attack meets the independent dual bound
    assert abs(float(c @ w_adv) - float(robust_cost(c, w, rho))) < 1e-3
    # degenerate ball: rho >= ln 4 covers the whole simplex -> point mass
    w_big = np.asarray(worst_case_workload(c, w, 2.0, iters=80))
    assert w_big[3] > 0.99


def test_adversary_attack_stays_on_ball_boundary():
    tr = tune_nominal(np.full(4, 0.25), SYS, **SMALL)
    sc = get_scenario(_drift("adversary", scenario_params=(("rho", 0.2),)))
    w_adv, rec = sc.attack(tr.phi, np.full(4, 0.25), 0.0, SYS)
    assert abs(rec["kl_adv"] - 0.2) < 1e-3         # fallback rho, exact KL
    assert rec["le_dual_bound"] and rec["regret"] >= 0.0
    # a live defender rho overrides the fallback
    _, rec2 = sc.attack(tr.phi, np.full(4, 0.25), 0.05, SYS)
    assert abs(rec2["kl_adv"] - 0.05) < 1e-3
    assert rec2["cost_adv"] <= rec["cost_adv"] + 1e-9   # smaller ball


# ---------------------------------------------------------------------------
# Page-Hinkley change-point trigger
# ---------------------------------------------------------------------------

def test_page_hinkley_detector_units():
    det = PageHinkleyDetector(delta=0.0, lam=0.1)
    assert not any(det.update(0.0) for _ in range(8))   # flat: no alarm
    assert det.update(0.5)                              # upward shift fires
    det.reset()
    assert not any(det.update(0.01) for _ in range(8))  # re-armed
    # delta absorbs drifts below the noise floor
    det2 = PageHinkleyDetector(delta=0.05, lam=0.1)
    assert not any(det2.update(x) for x in [0.0, 0.02, 0.03, 0.02, 0.03])


def test_change_point_reason_fires_in_session():
    """With the KL triggers parked out of reach, a sustained shift in the
    per-segment KL stream fires the policy through reason='change_point'."""
    tree, keys = _tree_and_keys()
    policy = DriftPolicy(kl_threshold=99.0, budget_slack=1e9,
                         min_windows=1, cooldown=1,
                         detector="page_hinkley", ph_delta=0.0,
                         ph_lambda=0.05)
    assert isinstance(policy.make_detector(), PageHinkleyDetector)
    assert DriftPolicy().make_detector() is None
    expected = (0.01, 0.01, 0.01, 0.97)
    sess = OnlineSession(tree, expected=expected, rho=0.0, sys=SYS,
                         mode="online", policy=policy)
    matched = materialize_session(keys, expected, n_queries=300, seed=1,
                                  key_space=2 ** 20)
    drifted = materialize_session(keys, (0.4, 0.4, 0.1, 0.1),
                                  n_queries=300, seed=2, key_space=2 ** 20)
    for s in range(2):
        sess.execute_segment(matched, expected, s)
    assert sess.take_request() is None
    reasons = []
    for s in range(2, 5):
        sess.execute_segment(drifted, (0.4, 0.4, 0.1, 0.1), s)
        req = sess.take_request()
        if req is not None:
            reasons.append(req.reason)
    assert "change_point" in reasons


# ---------------------------------------------------------------------------
# Overlap-based partial-compaction slice selection
# ---------------------------------------------------------------------------

def test_overlap_select_validates_and_defaults_unchanged():
    cfg = EngineConfig(T=4, buf_entries=64, mfilt_bits_per_entry=6.0,
                       expected_entries=2000, policy="partial")
    assert PartialCompactionPlanner(cfg).select == "round_robin"
    with pytest.raises(ValueError, match="slice selection"):
        PartialCompactionPlanner(cfg, select="best_effort")


def test_overlap_picks_min_overlap_slice_and_progresses():
    tree = LSMTree(EngineConfig(T=4, buf_entries=64,
                                mfilt_bits_per_entry=6.0,
                                expected_entries=4000, policy="partial",
                                policy_params=(("select", "overlap"),)))
    keys = populate(tree, 4000, seed=11, key_space=2 ** 20)
    # drive an overfull level through a write-heavy session; the skip-set
    # guarantees _maintain terminates even when a slice extracts nothing
    from repro.lsm import run_session
    res = run_session(tree, keys, (0.05, 0.15, 0.05, 0.75),
                      n_queries=2500, seed=3, key_space=2 ** 20)
    assert res.avg_io_per_query > 0
    # logical equivalence with round-robin selection: same live content
    tree2 = LSMTree(EngineConfig(T=4, buf_entries=64,
                                 mfilt_bits_per_entry=6.0,
                                 expected_entries=4000, policy="partial"))
    populate(tree2, 4000, seed=11, key_space=2 ** 20)
    run_session(tree2, keys, (0.05, 0.15, 0.05, 0.75),
                n_queries=2500, seed=3, key_space=2 ** 20)
    for k in keys[::97]:
        assert tree.get(int(k)) == tree2.get(int(k))


def test_overlap_scoring_prefers_empty_target_span():
    """The score is the uniform-density estimate of target-level entries
    under the slice; a slice over a hole in the target level must win."""
    tree = LSMTree(EngineConfig(T=4, buf_entries=64,
                                mfilt_bits_per_entry=6.0,
                                expected_entries=4000, policy="partial",
                                policy_params=(("select", "overlap"),
                                               ("parts", 4))))
    populate(tree, 4000, seed=11, key_space=2 ** 20)
    planner = tree.planner
    planner._tried.clear()      # re-arm: populate already cycled the state
    planner._state.clear()
    store = tree.store
    # find a populated level with a populated next level
    level = next(i + 1 for i, lv in enumerate(store.levels)
                 if lv.num_runs and i + 1 < len(store.levels)
                 and store.levels[i + 1].num_runs)
    lv = store.levels[level - 1]
    lo_key, hi_key = int(lv.min_keys.min()), int(lv.max_keys.max())
    width = max(1, (hi_key - lo_key + 1) // planner.parts)
    cands = planner._candidates(lo_key, hi_key, width)
    scores = [planner._overlap_score(store, level, clo, chi)
              for clo, chi in cands]
    picked = planner._pick_overlap(store, level, lo_key, hi_key, width)
    assert picked in cands
    assert planner._overlap_score(store, level, *picked) == min(scores)
    # progress: with frozen state, repeated picks cycle without repeats
    seen = {picked}
    for _ in range(len(cands) - 1):
        nxt = planner._pick_overlap(store, level, lo_key, hi_key, width)
        assert nxt not in seen
        seen.add(nxt)


# ---------------------------------------------------------------------------
# uint32-limb splitmix64
# ---------------------------------------------------------------------------

def test_limb_splitmix64_bit_identity():
    import jax
    from repro.lsm.bloom import splitmix64
    with jax.experimental.enable_x64():
        import jax.numpy as jnp
        from repro.kernels.point_read.limb import (from_limbs, mod_limbs,
                                                   split64_jnp,
                                                   splitmix64_limbs,
                                                   to_limbs)
        rng = np.random.default_rng(0)
        x = rng.integers(0, 2 ** 64, size=4096, dtype=np.uint64)
        x = np.concatenate([x, np.array(
            [0, 1, 2 ** 32 - 1, 2 ** 32, 2 ** 64 - 1, 0x9E3779B97F4A7C15],
            np.uint64)])
        lo, hi = to_limbs(x)
        assert np.array_equal(from_limbs(lo, hi), x)     # round trip
        jlo, jhi = split64_jnp(jnp.asarray(x))
        for seed in (1, 2, 7, 255):
            ref = splitmix64(x, np.uint64(seed))
            zlo, zhi = splitmix64_limbs(jlo, jhi, seed)
            got = from_limbs(np.asarray(zlo), np.asarray(zhi))
            assert np.array_equal(ref, got), f"seed={seed}"
            for m in (63, 64, 1021, 2 ** 20 + 7, 2 ** 31 - 1):
                want = (ref % np.uint64(m)).astype(np.uint64)
                have = np.asarray(mod_limbs(zlo, zhi, m)).astype(np.uint64)
                assert np.array_equal(want, have), f"m={m}"
        with pytest.raises(ValueError, match="2\\^31"):
            mod_limbs(jlo, jhi, 2 ** 31)


def test_limb_read_kernel_matches_native():
    from repro.lsm import read_path
    tree, keys = _tree_and_keys(n=2000)
    sub = np.concatenate([keys[:400], keys[:100] | np.uint64(1 << 60)])
    outs = {}
    for mode in ("jnp", "jnp_limb"):
        with read_path.read_kernel(mode):
            lv = next(lv for lv in tree.store.levels if lv.num_runs)
            outs[mode] = read_path.point_read_level_numpy(lv, sub)
    a, b = outs["jnp"], outs["jnp_limb"]
    assert np.array_equal(np.asarray(a[0]), np.asarray(b[0]))
    assert np.array_equal(np.asarray(a[1]), np.asarray(b[1]))
    assert a[2:] == b[2:]
    with pytest.raises(ValueError):
        read_path.set_read_kernel("uint128")


# ---------------------------------------------------------------------------
# End to end: five kinds x three backends, bit-identical across backends
# ---------------------------------------------------------------------------

SCENARIO_MATRIX = [
    ("zipf_migrate", ()),
    ("burst_storm", (("amplitude", 3.0), ("period", 2))),
    ("tombstone_churn", (("delete_fraction", 0.4),)),
    ("scan_heavy", (("scan_scale", 4.0),)),
    ("adversary", (("rho", 0.2),)),
]


def _scenario_spec(kind, params, backend):
    api = _api()
    return api.ExperimentSpec(
        name=f"sc_{kind}",
        workload=api.WorkloadSpec(indices=(4,), nominal=True,
                                  rho_source="from_history",
                                  history=((0.01, 0.01, 0.01, 0.97),
                                           (0.3, 0.3, 0.3, 0.1))),
        design=api.DesignSpec(**SMALL), system=SYS_PAIRS,
        backend=backend,
        backend_params=(("workers", 2),) if backend != "inline" else (),
        drift=api.DriftSpec(kind=kind, segments=3, n_queries=150,
                            scenario_params=params, n_keys=2500,
                            key_space=2 ** 20, window=2, min_windows=1,
                            cooldown=1, retune_starts=4, retune_steps=40))


def _segment_ios(report):
    return {key: [r.avg_io_per_query for r in res.records]
            for key, res in sorted(report.drift.items())}


@pytest.mark.parametrize("kind,params", SCENARIO_MATRIX,
                         ids=[k for k, _ in SCENARIO_MATRIX])
def test_scenarios_end_to_end_all_backends(kind, params):
    """Each scenario kind runs unchanged on inline, sharded and subprocess
    backends, measuring bit-identical I/O (the backend moves work, never
    changes it); the adversary's regret claim holds on every backend."""
    api = _api()
    reports = {}
    for backend in ("inline", "sharded", "subprocess"):
        rep = api.run_experiment(_scenario_spec(kind, params, backend))
        arms = {arm for _, arm in rep.drift}
        assert arms == {"stale_nominal", "static_robust", "online", "oracle"}
        for res in rep.drift.values():
            assert all(r.avg_io_per_query > 0 for r in res.records)
        qs = {tuple(r.queries for r in res.records)
              for res in rep.drift.values()}
        assert len(qs) == 1                    # paired arms, same volume
        if kind == "burst_storm":
            assert list(qs)[0] == (150, 450, 150)
        if kind == "adversary":
            recs = rep.regret[0]
            assert len(recs) == 3
            assert all(r["le_dual_bound"] for r in recs)
            assert all(r["kl_adv"] > 0 for r in recs)
        else:
            assert rep.regret == {}
        reports[backend] = rep
    base = _segment_ios(reports["inline"])
    for other in ("sharded", "subprocess"):
        assert _segment_ios(reports[other]) == base, other
    # the report serializes in the BENCH schema with the regret row
    import json
    payload = reports["inline"].to_bench_payload()
    json.dumps(payload, allow_nan=False)
    names = [r["name"] for r in payload["rows"]]
    if kind == "adversary":
        assert f"sc_{kind}_regret_w0" in names
