"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU asserting output shapes + no NaNs, plus a
prefill->decode consistency check (decode over cached context must produce
the same logits as the full forward)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import build_model

ARCH_NAMES = sorted(ARCHS.keys())


def _smoke_batch(api, B=2, S=16, seed=0):
    cfg = api.cfg
    rng = np.random.default_rng(seed)
    batch = {}
    if cfg.encoder is not None:
        d_in = cfg.encoder.d_input or cfg.d_model
        batch["embeds"] = jnp.asarray(
            rng.normal(size=(B, S, d_in)).astype(np.float32))
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    elif cfg.embed_inputs:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    else:
        batch["embeds"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)).astype(np.float32))
        if cfg.mrope_sections is not None:
            base = np.broadcast_to(np.arange(S)[None], (B, S))
            batch["positions"] = jnp.asarray(
                np.broadcast_to(base[None], (3, B, S)), jnp.int32)
    batch["labels"] = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = _smoke_batch(api)

    loss, metrics = jax.jit(api.loss_fn)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss={loss}"
    # ~log(vocab) at init
    assert 0.0 < float(metrics["xent"]) < 3 * np.log(cfg.vocab_size)

    grads = jax.jit(jax.grad(lambda p, b: api.loss_fn(p, b)[0]))(params, batch)
    leaves = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g))) for g in leaves), arch
    total_norm = float(sum(jnp.sum(jnp.square(g)) for g in leaves)) ** 0.5
    assert total_norm > 0.0, f"{arch}: zero gradient"


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_decode_consistency(arch):
    """Decoding token-by-token against the cache must match the parallel
    forward pass (validates every cache/state path incl. ring buffers)."""
    cfg = get_config(arch).reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(1))
    B, S = 2, 8
    batch = _smoke_batch(api, B=B, S=S, seed=3)

    # Full-sequence logits via prefill on the whole sequence.
    logits_full, _ = jax.jit(api.prefill)(params, batch)  # (B,1,V): last pos

    # Incremental: prefill on S-1 tokens, then decode the final token.
    if cfg.encoder is not None:
        batch_part = dict(batch)
        batch_part["tokens"] = batch["tokens"][:, :-1]
        logits_part, cache = jax.jit(api.prefill)(params, batch_part)
        cache = _pad_cache(cache, api, B, S, part=S - 1, encdec=True)
        last = batch["tokens"][:, -1:]
        logits_dec, _ = jax.jit(api.decode_step)(
            params, cache, last, jnp.asarray(S - 1, jnp.int32))
    elif cfg.embed_inputs:
        batch_part = {"tokens": batch["tokens"][:, :-1]}
        logits_part, cache = jax.jit(api.prefill)(params, batch_part)
        cache = _pad_cache(cache, api, B, S, part=S - 1)
        last = batch["tokens"][:, -1:]
        logits_dec, _ = jax.jit(api.decode_step)(
            params, cache, last, jnp.asarray(S - 1, jnp.int32))
    else:
        batch_part = {k: (v[:, :, :-1] if k == "positions" else v[:, :-1])
                      for k, v in batch.items() if k != "labels"}
        logits_part, cache = jax.jit(api.prefill)(params, batch_part)
        cache = _pad_cache(cache, api, B, S, part=S - 1)
        last = batch["embeds"][:, -1:]
        logits_dec, _ = jax.jit(api.decode_step)(
            params, cache, last, jnp.asarray(S - 1, jnp.int32))

    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full),
                               rtol=2e-2, atol=2e-2)


def _pad_cache(cache, api, B, S, part, encdec=False):
    """Pad a prefill cache (seq length `part`) out to decode capacity S.
    Only attention KV caches need padding; recurrent states are size-fixed."""
    full = api.init_cache(B, S)

    def pad(c, f):
        if c.shape == f.shape:
            return c.astype(f.dtype)
        pads = [(0, fs - cs) for cs, fs in zip(c.shape, f.shape)]
        return jnp.pad(c, pads).astype(f.dtype)

    return jax.tree.map(pad, cache, full)


def test_whisper_encoder_is_bidirectional():
    cfg = get_config("whisper-base").reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    from repro.models.encdec import encode
    rng = np.random.default_rng(0)
    e = jnp.asarray(rng.normal(size=(1, 8, cfg.encoder.d_input)), jnp.float32)
    out1 = encode(params, e, cfg)
    # perturb the LAST frame; with bidirectional attention the FIRST output
    # position must change too
    e2 = e.at[:, -1].add(1.0)
    out2 = encode(params, e2, cfg)
    assert not np.allclose(np.asarray(out1[:, 0]), np.asarray(out2[:, 0]))


def test_causality_dense():
    """Future tokens must not influence past logits (decoder-only)."""
    cfg = get_config("qwen3-14b").reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    from repro.models.lm import embed_tokens, apply_stack
    rng = np.random.default_rng(0)
    t1 = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 8)), jnp.int32)
    t2 = t1.at[0, -1].set((int(t1[0, -1]) + 1) % cfg.vocab_size)

    def hidden(tokens):
        x, pos = embed_tokens(params, {"tokens": tokens}, cfg)
        x, _, _ = apply_stack(params, x, cfg, "prefill", positions=pos)
        return x

    h1, h2 = hidden(t1), hidden(t2)
    np.testing.assert_allclose(np.asarray(h1[:, :-1]), np.asarray(h2[:, :-1]),
                               rtol=1e-5, atol=1e-5)
    assert not np.allclose(np.asarray(h1[:, -1]), np.asarray(h2[:, -1]))


def test_moe_routes_to_multiple_experts():
    cfg = get_config("mixtral-8x7b").reduced()
    from repro.models.moe import apply_moe, init_moe
    p = init_moe(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 16, cfg.d_model)), jnp.float32)
    out, aux = apply_moe(p, x, cfg)
    assert out.shape == x.shape
    assert np.all(np.isfinite(np.asarray(out)))
    assert float(aux) > 0.0
    # aux ~ 1.0 under balanced routing
    assert 0.5 < float(aux) < 4.0


def test_param_counts_match_spec():
    """Sanity-pin the parameter counts to the architecture names."""
    expect = {
        "qwen1.5-110b": (105e9, 120e9),
        "glm4-9b": (8e9, 11e9),
        "phi3-mini-3.8b": (3.3e9, 4.3e9),
        "qwen3-14b": (13e9, 16e9),
        "rwkv6-3b": (2.5e9, 3.6e9),
        "whisper-base": (0.04e9, 0.12e9),
        "deepseek-moe-16b": (14e9, 18e9),
        "mixtral-8x7b": (43e9, 50e9),
        "qwen2-vl-72b": (68e9, 77e9),
        "jamba-1.5-large-398b": (370e9, 420e9),
    }
    for name, (lo, hi) in expect.items():
        n = get_config(name).param_count()
        assert lo <= n <= hi, f"{name}: {n/1e9:.1f}B not in [{lo/1e9},{hi/1e9}]"
    # MoE active counts
    assert 2.2e9 <= get_config("deepseek-moe-16b").active_param_count() <= 3.5e9
    assert 11e9 <= get_config("mixtral-8x7b").active_param_count() <= 14e9
    assert 88e9 <= get_config("jamba-1.5-large-398b").active_param_count() <= 99e9
