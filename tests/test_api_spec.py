"""Tests for the unified experiment API (repro.api).

The facade's contract is *zero semantic surface*: a spec lowered through
``compile.py`` + any backend must produce bit-identical tunings and
``IOStats`` to hand-wiring the same experiment on the low-level layer
(``tune_nominal_many`` / ``tune_robust_many`` + ``run_policy_fleet``).
These tests pin that contract on a small grid for the inline and
sharded-fallback backends (single device -> the sharded backend must take
the inline path), plus the subprocess fleet backend, the spec <-> JSON
round-trip, and the joint policy-arm selection.

Deliberately hypothesis-free; solver sizes are small so the file runs in
about a minute on CPU.
"""

import dataclasses

import numpy as np
import pytest

from repro.api import (DesignSpec, ExperimentSpec, TrialSpec, WorkloadSpec,
                       run_experiment)
from repro.core import EXPECTED_WORKLOADS, LSMSystem, tune_nominal_many, \
    tune_robust_many
from repro.lsm import run_policy_fleet

SMALL = dict(n_starts=8, steps=60, seed=3)
RHOS = (0.25, 1.0)
WIDX = (7, 11)
SYS_PAIRS = (("N", 8000.0), ("entry_bits", 512.0), ("bits_per_entry", 6.0),
             ("min_buf_bits", 512.0 * 64), ("max_T", 20.0))
SESSIONS = ((0.05, 0.85, 0.05, 0.05), (0.05, 0.05, 0.05, 0.85))


def _spec(**kw) -> ExperimentSpec:
    base = dict(
        name="t",
        workload=WorkloadSpec(indices=WIDX, rhos=RHOS, nominal=True),
        design=DesignSpec(**SMALL),
        system=SYS_PAIRS,
    )
    base.update(kw)
    return ExperimentSpec(**base)


def _assert_same_tuning(a, b):
    assert float(a.phi.T) == float(b.phi.T)
    assert np.array_equal(np.asarray(a.phi.K), np.asarray(b.phi.K))
    assert float(a.phi.mfilt_bits) == float(b.phi.mfilt_bits)
    assert a.cost == b.cost
    assert a.design is b.design


# ---------------------------------------------------------------------------
# Spec <-> JSON round-trip
# ---------------------------------------------------------------------------

def test_spec_json_round_trip():
    spec = _spec(
        trial=TrialSpec(n_keys=5000, n_queries=300, sessions=SESSIONS,
                        key_space=2 ** 22, session_seeds=(4, 5)),
        design=DesignSpec(policies=("klsm", "lazy_leveling"),
                          policy_params=(
                              ("lazy_leveling", (("read_trigger", 64),)),),
                          **SMALL),
        backend="subprocess", backend_params=(("workers", 2),))
    back = ExperimentSpec.from_json(spec.to_json())
    assert back == spec
    # frozen dataclasses: equal means field-for-field equal, incl. nesting
    assert back.trial.sessions == spec.trial.sessions
    assert back.design.params_for("lazy_leveling") == (("read_trigger", 64),)


def test_spec_validation():
    with pytest.raises(ValueError):
        WorkloadSpec(indices=(1,), workloads=((0.25,) * 4,))
    with pytest.raises(ValueError):
        WorkloadSpec(indices=(1,), rhos=(), nominal=False)
    with pytest.raises(ValueError):
        DesignSpec(policies=())
    with pytest.raises(ValueError):
        TrialSpec(sessions=())


# ---------------------------------------------------------------------------
# Bit-identity vs the direct low-level calls
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def direct():
    sys_small = LSMSystem().replace(**dict(SYS_PAIRS))
    W = EXPECTED_WORKLOADS[list(WIDX)]
    nominal = tune_nominal_many(W, sys_small, **SMALL)
    robust = tune_robust_many(W, list(RHOS), sys_small, **SMALL)
    return sys_small, nominal, robust


@pytest.mark.parametrize("backend", ["inline", "sharded"])
def test_tunings_bit_identical_to_direct(direct, backend):
    """Facade tunings == direct tune_*_many, inline AND sharded fallback
    (this host has one device, so `sharded` must take the inline path)."""
    _, nominal, robust = direct
    report = run_experiment(_spec(backend=backend))
    for i in range(len(WIDX)):
        _assert_same_tuning(report.tuning((i, None)), nominal[i])
        for j, rho in enumerate(RHOS):
            _assert_same_tuning(report.tuning((i, rho)), robust[i][j])


def test_trial_bit_identical_to_run_policy_fleet(direct):
    """Facade fleet IOStats == a direct run_policy_fleet on the same phis
    (same key draw, same session seeds, same tree order)."""
    sys_small, _, robust = direct
    spec = _spec(
        workload=WorkloadSpec(indices=WIDX, rhos=(1.0,), nominal=False),
        trial=TrialSpec(n_keys=5000, n_queries=300, sessions=SESSIONS,
                        key_space=2 ** 22, range_fraction=1e-3, key_seed=7))
    report = run_experiment(spec)
    phis = [robust[i][1].phi for i in range(len(WIDX))]  # rho=1.0 column
    _, results = run_policy_fleet(
        phis, sys_small, ["klsm"], np.asarray(SESSIONS), n_keys=5000,
        n_queries=300, seed=7, key_space=2 ** 22, range_fraction=1e-3)
    for i in range(len(WIDX)):
        facade = report.fleet[((i, 1.0), "klsm")]
        for s, direct_res in enumerate(results[i][0]):
            assert facade[s].io == direct_res.io
            assert facade[s].avg_io_per_query == direct_res.avg_io_per_query


def test_subprocess_backend_matches_inline():
    spec = _spec(
        workload=WorkloadSpec(indices=WIDX, rhos=(1.0,), nominal=False),
        trial=TrialSpec(n_keys=5000, n_queries=300, sessions=SESSIONS,
                        key_space=2 ** 22, per_workload_keys=True))
    inline = run_experiment(spec)
    sub = run_experiment(dataclasses.replace(
        spec, backend="subprocess", backend_params=(("workers", 2),)))
    assert set(sub.fleet) == set(inline.fleet)
    for key in inline.fleet:
        for a, b in zip(inline.fleet[key], sub.fleet[key]):
            assert a.io == b.io
    assert sub.walls["trial_workers"] == 2


# ---------------------------------------------------------------------------
# Joint policy-arm selection + report surface
# ---------------------------------------------------------------------------

def test_policy_arm_selection_is_joint():
    """Write-heavy cells pick the lazy arm, read-heavy cells the leveled
    K-LSM arm, under the same spec — the discrete axis is optimized per
    cell, not globally."""
    spec = ExperimentSpec(
        name="arms",
        workload=WorkloadSpec(indices=(4, 11), rhos=(1.0,), nominal=False),
        design=DesignSpec(policies=("klsm", "lazy_leveling"), **SMALL))
    report = run_experiment(spec)
    assert report.chosen[(0, 1.0)] == "lazy_leveling"   # w4: write-heavy
    assert report.chosen[(1, 1.0)] == "klsm"            # w11: read-mixed
    for cell in report.cells:
        costs = report.arm_costs[cell]
        assert costs[report.chosen[cell]] == min(costs.values())


def test_single_arm_spec_chooses_primary():
    report = run_experiment(_spec())
    assert all(report.chosen[c] == "klsm" for c in report.cells)


def test_report_bench_payload_schema():
    """The report serializes in exactly the BENCH_<suite>.json shape the
    perf gate consumes."""
    spec = _spec(workload=WorkloadSpec(indices=(7,), rhos=(1.0,),
                                       nominal=True, bench_n=200))
    report = run_experiment(spec)
    from repro import obs
    with obs.scoped(enabled=False):
        payload = report.to_bench_payload()
    # the baseline shape — REPRO_OBS must not change untraced payloads
    assert set(payload) == {"suite", "wall_time_s", "error", "rows",
                            "checksum"}
    with obs.scoped(enabled=True, clock="ticks"):
        traced = report.to_bench_payload()
    # a live telemetry plane merges its metrics block (and re-checksums)
    assert set(traced) == {"suite", "wall_time_s", "error", "rows",
                           "metrics", "checksum"}
    assert payload["suite"] == "t"
    assert payload["error"] is None
    for row in payload["rows"]:
        assert set(row) == {"name", "us_per_call", "derived"}
    import json
    json.dumps(payload, allow_nan=False)     # strict-JSON clean
    from repro.faults import checksum_ok
    assert checksum_ok(payload)              # self-validating baseline
    # delta-throughput metric surface
    d = report.delta_tp_vs_nominal(0, 1.0)
    assert d.shape == (200,)
    assert np.isfinite(d).all()


def test_fixed_design_skips_tuning():
    spec = ExperimentSpec(
        name="fixed",
        workload=WorkloadSpec(workloads=((0.25, 0.25, 0.25, 0.25),),
                              rhos=(), nominal=True),
        design=DesignSpec(fixed=(6.0, 4.0, 1.0),
                          policies=("klsm", "lazy_leveling")),
        system=SYS_PAIRS)
    report = run_experiment(spec)
    assert report.walls["tuning_s"] == pytest.approx(0.0, abs=0.05)
    r = report.tuning((0, None), "klsm")
    assert float(r.phi.T) == 6.0
    assert r.solver == "fixed"
    # the lazy arm's effective profile differs -> different model cost
    mc = report.model_costs[(0, None)]
    assert not np.allclose(mc["klsm"], mc["lazy_leveling"])
