"""Engine-level goldens for the kernel data plane (PR 7).

Switching the point-read implementation (``lsm.read_path`` modes) or the
compaction-merge implementation (``lsm.merge_path`` modes) is a pure
execution choice: query results, tree shape, on-disk arenas, and the
``IOStats`` I/O accounting must all stay bit-identical.  These tests pin
that contract at the engine boundary — the per-kernel bit-equivalence
tests live in ``tests/test_kernels.py``.

Trees are deliberately small: off-TPU the Pallas legs run under the
interpret-mode evaluator, which re-traces per arena layout.
"""

import dataclasses

import numpy as np
import pytest

from repro.lsm import EngineConfig, LSMTree
from repro.lsm.merge_path import get_merge_kernel, merge_kernel
from repro.lsm.read_path import get_read_kernel, read_kernel

N_KEYS = 1500


def _build(policy="klsm", n=N_KEYS, seed=0):
    tree = LSMTree(EngineConfig(T=3, K=(2, 2), buf_entries=64,
                                expected_entries=n,
                                mfilt_bits_per_entry=8.0, policy=policy))
    rng = np.random.default_rng(seed)
    keys = rng.choice(1 << 32, n, replace=False).astype(np.uint64)
    tree.put_batch(keys, [int(k) % 1009 for k in keys])
    for k in keys[:40]:                      # tombstones in the mix
        tree.delete(int(k))
    tree.flush()
    return tree, keys


def _queries(keys, seed=1):
    rng = np.random.default_rng(seed)
    q = np.concatenate([
        rng.choice(keys, 150),               # present (some deleted)
        keys[:20],                           # definitely deleted
        rng.choice(1 << 32, 87).astype(np.uint64),   # mostly absent
    ])
    return [int(k) for k in q]


def _fingerprint(tree):
    """Everything the data plane could possibly perturb."""
    shape = tree.shape()
    arenas = [(lv.keys.tobytes(), lv.vals.tobytes(),
               tuple(np.asarray(lv.starts)))
              for lv in tree.store.levels]
    return shape, arenas, dataclasses.asdict(tree.stats)


def test_read_mode_default_is_numpy():
    assert get_read_kernel() == "numpy"
    assert get_merge_kernel() == "numpy"


def test_point_query_batch_golden_across_read_modes():
    """Results AND per-query IOStats deltas identical in all 3 modes."""
    out = {}
    for mode in ("numpy", "jnp", "pallas"):
        tree, keys = _build()
        q = _queries(keys)
        with read_kernel(mode):
            before = tree.stats.snapshot()
            res = tree.point_query_batch(q)
            delta = tree.stats.minus(before)
        out[mode] = (res, dataclasses.asdict(delta))
    assert out["jnp"] == out["numpy"]
    assert out["pallas"] == out["numpy"]


def test_read_mode_scoped_switch_restores():
    with read_kernel("jnp"):
        assert get_read_kernel() == "jnp"
    assert get_read_kernel() == "numpy"
    with pytest.raises(ValueError):
        read_kernel("vulkan").__enter__()


@pytest.mark.parametrize("policy", ["klsm", "partial", "lazy_leveling",
                                    "tombstone_ttl"])
def test_build_golden_across_merge_modes_jnp(policy):
    """Building the tree with the jnp rank-merge must leave shape,
    arenas, compaction accounting, and query answers unchanged."""
    # partial compaction emits many distinct merge shapes; a smaller
    # tree keeps its eager-jnp dispatch cost bounded
    n = 700 if policy == "partial" else N_KEYS
    tree_ref, keys = _build(policy, n=n)
    ref = (_fingerprint(tree_ref), tree_ref.point_query_batch(_queries(keys)))
    with merge_kernel("jnp"):
        tree, _ = _build(policy, n=n)
        got = (_fingerprint(tree), tree.point_query_batch(_queries(keys)))
    assert got == ref


@pytest.mark.parametrize("policy", ["klsm", "partial"])
def test_build_golden_across_merge_modes_pallas(policy):
    """Same contract for the Pallas merge-path kernel (interpret mode);
    two policies keep the re-trace count bounded off-TPU."""
    n = 600                                  # smaller: interpret re-traces
    tree_ref, keys = _build(policy, n=n)
    ref = (_fingerprint(tree_ref), tree_ref.point_query_batch(_queries(keys)))
    with merge_kernel("pallas"):
        tree, _ = _build(policy, n=n)
        got = (_fingerprint(tree), tree.point_query_batch(_queries(keys)))
    assert got == ref
