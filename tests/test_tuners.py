"""Tests for the nominal and robust tuners (paper Sections 5-6)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (EXPECTED_WORKLOADS, DesignSpace, LSMSystem,
                        cost_vector, expected_cost, kl_divergence,
                        primal_worst_case, robust_cost, tune_nominal,
                        tune_nominal_slsqp, tune_robust, worst_case_workload)
from repro.core.robust import _g_of_lam, dual_objective_explicit

SYS = LSMSystem()
W7 = EXPECTED_WORKLOADS[7]
W11 = EXPECTED_WORKLOADS[11]


# ---------------------------------------------------------------------------
# Robust dual machinery (independent of the LSM cost model)
# ---------------------------------------------------------------------------

cost_strat = st.lists(st.floats(min_value=0.01, max_value=100.0), min_size=4,
                      max_size=4)
w_strat = st.lists(st.floats(min_value=0.01, max_value=1.0), min_size=4,
                   max_size=4)
rho_strat = st.floats(min_value=0.01, max_value=4.0)


@settings(max_examples=50, deadline=None)
@given(c=cost_strat, w=w_strat, rho=rho_strat)
def test_duality_gap_zero(c, w, rho):
    """Lemma 1 / Ben-Tal et al.: dual value == exact primal worst case."""
    c = jnp.asarray(c, jnp.float32)
    w = jnp.asarray(w, jnp.float32)
    w = w / w.sum()
    dual = float(robust_cost(c, w, rho))
    w_hat = worst_case_workload(c, w, rho)
    primal = float(jnp.dot(w_hat, c))
    assert dual == pytest.approx(primal, rel=2e-3, abs=1e-4)


@settings(max_examples=50, deadline=None)
@given(c=cost_strat, w=w_strat, rho=rho_strat)
def test_worst_case_in_uncertainty_region(c, w, rho):
    """Eq. 12: the maximizer lies in U^rho_w (KL <= rho, simplex)."""
    c = jnp.asarray(c, jnp.float32)
    w = jnp.asarray(w, jnp.float32)
    w = w / w.sum()
    w_hat = worst_case_workload(c, w, rho)
    assert float(jnp.sum(w_hat)) == pytest.approx(1.0, abs=1e-5)
    assert float(kl_divergence(w_hat, w)) <= rho * (1 + 1e-3) + 1e-5
    # And it is at least as adversarial as the nominal workload.
    assert float(jnp.dot(w_hat, c)) >= float(jnp.dot(w, c)) - 1e-5


@settings(max_examples=30, deadline=None)
@given(c=cost_strat, w=w_strat, rho=rho_strat)
def test_eta_elimination_exact(c, w, rho):
    """The closed-form eta* = lam log E[e^{c/lam}] makes Eq. 16 == the
    entropic-risk form used by robust_cost."""
    c64 = np.asarray(c, np.float64)
    w64 = np.asarray(w, np.float64)
    w64 = w64 / w64.sum()
    for lam in (0.5, 1.0, 10.0):
        # float64 host evaluation of Eq. 16 verbatim (the f32 device version
        # overflows exp() at small lam -- which is *why* robust_cost uses the
        # eta-eliminated logsumexp form).
        m = (c64 / lam).max()
        eta_star = lam * (m + np.log(np.sum(w64 * np.exp(c64 / lam - m))))
        s = (c64 - eta_star) / lam
        explicit = eta_star + rho * lam + lam * np.sum(w64 * (np.exp(s) - 1.0))
        eliminated = float(_g_of_lam(jnp.asarray(c64, jnp.float32),
                                     jnp.asarray(w64, jnp.float32), rho,
                                     jnp.asarray(lam, jnp.float32)))
        assert explicit == pytest.approx(eliminated, rel=1e-3, abs=1e-3)


@settings(max_examples=30, deadline=None)
@given(c=cost_strat, w=w_strat)
def test_rho_zero_is_nominal(c, w):
    c = jnp.asarray(c, jnp.float32)
    w = jnp.asarray(w, jnp.float32)
    w = w / w.sum()
    assert float(robust_cost(c, w, 0.0)) == pytest.approx(
        float(jnp.dot(w, c)), rel=1e-5)


@settings(max_examples=30, deadline=None)
@given(c=cost_strat, w=w_strat, rho=rho_strat)
def test_robust_cost_monotone_in_rho(c, w, rho):
    c = jnp.asarray(c, jnp.float32)
    w = jnp.asarray(w, jnp.float32)
    w = w / w.sum()
    a = float(robust_cost(c, w, rho))
    b = float(robust_cost(c, w, rho + 0.5))
    assert b >= a - 1e-4
    # And bounded by the max cost (point mass is the worst possible).
    assert b <= float(jnp.max(c)) * (1 + 1e-4) + 1e-5


# ---------------------------------------------------------------------------
# End-to-end tuner behaviour on the paper's workloads
# ---------------------------------------------------------------------------

def test_nominal_matches_paper_structure_w7():
    """Paper Table 5 w7 (49% z0, 49% w): nominal = tiering, T ~ 8."""
    r = tune_nominal(W7, SYS, seed=0)
    K = np.asarray(r.phi.K)
    T = float(r.phi.T)
    assert np.allclose(K[:2], T - 1.0), "w7 nominal should be tiering"
    assert 4 <= T <= 20


def test_nominal_matches_paper_structure_w11():
    """Paper Table 5 w11 (read-heavy): nominal = leveling, large T."""
    r = tune_nominal(W11, SYS, seed=0)
    K = np.asarray(r.phi.K)
    assert np.allclose(K[:2], 1.0), "w11 nominal should be leveling"
    assert float(r.phi.T) >= 20


def test_robust_zero_rho_equals_nominal():
    """Section 8: ENDURE matches nominal when uncertainty is zero."""
    rn = tune_nominal(W11, SYS, seed=0)
    rr = tune_robust(W11, 0.0, SYS, seed=0)
    assert rr.cost == pytest.approx(rn.cost, rel=0.02)


def test_robust_prefers_leveling_and_smaller_T():
    """Section 8.3 / Table 5: robust w11 tunings shrink T and choose
    leveling; Section 11: 'leveling is more robust than tiering'."""
    rn = tune_nominal(W11, SYS, seed=0)
    rr = tune_robust(W11, 1.0, SYS, seed=0)
    assert float(rr.phi.T) < float(rn.phi.T)
    assert np.allclose(np.asarray(rr.phi.K)[:2], 1.0)


def test_robust_improves_worst_case():
    """The whole point: Phi_R beats Phi_N on the worst case at radius rho."""
    rho = 1.0
    rn = tune_nominal(W7, SYS, seed=0)
    rr = tune_robust(W7, rho, SYS, seed=0)
    c_n = cost_vector(rn.phi, SYS)
    c_r = cost_vector(rr.phi, SYS)
    w = jnp.asarray(W7, jnp.float32)
    assert float(robust_cost(c_r, w, rho)) <= float(
        robust_cost(c_n, w, rho)) * (1 + 1e-3)


def test_flexible_designs_no_worse_nominal():
    """Fig. 4: K-LSM >= Fluid >= classic at their own nominal optima."""
    r_classic = tune_nominal(W7, SYS, DesignSpace.CLASSIC, seed=0)
    r_fluid = tune_nominal(W7, SYS, DesignSpace.FLUID, seed=0)
    r_klsm = tune_nominal(W7, SYS, DesignSpace.KLSM, n_starts=128, seed=0)
    assert r_fluid.cost <= r_classic.cost * 1.02
    assert r_klsm.cost <= r_fluid.cost * 1.05  # equal-or-better up to solver noise


@pytest.mark.slow
def test_slsqp_parity_nominal():
    """SciPy SLSQP (paper solver) agrees with the JAX tuner within a few %."""
    r_jax = tune_nominal(W11, SYS, seed=0)
    r_slsqp = tune_nominal_slsqp(W11, SYS, seed=0)
    assert r_slsqp.cost == pytest.approx(r_jax.cost, rel=0.05)
