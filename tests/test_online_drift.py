"""Tests for the online drift subsystem (repro.online) and its API surface.

Covers the tentpole contract end to end: observed-mix accounting is
bit-exact against session plans, rho-from-history reproduces hand-computed
KL, the drift triggers and in-place engine re-tune behave, the storm path
is bit-identical to individual tuner calls (padding included), the
WorkloadSpec rho source round-trips and compiles, the design-space axis
matches per-space specs, the remote backend stub is registered-but-raising,
and the perf gate exits "misconfigured" (not crash / phantom regression)
on a baseline missing its CHECK_METRICS keys.

Deliberately hypothesis-free; solver sizes match test_api_spec's SMALL so
the jit cache is shared and the file stays fast."""

import dataclasses
import math
import os
import sys

import numpy as np
import pytest

from repro.core import LSMSystem, make_phi, rho_from_history, tune_nominal, \
    tune_robust
from repro.lsm import (EngineConfig, LSMTree, execute_session,
                       materialize_session, populate)
from repro.online import (DriftPolicy, EWMAEstimator, OnlineSession,
                          SlidingWindowEstimator, WindowHistory, kl_np,
                          rho_from_history_batch, rho_from_windows)

SMALL = dict(n_starts=8, steps=60, seed=3)
SYS_PAIRS = (("N", 8000.0), ("entry_bits", 512.0), ("bits_per_entry", 6.0),
             ("min_buf_bits", 512.0 * 64), ("max_T", 20.0))
SYS = LSMSystem().replace(**dict(SYS_PAIRS))


def _small_tree(T=4, buf=64, n=1500, mfilt=6.0):
    tree = LSMTree(EngineConfig(T=T, buf_entries=buf,
                                mfilt_bits_per_entry=mfilt,
                                expected_entries=n))
    keys = populate(tree, n, seed=11, key_space=2 ** 20)
    return tree, keys


# ---------------------------------------------------------------------------
# Observation: window counters vs session plans (golden accounting)
# ---------------------------------------------------------------------------

def test_window_ops_sum_exactly_to_plan_counts():
    """Per-window op counters partition the session plan's op counts
    bit-exactly across flush boundaries."""
    tree, keys = _small_tree()
    plan = materialize_session(keys, (0.2, 0.2, 0.1, 0.5), n_queries=900,
                               seed=5, key_space=2 ** 20,
                               range_fraction=1e-3)
    seq_before = tree.flush_seq
    res = execute_session(tree, plan)
    assert res.window_ops is not None and res.window_ops.dtype == np.int64
    # bit-exact partition of the plan
    plan_counts = np.bincount(plan.kinds, minlength=4)
    assert np.array_equal(res.window_ops.sum(axis=0), plan_counts)
    assert res.window_ops.min() >= 0
    # one window per session flush, plus the unflushed tail
    flushes = tree.flush_seq - seq_before
    assert flushes >= 3, "test needs several flush windows to mean anything"
    assert len(res.window_ops) in (flushes, flushes + 1)
    # every flush window ends on a write (the flush-triggering put)
    assert all(res.window_ops[i, 3] > 0 for i in range(flushes))
    assert np.allclose(res.observed_mix.sum(), 1.0)


def test_window_ops_empty_and_readonly_sessions():
    tree, keys = _small_tree()
    plan = materialize_session(keys, (0.5, 0.5, 0.0, 0.0), n_queries=120,
                               seed=2, key_space=2 ** 20)
    res = execute_session(tree, plan)
    assert res.window_ops.shape == (1, 4)          # no flush: one tail window
    assert np.array_equal(res.window_ops.sum(axis=0),
                          np.bincount(plan.kinds, minlength=4))


# ---------------------------------------------------------------------------
# Estimation: hand-computed KL, estimators, fleet batch
# ---------------------------------------------------------------------------

def test_rho_from_history_reproduces_hand_computed_kl():
    """Algorithm 1 on a 2-window toy history, against the formula by hand."""
    w1 = np.array([0.5, 0.2, 0.2, 0.1])
    w2 = np.array([0.1, 0.2, 0.2, 0.5])
    mean = (w1 + w2) / 2.0                          # (0.3, 0.2, 0.2, 0.3)
    hand = max(
        sum(p * math.log(p / q) for p, q in zip(w1, mean)),
        sum(p * math.log(p / q) for p, q in zip(w2, mean)))
    assert rho_from_history(np.stack([w1, w2])) == pytest.approx(
        hand, rel=1e-6)                             # core path is float32
    # the online scalar twin agrees (given counts, not mixes)
    counts = np.stack([w1, w2]) * 1000
    assert rho_from_windows(counts) == pytest.approx(hand, rel=1e-9)
    # explicit center: KL against the center, not the mean
    rho_c = rho_from_windows(counts, center=w1)
    hand_c = sum(p * math.log(p / q) for p, q in zip(w2, w1))
    assert rho_c == pytest.approx(hand_c, rel=1e-9)
    # floor clamps
    assert rho_from_windows(np.stack([w1, w1]), floor=0.25) == 0.25


def test_rho_from_history_batch_matches_scalar():
    rng = np.random.default_rng(0)
    E = rng.dirichlet(np.ones(4), size=3)
    C = rng.integers(1, 500, size=(3, 5, 4))
    rhos = rho_from_history_batch(E, C, floor=0.01)
    assert rhos.shape == (3,)
    for f in range(3):
        mixes = C[f] / C[f].sum(axis=1, keepdims=True)
        want = max(float(kl_np(m, E[f])) for m in mixes)
        assert rhos[f] == pytest.approx(max(want, 0.01), rel=1e-6)


def test_window_history_ring_and_estimators():
    h = WindowHistory(capacity=4)
    for i in range(6):                       # wraps: windows 2..5 survive
        h.append([i, 0, 0, 10])
    assert len(h) == 4 and h.total_windows == 6
    assert np.array_equal(h.counts()[:, 0], [2, 3, 4, 5])
    # sliding window: count-weighted over the last `window` rows
    est = SlidingWindowEstimator(window=2).estimate(h)
    assert est == pytest.approx(np.array([9, 0, 0, 20]) / 29.0)
    # ewma: weights (1-a)^age, renormalized; newest dominates as a -> 1
    near_one = EWMAEstimator(alpha=0.999).estimate(h)
    assert near_one == pytest.approx(np.array([5, 0, 0, 10]) / 15.0,
                                     abs=1e-2)
    # batch append equals row-by-row
    h2 = WindowHistory(capacity=4)
    h2.append(np.array([[i, 0, 0, 10] for i in range(6)]))
    assert np.array_equal(h.counts(), h2.counts())


def test_window_history_empty_and_single_window():
    """An empty history is evidence-free: estimators fall back to uniform
    and the rho sources return exactly their floor."""
    h = WindowHistory(capacity=8)
    assert len(h) == 0 and h.total_windows == 0
    assert h.counts().shape == (0, 4)
    uniform = np.full(4, 0.25)
    assert np.array_equal(h.total_mix(), uniform)
    assert np.array_equal(SlidingWindowEstimator(window=4).estimate(h),
                          uniform)
    assert np.array_equal(EWMAEstimator(alpha=0.5).estimate(h), uniform)
    assert rho_from_windows(h.counts(), floor=0.125) == 0.125
    assert rho_from_windows(h.counts()) == 0.0
    # all-zero counts carry no evidence either
    assert np.array_equal(WindowHistory(capacity=2).total_mix(), uniform)
    assert rho_from_windows(np.zeros((3, 4)), floor=0.125) == 0.125
    # a single window: both estimators return exactly its mix, and the
    # budget against the mean center is zero (clamped to the floor)
    h.append([10, 30, 40, 20])
    one = np.array([0.1, 0.3, 0.4, 0.2])
    assert SlidingWindowEstimator(window=4).estimate(h) \
        == pytest.approx(one)
    assert EWMAEstimator(alpha=0.5).estimate(h) == pytest.approx(one)
    assert rho_from_windows(h.counts(), floor=0.01) == 0.01
    # ...but against an explicit center it is the measured divergence
    center = np.full(4, 0.25)
    assert rho_from_windows(h.counts(), center=center) \
        == pytest.approx(float(kl_np(one, center)))


def test_window_history_capacity_wrap_batches():
    """Batch appends at and beyond capacity keep exactly the newest rows."""
    rows = np.array([[i, 1, 1, 1] for i in range(10)])
    exact = WindowHistory(capacity=5)
    exact.append(rows[:5])                     # batch == capacity
    assert len(exact) == 5 and exact.total_windows == 5
    assert np.array_equal(exact.counts()[:, 0], np.arange(5))
    exact.append(rows[5])                      # next row wraps the ring
    assert len(exact) == 5 and exact.total_windows == 6
    assert np.array_equal(exact.counts()[:, 0], np.arange(1, 6))
    over = WindowHistory(capacity=5)
    over.append(rows)                          # batch > capacity
    assert len(over) == 5 and over.total_windows == 10
    assert np.array_equal(over.counts()[:, 0], np.arange(5, 10))
    # `last` never exceeds the live rows
    assert over.counts(last=99).shape == (5, 4)
    assert np.array_equal(over.counts(last=2)[:, 0], [8, 9])


def test_rho_from_history_batch_edge_shapes():
    E = np.array([[0.25, 0.25, 0.25, 0.25], [0.7, 0.1, 0.1, 0.1]])
    # zero observed windows: no measured drift anywhere, budgets == floor
    empty = rho_from_history_batch(E, np.zeros((2, 0, 4)), floor=0.05)
    assert np.array_equal(empty, np.full(2, 0.05))
    # a single window per tree matches the scalar path
    C = np.array([[[10, 10, 10, 10]], [[70, 10, 10, 10]]], np.float64)
    rhos = rho_from_history_batch(E, C, floor=0.0)
    assert rhos == pytest.approx([0.0, 0.0], abs=1e-7)
    # shape mismatches are loud, not broadcast accidents
    with pytest.raises(ValueError, match="counts"):
        rho_from_history_batch(E, np.zeros((3, 2, 4)))
    with pytest.raises(ValueError, match="counts"):
        rho_from_history_batch(E, np.zeros((2, 4)))


# ---------------------------------------------------------------------------
# Policy triggers
# ---------------------------------------------------------------------------

def test_drift_policy_triggers():
    p = DriftPolicy(kl_threshold=0.1, budget_slack=1.0, min_windows=3,
                    cooldown=2)
    big = 10 ** 9
    assert p.decide(0.5, 1.0, n_windows=2, since_retune=big) is None
    assert p.decide(0.5, 1.0, n_windows=3, since_retune=1) is None  # cooldown
    assert p.decide(0.05, 1.0, n_windows=3, since_retune=big) is None
    assert p.decide(0.5, 1.0, 3, big) == "kl_threshold"
    # budget exhaustion outranks the threshold reason
    assert p.decide(1.5, 1.0, 3, big) == "budget_exhausted"
    # nominal deployments (rho 0) never exhaust a budget
    assert p.decide(1.5, 0.0, 3, big) == "kl_threshold"


# ---------------------------------------------------------------------------
# Engine re-tune + the storm path
# ---------------------------------------------------------------------------

def test_engine_retune_in_place():
    tree, keys = _small_tree(T=4, n=1500)
    probe = keys[::97]
    before = [tree.get(int(k)) for k in probe]
    old_cfg = tree.cfg
    phi = make_phi(8.0, 4.0 * SYS.N, 1.0, SYS)
    tree.retune(phi, SYS)
    assert tree.cfg.T == 8 and tree.cfg is not old_cfg
    assert len(tree.buffer) == 0                    # swapped at flush boundary
    # data survives; structure converges through normal writes
    assert [tree.get(int(k)) for k in probe] == before
    comp_before = tree.stats.comp_pages_written
    tree.put_batch(np.arange(2 ** 21, 2 ** 21 + 600, dtype=np.uint64),
                   np.ones(600, np.int64))
    tree.flush()
    assert tree.stats.comp_pages_written > comp_before  # transition measured
    assert [tree.get(int(k)) for k in probe] == before
    # re-tuning to the identical config is a no-op (no forced flush)
    tree.put(int(probe[0]), 7)
    tree.retune(phi, SYS)
    assert len(tree.buffer) == 1


def test_retune_storm_bit_identical_to_individual_calls():
    from repro.checkpoint import retune_storm
    W = np.array([[0.05, 0.85, 0.05, 0.05],
                  [0.05, 0.05, 0.05, 0.85],
                  [0.25, 0.25, 0.25, 0.25]])
    rhos = [1.0, 0.0, 0.25]
    out = retune_storm(W, rhos, SYS, pad_pow2=True, **SMALL)
    direct = [tune_robust(W[0], rho=1.0, sys=SYS, **SMALL),
              tune_nominal(W[1], SYS, **SMALL),
              tune_robust(W[2], rho=0.25, sys=SYS, **SMALL)]
    for got, want in zip(out, direct):
        assert float(got.phi.T) == float(want.phi.T)
        assert np.array_equal(np.asarray(got.phi.K), np.asarray(want.phi.K))
        assert float(got.phi.mfilt_bits) == float(want.phi.mfilt_bits)
        assert got.cost == want.cost


# ---------------------------------------------------------------------------
# API: rho source, design axis, remote stub, drift end-to-end
# ---------------------------------------------------------------------------

def _api():
    from repro import api
    return api


def test_rho_source_round_trip_and_compile():
    api = _api()
    hist = ((0.01, 0.01, 0.01, 0.97), (0.33, 0.33, 0.33, 0.01))
    spec = api.ExperimentSpec(
        name="rs",
        workload=api.WorkloadSpec(indices=(4,), rhos=(0.5,), nominal=True,
                                  rho_source="from_history", history=hist),
        design=api.DesignSpec(**SMALL), system=SYS_PAIRS)
    back = api.ExperimentSpec.from_json(spec.to_json())
    assert back == spec
    cx = api.compile_spec(back)
    want = float(rho_from_history(np.asarray(hist)))
    assert cx.rhos == (0.5, want)                  # declared + measured
    assert cx.cells == [(0, None), (0, 0.5), (0, want)]
    with pytest.raises(ValueError):
        api.WorkloadSpec(indices=(4,), rho_source="from_history")
    with pytest.raises(ValueError):
        api.WorkloadSpec(indices=(4,), rho_source="sometimes")


@pytest.mark.parametrize("backend", ["inline", "sharded"])
def test_fixed_rho_source_bit_identical(backend):
    """The default 'fixed' source compiles to exactly the pre-field
    behavior on the inline AND sharded backends."""
    api = _api()
    spec = api.ExperimentSpec(
        name="fx",
        workload=api.WorkloadSpec(indices=(7,), rhos=(1.0,), nominal=False,
                                  rho_source="fixed"),
        design=api.DesignSpec(**SMALL), system=SYS_PAIRS, backend=backend)
    report = api.run_experiment(spec)
    want = tune_robust(np.asarray([0.49, 0.01, 0.01, 0.49]), rho=1.0,
                       sys=SYS, **SMALL)
    got = report.tuning((0, 1.0))
    assert float(got.phi.T) == float(want.phi.T)
    assert np.array_equal(np.asarray(got.phi.K), np.asarray(want.phi.K))
    assert got.cost == want.cost


def test_design_space_axis_matches_per_space_specs():
    api = _api()
    arms = (("classic", 8), ("lazy_leveling", 4))
    axis = api.run_experiment(api.ExperimentSpec(
        name="axis",
        workload=api.WorkloadSpec(indices=(7,), nominal=True, bench_n=64),
        design=api.DesignSpec(spaces=arms, **SMALL), system=SYS_PAIRS))
    for space, n_starts in arms:
        solo = api.run_experiment(api.ExperimentSpec(
            name=f"solo_{space}",
            workload=api.WorkloadSpec(indices=(7,), nominal=True,
                                      bench_n=64),
            design=api.DesignSpec(space=space,
                                  **{**SMALL, "n_starts": n_starts}),
            system=SYS_PAIRS))
        a = axis.design_tunings[space][(0, None)]
        b = solo.tuning((0, None))
        assert float(a.phi.T) == float(b.phi.T)
        assert np.array_equal(np.asarray(a.phi.K), np.asarray(b.phi.K))
        assert a.cost == b.cost
        assert np.array_equal(axis.design_bench_costs[space][(0, None)],
                              solo.bench_costs[(0, None)])
    # primary results are untouched by the axis
    assert axis.chosen[(0, None)] == "klsm"
    with pytest.raises(ValueError):
        api.DesignSpec(spaces=(("classic", 8),), fixed=(6.0, 4.0, 1.0))
    with pytest.raises(ValueError):        # report keys are space names
        api.DesignSpec(spaces=(("classic", 8), ("classic", 16)))


def test_remote_backend_is_registered_stub():
    api = _api()
    spec = api.ExperimentSpec(
        name="rb", workload=api.WorkloadSpec(indices=(4,)),
        backend="remote", backend_params=(("scheduler", "slurm"),))
    assert api.ExperimentSpec.from_json(spec.to_json()) == spec
    backend = api.get_backend(spec.backend, spec.backend_params)
    assert backend.name == "remote" and backend.scheduler == "slurm"
    job = backend.serialize_job(spec)
    # v2 envelope: checksummed spec + the retry/timeout policy block
    import json as _json
    env = _json.loads(job)
    assert env["version"] == 2 and env["scheduler"] == "slurm"
    assert set(env["retry"]) == {"max_retries", "backoff_s", "timeout_s",
                                 "seed"}
    spec_back, retry = type(backend).deserialize_job(job)
    assert spec_back == spec and retry["timeout_s"] == 900.0
    env["queue"] = "tampered"
    with pytest.raises(ValueError, match="checksum"):
        type(backend).deserialize_job(_json.dumps(env))
    with pytest.raises(NotImplementedError, match="scheduling stub"):
        api.run_experiment(spec)


def test_drift_experiment_end_to_end():
    """A tiny flip experiment: all arms run paired, the online arm
    re-tunes, and the report serializes in the BENCH schema."""
    api = _api()
    target = (0.33, 0.33, 0.33, 0.01)
    spec = api.ExperimentSpec(
        name="dd",
        workload=api.WorkloadSpec(indices=(4,), nominal=True,
                                  rho_source="from_history",
                                  history=((0.01, 0.01, 0.01, 0.97),
                                           target)),
        design=api.DesignSpec(**SMALL), system=SYS_PAIRS,
        drift=api.DriftSpec(kind="flip", segments=4, n_queries=250,
                            target=target, n_keys=4000, key_space=2 ** 22,
                            window=2, min_windows=1, cooldown=1,
                            retune_starts=4, retune_steps=40))
    report = api.run_experiment(spec)
    arms = {arm for _, arm in report.drift}
    assert arms == {"stale_nominal", "static_robust", "online", "oracle"}
    online = report.drift[(0, "online")]
    assert online.retunes >= 1                      # the flip fires the loop
    assert report.drift[(0, "stale_nominal")].retunes == 0
    for res in report.drift.values():               # paired arms, same load
        assert [r.queries for r in res.records] == [250] * 4
        assert res.avg_io_per_query > 0
    # post-retune the online arm re-centers: drift vs the live expected mix
    # collapses from its post-flip peak
    peak = max(r.kl_est for r in online.records)
    assert online.records[-1].kl_est < 0.5 * peak
    import json
    payload = report.to_bench_payload()
    json.dumps(payload, allow_nan=False)
    names = [r["name"] for r in payload["rows"]]
    assert "dd_drift_w0_online" in names
    # re-tunes solve in the spec's design space, not a hardcoded default
    plan = api.compile_spec(spec).build_drift(report)
    assert plan.design.value == "classic"
    # schedule validation
    with pytest.raises(ValueError):
        api.DriftSpec(kind="gradual", target=None)
    with pytest.raises(ValueError):       # schedule rows must be 4-wide
        api.DriftSpec(kind="schedule", segments=2,
                      schedule=((0.5, 0.3, 0.2), (0.2, 0.3, 0.5)))
    with pytest.raises(ValueError):
        dataclasses.replace(spec, drift=api.DriftSpec(
            kind="flip", target=target, arms=("mystery",)))


def test_online_session_budget_resets_on_apply():
    tree, keys = _small_tree()
    sess = OnlineSession(tree, expected=(0.01, 0.01, 0.01, 0.97), rho=0.3,
                         sys=SYS, mode="online",
                         policy=DriftPolicy(min_windows=1, cooldown=1),
                         estimator=SlidingWindowEstimator(window=4))
    plan = materialize_session(keys, (0.45, 0.45, 0.05, 0.05),
                               n_queries=400, seed=9, key_space=2 ** 20)
    rec = sess.execute_segment(plan, (0.45, 0.45, 0.05, 0.05), 0)
    assert rec.kl_est > 0.3                         # way outside the budget
    req = sess.take_request()
    assert req is not None and req.reason == "budget_exhausted"
    assert sess.take_request() is None              # consumed
    sess.apply(tune_nominal(np.asarray(req.w), SYS, **SMALL), req.w,
               req.rho, req.reason)
    assert sess.rho == req.rho
    rec2 = sess.execute_segment(plan, (0.45, 0.45, 0.05, 0.05), 1)
    assert rec2.retuned and rec2.retune_reason == "budget_exhausted"
    assert rec2.kl_est < rec.kl_est                 # re-centered


# ---------------------------------------------------------------------------
# Perf gate: misconfigured baselines exit 2, not crash / phantom regression
# ---------------------------------------------------------------------------

def _run_py():
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.run import _check_suite
    return _check_suite


def test_check_suite_missing_metrics_is_misconfigured():
    _check_suite = _run_py()
    import benchmarks.run as run_mod
    from repro.api import Row
    n_gated = len(run_mod.CHECK_METRICS["online"])
    rows = [Row("online_fleet", 0.0, engine_s=5.0),
            Row("online_summary", 0.0, online_recovery_min=1.1,
                claim_online_ge_robust_ge_stale=True)]
    # baseline valid JSON but missing the CHECK_METRICS keys -> misconfig
    base = {"wall_time_s": 1.0, "rows": [{"name": "online_fleet",
                                          "derived": {}}]}
    regs, miscfg = _check_suite("online", rows, 1.0, base, tol=1.5)
    assert regs == []
    assert len(miscfg) == n_gated and all("BENCH_online.json" in m
                                          for m in miscfg)
    # structurally-wrong baselines are misconfigured too, never a crash
    assert _check_suite("online", rows, 1.0, [1, 2], tol=1.5)[1]
    assert _check_suite("online", rows, 1.0, {"rows": "nope"}, tol=1.5)[1]
    assert _check_suite("online", rows, 1.0, {"rows": [42]}, tol=1.5)[1]
    # a metric missing from the RUN stays a regression
    base_ok = {"wall_time_s": 1.0, "rows": [
        {"name": "online_fleet", "derived": {"engine_s": 5.0}},
        {"name": "online_summary",
         "derived": {"online_recovery_min": 1.1,
                     "claim_online_ge_robust_ge_stale": True}}]}
    regs, miscfg = _check_suite("online", [rows[0]], 1.0, base_ok, tol=1.5)
    assert miscfg == [] and any("missing (run)" in r for r in regs)
    # and the healthy path still passes clean
    regs, miscfg = _check_suite("online", rows, 1.0, base_ok, tol=1.5)
    assert regs == [] and miscfg == []
