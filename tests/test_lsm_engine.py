"""Tests for the executable LSM engine: KV semantics, compaction shape,
I/O accounting, and agreement with the analytic cost model."""

import math

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import LSMSystem, cost_vector, make_phi
from repro.lsm import (BloomFilter, EngineConfig, LSMTree, populate,
                       run_session)


def _mk(T=4, K=(1,), buf=256, n=20_000, bpe=8.0):
    return LSMTree(EngineConfig(T=T, K=K, buf_entries=buf,
                                expected_entries=n,
                                mfilt_bits_per_entry=bpe))


# ---------------------------------------------------------------------------
# KV correctness
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000), T=st.integers(2, 8),
       kcap=st.integers(1, 6))
def test_kv_roundtrip_property(seed, T, kcap):
    """Whatever is put (newest version) must be returned by get."""
    tree = LSMTree(EngineConfig(T=T, K=(min(kcap, T - 1),) * 8,
                                buf_entries=32, expected_entries=2000))
    rng = np.random.default_rng(seed)
    keys = rng.choice(2 ** 40, size=600, replace=False)
    model = {}
    for i, k in enumerate(keys):
        tree.put(int(k), i)
        model[int(k)] = i
    # overwrite a subset
    for k in keys[::5]:
        tree.put(int(k), -1)
        model[int(k)] = -1
    # delete a subset
    for k in keys[::7]:
        tree.delete(int(k))
        model.pop(int(k), None)
    for k in keys[:200]:
        assert tree.get(int(k)) == model.get(int(k)), int(k)


def test_range_query_matches_brute_force():
    tree = _mk(T=3, K=(2,), buf=64, n=5000)
    rng = np.random.default_rng(3)
    keys = np.sort(rng.choice(100_000, size=3000, replace=False))
    for k in keys:
        tree.put(int(k), int(k) * 2)
    lo, hi = 20_000, 30_000
    got = tree.range_query(lo, hi)
    expect = [(int(k), int(k) * 2) for k in keys if lo <= k < hi]
    assert got == expect


def test_leveling_vs_tiering_run_counts():
    """K_i=1 keeps one run per level at all times; K_i=T-1 accumulates up to
    T-1 runs (sampled during insertion: a single end-state snapshot can land
    exactly on a compaction boundary)."""
    lev = _mk(T=5, K=(1,) * 8, buf=128, n=20_000)
    tier = _mk(T=5, K=(4,) * 8, buf=128, n=20_000)
    rng = np.random.default_rng(0)
    max_tier_runs = 0
    for i, k in enumerate(rng.choice(2 ** 40, size=20_000, replace=False)):
        lev.put(int(k), 0)
        tier.put(int(k), 0)
        if i % 256 == 0:
            assert all(len(runs) == 1 for _, runs in lev.shape())
            max_tier_runs = max(max_tier_runs, *(len(r)
                                                 for _, r in tier.shape()),
                                0)
            assert all(len(runs) <= 4 for _, runs in tier.shape())
    assert max_tier_runs > 1


def test_level_capacities_exponential():
    tree = _mk(T=4, K=(1,) * 8, buf=128, n=30_000)
    rng = np.random.default_rng(1)
    for k in rng.choice(2 ** 40, size=30_000, replace=False):
        tree.put(int(k), 0)
    shape = dict(tree.shape())
    for lvl, runs in shape.items():
        assert sum(runs) <= (4 - 1) * 4 ** (lvl - 1) * 128


# ---------------------------------------------------------------------------
# Bloom filters
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100),
       bpk=st.floats(min_value=4.0, max_value=14.0))
def test_bloom_no_false_negatives_and_fpr(seed, bpk):
    rng = np.random.default_rng(seed)
    keys = rng.choice(2 ** 50, size=4000, replace=False).astype(np.uint64)
    bf = BloomFilter(keys[:2000], bits_per_key=bpk)
    assert bf.might_contain_batch(keys[:2000]).all(), "false negative!"
    fpr = bf.might_contain_batch(keys[2000:]).mean()
    theory = math.exp(-bpk * math.log(2) ** 2)
    assert fpr <= max(4 * theory, 0.02)


# ---------------------------------------------------------------------------
# I/O accounting vs the analytic cost model (Section 9 analogue)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_engine_io_tracks_model_ranking():
    """The model's predicted ordering of tunings by cost must match the
    engine's measured ordering (the paper's 'model matches system' claim,
    Section 9.3).

    We use a dense keyspace with spans that touch every run: the paper notes
    that with *short* ranges, fence pointers let the system skip whole runs,
    making measured I/O lower than predicted (their Figure 12 discrepancy) —
    the same effect exists in this engine and is covered by
    test_short_ranges_cheaper_than_model below."""
    n = 40_000
    key_space = 2 ** 26  # dense: ~1.7k gap between keys
    sys_small = LSMSystem(N=float(n), entry_bits=64 * 8,
                          page_bits=4096 * 8, bits_per_entry=16.0,
                          min_buf_bits=64 * 8 * 128, s_rq=2e-5)
    w_mix = np.array([0.25, 0.25, 0.10, 0.40])

    tunings = [
        ("lev_T4", make_phi(4, 10.0 * n, 1.0, sys_small)),
        ("tier_T4", make_phi(4, 10.0 * n, 3.0, sys_small)),
        ("lev_T10", make_phi(10, 10.0 * n, 1.0, sys_small)),
    ]
    model_costs, engine_costs = [], []
    for name, phi in tunings:
        c = np.asarray(cost_vector(phi, sys_small))
        model_costs.append(float(w_mix @ c))
        tree = LSMTree.from_phi(phi, sys_small, expected_entries=n,
                                entry_bytes=64)
        keys = populate(tree, n, seed=11, key_space=key_space)
        res = run_session(tree, keys, w_mix, n_queries=4000, seed=5,
                          key_space=key_space, range_fraction=1e-3)
        engine_costs.append(res.avg_io_per_query)
    model_rank = np.argsort(model_costs)
    engine_rank = np.argsort(engine_costs)
    assert list(model_rank) == list(engine_rank), (
        f"model {model_costs} vs engine {engine_costs}")


def test_short_ranges_cheaper_than_model():
    """Paper Section 9.3: fence pointers skip non-overlapping runs, so
    measured short-range I/O < model-predicted sum(K_i)."""
    n = 30_000
    sys_small = LSMSystem(N=float(n), entry_bits=64 * 8, page_bits=4096 * 8,
                          bits_per_entry=16.0, min_buf_bits=64 * 8 * 128,
                          s_rq=2e-5)
    phi = make_phi(4, 10.0 * n, 1.0, sys_small)
    tree = LSMTree.from_phi(phi, sys_small, expected_entries=n,
                            entry_bytes=64)
    keys = populate(tree, n, seed=3)  # sparse 2**48 keyspace
    res = run_session(tree, keys, np.array([0.01, 0.01, 0.97, 0.01]),
                      n_queries=800, seed=9, range_fraction=2e-7)
    model_q = float(np.asarray(cost_vector(phi, sys_small))[2])
    assert res.avg_io_per_query < model_q


def test_empty_queries_cheaper_than_nonempty():
    """Bloom filters make empty lookups nearly free (Z0 << Z1)."""
    tree = _mk(T=4, K=(1,) * 8, buf=256, n=30_000, bpe=10.0)
    keys = populate(tree, 30_000, seed=2)
    r_z0 = run_session(tree, keys, np.array([0.97, 0.01, 0.01, 0.01]),
                       n_queries=1500, seed=3)
    r_z1 = run_session(tree, keys, np.array([0.01, 0.97, 0.01, 0.01]),
                       n_queries=1500, seed=4)
    assert r_z0.avg_io_per_query < r_z1.avg_io_per_query
    assert r_z1.avg_io_per_query >= 0.9  # a hit costs ~1 page I/O


# ---------------------------------------------------------------------------
# Intern-table reclamation
# ---------------------------------------------------------------------------

def test_intern_table_bounded_under_churn():
    """A churn workload overwriting object values must not grow the codec's
    intern table without bound: compaction-time sweeps remap live slots and
    drop dead ones (the engine's doubling-threshold trigger keeps the table
    within ~2x the live object count)."""
    tree = _mk(T=4, K=(1,), buf=64, n=4000)
    keys = list(range(150))
    rounds = 50
    for round_ in range(rounds):
        for k in keys:
            tree.put(k, f"v{round_}_{k}")
    tree.flush()
    table = len(tree.store.codec.objects)
    assert table <= max(64, 4 * len(keys)), (
        f"intern table grew to {table} after {rounds * len(keys)} object "
        "writes over 150 live keys")
    # the sweep remapped, not clobbered: newest version of every key decodes
    for k in (0, 73, 149):
        assert tree.get(k) == f"v{rounds - 1}_{k}"


def test_intern_reclaim_preserves_tombstones_and_ints():
    """The sweep must leave inline ints and TOMB encodings untouched and
    keep deletes dead."""
    tree = _mk(T=3, K=(1,), buf=32, n=2000)
    for i in range(64):
        tree.put(i, i * 10)                 # inline ints: never interned
    for round_ in range(20):
        for i in range(64, 96):
            tree.put(i, f"obj{round_}_{i}")  # churning interned objects
    for i in range(0, 64, 2):
        tree.delete(i)
    tree.flush()
    dropped = tree.store.reclaim_interned()  # force a final sweep
    assert dropped >= 0
    assert len(tree.store.codec.objects) <= 96
    for i in range(0, 64, 2):
        assert tree.get(i) is None           # deletes stay dead
    for i in range(1, 64, 2):
        assert tree.get(i) == i * 10         # ints untouched
    for i in range(64, 96):
        assert tree.get(i) == f"obj19_{i}"   # newest objects survive remap
