"""Doc-link lint: every path the documentation points at must exist.

Two classes of reference are checked across ``README.md`` and every page
under ``docs/``:

* relative markdown links — ``[text](docs/online.md)``, ``[x](../README.md)``
  — resolved against the file that contains them (external ``http(s)://`` /
  ``mailto:`` targets and pure ``#anchor`` links are skipped);
* repo-path mentions — any ``src/...``, ``benchmarks/...``, ``tests/...`` or
  ``docs/...`` token in the prose or code spans.  Tokens containing ``*``
  are treated as globs and must match at least one file.

Runs under the tier-1 suite (so CI enforces it) and directly as a script::

    python tests/test_doc_links.py
"""

import glob
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: [text](target) — target captured up to the closing paren or an anchor.
_MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
#: repo paths mentioned in prose/code spans (globs allowed via ``*``).
_REPO_PATH = re.compile(
    r"(?:src|benchmarks|tests|docs)/[A-Za-z0-9_.\-/*]+")
_EXTERNAL = ("http://", "https://", "mailto:")


def doc_files():
    files = [os.path.join(ROOT, "README.md")]
    files += sorted(glob.glob(os.path.join(ROOT, "docs", "*.md")))
    return [f for f in files if os.path.exists(f)]


def _broken_in(path):
    """Yield (kind, target) for every dangling reference in one file."""
    text = open(path, encoding="utf-8").read()
    base = os.path.dirname(path)
    seen = set()
    for m in _MD_LINK.finditer(text):
        target = m.group(1).split("#", 1)[0]
        if not target or target.startswith(_EXTERNAL) or target in seen:
            continue
        seen.add(target)
        if not os.path.exists(os.path.normpath(os.path.join(base, target))):
            yield "link", target
    for token in _REPO_PATH.findall(text):
        token = token.rstrip(".,:;")        # sentence punctuation, ellipses
        if not token or token in seen:
            continue
        seen.add(token)
        full = os.path.join(ROOT, token)
        if "*" in token:
            if not glob.glob(full):
                yield "glob", token
        elif not os.path.exists(full):
            yield "path", token


def lint():
    """Return human-readable problem lines (empty list == clean)."""
    problems = []
    files = doc_files()
    for f in files:
        rel = os.path.relpath(f, ROOT)
        problems.extend(f"{rel}: dangling {kind} -> {target}"
                        for kind, target in _broken_in(f))
    return files, problems


def test_docs_exist():
    files, _ = lint()
    names = {os.path.relpath(f, ROOT) for f in files}
    assert "README.md" in names, "repo front door README.md is missing"
    assert "docs/memory.md" in names
    assert len([n for n in names if n.startswith("docs/")]) >= 6


def test_no_dangling_doc_references():
    _, problems = lint()
    assert not problems, "\n".join(problems)


def test_lint_catches_planted_breakage(tmp_path):
    bad = tmp_path / "bad.md"
    bad.write_text("see [x](no/such.md), `src/repro/missing_mod.py`, "
                   "and benchmarks/bench_none_*.py\n")
    found = dict(_broken_in(str(bad)))
    assert found == {"link": "no/such.md",
                     "path": "src/repro/missing_mod.py",
                     "glob": "benchmarks/bench_none_*.py"}


if __name__ == "__main__":
    files, problems = lint()
    for p in problems:
        print(p)
    print(f"checked {len(files)} files: "
          f"{'FAIL' if problems else 'ok'}")
    sys.exit(1 if problems else 0)
