"""Telemetry plane tests: schema stability, zero-perturbation, jax-free.

Three contracts from the observability design:

* **golden event sequences** — a seeded engine run under the deterministic
  ``ticks`` clock emits a reproducible event-name sequence (run-twice
  equality), so telemetry is diffable across commits;
* **identity** — enabled-vs-disabled engine results are bit-identical
  (the instrumentation only *reads* IOStats, never steers);
* **isolation** — ``import repro.obs`` pulls in neither jax nor numpy, so
  subprocess workers (the fault-injection sandbox) can import it freely.

Every test runs under a save/restore fixture so the suite behaves the same
with and without ``REPRO_OBS=1`` in the environment (CI runs both legs).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro import obs
from repro.obs import calibrate as cal
from repro.obs.core import DEFAULT_CAPACITY
from repro.obs.trace import chrome_trace, write_trace

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(autouse=True)
def _isolated_obs():
    """Save/restore the process-global telemetry object around every test
    (REPRO_OBS=1 installs one at import; tests must not clobber it)."""
    prev = obs.get()
    obs.disable()
    yield
    obs.core._T = prev


# -- core: ring, counters, spans -------------------------------------------

def test_disabled_is_noop():
    assert not obs.enabled()
    with obs.span("x", a=1) as sp:
        assert not sp                      # NULL_SPAN is falsy
        sp.set(b=2)                        # and absorbs attributes
    obs.count("c")
    obs.gauge("g", 3.5)
    obs.event("e")
    assert obs.events_snapshot() == []
    assert obs.metrics_snapshot() == {}


def test_counters_events_and_spans_record():
    obs.configure(enabled=True, clock="ticks")
    obs.count("hits")
    obs.count("hits", 2)
    obs.gauge("depth", 4)
    obs.event("boom", where="here")
    with obs.span("outer", a=1) as sp:
        sp.set(b=2)
        with obs.span("inner"):
            pass
    snap = obs.metrics_snapshot()
    assert snap["counters"] == {"hits": 3}
    assert snap["gauges"] == {"depth": 4}
    events = obs.events_snapshot()
    names = [e["name"] for e in events]
    assert names == ["boom", "inner", "outer"]     # spans emit on exit
    outer = events[-1]
    assert outer["kind"] == "span"
    assert outer["attrs"] == {"a": 1, "b": 2}
    inner = events[1]
    assert inner["parent"] == outer["sid"]         # nesting is recorded


def test_ring_capacity_and_dropped():
    obs.configure(enabled=True, capacity=8, clock="ticks")
    for i in range(20):
        obs.event("e", i=i)
    events = obs.events_snapshot()
    assert len(events) == 8
    assert [e["attrs"]["i"] for e in events] == list(range(12, 20))
    assert obs.metrics_snapshot()["events_dropped"] == 12
    assert obs.metrics_snapshot()["events_total"] == 20


def test_track_labels_events():
    obs.configure(enabled=True, clock="ticks")
    with obs.track("w0/klsm"):
        obs.event("inside")
    obs.event("outside")
    ev = obs.events_snapshot()
    assert ev[0]["track"] == "w0/klsm"
    assert ev[1]["track"] == ""


def test_scoped_restores_previous():
    obs.configure(enabled=True, clock="ticks")
    obs.count("before")
    with obs.scoped(enabled=True, clock="ticks"):
        obs.count("inside")
        assert obs.metrics_snapshot()["counters"] == {"inside": 1}
    assert obs.metrics_snapshot()["counters"] == {"before": 1}


def test_jsonl_sink_streams(tmp_path):
    path = str(tmp_path / "events.jsonl")
    obs.configure(enabled=True, clock="ticks", jsonl_path=path)
    obs.event("a", n=1)
    with obs.span("s"):
        pass
    obs.get().close()
    lines = [json.loads(l) for l in open(path)]
    assert [l["name"] for l in lines] == ["a", "s"]
    assert lines[1]["kind"] == "span"


def test_configure_defaults():
    t = obs.configure(enabled=True)
    assert t.capacity == DEFAULT_CAPACITY and t.clock == "wall"
    with pytest.raises(ValueError):
        obs.configure(enabled=True, clock="sundial")


# -- golden event sequences -------------------------------------------------

def _tiny_engine_run():
    """A seeded single-tree workload; returns (event names, results)."""
    from repro.api import (DesignSpec, ExperimentSpec, TrialSpec,
                           WorkloadSpec, run_experiment)
    spec = ExperimentSpec(
        name="obs_golden",
        workload=WorkloadSpec(workloads=((0.25, 0.25, 0.25, 0.25),),
                              rhos=(), nominal=True),
        design=DesignSpec(fixed=(4.0, 4.0, 1.0), policies=("klsm",)),
        trial=TrialSpec(n_keys=4_000, n_queries=400,
                        sessions=((0.4, 0.2, 0.2, 0.2),),
                        key_space=2 ** 20, key_seed=7,
                        session_seeds=(11,)),
        system=(("N", 4000.0), ("entry_bits", 512.0),
                ("page_bits", 4096.0 * 8), ("bits_per_entry", 6.0),
                ("min_buf_bits", 512.0 * 64), ("s_rq", 1e-3),
                ("max_T", 30.0)),
    )
    report = run_experiment(spec)
    res = report.fleet[((0, None), "klsm")]
    return report, res


def test_golden_event_sequence_reproducible():
    with obs.scoped(enabled=True, clock="ticks"):
        _tiny_engine_run()
        first = [(e["kind"], e["name"], e["track"])
                 for e in obs.events_snapshot()]
        snap1 = obs.metrics_snapshot()
    with obs.scoped(enabled=True, clock="ticks"):
        _tiny_engine_run()
        second = [(e["kind"], e["name"], e["track"])
                  for e in obs.events_snapshot()]
        snap2 = obs.metrics_snapshot()
    assert first == second
    assert snap1["counters"] == snap2["counters"]
    assert first, "instrumented engine emitted no events"
    names = {n for _, n, _ in first}
    assert "session.execute" in names
    assert "trial.populate" in names
    assert any(n.startswith("engine.") for n in snap1["counters"])
    assert any(n.startswith("kernel.dispatch.") for n in snap1["counters"])
    # the fleet convention: track labels end with /<policy>
    assert any(t.endswith("/klsm") for _, _, t in first)


def test_enabled_vs_disabled_bit_identical():
    with obs.scoped(enabled=False):
        _, res_off = _tiny_engine_run()
    with obs.scoped(enabled=True, clock="ticks"):
        _, res_on = _tiny_engine_run()
    assert len(res_on) == len(res_off) == 1
    assert res_on[0].avg_io_per_query == res_off[0].avg_io_per_query
    assert np.array_equal(res_on[0].window_ops, res_off[0].window_ops)
    assert np.array_equal(res_on[0].observed_mix, res_off[0].observed_mix)


def test_session_span_carries_calibration_attrs():
    with obs.scoped(enabled=True, clock="ticks"):
        _tiny_engine_run()
        spans = [e for e in obs.events_snapshot()
                 if e["kind"] == "span" and e["name"] == "session.execute"]
    assert len(spans) == 1
    attrs = spans[0]["attrs"]
    assert len(attrs["mix"]) == 4
    assert attrs["avg_io"] > 0
    assert attrs["queries"] == 400
    assert sum(attrs["io"]["queries"].values()) == 400


# -- jax-free import (subprocess workers) -----------------------------------

def test_obs_import_is_jax_and_numpy_free():
    code = ("import sys, repro.obs, repro.obs.trace\n"
            "assert 'jax' not in sys.modules, 'obs pulled in jax'\n"
            "assert 'numpy' not in sys.modules, 'obs pulled in numpy'\n"
            "print('clean')\n")
    env = dict(os.environ, PYTHONPATH=os.path.abspath(SRC))
    env.pop("REPRO_OBS", None)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True)
    assert out.returncode == 0, out.stderr
    assert out.stdout.strip() == "clean"


# -- chrome trace export ----------------------------------------------------

def test_chrome_trace_schema(tmp_path):
    obs.configure(enabled=True, clock="ticks")
    with obs.track("w0/klsm"):
        with obs.span("engine.flush", entries=5):
            obs.event("drift.decide", kl=0.1)
    obs.count("engine.flush")
    doc = chrome_trace(obs.events_snapshot(), clock="ticks",
                       counters=obs.metrics_snapshot()["counters"])
    assert doc["displayTimeUnit"] == "ms"
    phases = [e["ph"] for e in doc["traceEvents"]]
    assert "X" in phases and "i" in phases and "M" in phases
    assert "C" in phases                       # terminal counter samples
    x = next(e for e in doc["traceEvents"] if e["ph"] == "X")
    assert x["name"] == "engine.flush" and x["dur"] >= 0
    # one thread per track: metadata names the w0/klsm lane
    meta = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert any(e["args"].get("name") == "w0/klsm" for e in meta)

    path = str(tmp_path / "trace.json")
    n = write_trace(path)
    assert n == len(obs.events_snapshot())
    on_disk = json.load(open(path))
    assert on_disk["traceEvents"]


def test_write_trace_disabled_writes_empty_doc(tmp_path):
    path = str(tmp_path / "trace.json")
    assert write_trace(path) == 0
    assert json.load(open(path))["traceEvents"] == []


# -- calibration ------------------------------------------------------------

def _synthetic_events(c, n=6, seed=0):
    rng = np.random.default_rng(seed)
    events = []
    eye = np.eye(4) * 0.85 + 0.05
    for i in range(n):
        mix = eye[i % 4] / eye[i % 4].sum() if i < 4 else \
            rng.dirichlet((1.0,) * 4)
        events.append({
            "seq": i, "kind": "span", "name": "session.execute",
            "ts": float(i), "track": "w0/klsm", "dur": 1.0,
            "sid": i + 1, "parent": 0,
            "attrs": {"mix": [float(x) for x in mix],
                      "avg_io": float(mix @ c), "queries": 100},
        })
    return events


def test_calibration_recovers_true_weights():
    c_true = np.array([1.5, 0.4, 2.0, 3.0])
    c_hand = c_true * np.array([1.3, 0.7, 1.1, 0.9])   # the "hand" model
    events = _synthetic_events(c_true)
    payload = cal.calibrate(events, model_costs={"klsm": c_hand})
    fit = payload["policies"]["klsm"]
    assert payload["all_fitted_ge_hand"]
    assert fit["closeness_fitted"] >= fit["closeness_hand"]
    np.testing.assert_allclose(fit["c_fitted"], c_true, rtol=1e-4)
    # alpha is the hand constants' measured correction
    np.testing.assert_allclose(fit["alpha"],
                               c_true / c_hand, rtol=1e-4)


def test_calibration_artifact_roundtrip(tmp_path):
    from repro.faults import checksum_ok
    c = np.array([1.0, 0.5, 2.0, 3.0])
    payload = cal.calibrate(_synthetic_events(c), model_costs={"klsm": c})
    path = str(tmp_path / "calibration.json")
    cal.write_calibration(path, payload)
    on_disk = json.load(open(path))
    assert checksum_ok(on_disk)
    assert on_disk["schema"] == cal.SCHEMA
    assert "klsm" in on_disk["policies"]


def test_calibration_skips_unseen_policies():
    c = np.array([1.0, 0.5, 2.0, 3.0])
    payload = cal.calibrate(_synthetic_events(c),
                            model_costs={"klsm": c, "partial": c})
    assert set(payload["policies"]) == {"klsm"}   # no partial samples


# -- shard attempt surfacing (satellite bugfix) -----------------------------

def test_flapping_shard_attempts_surface_in_report():
    """A shard that crashes once and recovers used to vanish from the
    report (per-attempt latencies were dropped on success); now every
    attempt is logged, the walls carry the count, rows() renders the
    flapping-shard summary, and telemetry sees the fault."""
    from repro.api import (DesignSpec, ExperimentSpec, FaultSpec,
                           TrialSpec, WorkloadSpec, run_experiment)
    spec = ExperimentSpec(
        name="flap",
        workload=WorkloadSpec(indices=(7, 11), rhos=(), nominal=True,
                              bench_n=0),
        design=DesignSpec(fixed=(6.0, 4.0, 1.0)),
        trial=TrialSpec(n_keys=4000, n_queries=300,
                        sessions=((0.05, 0.85, 0.05, 0.05),)),
        system=(("N", 8000.0), ("bits_per_entry", 6.0), ("max_T", 20.0)),
        backend="subprocess",
        backend_params=(("workers", 2), ("max_retries", 2),
                        ("backoff_s", 0.01), ("timeout_s", 120.0)),
        faults=(FaultSpec(kind="crash", shards=(0,), max_hits=1, seed=3),),
    )
    with obs.scoped(enabled=True, clock="ticks"):
        report = run_experiment(spec)
        counters = obs.metrics_snapshot()["counters"]
        names = {e["name"] for e in obs.events_snapshot()}
    log = report.shard_attempts
    assert log, "per-attempt log missing from Report"
    assert report.walls["shard_attempt_count"] == len(log)
    shard0 = [a for a in log if a["shard"] == 0]
    assert [a["ok"] for a in shard0] == [False, True]     # flapped
    assert all(a["latency_s"] >= 0 for a in log)
    row = next(r for r in report.rows() if r.name.endswith("_shards"))
    assert row.derived["flapping_shards"] == [0]
    assert row.derived["failed_attempts"] == 1
    assert row.derived["attempts"] == len(log)
    assert counters["shard.failed_attempts"] == 1
    assert counters["shard.attempts"] == len(log)
    assert "shard.fault_injected" in names
    assert "shard.attempt" in names


# -- CUSUM detector (satellite) ---------------------------------------------

def test_cusum_fires_in_session_and_is_observable():
    """With the KL triggers parked out of reach, sustained drift fires the
    CUSUM change-point path — and every per-segment decision lands in the
    telemetry ring as a ``drift.decide`` event naming the detector."""
    from repro.core import LSMSystem
    from repro.lsm import EngineConfig, LSMTree, materialize_session, \
        populate
    from repro.online import CusumDetector, DriftPolicy, OnlineSession
    sys_ = LSMSystem().replace(N=1500.0, entry_bits=512.0,
                               bits_per_entry=6.0)
    tree = LSMTree(EngineConfig(T=4, buf_entries=64,
                                mfilt_bits_per_entry=6.0,
                                expected_entries=1500))
    keys = populate(tree, 1500, seed=11, key_space=2 ** 20)
    policy = DriftPolicy(kl_threshold=99.0, budget_slack=1e9,
                         min_windows=1, cooldown=1,
                         detector="cusum", cusum_k=0.0, cusum_h=0.05)
    assert isinstance(policy.make_detector(), CusumDetector)
    expected = (0.01, 0.01, 0.01, 0.97)
    sess = OnlineSession(tree, expected=expected, rho=0.0, sys=sys_,
                         mode="online", policy=policy)
    matched = materialize_session(keys, expected, n_queries=300, seed=1,
                                  key_space=2 ** 20)
    drifted = materialize_session(keys, (0.4, 0.4, 0.1, 0.1),
                                  n_queries=300, seed=2, key_space=2 ** 20)
    with obs.scoped(enabled=True, clock="ticks"):
        for s in range(2):
            sess.execute_segment(matched, expected, s)
        assert sess.take_request() is None
        reasons = []
        for s in range(2, 5):
            sess.execute_segment(drifted, (0.4, 0.4, 0.1, 0.1), s)
            req = sess.take_request()
            if req is not None:
                reasons.append(req.reason)
        decides = [e for e in obs.events_snapshot()
                   if e["name"] == "drift.decide"]
        counters = obs.metrics_snapshot()["counters"]
    assert "change_point" in reasons
    assert len(decides) == 5                     # one per segment
    assert all(e["attrs"]["detector"] == "cusum" for e in decides)
    assert any(e["attrs"]["reason"] == "change_point" for e in decides)
    assert counters["drift.trigger.change_point"] >= 1


def test_cusum_detector_alarm_and_reset():
    from repro.online import CusumDetector
    det = CusumDetector(k=0.05, h=0.2)
    det.reset()
    assert not any(det.update(0.04) for _ in range(50))   # under drift slack
    det.reset()
    fired = [det.update(0.15) for _ in range(5)]
    assert fired[-1] and not fired[0]                     # accumulates
    det.reset()
    assert det.s == 0.0


def test_drift_spec_accepts_cusum():
    from repro.api.spec import DriftSpec
    from repro.online import CusumDetector
    from repro.online.retune import DriftPolicy
    target = (0.1, 0.1, 0.1, 0.7)
    d = DriftSpec(target=target, detector="cusum", cusum_k=0.02,
                  cusum_h=0.1)
    pol = DriftPolicy(detector="cusum", cusum_k=d.cusum_k,
                      cusum_h=d.cusum_h)
    det = pol.make_detector()
    assert isinstance(det, CusumDetector)
    assert det.k == 0.02 and det.h == 0.1
    with pytest.raises(ValueError, match="cusum"):
        DriftSpec(target=target, detector="mahalanobis")
