"""Tests for the batched tuning engine (core/batch.py) and the warm-started
robust dual solve (core/robust.py: dual_solve_cold / dual_solve_warm).

The batched API must reproduce the sequential tuners seed-for-seed: same
costs, identical integral Phi for CLASSIC (where both LEVELING/TIERING
branches are folded onto one batch axis).  The warm-started dual must keep
the ~zero primal-dual gap (Lemma 1) that the cold grid solve has.

Deliberately hypothesis-free (the module must collect in minimal envs);
solver sizes are small so the whole file compiles + runs in ~a minute on CPU.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (EXPECTED_WORKLOADS, DesignSpace, LSMSystem,
                        cost_vector, dual_solve_cold, dual_solve_warm,
                        make_phi, robust_cost, to_phi, to_phi_policy,
                        tune_nominal, tune_nominal_many, tune_robust,
                        tune_robust_many, worst_case_workload)

SYS = LSMSystem()
SMALL = dict(n_starts=8, steps=60, seed=3)
RHOS = (0.25, 1.0, 3.0)
WS = EXPECTED_WORKLOADS[[1, 7, 11]]


def _assert_same_phi(a, b):
    assert float(a.phi.T) == float(b.phi.T)
    assert np.allclose(np.asarray(a.phi.K), np.asarray(b.phi.K))
    assert float(a.phi.mfilt_bits) == pytest.approx(
        float(b.phi.mfilt_bits), rel=1e-6)


# ---------------------------------------------------------------------------
# Batched vs sequential tuners
# ---------------------------------------------------------------------------

def test_nominal_many_matches_sequential_classic():
    batched = tune_nominal_many(WS, SYS, **SMALL)
    for k, w in enumerate(WS):
        seq = tune_nominal(w, SYS, **SMALL)
        assert batched[k].cost == pytest.approx(seq.cost, rel=1e-4)
        assert batched[k].design is seq.design
        _assert_same_phi(batched[k], seq)


def test_nominal_many_matches_sequential_fluid():
    batched = tune_nominal_many(WS[:2], SYS, DesignSpace.FLUID, **SMALL)
    for k, w in enumerate(WS[:2]):
        seq = tune_nominal(w, SYS, DesignSpace.FLUID, **SMALL)
        assert batched[k].cost == pytest.approx(seq.cost, rel=1e-4)
        _assert_same_phi(batched[k], seq)


def test_robust_many_matches_sequential_grid():
    W2 = WS[1:]
    batched = tune_robust_many(W2, RHOS, SYS, **SMALL)
    for i, w in enumerate(W2):
        for j, rho in enumerate(RHOS):
            seq = tune_robust(w, rho, SYS, **SMALL)
            assert batched[i][j].cost == pytest.approx(seq.cost, rel=1e-4)
            assert batched[i][j].design is seq.design
            _assert_same_phi(batched[i][j], seq)


def test_robust_zero_rho_matches_nominal_batched():
    rn = tune_nominal_many([WS[2]], SYS, **SMALL)[0]
    rr = tune_robust_many([WS[2]], [0.0], SYS, **SMALL)[0][0]
    assert rr.cost == pytest.approx(rn.cost, rel=1e-4)


# ---------------------------------------------------------------------------
# Fused cost_vector (the hot path under every tuner lane) == components
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("smooth", [False, True])
def test_cost_vector_fused_matches_components(smooth):
    from repro.core.lsm_cost import (empty_read_cost, nonempty_read_cost,
                                     range_cost, write_cost)
    rng = np.random.default_rng(0)
    for _ in range(20):
        T = float(rng.uniform(2.0, 90.0))
        h = float(rng.uniform(0.0, 9.9))
        K = float(rng.uniform(1.0, T))
        phi = make_phi(T, h * SYS.N, K, SYS)
        fused = np.asarray(cost_vector(phi, SYS, smooth=smooth))
        parts = np.asarray([
            empty_read_cost(phi, SYS, smooth=smooth),
            nonempty_read_cost(phi, SYS, smooth=smooth),
            range_cost(phi, SYS, smooth=smooth),
            write_cost(phi, SYS, smooth=smooth)])
        np.testing.assert_allclose(fused, parts, rtol=1e-6, atol=0.0)


# ---------------------------------------------------------------------------
# CLASSIC fold: the policy-axis to_phi
# ---------------------------------------------------------------------------

def test_to_phi_policy_reproduces_classic_branches():
    rng = np.random.default_rng(0)
    for _ in range(10):
        theta = jnp.asarray(rng.uniform(-3, 3, 2), jnp.float32)
        lev = to_phi(theta, DesignSpace.LEVELING, SYS)
        tier = to_phi(theta, DesignSpace.TIERING, SYS)
        lev_p = to_phi_policy(theta, jnp.asarray(0.0, jnp.float32), SYS)
        tier_p = to_phi_policy(theta, jnp.asarray(1.0, jnp.float32), SYS)
        for a, b in ((lev, lev_p), (tier, tier_p)):
            assert float(a.T) == pytest.approx(float(b.T), rel=1e-6)
            assert float(a.mfilt_bits) == pytest.approx(float(b.mfilt_bits),
                                                        rel=1e-6)
            assert np.allclose(np.asarray(a.K), np.asarray(b.K))


# ---------------------------------------------------------------------------
# Warm-started dual: primal-dual gap stays ~zero along a trajectory
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rho", RHOS)
def test_warm_dual_gap_near_zero(rho):
    w = jnp.asarray(EXPECTED_WORKLOADS[7], jnp.float32)
    phi = make_phi(8.0, 0.8 * SYS.m_total_bits, 1.0, SYS)
    c = np.asarray(cost_vector(phi, SYS), np.float32)
    _, llam = dual_solve_cold(jnp.asarray(c), w, rho)
    rng = np.random.default_rng(int(rho * 10))
    for _ in range(25):
        # small multiplicative drift, like successive Adam iterates
        c = c * (1.0 + rng.normal(0.0, 0.01, 4)).astype(np.float32)
        val, llam = dual_solve_warm(jnp.asarray(c), w, rho, llam)
        w_hat = worst_case_workload(jnp.asarray(c), w, rho)
        primal = float(jnp.dot(w_hat, jnp.asarray(c)))
        assert float(val) == pytest.approx(primal, rel=2e-3, abs=1e-4)
        # and it agrees with the exact cold-grid solve
        cold = float(robust_cost(jnp.asarray(c), w, rho))
        assert float(val) == pytest.approx(cold, rel=2e-3, abs=1e-4)


def test_warm_dual_rho_zero_is_nominal():
    w = jnp.asarray(EXPECTED_WORKLOADS[7], jnp.float32)
    c = jnp.asarray([1.0, 3.0, 2.0, 7.0], jnp.float32)
    _, llam = dual_solve_cold(c, w, 0.0)
    for _ in range(5):
        val, llam = dual_solve_warm(c, w, 0.0, llam)
    assert float(val) == pytest.approx(float(jnp.dot(w, c)), rel=1e-5)
    assert np.isfinite(float(llam))


def test_warm_dual_recovers_from_bad_carry():
    """Even a badly off-center carry re-locks within a few warm steps
    (the window re-centers by half_width per step)."""
    w = jnp.asarray(EXPECTED_WORKLOADS[7], jnp.float32)
    c = jnp.asarray([1.0, 3.0, 2.0, 7.0], jnp.float32)
    rho = 1.0
    exact = float(robust_cost(c, w, rho))
    _, llam_good = dual_solve_cold(c, w, rho)
    llam = llam_good + 6.0  # six nats off
    for _ in range(12):
        val, llam = dual_solve_warm(c, w, rho, llam)
    assert float(val) == pytest.approx(exact, rel=2e-3)
