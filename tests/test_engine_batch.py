"""Batched LSM-engine paths (put_batch / point_query_batch / populate) must
be observationally identical to the per-key paths: same tree shape, same
values, same I/O accounting.  Hypothesis-free companion to test_lsm_engine."""

import dataclasses

import numpy as np

from repro.lsm import LSMTree, populate
from repro.lsm.bloom import splitmix64, splitmix64_scalar
from repro.lsm.engine import EngineConfig, IOStats

CFG = EngineConfig(T=4, K=(3, 3, 1), buf_entries=128,
                   expected_entries=4_000)
KEY_SPACE = 2 ** 24


def _per_key_populate(tree, n, seed):
    rng = np.random.default_rng(seed)
    keys = rng.choice(KEY_SPACE, size=n, replace=False).astype(np.uint64)
    for k in keys:
        tree.put(int(k), int(k) % 997)
    tree.flush()
    tree.stats = IOStats()
    return keys


def test_splitmix_scalar_matches_vector():
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 2 ** 63, size=64).astype(np.uint64)
    for seed in (1, 2, 7):
        vec = splitmix64(keys, np.uint64(seed))
        for k, v in zip(keys, vec):
            assert splitmix64_scalar(int(k), seed) == int(v)


def test_populate_matches_per_key_puts():
    a, b = LSMTree(CFG), LSMTree(CFG)
    keys_a = _per_key_populate(a, 4_000, seed=7)
    keys_b = populate(b, 4_000, seed=7, key_space=KEY_SPACE)
    assert np.array_equal(keys_a, keys_b)
    assert a.shape() == b.shape()
    assert a.num_entries == b.num_entries
    # spot-check values survived identically
    for k in keys_a[::397]:
        assert a.get(int(k)) == b.get(int(k)) == int(k) % 997


def test_point_query_batch_matches_sequential():
    tree = LSMTree(CFG)
    keys = populate(tree, 4_000, seed=3, key_space=KEY_SPACE)
    rng = np.random.default_rng(1)
    misses = rng.integers(0, KEY_SPACE, 200).astype(np.uint64) \
        | np.uint64(1 << 30)
    q = np.concatenate([keys[:200], misses])
    rng.shuffle(q)

    tree.stats = IOStats()
    batch_res = tree.point_query_batch(q)
    batch_stats = tree.stats.snapshot()

    tree.stats = IOStats()
    seq_res = [tree.point_query(int(k)) for k in q]
    seq_stats = tree.stats

    assert batch_res == seq_res
    assert dataclasses.asdict(batch_stats) == dataclasses.asdict(seq_stats)


def test_point_query_batch_respects_tombstones_and_buffer():
    tree = LSMTree(CFG)
    keys = populate(tree, 1_000, seed=5, key_space=KEY_SPACE)
    dead = int(keys[10])
    tree.delete(dead)
    tree.put(123456789, "fresh")          # lives in the write buffer
    res = tree.point_query_batch([dead, 123456789, int(keys[20])])
    assert res[0] is None
    assert res[1] == "fresh"
    assert res[2] == int(keys[20]) % 997


def test_put_batch_duplicate_keys_newest_wins():
    tree = LSMTree(EngineConfig(T=3, buf_entries=16, expected_entries=256))
    keys = np.array([5, 9, 5, 7, 9, 5], np.uint64)
    tree.put_batch(keys, ["a", "b", "c", "d", "e", "f"])
    assert tree.get(5) == "f"
    assert tree.get(9) == "e"
    assert tree.get(7) == "d"
    assert tree.stats.queries["w"] == len(keys)
