"""Per-kernel validation: shape/dtype sweeps in interpret mode against the
pure-jnp oracles (+ hypothesis property tests).

Kernels construct their CompilerParams through ``repro.kernels._compat``
(which resolves ``pltpu.CompilerParams`` vs the older
``pltpu.TPUCompilerParams`` spelling, or returns None on builds without
the TPU backend), so this module runs everywhere: the interpret leg
(``interpret=True``, exercised below) works on any backend, and the
compiled leg is auto-selected by each ``ops.py`` wrapper when the
default backend is an actual TPU.
"""

import pytest

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.bloom_probe.kernel import bloom_probe_kernel
from repro.kernels.bloom_probe.ref import build_plane, probe_ref
from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.dual_solve.ops import (dual_solve_warm,
                                          dual_solve_warm_batch)
from repro.kernels.merge.ops import merge_runs_arrays
from repro.kernels.point_read.ops import point_read_level_arrays
from repro.kernels.rwkv6.kernel import rwkv6_kernel
from repro.kernels.rwkv6.ops import rwkv6_chunked
from repro.kernels.rwkv6.ref import wkv_ref
from repro.lsm.merge_path import merge_runs_numpy
from repro.lsm.read_path import point_read_level_numpy
from repro.lsm.store import TOMB, LevelStore, RunData


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,d,causal,window,dtype", [
    (128, 64, True, None, jnp.float32),
    (256, 64, False, None, jnp.float32),
    (256, 128, True, None, jnp.float32),
    (256, 96, True, None, jnp.float32),        # phi3 head_dim
    (512, 64, True, 128, jnp.float32),         # SWA
    (256, 64, True, None, jnp.bfloat16),
])
def test_flash_attention_shapes(S, d, causal, window, dtype):
    rng = np.random.default_rng(hash((S, d, causal)) % 2 ** 31)
    q = jnp.asarray(rng.normal(size=(3, S, d)), dtype)
    k = jnp.asarray(rng.normal(size=(3, S, d)), dtype)
    v = jnp.asarray(rng.normal(size=(3, S, d)), dtype)
    out = flash_attention_kernel(q, k, v, causal=causal, window=window,
                                 block_q=64, block_kv=64, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


@settings(max_examples=8, deadline=None)
@given(bq=st.sampled_from([32, 64, 128]), bkv=st.sampled_from([32, 64, 128]),
       seed=st.integers(0, 100))
def test_flash_attention_block_shape_invariance(bq, bkv, seed):
    """Output must not depend on the tiling."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(2, 128, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 128, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 128, 64)), jnp.float32)
    a = flash_attention_kernel(q, k, v, block_q=bq, block_kv=bkv,
                               interpret=True)
    b = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                               rtol=2e-5)


def test_flash_attention_gqa_wrapper_matches_model_sdpa():
    """ops.flash_attention (GQA expansion) vs the model's XLA attention."""
    from repro.configs import get_config
    from repro.models.layers import _repeat_kv, _sdpa, causal_mask
    cfg = get_config("qwen3-14b").reduced()
    rng = np.random.default_rng(0)
    B, S, H, KV, hd = 2, 64, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    a = flash_attention(q, k, v, causal=True, block_q=32, block_kv=32)
    b = _sdpa(q, k, v, causal_mask(S, S, None), cfg)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5,
                               rtol=3e-5)


# ---------------------------------------------------------------------------
# rwkv6 wkv
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,n,chunk,dtype", [
    (64, 64, 16, jnp.float32),
    (128, 64, 32, jnp.float32),
    (96, 32, 32, jnp.float32),    # chunk == S/3
    (128, 64, 32, jnp.bfloat16),
])
def test_rwkv6_kernel_shapes(S, n, chunk, dtype):
    rng = np.random.default_rng(S + n)
    BH = 4
    r = jnp.asarray(rng.normal(size=(BH, S, n)), dtype)
    k = jnp.asarray(rng.normal(size=(BH, S, n)), dtype)
    v = jnp.asarray(rng.normal(size=(BH, S, n)), dtype)
    logw = -jnp.exp(jnp.asarray(rng.normal(size=(BH, S, n)) * 0.5 - 0.6,
                                jnp.float32)).astype(dtype)
    u = jnp.asarray(rng.normal(size=(BH, n)) * 0.1, jnp.float32)
    y, s = rwkv6_kernel(r, k, v, logw, u, chunk=chunk, interpret=True)
    y_ref, s_ref = wkv_ref(r, k, v, logw, u)
    tol = 5e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=tol,
                               rtol=tol)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), atol=tol,
                               rtol=tol)


@settings(max_examples=6, deadline=None)
@given(chunk=st.sampled_from([8, 16, 32, 64]), seed=st.integers(0, 50))
def test_rwkv6_chunk_size_invariance(chunk, seed):
    """The chunked algorithm must be exact for any chunk size."""
    rng = np.random.default_rng(seed)
    BH, S, n = 2, 64, 32
    r = jnp.asarray(rng.normal(size=(BH, S, n)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(BH, S, n)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(BH, S, n)), jnp.float32)
    logw = -jnp.exp(jnp.asarray(rng.normal(size=(BH, S, n)) * 0.3 - 1.0,
                                jnp.float32))
    u = jnp.asarray(rng.normal(size=(BH, n)) * 0.1, jnp.float32)
    y, _ = rwkv6_kernel(r, k, v, logw, u, chunk=chunk, interpret=True)
    y_ref, _ = wkv_ref(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=5e-4,
                               rtol=5e-4)


def test_rwkv6_ops_matches_model_path():
    """kernels.rwkv6.ops vs models.rwkv.wkv_chunked (the XLA path)."""
    from repro.models.rwkv import wkv_chunked
    rng = np.random.default_rng(3)
    B, S, H, n = 2, 64, 3, 32
    r = jnp.asarray(rng.normal(size=(B, S, H, n)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, n)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, n)), jnp.float32)
    logw = -jnp.exp(jnp.asarray(rng.normal(size=(B, S, H, n)) * 0.3 - 1.0,
                                jnp.float32))
    u = jnp.asarray(rng.normal(size=(H, n)) * 0.1, jnp.float32)
    y1, s1 = rwkv6_chunked(r, k, v, logw, u, chunk=16)
    y2, s2 = wkv_chunked(r, k, v, logw, u, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4,
                               rtol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-4,
                               rtol=2e-4)


# ---------------------------------------------------------------------------
# bloom probe
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("num_blocks,block_bits,num_hashes", [
    (128, 256, 3), (256, 512, 4), (64, 1024, 6),
])
def test_bloom_probe_shapes(num_blocks, block_bits, num_hashes):
    rng = np.random.default_rng(num_blocks)
    keys = rng.choice(2 ** 32, 2048, replace=False).astype(np.uint32)
    plane = build_plane(keys[:1024], num_blocks, block_bits, num_hashes)
    out = bloom_probe_kernel(jnp.asarray(keys), jnp.asarray(plane),
                             num_hashes=num_hashes, interpret=True)
    ref = probe_ref(keys, plane, num_hashes)
    assert (np.asarray(out) == ref).all()
    # no false negatives, ever
    assert (np.asarray(out[:1024]) > 0.5).all()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_bloom_probe_no_false_negatives(seed):
    rng = np.random.default_rng(seed)
    keys = rng.choice(2 ** 32, 512, replace=False).astype(np.uint32)
    plane = build_plane(keys, 128, 512, 4)
    out = bloom_probe_kernel(jnp.asarray(keys), jnp.asarray(plane),
                             num_hashes=4, interpret=True)
    assert (np.asarray(out) > 0.5).all()


# ---------------------------------------------------------------------------
# point read (fused per-level batched read; PR 7)
# ---------------------------------------------------------------------------

def _mk_level(run_specs, bpk=8.0):
    """LevelStore from newest-first ``[(keys, vals), ...]`` run specs."""
    runs = [RunData.build(np.asarray(k, np.uint64), np.asarray(v, np.int64),
                          bpk, flushes=1) for k, v in run_specs]
    lv = LevelStore()
    lv._set_runs(runs)
    return lv


def _level_arrays(lv):
    pack = lv.pack
    return (lv.keys, lv.vals, np.asarray(lv.starts, np.int64), pack.words,
            np.asarray(pack.n_bits, np.uint64), np.asarray(pack.ks, np.int64),
            lv.min_keys, lv.max_keys)


def _assert_read_modes_bit_equal(lv, q):
    """numpy (engine-verbatim) / jnp ref / pallas must agree exactly."""
    q = np.asarray(q, np.uint64)
    ref = point_read_level_numpy(lv, q)
    for impl in ("jnp", "pallas"):
        hit, enc, probes, reads, fps = point_read_level_arrays(
            q, *_level_arrays(lv), impl=impl)
        np.testing.assert_array_equal(hit, ref[0], err_msg=impl)
        np.testing.assert_array_equal(enc[hit], ref[1][ref[0]],
                                      err_msg=impl)
        assert (probes, reads, fps) == ref[2:], impl


def test_point_read_multi_run_level_bit_equal():
    rng = np.random.default_rng(0)
    pool = rng.choice(1 << 48, 3000, replace=False).astype(np.uint64)
    specs = [(np.sort(pool[:900]), np.arange(900)),
             (np.sort(pool[900:1100]), np.arange(200) + 10_000),
             (np.sort(pool[1100:2400]), np.arange(1300) + 50_000)]
    lv = _mk_level(specs)
    # present in various runs, absent, duplicated queries; B = 200 is
    # not a multiple of the 128-key pallas tile (exercises padding)
    q = np.concatenate([pool[rng.integers(0, 2400, 120)],
                        pool[2400:2470], pool[:10]])
    _assert_read_modes_bit_equal(lv, q)


def test_point_read_overlapping_runs_newest_wins():
    """Same key in several runs: only the newest run's value counts and
    older runs are not probed for the resolved key (counter semantics)."""
    keys = np.arange(100, 200, dtype=np.uint64)
    specs = [(keys[:60], np.full(60, 1)),       # newest
             (keys[20:80], np.full(60, 2)),
             (keys, np.full(100, 3))]           # oldest
    lv = _mk_level(specs)
    _assert_read_modes_bit_equal(lv, keys)
    hit, enc, *_ = point_read_level_arrays(keys, *_level_arrays(lv),
                                           impl="pallas")
    assert hit.all()
    np.testing.assert_array_equal(enc[:60], 1)
    np.testing.assert_array_equal(enc[60:80], 2)
    np.testing.assert_array_equal(enc[80:], 3)


@pytest.mark.parametrize("case", ["empty_run", "single_entry",
                                  "all_tombstone", "odd_batch"])
def test_point_read_edge_cases(case):
    rng = np.random.default_rng(hash(case) % 2 ** 31)
    if case == "empty_run":
        specs = [(np.arange(10, 20), np.arange(10)),
                 ([], []),                       # merged-away run
                 (np.arange(15, 40), np.arange(25))]
        q = np.arange(5, 45)
    elif case == "single_entry":
        specs = [([7], [70]), ([7], [71]), ([9], [90])]
        q = np.array([7, 8, 9, 7])
    elif case == "all_tombstone":
        keys = np.arange(50, 80, dtype=np.uint64)
        specs = [(keys, np.full(30, TOMB)),      # deletes shadow ...
                 (keys, np.arange(30))]          # ... the older values
        q = np.arange(40, 90)
    else:                                        # batch % 128 != 0
        keys = np.sort(rng.choice(1 << 32, 500, replace=False)
                       .astype(np.uint64))
        specs = [(keys[::2], np.arange(250))]
        q = rng.choice(keys, 37)
    lv = _mk_level(specs)
    _assert_read_modes_bit_equal(lv, q)
    if case == "all_tombstone":
        hit, enc, *_ = point_read_level_arrays(
            np.arange(50, 80, dtype=np.uint64), *_level_arrays(lv),
            impl="pallas")
        assert hit.all() and (enc == TOMB).all()


def test_point_read_empty_level_and_empty_batch():
    lv = _mk_level([(np.arange(5), np.arange(5))])
    hit, enc, probes, reads, fps = point_read_level_arrays(
        np.empty(0, np.uint64), *_level_arrays(lv), impl="pallas")
    assert len(hit) == 0 and (probes, reads, fps) == (0, 0, 0)
    lv0 = _mk_level([([], []), ([], [])])
    q = np.arange(3, dtype=np.uint64)
    _assert_read_modes_bit_equal(lv0, q)


# ---------------------------------------------------------------------------
# dual solve (robust tuner inner loop; PR 7)
# ---------------------------------------------------------------------------

def _dual_solve_batch(L, n=33, seed=0):
    rng = np.random.default_rng(seed)
    C = rng.gamma(2.0, 2.0, (L, n)).astype(np.float32)
    W = rng.dirichlet(np.ones(n), L).astype(np.float32)
    rho = rng.uniform(0.0, 2.0, L).astype(np.float32)
    rho[::3] = 0.0                      # exercise the nominal branch
    llam = np.log(C.max(1) - C.min(1)).astype(np.float32)
    return C, W, rho, llam


@pytest.mark.parametrize("L", [1, 7, 128, 300])
def test_dual_solve_pallas_bit_equals_fused(L):
    """Lane-tiled kernel vs vmapped fused: exact f32 equality, including
    lane counts that are not a multiple of the 128-lane tile."""
    C, W, rho, llam = _dual_solve_batch(L, seed=L)
    vf, lf = dual_solve_warm_batch(C, W, rho, llam, impl="fused")
    vp, lp = dual_solve_warm_batch(C, W, rho, llam, impl="pallas")
    np.testing.assert_array_equal(np.asarray(vf), np.asarray(vp))
    np.testing.assert_array_equal(np.asarray(lf), np.asarray(lp))


def test_dual_solve_fused_matches_ref_values():
    """Cached-point golden (12 evals) vs two-point reference (16 evals):
    same bracket-shrink rate, so values agree to optimizer-noise level."""
    C, W, rho, llam = _dual_solve_batch(64, seed=3)
    vr, lr = dual_solve_warm_batch(C, W, rho, llam, impl="ref")
    vf, lf = dual_solve_warm_batch(C, W, rho, llam, impl="fused")
    np.testing.assert_allclose(np.asarray(vf), np.asarray(vr),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(lf), np.asarray(lr), atol=2e-3)


def test_dual_solve_single_lane_dispatch():
    C, W, rho, llam = _dual_solve_batch(1, seed=9)
    vf, _ = dual_solve_warm(C[0], W[0], rho[0], llam[0], impl="fused")
    vr, _ = dual_solve_warm(C[0], W[0], rho[0], llam[0], impl="ref")
    assert float(vf) == pytest.approx(float(vr), rel=1e-4, abs=1e-4)
    with pytest.raises(ValueError):
        dual_solve_warm(C[0], W[0], rho[0], llam[0], impl="pallas")


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 200), L=st.integers(1, 40))
def test_dual_solve_pallas_fused_property(seed, L):
    C, W, rho, llam = _dual_solve_batch(L, n=17, seed=seed)
    vf, lf = dual_solve_warm_batch(C, W, rho, llam, impl="fused")
    vp, lp = dual_solve_warm_batch(C, W, rho, llam, impl="pallas")
    np.testing.assert_array_equal(np.asarray(vf), np.asarray(vp))
    np.testing.assert_array_equal(np.asarray(lf), np.asarray(lp))


# ---------------------------------------------------------------------------
# compaction merge (k-way stable merge; PR 7)
# ---------------------------------------------------------------------------

def _mk_runs(sizes, seed=0, overlap=True):
    """Newest-first sorted-unique runs with heavy key overlap."""
    rng = np.random.default_rng(seed)
    pool = rng.choice(1 << 20 if overlap else 1 << 48, max(sizes) * 2 + 4,
                      replace=False).astype(np.uint64)
    keys, vals = [], []
    for i, n in enumerate(sizes):
        k = np.sort(rng.choice(pool, n, replace=False)) if n else \
            np.empty(0, np.uint64)
        keys.append(k)
        vals.append((rng.integers(0, 1 << 30, n) * 10 + i).astype(np.int64))
    return keys, vals


@pytest.mark.parametrize("sizes", [
    (100, 80), (1, 1), (1, 0, 5), (0, 0), (257, 100, 3),   # != 128 tiles
    (64, 64, 64, 64),
])
def test_merge_modes_bit_equal(sizes):
    keys, vals = _mk_runs(list(sizes), seed=sum(sizes))
    ref_k, ref_v = merge_runs_numpy(keys, vals)
    for impl in ("jnp", "pallas"):
        mk, mv = merge_runs_arrays(keys, vals, impl=impl)
        np.testing.assert_array_equal(mk, ref_k, err_msg=impl)
        np.testing.assert_array_equal(mv, ref_v, err_msg=impl)


def test_merge_newest_wins_on_duplicates():
    """Every key duplicated across all runs: output must keep run 0's
    value (newest-first input order, like the legacy argsort merge)."""
    keys = np.arange(1000, 1300, dtype=np.uint64)
    klist = [keys, keys, keys]
    vlist = [np.full(300, i, np.int64) for i in range(3)]
    ref_k, ref_v = merge_runs_numpy(klist, vlist)
    assert (ref_v == 0).all()
    for impl in ("jnp", "pallas"):
        mk, mv = merge_runs_arrays(klist, vlist, impl=impl)
        np.testing.assert_array_equal(mk, ref_k)
        np.testing.assert_array_equal(mv, ref_v)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 500), na=st.integers(0, 60),
       nb=st.integers(0, 60), nc=st.integers(0, 60))
def test_merge_modes_property(seed, na, nb, nc):
    keys, vals = _mk_runs([na, nb, nc], seed=seed)
    ref_k, ref_v = merge_runs_numpy(keys, vals)
    mk, mv = merge_runs_arrays(keys, vals, impl="jnp")
    np.testing.assert_array_equal(mk, ref_k)
    np.testing.assert_array_equal(mv, ref_v)


# ---------------------------------------------------------------------------
# model integration: attention_impl="pallas" end to end
# ---------------------------------------------------------------------------

def test_model_with_pallas_attention_matches_xla():
    from repro.configs import get_config
    from repro.models import build_model
    cfg_x = get_config("mixtral-8x7b").reduced()
    cfg_p = cfg_x.replace(attention_impl="pallas")
    api_x, api_p = build_model(cfg_x), build_model(cfg_p)
    params = api_x.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg_x.vocab_size, (2, 32)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg_x.vocab_size, (2, 32)),
                              jnp.int32),
    }
    lx, _ = api_x.loss_fn(params, batch)
    lp, _ = api_p.loss_fn(params, batch)
    assert float(lx) == pytest.approx(float(lp), rel=1e-3)


def test_model_with_pallas_rwkv_matches_xla():
    from repro.configs import get_config
    from repro.models import build_model
    cfg_x = get_config("rwkv6-3b").reduced()
    cfg_p = cfg_x.replace(attention_impl="pallas")
    api_x, api_p = build_model(cfg_x), build_model(cfg_p)
    params = api_x.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg_x.vocab_size, (2, 32)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg_x.vocab_size, (2, 32)),
                              jnp.int32),
    }
    lx, _ = api_x.loss_fn(params, batch)
    lp, _ = api_p.loss_fn(params, batch)
    assert float(lx) == pytest.approx(float(lp), rel=1e-3)
