"""Per-kernel validation: shape/dtype sweeps in interpret mode against the
pure-jnp oracles (+ hypothesis property tests)."""

import pytest

try:
    from jax.experimental.pallas import tpu as _pltpu
except Exception:      # pallas TPU backend entirely absent
    _pltpu = None
if _pltpu is None or not hasattr(_pltpu, "CompilerParams"):
    pytest.skip("Pallas TPU API surface (pltpu.CompilerParams) not in this "
                "JAX build; kernels cannot be constructed",
                allow_module_level=True)

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.bloom_probe.kernel import bloom_probe_kernel
from repro.kernels.bloom_probe.ref import build_plane, probe_ref
from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rwkv6.kernel import rwkv6_kernel
from repro.kernels.rwkv6.ops import rwkv6_chunked
from repro.kernels.rwkv6.ref import wkv_ref


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,d,causal,window,dtype", [
    (128, 64, True, None, jnp.float32),
    (256, 64, False, None, jnp.float32),
    (256, 128, True, None, jnp.float32),
    (256, 96, True, None, jnp.float32),        # phi3 head_dim
    (512, 64, True, 128, jnp.float32),         # SWA
    (256, 64, True, None, jnp.bfloat16),
])
def test_flash_attention_shapes(S, d, causal, window, dtype):
    rng = np.random.default_rng(hash((S, d, causal)) % 2 ** 31)
    q = jnp.asarray(rng.normal(size=(3, S, d)), dtype)
    k = jnp.asarray(rng.normal(size=(3, S, d)), dtype)
    v = jnp.asarray(rng.normal(size=(3, S, d)), dtype)
    out = flash_attention_kernel(q, k, v, causal=causal, window=window,
                                 block_q=64, block_kv=64, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


@settings(max_examples=8, deadline=None)
@given(bq=st.sampled_from([32, 64, 128]), bkv=st.sampled_from([32, 64, 128]),
       seed=st.integers(0, 100))
def test_flash_attention_block_shape_invariance(bq, bkv, seed):
    """Output must not depend on the tiling."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(2, 128, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 128, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 128, 64)), jnp.float32)
    a = flash_attention_kernel(q, k, v, block_q=bq, block_kv=bkv,
                               interpret=True)
    b = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                               rtol=2e-5)


def test_flash_attention_gqa_wrapper_matches_model_sdpa():
    """ops.flash_attention (GQA expansion) vs the model's XLA attention."""
    from repro.configs import get_config
    from repro.models.layers import _repeat_kv, _sdpa, causal_mask
    cfg = get_config("qwen3-14b").reduced()
    rng = np.random.default_rng(0)
    B, S, H, KV, hd = 2, 64, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    a = flash_attention(q, k, v, causal=True, block_q=32, block_kv=32)
    b = _sdpa(q, k, v, causal_mask(S, S, None), cfg)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5,
                               rtol=3e-5)


# ---------------------------------------------------------------------------
# rwkv6 wkv
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,n,chunk,dtype", [
    (64, 64, 16, jnp.float32),
    (128, 64, 32, jnp.float32),
    (96, 32, 32, jnp.float32),    # chunk == S/3
    (128, 64, 32, jnp.bfloat16),
])
def test_rwkv6_kernel_shapes(S, n, chunk, dtype):
    rng = np.random.default_rng(S + n)
    BH = 4
    r = jnp.asarray(rng.normal(size=(BH, S, n)), dtype)
    k = jnp.asarray(rng.normal(size=(BH, S, n)), dtype)
    v = jnp.asarray(rng.normal(size=(BH, S, n)), dtype)
    logw = -jnp.exp(jnp.asarray(rng.normal(size=(BH, S, n)) * 0.5 - 0.6,
                                jnp.float32)).astype(dtype)
    u = jnp.asarray(rng.normal(size=(BH, n)) * 0.1, jnp.float32)
    y, s = rwkv6_kernel(r, k, v, logw, u, chunk=chunk, interpret=True)
    y_ref, s_ref = wkv_ref(r, k, v, logw, u)
    tol = 5e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=tol,
                               rtol=tol)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), atol=tol,
                               rtol=tol)


@settings(max_examples=6, deadline=None)
@given(chunk=st.sampled_from([8, 16, 32, 64]), seed=st.integers(0, 50))
def test_rwkv6_chunk_size_invariance(chunk, seed):
    """The chunked algorithm must be exact for any chunk size."""
    rng = np.random.default_rng(seed)
    BH, S, n = 2, 64, 32
    r = jnp.asarray(rng.normal(size=(BH, S, n)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(BH, S, n)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(BH, S, n)), jnp.float32)
    logw = -jnp.exp(jnp.asarray(rng.normal(size=(BH, S, n)) * 0.3 - 1.0,
                                jnp.float32))
    u = jnp.asarray(rng.normal(size=(BH, n)) * 0.1, jnp.float32)
    y, _ = rwkv6_kernel(r, k, v, logw, u, chunk=chunk, interpret=True)
    y_ref, _ = wkv_ref(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=5e-4,
                               rtol=5e-4)


def test_rwkv6_ops_matches_model_path():
    """kernels.rwkv6.ops vs models.rwkv.wkv_chunked (the XLA path)."""
    from repro.models.rwkv import wkv_chunked
    rng = np.random.default_rng(3)
    B, S, H, n = 2, 64, 3, 32
    r = jnp.asarray(rng.normal(size=(B, S, H, n)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, n)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, n)), jnp.float32)
    logw = -jnp.exp(jnp.asarray(rng.normal(size=(B, S, H, n)) * 0.3 - 1.0,
                                jnp.float32))
    u = jnp.asarray(rng.normal(size=(H, n)) * 0.1, jnp.float32)
    y1, s1 = rwkv6_chunked(r, k, v, logw, u, chunk=16)
    y2, s2 = wkv_chunked(r, k, v, logw, u, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4,
                               rtol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-4,
                               rtol=2e-4)


# ---------------------------------------------------------------------------
# bloom probe
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("num_blocks,block_bits,num_hashes", [
    (128, 256, 3), (256, 512, 4), (64, 1024, 6),
])
def test_bloom_probe_shapes(num_blocks, block_bits, num_hashes):
    rng = np.random.default_rng(num_blocks)
    keys = rng.choice(2 ** 32, 2048, replace=False).astype(np.uint32)
    plane = build_plane(keys[:1024], num_blocks, block_bits, num_hashes)
    out = bloom_probe_kernel(jnp.asarray(keys), jnp.asarray(plane),
                             num_hashes=num_hashes, interpret=True)
    ref = probe_ref(keys, plane, num_hashes)
    assert (np.asarray(out) == ref).all()
    # no false negatives, ever
    assert (np.asarray(out[:1024]) > 0.5).all()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_bloom_probe_no_false_negatives(seed):
    rng = np.random.default_rng(seed)
    keys = rng.choice(2 ** 32, 512, replace=False).astype(np.uint32)
    plane = build_plane(keys, 128, 512, 4)
    out = bloom_probe_kernel(jnp.asarray(keys), jnp.asarray(plane),
                             num_hashes=4, interpret=True)
    assert (np.asarray(out) > 0.5).all()


# ---------------------------------------------------------------------------
# model integration: attention_impl="pallas" end to end
# ---------------------------------------------------------------------------

def test_model_with_pallas_attention_matches_xla():
    from repro.configs import get_config
    from repro.models import build_model
    cfg_x = get_config("mixtral-8x7b").reduced()
    cfg_p = cfg_x.replace(attention_impl="pallas")
    api_x, api_p = build_model(cfg_x), build_model(cfg_p)
    params = api_x.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg_x.vocab_size, (2, 32)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg_x.vocab_size, (2, 32)),
                              jnp.int32),
    }
    lx, _ = api_x.loss_fn(params, batch)
    lp, _ = api_p.loss_fn(params, batch)
    assert float(lx) == pytest.approx(float(lp), rel=1e-3)


def test_model_with_pallas_rwkv_matches_xla():
    from repro.configs import get_config
    from repro.models import build_model
    cfg_x = get_config("rwkv6-3b").reduced()
    cfg_p = cfg_x.replace(attention_impl="pallas")
    api_x, api_p = build_model(cfg_x), build_model(cfg_p)
    params = api_x.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg_x.vocab_size, (2, 32)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg_x.vocab_size, (2, 32)),
                              jnp.int32),
    }
    lx, _ = api_x.loss_fn(params, batch)
    lp, _ = api_p.loss_fn(params, batch)
    assert float(lx) == pytest.approx(float(lp), rel=1e-3)
