"""Golden-equivalence tests for the columnar LSM engine refactor.

The store/planner/executor engine must reproduce the frozen pre-refactor
engine (``tests/_legacy_engine.py``, a verbatim snapshot) EXACTLY: the same
``IOStats`` (random/seq reads, compaction pages, bloom probes and false
positives, z0/z1/q/w counts) on fixed-seed populate + session scenarios
across leveling / tiering / mixed-K configs, the same tree shapes, the same
values, the same filter-bit budgets.  Plus property tests for newest-wins
and tombstone semantics under interleaved puts / deletes / range scans, and
unit tests for the new layers (codec, Bloom pack, planner, batch paths).
"""

import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import _legacy_engine as legacy
from repro.lsm import (EngineConfig, LSMTree, draw_keys, populate, run_fleet,
                       run_session)
from repro.lsm.bloom import BloomFilter, BloomPack
from repro.lsm.planner import KLSMPlanner, MergePlan
from repro.lsm.store import TOMB, ValueCodec

KEY_SPACE = 2 ** 24

CONFIGS = {
    "leveling": dict(T=4, K=(1,) * 8, buf_entries=128, expected_entries=6000,
                     mfilt_bits_per_entry=8.0),
    "tiering": dict(T=5, K=(4,) * 8, buf_entries=128, expected_entries=6000,
                    mfilt_bits_per_entry=8.0),
    "mixed_k": dict(T=4, K=(3, 1, 2), buf_entries=64, expected_entries=5000,
                    mfilt_bits_per_entry=8.0),
}

SESSIONS = [
    [0.25, 0.25, 0.25, 0.25],
    [0.85, 0.05, 0.05, 0.05],
    [0.05, 0.85, 0.05, 0.05],
    [0.05, 0.05, 0.85, 0.05],
    [0.05, 0.05, 0.05, 0.85],
]


def _pair(name):
    kw = CONFIGS[name]
    return LSMTree(EngineConfig(**kw)), legacy.LSMTree(legacy.EngineConfig(**kw))


@pytest.mark.parametrize("config", sorted(CONFIGS))
def test_golden_iostats_populate_and_sessions(config):
    """New engine == frozen engine, stat for stat, on every session mix."""
    n = CONFIGS[config]["expected_entries"]
    new, old = _pair(config)
    keys_new = populate(new, n, seed=11, key_space=KEY_SPACE)
    keys_old = legacy.populate(old, n, seed=11, key_space=KEY_SPACE)
    assert np.array_equal(keys_new, keys_old)
    assert new.shape() == old.shape()
    assert new.filter_bits_in_use() == old.filter_bits_in_use()
    for i, w in enumerate(SESSIONS):
        res_new = run_session(new, keys_new, np.asarray(w), n_queries=600,
                              seed=50 + i, key_space=KEY_SPACE,
                              range_fraction=1e-3)
        res_old = legacy.run_session(old, keys_old, np.asarray(w),
                                     n_queries=600, seed=50 + i,
                                     key_space=KEY_SPACE,
                                     range_fraction=1e-3)
        assert dataclasses.asdict(res_new.io) == \
            dataclasses.asdict(res_old.io), (config, i)
        assert res_new.avg_io_per_query == res_old.avg_io_per_query
    # sessions mutate the tree; shapes must still agree afterwards
    assert new.shape() == old.shape()


def test_golden_point_and_range_results_match():
    """Query *results* (not just accounting) agree with the frozen engine."""
    new, old = _pair("mixed_k")
    n = CONFIGS["mixed_k"]["expected_entries"]
    keys = populate(new, n, seed=3, key_space=KEY_SPACE)
    legacy.populate(old, n, seed=3, key_space=KEY_SPACE)
    rng = np.random.default_rng(0)
    probe = np.concatenate([keys[::7],
                            rng.integers(0, KEY_SPACE, 300).astype(np.uint64)])
    assert new.point_query_batch(probe) == old.point_query_batch(probe)
    for lo in rng.integers(0, KEY_SPACE - 40_000, 20):
        assert new.range_query(int(lo), int(lo) + 40_000) == \
            old.range_query(int(lo), int(lo) + 40_000)


def test_run_fleet_matches_run_session():
    """The fleet executor is exactly per-tree run_session, plans shared."""
    cfgs = [CONFIGS["leveling"], CONFIGS["tiering"]]
    keys = draw_keys(4000, seed=9, key_space=KEY_SPACE)
    trees, singles = [], []
    for kw in cfgs:
        t_fleet = LSMTree(EngineConfig(**kw))
        t_single = LSMTree(EngineConfig(**kw))
        populate(t_fleet, 4000, key_space=KEY_SPACE, keys=keys)
        populate(t_single, 4000, key_space=KEY_SPACE, keys=keys)
        trees.append(t_fleet)
        singles.append(t_single)
    sessions = np.asarray(SESSIONS[:3])
    seeds = np.asarray([7, 8, 9])
    fleet = run_fleet(trees, sessions, keys, n_queries=400, seeds=seeds,
                      key_space=KEY_SPACE, range_fraction=1e-3)
    for tree, row in zip(singles, fleet):
        for s, res in enumerate(row):
            ref = run_session(tree, keys, sessions[s], n_queries=400,
                              seed=int(seeds[s]), key_space=KEY_SPACE,
                              range_fraction=1e-3)
            assert dataclasses.asdict(res.io) == dataclasses.asdict(ref.io)


# ---------------------------------------------------------------------------
# Newest-wins / tombstone property tests
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 1000), T=st.integers(2, 6),
       kcap=st.integers(1, 5))
def test_interleaved_puts_deletes_scans_property(seed, T, kcap):
    """Under interleaved puts / overwrites / deletes / range scans the
    engine must agree with a dict model: newest version wins, deleted keys
    stay dead (range scans exercise compaction state mid-stream)."""
    tree = LSMTree(EngineConfig(T=T, K=(min(kcap, T - 1),) * 8,
                                buf_entries=32, expected_entries=2000))
    rng = np.random.default_rng(seed)
    universe = rng.choice(100_000, size=400, replace=False)
    model = {}
    for step in range(1200):
        op = rng.integers(0, 10)
        k = int(universe[rng.integers(0, len(universe))])
        if op < 6:                       # put (sometimes an overwrite)
            v = int(rng.integers(0, 10_000))
            tree.put(k, v)
            model[k] = v
        elif op < 8:                     # delete (sometimes nonexistent)
            tree.delete(k)
            model.pop(k, None)
        else:                            # range scan vs the model
            lo = int(rng.integers(0, 90_000))
            hi = lo + int(rng.integers(1, 20_000))
            got = tree.range_query(lo, hi)
            expect = sorted((kk, vv) for kk, vv in model.items()
                            if lo <= kk < hi)
            assert got == expect
    for k in universe[:100]:
        assert tree.get(int(k)) == model.get(int(k))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 500))
def test_tombstones_never_resurface_after_compaction(seed):
    """Deleting a key buried in deep levels must survive any amount of
    subsequent compaction (tombstones only dropped at the deepest level)."""
    tree = LSMTree(EngineConfig(T=3, K=(2,) * 8, buf_entries=16,
                                expected_entries=1000))
    rng = np.random.default_rng(seed)
    keys = rng.choice(50_000, size=600, replace=False)
    for k in keys:
        tree.put(int(k), int(k))
    dead = keys[::3]
    for k in dead:
        tree.delete(int(k))
    # churn: force multi-level compaction waves over the tombstones
    for k in rng.choice(50_000, size=600, replace=False):
        tree.put(int(k) + 1_000_000, 0)
    for k in dead[:80]:
        assert tree.get(int(k)) is None
    alive = [int(k) for k in keys if k not in set(dead.tolist())]
    for k in alive[:80]:
        assert tree.get(k) == k


# ---------------------------------------------------------------------------
# Layer unit tests: codec, Bloom pack, planner
# ---------------------------------------------------------------------------

def test_value_codec_roundtrip_and_interning():
    c = ValueCodec()
    ints = [0, 1, -1, 7, -2 ** 61, 2 ** 61]
    for v in ints:
        assert c.decode(c.encode(v)) == v
    objs = ["json", (1, 2), None, True, 2 ** 63]   # non-int / out of range
    encs = [c.encode(v) for v in objs]
    assert all(e % 2 == 0 for e in encs), "objects must intern to even slots"
    assert [c.decode(e) for e in encs] == objs
    assert c.decode(encs[3]) is True               # bool identity preserved
    enc_many = c.encode_many(np.arange(-5, 5))
    assert c.decode_many(enc_many) == list(range(-5, 5))
    assert TOMB not in enc_many.tolist()


def test_bloom_pack_matches_per_run_filters():
    rng = np.random.default_rng(1)
    runs = [rng.choice(2 ** 48, size=n, replace=False).astype(np.uint64)
            for n in (500, 1200, 64)]
    filters = [BloomFilter(k, bits_per_key=b)
               for k, b in zip(runs, (9.0, 5.0, 12.0))]
    pack = BloomPack([f.words for f in filters],
                     [f.n_bits for f in filters], [f.k for f in filters])
    probe = np.concatenate([runs[0][:50], runs[1][:50],
                            rng.integers(0, 2 ** 48, 400).astype(np.uint64)])
    got = pack.probe(probe)
    for r, f in enumerate(filters):
        assert np.array_equal(got[r], f.might_contain_batch(probe)), r


def test_planner_emits_klsm_plans_as_data():
    cfg = EngineConfig(T=4, K=(2,) * 4, buf_entries=100,
                       expected_entries=4000)
    planner = KLSMPlanner(cfg)
    entries = np.array([250, 0, 0])
    runs = np.array([2, 0, 0])
    flushes = np.array([1, 0, 0])
    # level 1 capacity = 3 * 100: an incoming 100-entry run overflows -> spill
    plan = planner.plan_push((entries, runs, flushes), 1, 100, 1)
    assert plan == MergePlan(kind="spill", level=1, run_ids=(0, 1),
                             target_level=2, drop_tombstones=True)
    # with a populated deeper level the spill must keep tombstones
    plan = planner.plan_push((entries, np.array([2, 1, 0]), flushes), 1,
                             100, 1)
    assert plan.drop_tombstones is False
    # under capacity: eager-merge while the active run's lineage fits
    plan = planner.plan_push((np.array([100, 0, 0]), np.array([1, 0, 0]),
                              np.array([1, 0, 0])), 1, 100, 1)
    assert plan.kind == "eager" and plan.target_level == 1
    # lineage exhausted -> logical move, then clamps restore the K cap
    plan = planner.plan_push((np.array([200, 0, 0]), np.array([1, 0, 0]),
                              np.array([2, 0, 0])), 1, 50, 1)
    assert plan.kind == "move"
    clamps = planner.plan_clamps((entries, np.array([4, 0, 0]), flushes), 1)
    assert [p.kind for p in clamps] == ["clamp", "clamp"]
    assert all(p.run_ids == (0, 1) for p in clamps)


def test_range_query_batch_matches_single_queries():
    tree = LSMTree(EngineConfig(T=4, K=(2,) * 8, buf_entries=64,
                                expected_entries=3000))
    keys = populate(tree, 3000, seed=5, key_space=KEY_SPACE)
    tree.put(int(keys[0]), "overwrite")      # buffered newest version
    tree.delete(int(keys[1]))
    rng = np.random.default_rng(2)
    los = rng.integers(0, KEY_SPACE - 50_000, 40).astype(np.uint64)
    his = los + np.uint64(50_000)
    from repro.lsm.engine import IOStats
    tree.stats = IOStats()
    batch = tree.range_query_batch(los, his, return_results=True)
    batch_stats = dataclasses.asdict(tree.stats.snapshot())
    tree.stats = IOStats()
    singles = [tree.range_query(int(lo), int(hi))
               for lo, hi in zip(los, his)]
    assert batch == singles
    assert batch_stats == dataclasses.asdict(tree.stats)
