"""Tests for the compaction design-space planners.

Golden tests pin lazy-leveling and partial-compaction ``IOStats`` against
small HAND-COMPUTED scenarios (every page count in the asserts is derived
in the comments, not recorded from a run); property tests check KV
correctness under every policy; the tombstone-TTL invariant is checked both
on a direct delete/churn scenario and at fleet level; and unit tests cover
the planner registry, the policy cost-model hook, and the policy-axis fleet
runner.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import (LAZY_LEVELING_FILL, LSMSystem, make_phi, num_levels,
                        policy_effective_phi)
from repro.core.lsm_cost import mbuf_bits
from repro.lsm import (EngineConfig, IOStats, LSMTree, MergePlan, POLICIES,
                       draw_keys, make_planner, populate, run_fleet,
                       run_policy_fleet, run_session)

KEY_SPACE = 2 ** 24


def _cfg(policy, params=(), T=3, buf=4, K=()):
    # entry_bytes=2048 / page_bytes=4096 -> 2 entries per page: page counts
    # in the golden asserts stay small enough to derive by hand
    return EngineConfig(T=T, K=K, buf_entries=buf, entry_bytes=2048,
                        page_bytes=4096, expected_entries=64,
                        policy=policy, policy_params=params)


# ---------------------------------------------------------------------------
# Golden, hand-computed IOStats
# ---------------------------------------------------------------------------

def test_golden_lazy_leveling_read_triggered_squeeze():
    """T=3, buf=4, epp=2, read_trigger=2.

    Two flushes of 4 entries each land as two level-1 runs (lazy leveling
    accumulates tiering-style: run cap T-1=2, flush lineage cap
    ceil((T-1)/K)=1 forces a move, no write-path merging), costing
    pages_of(4)=2 written each.  Two point hits on the newest run cost one
    bloom probe + one random read each; the second read crosses the
    read_trigger=2 pressure threshold, so maintenance squeezes the deepest
    level: one merge reading 2+2 pages and writing pages_of(8)=4."""
    tree = LSMTree(_cfg("lazy_leveling", (("read_trigger", 2),)))
    for k in range(8):
        tree.put(k, k)
    assert tree.shape() == [(1, [4, 4])]
    assert tree.stats.comp_pages_written == 4      # two flushes, no merges
    assert tree.stats.comp_pages_read == 0

    assert tree.point_query(4) == 4        # newest run: 1 probe, 1 read
    assert tree.shape() == [(1, [4, 4])]   # pressure 1 < trigger 2
    assert tree.point_query(5) == 5        # pressure 2 -> squeeze
    assert tree.shape() == [(1, [8])]

    s = tree.stats
    assert s.random_reads == 2
    assert s.seq_reads == 0
    assert s.bloom_probes == 2
    assert s.bloom_false_positives == 0
    assert s.comp_pages_read == 4          # squeeze inputs: 2 + 2 pages
    assert s.comp_pages_written == 4 + 4   # flushes + squeeze output
    assert s.queries == {"z0": 0, "z1": 2, "q": 0, "w": 8}


def test_golden_partial_compaction_slices_half_the_level():
    """T=3, buf=4, epp=2, K=1 (leveling), parts=2.

    Flush 1 ([0..3]) moves in (2 pages written).  Flush 2 ([4..7]) eager-
    merges into the active run (read 2+2, write pages_of(8)=4).  Flush 3
    ([8..11]) exceeds the lineage cap -> move; maintenance first clamps the
    K=1 run cap (read pages_of(4)+pages_of(8)=6, write pages_of(12)=6),
    then sees 12 > capacity 8 and sheds ONE partial slice: the cursor's
    first stride covers keys [0, 6) -> a 6-entry piece (read 3 pages) is
    merged (nothing at level 2 yet) and placed as level 2's newest run
    (write 3 pages).  The 6-entry remainder stays at level 1 — under
    capacity, so exactly one slice moved per trigger."""
    tree = LSMTree(_cfg("partial", (("parts", 2),)))
    for k in range(12):
        tree.put(k, 10 * k)
    assert tree.shape() == [(1, [6]), (2, [6])]

    s = tree.stats
    assert s.comp_pages_read == 4 + 6 + 3
    assert s.comp_pages_written == (3 * 2) + 4 + 6 + 3
    assert s.queries["w"] == 12
    # remainder/piece boundary: level 1 holds [6..11], level 2 holds [0..5]
    assert tree.store.levels[0].keys.tolist() == list(range(6, 12))
    assert tree.store.levels[1].keys.tolist() == list(range(0, 6))
    for k in range(12):
        assert tree.get(k) == 10 * k
    assert tree.range_query(0, 12) == [(k, 10 * k) for k in range(12)]


# ---------------------------------------------------------------------------
# KV correctness under every policy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy,params", [
    ("lazy_leveling", (("read_trigger", 8),)),
    ("partial", (("parts", 3),)),
    ("tombstone_ttl", (("ttl_flushes", 3),)),
])
@pytest.mark.parametrize("seed", [0, 7, 101, 499])
def test_policies_agree_with_dict_model(policy, params, seed):
    """Interleaved puts / overwrites / deletes / reads / scans match a dict
    model under every new policy (maintenance merges run mid-stream)."""
    tree = LSMTree(EngineConfig(T=3, K=(2,) * 6, buf_entries=16,
                                expected_entries=1000, policy=policy,
                                policy_params=params))
    rng = np.random.default_rng(seed)
    universe = rng.choice(50_000, size=250, replace=False)
    model = {}
    for step in range(700):
        op = rng.integers(0, 10)
        k = int(universe[rng.integers(0, len(universe))])
        if op < 5:
            v = int(rng.integers(0, 10_000))
            tree.put(k, v)
            model[k] = v
        elif op < 7:
            tree.delete(k)
            model.pop(k, None)
        elif op < 9:
            assert tree.point_query(k) == model.get(k)
        else:
            lo = int(rng.integers(0, 45_000))
            hi = lo + int(rng.integers(1, 10_000))
            got = tree.range_query(lo, hi)
            expect = sorted((kk, vv) for kk, vv in model.items()
                            if lo <= kk < hi)
            assert got == expect
    for k in universe[:120]:
        assert tree.get(int(k)) == model.get(int(k))


# ---------------------------------------------------------------------------
# Tombstone-TTL: bounded delete persistence, no resurrection
# ---------------------------------------------------------------------------

def _max_tomb_age(tree):
    return max((tree.flush_seq - ts for lv in tree.store.levels
                for ts in lv.tomb_seqs if ts >= 0), default=0)


def test_ttl_bounds_tombstone_age_under_churn():
    ttl = 4
    tree = LSMTree(EngineConfig(T=3, K=(2,) * 6, buf_entries=16,
                                expected_entries=2000,
                                policy="tombstone_ttl",
                                policy_params=(("ttl_flushes", ttl),)))
    rng = np.random.default_rng(0)
    keys = rng.choice(100_000, size=400, replace=False)
    for k in keys:
        tree.put(int(k), int(k))
    dead = [int(k) for k in keys[::3]]
    for k in dead:
        tree.delete(k)
    # churn: every flush advances the clock; the sweep must keep up
    fresh = rng.choice(100_000, size=600, replace=False)
    for i, k in enumerate(fresh):
        tree.put(int(k) + 1_000_000, 0)
        if i % 16 == 0:
            assert _max_tomb_age(tree) < ttl, (i, _max_tomb_age(tree))
            assert tree.get(dead[0]) is None
    assert _max_tomb_age(tree) < ttl
    for k in dead[:100]:
        assert tree.get(k) is None, "deleted key resurfaced past its TTL"
    alive = [int(k) for k in keys if int(k) not in set(dead)]
    for k in alive[:100]:
        assert tree.get(k) == k


def test_ttl_invariant_at_fleet_level():
    """After a write-heavy fleet session churns the tree, the TTL bound
    still holds and every pre-session delete stays dead."""
    ttl = 6
    n = 4000
    sys_small = LSMSystem(N=float(n), entry_bits=64 * 8, page_bits=4096 * 8,
                          bits_per_entry=8.0, min_buf_bits=64 * 8 * 64,
                          s_rq=2e-5, max_T=30)
    phi = make_phi(4, 6.0 * n, 1.0, sys_small)
    tree = LSMTree.from_phi(phi, sys_small, expected_entries=n,
                            entry_bytes=64, policy="tombstone_ttl",
                            policy_params=(("ttl_flushes", ttl),))
    keys = populate(tree, n, seed=5, key_space=KEY_SPACE)
    dead = [int(k) for k in keys[::50]]
    for k in dead:
        tree.delete(k)
    tree.flush()
    fleet = run_fleet([tree], np.array([[0.05, 0.05, 0.05, 0.85]]), keys,
                      n_queries=3000, seeds=np.array([9]),
                      key_space=KEY_SPACE, range_fraction=1e-3)
    assert fleet[0][0].io.queries["w"] > 2000     # the churn happened
    assert _max_tomb_age(tree) < ttl
    for k in dead:
        assert tree.get(k) is None


# ---------------------------------------------------------------------------
# Planner unit tests + registry
# ---------------------------------------------------------------------------

def test_registry_builds_policies_and_rejects_unknown():
    cfg = _cfg("lazy_leveling", (("read_trigger", 17),))
    planner = make_planner(cfg)
    assert planner.read_trigger == 17 and planner.has_maintenance
    assert not make_planner(_cfg("klsm")).has_maintenance
    assert set(POLICIES) == {"klsm", "lazy_leveling", "partial",
                             "tombstone_ttl"}
    with pytest.raises(ValueError, match="unknown compaction policy"):
        make_planner(_cfg("rocksdb"))
    with pytest.raises(TypeError):
        make_planner(_cfg("partial", (("no_such_param", 1),)))


def test_partial_planner_emits_range_sliced_plans():
    """Capture the partial plans directly: load the tree over capacity with
    maintenance disarmed, then poll the planner by hand and watch the
    cursor walk the fence span in 1/parts strides."""
    tree = LSMTree(_cfg("partial", (("parts", 4),)))
    tree.planner.has_maintenance = False     # defer draining to the poll
    for k in range(16):
        tree.put(k, k)
    lv1 = tree.store.levels[0]
    assert lv1.entries == 16                 # over the capacity of 8
    tree.planner.has_maintenance = True
    planner = tree.planner

    plan = planner.plan_maintenance(tree.store, tree.stats, tree.flush_seq)[0]
    # span [0, 15], parts=4 -> first stride covers keys [0, 4)
    assert plan == MergePlan(kind="partial", level=1, run_ids=(0,),
                             target_level=2, drop_tombstones=True,
                             key_lo=0, key_hi=4)
    tree.store.execute(plan, None, tree.stats, 8.0)
    assert tree.store.levels[0].entries == 12    # still over capacity

    plan2 = planner.plan_maintenance(tree.store, tree.stats,
                                     tree.flush_seq)[0]
    assert plan2.kind == "partial" and plan2.key_lo == 4  # cursor advanced
    # drain to convergence: more partial slices (stride recomputed from the
    # shrinking remaining span), then clamps restoring level 2's K cap
    kinds = [plan.kind, plan2.kind]
    tree.store.execute(plan2, None, tree.stats, 8.0)
    for _ in range(20):
        plans = planner.plan_maintenance(tree.store, tree.stats,
                                         tree.flush_seq)
        if not plans:
            break
        kinds.append(plans[0].kind)
        tree.store.execute(plans[0], None, tree.stats, 8.0)
    else:
        pytest.fail("partial maintenance did not converge")
    assert kinds.count("partial") >= 3 and "clamp" in kinds
    lv1, lv2 = tree.store.levels[:2]
    assert lv1.entries <= 8                       # capacity restored
    assert lv1.num_runs == 1 and lv2.num_runs == 1  # K caps restored
    assert lv2.keys.tolist() == sorted(lv2.keys.tolist())
    for k in range(16):
        assert tree.get(k) == k


def test_lazy_planner_waits_for_read_pressure():
    tree = LSMTree(_cfg("lazy_leveling", (("read_trigger", 1000),)))
    for k in range(16):
        tree.put(k, k)
    runs_before = tree.shape()
    for k in range(8):
        tree.point_query(k)            # pressure stays under the trigger
    assert tree.shape() == runs_before
    tree.planner.read_trigger = 1      # now any read pressure triggers
    tree.point_query(0)
    deepest = tree.shape()[-1]
    assert len(deepest[1]) == 1        # deepest level squeezed to one run


# ---------------------------------------------------------------------------
# Cost-model hook + policy-axis fleet
# ---------------------------------------------------------------------------

def test_policy_effective_phi_profiles():
    sys = LSMSystem(N=1e6, bits_per_entry=10.0, max_levels=8)
    phi = make_phi(5, 8.0 * 1e6, 1.0, sys)
    lazy = policy_effective_phi(phi, sys, "lazy_leveling")
    L = int(num_levels(phi.T, mbuf_bits(phi, sys), sys))
    K = np.asarray(lazy.K)
    assert K[L - 1] == 1.0
    # calibrated sub-tiering steady state, not the K = T-1 ceiling
    k_up = 1.0 + LAZY_LEVELING_FILL * (5.0 - 2.0)
    assert np.allclose(K[: L - 1], k_up)
    assert np.all(K[: L - 1] < 4.0)            # strictly below the ceiling
    # a model-side fill override restores any profile, incl. the ceiling
    ceiling = policy_effective_phi(phi, sys, "lazy_leveling",
                                   (("fill", 1.0),))
    assert np.all(np.asarray(ceiling.K)[: L - 1] == 4.0)
    for pol in ("klsm", "partial", "tombstone_ttl"):
        assert policy_effective_phi(phi, sys, pol) is phi
    with pytest.raises(ValueError, match="unknown engine policy"):
        policy_effective_phi(phi, sys, "leveled")


def test_run_policy_fleet_klsm_column_matches_plain_fleet():
    n = 3000
    sys_small = LSMSystem(N=float(n), entry_bits=64 * 8, page_bits=4096 * 8,
                          bits_per_entry=8.0, min_buf_bits=64 * 8 * 64,
                          s_rq=2e-5, max_T=30)
    phi = make_phi(4, 6.0 * n, 1.0, sys_small)
    sessions = np.array([[0.25, 0.25, 0.25, 0.25], [0.05, 0.85, 0.05, 0.05]])
    trees, results = run_policy_fleet(
        [phi], sys_small, ["klsm", "lazy_leveling"], sessions, n_keys=n,
        seed=13, key_space=KEY_SPACE, range_fraction=1e-3, n_queries=400)
    assert len(trees) == 1 and len(trees[0]) == 2
    assert [len(r) for r in results[0]] == [2, 2]
    # reference: the same grid by hand for the klsm column
    keys = draw_keys(n, seed=13, key_space=KEY_SPACE)
    ref_tree = LSMTree.from_phi(phi, sys_small, expected_entries=n,
                                entry_bytes=64)
    populate(ref_tree, n, key_space=KEY_SPACE, keys=keys)
    ref = run_fleet([ref_tree], sessions, keys, n_queries=400,
                    key_space=KEY_SPACE, range_fraction=1e-3)
    for got, want in zip(results[0][0], ref[0]):
        assert dataclasses.asdict(got.io) == dataclasses.asdict(want.io)
    # the policy axis actually changed execution for the non-klsm column
    assert trees[0][1].cfg.policy == "lazy_leveling"


def test_merge_plan_slice_fields_default_none():
    p = MergePlan(kind="spill", level=1, run_ids=(0,), target_level=2)
    assert p.key_lo is None and p.key_hi is None
