"""Test-environment shims so the suite runs in minimal containers.

1. ``hypothesis`` fallback: several modules use hypothesis property tests.
   When the real library is absent (it is not part of the runtime deps), a
   tiny deterministic stub is registered instead: ``@given`` draws
   ``max_examples`` pseudo-random examples from the declared strategies with
   a fixed seed.  This keeps the property tests *running* (fixed-seed random
   sampling, no shrinking / database / edge-case heuristics) rather than
   failing at collection.  With real hypothesis installed the stub is inert.

   Stub mode is announced in the pytest report header, and CI's stub leg
   sets ``REPRO_HYPOTHESIS_STUB=skip`` so the stub-sampled tests report as
   *skipped* with a reason instead of passing under degraded coverage —
   the matrix's real-hypothesis leg is where they count.

2. Kernel tests (``test_kernels.py``, ``test_engine_kernels.py``) run on
   every container: kernels resolve the Pallas TPU CompilerParams class
   through ``repro.kernels._compat`` (``CompilerParams`` vs the older
   ``TPUCompilerParams`` spelling, or None when the TPU backend is
   absent), and the tests pin ``interpret=True`` so no Mosaic lowering
   is required.  The compiled leg is auto-selected by the ``ops.py``
   dispatch wrappers when the default backend is a real TPU.
"""

import importlib.util
import os
import random
import sys
import types

_HYPOTHESIS_STUBBED = importlib.util.find_spec("hypothesis") is None
_STUB_SKIP = os.environ.get("REPRO_HYPOTHESIS_STUB", "run") == "skip"

# --- 1. hypothesis fallback stub -------------------------------------------

if _HYPOTHESIS_STUBBED:
    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def _floats(min_value=0.0, max_value=1.0, **kw):
        return _Strategy(lambda r: r.uniform(min_value, max_value))

    def _integers(min_value=0, max_value=100):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    def _lists(elem, min_size=0, max_size=10):
        return _Strategy(lambda r: [elem.draw(r)
                                    for _ in range(r.randint(min_size,
                                                             max_size))])

    def _sampled_from(seq):
        items = list(seq)
        return _Strategy(lambda r: r.choice(items))

    def _given(*args, **kwargs):
        def deco(fn):
            def wrapper():
                if _STUB_SKIP:
                    import pytest
                    pytest.skip("hypothesis stub active (fixed-seed "
                                "sampling, no shrinking); the real-"
                                "hypothesis matrix leg runs this test")
                n = getattr(wrapper, "_stub_max_examples", 20)
                r = random.Random(1234)
                for _ in range(n):
                    fn(**{name: s.draw(r) for name, s in kwargs.items()})
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco

    def _settings(max_examples=20, deadline=None, **kw):
        def deco(fn):
            fn._stub_max_examples = max_examples
            return fn
        return deco

    _strategies = types.ModuleType("hypothesis.strategies")
    _strategies.floats = _floats
    _strategies.integers = _integers
    _strategies.lists = _lists
    _strategies.sampled_from = _sampled_from

    _hypothesis = types.ModuleType("hypothesis")
    _hypothesis.given = _given
    _hypothesis.settings = _settings
    _hypothesis.strategies = _strategies
    _hypothesis.__is_stub__ = True

    sys.modules["hypothesis"] = _hypothesis
    sys.modules["hypothesis.strategies"] = _strategies

# --- 2. pytest hooks ---------------------------------------------------------
# (test_kernels.py gates itself on the Pallas TPU API surface with a
# module-level pytest.skip, so its absence shows up as a skip with a reason
# rather than a silent collect_ignore.)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")


def pytest_report_header(config):
    if not _HYPOTHESIS_STUBBED:
        return "hypothesis: real library (shrinking + edge cases active)"
    mode = ("SKIPPING property tests (REPRO_HYPOTHESIS_STUB=skip)"
            if _STUB_SKIP else
            "fixed-seed sampling, no shrinking (set "
            "REPRO_HYPOTHESIS_STUB=skip to surface them as skips)")
    return f"hypothesis: STUB — {mode}"
