"""Test-environment shims so the suite runs in minimal containers.

1. ``hypothesis`` fallback: several modules use hypothesis property tests.
   When the real library is absent (it is not part of the runtime deps), a
   tiny deterministic stub is registered instead: ``@given`` draws
   ``max_examples`` pseudo-random examples from the declared strategies with
   a fixed seed.  This keeps the property tests *running* (fixed-seed random
   sampling, no shrinking / database / edge-case heuristics) rather than
   failing at collection.  With real hypothesis installed the stub is inert.

2. ``test_kernels.py`` targets the Pallas TPU API surface
   (``pltpu.CompilerParams``); on JAX builds that predate/postdate it the
   module cannot even construct its kernels, so it is skipped at collection
   (it never ran in such environments anyway).
"""

import importlib.util
import random
import sys
import types

# --- 1. hypothesis fallback stub -------------------------------------------

if importlib.util.find_spec("hypothesis") is None:
    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def _floats(min_value=0.0, max_value=1.0, **kw):
        return _Strategy(lambda r: r.uniform(min_value, max_value))

    def _integers(min_value=0, max_value=100):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    def _lists(elem, min_size=0, max_size=10):
        return _Strategy(lambda r: [elem.draw(r)
                                    for _ in range(r.randint(min_size,
                                                             max_size))])

    def _sampled_from(seq):
        items = list(seq)
        return _Strategy(lambda r: r.choice(items))

    def _given(*args, **kwargs):
        def deco(fn):
            def wrapper():
                n = getattr(wrapper, "_stub_max_examples", 20)
                r = random.Random(1234)
                for _ in range(n):
                    fn(**{name: s.draw(r) for name, s in kwargs.items()})
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco

    def _settings(max_examples=20, deadline=None, **kw):
        def deco(fn):
            fn._stub_max_examples = max_examples
            return fn
        return deco

    _strategies = types.ModuleType("hypothesis.strategies")
    _strategies.floats = _floats
    _strategies.integers = _integers
    _strategies.lists = _lists
    _strategies.sampled_from = _sampled_from

    _hypothesis = types.ModuleType("hypothesis")
    _hypothesis.given = _given
    _hypothesis.settings = _settings
    _hypothesis.strategies = _strategies
    _hypothesis.__is_stub__ = True

    sys.modules["hypothesis"] = _hypothesis
    sys.modules["hypothesis.strategies"] = _strategies

# --- 2. environment-gated modules -------------------------------------------

collect_ignore = []
try:
    from jax.experimental.pallas import tpu as _pltpu
    if not hasattr(_pltpu, "CompilerParams"):
        collect_ignore.append("test_kernels.py")
except Exception:
    collect_ignore.append("test_kernels.py")


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")
