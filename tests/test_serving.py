"""End-to-end batched serving tests (launch/serve.py)."""

import numpy as np
import pytest

from repro.launch.serve import serve_batch


@pytest.mark.parametrize("arch", ["qwen3-14b", "mixtral-8x7b", "rwkv6-3b"])
def test_serve_batch_produces_tokens(arch):
    out = serve_batch(arch, reduced=True, batch=2, prompt_len=8, gen=6,
                      seed=0)
    toks = out["tokens"]
    assert toks.shape == (2, 6)
    assert (toks >= 0).all()
    assert out["tok_per_s"] > 0


def test_serve_deterministic():
    a = serve_batch("qwen3-14b", reduced=True, batch=2, prompt_len=8,
                    gen=5, seed=3)
    b = serve_batch("qwen3-14b", reduced=True, batch=2, prompt_len=8,
                    gen=5, seed=3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
