"""Unit + property tests for the K-LSM cost model (paper Eqs. 1-9)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (DesignSpace, LSMSystem, cost_vector, expected_cost,
                        leveling_phi, make_phi, num_levels, tiering_phi,
                        to_phi)
from repro.core.lsm_cost import (Phi, empty_read_cost, level_fprs, level_mask,
                                 mbuf_bits, nonempty_read_cost, range_cost,
                                 write_cost)

SYS = LSMSystem()

T_strat = st.floats(min_value=2.0, max_value=100.0, allow_nan=False)
h_strat = st.floats(min_value=0.0, max_value=9.9, allow_nan=False)  # bits/entry
K_strat = st.floats(min_value=1.0, max_value=99.0, allow_nan=False)


@settings(max_examples=60, deadline=None)
@given(T=T_strat, h=h_strat, K=K_strat)
def test_cost_vector_finite_positive(T, h, K):
    phi = make_phi(T, h * SYS.N, K, SYS)
    c = np.asarray(cost_vector(phi, SYS))
    assert np.all(np.isfinite(c)), c
    assert np.all(c >= 0.0), c
    # A point lookup costs at least ~0 and a non-empty lookup at least ~1 I/O.
    assert c[1] >= 0.99


@settings(max_examples=40, deadline=None)
@given(T=T_strat, h=h_strat)
def test_more_filter_memory_reduces_empty_reads(T, h):
    lo = make_phi(T, h * SYS.N, 1.0, SYS)
    hi = make_phi(T, min(h + 2.0, 9.9) * SYS.N, 1.0, SYS)
    # Note: adding filter memory shrinks the buffer, which can add a level;
    # compare at equal level counts to isolate the Bloom effect.
    if float(num_levels(lo.T, mbuf_bits(lo, SYS), SYS)) == float(
            num_levels(hi.T, mbuf_bits(hi, SYS), SYS)):
        assert float(empty_read_cost(hi, SYS)) <= float(
            empty_read_cost(lo, SYS)) + 1e-9


@settings(max_examples=40, deadline=None)
@given(T=st.floats(min_value=3.0, max_value=50.0), h=h_strat)
def test_tiering_writes_cheaper_reads_dearer(T, h):
    """Leveling optimizes reads, tiering writes (Section 2)."""
    lev = leveling_phi(T, h * SYS.N, SYS)
    tier = tiering_phi(T, h * SYS.N, SYS)
    assert float(write_cost(tier, SYS)) <= float(write_cost(lev, SYS)) + 1e-9
    assert float(empty_read_cost(tier, SYS)) >= float(
        empty_read_cost(lev, SYS)) - 1e-9
    assert float(range_cost(tier, SYS)) >= float(range_cost(lev, SYS)) - 1e-9


def test_levels_eq1_exact():
    # L = ceil(log_T(N E / m_buf + 1))
    phi = leveling_phi(10.0, 2.0 * SYS.N, SYS)
    mbuf = mbuf_bits(phi, SYS)
    expect = np.ceil(np.log(SYS.N * SYS.entry_bits / float(mbuf) + 1) /
                     np.log(10.0))
    assert float(num_levels(phi.T, mbuf, SYS)) == expect


def test_monkey_fprs_monotone_deeper_levels():
    """Eq. 3: deeper levels get larger FPR (less filter memory per entry)."""
    phi = leveling_phi(8.0, 5.0 * SYS.N, SYS)
    f = np.asarray(level_fprs(phi, SYS))
    m = np.asarray(level_mask(phi, SYS))
    L = int(m.sum())
    assert np.all(np.diff(f[:L]) >= -1e-12)
    assert np.all(f <= 1.0 + 1e-6)


def test_design_reductions_match_closed_forms():
    """Table 3: K-LSM with the right K vector reproduces each design."""
    theta = jnp.zeros((2 + SYS.max_levels,))
    for design, ref_K in [
        (DesignSpace.LEVELING, 1.0),
        (DesignSpace.TIERING, None),
    ]:
        phi = to_phi(theta[:2], design, SYS)
        T = float(phi.T)
        K = np.asarray(phi.K)
        if ref_K is not None:
            assert np.allclose(K, ref_K)
        else:
            assert np.allclose(K, T - 1.0)

    phi_lazy = to_phi(theta[:2], DesignSpace.LAZY_LEVELING, SYS)
    L = int(num_levels(phi_lazy.T, mbuf_bits(phi_lazy, SYS), SYS))
    K = np.asarray(phi_lazy.K)
    assert K[L - 1] == 1.0
    assert np.allclose(K[:L - 1], float(phi_lazy.T) - 1.0)

    phi_1lvl = to_phi(theta[:2], DesignSpace.ONE_LEVELING, SYS)
    K = np.asarray(phi_1lvl.K)
    assert K[0] == float(phi_1lvl.T) - 1.0 and np.allclose(K[1:], 1.0)


def test_klsm_generalizes_leveling_cost():
    """cost(K-LSM with K=1) == cost(leveling) at identical (T, m_filt)."""
    phi_lev = leveling_phi(12.0, 6.0 * SYS.N, SYS)
    phi_klsm = make_phi(12.0, 6.0 * SYS.N, 1.0, SYS)
    np.testing.assert_allclose(np.asarray(cost_vector(phi_lev, SYS)),
                               np.asarray(cost_vector(phi_klsm, SYS)))


def test_write_cost_eq9_hand_computed():
    """Eq. 9 against a hand computation for T=5, leveling, 3 levels."""
    sys = LSMSystem(N=1e6, entry_bits=8192, bits_per_entry=10.0,
                    min_buf_bits=8192 * 128)
    phi = leveling_phi(5.0, 5.0 * sys.N, sys)
    mbuf = float(mbuf_bits(phi, sys))
    L = float(num_levels(phi.T, mbuf, sys))
    per_level = (5.0 - 1.0 + 1.0) / 2.0
    expect = sys.f_seq * (1 + sys.f_a) / sys.B * per_level * L
    np.testing.assert_allclose(float(write_cost(phi, sys)), expect, rtol=1e-5)


def test_range_cost_eq7_hand_computed():
    phi = leveling_phi(10.0, 5.0 * SYS.N, SYS)
    L = float(num_levels(phi.T, mbuf_bits(phi, SYS), SYS))
    expect = SYS.f_seq * SYS.s_rq * SYS.N / SYS.B + L  # K_i = 1
    np.testing.assert_allclose(float(range_cost(phi, SYS)), expect, rtol=1e-6)


def test_rounding_respects_bounds():
    phi = make_phi(7.3, 5 * SYS.N, 3.7, SYS).round_integral(SYS)
    assert float(phi.T) == 8.0
    K = np.asarray(phi.K)
    assert np.all((K >= 1.0) & (K <= 7.0))
