"""Paper Table 5 + Figures 12-17 analogue: SYSTEM-measured (not model)
delta throughput of robust vs nominal tunings on the executable LSM engine.

Per expected workload: deploy Phi_N and Phi_R at reduced scale, execute
drifted workload sessions sampled from the uncertainty benchmark, and
measure avg I/O per query.

The whole evaluation is ONE declarative spec: five expected workloads, the
nominal baseline plus rho=1 robust cells, and a Table-5 trial
(``per_workload_keys``: the nominal/robust pair of a workload shares its
key draw and session seeds, so the facade's fleet call materializes each
drifted session once and replays it on both trees).  The facade lowers it
onto the same two batched-tuner dispatches and single ``run_fleet`` grid
the hand-wired version used, at 250k keys x 10k queries per session.

Claims validated:
  * robust beats nominal on most expected workloads (Table 5: 10 of 15,
    2 slight losses);
  * robust tunings choose leveling ("leveling is more robust", Sec. 11);
  * model-predicted and engine-measured RANKING of the two tunings agree
    (Figures 12-15 'model matches system').
"""

from __future__ import annotations

from typing import List

import numpy as np

from repro.api import (ExperimentSpec, Row, TrialSpec, WorkloadSpec,
                       run_experiment)

N_KEYS = 250_000
QUERIES = 10_000
KEY_SPACE = 2 ** 26    # dense keyspace so ranges overlap runs
RANGE_FRACTION = 1e-3
RHO = 1.0
BITS_PER_ENTRY = 6.0   # memory-constrained: deeper trees (L=2-4) at small N
MAX_T = 30             # cap T so the scaled-down tree cannot degenerate to L=1
WIDX = (0, 4, 7, 11, 13)
# drifted sessions: dominant query type >= 80% (paper Section 9.2)
SESSIONS = (
    (0.85, 0.05, 0.05, 0.05),
    (0.05, 0.85, 0.05, 0.05),
    (0.05, 0.05, 0.85, 0.05),
    (0.05, 0.05, 0.05, 0.85),
)

def make_spec(widx_list=WIDX) -> ExperimentSpec:
    return ExperimentSpec(
        name="tab5",
        workload=WorkloadSpec(indices=tuple(widx_list), rhos=(RHO,),
                              nominal=True),
        trial=TrialSpec(n_keys=N_KEYS, n_queries=QUERIES, sessions=SESSIONS,
                        key_space=KEY_SPACE, range_fraction=RANGE_FRACTION,
                        per_workload_keys=True, key_seed=100),
        system=(("N", float(N_KEYS)), ("entry_bits", 64.0 * 8),
                ("page_bits", 4096.0 * 8),
                ("bits_per_entry", BITS_PER_ENTRY),
                ("min_buf_bits", 64.0 * 8 * 64), ("s_rq", 2e-5),
                ("max_T", float(MAX_T))),
    )


SPEC = make_spec()


def run(widx_list=WIDX) -> List[Row]:
    report = run_experiment(make_spec(widx_list))

    rows: List[Row] = []
    n_wins = 0
    ranking_agree = 0
    leveling_robust = 0
    for i, widx in enumerate(widx_list):
        rn, rr = report.tuning((i, None)), report.tuning((i, RHO))
        io_n = float(report.measured_io((i, None)).mean())
        io_r = float(report.measured_io((i, RHO)).mean())
        delta = (1.0 / io_r - 1.0 / io_n) / (1.0 / io_n)
        n_wins += delta > 0
        # model prediction for the same drifted sessions
        cn = float(report.model_session_io((i, None), SESSIONS).mean())
        cr = float(report.model_session_io((i, RHO), SESSIONS).mean())
        ranking_agree += (cr < cn) == (io_r < io_n)
        leveling_robust += bool(np.allclose(np.asarray(rr.phi.K)[:2], 1.0))
        rows.append(Row(
            f"tab5_system_w{widx}", 0.0,
            engine_io_nominal=round(io_n, 3),
            engine_io_robust=round(io_r, 3),
            measured_delta_tp=round(delta, 3),
            model_predicts_robust=cr < cn,
            nominal=f"T{float(rn.phi.T):.0f}",
            robust=f"T{float(rr.phi.T):.0f}",
        ))
    walls = report.walls
    rows.append(Row(
        "tab5_fleet", report.wall_time_s * 1e6,
        n_keys=N_KEYS, n_queries=QUERIES,
        trees=len(report.fleet), sessions_per_tree=len(SESSIONS),
        tuning_s=round(walls["tuning_s"], 2),
        populate_s=round(walls["populate_s"], 2),
        engine_s=round(walls["populate_s"] + walls["fleet_s"], 2),
    ))
    rows.append(Row(
        "tab5_summary", 0.0,
        robust_wins=f"{n_wins}/{len(widx_list)}",
        claim_majority_wins=n_wins >= 3,
        note="paper Table 5 itself reports robust losses on w13/w14 and ~0 "
             "on uniform w0 - the same cells lose here",
        model_system_ranking_agreement=f"{ranking_agree}/{len(widx_list)}",
        claim_leveling_is_robust=leveling_robust == len(widx_list),
    ))
    return rows
