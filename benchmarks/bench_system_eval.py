"""Paper Table 5 + Figures 12-17 analogue: SYSTEM-measured (not model)
delta throughput of robust vs nominal tunings on the executable LSM engine.

Per expected workload: deploy Phi_N and Phi_R at reduced scale
(LSMTree.from_phi), execute drifted workload sessions sampled from the
uncertainty benchmark (dominant-query sessions like the paper's
empty-read/read/range/write sessions), and measure avg I/O per query.

The whole evaluation runs as one grid: the tunings come from a single
``tune_nominal_many`` / ``tune_robust_many`` dispatch over every expected
workload, and the (tuning x drifted-session) engine matrix is one
``run_fleet`` call over the populated trees — the columnar engine's batched
read/write/range primitives carry each session.  The scale (250k keys, 10k
queries per session) is ~20x the pre-refactor engine's 60k x 2k at lower
wall clock.

Claims validated:
  * robust beats nominal on most expected workloads (Table 5: 10 of 15,
    2 slight losses);
  * robust tunings choose leveling ("leveling is more robust", Sec. 11);
  * model-predicted and engine-measured RANKING of the two tunings agree
    (Figures 12-15 'model matches system').
"""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import (EXPECTED_WORKLOADS, LSMSystem, cost_vector,
                        tune_nominal_many, tune_robust_many)
from repro.lsm import LSMTree, draw_keys, populate, run_fleet
from .common import Row

N_KEYS = 250_000
QUERIES = 10_000
KEY_SPACE = 2 ** 26    # dense keyspace so ranges overlap runs
RANGE_FRACTION = 1e-3
RHO = 1.0
BITS_PER_ENTRY = 6.0   # memory-constrained: deeper trees (L=2-4) at small N
MAX_T = 30             # cap T so the scaled-down tree cannot degenerate to L=1
# drifted sessions: dominant query type >= 80% (paper Section 9.2)
SESSIONS = np.array([
    [0.85, 0.05, 0.05, 0.05],
    [0.05, 0.85, 0.05, 0.05],
    [0.05, 0.05, 0.85, 0.05],
    [0.05, 0.05, 0.05, 0.85],
])


def run(widx_list=(0, 4, 7, 11, 13)) -> List[Row]:
    sys_small = LSMSystem(N=float(N_KEYS), entry_bits=64 * 8,
                          page_bits=4096 * 8, bits_per_entry=BITS_PER_ENTRY,
                          min_buf_bits=64 * 8 * 64, s_rq=2e-5, max_T=MAX_T)
    W = np.stack([EXPECTED_WORKLOADS[w] for w in widx_list])

    t0 = time.time()
    nominals = tune_nominal_many(W, sys_small, seed=0)
    robusts = [row[0] for row in tune_robust_many(W, [RHO], sys_small,
                                                  seed=0)]
    tuning_s = time.time() - t0

    # one populated tree per tuning; the nominal/robust pair of a workload
    # shares its key draw and session seeds, so run_fleet materializes each
    # drifted session once and replays it on both trees
    t0 = time.time()
    trees, keys_list, seed_rows = [], [], []
    for widx, rn, rr in zip(widx_list, nominals, robusts):
        keys = draw_keys(N_KEYS, seed=100 + widx, key_space=KEY_SPACE)
        for tuning in (rn, rr):
            tree = LSMTree.from_phi(tuning.phi, sys_small,
                                    expected_entries=N_KEYS, entry_bytes=64)
            populate(tree, N_KEYS, key_space=KEY_SPACE, keys=keys)
            trees.append(tree)
            keys_list.append(keys)
            seed_rows.append([100 + widx + i for i in range(len(SESSIONS))])
    populate_s = time.time() - t0

    t0 = time.time()
    fleet = run_fleet(trees, SESSIONS, keys_list, n_queries=QUERIES,
                      seeds=np.asarray(seed_rows), key_space=KEY_SPACE,
                      range_fraction=RANGE_FRACTION)
    fleet_s = time.time() - t0

    rows: List[Row] = []
    n_wins = 0
    ranking_agree = 0
    leveling_robust = 0
    for i, widx in enumerate(widx_list):
        rn, rr = nominals[i], robusts[i]
        io_n = float(np.mean([r.avg_io_per_query for r in fleet[2 * i]]))
        io_r = float(np.mean([r.avg_io_per_query for r in fleet[2 * i + 1]]))
        delta = (1.0 / io_r - 1.0 / io_n) / (1.0 / io_n)
        n_wins += delta > 0
        # model prediction for the same drifted sessions
        cn = float(np.mean(SESSIONS @ np.asarray(
            cost_vector(rn.phi, sys_small), np.float64)))
        cr = float(np.mean(SESSIONS @ np.asarray(
            cost_vector(rr.phi, sys_small), np.float64)))
        ranking_agree += (cr < cn) == (io_r < io_n)
        leveling_robust += bool(np.allclose(np.asarray(rr.phi.K)[:2], 1.0))
        rows.append(Row(
            f"tab5_system_w{widx}", 0.0,
            engine_io_nominal=round(io_n, 3),
            engine_io_robust=round(io_r, 3),
            measured_delta_tp=round(delta, 3),
            model_predicts_robust=cr < cn,
            nominal=f"T{float(rn.phi.T):.0f}",
            robust=f"T{float(rr.phi.T):.0f}",
        ))
    rows.append(Row(
        "tab5_fleet", (tuning_s + populate_s + fleet_s) * 1e6,
        n_keys=N_KEYS, n_queries=QUERIES,
        trees=len(trees), sessions_per_tree=len(SESSIONS),
        tuning_s=round(tuning_s, 2),
        populate_s=round(populate_s, 2),
        engine_s=round(populate_s + fleet_s, 2),
    ))
    rows.append(Row(
        "tab5_summary", 0.0,
        robust_wins=f"{n_wins}/{len(widx_list)}",
        claim_majority_wins=n_wins >= 3,
        note="paper Table 5 itself reports robust losses on w13/w14 and ~0 "
             "on uniform w0 - the same cells lose here",
        model_system_ranking_agreement=f"{ranking_agree}/{len(widx_list)}",
        claim_leveling_is_robust=leveling_robust == len(widx_list),
    ))
    return rows
