"""Paper Table 5 + Figures 12-17 analogue: SYSTEM-measured (not model)
delta throughput of robust vs nominal tunings on the executable LSM engine.

Per expected workload: deploy Phi_N and Phi_R at reduced scale
(LSMTree.from_phi), execute drifted workload sessions sampled from the
uncertainty benchmark (dominant-query sessions like the paper's
empty-read/read/range/write sessions), and measure avg I/O per query.

Claims validated:
  * robust beats nominal on most expected workloads (Table 5: 10 of 15,
    2 slight losses);
  * robust tunings choose leveling ("leveling is more robust", Sec. 11);
  * model-predicted and engine-measured RANKING of the two tunings agree
    (Figures 12-15 'model matches system').
"""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import (EXPECTED_WORKLOADS, LSMSystem, cost_vector,
                        tune_nominal, tune_robust)
from repro.lsm import LSMTree, populate, run_session
from .common import Row

N_KEYS = 60_000
QUERIES = 2_000
RHO = 1.0
BITS_PER_ENTRY = 6.0   # memory-constrained: deeper trees (L=2-4) at small N
MAX_T = 30             # cap T so the scaled-down tree cannot degenerate to L=1
# drifted sessions: dominant query type >= 80% (paper Section 9.2)
SESSIONS = np.array([
    [0.85, 0.05, 0.05, 0.05],
    [0.05, 0.85, 0.05, 0.05],
    [0.05, 0.05, 0.85, 0.05],
    [0.05, 0.05, 0.05, 0.85],
])


def _engine_cost(phi, sys_small, seed: int) -> float:
    tree = LSMTree.from_phi(phi, sys_small, expected_entries=N_KEYS,
                            entry_bytes=64)
    keys = populate(tree, N_KEYS, seed=seed, key_space=2 ** 26)
    total = 0.0
    for i, sess in enumerate(SESSIONS):
        res = run_session(tree, keys, sess, n_queries=QUERIES,
                          seed=seed + i, key_space=2 ** 26,
                          range_fraction=1e-3)
        total += res.avg_io_per_query
    return total / len(SESSIONS)


def run(widx_list=(0, 4, 7, 11, 13)) -> List[Row]:
    sys_small = LSMSystem(N=float(N_KEYS), entry_bits=64 * 8,
                          page_bits=4096 * 8, bits_per_entry=BITS_PER_ENTRY,
                          min_buf_bits=64 * 8 * 64, s_rq=2e-5, max_T=MAX_T)
    rows: List[Row] = []
    n_wins = 0
    ranking_agree = 0
    leveling_robust = 0
    for widx in widx_list:
        w = EXPECTED_WORKLOADS[widx]
        t0 = time.time()
        rn = tune_nominal(w, sys_small, seed=0)
        rr = tune_robust(w, RHO, sys_small, seed=0)
        io_n = _engine_cost(rn.phi, sys_small, seed=100 + widx)
        io_r = _engine_cost(rr.phi, sys_small, seed=100 + widx)
        us = (time.time() - t0) * 1e6

        delta = (1.0 / io_r - 1.0 / io_n) / (1.0 / io_n)
        n_wins += delta > 0
        # model prediction for the same drifted sessions
        cn = float(np.mean(SESSIONS @ np.asarray(
            cost_vector(rn.phi, sys_small), np.float64)))
        cr = float(np.mean(SESSIONS @ np.asarray(
            cost_vector(rr.phi, sys_small), np.float64)))
        ranking_agree += (cr < cn) == (io_r < io_n)
        leveling_robust += bool(np.allclose(np.asarray(rr.phi.K)[:2], 1.0))
        rows.append(Row(
            f"tab5_system_w{widx}", us,
            engine_io_nominal=round(io_n, 3),
            engine_io_robust=round(io_r, 3),
            measured_delta_tp=round(delta, 3),
            model_predicts_robust=cr < cn,
            nominal=f"T{float(rn.phi.T):.0f}",
            robust=f"T{float(rr.phi.T):.0f}",
        ))
    rows.append(Row(
        "tab5_summary", 0.0,
        robust_wins=f"{n_wins}/{len(widx_list)}",
        claim_majority_wins=n_wins >= 3,
        note="paper Table 5 itself reports robust losses on w13/w14 and ~0 "
             "on uniform w0 - the same cells lose here",
        model_system_ranking_agreement=f"{ranking_agree}/{len(widx_list)}",
        claim_leveling_is_robust=leveling_robust == len(widx_list),
    ))
    return rows
