"""Paper Figure 4: nominal tunings of flexible vs classic LSM designs.

For the mixed read/write workload (w7) and the read-heavy workload (w11),
solve NOMINAL TUNING per design and report average I/Os per query
normalized to K-LSM (hatched-cyan best performer in the paper's figure).

Expected outcome (paper 5.3): the flexible designs (K-LSM, Fluid) always
match-or-beat the others; w11 collapses to leveling; Dostoevsky (fixed
memory) is worst because it cannot move memory between buffer and filters.

Both workloads are tuned per design in one batched dispatch."""

from __future__ import annotations

import time
from typing import List

from repro.core import EXPECTED_WORKLOADS, DesignSpace, tune_nominal_many
from .common import SYS, Row

DESIGNS = [
    ("leveling", DesignSpace.LEVELING),
    ("tiering", DesignSpace.TIERING),
    ("lazy_leveling", DesignSpace.LAZY_LEVELING),
    ("1-leveling", DesignSpace.ONE_LEVELING),
    ("dostoevsky", DesignSpace.DOSTOEVSKY),
    ("fluid", DesignSpace.FLUID),
    ("klsm", DesignSpace.KLSM),
]
WIDX = (7, 11)


def run() -> List[Row]:
    W = EXPECTED_WORKLOADS[list(WIDX)]
    t0 = time.time()
    costs = {}            # name -> [cost for w7, cost for w11]
    for name, design in DESIGNS:
        n_starts = 192 if design is DesignSpace.KLSM else 64
        results = tune_nominal_many(W, SYS, design, n_starts=n_starts,
                                    seed=0)
        costs[name] = [r.cost for r in results]
    us = (time.time() - t0) * 1e6 / (len(DESIGNS) * len(WIDX))

    rows: List[Row] = []
    for k, widx in enumerate(WIDX):
        per_design = {name: c[k] for name, c in costs.items()}
        base = per_design["klsm"]
        derived = {f"io_norm_{name}": round(v / base, 3)
                   for name, v in per_design.items()}
        # paper claims: flexible designs produce the best tunings
        derived["klsm_best"] = all(base <= v * 1.02
                                   for v in per_design.values())
        derived["klsm_io"] = round(base, 3)
        rows.append(Row(f"fig4_nominal_designs_w{widx}", us, **derived))
    return rows
