"""Paper Figure 4: nominal tunings of flexible vs classic LSM designs.

For the mixed read/write workload (w7) and the read-heavy workload (w11),
solve NOMINAL TUNING per design and report average I/Os per query
normalized to K-LSM (hatched-cyan best performer in the paper's figure).

Expected outcome (paper 5.3): the flexible designs (K-LSM, Fluid) always
match-or-beat the others; w11 collapses to leveling; Dostoevsky (fixed
memory) is worst because it cannot move memory between buffer and filters.
"""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import EXPECTED_WORKLOADS, DesignSpace, expected_cost, tune_nominal
from .common import SYS, Row

DESIGNS = [
    ("leveling", DesignSpace.LEVELING),
    ("tiering", DesignSpace.TIERING),
    ("lazy_leveling", DesignSpace.LAZY_LEVELING),
    ("1-leveling", DesignSpace.ONE_LEVELING),
    ("dostoevsky", DesignSpace.DOSTOEVSKY),
    ("fluid", DesignSpace.FLUID),
    ("klsm", DesignSpace.KLSM),
]


def run() -> List[Row]:
    rows: List[Row] = []
    for widx in (7, 11):
        w = EXPECTED_WORKLOADS[widx]
        costs = {}
        t0 = time.time()
        for name, design in DESIGNS:
            n_starts = 192 if design is DesignSpace.KLSM else 64
            r = tune_nominal(w, SYS, design, n_starts=n_starts, seed=0)
            costs[name] = r.cost
        us = (time.time() - t0) * 1e6 / len(DESIGNS)
        base = costs["klsm"]
        derived = {f"io_norm_{k}": round(v / base, 3)
                   for k, v in costs.items()}
        # paper claims: flexible designs produce the best tunings
        klsm_best = all(base <= v * 1.02 for v in costs.values())
        derived["klsm_best"] = klsm_best
        derived["klsm_io"] = round(base, 3)
        rows.append(Row(f"fig4_nominal_designs_w{widx}", us, **derived))
    return rows
