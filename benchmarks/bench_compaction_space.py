"""Compaction design-space evaluation: measured vs model, per policy.

ONE declarative spec deploys a single pinned tuning (``DesignSpec.fixed``)
under every compaction policy in the planner registry (K-LSM baseline +
lazy leveling + partial compaction + tombstone-TTL) — the policy axis as
discrete arms — populates each tree from a shared 250k-key draw, seeds real
tombstones (1% deletes, so the TTL sweeps have something to age out), and
runs the same four drifted 10k-query sessions against every tree as ONE
fleet grid: the Section 9 experiment design extended along the
Sarkar-taxonomy policy axis.

Per policy the suite reports measured avg I/O per query per session next
to the cost model's prediction through
:func:`repro.core.policy_effective_phi` (the policy's steady-state K
profile), plus the policy-specific invariants from the facade's tree
probes: the lazy tree's last-level run count (read pressure keeps it
squeezed), the TTL tree's maximum surviving tombstone age, and that
deletes never resurface.

Claims validated:
  * the model's predicted ORDERING of policies by cost matches the
    engine's measured ordering on most distinguishable (policy, policy,
    session) pairs (the design-space analogue of 'model matches system');
  * lazy leveling cuts write I/O vs leveling while read-triggered
    squeezes keep point reads close to leveled cost;
  * tombstone-TTL bounds delete persistence (max tombstone age <= TTL)
    at a measurable write-amplification premium on write-heavy sessions.

The lazy-leveling prediction uses the *calibrated sub-tiering* steady
state (``repro.core.LAZY_LEVELING_FILL``, measured ~1-1.6 live runs per
upper level) instead of the old K = T-1 tiering ceiling, which documented
a ~2x overestimate (agreement 0.45) on range-heavy mixes; the
agreement_ratio column reports the remaining honest gap.
"""

from __future__ import annotations

from typing import List

import numpy as np

from repro.api import (DesignSpec, ExperimentSpec, Row, TrialSpec,
                       WorkloadSpec, run_experiment)

N_KEYS = 250_000
QUERIES = 10_000
KEY_SPACE = 2 ** 26    # dense keyspace so ranges overlap runs
RANGE_FRACTION = 1e-3  # of the keyspace == expected fraction of N per range,
                       # so the model system below uses s_rq = RANGE_FRACTION
BITS_PER_ENTRY = 6.0   # memory-constrained: deeper trees at small N
DELETE_FRACTION = 0.01
TTL_FLUSHES = 8        # short enough that sweeps fire inside the sessions
T, FILT_BPE = 6, 4.0   # one mid-range leveled tuning, shared by all policies

POLICIES = ("klsm", "lazy_leveling", "partial", "tombstone_ttl")
# drifted sessions: dominant query type >= 80% (paper Section 9.2)
SESSIONS = (
    (0.85, 0.05, 0.05, 0.05),
    (0.05, 0.85, 0.05, 0.05),
    (0.05, 0.05, 0.85, 0.05),
    (0.05, 0.05, 0.05, 0.85),
)

SPEC = ExperimentSpec(
    name="compaction",
    workload=WorkloadSpec(workloads=((0.25, 0.25, 0.25, 0.25),),
                          rhos=(), nominal=True),
    design=DesignSpec(fixed=(float(T), FILT_BPE, 1.0), policies=POLICIES,
                      policy_params=(
                          ("lazy_leveling", (("read_trigger", 512),)),
                          ("partial", (("parts", 4),)),
                          ("tombstone_ttl", (("ttl_flushes", TTL_FLUSHES),)),
                      )),
    trial=TrialSpec(n_keys=N_KEYS, n_queries=QUERIES, sessions=SESSIONS,
                    key_space=KEY_SPACE, range_fraction=RANGE_FRACTION,
                    key_seed=77, session_seeds=(200, 201, 202, 203),
                    delete_fraction=DELETE_FRACTION),
    system=(("N", float(N_KEYS)), ("entry_bits", 64.0 * 8),
            ("page_bits", 4096.0 * 8), ("bits_per_entry", BITS_PER_ENTRY),
            ("min_buf_bits", 64.0 * 8 * 64), ("s_rq", RANGE_FRACTION),
            ("max_T", 30.0)),
)
CELL = (0, None)       # the single pinned-tuning cell


def run() -> List[Row]:
    report = run_experiment(SPEC)

    rows: List[Row] = []
    measured_by_policy, model_by_policy = {}, {}
    for pol in POLICIES:
        measured = report.measured_io(CELL, pol)
        model = report.model_session_io(CELL, SESSIONS, pol)
        measured_by_policy[pol] = measured
        model_by_policy[pol] = model
        probe = report.probes[(CELL, pol)]
        rows.append(Row(
            f"compaction_{pol}", 0.0,
            measured_io=[round(float(x), 3) for x in measured],
            model_io=[round(float(x), 3) for x in model],
            agreement_ratio=round(float(measured.mean() / model.mean()), 3),
            last_level_runs=probe.last_level_runs,
            max_tombstone_age_flushes=int(probe.max_tombstone_age),
            dead_keys_resurfaced=probe.dead_keys_resurfaced,
        ))

    # model-vs-system ranking agreement, pairwise per drifted session: only
    # pairs the model actually distinguishes (>2% predicted gap) count —
    # klsm/partial/tombstone_ttl share a steady-state profile, so the model
    # deliberately predicts ties for them
    agree = total = 0
    for s in range(len(SESSIONS)):
        for a in range(len(POLICIES)):
            for b in range(a + 1, len(POLICIES)):
                dm = model_by_policy[POLICIES[a]][s] \
                    - model_by_policy[POLICIES[b]][s]
                if abs(dm) < 0.02 * model_by_policy[POLICIES[a]][s]:
                    continue
                de = measured_by_policy[POLICIES[a]][s] \
                    - measured_by_policy[POLICIES[b]][s]
                total += 1
                agree += (dm > 0) == (de > 0)
    lazy_w = float(measured_by_policy["lazy_leveling"][3])
    klsm_w = float(measured_by_policy["klsm"][3])
    ttl_probe = report.probes[(CELL, "tombstone_ttl")]
    rows.append(Row(
        "compaction_summary", 0.0,
        policies=len(POLICIES),
        pairwise_rank_agreement=f"{agree}/{total}",
        lazy_beats_leveling_on_writes=lazy_w < klsm_w,
        ttl_bound_holds=all(age < TTL_FLUSHES
                            for age in ttl_probe.tomb_ages),
    ))
    walls = report.walls
    rows.append(Row(
        "compaction_fleet", report.wall_time_s * 1e6,
        n_keys=N_KEYS, n_queries=QUERIES, trees=len(report.fleet),
        sessions_per_tree=len(SESSIONS),
        populate_s=round(walls["populate_s"], 2),
        engine_s=round(walls["populate_s"] + walls["fleet_s"], 2),
    ))
    return rows
