"""Compaction design-space evaluation: measured vs model, per policy.

Deploys ONE tuning under every compaction policy in the planner registry
(K-LSM baseline + lazy leveling + partial compaction + tombstone-TTL),
populates each tree from a shared 250k-key draw, seeds real tombstones
(1% deletes, so the TTL sweeps have something to age out), and runs the
same four drifted 10k-query sessions against every tree as ONE
``run_fleet`` grid — the Section 9 experiment design extended along the
Sarkar-taxonomy policy axis.

Per policy the suite reports measured avg I/O per query per session next
to the cost model's prediction through
:func:`repro.core.policy_effective_phi` (the policy's steady-state K
profile), plus the policy-specific invariants: the lazy tree's last-level
run count (read pressure keeps it squeezed), the TTL tree's maximum
surviving tombstone age, and the partial tree's bounded per-trigger merge
size.

Claims validated:
  * the model's predicted ORDERING of policies by cost matches the
    engine's measured ordering on most distinguishable (policy, policy,
    session) pairs (the design-space analogue of 'model matches system');
  * lazy leveling cuts write I/O vs leveling while read-triggered
    squeezes keep point reads close to leveled cost;
  * tombstone-TTL bounds delete persistence (max tombstone age <= TTL)
    at a measurable write-amplification premium on write-heavy sessions.

Known, expected discrepancy: the lazy-leveling prediction assumes the
full tiering steady state (K_i = T-1 runs on every upper level), but the
measured tree runs *below* that — read-triggered squeezes plus fence
pointers that skip non-overlapping runs (the paper's own Figure 12
range-query discrepancy) make measured cost ~2x lower than predicted.
The agreement_ratio column reports this honestly rather than fitting
the model to the engine.
"""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import LSMSystem, cost_vector, make_phi, policy_effective_phi
from repro.lsm import IOStats, LSMTree, draw_keys, populate, run_fleet
from .common import Row

N_KEYS = 250_000
QUERIES = 10_000
KEY_SPACE = 2 ** 26    # dense keyspace so ranges overlap runs
RANGE_FRACTION = 1e-3  # of the keyspace == expected fraction of N per range,
                       # so the model system below uses s_rq = RANGE_FRACTION
BITS_PER_ENTRY = 6.0   # memory-constrained: deeper trees at small N
DELETE_FRACTION = 0.01
TTL_FLUSHES = 8        # short enough that sweeps fire inside the sessions
T, FILT_BPE = 6, 4.0   # one mid-range leveled tuning, shared by all policies

POLICY_PARAMS = {
    "klsm": (),
    "lazy_leveling": (("read_trigger", 512),),
    "partial": (("parts", 4),),
    "tombstone_ttl": (("ttl_flushes", TTL_FLUSHES),),
}
# drifted sessions: dominant query type >= 80% (paper Section 9.2)
SESSIONS = np.array([
    [0.85, 0.05, 0.05, 0.05],
    [0.05, 0.85, 0.05, 0.05],
    [0.05, 0.05, 0.85, 0.05],
    [0.05, 0.05, 0.05, 0.85],
])


def run() -> List[Row]:
    policies = list(POLICY_PARAMS)
    sys_small = LSMSystem(N=float(N_KEYS), entry_bits=64 * 8,
                          page_bits=4096 * 8, bits_per_entry=BITS_PER_ENTRY,
                          min_buf_bits=64 * 8 * 64, s_rq=RANGE_FRACTION,
                          max_T=30)
    phi = make_phi(T, FILT_BPE * N_KEYS, 1.0, sys_small)

    t0 = time.time()
    keys = draw_keys(N_KEYS, seed=77, key_space=KEY_SPACE)
    dead = keys[:: int(1 / DELETE_FRACTION)]
    trees = []
    for pol in policies:
        tree = LSMTree.from_phi(phi, sys_small, expected_entries=N_KEYS,
                                entry_bytes=64, policy=pol,
                                policy_params=POLICY_PARAMS[pol])
        populate(tree, N_KEYS, key_space=KEY_SPACE, keys=keys)
        for k in dead:                    # seed tombstones for TTL sweeps
            tree.delete(int(k))
        tree.flush()
        tree.stats = IOStats()            # deletes are setup, not workload
        trees.append(tree)
    populate_s = time.time() - t0

    t0 = time.time()
    fleet = run_fleet(trees, SESSIONS, keys, n_queries=QUERIES,
                      seeds=np.arange(200, 200 + len(SESSIONS)),
                      key_space=KEY_SPACE, range_fraction=RANGE_FRACTION)
    fleet_s = time.time() - t0

    rows: List[Row] = []
    measured_by_policy, model_by_policy = {}, {}
    for j, pol in enumerate(policies):
        tree = trees[j]
        eff = policy_effective_phi(phi, sys_small, pol)
        c = np.asarray(cost_vector(eff, sys_small), np.float64)
        model = SESSIONS @ c
        measured = np.array([r.avg_io_per_query for r in fleet[j]])
        measured_by_policy[pol] = measured
        model_by_policy[pol] = model
        shape = tree.shape()
        last_runs = len(shape[-1][1]) if shape else 0
        max_tomb_age = max(
            (tree.flush_seq - ts for lv in tree.store.levels
             for ts in lv.tomb_seqs if ts >= 0), default=0)
        rows.append(Row(
            f"compaction_{pol}", 0.0,
            measured_io=[round(float(x), 3) for x in measured],
            model_io=[round(float(x), 3) for x in model],
            agreement_ratio=round(float(measured.mean() / model.mean()), 3),
            last_level_runs=last_runs,
            max_tombstone_age_flushes=int(max_tomb_age),
            dead_keys_resurfaced=sum(
                tree.get(int(k)) is not None for k in dead[:200]),
        ))

    # model-vs-system ranking agreement, pairwise per drifted session: only
    # pairs the model actually distinguishes (>2% predicted gap) count —
    # klsm/partial/tombstone_ttl share a steady-state profile, so the model
    # deliberately predicts ties for them
    agree = total = 0
    for s in range(len(SESSIONS)):
        for a in range(len(policies)):
            for b in range(a + 1, len(policies)):
                dm = model_by_policy[policies[a]][s] \
                    - model_by_policy[policies[b]][s]
                if abs(dm) < 0.02 * model_by_policy[policies[a]][s]:
                    continue
                de = measured_by_policy[policies[a]][s] \
                    - measured_by_policy[policies[b]][s]
                total += 1
                agree += (dm > 0) == (de > 0)
    lazy_w = float(measured_by_policy["lazy_leveling"][3])
    klsm_w = float(measured_by_policy["klsm"][3])
    ttl_tree = trees[policies.index("tombstone_ttl")]
    rows.append(Row(
        "compaction_summary", 0.0,
        policies=len(policies),
        pairwise_rank_agreement=f"{agree}/{total}",
        lazy_beats_leveling_on_writes=lazy_w < klsm_w,
        ttl_bound_holds=all(
            ttl_tree.flush_seq - ts < TTL_FLUSHES
            for lv in ttl_tree.store.levels
            for ts in lv.tomb_seqs if ts >= 0),
    ))
    rows.append(Row(
        "compaction_fleet", (populate_s + fleet_s) * 1e6,
        n_keys=N_KEYS, n_queries=QUERIES, trees=len(trees),
        sessions_per_tree=len(SESSIONS),
        populate_s=round(populate_s, 2),
        engine_s=round(populate_s + fleet_s, 2),
    ))
    return rows
