"""Scenario stress suite: the named workload generators + the live adversary.

The online suite (``bench_online_drift``) moves mixes along synthetic
paths; this suite replays the richer stress patterns of
``src/repro/scenarios/`` (``docs/scenarios.md``) on the executable engine
and measures three arms per scenario — ``stale_nominal`` (tuned once for
the expected mix), ``static_robust`` (one ENDURE robust tuning at the
measured ``rho_source="from_history"`` budget), and ``online`` (the
adaptive loop) — plus the ``oracle`` upper bound for context:

* ``zipf_migrate`` — Zipf-skewed reads whose hot set rotates per segment;
* ``burst_storm`` — periodic read-heavy flash crowds at ``amplitude`` x
  baseline volume, watched by the Page-Hinkley change-point detector;
* ``tombstone_churn`` — write-dominant delete churn against a read-tuned
  deployment (expected mix is the read-trimodal w11);
* ``scan_heavy`` — mix ramps toward range scans while the scans widen;
* ``adversary`` — the robust objective's inner max played live: each
  segment the worst-case mix inside the defender's rho-ball is solved
  exactly and executed against every arm, emitting per-window measured
  regret next to the independently-solved KL dual bound.

Every scenario drifts toward *expensive* query classes relative to its
expected mix — the direction the KL worst case tilts and the robust
hedge anticipates (see "direction matters" in ``docs/online.md``).

Claims gated by ``--check`` (see ``CHECK_METRICS['scenarios']``): on
every scenario ``static_robust >= stale_nominal`` in throughput (the
paper's hedge survives every named stress pattern), and on every
adversary window the realized model cost stays under the KL dual bound
(``claim_regret_le_dual_bound`` — Eq. 13 measured live, zero duality
gap between the primal tilt solve and the 1-D dual minimization).
"""

from __future__ import annotations

from typing import List

from repro.api import (DesignSpec, DriftSpec, ExperimentSpec, Row,
                       WorkloadSpec, run_experiment)
from repro.core import EXPECTED_WORKLOADS

N_KEYS = 100_000
SEGMENTS = 8
SEG_QUERIES = 600            # baseline; burst segments arrive at amplitude x
KEY_SPACE = 2 ** 26
RANGE_FRACTION = 5e-4
BITS_PER_ENTRY = 6.0
MAX_T = 30

#: (kind, expected workload index, history drift row, scenario_params,
#: detector).  The history row feeds ``rho_source="from_history"`` — the
#: robust arm's budget is the *measured* KL of the drift the scenario
#: executes, not a guessed rho.  Expected mixes: write-heavy w4 for the
#: read-tilting scenarios, read-trimodal w11 for tombstone churn (so the
#: write-dominant churn is the expensive direction).  The adversary's
#: history row is milder: it keeps the defender's ball non-degenerate
#: (rho < ln 4), so the inner max stays an interior tilt rather than a
#: point mass — the regime where the dual-bound cross-check has teeth.
SCENARIOS = (
    ("zipf_migrate", 4, (0.10, 0.70, 0.10, 0.10), (), "kl"),
    ("burst_storm", 4, (0.25, 0.60, 0.10, 0.05),
     (("amplitude", 6.0), ("period", 3)), "page_hinkley"),
    ("tombstone_churn", 11, (0.05, 0.10, 0.05, 0.80), (), "kl"),
    ("scan_heavy", 4, (0.05, 0.10, 0.80, 0.05), (), "kl"),
    ("adversary", 4, (0.10, 0.25, 0.10, 0.55), (), "kl"),
)

ARMS = ("stale_nominal", "static_robust", "online", "oracle")

SYSTEM = (("N", float(N_KEYS)), ("entry_bits", 64.0 * 8),
          ("page_bits", 4096.0 * 8), ("bits_per_entry", BITS_PER_ENTRY),
          ("min_buf_bits", 64.0 * 8 * 64), ("s_rq", 2e-5),
          ("max_T", float(MAX_T)))


def make_spec(kind: str, widx: int, history_row, scenario_params,
              detector: str, n_keys: int = N_KEYS,
              segments: int = SEGMENTS,
              seg_queries: int = SEG_QUERIES) -> ExperimentSpec:
    expected = tuple(float(x) for x in EXPECTED_WORKLOADS[widx])
    return ExperimentSpec(
        name=f"scenarios_{kind}",
        workload=WorkloadSpec(indices=(widx,), nominal=True,
                              rho_source="from_history",
                              history=(expected, tuple(history_row))),
        design=DesignSpec(seed=0),
        drift=DriftSpec(kind=kind, segments=segments, n_queries=seg_queries,
                        scenario_params=tuple(scenario_params),
                        detector=detector, n_keys=n_keys,
                        key_space=KEY_SPACE, range_fraction=RANGE_FRACTION,
                        key_seed=100, estimator="window", window=4,
                        capacity=64, kl_threshold=0.2, budget_slack=1.0,
                        min_windows=2, cooldown=2,
                        retune_starts=16, retune_steps=120),
        system=SYSTEM)


def run(n_keys: int = N_KEYS, segments: int = SEGMENTS,
        seg_queries: int = SEG_QUERIES) -> List[Row]:
    rows: List[Row] = []
    orderings = []
    regret_claims = []
    drift_s = tuning_s = 0.0
    for kind, widx, history_row, params, detector in SCENARIOS:
        report = run_experiment(make_spec(kind, widx, history_row, params,
                                          detector, n_keys, segments,
                                          seg_queries))
        res = {arm: report.drift[(0, arm)] for arm in ARMS}
        tp = {arm: r.throughput for arm, r in res.items()}
        # same 0.999 machine-noise slack as the online suite's ordering
        ordered = tp["static_robust"] >= tp["stale_nominal"] * 0.999
        orderings.append((kind, ordered))
        drift_s += report.walls["drift_s"]
        tuning_s += report.walls["tuning_s"]
        rho0 = report.cells[-1][1]
        derived = dict(
            tp_stale_nominal=round(tp["stale_nominal"], 4),
            tp_static_robust=round(tp["static_robust"], 4),
            tp_online=round(tp["online"], 4),
            tp_oracle=round(tp["oracle"], 4),
            claim_robust_ge_stale=ordered,
            online_retunes=res["online"].retunes,
            rho_from_history=round(float(rho0), 3),
            segment_queries=[r.queries for r in res["online"].records],
            segment_io_robust=[round(r.avg_io_per_query, 3)
                               for r in res["static_robust"].records],
            segment_io_stale=[round(r.avg_io_per_query, 3)
                              for r in res["stale_nominal"].records],
        )
        if kind == "adversary":
            recs = report.regret[0]
            claim = bool(all(r["le_dual_bound"] for r in recs))
            regret_claims.append(claim)
            derived.update(
                defender=recs[-1]["defender"],
                claim_regret_le_dual_bound=claim,
                max_regret=round(max(r["regret"] for r in recs), 6),
                max_kl_adv=round(max(r["kl_adv"] for r in recs), 6),
                bound_margin_min=round(
                    min(r["dual_bound"] - r["cost_adv"] for r in recs), 6),
            )
        rows.append(Row(f"scenarios_{kind}", 0.0, **derived))
    rows.append(Row(
        "scenarios_fleet", drift_s * 1e6,
        n_keys=n_keys, segments=segments, seg_queries=seg_queries,
        scenarios=len(SCENARIOS), arms=len(ARMS),
        tuning_s=round(tuning_s, 2), engine_s=round(drift_s, 2),
    ))
    rows.append(Row(
        "scenarios_summary", 0.0,
        claim_robust_ge_stale=all(ok for _, ok in orderings),
        claim_regret_le_dual_bound=all(regret_claims),
        ordering={kind: ok for kind, ok in orderings},
    ))
    return rows
