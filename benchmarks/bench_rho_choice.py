"""Paper Figure 9: Delta(Phi_N, Phi_R) over the (rho, observed-KL) plane.

Claim: nominal wins only (1) when the observed workload is ~= expected
(KL ~ 0) or (2) when rho < 0.2 while real variation is higher; elsewhere
robust dominates.  Rule of thumb validated: pick rho ~= max pairwise KL of
observed workloads.

One declarative spec: w7 x six rhos + nominal, model-scored over B."""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.api import ExperimentSpec, Row, WorkloadSpec, run_experiment
from repro.core import EXPECTED_WORKLOADS, kl_divergence

RHOS = (0.1, 0.25, 0.5, 1.0, 2.0, 3.0)
KL_BINS = [(0.0, 0.2), (0.2, 0.6), (0.6, 1.2), (1.2, 2.5), (2.5, 10.0)]

SPEC = ExperimentSpec(
    name="fig9",
    workload=WorkloadSpec(indices=(7,), rhos=RHOS, nominal=True,
                          bench_n=10_000, bench_seed=0),
)


def run() -> List[Row]:
    import jax.numpy as jnp
    t0 = time.time()
    report = run_experiment(SPEC)
    w7 = EXPECTED_WORKLOADS[7]
    kls = np.asarray([float(kl_divergence(jnp.asarray(w), jnp.asarray(w7)))
                      for w in report.bench_set])

    grid = {}
    for rho in RHOS:
        d = report.delta_tp_vs_nominal(0, rho)
        for lo, hi in KL_BINS:
            sel = (kls >= lo) & (kls < hi)
            if sel.any():
                grid[(rho, lo)] = float(d[sel].mean())
    us = (time.time() - t0) * 1e6

    # nominal should only win near (small KL) or (tiny rho)
    nominal_wins = [(rho, lo) for (rho, lo), v in grid.items() if v < 0]
    ok = all(lo < 0.2 or rho < 0.2 for rho, lo in nominal_wins)
    robust_region = [v for (rho, lo), v in grid.items()
                     if rho >= 0.25 and lo >= 0.2]
    return [Row("fig9_rho_choice", us,
                claim_nominal_wins_only_near_zero=ok,
                mean_gain_in_robust_region=round(float(np.mean(
                    robust_region)), 3),
                n_grid_cells=len(grid),
                worst_cell=round(float(np.min(list(grid.values()))), 3))]
