"""Shared helpers for the paper-reproduction benchmarks.

The row/formatting/metric layer lives in :mod:`repro.api.report` (the
unified report schema) since the experiment-API refactor; this module keeps
the benchmark-local singletons (the paper-scale system and the Section 7
benchmark set) and re-exports the helpers so pre-facade suites keep their
imports.
"""

from __future__ import annotations

import numpy as np

from repro.api.report import (Row, costs_over_benchmark, delta_tp, fmt,
                              timed)
from repro.core import LSMSystem, sample_benchmark

__all__ = ["SYS", "B_SET", "Row", "timed", "fmt", "costs_over_B",
           "delta_tp"]

SYS = LSMSystem()
B_SET = sample_benchmark(10_000, seed=0)


def costs_over_B(phi, sys=SYS) -> np.ndarray:
    """C(w, phi) for every workload in the benchmark set (vectorized)."""
    return costs_over_benchmark(phi, sys, B_SET)
