"""Shared helpers for the paper-reproduction benchmarks."""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import (EXPECTED_WORKLOADS, DesignSpace, LSMSystem,
                        cost_vector, sample_benchmark, tune_nominal,
                        tune_robust)

SYS = LSMSystem()
B_SET = sample_benchmark(10_000, seed=0)


def timed(fn: Callable, *args, **kw) -> Tuple[float, object]:
    t0 = time.time()
    out = fn(*args, **kw)
    return (time.time() - t0) * 1e6, out


class Row:
    """One CSV output row: name,us_per_call,derived."""

    def __init__(self, name: str, us: float, **derived):
        self.name = name
        self.us = us
        self.derived = derived

    def csv(self) -> str:
        d = ";".join(f"{k}={v}" for k, v in self.derived.items())
        return f"{self.name},{self.us:.1f},{d}"


def fmt(x: float) -> str:
    return f"{x:.4g}"


def costs_over_B(phi, sys=SYS) -> np.ndarray:
    """C(w, phi) for every workload in the benchmark set (vectorized)."""
    c = np.asarray(cost_vector(phi, sys), np.float64)
    return B_SET @ c


def delta_tp(cn: np.ndarray, cr: np.ndarray) -> np.ndarray:
    """Normalized delta throughput of robust (cr) vs nominal (cn)."""
    return (1.0 / cr - 1.0 / cn) / (1.0 / cn)
