"""Roofline table: measured kernel cells + compiled dry-run aggregation.

Two kinds of rows:

* ``roofline_kernels`` — **measured on this host**.  Every kernel-tier
  cell (``bench_kernels.measure_cells``: fused point read, warm dual
  solve, compaction merge) is timed for real, its effective bytes are
  derived from the engine's own I/O accounting, and the achieved
  bytes/s is placed against a *measured* roofline ceiling: the host's
  large-array copy bandwidth (best-of-N ``np.copyto``, read + write
  charged).  ``measured_cells`` counts the cells that produced a finite
  achieved-bandwidth number and is perf-gated — the table can never
  silently go vacuous again (an all-empty run raises instead of
  emitting zero rows; see the PR-7 issue: the previous implementation
  reported ``cells 0/40, ok 0, us 0.0`` forever).
* ``roofline_single`` / ``roofline_multipod`` — aggregation of the
  512-device compiled dry-run artifacts (``launch/dryrun.py``, a
  separate process).  When ``experiments/dryrun`` holds no artifacts
  these rows now say so explicitly (``cells="skipped"`` plus a reason)
  instead of masquerading as a measurement.
"""

from __future__ import annotations

import json
import math
import pathlib
import time
from collections import Counter
from typing import Dict, List

import numpy as np

from repro.configs import ARCHS, SHAPES
from repro.utils.roofline import TABLE_HEADER
from .common import Row

DRYRUN_DIR = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"

#: why a dryrun row is skipped (kept one place so tests can match it)
NO_ARTIFACTS = ("no dry-run artifacts under experiments/dryrun "
                "(launch/dryrun.py is a separate 512-device process)")


def load_records(mesh: str, tag: str = "baseline") -> dict:
    out = {}
    for f in sorted(DRYRUN_DIR.glob(f"*__{mesh}__{tag}.json")):
        r = json.loads(f.read_text())
        out[(r["arch"], r["shape"])] = r
    return out


def markdown_table(mesh: str, tag: str = "baseline") -> str:
    recs = load_records(mesh, tag)
    lines = [TABLE_HEADER]
    for (arch, shape), r in sorted(recs.items()):
        if r["status"] == "skipped":
            lines.append(f"| {arch} | {shape} | {mesh} | — | — | — | "
                         f"skipped: {r['reason'][:60]} | — | — | — |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {arch} | {shape} | {mesh} | ERROR | | | | | | |")
            continue
        t = r["roofline"]
        col = ",".join(f"{a}:{v*1e3:.1f}"
                       for a, v in sorted(t["collective_by_axis"].items()))
        mem = t.get("memory_per_dev_gb")
        lines.append(
            f"| {arch} | {shape} | {mesh} | {t['compute_s']*1e3:.1f} "
            f"| {t['memory_s']*1e3:.1f} | {t['collective_s']*1e3:.1f} ({col}) "
            f"| **{t['bottleneck']}** | {t['useful_ratio']:.2f} "
            f"| {t['roofline_frac']:.2f} "
            f"| {mem:.2f} |" if mem is not None else
            f"| {arch} | {shape} | {mesh} | ... | - |")
    return "\n".join(lines)


def host_copy_gbps(nbytes: int = 1 << 26, repeats: int = 5) -> float:
    """Measured roofline ceiling: streaming copy bandwidth on this host.

    Best-of-N ``np.copyto`` over a 64 MiB array (large enough to defeat
    L2/L3 on common parts); read + write both charged, matching how the
    kernel cells charge their effective bytes.
    """
    src = np.ones(nbytes // 8, np.uint64)
    dst = np.empty_like(src)
    best = math.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        np.copyto(dst, src)
        best = min(best, time.perf_counter() - t0)
    return 2 * nbytes / best / 1e9


def _kernel_cells_row() -> Row:
    from .bench_kernels import measure_cells
    peak = host_copy_gbps()
    cells: Dict[str, Dict[str, float]] = {}
    for name, d in measure_cells().items():
        g = d.get("achieved_gbps")
        if not isinstance(g, (int, float)) or not math.isfinite(g) or g <= 0:
            cells[name] = {"achieved_gbps": None,
                           "skipped_reason": "no finite bandwidth measured"}
            continue
        cells[name] = {
            "achieved_gbps": g,
            "frac_of_copy_peak": g / peak,
            "effective_bytes": d.get("effective_bytes"),
            "us": d.get("us_numpy", d.get("us_fused")),
        }
    measured = sum(1 for c in cells.values()
                   if c.get("achieved_gbps") is not None)
    return Row("roofline_kernels", 0.0,
               measured_cells=measured,
               copy_peak_gbps=peak,
               cells=cells)


def run() -> List[Row]:
    rows: List[Row] = [_kernel_cells_row()]
    any_dryrun = False
    for mesh in ("single", "multipod"):
        recs = load_records(mesh)
        expected = len(ARCHS) * len(SHAPES)
        if not recs:
            rows.append(Row(f"roofline_{mesh}", 0.0, cells="skipped",
                            expected=expected, skipped_reason=NO_ARTIFACTS))
            continue
        any_dryrun = True
        statuses = Counter(r["status"] for r in recs.values())
        bottl = Counter(r["roofline"]["bottleneck"] for r in recs.values()
                        if r["status"] == "ok")
        fits = [r for r in recs.values() if r["status"] == "ok"
                and (r["roofline"].get("memory_per_dev_gb") or 0) <= 16.0]
        oks = [r for r in recs.values() if r["status"] == "ok"]
        worst = min(oks, key=lambda r: r["roofline"]["roofline_frac"],
                    default=None)
        rows.append(Row(
            f"roofline_{mesh}", 0.0,
            cells=f"{len(recs)}/{expected}",
            ok=statuses.get("ok", 0),
            skipped=statuses.get("skipped", 0),
            errors=statuses.get("error", 0),
            all_compile=statuses.get("error", 0) == 0,
            bottlenecks=dict(bottl),
            fits_16gb=f"{len(fits)}/{len(oks)}",
            worst_cell=(f"{worst['arch']}x{worst['shape']}"
                        f"={worst['roofline']['roofline_frac']:.3f}"
                        if worst else "n/a"),
        ))
    measured = rows[0].derived["measured_cells"]
    if measured == 0 and not any_dryrun:
        # The one failure mode this rewrite exists to kill: an all-empty
        # "roofline" that still exits 0 and commits a vacuous baseline.
        raise RuntimeError(
            "roofline measured nothing: no kernel cell produced a finite "
            "bandwidth and no dry-run artifacts exist — refusing to emit "
            "a vacuous table")
    return rows
