"""Roofline aggregation: reads experiments/dryrun/*.json (written by
repro.launch.dryrun) into the EXPERIMENTS.md tables.

This bench does not compile anything itself — the dry-run is a separate,
512-device process (see launch/dryrun.py).  Here we summarize per-cell
terms, check coverage (every (arch x shape) present per mesh), and emit the
markdown roofline table."""

from __future__ import annotations

import json
import pathlib
from collections import Counter
from typing import List

from repro.configs import ARCHS, SHAPES
from repro.utils.roofline import TABLE_HEADER
from .common import Row

DRYRUN_DIR = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load_records(mesh: str, tag: str = "baseline") -> dict:
    out = {}
    for f in sorted(DRYRUN_DIR.glob(f"*__{mesh}__{tag}.json")):
        r = json.loads(f.read_text())
        out[(r["arch"], r["shape"])] = r
    return out


def markdown_table(mesh: str, tag: str = "baseline") -> str:
    recs = load_records(mesh, tag)
    lines = [TABLE_HEADER]
    for (arch, shape), r in sorted(recs.items()):
        if r["status"] == "skipped":
            lines.append(f"| {arch} | {shape} | {mesh} | — | — | — | "
                         f"skipped: {r['reason'][:60]} | — | — | — |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {arch} | {shape} | {mesh} | ERROR | | | | | | |")
            continue
        t = r["roofline"]
        col = ",".join(f"{a}:{v*1e3:.1f}"
                       for a, v in sorted(t["collective_by_axis"].items()))
        mem = t.get("memory_per_dev_gb")
        lines.append(
            f"| {arch} | {shape} | {mesh} | {t['compute_s']*1e3:.1f} "
            f"| {t['memory_s']*1e3:.1f} | {t['collective_s']*1e3:.1f} ({col}) "
            f"| **{t['bottleneck']}** | {t['useful_ratio']:.2f} "
            f"| {t['roofline_frac']:.2f} "
            f"| {mem:.2f} |" if mem is not None else
            f"| {arch} | {shape} | {mesh} | ... | - |")
    return "\n".join(lines)


def run() -> List[Row]:
    rows: List[Row] = []
    for mesh in ("single", "multipod"):
        recs = load_records(mesh)
        statuses = Counter(r["status"] for r in recs.values())
        expected = len(ARCHS) * len(SHAPES)
        bottl = Counter(r["roofline"]["bottleneck"] for r in recs.values()
                        if r["status"] == "ok")
        fits = [r for r in recs.values() if r["status"] == "ok"
                and (r["roofline"].get("memory_per_dev_gb") or 0) <= 16.0]
        oks = [r for r in recs.values() if r["status"] == "ok"]
        worst = min(oks, key=lambda r: r["roofline"]["roofline_frac"],
                    default=None)
        rows.append(Row(
            f"roofline_{mesh}", 0.0,
            cells=f"{len(recs)}/{expected}",
            ok=statuses.get("ok", 0),
            skipped=statuses.get("skipped", 0),
            errors=statuses.get("error", 0),
            all_compile=statuses.get("error", 0) == 0,
            bottlenecks=dict(bottl),
            fits_16gb=f"{len(fits)}/{len(oks)}",
            worst_cell=(f"{worst['arch']}x{worst['shape']}"
                        f"={worst['roofline']['roofline_frac']:.3f}"
                        if worst else "n/a"),
        ))
    return rows
