"""Paper Figure 6 + Section 8.3: avg Delta-throughput of robust vs nominal
per workload category, as a function of rho.

Paper claims reproduced here:
  * >= 95% average improvement for unimodal/bimodal/trimodal expected
    workloads once rho >= 0.5;
  * uniform (w0) is the one case where nominal stays ~5% ahead;
  * robust tunings win the overwhelming majority of the ~2M comparisons.

The whole figure — 15 nominal tunings plus the full 15-workload x 5-rho
robust grid — is two device dispatches (`tune_nominal_many` +
`tune_robust_many`); only the benchmark-set evaluation happens per cell.
"""

from __future__ import annotations

import time
from collections import defaultdict
from typing import List

import numpy as np

from repro.core import (EXPECTED_WORKLOADS, WORKLOAD_CATEGORY,
                        tune_nominal_many, tune_robust_many)
from .common import SYS, Row, costs_over_B, delta_tp

RHOS = (0.25, 0.5, 1.0, 2.0, 3.0)


def run() -> List[Row]:
    t0 = time.time()
    nominal = tune_nominal_many(EXPECTED_WORKLOADS, SYS, seed=0)
    robust_grid = tune_robust_many(EXPECTED_WORKLOADS, RHOS, SYS, seed=0)

    cat_delta = defaultdict(lambda: defaultdict(list))
    wins = total = 0
    for widx in range(len(EXPECTED_WORKLOADS)):
        cat = WORKLOAD_CATEGORY[widx]
        cn = costs_over_B(nominal[widx].phi)
        for j, rho in enumerate(RHOS):
            cr = costs_over_B(robust_grid[widx][j].phi)
            d = delta_tp(cn, cr)
            cat_delta[cat][rho].append(float(d.mean()))
            wins += int((d > 0).sum())
            total += d.size
    us = (time.time() - t0) * 1e6

    rows: List[Row] = []
    for cat, per_rho in cat_delta.items():
        derived = {f"avg_delta_rho{rho}": round(float(np.mean(v)), 3)
                   for rho, v in per_rho.items()}
        rows.append(Row(f"fig6_avg_delta_{cat}", us / 4, **derived))

    win_rate = wins / max(total, 1)
    nonuni = [np.mean(cat_delta[c][rho])
              for c in ("unimodal", "bimodal", "trimodal")
              for rho in (0.5, 1.0, 2.0)]
    rows.append(Row(
        "fig6_summary", us,
        robust_win_rate=round(win_rate, 3),
        claim_win_majority=win_rate > 0.8,          # paper: >80% of comps
        min_nonuniform_gain_rho_ge_05=round(float(np.min(nonuni)), 3),
        claim_95pct_gain=bool(np.mean(nonuni) > 0.95),
        max_delta=round(float(np.max([v for d in cat_delta.values()
                                      for vs in d.values()
                                      for v in np.atleast_1d(vs)])), 2),
    ))
    return rows
