"""Paper Figure 6 + Section 8.3: avg Delta-throughput of robust vs nominal
per workload category, as a function of rho.

Paper claims reproduced here:
  * >= 95% average improvement for unimodal/bimodal/trimodal expected
    workloads once rho >= 0.5;
  * uniform (w0) is the one case where nominal stays ~5% ahead;
  * robust tunings win the overwhelming majority of the ~2M comparisons.

The whole figure is one declarative :class:`repro.api.ExperimentSpec` —
all 15 expected workloads x 5 rhos plus the nominal baselines, with model
evaluation over the Section 7 benchmark set — lowered by the facade onto
two batched-tuner dispatches.
"""

from __future__ import annotations

import time
from collections import defaultdict
from typing import List

import numpy as np

from repro.api import ExperimentSpec, Row, WorkloadSpec, run_experiment
from repro.core import WORKLOAD_CATEGORY

RHOS = (0.25, 0.5, 1.0, 2.0, 3.0)

SPEC = ExperimentSpec(
    name="fig6",
    workload=WorkloadSpec(indices=tuple(range(15)), rhos=RHOS,
                          nominal=True, bench_n=10_000, bench_seed=0),
)


def run() -> List[Row]:
    t0 = time.time()
    report = run_experiment(SPEC)

    cat_delta = defaultdict(lambda: defaultdict(list))
    wins = total = 0
    for widx in range(15):
        cat = WORKLOAD_CATEGORY[widx]
        for rho in RHOS:
            d = report.delta_tp_vs_nominal(widx, rho)
            cat_delta[cat][rho].append(float(d.mean()))
            wins += int((d > 0).sum())
            total += d.size
    us = (time.time() - t0) * 1e6

    rows: List[Row] = []
    for cat, per_rho in cat_delta.items():
        derived = {f"avg_delta_rho{rho}": round(float(np.mean(v)), 3)
                   for rho, v in per_rho.items()}
        rows.append(Row(f"fig6_avg_delta_{cat}", us / 4, **derived))

    win_rate = wins / max(total, 1)
    nonuni = [np.mean(cat_delta[c][rho])
              for c in ("unimodal", "bimodal", "trimodal")
              for rho in (0.5, 1.0, 2.0)]
    rows.append(Row(
        "fig6_summary", us,
        robust_win_rate=round(win_rate, 3),
        claim_win_majority=win_rate > 0.8,          # paper: >80% of comps
        min_nonuniform_gain_rho_ge_05=round(float(np.min(nonuni)), 3),
        claim_95pct_gain=bool(np.mean(nonuni) > 0.95),
        max_delta=round(float(np.max([v for d in cat_delta.values()
                                      for vs in d.values()
                                      for v in np.atleast_1d(vs)])), 2),
    ))
    return rows
