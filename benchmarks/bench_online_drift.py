"""Online drift: the paper's robustness claim, reproduced *dynamically*.

ENDURE argues a robust tuning protects against executed workloads that
drift from the expected one; the :mod:`repro.online` subsystem closes the
loop by observing the drift and re-tuning.  This suite replays three drift
scenarios on the executable engine at 250k keys x 10k queries per
deployment and measures four arms per scenario:

* ``stale_nominal`` — tuned once for the expected mix, never re-tuned
  (the static-input baseline the rest of the repo assumes);
* ``static_robust`` — ENDURE's answer: one robust tuning whose rho comes
  from the observed history (``rho_source="from_history"``), never
  re-tuned;
* ``online`` — starts from the same robust tuning, then runs the
  observe -> estimate -> re-tune loop (KL drift triggers, storm-batched
  ``tune_robust_many`` re-tunes, tuning swaps at flush boundaries whose
  transition compaction is charged to the workload);
* ``oracle`` — re-tuned every segment to the true upcoming mix: the
  adaptation upper bound.

Scenarios: *gradual* rotation (write-heavy w4 ramps to the read-trimodal
w11), *abrupt flip* (w7 switches to non-empty-read-heavy mid-run), and
*cyclic* alternation (w4 <-> w11 every segment).  All arms of a scenario
share the key population and the per-segment session plans, so throughput
differences are tuning differences.

Claims gated by ``--check`` (see ``CHECK_METRICS['online']``): on every
scenario online-adaptive >= static-robust >= stale-nominal in throughput,
and online-adaptive recovers >= 80% of the oracle.
"""

from __future__ import annotations

from typing import List

from repro.api import (DesignSpec, DriftSpec, ExperimentSpec, Row,
                       WorkloadSpec, run_experiment)
from repro.core import EXPECTED_WORKLOADS

N_KEYS = 250_000
SEGMENTS = 10
SEG_QUERIES = 1_000          # x SEGMENTS = 10k queries per deployment
KEY_SPACE = 2 ** 26          # tab5 conventions: dense keyspace, short ranges
RANGE_FRACTION = 1e-3
BITS_PER_ENTRY = 6.0
MAX_T = 30

#: (drift kind, expected workload index, drift target mix).  The expected
#: workload is write-heavy w4: its nominal tuning is write-optimized, so
#: drift toward the *expensive* read classes — the direction the KL worst
#: case tilts, i.e. what the robust hedge anticipates — is exactly where a
#: stale tuning bleeds.  (Drift toward cheap classes, e.g. z0-heavy, makes
#: every tuning faster and rewards nobody; see docs/online.md.)
SCENARIOS = (
    ("gradual", 4, (0.33, 0.33, 0.33, 0.01)),
    ("flip", 4, (0.475, 0.475, 0.04, 0.01)),
    ("cyclic", 4, (0.33, 0.33, 0.33, 0.01)),
)

SYSTEM = (("N", float(N_KEYS)), ("entry_bits", 64.0 * 8),
          ("page_bits", 4096.0 * 8), ("bits_per_entry", BITS_PER_ENTRY),
          ("min_buf_bits", 64.0 * 8 * 64), ("s_rq", 2e-5),
          ("max_T", float(MAX_T)))


def make_spec(kind: str, widx: int, target, n_keys: int = N_KEYS,
              segments: int = SEGMENTS,
              seg_queries: int = SEG_QUERIES) -> ExperimentSpec:
    expected = tuple(float(x) for x in EXPECTED_WORKLOADS[widx])
    return ExperimentSpec(
        name=f"online_{kind}",
        workload=WorkloadSpec(indices=(widx,), nominal=True,
                              rho_source="from_history",
                              history=(expected, tuple(target))),
        design=DesignSpec(seed=0),
        drift=DriftSpec(kind=kind, segments=segments, n_queries=seg_queries,
                        target=tuple(target), n_keys=n_keys,
                        key_space=KEY_SPACE, range_fraction=RANGE_FRACTION,
                        key_seed=100, estimator="window", window=4,
                        capacity=64, kl_threshold=0.2, budget_slack=1.0,
                        min_windows=2, cooldown=2,
                        retune_starts=32, retune_steps=200),
        system=SYSTEM)


def run(n_keys: int = N_KEYS, segments: int = SEGMENTS,
        seg_queries: int = SEG_QUERIES) -> List[Row]:
    rows: List[Row] = []
    recoveries = []
    orderings = []
    drift_s = tuning_s = 0.0
    for kind, widx, target in SCENARIOS:
        report = run_experiment(make_spec(kind, widx, target, n_keys,
                                          segments, seg_queries))
        res = {arm: report.drift[(0, arm)]
               for arm in ("stale_nominal", "static_robust", "online",
                           "oracle")}
        tp = {arm: r.throughput for arm, r in res.items()}
        recovery = tp["online"] / tp["oracle"]
        ordered = (tp["online"] >= tp["static_robust"] * 0.999
                   and tp["static_robust"] >= tp["stale_nominal"] * 0.999)
        recoveries.append(recovery)
        orderings.append(ordered)
        drift_s += report.walls["drift_s"]
        tuning_s += report.walls["tuning_s"]
        rho0 = report.cells[-1][1]
        rows.append(Row(
            f"online_{kind}", 0.0,
            tp_stale_nominal=round(tp["stale_nominal"], 4),
            tp_static_robust=round(tp["static_robust"], 4),
            tp_online=round(tp["online"], 4),
            tp_oracle=round(tp["oracle"], 4),
            online_retunes=res["online"].retunes,
            online_recovery=round(recovery, 3),
            claim_adaptive_ordering=ordered,
            rho_from_history=round(float(rho0), 3),
            segment_io_online=[round(r.avg_io_per_query, 3)
                               for r in res["online"].records],
            segment_io_stale=[round(r.avg_io_per_query, 3)
                              for r in res["stale_nominal"].records],
        ))
    rows.append(Row(
        "online_fleet", drift_s * 1e6,
        n_keys=n_keys, segments=segments, seg_queries=seg_queries,
        scenarios=len(SCENARIOS), arms=4,
        tuning_s=round(tuning_s, 2), engine_s=round(drift_s, 2),
    ))
    rows.append(Row(
        "online_summary", 0.0,
        claim_online_ge_robust_ge_stale=all(orderings),
        claim_online_recovers_oracle=min(recoveries) >= 0.8,
        online_recovery_min=round(min(recoveries), 3),
    ))
    return rows
