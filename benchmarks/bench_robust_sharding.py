"""Beyond-paper: robust mesh/layout selection from real dry-run records.

Builds layout candidates for archs with full 4-shape coverage from the
dry-run roofline step times (experiments/dryrun), then compares the nominal
pick (best for the expected traffic mix) with the ENDURE-style robust pick
(best worst case over a KL ball of mixes) under a long-context burst.

This is the paper's Section 11 observation — "the robust paradigm ...
can be applied to any database tuning problem" — instantiated on the
framework's own tuning problem, with cost vectors measured by the same
dry-run that produced the roofline tables."""

from __future__ import annotations

import pathlib
import time
from typing import List

import numpy as np

from repro.core.robust_sharding import (LayoutCandidate, adversarial_mix,
                                        candidates_from_dryrun,
                                        nominal_layout, robust_layout)
from .common import Row

DRYRUN = str(pathlib.Path(__file__).resolve().parents[1] / "experiments"
             / "dryrun")
# archs that run all four shapes (incl. long_500k)
ARCHS = ("mixtral-8x7b", "jamba-1.5-large-398b", "rwkv6-3b")


def run() -> List[Row]:
    rows: List[Row] = []
    expected = np.array([0.70, 0.15, 0.14, 0.01])   # training-dominated
    burst = np.array([0.30, 0.10, 0.20, 0.40])      # long-context burst
    for arch in ARCHS:
        t0 = time.time()
        cands = candidates_from_dryrun(arch, DRYRUN,
                                       tags=("baseline", "opt"))
        if len(cands) < 2:
            rows.append(Row(f"robust_sharding_{arch}", 0.0,
                            skipped="needs >=2 tagged dry-run configs"))
            continue
        nom = nominal_layout(cands, expected)
        rob = robust_layout(cands, expected, rho=1.0)
        adv = adversarial_mix(nom, expected, rho=1.0)
        us = (time.time() - t0) * 1e6
        rows.append(Row(
            f"robust_sharding_{arch}", us,
            candidates=len(cands),
            nominal=nom.name.split(":")[1],
            robust=rob.name.split(":")[1],
            nominal_expected_s=round(nom.expected_cost(expected), 2),
            robust_worst_case_s=round(rob.worst_case, 2),
            nominal_worst_case_s=round(rob.nominal_worst_case, 2),
            robust_no_worse_in_worst_case=rob.worst_case
            <= rob.nominal_worst_case * (1 + 1e-6),
            nominal_burst_s=round(nom.expected_cost(burst), 2),
            robust_burst_s=round(rob.expected_cost(burst), 2),
            adversarial_mix_long_frac=round(float(adv[3]), 3),
        ))
    return rows
