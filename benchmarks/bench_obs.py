"""Telemetry plane: overhead, result identity, measured-cost calibration.

Three contracts of :mod:`repro.obs`, each measured on the same scaled-down
compaction fleet (the four-policy, four-drifted-session design of
``bench_compaction_space``, shrunk so the suite re-runs it five times):

  * **overhead** — the fully instrumented engine (spans on flush /
    compaction / retune, per-batch read counters, per-window session
    events) costs <= 5% wall time over the disabled path.  Disabled-path
    calls are a single ``None`` check, so the tax only exists while a
    trace is actually being captured.
  * **identity** — tracing never perturbs results: per-session avg I/O,
    window op counts, and observed mixes are bit-identical between the
    enabled and disabled legs (telemetry only *reads* IOStats deltas).
  * **calibration** — the captured ``session.execute`` spans are enough
    to refit the cost model's profile constants (per-op I/O weights per
    policy, the lazy-leveling fill factor) via :mod:`repro.obs.calibrate`,
    and the fitted model agrees with measurement at least as well as the
    hand-calibrated constants for EVERY policy (the gate
    ``claim_fit_ge_hand``).  When ``REPRO_OBS_OUT`` is set (the harness's
    ``--trace DIR``), the calibration artifact is written there.
"""

from __future__ import annotations

import os
from typing import List

import numpy as np

from repro import obs
from repro.api import (DesignSpec, ExperimentSpec, Row, TrialSpec,
                       WorkloadSpec, run_experiment)
from repro.obs.calibrate import calibrate, write_calibration

N_KEYS = 50_000
QUERIES = 2_500
KEY_SPACE = 2 ** 24
RANGE_FRACTION = 1e-3
BITS_PER_ENTRY = 6.0
TTL_FLUSHES = 8
T, FILT_BPE = 6, 4.0
REPS = 2              # timed repetitions per leg (after a shared warmup)
OVERHEAD_BOUND = 1.05

POLICIES = ("klsm", "lazy_leveling", "partial", "tombstone_ttl")
SESSIONS = (
    (0.85, 0.05, 0.05, 0.05),
    (0.05, 0.85, 0.05, 0.05),
    (0.05, 0.05, 0.85, 0.05),
    (0.05, 0.05, 0.05, 0.85),
)

SPEC = ExperimentSpec(
    name="obs",
    workload=WorkloadSpec(workloads=((0.25, 0.25, 0.25, 0.25),),
                          rhos=(), nominal=True),
    design=DesignSpec(fixed=(float(T), FILT_BPE, 1.0), policies=POLICIES,
                      policy_params=(
                          ("lazy_leveling", (("read_trigger", 512),)),
                          ("partial", (("parts", 4),)),
                          ("tombstone_ttl", (("ttl_flushes", TTL_FLUSHES),)),
                      )),
    trial=TrialSpec(n_keys=N_KEYS, n_queries=QUERIES, sessions=SESSIONS,
                    key_space=KEY_SPACE, range_fraction=RANGE_FRACTION,
                    key_seed=77, session_seeds=(300, 301, 302, 303),
                    delete_fraction=0.01),
    system=(("N", float(N_KEYS)), ("entry_bits", 64.0 * 8),
            ("page_bits", 4096.0 * 8), ("bits_per_entry", BITS_PER_ENTRY),
            ("min_buf_bits", 64.0 * 8 * 64), ("s_rq", RANGE_FRACTION),
            ("max_T", 30.0)),
)
CELL = (0, None)


def _engine_s(report) -> float:
    return float(report.walls["populate_s"] + report.walls["fleet_s"])


def _run_leg(traced: bool):
    """One fleet run with telemetry on/off; returns (report, engine_s,
    events) — events empty on the disabled leg."""
    with obs.scoped(enabled=traced, clock="wall") as t:
        report = run_experiment(SPEC)
        events = t.events_snapshot() if t is not None else []
    return report, _engine_s(report), events


def _fleet_signature(report):
    """Everything the engine measured, exactly: per-(policy, session)
    avg I/O and the full per-window op-count matrices."""
    sig = {}
    for pol in POLICIES:
        for i, res in enumerate(report.fleet[(CELL, pol)]):
            sig[(pol, i)] = (float(res.avg_io_per_query),
                             np.asarray(res.window_ops).copy())
    return sig


def run() -> List[Row]:
    rows: List[Row] = []

    _run_leg(traced=False)                    # warmup: jit compiles, caches
    disabled, enabled = [], []
    events, report_on, report_off = [], None, None
    for _ in range(REPS):
        report_off, s_off, _ = _run_leg(traced=False)
        disabled.append(s_off)
        report_on, s_on, ev = _run_leg(traced=True)
        enabled.append(s_on)
        events = ev                           # any rep's ring will do
    off_s = float(np.median(disabled))
    on_s = float(np.median(enabled))
    ratio = on_s / off_s
    rows.append(Row(
        "obs_overhead", 0.0,
        overhead_ratio=round(ratio, 4),
        overhead_bound=OVERHEAD_BOUND,
        enabled_engine_s=round(on_s, 3),
        disabled_engine_s=round(off_s, 3),
        reps=REPS,
    ))

    sig_on = _fleet_signature(report_on)
    sig_off = _fleet_signature(report_off)
    identical = sig_on.keys() == sig_off.keys() and all(
        sig_on[k][0] == sig_off[k][0]
        and np.array_equal(sig_on[k][1], sig_off[k][1])
        for k in sig_on)
    rows.append(Row(
        "obs_identity", 0.0,
        claim_bit_identical=bool(identical),
        sessions_compared=len(sig_on),
        trees=len(POLICIES),
    ))

    cal = calibrate(
        events,
        model_costs=report_on.model_costs[CELL],
        phi_by_policy={p: report_on.tuning(CELL, p).phi for p in POLICIES},
        sys=report_on.sys,
        policy_params=SPEC.design.policy_params,
    )
    out_dir = os.environ.get("REPRO_OBS_OUT")
    if out_dir:
        write_calibration(os.path.join(out_dir, "calibration_obs.json"), cal)
    lazy = cal["policies"].get("lazy_leveling", {})
    rows.append(Row(
        "obs_calibration", 0.0,
        claim_fit_ge_hand=bool(cal["all_fitted_ge_hand"]),
        policies_fit=len(cal["policies"]),
        closeness_hand={p: f["closeness_hand"]
                        for p, f in cal["policies"].items()},
        closeness_fitted={p: f["closeness_fitted"]
                          for p, f in cal["policies"].items()},
        lazy_fill_hand=lazy.get("fill", {}).get("fill_hand"),
        lazy_fill_fitted=lazy.get("fill", {}).get("fill_fitted"),
    ))

    n_spans = sum(ev.get("kind") == "span" for ev in events)
    rows.append(Row(
        "obs_trace", 0.0,
        events=len(events),
        spans=n_spans,
        session_spans=sum(ev.get("name") == "session.execute"
                          for ev in events),
    ))
    rows.append(Row(
        "obs_fleet", off_s * 1e6,
        n_keys=N_KEYS, n_queries=QUERIES, trees=len(POLICIES),
        sessions_per_tree=len(SESSIONS),
        engine_s=round(off_s, 2),
    ))
    return rows
